"""Cross-language contract: constants the Rust side mirrors.

`rust/src/forecast/predictors.rs` re-implements the predictor bank and
`rust/tests/it_runtime_artifacts.rs` checks numerics through the
compiled artifact; this file pins the *layout* contract from the Python
side so a drift fails fast in `make test` before the Rust suite runs.
"""

import numpy as np

from compile.kernels import forecast as fk
from compile.kernels import ref
from compile.kernels.common import (
    AOT_ATTRS,
    AOT_REPLICAS,
    AOT_REQUESTS,
    AOT_SITES,
    AOT_WINDOW,
    EMA_ALPHAS,
    NUM_PREDICTORS,
    TILE_SITES,
    WINDOW_LONG,
    WINDOW_SHORT,
)


class TestBankLayout:
    def test_bank_constants(self):
        # Mirrored in rust/src/forecast/predictors.rs — do not change
        # one side without the other.
        assert NUM_PREDICTORS == 8
        assert WINDOW_SHORT == 4
        assert WINDOW_LONG == 16
        assert EMA_ALPHAS == (0.10, 0.30, 0.60)

    def test_aot_shapes(self):
        assert AOT_SITES % TILE_SITES == 0
        assert AOT_SITES == 128 and AOT_WINDOW == 64
        assert (AOT_REPLICAS, AOT_REQUESTS, AOT_ATTRS) == (128, 8, 8)

    def test_predictor_index_semantics(self):
        """Pin each index's meaning with a series where they differ."""
        obs = np.array(
            [[10.0] * 16 + [100.0] * 4], np.float32
        ).repeat(4, 0)
        mask = np.ones_like(obs)
        p, _ = fk.forecast(obs, mask, tile_sites=4)
        p = np.asarray(p)[0]
        assert p[0] == 100.0  # last value
        np.testing.assert_allclose(p[1], (10 * 16 + 100 * 4) / 20)  # run mean
        np.testing.assert_allclose(p[2], 100.0)  # sliding-4
        np.testing.assert_allclose(p[3], (10 * 12 + 100 * 4) / 16)  # sliding-16
        assert p[4] < p[5] < p[6]  # EMA alphas ascending
        assert p[7] == 100.0  # median-3 of trailing 100s

    def test_vmem_budget_estimate(self):
        """DESIGN.md hardware-adaptation claim: one tile's working set
        stays far under a ~16 MiB VMEM budget."""
        hist_bytes = TILE_SITES * AOT_WINDOW * 4 * 2  # hist + mask
        state_bytes = TILE_SITES * 4 * 13  # flat state vectors
        out_bytes = TILE_SITES * NUM_PREDICTORS * 4 * 2
        total = hist_bytes + state_bytes + out_bytes
        assert total < 1 << 20, f"{total} bytes exceeds 1 MiB guard"


class TestRefSelfConsistency:
    def test_ref_is_permutation_invariant_across_sites(self):
        rng = np.random.default_rng(3)
        hist = rng.uniform(1, 100, (6, 24)).astype(np.float32)
        mask = (rng.random((6, 24)) > 0.2).astype(np.float32)
        p, m = ref.forecast_ref(hist, mask)
        perm = np.array([3, 1, 5, 0, 2, 4])
        p2, m2 = ref.forecast_ref(hist[perm], mask[perm])
        np.testing.assert_allclose(np.asarray(p)[perm], p2, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(m)[perm], m2, rtol=1e-6)

    def test_ref_scale_equivariance(self):
        """Predictions scale linearly; MSEs quadratically."""
        rng = np.random.default_rng(4)
        hist = rng.uniform(1, 100, (4, 20)).astype(np.float32)
        mask = np.ones_like(hist)
        p1, m1 = ref.forecast_ref(hist, mask)
        p2, m2 = ref.forecast_ref(hist * 10.0, mask)
        np.testing.assert_allclose(np.asarray(p1) * 10.0, p2, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(m1) * 100.0, m2, rtol=1e-4)
