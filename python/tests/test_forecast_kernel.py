"""Forecast Pallas kernel vs the pure-jnp oracle (the core L1 signal)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import forecast as fk
from compile.kernels import ref
from compile.kernels.common import NUM_PREDICTORS

RTOL = 2e-4
ATOL = 1e-3


def _check(hist, mask, tile):
    p1, m1 = fk.forecast(hist, mask, tile_sites=tile)
    p2, m2 = ref.forecast_ref(hist, mask)
    np.testing.assert_allclose(p1, p2, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(m1, m2, rtol=RTOL, atol=ATOL)
    return np.asarray(p1), np.asarray(m1)


def _rand(seed, s, w, p_valid=0.8, lo=1.0, hi=100.0):
    rng = np.random.default_rng(seed)
    hist = rng.uniform(lo, hi, (s, w)).astype(np.float32)
    mask = (rng.random((s, w)) < p_valid).astype(np.float32)
    return hist, mask


class TestAgainstOracle:
    def test_dense_history(self):
        hist, _ = _rand(1, 8, 32)
        _check(hist, np.ones_like(hist), tile=4)

    def test_sparse_history(self):
        hist, mask = _rand(2, 12, 48, p_valid=0.4)
        _check(hist, mask, tile=4)

    def test_empty_site_predicts_zero(self):
        hist, mask = _rand(3, 4, 16)
        mask[0] = 0.0
        p, m = _check(hist, mask, tile=4)
        assert np.all(p[0] == 0.0)
        assert np.all(m[0] == 0.0)

    def test_single_observation_site(self):
        hist, mask = _rand(4, 4, 16)
        mask[1] = 0.0
        mask[1, 7] = 1.0
        p, m = _check(hist, mask, tile=4)
        # Every predictor collapses to the lone observation; no backtest
        # step was scorable so MSE stays 0.
        np.testing.assert_allclose(p[1], np.full(NUM_PREDICTORS, hist[1, 7]), rtol=1e-6)
        assert np.all(m[1] == 0.0)

    def test_two_observations_median_path(self):
        hist, mask = _rand(5, 4, 16)
        mask[2] = 0.0
        mask[2, 3] = 1.0
        mask[2, 9] = 1.0
        _check(hist, mask, tile=4)

    def test_constant_series_zero_mse(self):
        hist = np.full((4, 24), 42.0, np.float32)
        mask = np.ones_like(hist)
        p, m = _check(hist, mask, tile=4)
        np.testing.assert_allclose(p, 42.0, rtol=1e-6)
        np.testing.assert_allclose(m, 0.0, atol=1e-6)

    def test_window_of_one(self):
        hist, mask = _rand(6, 4, 1)
        _check(hist, mask, tile=4)

    def test_large_batch_matches_default_tile(self):
        hist, mask = _rand(7, 128, 64)
        _check(hist, mask, tile=32)

    def test_tile_size_is_numerically_irrelevant(self):
        hist, mask = _rand(8, 16, 40)
        p4, m4 = fk.forecast(hist, mask, tile_sites=4)
        p16, m16 = fk.forecast(hist, mask, tile_sites=16)
        np.testing.assert_allclose(p4, p16, rtol=1e-6)
        np.testing.assert_allclose(m4, m16, rtol=1e-6)

    def test_non_multiple_tile_rejected(self):
        hist, mask = _rand(9, 6, 8)
        with pytest.raises(ValueError, match="multiple"):
            fk.forecast(hist, mask, tile_sites=4)


class TestPredictorSemantics:
    def test_last_value_is_last_valid(self):
        hist = np.array([[10.0, 20.0, 30.0, 40.0]], np.float32).repeat(4, 0)
        mask = np.ones_like(hist)
        mask[0, 3] = 0.0  # last slot invalid -> last value is 30
        p, _ = fk.forecast(hist, mask, tile_sites=4)
        assert p[0, 0] == 30.0
        assert p[1, 0] == 40.0

    def test_running_mean(self):
        hist = np.arange(1, 9, dtype=np.float32)[None, :].repeat(4, 0)
        mask = np.ones_like(hist)
        p, _ = fk.forecast(hist, mask, tile_sites=4)
        np.testing.assert_allclose(p[:, 1], 4.5, rtol=1e-6)

    def test_sliding_mean_short(self):
        hist = np.arange(1, 13, dtype=np.float32)[None, :].repeat(4, 0)
        mask = np.ones_like(hist)
        p, _ = fk.forecast(hist, mask, tile_sites=4)
        # last 4 of 1..12 -> mean(9,10,11,12) = 10.5
        np.testing.assert_allclose(p[:, 2], 10.5, rtol=1e-6)

    def test_median_of_three_robust_to_spike(self):
        hist = np.array([[50.0] * 10 + [5000.0, 50.0, 50.0]], np.float32).repeat(4, 0)
        mask = np.ones_like(hist)
        p, _ = fk.forecast(hist, mask, tile_sites=4)
        # median of (5000, 50, 50)... window is last 3 = (5000, 50, 50)?
        # last3 ring holds the final three observations (5000, 50, 50);
        # the median is 50 — the spike is rejected.
        np.testing.assert_allclose(p[:, 7], 50.0, rtol=1e-6)

    def test_ema_tracks_step_change_fastest_at_high_alpha(self):
        hist = np.array([[10.0] * 16 + [100.0] * 8], np.float32).repeat(4, 0)
        mask = np.ones_like(hist)
        p, _ = fk.forecast(hist, mask, tile_sites=4)
        # alpha order: 0.1, 0.3, 0.6 -> higher alpha is closer to 100.
        assert p[0, 4] < p[0, 5] < p[0, 6]
        assert p[0, 6] > 90.0

    def test_adaptive_selection_prefers_mean_on_noise(self):
        # White noise around a constant: the running mean has the lowest
        # backtest MSE among the bank (last-value has ~2x the variance).
        rng = np.random.default_rng(11)
        hist = (50.0 + rng.normal(0, 5, (8, 64))).astype(np.float32)
        mask = np.ones_like(hist)
        _, m = fk.forecast(hist, mask, tile_sites=8)
        best = np.argmin(np.asarray(m), axis=1)
        assert np.all(m[np.arange(8), best] <= m[:, 0] + 1e-6)
        assert (best == 1).mean() >= 0.5


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    tiles=st.integers(1, 4),
    window=st.integers(1, 40),
    p_valid=st.floats(0.0, 1.0),
    scale=st.sampled_from([1.0, 1e-3, 1e4]),
)
def test_hypothesis_sweep(seed, tiles, window, p_valid, scale):
    """Shape/mask/scale sweep: kernel == oracle everywhere."""
    rng = np.random.default_rng(seed)
    s = tiles * 4
    hist = (rng.uniform(0.1, 100.0, (s, window)) * scale).astype(np.float32)
    mask = (rng.random((s, window)) < p_valid).astype(np.float32)
    p1, m1 = fk.forecast(hist, mask, tile_sites=4)
    p2, m2 = ref.forecast_ref(hist, mask)
    np.testing.assert_allclose(p1, p2, rtol=5e-4, atol=1e-3 * scale)
    np.testing.assert_allclose(m1, m2, rtol=5e-4, atol=1e-3 * scale * scale)
