"""Rank Pallas kernel vs the pure-jnp oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import rank as rk
from compile.kernels import ref

BIG = 1e9


def _check(attrs, lo, hi, w, tile=8):
    s1 = rk.rank(attrs, lo, hi, w, tile_replicas=tile)
    s2 = ref.rank_ref(attrs, lo, hi, w)
    np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-4)
    return np.asarray(s1)


class TestRank:
    def test_unconstrained_is_plain_matmul(self):
        rng = np.random.default_rng(0)
        attrs = rng.uniform(-5, 5, (16, 6)).astype(np.float32)
        lo = np.full((3, 6), -BIG, np.float32)
        hi = np.full((3, 6), BIG, np.float32)
        w = rng.uniform(-1, 1, (3, 6)).astype(np.float32)
        s = _check(attrs, lo, hi, w)
        np.testing.assert_allclose(s, w @ attrs.T, rtol=1e-5)

    def test_infeasible_scores_neg_inf(self):
        attrs = np.tile(np.array([[1.0, 1.0], [9.0, 1.0]], np.float32), (4, 1))
        lo = np.array([[2.0, -BIG]], np.float32)
        hi = np.array([[BIG, BIG]], np.float32)
        w = np.ones((1, 2), np.float32)
        s = _check(attrs, lo, hi, w, tile=4)
        assert np.isneginf(s[0, 0])
        assert s[0, 1] == 10.0

    def test_paper_example_ads(self):
        """§4 storage ad vs §5.2 request: availableSpace=50G, MaxRD=75K,
        request wants >5G and >50K ranked by availableSpace."""
        # attrs: [availableSpace(GB), MaxRDBandwidth(KB/s)]
        attrs = np.tile(
            np.array(
                [[50.0, 75.0], [3.0, 200.0], [80.0, 40.0], [60.0, 60.0]], np.float32
            ),
            (2, 1),
        )
        lo = np.array([[5.0, 50.0]], np.float32)
        hi = np.full((1, 2), BIG, np.float32)
        w = np.array([[1.0, 0.0]], np.float32)  # rank = other.availableSpace
        s = _check(attrs, lo, hi, w, tile=8)
        # Replica 1 fails space, replica 2 fails bandwidth.
        assert np.isneginf(s[0, 1]) and np.isneginf(s[0, 2])
        # Winner is the feasible replica with the most available space.
        feas = np.where(np.isfinite(s[0]))[0]
        assert s[0, feas].max() == 60.0

    def test_boundary_is_inclusive(self):
        attrs = np.array([[5.0]], np.float32).repeat(8, 0)
        lo = np.array([[5.0]], np.float32)
        hi = np.array([[5.0]], np.float32)
        w = np.ones((1, 1), np.float32)
        s = _check(attrs, lo, hi, w, tile=8)
        assert np.all(np.isfinite(s))

    def test_tile_invariance(self):
        rng = np.random.default_rng(1)
        attrs = rng.uniform(-5, 5, (32, 4)).astype(np.float32)
        lo = rng.uniform(-6, 0, (2, 4)).astype(np.float32)
        hi = rng.uniform(0, 6, (2, 4)).astype(np.float32)
        w = rng.uniform(-1, 1, (2, 4)).astype(np.float32)
        a = rk.rank(attrs, lo, hi, w, tile_replicas=8)
        b = rk.rank(attrs, lo, hi, w, tile_replicas=32)
        np.testing.assert_allclose(a, b, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    tiles=st.integers(1, 4),
    n_req=st.integers(1, 8),
    n_attr=st.integers(1, 12),
)
def test_hypothesis_sweep(seed, tiles, n_req, n_attr):
    rng = np.random.default_rng(seed)
    n_rep = tiles * 8
    attrs = rng.uniform(-100, 100, (n_rep, n_attr)).astype(np.float32)
    lo = rng.uniform(-120, 20, (n_req, n_attr)).astype(np.float32)
    hi = rng.uniform(-20, 120, (n_req, n_attr)).astype(np.float32)
    w = rng.uniform(-2, 2, (n_req, n_attr)).astype(np.float32)
    _check(attrs, lo, hi, w, tile=8)
