"""AOT path: lowering produces loadable HLO text + a coherent manifest."""

import json
import os

import numpy as np
import pytest

from compile import aot, model
from compile.kernels.common import (
    AOT_ATTRS,
    AOT_REPLICAS,
    AOT_REQUESTS,
    AOT_SITES,
    AOT_WINDOW,
    NUM_PREDICTORS,
)


class TestLowering:
    def test_forecast_hlo_text(self):
        text = aot.to_hlo_text(model.jit_forecast(AOT_SITES, AOT_WINDOW))
        assert text.startswith("HloModule")
        # AOT input/output shapes must appear in the entry computation.
        assert f"f32[{AOT_SITES},{AOT_WINDOW}]" in text
        assert f"f32[{AOT_SITES},{NUM_PREDICTORS}]" in text

    def test_rank_hlo_text(self):
        text = aot.to_hlo_text(model.jit_rank(AOT_REPLICAS, AOT_REQUESTS, AOT_ATTRS))
        assert text.startswith("HloModule")
        assert f"f32[{AOT_REQUESTS},{AOT_REPLICAS}]" in text

    def test_no_mosaic_custom_calls(self):
        """interpret=True must lower to plain HLO ops — a Mosaic
        custom-call would be unloadable by the CPU PJRT client."""
        for text in (
            aot.to_hlo_text(model.jit_forecast(AOT_SITES, AOT_WINDOW)),
            aot.to_hlo_text(model.jit_rank(AOT_REPLICAS, AOT_REQUESTS, AOT_ATTRS)),
        ):
            assert "tpu_custom_call" not in text
            assert "mosaic" not in text.lower()


class TestBuild:
    @pytest.fixture(scope="class")
    def built(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        manifest = aot.build(str(out))
        return out, manifest

    def test_files_exist(self, built):
        out, manifest = built
        for entry in manifest["entries"].values():
            assert (out / entry["file"]).exists()

    def test_manifest_round_trips(self, built):
        out, manifest = built
        on_disk = json.loads((out / "manifest.json").read_text())
        assert on_disk == json.loads(json.dumps(manifest))
        bank = on_disk["predictor_bank"]
        assert bank["num_predictors"] == NUM_PREDICTORS
        assert len(bank["names"]) == NUM_PREDICTORS

    def test_manifest_shapes_match_kernel_constants(self, built):
        _, manifest = built
        fc = manifest["entries"]["forecast"]
        assert fc["inputs"][0]["shape"] == [AOT_SITES, AOT_WINDOW]
        rk = manifest["entries"]["rank"]
        assert rk["outputs"][0]["shape"] == [AOT_REQUESTS, AOT_REPLICAS]

    def test_sha256_matches_file(self, built):
        import hashlib

        out, manifest = built
        for entry in manifest["entries"].values():
            data = (out / entry["file"]).read_text().encode()
            assert hashlib.sha256(data).hexdigest() == entry["sha256"]


class TestExecutedArtifactSemantics:
    """Run the lowered computation via jax itself and compare with the
    eager model — catches lowering bugs before the Rust side ever loads
    the artifact."""

    def test_forecast_compiled_equals_eager(self):
        rng = np.random.default_rng(0)
        hist = rng.uniform(1, 100, (AOT_SITES, AOT_WINDOW)).astype(np.float32)
        mask = (rng.random((AOT_SITES, AOT_WINDOW)) < 0.8).astype(np.float32)
        load = rng.uniform(0, 1, (AOT_SITES,)).astype(np.float32)
        compiled = model.jit_forecast(AOT_SITES, AOT_WINDOW).compile()
        got = compiled(hist, mask, load)
        want = model.forecast_model(hist, mask, load)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-5)

    def test_rank_compiled_equals_eager(self):
        rng = np.random.default_rng(1)
        attrs = rng.uniform(0, 100, (AOT_REPLICAS, AOT_ATTRS)).astype(np.float32)
        lo = rng.uniform(0, 50, (AOT_REQUESTS, AOT_ATTRS)).astype(np.float32)
        hi = rng.uniform(50, 120, (AOT_REQUESTS, AOT_ATTRS)).astype(np.float32)
        w = rng.uniform(-1, 1, (AOT_REQUESTS, AOT_ATTRS)).astype(np.float32)
        compiled = model.jit_rank(AOT_REPLICAS, AOT_REQUESTS, AOT_ATTRS).compile()
        got = compiled(attrs, lo, hi, w)
        want = model.rank_model(attrs, lo, hi, w)
        for g, w_ in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w_), rtol=1e-5, atol=1e-5)
