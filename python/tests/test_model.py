"""L2 model: shapes, adaptive selection, load discounting, padding."""

import numpy as np

from compile import model
from compile.kernels.common import AOT_SITES, AOT_WINDOW, NUM_PREDICTORS


def _rand(seed, s=32, w=64, p_valid=0.9):
    rng = np.random.default_rng(seed)
    hist = rng.uniform(10, 90, (s, w)).astype(np.float32)
    mask = (rng.random((s, w)) < p_valid).astype(np.float32)
    load = rng.uniform(0, 1, (s,)).astype(np.float32)
    return hist, mask, load


class TestForecastModel:
    def test_shapes(self):
        hist, mask, load = _rand(0)
        preds, mses, best, eff = model.forecast_model(hist, mask, load)
        assert preds.shape == (32, NUM_PREDICTORS)
        assert mses.shape == (32, NUM_PREDICTORS)
        assert best.shape == (32,)
        assert eff.shape == (32,)

    def test_best_is_min_mse_prediction(self):
        hist, mask, load = _rand(1)
        preds, mses, best, _ = model.forecast_model(hist, mask, load)
        preds, mses, best = map(np.asarray, (preds, mses, best))
        idx = mses.argmin(axis=1)
        np.testing.assert_allclose(best, preds[np.arange(32), idx], rtol=1e-6)

    def test_eff_discounts_by_load(self):
        hist, mask, _ = _rand(2)
        _, _, best, eff0 = model.forecast_model(hist, mask, np.zeros(32, np.float32))
        _, _, _, eff_half = model.forecast_model(
            hist, mask, np.full(32, 0.5, np.float32)
        )
        np.testing.assert_allclose(np.asarray(eff0), np.asarray(best), rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(eff_half), 0.5 * np.asarray(best), rtol=1e-6
        )

    def test_load_clipped(self):
        hist, mask, _ = _rand(3)
        _, _, _, eff = model.forecast_model(hist, mask, np.full(32, 7.0, np.float32))
        np.testing.assert_allclose(np.asarray(eff), 0.0, atol=1e-6)

    def test_matches_reference_model(self):
        hist, mask, load = _rand(4)
        got = model.forecast_model(hist, mask, load)
        want = model.forecast_model_reference(hist, mask, load)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=5e-4, atol=1e-3)

    def test_padding_rows_are_inert(self):
        """Padded (all-masked) sites — how the Rust runtime feeds batches
        smaller than AOT_SITES — predict 0 and never perturb real rows."""
        hist, mask, load = _rand(5, s=AOT_SITES, w=AOT_WINDOW)
        mask[40:] = 0.0
        preds, mses, best, eff = map(
            np.asarray, model.forecast_model(hist, mask, load)
        )
        assert np.all(preds[40:] == 0.0)
        assert np.all(best[40:] == 0.0)
        # Same real rows, different padding content -> identical output.
        hist2 = hist.copy()
        hist2[40:] = 123.0
        preds2, _, best2, _ = map(np.asarray, model.forecast_model(hist2, mask, load))
        np.testing.assert_allclose(preds[:40], preds2[:40], rtol=1e-6)
        np.testing.assert_allclose(best[:40], best2[:40], rtol=1e-6)


class TestRankModel:
    def test_argmax_consistent(self):
        rng = np.random.default_rng(6)
        attrs = rng.uniform(0, 100, (64, 8)).astype(np.float32)
        lo = np.full((4, 8), -1e9, np.float32)
        hi = np.full((4, 8), 1e9, np.float32)
        w = rng.uniform(0, 1, (4, 8)).astype(np.float32)
        scores, idx, best = map(np.asarray, model.rank_model(attrs, lo, hi, w))
        np.testing.assert_allclose(best, scores.max(axis=1), rtol=1e-6)
        assert np.all(scores[np.arange(4), idx] == best)

    def test_no_feasible_replica_reports_neg_inf(self):
        attrs = np.zeros((64, 2), np.float32)
        lo = np.full((1, 2), 5.0, np.float32)
        hi = np.full((1, 2), 1e9, np.float32)
        w = np.ones((1, 2), np.float32)
        _, _, best = model.rank_model(attrs, lo, hi, w)
        assert np.isneginf(np.asarray(best)[0])
