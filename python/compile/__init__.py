"""Build-time compile package: L2 JAX model + L1 Pallas kernels + AOT.

Nothing here runs at request time; ``make artifacts`` invokes
``python -m compile.aot`` once and the Rust coordinator loads the
resulting HLO-text artifacts through PJRT.
"""
