"""Layer-2 JAX model: the broker's forecast + rank compute graph.

Two exported entry points (AOT-lowered to HLO text by :mod:`compile.aot`
and executed from ``rust/src/runtime`` — Python never runs at request
time):

* :func:`forecast_model` — predictor bank over per-site bandwidth
  history (L1 kernel), adaptive best-forecaster selection by backtest
  MSE, and the paper's §3.2 heuristic of *“combining past observed
  performance with current load of server”*: the effective bandwidth fed
  to ranking is ``best_prediction * (1 - load)``.
* :func:`rank_model` — constraint-masked scoring of all replicas against
  all outstanding requests (L1 rank kernel) plus per-request argmax.

Both are pure functions of dense arrays; the Rust side assembles the
arrays from GRIS query results and pads to the AOT shapes recorded in
``artifacts/manifest.json``.
"""

import jax
import jax.numpy as jnp

from .kernels import forecast as fk
from .kernels import rank as rk
from .kernels.common import NUM_PREDICTORS


def forecast_model(hist, mask, load):
    """Adaptive bandwidth forecast for every site.

    Args:
      hist: f32[S, W] per-site bandwidth history, oldest -> newest.
      mask: f32[S, W] validity mask.
      load: f32[S] current utilization in [0, 1] (from the site's GRIS
        dynamic attributes).

    Returns a 4-tuple:
      preds   f32[S, P] — every forecaster's prediction,
      mses    f32[S, P] — every forecaster's backtest MSE,
      best    f32[S]    — prediction of the per-site best (min-MSE)
                          forecaster,
      eff     f32[S]    — load-discounted effective bandwidth
                          (the rank input).
    """
    preds, mses = fk.forecast(hist, mask)
    best_idx = jnp.argmin(mses, axis=1)
    best = jnp.take_along_axis(preds, best_idx[:, None], axis=1)[:, 0]
    load = jnp.clip(jnp.asarray(load, jnp.float32), 0.0, 1.0)
    eff = best * (1.0 - load)
    return preds, mses, best, eff


def rank_model(attrs, lo, hi, weights):
    """Score replicas against requests and pick each request's winner.

    Returns ``(scores f32[Q, R], best_idx i32[Q], best_score f32[Q])``.
    A request with no feasible replica reports ``best_score = -inf``
    (its ``best_idx`` is then meaningless and the Rust caller falls back
    to 'no match', mirroring an unsatisfied ClassAd ``requirement``).
    """
    scores = rk.rank(attrs, lo, hi, weights)
    best_idx = jnp.argmax(scores, axis=1).astype(jnp.int32)
    best_score = jnp.max(scores, axis=1)
    return scores, best_idx, best_score


def forecast_model_reference(hist, mask, load):
    """Pure-jnp twin of :func:`forecast_model` (oracle for tests)."""
    from .kernels import ref

    preds, mses = ref.forecast_ref(hist, mask)
    best_idx = jnp.argmin(mses, axis=1)
    best = jnp.take_along_axis(preds, best_idx[:, None], axis=1)[:, 0]
    load = jnp.clip(jnp.asarray(load, jnp.float32), 0.0, 1.0)
    eff = best * (1.0 - load)
    return preds, mses, best, eff


def jit_forecast(n_sites, window):
    """Lowered forecast_model for fixed AOT shapes."""
    spec_h = jax.ShapeDtypeStruct((n_sites, window), jnp.float32)
    spec_l = jax.ShapeDtypeStruct((n_sites,), jnp.float32)
    return jax.jit(forecast_model).lower(spec_h, spec_h, spec_l)


def jit_rank(n_rep, n_req, n_attr):
    """Lowered rank_model for fixed AOT shapes."""
    a = jax.ShapeDtypeStruct((n_rep, n_attr), jnp.float32)
    q = jax.ShapeDtypeStruct((n_req, n_attr), jnp.float32)
    return jax.jit(rank_model).lower(a, q, q, q)


__all__ = [
    "NUM_PREDICTORS",
    "forecast_model",
    "forecast_model_reference",
    "jit_forecast",
    "jit_rank",
    "rank_model",
]
