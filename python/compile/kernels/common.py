"""Shared constants for the forecast predictor bank.

The bank mirrors the forecaster families used by the Network Weather
Service, which the paper (§7) identifies as the natural consumer of the
published bandwidth history: last-value, running mean, sliding-window
means, exponential smoothing at several gains, and a small-median robust
predictor.

Index layout of the ``P`` axis (must stay in sync with
``rust/src/forecast/predictors.rs`` — checked by the cross-language test
``rust/tests/it_runtime_artifacts.rs``):

====  =======================  =========================
 idx   predictor                parameter
====  =======================  =========================
  0    last value               —
  1    running mean             full history
  2    sliding mean             w = 4
  3    sliding mean             w = 16
  4    exponential smoothing    alpha = 0.10
  5    exponential smoothing    alpha = 0.30
  6    exponential smoothing    alpha = 0.60
  7    median-of-3              last 3 observations
====  =======================  =========================
"""

# Number of predictors in the bank.
NUM_PREDICTORS = 8

# Sliding-window widths for predictors 2 and 3.
WINDOW_SHORT = 4
WINDOW_LONG = 16

# Exponential-smoothing gains for predictors 4..6.
EMA_ALPHAS = (0.10, 0.30, 0.60)

# Default AOT shapes (the Rust runtime pads batches to these — see
# artifacts/manifest.json and rust/src/runtime/artifacts.rs).
AOT_SITES = 128
AOT_WINDOW = 64

# Rank kernel AOT shapes: replicas x requests x attributes.
AOT_REPLICAS = 128
AOT_REQUESTS = 8
AOT_ATTRS = 8

# Site tile for the Pallas grid. 32 sites x 64-step window x f32 is 8 KiB
# of history per tile plus ~10 small state vectors -> comfortably
# VMEM-resident. (Perf log P1: widening to 128 was neutral at 128 sites
# and ~45% slower at 512 on CPU PJRT — wider rows inflate every
# dynamic-slice inside the window walk; kept at 32.)
TILE_SITES = 32
