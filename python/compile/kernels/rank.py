"""Pallas rank kernel: constraint-masked replica scoring.

The Match phase of the broker evaluates the request ClassAd's
``requirement`` against every storage ad and orders survivors by the
``rank`` expression (paper §4, §5.2).  For the common case — interval
constraints over numeric attributes and a linear rank expression — the
broker compiles the ad pair down to dense matrices and calls this kernel,
scoring *all* replicas against *all* outstanding requests in one shot:

* ``attrs``   f32[R, A] — replica attribute matrix (one row per storage
  ad: availableSpace, MaxRDBandwidth, predicted bandwidth, load, ...)
* ``lo, hi``  f32[Q, A] — per-request interval constraints (±BIG for
  unconstrained attributes)
* ``weights`` f32[Q, A] — the linearized rank expression

Score: ``weights @ attrs.T`` where feasible, ``-inf`` otherwise.

TPU mapping: the weighted sum is a (Q, A) x (A, R) matmul — MXU work —
while feasibility is a VPU broadcast-compare reduced over A.  The grid
tiles replicas; Q and A are small and stay resident.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile over the replica axis; requests/attributes are small and resident.
TILE_REPLICAS = 64


def _rank_kernel(attrs_ref, lo_ref, hi_ref, w_ref, out_ref):
    attrs = attrs_ref[...]  # [TR, A]
    lo = lo_ref[...]  # [Q, A]
    hi = hi_ref[...]
    w = w_ref[...]
    feas = jnp.all(
        (attrs[None, :, :] >= lo[:, None, :]) & (attrs[None, :, :] <= hi[:, None, :]),
        axis=2,
    )  # [Q, TR]
    raw = jnp.dot(w, attrs.T, preferred_element_type=jnp.float32)  # MXU
    out_ref[...] = jnp.where(feas, raw, float("-inf"))


@functools.partial(jax.jit, static_argnames=("tile_replicas",))
def rank(attrs, lo, hi, weights, *, tile_replicas=TILE_REPLICAS):
    """Score replicas against requests. Returns f32[Q, R].

    ``R`` must be a multiple of ``tile_replicas`` (the AOT wrapper pads;
    padded replica rows carry out-of-interval sentinel attributes so they
    score ``-inf`` and can never win).
    """
    attrs = jnp.asarray(attrs, jnp.float32)
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    weights = jnp.asarray(weights, jnp.float32)
    n_rep, n_attr = attrs.shape
    n_req = lo.shape[0]
    if n_rep % tile_replicas != 0:
        raise ValueError(f"n_rep={n_rep} not a multiple of tile={tile_replicas}")
    grid = (n_rep // tile_replicas,)
    out = pl.pallas_call(
        _rank_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_replicas, n_attr), lambda i: (i, 0)),
            pl.BlockSpec((n_req, n_attr), lambda i: (0, 0)),
            pl.BlockSpec((n_req, n_attr), lambda i: (0, 0)),
            pl.BlockSpec((n_req, n_attr), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n_req, tile_replicas), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n_req, n_rep), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(attrs, lo, hi, weights)
    return out
