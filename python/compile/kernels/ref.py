"""Pure-``jax.numpy`` oracles for the Pallas kernels.

These are deliberately written as straight-line, obviously-correct code
(python loop over the time axis, no pallas, no fori_loop state packing)
so that any disagreement with the kernels points at the kernel.

Semantics of the predictor bank (shared with
``rust/src/forecast/predictors.rs``):

* Observations arrive oldest -> newest along the window axis. ``mask`` is
  1.0 where the slot holds a real observation, 0.0 for padding. Padding
  may appear anywhere (sites report at different rates), and masked slots
  must not perturb any predictor state.
* Every predictor is *causal*: its backtest error at step ``t`` uses only
  observations strictly before ``t``.
* Backtest MSE for predictor ``p`` on site ``s`` is the mean over valid
  steps ``t`` (mask 1, at least one prior valid observation) of
  ``(pred_p(s, <t) - x[s, t])**2``. Sites with fewer than 2 valid
  observations report MSE 0 and prediction equal to the last valid value
  (or 0.0 if the site has no history at all).
"""

import jax.numpy as jnp

from .common import EMA_ALPHAS, NUM_PREDICTORS, WINDOW_LONG, WINDOW_SHORT


def _bank_state_init(n_sites):
    """Initial predictor state for ``n_sites`` sites."""
    z = jnp.zeros((n_sites,), jnp.float32)
    return {
        "count": z,                # valid observations so far
        "last": z,                 # predictor 0
        "sum": z,                  # predictor 1 (running mean numerator)
        "last3": jnp.zeros((n_sites, 3), jnp.float32),  # predictor 7 ring
        "ema": jnp.zeros((n_sites, len(EMA_ALPHAS)), jnp.float32),
    }


def _bank_predict(state, hist, mask, t):
    """Predictions of each predictor given state *before* step ``t``.

    ``hist``/``mask`` are the full [S, W] arrays; sliding-window
    predictors read the trailing slices directly (they are causal: slice
    ends at ``t`` exclusive).
    """
    s = state
    count = s["count"]
    has = count > 0
    last = s["last"]
    preds = []
    # 0: last value
    preds.append(last)
    # 1: running mean
    preds.append(jnp.where(has, s["sum"] / jnp.maximum(count, 1.0), 0.0))
    # 2, 3: sliding means over the last w *valid* observations' slots
    # (masked mean over the trailing w slots, falling back to last value
    # when the trailing slots hold no valid data).
    for w in (WINDOW_SHORT, WINDOW_LONG):
        lo = max(0, t - w)
        seg = hist[:, lo:t]
        segm = mask[:, lo:t]
        n = segm.sum(axis=1)
        sm = (seg * segm).sum(axis=1)
        preds.append(jnp.where(n > 0, sm / jnp.maximum(n, 1.0), last))
    # 4..6: exponential smoothing
    for i in range(len(EMA_ALPHAS)):
        preds.append(s["ema"][:, i])
    # 7: median of the last 3 valid observations (fewer -> degrade to
    # median of what exists; none -> 0).
    l3 = s["last3"]
    m3 = jnp.sort(l3, axis=1)[:, 1]
    p7 = jnp.where(count >= 3, m3, jnp.where(count == 2, (l3[:, 1] + l3[:, 2]) / 2.0, last))
    preds.append(p7)
    out = jnp.stack(preds, axis=1)  # [S, P]
    # With no history at all every predictor reports 0.0.
    return jnp.where(has[:, None], out, 0.0)


def _bank_update(state, x, m):
    """Fold observation ``x`` (valid where ``m``) into the state."""
    s = dict(state)
    mb = m > 0.5
    first = jnp.logical_and(mb, s["count"] == 0)
    s["sum"] = s["sum"] + jnp.where(mb, x, 0.0)
    new_last3 = jnp.concatenate([s["last3"][:, 1:], x[:, None]], axis=1)
    # Before 3 observations exist, keep the ring saturated with x so the
    # median degrades gracefully.
    seed3 = jnp.stack([x, x, x], axis=1)
    s["last3"] = jnp.where(
        mb[:, None], jnp.where(first[:, None], seed3, new_last3), s["last3"]
    )
    emas = []
    for i, a in enumerate(EMA_ALPHAS):
        e = s["ema"][:, i]
        e2 = jnp.where(first, x, (1.0 - a) * e + a * x)
        emas.append(jnp.where(mb, e2, e))
    s["ema"] = jnp.stack(emas, axis=1)
    s["last"] = jnp.where(mb, x, s["last"])
    s["count"] = s["count"] + jnp.where(mb, 1.0, 0.0)
    return s


def forecast_ref(hist, mask):
    """Oracle for the forecast kernel.

    Args:
      hist: f32[S, W] bandwidth observations, oldest -> newest.
      mask: f32[S, W] validity mask (1.0 = real observation).

    Returns:
      preds: f32[S, P] final prediction of each predictor.
      mses:  f32[S, P] backtest MSE of each predictor.
    """
    hist = jnp.asarray(hist, jnp.float32)
    mask = jnp.asarray(mask, jnp.float32)
    n_sites, window = hist.shape
    state = _bank_state_init(n_sites)
    err = jnp.zeros((n_sites, NUM_PREDICTORS), jnp.float32)
    nerr = jnp.zeros((n_sites,), jnp.float32)
    for t in range(window):
        x = hist[:, t]
        m = mask[:, t]
        scorable = jnp.logical_and(m > 0.5, state["count"] > 0)
        p = _bank_predict(state, hist, mask, t)
        e = (p - x[:, None]) ** 2
        err = err + jnp.where(scorable[:, None], e, 0.0)
        nerr = nerr + jnp.where(scorable, 1.0, 0.0)
        state = _bank_update(state, x, m)
    mses = err / jnp.maximum(nerr, 1.0)[:, None]
    preds = _bank_predict(state, hist, mask, window)
    return preds, mses


def rank_ref(attrs, lo, hi, weights):
    """Oracle for the rank kernel.

    Implements the broker's vectorized Match-phase scoring: a replica is
    *feasible* for a request iff every attribute lies in [lo, hi]; the
    score of a feasible replica is the weighted sum of its attributes
    (the ClassAd ``rank`` expression compiled to a linear form), and
    infeasible replicas score ``-inf``.

    Args:
      attrs:   f32[R, A] replica attribute matrix.
      lo, hi:  f32[Q, A] per-request constraint bounds (use -/+ large
               sentinels for unconstrained attributes).
      weights: f32[Q, A] per-request rank weights.

    Returns:
      scores: f32[Q, R], ``-inf`` where infeasible.
    """
    attrs = jnp.asarray(attrs, jnp.float32)
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    weights = jnp.asarray(weights, jnp.float32)
    feas = jnp.all(
        (attrs[None, :, :] >= lo[:, None, :]) & (attrs[None, :, :] <= hi[:, None, :]),
        axis=2,
    )  # [Q, R]
    raw = weights @ attrs.T  # [Q, R] — the MXU-shaped part
    neg = jnp.float32(-jnp.inf)
    return jnp.where(feas, raw, neg)
