"""Layer-1 Pallas kernels for the replica-selection forecast engine.

Two kernels:

* :mod:`forecast` -- the NWS-style bandwidth predictor bank (paper 3.2):
  one pass over each site's trailing transfer-bandwidth window producing a
  bank of predictions *and* their backtested MSEs.
* :mod:`rank` -- the constraint-masked replica scoring kernel used by the
  broker's Match phase ranking (paper 4 / 5.2).

:mod:`ref` holds the pure-``jax.numpy`` oracles the kernels are tested
against (pytest + hypothesis, see ``python/tests``).
"""
