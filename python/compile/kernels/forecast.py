"""Pallas forecast kernel: the NWS-style bandwidth predictor bank.

The broker's rank phase needs, for every candidate replica site, a
prediction of the transfer bandwidth the site will deliver, derived from
the GridFTP instrumentation history the site publishes through its GRIS
(paper §3.2).  This kernel computes, in a single pass over each site's
trailing observation window:

* the current prediction of each of the ``NUM_PREDICTORS`` forecasters
  (last-value, running mean, two sliding means, three EMA gains,
  median-of-3 — the NWS forecaster family), and
* the *backtested MSE* of each forecaster over the same window, which the
  L2 model (and the Rust fallback) uses to select the per-site best
  forecaster ("adaptive" prediction).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid is 1-D over site
tiles; each program instance keeps its ``(TILE_SITES, WINDOW)`` history
block plus ~10 small state vectors in VMEM and walks the window axis with
``lax.fori_loop``, so HBM traffic is one read of the history block and
one write of the two output blocks.  All arithmetic is VPU-shaped
(element-wise + small sorts); there is no MXU work here.

``interpret=True`` everywhere — the CPU PJRT client cannot execute Mosaic
custom-calls; numerics are validated through the interpret path against
:func:`compile.kernels.ref.forecast_ref`.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .common import EMA_ALPHAS, NUM_PREDICTORS, TILE_SITES, WINDOW_LONG, WINDOW_SHORT


# State is a *flat* tuple of [TS] vectors (no [TS, k] stacking inside
# the window walk — Perf log P5: per-step stack/concat on small tensors
# cost ~15% on CPU PJRT):
#   (count, last, total,
#    l3a, l3b, l3c,            # last-3 ring, oldest..newest
#    ema0, ema1, ema2,
#    sw_sum_s, sw_cnt_s, sw_sum_l, sw_cnt_l)


def _predict_list(state):
    """The bank's predictions as a list of P [TS] vectors (no stack —
    Perf log P6: the per-step [TS, P] stack cost ~10% on CPU PJRT)."""
    (count, last, total, l3a, l3b, l3c, ema0, ema1, ema2, sws, cns, swl, cnl) = state
    has = count > 0
    preds = [
        last,
        jnp.where(has, total / jnp.maximum(count, 1.0), 0.0),
        jnp.where(cns > 0, sws / jnp.maximum(cns, 1.0), last),
        jnp.where(cnl > 0, swl / jnp.maximum(cnl, 1.0), last),
        ema0,
        ema1,
        ema2,
    ]
    # Median of the 3-ring without sort: max(min pairs).
    m3 = jnp.maximum(
        jnp.minimum(jnp.maximum(l3a, l3b), l3c), jnp.minimum(l3a, l3b)
    )
    p7 = jnp.where(count >= 3, m3, jnp.where(count == 2, (l3b + l3c) / 2.0, last))
    preds.append(p7)
    return [jnp.where(has, p, 0.0) for p in preds]


def _predict(state, ts):
    """Stacked [TS, P] view (used once, after the walk)."""
    return jnp.stack(_predict_list(state), axis=1)


def _update(state, x, m):
    """Fold one observation column into the bank state (masked)."""
    (count, last, total, l3a, l3b, l3c, ema0, ema1, ema2, sws, cns, swl, cnl) = state
    mb = m > 0.5
    first = jnp.logical_and(mb, count == 0)
    total = total + jnp.where(mb, x, 0.0)
    l3a = jnp.where(mb, jnp.where(first, x, l3b), l3a)
    l3b = jnp.where(mb, jnp.where(first, x, l3c), l3b)
    l3c = jnp.where(mb, x, l3c)
    emas = []
    for a, e in zip(EMA_ALPHAS, (ema0, ema1, ema2)):
        e2 = jnp.where(first, x, (1.0 - a) * e + a * x)
        emas.append(jnp.where(mb, e2, e))
    ema0, ema1, ema2 = emas
    last = jnp.where(mb, x, last)
    count = count + jnp.where(mb, 1.0, 0.0)
    return (count, last, total, l3a, l3b, l3c, ema0, ema1, ema2, sws, cns, swl, cnl)


def _forecast_kernel(hist_ref, mask_ref, preds_ref, mses_ref):
    """One site tile: walk the window, emit predictions + backtest MSEs."""
    hist = hist_ref[...]  # [TS, W] — VMEM resident for the whole walk
    mask = mask_ref[...]
    ts, window = hist.shape
    xm = hist * mask

    z = jnp.zeros((ts,), jnp.float32)
    state0 = (z,) * 13  # see state layout above
    err0 = tuple(z for _ in range(NUM_PREDICTORS))
    nerr0 = z

    def body(t, carry):
        state, err, nerr = carry
        x = lax.dynamic_slice_in_dim(hist, t, 1, axis=1)[:, 0]
        m = lax.dynamic_slice_in_dim(mask, t, 1, axis=1)[:, 0]
        count = state[0]
        scorable = (jnp.logical_and(m > 0.5, count > 0)).astype(jnp.float32)
        preds = _predict_list(state)
        err = tuple(
            e + scorable * (p - x) * (p - x) for e, p in zip(err, preds)
        )
        nerr = nerr + scorable
        state = _update(state, x, m)
        # Advance the sliding windows: [t-w, t) -> [t+1-w, t+1).
        (count, last, total, l3a, l3b, l3c, ema0, ema1, ema2, sws, cns, swl, cnl) = state
        add_x = x * m
        new_sw = []
        for w, (ss, cc) in ((WINDOW_SHORT, (sws, cns)), (WINDOW_LONG, (swl, cnl))):
            drop = t - w  # slot leaving the window (may be negative)
            safe = jnp.maximum(drop, 0)
            live = (t >= w).astype(jnp.float32)
            rem_x = lax.dynamic_slice_in_dim(xm, safe, 1, axis=1)[:, 0] * live
            rem_m = lax.dynamic_slice_in_dim(mask, safe, 1, axis=1)[:, 0] * live
            new_sw.append((ss + add_x - rem_x, cc + m - rem_m))
        (sws, cns), (swl, cnl) = new_sw
        state = (count, last, total, l3a, l3b, l3c, ema0, ema1, ema2, sws, cns, swl, cnl)
        return state, err, nerr

    # Perf log P2: unroll=8 was tried and *regressed* ~5-13% on CPU PJRT
    # (longer body, same sequential dependency); plain fori_loop kept.
    state, err, nerr = lax.fori_loop(0, window, body, (state0, err0, nerr0))
    mses_ref[...] = jnp.stack(err, axis=1) / jnp.maximum(nerr, 1.0)[:, None]
    preds_ref[...] = _predict(state, ts)


@functools.partial(jax.jit, static_argnames=("tile_sites",))
def forecast(hist, mask, *, tile_sites=TILE_SITES):
    """Run the predictor bank over ``hist``/``mask`` (f32[S, W]).

    ``S`` must be a multiple of ``tile_sites`` (the AOT wrapper pads).
    Returns ``(preds, mses)``, both f32[S, NUM_PREDICTORS].
    """
    hist = jnp.asarray(hist, jnp.float32)
    mask = jnp.asarray(mask, jnp.float32)
    n_sites, window = hist.shape
    if n_sites % tile_sites != 0:
        raise ValueError(f"n_sites={n_sites} not a multiple of tile={tile_sites}")
    grid = (n_sites // tile_sites,)
    out_shape = [
        jax.ShapeDtypeStruct((n_sites, NUM_PREDICTORS), jnp.float32),
        jax.ShapeDtypeStruct((n_sites, NUM_PREDICTORS), jnp.float32),
    ]
    in_spec = pl.BlockSpec((tile_sites, window), lambda i: (i, 0))
    out_spec = pl.BlockSpec((tile_sites, NUM_PREDICTORS), lambda i: (i, 0))
    preds, mses = pl.pallas_call(
        _forecast_kernel,
        grid=grid,
        in_specs=[in_spec, in_spec],
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(hist, mask)
    return preds, mses
