"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (``make artifacts``):

* ``artifacts/forecast.hlo.txt`` — forecast_model at the AOT shapes
* ``artifacts/rank.hlo.txt``     — rank_model at the AOT shapes
* ``artifacts/manifest.json``    — shapes / dtypes / predictor-bank
  layout consumed by ``rust/src/runtime/artifacts.rs``

Python runs exactly once, at build time; the Rust binary is
self-contained afterwards.
"""

import argparse
import hashlib
import json
import os

from jax._src.lib import xla_client as xc

from . import model
from .kernels.common import (
    AOT_ATTRS,
    AOT_REPLICAS,
    AOT_REQUESTS,
    AOT_SITES,
    AOT_WINDOW,
    EMA_ALPHAS,
    NUM_PREDICTORS,
    WINDOW_LONG,
    WINDOW_SHORT,
)


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = {}

    specs = {
        "forecast": dict(
            lowered=model.jit_forecast(AOT_SITES, AOT_WINDOW),
            inputs=[
                {"name": "hist", "shape": [AOT_SITES, AOT_WINDOW], "dtype": "f32"},
                {"name": "mask", "shape": [AOT_SITES, AOT_WINDOW], "dtype": "f32"},
                {"name": "load", "shape": [AOT_SITES], "dtype": "f32"},
            ],
            outputs=[
                {"name": "preds", "shape": [AOT_SITES, NUM_PREDICTORS], "dtype": "f32"},
                {"name": "mses", "shape": [AOT_SITES, NUM_PREDICTORS], "dtype": "f32"},
                {"name": "best", "shape": [AOT_SITES], "dtype": "f32"},
                {"name": "eff", "shape": [AOT_SITES], "dtype": "f32"},
            ],
        ),
        "rank": dict(
            lowered=model.jit_rank(AOT_REPLICAS, AOT_REQUESTS, AOT_ATTRS),
            inputs=[
                {"name": "attrs", "shape": [AOT_REPLICAS, AOT_ATTRS], "dtype": "f32"},
                {"name": "lo", "shape": [AOT_REQUESTS, AOT_ATTRS], "dtype": "f32"},
                {"name": "hi", "shape": [AOT_REQUESTS, AOT_ATTRS], "dtype": "f32"},
                {"name": "weights", "shape": [AOT_REQUESTS, AOT_ATTRS], "dtype": "f32"},
            ],
            outputs=[
                {"name": "scores", "shape": [AOT_REQUESTS, AOT_REPLICAS], "dtype": "f32"},
                {"name": "best_idx", "shape": [AOT_REQUESTS], "dtype": "i32"},
                {"name": "best_score", "shape": [AOT_REQUESTS], "dtype": "f32"},
            ],
        ),
    }

    for name, spec in specs.items():
        text = to_hlo_text(spec["lowered"])
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries[name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": spec["inputs"],
            "outputs": spec["outputs"],
        }
        print(f"wrote {path} ({len(text)} chars)")

    manifest = {
        "version": 1,
        "interchange": "hlo-text",
        "predictor_bank": {
            "num_predictors": NUM_PREDICTORS,
            "window_short": WINDOW_SHORT,
            "window_long": WINDOW_LONG,
            "ema_alphas": list(EMA_ALPHAS),
            "names": [
                "last_value",
                "running_mean",
                "sliding_mean_%d" % WINDOW_SHORT,
                "sliding_mean_%d" % WINDOW_LONG,
                *["ema_%.2f" % a for a in EMA_ALPHAS],
                "median_3",
            ],
        },
        "entries": entries,
    }
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-lower L2 graphs to HLO text")
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
