//! End-to-end data-grid simulation — the headline experiment (R7).
//!
//! Builds a heterogeneous grid (simnet links + GridFTP instrumentation
//! + live GRIS per site + replica catalog), replays a Zipf/Pareto
//! workload under every selection policy on identically seeded grids,
//! and reports the paper's qualitative claim quantitatively: informed,
//! history-based selection beats uninformed selection.
//!
//! Uses the PJRT forecast artifact (L1 Pallas kernel through the L2 JAX
//! graph) when `artifacts/` is built; falls back to the numerically
//! equivalent pure-Rust bank otherwise.
//!
//! ```sh
//! cargo run --release --example datagrid_sim -- --sites 12 --requests 400
//! # record / replay a workload trace (JSONL):
//! cargo run --release --example datagrid_sim -- --trace-out /tmp/w.jsonl
//! cargo run --release --example datagrid_sim -- --trace-in /tmp/w.jsonl
//! ```

use globus_replica::broker::selectors::SelectorKind;
use globus_replica::config::GridConfig;
use globus_replica::experiment::run_quality_trace;
use globus_replica::runtime::engine::EngineHandle;
use globus_replica::simnet::{trace, Workload, WorkloadSpec};
use globus_replica::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let sites = args.usize_or("sites", 12);
    let requests = args.usize_or("requests", 400);
    let seed = args.u64_or("seed", 42);
    let replicas = args.usize_or("replicas", 4);
    let warm = args.usize_or("warm", 12);
    let files = args.usize_or("files", 32);

    let cfg = GridConfig::generate(sites, seed);
    let spec = WorkloadSpec { files, ..Default::default() };

    // Workload: synthetic by default; --trace-in replays a recorded
    // trace, --trace-out records the synthetic one for later replay.
    let trace_reqs = match args.get("trace-in") {
        Some(path) => {
            let t = trace::load(path).expect("loading trace");
            println!("replaying {} requests from {path}", t.len());
            t
        }
        None => Workload::new(spec.clone(), seed).take(requests),
    };
    if let Some(path) = args.get("trace-out") {
        trace::save(path, &trace_reqs).expect("saving trace");
        println!("recorded {} requests to {path}", trace_reqs.len());
    }
    let requests = trace_reqs.len();

    println!("== datagrid_sim: {sites} sites, {files} files x{replicas} replicas, {requests} requests, seed {seed} ==");
    let engine = match EngineHandle::spawn_default() {
        Ok(e) => {
            println!(
                "forecast engine: PJRT artifact (AOT {}x{} window, {} predictors)",
                e.aot_sites, e.aot_window, e.num_predictors
            );
            Some(e)
        }
        Err(err) => {
            println!("forecast engine: pure-Rust bank (artifacts not loaded: {err:#})");
            None
        }
    };

    println!(
        "\n{:<16} {:>10} {:>10} {:>12} {:>10} {:>10}",
        "policy", "mean(s)", "p95(s)", "mean KB/s", "%optimal", "slowdown"
    );
    let mut rows = Vec::new();
    for kind in SelectorKind::all() {
        let engine = if kind == SelectorKind::Forecast { engine.clone() } else { None };
        let r = run_quality_trace(&cfg, &spec, &trace_reqs, replicas, warm, kind, engine);
        println!(
            "{:<16} {:>10.1} {:>10.1} {:>12.0} {:>9.0}% {:>10.2}",
            r.policy,
            r.mean_time,
            r.p95_time,
            r.mean_bandwidth / 1024.0,
            r.pct_optimal * 100.0,
            r.mean_slowdown
        );
        rows.push(r);
    }

    let random = rows.iter().find(|r| r.policy == "random").unwrap();
    let forecast = rows.iter().find(|r| r.policy == "forecast").unwrap();
    let speedup = random.mean_time / forecast.mean_time;
    println!(
        "\nheadline: forecast-ranked selection is {speedup:.2}x faster than random \
         (mean transfer {:.1}s vs {:.1}s), optimal pick rate {:.0}% vs {:.0}%",
        forecast.mean_time,
        random.mean_time,
        forecast.pct_optimal * 100.0,
        random.pct_optimal * 100.0
    );
    if speedup < 1.0 {
        println!("WARNING: informed selection did not win on this seed — inspect config");
        std::process::exit(1);
    }
}
