//! Co-allocation demo: one large file, many replicas, parallel ranges.
//!
//! Builds a simulated grid, warms the bandwidth history, then fetches a
//! large logical file twice — once from the broker's single best
//! replica, once co-allocated across the top-K replicas — and prints
//! the stripe plan, the per-stream outcomes (including work-stealing
//! rebalances) and the speedup.
//!
//! ```sh
//! cargo run --release --example coalloc_demo -- \
//!     --sites 8 --streams 4 --size-mb 1024 --seed 42
//! ```

use globus_replica::broker::RankPolicy;
use globus_replica::classad::parse_classad;
use globus_replica::coalloc;
use globus_replica::config::{CoallocPolicy, GridConfig};
use globus_replica::experiment::SimGrid;
use globus_replica::simnet::WorkloadSpec;
use globus_replica::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let sites = args.usize_or("sites", 8);
    let streams = args.usize_or("streams", 4);
    let size = args.f64_or("size-mb", 1024.0) * 1024.0 * 1024.0;
    let seed = args.u64_or("seed", 42);

    let cfg = GridConfig::generate(sites, seed);
    let spec = WorkloadSpec { files: 4, ..Default::default() };
    let mut grid = SimGrid::build(&cfg, &spec, sites.min(6), 32);
    grid.warm(6);

    let policy = CoallocPolicy { max_streams: streams, ..Default::default() };
    let broker = grid.broker(RankPolicy::ForecastBandwidth { engine: None });
    let request = parse_classad(
        "hostname = \"client\"; reqdSpace = 0; requirement = other.AvgRDBandwidth > 0;",
    )
    .unwrap();
    let logical = grid.files[0].clone();

    let sel = broker.select_coalloc(&logical, &request, size, &policy)?;
    println!(
        "file {logical} ({:.0} MB), {} candidate replicas, striping over {}",
        size / 1024.0 / 1024.0,
        sel.selection.candidates.len(),
        sel.plan.assignments.len()
    );
    println!("\nstripe plan (block {:.0} MB):", sel.plan.block_size / 1024.0 / 1024.0);
    println!(
        "{:<12} {:>14} {:>10} {:>8} {:>8}",
        "site", "pred KB/s", "offset MB", "blocks", "share"
    );
    for a in &sel.plan.assignments {
        println!(
            "{:<12} {:>14.1} {:>10.0} {:>8} {:>7.1}%",
            a.source.site,
            a.source.predicted_bw / 1024.0,
            a.offset / 1024.0 / 1024.0,
            a.blocks,
            a.share * 100.0
        );
    }

    // Single-best cost on a probe copy (identical upcoming link state).
    let best = grid.topo.index_of(&sel.selection.site).unwrap();
    let mut probe = grid.topo.clone_for_probe();
    probe.begin_transfer(best);
    let (single, _) = probe.transfer_from(best, size);

    // The real co-allocated Access.
    let out = coalloc::execute(&mut grid.topo, &grid.ftp, "client", &sel.plan, &policy)?;
    let metrics = globus_replica::metrics::Metrics::new();
    out.record_metrics(&metrics);

    println!("\nper-stream outcome:");
    println!(
        "{:<12} {:>8} {:>8} {:>12} {:>14}",
        "site", "blocks", "stolen", "MB", "mean KB/s"
    );
    for s in &out.streams {
        println!(
            "{:<12} {:>8} {:>8} {:>12.0} {:>14.1}",
            s.site,
            s.blocks,
            s.stolen,
            s.bytes / 1024.0 / 1024.0,
            s.mean_bandwidth / 1024.0
        );
    }
    println!(
        "\nsingle-best ({}): {:.0}s   co-allocated: {:.0}s   speedup: {:.2}x   steals: {}",
        sel.selection.site,
        single,
        out.duration,
        single / out.duration.max(1e-9),
        out.steals
    );
    println!(
        "aggregate bandwidth: {:.1} KB/s across {} streams",
        out.aggregate_bandwidth / 1024.0,
        out.streams.len()
    );
    println!("\nmetrics:\n{}", metrics.render());
    println!("coalloc_demo OK");
    Ok(())
}
