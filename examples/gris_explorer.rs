//! GRIS/GIIS explorer — regenerates the paper's Figures 2–5 from the
//! *live* system and demonstrates the MDS discovery pattern over TCP.
//!
//! 1. Prints the object-class definitions (Figures 2, 4, 5) from the
//!    schema registry.
//! 2. Spins up two GRIS daemons and a GIIS on loopback TCP, registers
//!    the GRISes, performs the paper's two-step discovery: broad GIIS
//!    query → drill-down GRIS search → LDIF → attributes.
//! 3. Renders each site's DIT (Figure 3).
//!
//! ```sh
//! cargo run --release --example gris_explorer
//! ```

use std::sync::{Arc, Mutex};

use globus_replica::directory::client::DirectoryClient;
use globus_replica::directory::schema;
use globus_replica::directory::server::DirectoryServer;
use globus_replica::directory::{Dn, Entry, Filter, Giis, Gris, Scope};

fn make_gris(org: &str, site: &str, avail_gb: f64, avg_kbps: f64) -> Gris {
    let mut gris = Gris::new(org, site);
    let base = gris.base_dn().clone();
    let vol = base.child("gss", "vol0");
    let mut e = Entry::new(vol.clone());
    e.add("objectClass", "GridStorageServerVolume");
    e.put_f64("totalSpace", 100.0 * 1024f64.powi(3));
    e.put_f64("availableSpace", avail_gb * 1024f64.powi(3));
    e.put("mountPoint", "/dev/sandbox");
    e.put_f64("diskTransferRate", 2e7);
    e.put_f64("drdTime", 8.5);
    e.put_f64("dwrTime", 9.5);
    e.add("filesystem", "ext3");
    e.add("filesystem", "xfs");
    gris.add_entry(e);
    let mut bw = Entry::new(vol.child("gss", "bw"));
    bw.add("objectClass", "GridStorageTransferBandwidth");
    for a in ["MaxRDBandwidth", "AvgRDBandwidth"] {
        bw.put_f64(a, avg_kbps * 1024.0);
    }
    for a in ["MinRDBandwidth", "MaxWRBandwidth", "MinWRBandwidth", "AvgWRBandwidth"] {
        bw.put_f64(a, avg_kbps * 512.0);
    }
    gris.add_entry(bw);
    gris
}

fn main() -> anyhow::Result<()> {
    // --- Figures 2, 4, 5: object classes ------------------------------
    println!("===== Figure 2: Grid::Storage::ServerVolume =====");
    println!("{}", schema::SERVER_VOLUME.render());
    println!("===== Figure 4: Grid::Storage::TransferBandwidth =====");
    println!("{}", schema::TRANSFER_BANDWIDTH.render());
    println!("===== Figure 5: Grid::Storage::SourceTransferBandwidth =====");
    println!("{}", schema::SOURCE_TRANSFER_BANDWIDTH.render());

    // --- Live daemons over TCP ----------------------------------------
    let gris_a = make_gris("anl", "mcs", 50.0, 75.0);
    let gris_b = make_gris("lbl", "dsd", 80.0, 60.0);
    let tree_a = gris_a.render_tree();
    let base_a = gris_a.base_dn().clone();
    let base_b = gris_b.base_dn().clone();

    let srv_a = DirectoryServer::spawn(Arc::new(Mutex::new(gris_a)), 0)?;
    let srv_b = DirectoryServer::spawn(Arc::new(Mutex::new(gris_b)), 0)?;
    let giis = DirectoryServer::spawn(Arc::new(Mutex::new(Giis::new())), 0)?;
    println!("GRIS mcs on {}, GRIS dsd on {}, GIIS on {}\n", srv_a.addr(), srv_b.addr(), giis.addr());

    // Register both GRISes with the GIIS (soft-state registration).
    let mut reg = DirectoryClient::connect(giis.addr())?;
    reg.register(
        "mcs",
        srv_a.addr(),
        &base_a,
        vec![("storageType".into(), "disk".into()), ("availableGB".into(), "50".into())],
    )?;
    reg.register(
        "dsd",
        srv_b.addr(),
        &base_b,
        vec![("storageType".into(), "disk".into()), ("availableGB".into(), "80".into())],
    )?;

    // Broad query at the GIIS: disk sites with >= 60 GB free.
    let found = reg.discover(&Filter::parse("(&(storageType=disk)(availableGB>=60))")?)?;
    println!("GIIS broad query (storageType=disk, availableGB>=60):");
    for e in &found {
        println!("  site={} addr={}", e.first("site").unwrap(), e.first("addr").unwrap());
    }
    assert_eq!(found.len(), 1);

    // Drill down: direct GRIS search for fresh detail.
    let addr = found[0].first("addr").unwrap().to_string();
    let mut gris_client = DirectoryClient::connect(&addr)?;
    let entries = gris_client.search(
        &Dn::parse("o=grid")?,
        Scope::Sub,
        &Filter::parse("(objectClass=GridStorage*)")?,
    )?;
    println!("\nGRIS drill-down returned {} entries (LDIF over TCP):", entries.len());
    for e in &entries {
        println!(
            "  dn: {}  ({} attrs)",
            e.dn,
            e.attr_count()
        );
    }
    let vol = entries
        .iter()
        .find(|e| e.object_classes().iter().any(|c| c.ends_with("ServerVolume")))
        .unwrap();
    println!(
        "  availableSpace = {} bytes, filesystem = {:?}",
        vol.first("availableSpace").unwrap(),
        vol.get("filesystem").unwrap()
    );

    // --- Figure 3: the DIT --------------------------------------------
    println!("\n===== Figure 3: live DIT of site mcs =====");
    println!("{tree_a}");

    println!("gris_explorer OK");
    Ok(())
}
