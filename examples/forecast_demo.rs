//! Forecast engine demo: PJRT artifact vs pure-Rust predictor bank.
//!
//! Generates synthetic bandwidth series of several regimes (white
//! noise, random walk, diurnal, spiky), runs both the AOT-compiled
//! JAX/Pallas forecast kernel (through `runtime::EngineHandle`) and the
//! pure-Rust bank, and prints per-regime predictions, chosen
//! forecaster, and cross-implementation agreement.
//!
//! ```sh
//! make artifacts && cargo run --release --example forecast_demo
//! ```

use globus_replica::forecast::forecast_bank;
use globus_replica::runtime::engine::EngineHandle;
use globus_replica::util::prng::Rng;

fn regimes(rng: &mut Rng, n: usize) -> Vec<(String, Vec<f64>)> {
    let mut out = Vec::new();
    // White noise around 400 KB/s.
    out.push((
        "white-noise".into(),
        (0..n).map(|_| rng.gauss(400e3, 40e3).max(1e3)).collect(),
    ));
    // Random walk.
    let mut x = 600e3;
    out.push((
        "random-walk".into(),
        (0..n)
            .map(|_| {
                x = (x + rng.gauss(0.0, 30e3)).max(1e3);
                x
            })
            .collect(),
    ));
    // Diurnal sinusoid + noise.
    out.push((
        "diurnal".into(),
        (0..n)
            .map(|i| {
                (500e3 * (1.0 + 0.5 * (i as f64 / 8.0).sin()) + rng.gauss(0.0, 20e3)).max(1e3)
            })
            .collect(),
    ));
    // Stable with rare congestion collapses.
    out.push((
        "spiky".into(),
        (0..n)
            .map(|_| {
                if rng.chance(0.1) {
                    rng.range(10e3, 50e3)
                } else {
                    rng.gauss(800e3, 30e3).max(1e3)
                }
            })
            .collect(),
    ));
    out
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(2026);
    let series = regimes(&mut rng, 48);

    let engine = EngineHandle::spawn_default().ok();
    match &engine {
        Some(e) => println!(
            "PJRT engine loaded: {} predictors, window {}\n",
            e.num_predictors, e.aot_window
        ),
        None => println!("artifacts not built — showing pure-Rust bank only\n"),
    }

    let names = [
        "last", "mean", "win4", "win16", "ema.1", "ema.3", "ema.6", "med3",
    ];
    println!(
        "{:<12} {:>10} {:>8} {:>12} {:>12} {:>10}",
        "regime", "truth-ish", "best", "rust pred", "pjrt pred", "agree"
    );
    for (name, obs) in &series {
        let mask = vec![1.0; obs.len()];
        let rust = forecast_bank(obs, &mask);
        let best = rust.best_index();
        let pjrt = engine
            .as_ref()
            .and_then(|e| e.forecast(&[obs.clone()], &[0.0]).ok())
            .map(|o| o.best[0] as f64);
        let recent = obs[obs.len() - 8..].iter().sum::<f64>() / 8.0;
        let agree = pjrt
            .map(|p| {
                let rel = (p - rust.best()).abs() / rust.best().abs().max(1.0);
                if rel < 1e-3 { "yes" } else { "NO" }
            })
            .unwrap_or("-");
        println!(
            "{:<12} {:>10.0} {:>8} {:>12.0} {:>12} {:>10}",
            name,
            recent,
            names[best],
            rust.best(),
            pjrt.map(|p| format!("{p:.0}")).unwrap_or_else(|| "-".into()),
            agree
        );
    }

    // Accuracy comparison: backtest each predictor and the adaptive
    // choice across regimes (MSE on the final 16 observations).
    println!("\nper-regime backtest MSE (lower better), adaptive vs fixed:");
    println!("{:<12} {:>12} {:>12} {:>12}", "regime", "last-value", "run-mean", "adaptive");
    for (name, obs) in &series {
        let mut errs = [0.0f64; 3];
        let mut n = 0.0;
        for t in 24..obs.len() {
            let past = &obs[..t];
            let mask = vec![1.0; past.len()];
            let bank = forecast_bank(past, &mask);
            let truth = obs[t];
            errs[0] += (bank.preds[0] - truth).powi(2);
            errs[1] += (bank.preds[1] - truth).powi(2);
            errs[2] += (bank.best() - truth).powi(2);
            n += 1.0;
        }
        println!(
            "{:<12} {:>12.3e} {:>12.3e} {:>12.3e}",
            name,
            errs[0] / n,
            errs[1] / n,
            errs[2] / n
        );
    }
    println!("\nforecast_demo OK");
    Ok(())
}
