//! Quickstart: the paper's own example, end to end (Figure 6 + §4/§5.2).
//!
//! Builds a three-site grid whose ANL site publishes exactly the
//! storage ClassAd from §4, registers a replica of `run42.dat` at every
//! site, then runs the decentralized broker with the §5.2 request ad
//! and prints the phase-by-phase trace: Search (catalog + GRIS + LDIF),
//! Match (LDIF→ClassAd conversion + Condor matchmaking + rank), Access
//! (simulated GridFTP fetch).
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::{Arc, Mutex, RwLock};

use globus_replica::broker::{Broker, LocalInfoService, RankPolicy};
use globus_replica::catalog::{PhysicalLocation, ReplicaCatalog};
use globus_replica::classad::parse_classad;
use globus_replica::config::GridConfig;
use globus_replica::directory::{Entry, Gris};
use globus_replica::gridftp::GridFtp;
use globus_replica::simnet::Topology;
use globus_replica::util::units::Bytes;

/// (site, org, availableSpace GB, MaxRDBandwidth KB/s)
const SITES: [(&str, &str, f64, f64); 3] = [
    ("hugo.mcs.anl.gov", "anl", 50.0, 75.0), // the §4 storage ad
    ("dsd.lbl.gov", "lbl", 80.0, 60.0),
    ("grid.isi.edu", "isi", 3.0, 90.0), // fails the 5G space floor
];

fn main() -> anyhow::Result<()> {
    println!("== Globus replica selection — paper §4/§5.2 walk-through ==\n");

    // --- Core services: replica catalog + per-site storage GRIS ------
    let mut catalog = ReplicaCatalog::new();
    catalog.create_logical("run42.dat", Bytes::from_gb(2.0), "cms-2001")?;
    let mut info = LocalInfoService::new();
    for (site, org, gb, kbps) in SITES {
        catalog.add_replica(
            "run42.dat",
            PhysicalLocation { site: site.into(), url: format!("gsiftp://{site}/run42.dat") },
        )?;
        let mut gris = Gris::new(org, site);
        let base = gris.base_dn().clone();
        let vol = base.child("gss", "sandbox");
        let mut e = Entry::new(vol.clone());
        e.add("objectClass", "GridStorageServerVolume");
        e.put_f64("totalSpace", 100.0 * 1024f64.powi(3));
        e.put_f64("availableSpace", gb * 1024f64.powi(3));
        e.put("mountPoint", "/dev/sandbox");
        e.put_f64("diskTransferRate", 2e7);
        e.put_f64("drdTime", 8.0);
        e.put_f64("dwrTime", 9.0);
        // The §4 usage policy, published through the GRIS.
        e.put(
            "requirements",
            "other.reqdSpace < 10G && other.reqdRDBandwidth < 75K/Sec",
        );
        gris.add_entry(e);
        let mut bw = Entry::new(vol.child("gss", "bw"));
        bw.add("objectClass", "GridStorageTransferBandwidth");
        for a in ["MaxRDBandwidth", "AvgRDBandwidth"] {
            bw.put_f64(a, kbps * 1024.0);
        }
        for a in ["MinRDBandwidth", "MaxWRBandwidth", "MinWRBandwidth", "AvgWRBandwidth"] {
            bw.put_f64(a, kbps * 512.0);
        }
        gris.add_entry(bw);
        info.add(site, Arc::new(RwLock::new(gris)));
    }

    // --- The application's request ad — verbatim from §5.2 -----------
    let request = parse_classad(
        r#"hostname = "comet.xyz.com";
           reqdSpace = 5G;
           reqdRDBandwidth = 50K/Sec;
           rank = other.availableSpace;
           requirement = other.availableSpace >
               5G && other.MaxRDBandwidth >
               50K/Sec;"#,
    )?;
    println!("application request ClassAd:\n{request}");

    // --- Decentralized selection (Figure 6) ---------------------------
    let broker = Broker::new(
        Arc::new(Mutex::new(catalog)),
        Arc::new(info),
        RankPolicy::ClassAdRank,
    );
    let sel = broker.select("run42.dat", &request)?;
    let t = &sel.trace;
    println!("SEARCH phase ({}µs):", t.search_us);
    println!("  replica catalog -> {:?}", t.replica_sites);
    println!("  + GRIS LDAP queries, LDIF responses");
    println!("CONVERT ({}µs): LDIF -> ClassAds", t.convert_us);
    println!("MATCH phase ({}µs):", t.match_us);
    for (site, ok) in &t.match_results {
        println!("  {site:<18} {}", if *ok { "MATCH" } else { "reject (requirements)" });
    }
    println!("  ranking by `rank = other.availableSpace`:");
    for (site, score) in &t.ranking {
        println!("    {site:<18} {:.0} GB", score / 1024f64.powi(3));
    }
    println!("  selected: {} ({})\n", sel.site, sel.url);

    // --- ACCESS phase: fetch over the simulated GridFTP fabric -------
    let cfg = GridConfig::generate(SITES.len(), 7);
    let mut topo = Topology::build(&cfg);
    let ftp = GridFtp::new(&topo, 16);
    let site_idx = SITES.iter().position(|(s, ..)| *s == sel.site).unwrap();
    let out = ftp.fetch(&mut topo, site_idx, "comet.xyz.com", 2.0 * 1024f64.powi(3));
    println!(
        "ACCESS phase: fetched 2G from {} in {:.1}s ({:.0} KB/s), instrumentation recorded",
        sel.site,
        out.duration,
        out.bandwidth / 1024.0
    );
    {
        let h = ftp.history(site_idx);
        let h = h.read().unwrap();
        assert_eq!(h.rd.count, 1);
        assert_eq!(h.rd.last_peer, "comet.xyz.com");
    }

    // The §4 storage ad should have produced the §5.2 expected outcome:
    // ISI rejected (3G < 5G floor), ANL matches, LBL wins on space.
    assert_eq!(sel.site, "dsd.lbl.gov");
    assert_eq!(t.match_results.iter().filter(|(_, ok)| *ok).count(), 2);
    println!("\nquickstart OK");
    Ok(())
}
