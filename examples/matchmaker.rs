//! Matchmaker: a standalone ClassAd matching/ranking tool.
//!
//! ```sh
//! # the paper's §4 + §5.2 ads, built in:
//! cargo run --release --example matchmaker -- --demo
//!
//! # your own ads (bare `attr = expr;` text files):
//! cargo run --release --example matchmaker -- --request req.ad storage1.ad storage2.ad
//! ```
//!
//! Prints, for every storage ad: whether the symmetric requirements
//! match holds, and the request's rank of the ad; then the winner.

use globus_replica::classad::{
    eval_in_match, parse_classad, rank_candidates, symmetric_match, ClassAd,
};
use globus_replica::util::cli::Args;

const DEMO_STORAGE: &str = r#"
    hostname = "hugo.mcs.anl.gov";
    volume = "/dev/sandbox";
    availableSpace = 50G;
    MaxRDBandwidth = 75K/Sec;
    requirement = other.reqdSpace < 10G
        && other.reqdRDBandwidth < 75K/Sec;
"#;

const DEMO_STORAGE_2: &str = r#"
    hostname = "dsd.lbl.gov";
    volume = "/scratch";
    availableSpace = 80G;
    MaxRDBandwidth = 60K/Sec;
"#;

const DEMO_STORAGE_3: &str = r#"
    hostname = "grid.isi.edu";
    volume = "/tmp";
    availableSpace = 3G;
    MaxRDBandwidth = 90K/Sec;
"#;

const DEMO_REQUEST: &str = r#"
    hostname = "comet.xyz.com";
    reqdSpace = 5G;
    reqdRDBandwidth = 50K/Sec;
    rank = other.availableSpace;
    requirement = other.availableSpace >
        5G && other.MaxRDBandwidth >
        50K/Sec;
"#;

fn load(path: &str) -> anyhow::Result<ClassAd> {
    let text = std::fs::read_to_string(path)?;
    Ok(parse_classad(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let (request, storages): (ClassAd, Vec<(String, ClassAd)>) = if args.has("demo") {
        (
            parse_classad(DEMO_REQUEST).unwrap(),
            vec![
                ("§4 storage ad (ANL)".into(), parse_classad(DEMO_STORAGE).unwrap()),
                ("LBL".into(), parse_classad(DEMO_STORAGE_2).unwrap()),
                ("ISI".into(), parse_classad(DEMO_STORAGE_3).unwrap()),
            ],
        )
    } else {
        let req_path = args
            .get("request")
            .ok_or_else(|| anyhow::anyhow!("need --demo or --request <file> <storage files...>"))?;
        let request = load(req_path)?;
        let mut storages = Vec::new();
        for p in args.positional() {
            storages.push((p.clone(), load(p)?));
        }
        if storages.is_empty() {
            anyhow::bail!("no storage ads given");
        }
        (request, storages)
    };

    println!("request ad:\n{request}");
    for (name, ad) in &storages {
        let ok = symmetric_match(&request, ad);
        let rank = eval_in_match(&request, ad, "rank");
        println!(
            "{name:<22} match={:<5} rank={rank}",
            if ok { "YES" } else { "no" }
        );
    }
    let ads: Vec<ClassAd> = storages.iter().map(|(_, a)| a.clone()).collect();
    let ranked = rank_candidates(&request, &ads);
    match ranked.first() {
        Some(best) => println!(
            "\nbest match: {} (rank {:.1})",
            storages[best.index].0, best.rank
        ),
        None => println!("\nno storage ad satisfies the request"),
    }
    Ok(())
}
