#!/usr/bin/env bash
# Tier-1 verification + hygiene, as specified in ROADMAP.md.
#
#   scripts/ci.sh                  full run
#   CI_REQUIRE_TOOLCHAIN=1         fail (exit 2) instead of skipping when
#                                  cargo is absent (what .github/workflows
#                                  sets so CI never silently no-ops)
#   BENCH_QUICK=1 also shortens the in-tree bench harness if benches run.
#
# Gates, in order: docs link/anchor check (pure shell — runs even in
# desk-check mode), release build, tests, rustfmt --check, clippy with
# -D warnings, rustdoc with -D warnings. The format/lint gates skip
# with a loud notice when the component is not installed (minimal
# rustup profiles); the toolchain gates skip — loudly, as "desk-check
# mode" — when there is no Rust toolchain at all, which is the
# documented state of several build containers (see ROADMAP
# "Seed-test triage").
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== docs: link + bench-key check (ARCHITECTURE.md, BENCHMARKS.md) =="
# Pure shell so it gates desk-check containers too: every relative
# markdown link in the two books must point at a file that exists, and
# the set of BENCH_*.json artifacts documented in BENCHMARKS.md must
# exactly match the set scripts/bench.sh produces.
docs_ok=1
for doc in ARCHITECTURE.md BENCHMARKS.md; do
    if [ ! -s "$doc" ]; then
        echo "DOCS GATE: $doc missing or empty"
        docs_ok=0
        continue
    fi
    # Inline links: ](target). Skip absolute URLs and pure anchors;
    # strip any #fragment before the existence test.
    while IFS= read -r target; do
        case "$target" in
            http://*|https://*|mailto:*|"#"*) continue ;;
        esac
        path="${target%%#*}"
        [ -z "$path" ] && continue
        if [ ! -e "$path" ]; then
            echo "DOCS GATE: $doc links to missing file: $target"
            docs_ok=0
        fi
    done < <(grep -oE '\]\([^)]+\)' "$doc" | sed -e 's/^](//' -e 's/)$//')
done
if [ -s BENCHMARKS.md ]; then
    documented="$(grep -oE 'BENCH_[a-z_]+\.json' BENCHMARKS.md | sort -u)"
    produced="$(grep -oE 'BENCH_[a-z_]+\.json' scripts/bench.sh | sort -u)"
    if [ "$documented" != "$produced" ]; then
        echo "DOCS GATE: BENCHMARKS.md artifacts do not match scripts/bench.sh"
        echo "--- documented (BENCHMARKS.md):"
        echo "$documented"
        echo "--- produced (scripts/bench.sh):"
        echo "$produced"
        docs_ok=0
    fi
fi
if [ "$docs_ok" != "1" ]; then
    echo "CI FAILED: docs gate"
    exit 2
fi
echo "docs OK (links resolve, bench artifact sets match)"

if ! command -v cargo >/dev/null 2>&1; then
    echo "!!=========================================================!!"
    echo "!! NO TOOLCHAIN — desk-check mode                          !!"
    echo "!! cargo/rustc are not on PATH in this container: tier-1   !!"
    echo "!! build, tests, rustfmt and clippy were NOT executed.     !!"
    echo "!! Nothing has been verified. Run this script again from a !!"
    echo "!! toolchain-equipped environment (CI does).               !!"
    echo "!!=========================================================!!"
    if [ "${CI_REQUIRE_TOOLCHAIN:-0}" != "0" ]; then
        echo "CI FAILED: CI_REQUIRE_TOOLCHAIN is set and no toolchain found"
        exit 2
    fi
    echo "CI SKIPPED (desk-check mode)"
    exit 0
fi

echo "== tier-1: build =="
cargo build --release

echo "== tier-1: tests =="
cargo test -q

echo "== tier-1: traced smoke (flight recorder end-to-end) =="
# Tiny flight-recorded open-loop scenario: exports TRACE_ci_smoke.json
# (Chrome trace-event) + .jsonl, then feeds the export back through
# `trace-summary`, whose loader rejects malformed JSON with exit 2 —
# that round trip IS the "exported JSON parses" validation.
cargo run --release --quiet -- simulate --trace \
    --sites 4 --requests 8 --seed 7 --trace-name ci_smoke
test -s TRACE_ci_smoke.json
test -s TRACE_ci_smoke.jsonl
cargo run --release --quiet -- trace-summary TRACE_ci_smoke.json --json >/dev/null
cargo run --release --quiet -- trace-summary TRACE_ci_smoke.jsonl >/dev/null
echo "traced smoke OK (TRACE_ci_smoke.json round-tripped through trace-summary)"

echo "== tier-1: chaos determinism smoke (grid weather end-to-end) =="
# Two identically seeded chaos sweeps (seeded weather + retry/failover
# on every request path) must produce byte-identical reports — the
# ISSUE-7 determinism acceptance, checked end-to-end through the CLI.
cargo run --release --quiet -- chaos --sites 4 --requests 6 --seed 7 \
    --weather storm --out CHAOS_ci_a.json >/dev/null
cargo run --release --quiet -- chaos --sites 4 --requests 6 --seed 7 \
    --weather storm --out CHAOS_ci_b.json >/dev/null
cmp CHAOS_ci_a.json CHAOS_ci_b.json
test -s CHAOS_ci_a.json
echo "chaos smoke OK (identically seeded sweeps byte-identical)"

echo "== tier-1: economy determinism smoke (replica economy end-to-end) =="
# Two identically seeded economy sweeps (popularity-driven replication
# + eviction ticking inside the kernel, static arm alongside) must
# produce byte-identical reports — the ISSUE-10 determinism
# acceptance, checked end-to-end through the CLI.
cargo run --release --quiet -- economy --sites 4 --requests 12 --seed 7 \
    --out ECONOMY_ci_a.json >/dev/null
cargo run --release --quiet -- economy --sites 4 --requests 12 --seed 7 \
    --out ECONOMY_ci_b.json >/dev/null
cmp ECONOMY_ci_a.json ECONOMY_ci_b.json
test -s ECONOMY_ci_a.json
echo "economy smoke OK (identically seeded sweeps byte-identical)"

echo "== hygiene: rustfmt =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "rustfmt unavailable in this image; skipping format check"
fi

echo "== hygiene: clippy =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "!! clippy unavailable in this image; LINT GATE SKIPPED !!"
fi

echo "== hygiene: rustdoc =="
# The module docs are the architecture book's source of truth
# (ARCHITECTURE.md links into them); broken intra-doc links are bugs.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "CI OK"
