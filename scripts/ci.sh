#!/usr/bin/env bash
# Tier-1 verification + hygiene, as specified in ROADMAP.md.
#
#   scripts/ci.sh                  full run
#   CI_REQUIRE_TOOLCHAIN=1         fail (exit 2) instead of skipping when
#                                  cargo is absent (what .github/workflows
#                                  sets so CI never silently no-ops)
#   BENCH_QUICK=1 also shortens the in-tree bench harness if benches run.
#
# Gates, in order: release build, tests, rustfmt --check, clippy with
# -D warnings. The format/lint gates skip with a loud notice when the
# component is not installed (minimal rustup profiles); the whole run
# skips — loudly, as "desk-check mode" — when there is no Rust
# toolchain at all, which is the documented state of several build
# containers (see ROADMAP "Seed-test triage").
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "!!=========================================================!!"
    echo "!! NO TOOLCHAIN — desk-check mode                          !!"
    echo "!! cargo/rustc are not on PATH in this container: tier-1   !!"
    echo "!! build, tests, rustfmt and clippy were NOT executed.     !!"
    echo "!! Nothing has been verified. Run this script again from a !!"
    echo "!! toolchain-equipped environment (CI does).               !!"
    echo "!!=========================================================!!"
    if [ "${CI_REQUIRE_TOOLCHAIN:-0}" != "0" ]; then
        echo "CI FAILED: CI_REQUIRE_TOOLCHAIN is set and no toolchain found"
        exit 2
    fi
    echo "CI SKIPPED (desk-check mode)"
    exit 0
fi

echo "== tier-1: build =="
cargo build --release

echo "== tier-1: tests =="
cargo test -q

echo "== tier-1: traced smoke (flight recorder end-to-end) =="
# Tiny flight-recorded open-loop scenario: exports TRACE_ci_smoke.json
# (Chrome trace-event) + .jsonl, then feeds the export back through
# `trace-summary`, whose loader rejects malformed JSON with exit 2 —
# that round trip IS the "exported JSON parses" validation.
cargo run --release --quiet -- simulate --trace \
    --sites 4 --requests 8 --seed 7 --trace-name ci_smoke
test -s TRACE_ci_smoke.json
test -s TRACE_ci_smoke.jsonl
cargo run --release --quiet -- trace-summary TRACE_ci_smoke.json --json >/dev/null
cargo run --release --quiet -- trace-summary TRACE_ci_smoke.jsonl >/dev/null
echo "traced smoke OK (TRACE_ci_smoke.json round-tripped through trace-summary)"

echo "== tier-1: chaos determinism smoke (grid weather end-to-end) =="
# Two identically seeded chaos sweeps (seeded weather + retry/failover
# on every request path) must produce byte-identical reports — the
# ISSUE-7 determinism acceptance, checked end-to-end through the CLI.
cargo run --release --quiet -- chaos --sites 4 --requests 6 --seed 7 \
    --weather storm --out CHAOS_ci_a.json >/dev/null
cargo run --release --quiet -- chaos --sites 4 --requests 6 --seed 7 \
    --weather storm --out CHAOS_ci_b.json >/dev/null
cmp CHAOS_ci_a.json CHAOS_ci_b.json
test -s CHAOS_ci_a.json
echo "chaos smoke OK (identically seeded sweeps byte-identical)"

echo "== hygiene: rustfmt =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "rustfmt unavailable in this image; skipping format check"
fi

echo "== hygiene: clippy =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "!! clippy unavailable in this image; LINT GATE SKIPPED !!"
fi

echo "CI OK"
