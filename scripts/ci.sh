#!/usr/bin/env bash
# Tier-1 verification + hygiene, as specified in ROADMAP.md.
#
#   scripts/ci.sh           full run
#   BENCH_QUICK=1 also shortens the in-tree bench harness if benches run.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: build =="
cargo build --release

echo "== tier-1: tests =="
cargo test -q

echo "== hygiene: rustfmt =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "rustfmt unavailable in this image; skipping format check"
fi

echo "CI OK"
