#!/usr/bin/env bash
# Perf-trajectory recorder (ROADMAP perf log).
#
#   scripts/bench.sh              full run; writes BENCH_matchmaking.json
#   BENCH_QUICK=1 scripts/bench.sh   shortened measurement budget
#
# Runs the three selection-path benches (matchmaking core, broker phase
# breakdown, directory/GRIS) and records the matchmaking headline
# numbers — ns/op, ops/sec, and the compiled-vs-per-pair speedup at
# 1,000 candidates — as JSON, so the perf trajectory across PRs is
# finally written down instead of scrolling away in bench output.
set -euo pipefail
cd "$(dirname "$0")/.."

out="${BENCH_JSON:-BENCH_matchmaking.json}"

echo "== bench: matchmaking (JSON -> ${out}) =="
BENCH_JSON="${out}" cargo bench --bench bench_matchmaking

echo "== bench: broker =="
cargo bench --bench bench_broker

echo "== bench: directory =="
cargo bench --bench bench_directory

echo
echo "recorded ${out}:"
cat "${out}"
echo
