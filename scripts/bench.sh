#!/usr/bin/env bash
# Perf-trajectory recorder (ROADMAP perf log).
#
#   scripts/bench.sh              full run; writes BENCH_matchmaking.json,
#                                 BENCH_directory.json, BENCH_coalloc.json,
#                                 BENCH_contention.json, BENCH_chaos.json,
#                                 BENCH_economy.json and BENCH_kernel.json
#   BENCH_QUICK=1 scripts/bench.sh   shortened measurement budget
#
# Runs the selection-path benches (matchmaking core, broker phase
# breakdown, directory/GRIS + the ISSUE-5 GIIS-routed-vs-direct
# discovery comparison at 256 sites), the co-allocation bench (failover
# path + churn scenario), the open-loop contention load sweep, the
# grid-weather chaos sweep (fault intensity x recovery policy), the
# replica-economy sweep (static placement vs popularity-driven
# replication/eviction on identical traces) and the kernel throughput
# sweep (events/sec at 10^5 concurrent transfers on the sharded
# control plane), and records the headline numbers as JSON,
# so the perf trajectory across PRs is written down instead of
# scrolling away in bench output. Schemas: see BENCHMARKS.md.
set -euo pipefail
cd "$(dirname "$0")/.."

out="${BENCH_JSON:-BENCH_matchmaking.json}"
directory_out="${BENCH_DIRECTORY_JSON:-BENCH_directory.json}"
coalloc_out="${BENCH_COALLOC_JSON:-BENCH_coalloc.json}"
contention_out="${BENCH_CONTENTION_JSON:-BENCH_contention.json}"
chaos_out="${BENCH_CHAOS_JSON:-BENCH_chaos.json}"
economy_out="${BENCH_ECONOMY_JSON:-BENCH_economy.json}"
kernel_out="${BENCH_KERNEL_JSON:-BENCH_kernel.json}"

echo "== bench: matchmaking (JSON -> ${out}) =="
BENCH_JSON="${out}" cargo bench --bench bench_matchmaking

echo "== bench: broker =="
cargo bench --bench bench_broker

echo "== bench: directory (JSON -> ${directory_out}) =="
BENCH_JSON="${directory_out}" cargo bench --bench bench_directory

echo "== bench: coalloc (JSON -> ${coalloc_out}) =="
BENCH_JSON="${coalloc_out}" cargo bench --bench bench_coalloc

echo "== bench: contention load sweep (JSON -> ${contention_out}) =="
BENCH_JSON="${contention_out}" cargo bench --bench bench_contention

echo "== bench: chaos weather sweep (JSON -> ${chaos_out}) =="
BENCH_JSON="${chaos_out}" cargo bench --bench bench_chaos

echo "== bench: economy placement sweep (JSON -> ${economy_out}) =="
BENCH_JSON="${economy_out}" cargo bench --bench bench_economy

echo "== bench: kernel throughput (JSON -> ${kernel_out}) =="
BENCH_JSON="${kernel_out}" cargo bench --bench bench_kernel

echo
echo "recorded ${out}:"
cat "${out}"
echo
echo "recorded ${directory_out}:"
cat "${directory_out}"
echo
echo "recorded ${coalloc_out}:"
cat "${coalloc_out}"
echo
echo "recorded ${contention_out}:"
cat "${contention_out}"
echo
echo "recorded ${chaos_out}:"
cat "${chaos_out}"
echo
echo "recorded ${economy_out}:"
cat "${economy_out}"
echo
echo "recorded ${kernel_out}:"
cat "${kernel_out}"
echo
