//! Transfer instrumentation records and bandwidth history.
//!
//! Implements the data behind the paper's Figure 4 (site-wide
//! `TransferBandwidth` summary: max/min/avg read+write bandwidth) and
//! Figure 5 (`SourceTransferBandwidth`: last transfer per source), plus
//! the §3.2 extensions the paper motivates: standard deviations and a
//! trailing per-source observation window for prediction.

use std::collections::BTreeMap;

/// Transfer direction, from the storage server's point of view.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Server → client (a read of the replica).
    Read,
    /// Client → server (a write / replica creation).
    Write,
}

/// One instrumented transfer.
#[derive(Debug, Clone, PartialEq)]
pub struct TransferRecord {
    /// Simulated start time.
    pub at: f64,
    /// The remote endpoint ("source site" in Fig 5 terms).
    pub peer: String,
    pub direction: Direction,
    pub bytes: f64,
    pub duration: f64,
}

impl TransferRecord {
    pub fn bandwidth(&self) -> f64 {
        if self.duration <= 0.0 {
            0.0
        } else {
            self.bytes / self.duration
        }
    }
}

/// Streaming summary statistics (Welford) for one direction.
///
/// Only *successful* transfers feed the summary: a failed or stalled
/// transfer reports `bandwidth() == 0.0` (`duration <= 0`, or zero
/// bytes delivered), and admitting it would pin Figure 4's
/// `MinRDBandwidth` at 0 forever — the forecasters read that attribute
/// as "the slowest this link has ever gone", not "it once died".
/// Non-positive observations are counted in [`Self::failed`] instead.
#[derive(Debug, Clone, Default)]
pub struct BandwidthStats {
    pub count: u64,
    /// Non-positive (failed/stalled) observations skipped by
    /// [`Self::observe`] — excluded from min/max/avg/std/last.
    pub failed: u64,
    pub max: f64,
    pub min: f64,
    mean: f64,
    m2: f64,
    pub last: f64,
    pub last_peer: String,
}

impl BandwidthStats {
    fn observe(&mut self, bw: f64, peer: &str) {
        if !(bw > 0.0) {
            self.failed += 1;
            return;
        }
        self.count += 1;
        if self.count == 1 {
            self.max = bw;
            self.min = bw;
        } else {
            self.max = self.max.max(bw);
            self.min = self.min.min(bw);
        }
        let delta = bw - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (bw - self.mean);
        self.last = bw;
        self.last_peer = peer.to_string();
    }

    pub fn avg(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn std(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            (self.m2 / self.count as f64).sqrt()
        }
    }
}

/// Per-source trailing window of read-bandwidth observations.
#[derive(Debug, Clone)]
pub struct SourceHistory {
    window: usize,
    /// (time, bandwidth) oldest → newest.
    obs: Vec<(f64, f64)>,
    pub stats: BandwidthStats,
}

impl SourceHistory {
    fn new(window: usize) -> Self {
        SourceHistory { window, obs: Vec::new(), stats: BandwidthStats::default() }
    }

    fn push(&mut self, at: f64, bw: f64, peer: &str) {
        self.stats.observe(bw, peer);
        self.obs.push((at, bw));
        if self.obs.len() > self.window {
            let drop = self.obs.len() - self.window;
            self.obs.drain(..drop);
        }
    }

    /// The trailing bandwidth window, oldest → newest.
    pub fn window(&self) -> Vec<f64> {
        self.obs.iter().map(|(_, bw)| *bw).collect()
    }

    pub fn len(&self) -> usize {
        self.obs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.obs.is_empty()
    }
}

/// The full history store of one storage site's GridFTP server.
/// `Clone` snapshots the whole store — experiment drivers use that to
/// roll instrumentation back alongside `Topology::clone_for_probe`.
#[derive(Debug, Clone)]
pub struct HistoryStore {
    site: String,
    window: usize,
    pub rd: BandwidthStats,
    pub wr: BandwidthStats,
    per_source: BTreeMap<String, SourceHistory>,
    records: Vec<TransferRecord>,
    keep_records: usize,
    /// Rendered-attribute caches, invalidated on `record` (GRIS
    /// providers query far more often than transfers complete — Perf
    /// log P4).
    cache_fig4: Option<Vec<(String, String)>>,
    cache_fig5: BTreeMap<String, Vec<(String, String)>>,
}

impl HistoryStore {
    pub fn new(site: &str, window: usize) -> Self {
        HistoryStore {
            site: site.to_string(),
            window,
            rd: BandwidthStats::default(),
            wr: BandwidthStats::default(),
            per_source: BTreeMap::new(),
            records: Vec::new(),
            keep_records: 4096,
            cache_fig4: None,
            cache_fig5: BTreeMap::new(),
        }
    }

    pub fn site(&self) -> &str {
        &self.site
    }

    /// Ingest one instrumented transfer.
    pub fn record(&mut self, rec: TransferRecord) {
        let bw = rec.bandwidth();
        match rec.direction {
            Direction::Read => {
                self.rd.observe(bw, &rec.peer);
                self.per_source
                    .entry(rec.peer.clone())
                    .or_insert_with(|| SourceHistory::new(self.window))
                    .push(rec.at, bw, &rec.peer);
            }
            Direction::Write => self.wr.observe(bw, &rec.peer),
        }
        self.records.push(rec);
        if self.records.len() > self.keep_records {
            let drop = self.records.len() - self.keep_records;
            self.records.drain(..drop);
        }
        self.cache_fig4 = None;
        self.cache_fig5.clear();
    }

    pub fn source(&self, peer: &str) -> Option<&SourceHistory> {
        self.per_source.get(peer)
    }

    pub fn sources(&self) -> impl Iterator<Item = (&str, &SourceHistory)> {
        self.per_source.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn records(&self) -> &[TransferRecord] {
        &self.records
    }

    /// Figure-4 attributes, as GRIS `(attr, value)` pairs (cached
    /// between transfers — GRIS queries dominate).
    pub fn fig4_attributes(&mut self) -> Vec<(String, String)> {
        if let Some(c) = &self.cache_fig4 {
            return c.clone();
        }
        let out = self.render_fig4();
        self.cache_fig4 = Some(out.clone());
        out
    }

    fn render_fig4(&self) -> Vec<(String, String)> {
        let f = crate::directory::entry::format_f64;
        vec![
            ("MaxRDBandwidth".into(), f(self.rd.max)),
            ("MinRDBandwidth".into(), f(self.rd.min)),
            ("AvgRDBandwidth".into(), f(self.rd.avg())),
            ("MaxWRBandwidth".into(), f(self.wr.max)),
            ("MinWRBandwidth".into(), f(self.wr.min)),
            ("AvgWRBandwidth".into(), f(self.wr.avg())),
            ("StdRDBandwidth".into(), f(self.rd.std())),
            ("StdWRBandwidth".into(), f(self.wr.std())),
            ("NumTransfers".into(), f((self.rd.count + self.wr.count) as f64)),
        ]
    }

    /// Figure-5 attributes for one source, plus the trailing window the
    /// forecast engine consumes (`rdHistory`). Cached per peer between
    /// transfers.
    pub fn fig5_attributes(&mut self, peer: &str) -> Vec<(String, String)> {
        if let Some(c) = self.cache_fig5.get(peer) {
            return c.clone();
        }
        let out = self.render_fig5(peer);
        self.cache_fig5.insert(peer.to_string(), out.clone());
        out
    }

    fn render_fig5(&self, peer: &str) -> Vec<(String, String)> {
        let f = crate::directory::entry::format_f64;
        let mut out = vec![
            ("lastRDBandwidth".into(), f(self.rd.last)),
            ("lastRDurl".into(), format!("gsiftp://{}/", self.rd.last_peer)),
            ("lastWRBandwidth".into(), f(self.wr.last)),
            ("lastWRurl".into(), format!("gsiftp://{}/", self.wr.last_peer)),
        ];
        if let Some(src) = self.source(peer) {
            out.push(("AvgRDBandwidth".into(), f(src.stats.avg())));
            out.push(("NumTransfers".into(), f(src.stats.count as f64)));
            let hist = src
                .window()
                .iter()
                .map(|bw| f(*bw))
                .collect::<Vec<_>>()
                .join(",");
            out.push(("rdHistory".into(), hist));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(at: f64, peer: &str, dir: Direction, bytes: f64, duration: f64) -> TransferRecord {
        TransferRecord { at, peer: peer.into(), direction: dir, bytes, duration }
    }

    #[test]
    fn summary_stats_match_hand_computation() {
        let mut h = HistoryStore::new("anl", 16);
        // Bandwidths: 100, 200, 400.
        h.record(rec(0.0, "c1", Direction::Read, 1000.0, 10.0));
        h.record(rec(1.0, "c1", Direction::Read, 2000.0, 10.0));
        h.record(rec(2.0, "c2", Direction::Read, 4000.0, 10.0));
        assert_eq!(h.rd.count, 3);
        assert_eq!(h.rd.max, 400.0);
        assert_eq!(h.rd.min, 100.0);
        assert!((h.rd.avg() - 233.333).abs() < 0.01);
        let var = ((100.0f64 - 233.3333).powi(2) + (200.0 - 233.3333f64).powi(2) + (400.0 - 233.3333f64).powi(2)) / 3.0;
        assert!((h.rd.std() - var.sqrt()).abs() < 0.01);
        assert_eq!(h.rd.last, 400.0);
        assert_eq!(h.rd.last_peer, "c2");
    }

    #[test]
    fn read_write_separated() {
        let mut h = HistoryStore::new("anl", 16);
        h.record(rec(0.0, "c1", Direction::Read, 1000.0, 1.0));
        h.record(rec(1.0, "c1", Direction::Write, 500.0, 1.0));
        assert_eq!(h.rd.count, 1);
        assert_eq!(h.wr.count, 1);
        assert_eq!(h.wr.last, 500.0);
    }

    #[test]
    fn per_source_window_trims() {
        let mut h = HistoryStore::new("anl", 4);
        for i in 0..10 {
            h.record(rec(i as f64, "c1", Direction::Read, (i + 1) as f64 * 100.0, 1.0));
        }
        let src = h.source("c1").unwrap();
        assert_eq!(src.len(), 4);
        assert_eq!(src.window(), vec![700.0, 800.0, 900.0, 1000.0]);
        assert_eq!(src.stats.count, 10); // stats see everything
    }

    #[test]
    fn fig4_attributes_complete() {
        let mut h = HistoryStore::new("anl", 8);
        h.record(rec(0.0, "c1", Direction::Read, 100.0, 1.0));
        h.record(rec(0.0, "c1", Direction::Write, 50.0, 1.0));
        let attrs: BTreeMap<String, String> = h.fig4_attributes().into_iter().collect();
        for key in [
            "MaxRDBandwidth",
            "MinRDBandwidth",
            "AvgRDBandwidth",
            "MaxWRBandwidth",
            "MinWRBandwidth",
            "AvgWRBandwidth",
        ] {
            assert!(attrs.contains_key(key), "missing {key}");
        }
        assert_eq!(attrs["NumTransfers"], "2");
    }

    #[test]
    fn fig5_attributes_for_source() {
        let mut h = HistoryStore::new("anl", 8);
        h.record(rec(0.0, "comet.xyz.com", Direction::Read, 100.0, 1.0));
        h.record(rec(1.0, "comet.xyz.com", Direction::Read, 300.0, 1.0));
        let attrs: BTreeMap<String, String> =
            h.fig5_attributes("comet.xyz.com").into_iter().collect();
        assert_eq!(attrs["lastRDBandwidth"], "300");
        assert_eq!(attrs["lastRDurl"], "gsiftp://comet.xyz.com/");
        assert_eq!(attrs["rdHistory"], "100,300");
        assert_eq!(attrs["NumTransfers"], "2");
    }

    #[test]
    fn failed_transfers_do_not_poison_min_bandwidth() {
        let mut h = HistoryStore::new("anl", 16);
        h.record(rec(0.0, "c1", Direction::Read, 1000.0, 10.0)); // 100 B/s
        // A stalled transfer: bytes delivered but duration 0 → bw 0.
        h.record(rec(1.0, "c1", Direction::Read, 1000.0, 0.0));
        // A dead-source transfer: nothing delivered.
        h.record(rec(2.0, "c2", Direction::Read, 0.0, 5.0));
        h.record(rec(3.0, "c2", Direction::Read, 4000.0, 10.0)); // 400 B/s
        assert_eq!(h.rd.count, 2, "only successful transfers counted");
        assert_eq!(h.rd.failed, 2);
        assert_eq!(h.rd.min, 100.0, "Fig-4 min reflects the slowest success, not a failure");
        assert_eq!(h.rd.max, 400.0);
        assert!((h.rd.avg() - 250.0).abs() < 1e-9);
        assert_eq!(h.rd.last, 400.0, "a failure must not overwrite `last`");
        assert_eq!(h.rd.last_peer, "c2");
    }

    #[test]
    fn record_buffer_bounded() {
        let mut h = HistoryStore::new("anl", 8);
        h.keep_records = 100;
        for i in 0..500 {
            h.record(rec(i as f64, "c", Direction::Read, 1.0, 1.0));
        }
        assert_eq!(h.records().len(), 100);
    }
}
