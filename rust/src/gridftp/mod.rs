//! Simulated GridFTP fabric with transfer instrumentation (paper §3.2).
//!
//! "We gather this performance data by using instrumentation
//! incorporated in the GridFTP server" — every transfer through
//! [`service::GridFtp`] produces a [`history::TransferRecord`]; the
//! per-site [`history::HistoryStore`] maintains the Figure-4 summary
//! statistics and the Figure-5 per-source records, and exposes the
//! trailing observation window the forecast engine consumes. A GRIS
//! provider closure publishes all of it into the directory.

pub mod history;
pub mod service;

pub use history::{HistoryStore, TransferRecord};
pub use service::{GridFtp, OpenFetch, OpenStore};
