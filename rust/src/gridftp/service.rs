//! The simulated GridFTP service: executes transfers over the simnet
//! topology and instruments every one of them into the history store.
//!
//! This is the Access-phase backend (paper §5.1.2) *and* the data
//! source for §3.2's history-based prediction: the same
//! `Arc<RwLock<HistoryStore>>` a `GridFtp` writes is read by the site's
//! GRIS provider when a broker queries performance attributes.

use std::sync::{Arc, RwLock};

use anyhow::{bail, Result};

use crate::simnet::{Engine, Topology};

use super::history::{Direction, HistoryStore, TransferRecord};

/// Outcome of one simulated transfer.
#[derive(Debug, Clone, PartialEq)]
pub struct TransferOutcome {
    pub site: String,
    pub bytes: f64,
    pub duration: f64,
    pub bandwidth: f64,
    /// Simulated start time.
    pub started_at: f64,
    /// First byte of the fetched range (0 for whole-file transfers).
    pub offset: f64,
    /// Bytes actually committed to the destination volume (writes
    /// only; 0 for reads). A store into a nearly-full volume clamps at
    /// capacity, so this can be less than `bytes` — deletion must
    /// reclaim *this* amount, not the file size, to keep the space
    /// invariant exact.
    pub applied: f64,
}

/// An in-flight open-loop fetch: the ticket [`GridFtp::fetch_begin`]
/// returns and [`GridFtp::fetch_finish`] consumes when the kernel
/// reports the flow done.
#[derive(Debug, Clone)]
pub struct OpenFetch {
    /// Flow id in the kernel's shared `FlowSet`.
    pub flow: usize,
    /// Topology index of the source site.
    pub site: usize,
    /// Requesting endpoint (the history store's per-source peer key).
    pub client: String,
    pub bytes: f64,
    pub started_at: f64,
    /// First byte of the fetched range — non-zero when a retry resumes
    /// a cancelled fetch from its delivered offset (extended block
    /// mode, the open-loop dual of [`GridFtp::fetch_range`]).
    pub offset: f64,
}

/// An in-flight open-loop store: the ticket [`GridFtp::store_begin`]
/// returns and [`GridFtp::store_finish`] consumes when the kernel
/// reports the push's flow done. The replica-economy engine carries
/// these across kernel events; space is committed only at the finish.
#[derive(Debug, Clone)]
pub struct OpenStore {
    /// Flow id in the kernel's shared `FlowSet`.
    pub flow: usize,
    /// Topology index of the destination site.
    pub site: usize,
    /// Writing endpoint (the history store's per-source peer key).
    pub client: String,
    pub bytes: f64,
    pub started_at: f64,
}

/// The per-grid GridFTP fabric: one logical server per site, all
/// writing instrumentation into per-site history stores.
pub struct GridFtp {
    histories: Vec<Arc<RwLock<HistoryStore>>>,
}

impl GridFtp {
    /// One history store per site in `topo`, with `window`-deep
    /// per-source observation windows.
    pub fn new(topo: &Topology, window: usize) -> GridFtp {
        let histories = (0..topo.len())
            .map(|i| {
                Arc::new(RwLock::new(HistoryStore::new(
                    &topo.site(i).cfg.name,
                    window,
                )))
            })
            .collect();
        GridFtp { histories }
    }

    /// Shared handle to a site's history (for GRIS providers).
    pub fn history(&self, site: usize) -> Arc<RwLock<HistoryStore>> {
        self.histories[site].clone()
    }

    /// Execute a read transfer of `bytes` from `site` to `client`,
    /// advancing nothing but sampling the topology's current state.
    /// Returns the outcome and logs the instrumentation record.
    pub fn fetch(
        &self,
        topo: &mut Topology,
        site: usize,
        client: &str,
        bytes: f64,
    ) -> TransferOutcome {
        self.fetch_range(topo, site, client, 0.0, bytes)
    }

    /// Execute a partial-range read (GridFTP extended block mode): the
    /// `bytes` starting at `offset`. The range boundary only changes
    /// where the read starts — seek overhead and link behaviour match a
    /// whole-file fetch of the same length — but the instrumentation
    /// record carries the true range length, so striped block fetches
    /// feed the per-source history exactly like whole files do.
    pub fn fetch_range(
        &self,
        topo: &mut Topology,
        site: usize,
        client: &str,
        offset: f64,
        bytes: f64,
    ) -> TransferOutcome {
        topo.begin_transfer(site);
        let (duration, bandwidth) = topo.transfer_from(site, bytes);
        topo.end_transfer(site);
        let started_at = topo.now;
        if !duration.is_finite() {
            // Dead source (control channel error): nothing moved and
            // nothing is recorded — an infinite-duration sample would
            // poison the bandwidth history the GRIS publishes.
            return TransferOutcome {
                site: topo.site(site).cfg.name.clone(),
                bytes: 0.0,
                duration,
                bandwidth: 0.0,
                started_at,
                offset,
                applied: 0.0,
            };
        }
        self.record(
            site,
            TransferRecord {
                at: started_at,
                peer: client.to_string(),
                direction: Direction::Read,
                bytes,
                duration,
            },
        );
        TransferOutcome {
            site: topo.site(site).cfg.name.clone(),
            bytes,
            duration,
            bandwidth,
            started_at,
            offset,
            applied: 0.0,
        }
    }

    /// Ingest one instrumentation record into `site`'s history store —
    /// the entry point for transfer engines that simulate byte movement
    /// themselves (the co-allocation scheduler's per-block records).
    pub fn record(&self, site: usize, rec: TransferRecord) {
        self.histories[site].write().unwrap().record(rec);
    }

    /// Begin an *open-loop* fetch on the event kernel: registers the
    /// transfer slot (the sharing convention every stream follows) and
    /// a flow in `eng`'s shared [`crate::simnet::FlowSet`], in downlink
    /// `group`. Unlike [`Self::fetch`], which costs the whole transfer
    /// in closed form at one instant, the open fetch occupies its site
    /// link — and contends with every other in-flight transfer — until
    /// the kernel reports its flow done; the caller then completes it
    /// with [`Self::fetch_finish`], which releases the slot and lands
    /// the instrumentation record. Errors on a dead source (the
    /// control-channel failure a closed-form fetch signals with an
    /// infinite duration).
    pub fn fetch_begin(
        &self,
        eng: &mut Engine,
        topo: &mut Topology,
        site: usize,
        client: &str,
        bytes: f64,
        group: usize,
    ) -> Result<OpenFetch> {
        self.fetch_begin_range(eng, topo, site, client, 0.0, bytes, group)
    }

    /// [`Self::fetch_begin`] from a byte `offset`: fetch the `bytes`
    /// starting there. The transfer-resilience path uses this to
    /// resume a cancelled fetch from its delivered offset on another
    /// (or the healed) replica instead of re-paying the whole file.
    /// The range start changes nothing about link behaviour — the
    /// stream pays the same connection/seek lead — but the outcome and
    /// instrumentation carry the true range length.
    pub fn fetch_begin_range(
        &self,
        eng: &mut Engine,
        topo: &mut Topology,
        site: usize,
        client: &str,
        offset: f64,
        bytes: f64,
        group: usize,
    ) -> Result<OpenFetch> {
        if !topo.site_alive(site) {
            bail!(
                "source {} is unreachable (control channel down)",
                topo.site(site).cfg.name
            );
        }
        topo.begin_transfer(site);
        // Per-stream setup: connection latency + the disk seek, paid
        // before bytes move (the same lead a co-allocated block pays).
        let lead = {
            let sc = &topo.site(site).cfg;
            sc.latency + sc.drd_time_ms / 1e3
        };
        let flow = eng.flows.add_in(topo, site, bytes, lead, group);
        Ok(OpenFetch {
            flow,
            site,
            client: client.to_string(),
            bytes,
            started_at: topo.now,
            offset,
        })
    }

    /// Complete an open-loop fetch whose flow the kernel reported done
    /// at instant `at`: release the transfer slot and record the
    /// instrumentation exactly like a closed-form fetch would.
    pub fn fetch_finish(&self, topo: &mut Topology, open: &OpenFetch, at: f64) -> TransferOutcome {
        topo.end_transfer(open.site);
        let duration = (at - open.started_at).max(1e-9);
        self.record(
            open.site,
            TransferRecord {
                at: open.started_at,
                peer: open.client.clone(),
                direction: Direction::Read,
                bytes: open.bytes,
                duration,
            },
        );
        TransferOutcome {
            site: topo.site(open.site).cfg.name.clone(),
            bytes: open.bytes,
            duration,
            bandwidth: open.bytes / duration,
            started_at: open.started_at,
            offset: open.offset,
            applied: 0.0,
        }
    }

    /// Begin an *open-loop* store on the event kernel — the
    /// write-direction dual of [`Self::fetch_begin`]: the replica push
    /// occupies `site`'s link as a flow in the shared `FlowSet`,
    /// contending with every in-flight fetch, until the kernel reports
    /// it done and the caller completes it with [`Self::store_finish`].
    /// The stream lead pays the connection latency plus the disk
    /// *write* setup (`dwrTime`). Nothing is committed until the finish
    /// — a push abandoned mid-flight (destination died, run wound down)
    /// consumes no space and records nothing; the caller only releases
    /// the transfer slot ([`Topology::end_transfer`]).
    pub fn store_begin(
        &self,
        eng: &mut Engine,
        topo: &mut Topology,
        site: usize,
        client: &str,
        bytes: f64,
        group: usize,
    ) -> Result<OpenStore> {
        if !topo.site_alive(site) {
            bail!(
                "destination {} is unreachable (control channel down)",
                topo.site(site).cfg.name
            );
        }
        topo.begin_transfer(site);
        let lead = {
            let sc = &topo.site(site).cfg;
            sc.latency + sc.dwr_time_ms / 1e3
        };
        let flow = eng.flows.add_in(topo, site, bytes, lead, group);
        Ok(OpenStore {
            flow,
            site,
            client: client.to_string(),
            bytes,
            started_at: topo.now,
        })
    }

    /// Complete an open-loop store whose flow the kernel reported done
    /// at `at`: release the slot, commit the copy's space (the clamped
    /// *applied* delta lands in the outcome for the caller's ledger)
    /// and record the write instrumentation.
    pub fn store_finish(&self, topo: &mut Topology, open: &OpenStore, at: f64) -> TransferOutcome {
        topo.end_transfer(open.site);
        let duration = (at - open.started_at).max(1e-9);
        let applied = topo.consume_space(open.site, open.bytes);
        self.record(
            open.site,
            TransferRecord {
                at: open.started_at,
                peer: open.client.clone(),
                direction: Direction::Write,
                bytes: open.bytes,
                duration,
            },
        );
        TransferOutcome {
            site: topo.site(open.site).cfg.name.clone(),
            bytes: open.bytes,
            duration,
            bandwidth: open.bytes / duration,
            started_at: open.started_at,
            offset: 0.0,
            applied,
        }
    }

    /// Execute a write (replica creation) to `site` from `client`.
    pub fn store(
        &self,
        topo: &mut Topology,
        site: usize,
        client: &str,
        bytes: f64,
    ) -> TransferOutcome {
        self.store_range(topo, site, client, 0.0, bytes)
    }

    /// Execute a partial-range write (GridFTP extended block mode):
    /// push the `bytes` starting at `offset`, the write-direction dual
    /// of [`Self::fetch_range`]. This is the *direct-execution*
    /// primitive (one synchronous ranged write, instrumented with the
    /// true range length and consuming the range's space); the striped
    /// `store()` of [`crate::coalloc::store`] simulates its concurrent
    /// pushes through `FlowSet` instead and feeds the same history via
    /// [`Self::record`]. A dead destination moves nothing, records
    /// nothing and consumes no space (infinite duration, the caller's
    /// failure signal).
    pub fn store_range(
        &self,
        topo: &mut Topology,
        site: usize,
        client: &str,
        offset: f64,
        bytes: f64,
    ) -> TransferOutcome {
        topo.begin_transfer(site);
        let (duration, bandwidth) = topo.transfer_from(site, bytes);
        topo.end_transfer(site);
        let started_at = topo.now;
        if !duration.is_finite() {
            return TransferOutcome {
                site: topo.site(site).cfg.name.clone(),
                bytes: 0.0,
                duration,
                bandwidth: 0.0,
                started_at,
                offset,
                applied: 0.0,
            };
        }
        let applied = topo.consume_space(site, bytes);
        self.histories[site].write().unwrap().record(TransferRecord {
            at: started_at,
            peer: client.to_string(),
            direction: Direction::Write,
            bytes,
            duration,
        });
        TransferOutcome {
            site: topo.site(site).cfg.name.clone(),
            bytes,
            duration,
            bandwidth,
            started_at,
            offset,
            applied,
        }
    }

    /// Warm every site's history with `n` synthetic probe transfers per
    /// site (what a freshly deployed grid accumulates organically).
    pub fn warm(&self, topo: &mut Topology, client: &str, n: usize, probe_bytes: f64) {
        for _ in 0..n {
            for site in 0..self.histories.len() {
                self.fetch(topo, site, client, probe_bytes);
            }
            topo.advance(60.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GridConfig;

    fn setup() -> (Topology, GridFtp) {
        let topo = Topology::build(&GridConfig::generate(4, 21));
        let ftp = GridFtp::new(&topo, 16);
        (topo, ftp)
    }

    #[test]
    fn fetch_records_instrumentation() {
        let (mut topo, ftp) = setup();
        let out = ftp.fetch(&mut topo, 1, "comet.xyz.com", 5e6);
        assert!(out.duration > 0.0);
        let h = ftp.history(1);
        let h = h.read().unwrap();
        assert_eq!(h.rd.count, 1);
        assert_eq!(h.rd.last_peer, "comet.xyz.com");
        assert!((h.rd.last - out.bandwidth).abs() / out.bandwidth < 1e-9);
        assert_eq!(h.source("comet.xyz.com").unwrap().len(), 1);
    }

    #[test]
    fn range_fetches_instrument_like_whole_files() {
        let (mut topo, ftp) = setup();
        let a = ftp.fetch_range(&mut topo, 0, "client", 0.0, 4e6);
        let b = ftp.fetch_range(&mut topo, 0, "client", 4e6, 4e6);
        assert_eq!(a.offset, 0.0);
        assert_eq!(b.offset, 4e6);
        assert!(a.duration > 0.0 && b.duration > 0.0);
        let h = ftp.history(0);
        let h = h.read().unwrap();
        assert_eq!(h.rd.count, 2);
        assert_eq!(h.source("client").unwrap().len(), 2);
    }

    #[test]
    fn record_feeds_history_directly() {
        let (_, ftp) = setup();
        ftp.record(
            3,
            TransferRecord {
                at: 12.0,
                peer: "striper".into(),
                direction: Direction::Read,
                bytes: 8e6,
                duration: 4.0,
            },
        );
        let h = ftp.history(3);
        let h = h.read().unwrap();
        assert_eq!(h.rd.count, 1);
        assert_eq!(h.rd.last, 2e6);
        assert_eq!(h.source("striper").unwrap().window(), vec![2e6]);
    }

    #[test]
    fn store_consumes_space_and_logs_write() {
        let (mut topo, ftp) = setup();
        let avail0 = topo.site(2).available_space();
        ftp.store(&mut topo, 2, "client-a", 1e9);
        assert!(topo.site(2).available_space() < avail0);
        let h = ftp.history(2);
        assert_eq!(h.read().unwrap().wr.count, 1);
        assert_eq!(h.read().unwrap().rd.count, 0);
    }

    #[test]
    fn range_stores_instrument_like_whole_files() {
        let (mut topo, ftp) = setup();
        let avail0 = topo.site(1).available_space();
        let a = ftp.store_range(&mut topo, 1, "client", 0.0, 4e6);
        let b = ftp.store_range(&mut topo, 1, "client", 4e6, 4e6);
        assert_eq!(a.offset, 0.0);
        assert_eq!(b.offset, 4e6);
        assert!(a.duration > 0.0 && b.duration > 0.0);
        let h = ftp.history(1);
        assert_eq!(h.read().unwrap().wr.count, 2);
        // Both ranges consumed their space.
        assert!((avail0 - topo.site(1).available_space() - 8e6).abs() < 1.0);
    }

    #[test]
    fn dead_site_transfers_record_and_consume_nothing() {
        use crate::simnet::FaultKind;
        let (mut topo, ftp) = setup();
        topo.schedule_fault(1, 0.0, FaultKind::ReplicaDeath);
        let avail0 = topo.site(1).available_space();
        let f = ftp.fetch(&mut topo, 1, "client", 5e6);
        assert!(!f.duration.is_finite());
        assert_eq!(f.bytes, 0.0);
        let s = ftp.store_range(&mut topo, 1, "client", 0.0, 5e6);
        assert!(!s.duration.is_finite());
        // No history pollution, no phantom space consumption, and the
        // transfer-slot accounting stayed balanced.
        let h = ftp.history(1);
        assert_eq!(h.read().unwrap().rd.count, 0);
        assert_eq!(h.read().unwrap().wr.count, 0);
        assert_eq!(topo.site(1).available_space(), avail0);
        assert_eq!(topo.site(1).active_transfers, 0);
    }

    #[test]
    fn open_fetch_occupies_the_link_and_records_on_finish() {
        use crate::simnet::{Engine, FlowSet, Signal};
        let mut cfg = crate::config::GridConfig::generate(2, 21);
        for s in &mut cfg.sites {
            s.wan_bandwidth = 1e6;
            s.diurnal_amp = 0.0;
            s.noise_frac = 0.0;
            s.congestion_prob = 0.0;
            s.ar_coeff = 0.0;
            s.latency = 0.0;
            s.drd_time_ms = 0.0;
            s.disk_rate = 1e9;
        }
        let mut topo = crate::simnet::Topology::build(&cfg);
        let ftp = GridFtp::new(&topo, 16);
        let mut eng = Engine::new(FlowSet::new(f64::INFINITY));
        let open = ftp
            .fetch_begin(&mut eng, &mut topo, 0, "client", 1e6, 0)
            .unwrap();
        // The slot is held while the flow is in flight.
        assert_eq!(topo.site(0).active_transfers, 1);
        match eng.next(&mut topo) {
            Some(Signal::FlowDone(c)) => {
                assert_eq!(c.flow, open.flow);
                // share 1/2 with its own registration → 2 s.
                assert!((c.at - 2.0).abs() < 1e-6, "at {}", c.at);
                let out = ftp.fetch_finish(&mut topo, &open, c.at);
                assert!((out.duration - 2.0).abs() < 1e-6);
                assert!((out.bandwidth - 0.5e6).abs() < 1.0);
            }
            other => panic!("expected FlowDone, got {other:?}"),
        }
        assert_eq!(topo.site(0).active_transfers, 0);
        let h = ftp.history(0);
        let h = h.read().unwrap();
        assert_eq!(h.rd.count, 1);
        assert_eq!(h.source("client").unwrap().len(), 1);
    }

    #[test]
    fn open_range_fetch_carries_its_offset_and_records_the_range() {
        use crate::simnet::{Engine, FlowSet, Signal};
        let (mut topo, ftp) = setup();
        let mut eng = Engine::new(FlowSet::new(f64::INFINITY));
        let open = ftp
            .fetch_begin_range(&mut eng, &mut topo, 2, "client", 3e6, 5e6, 0)
            .unwrap();
        assert_eq!(open.offset, 3e6);
        match eng.next(&mut topo) {
            Some(Signal::FlowDone(c)) => {
                let out = ftp.fetch_finish(&mut topo, &open, c.at);
                assert_eq!(out.offset, 3e6);
                assert_eq!(out.bytes, 5e6);
            }
            other => panic!("expected FlowDone, got {other:?}"),
        }
        // The record carries the range length like any whole file.
        let h = ftp.history(2);
        let h = h.read().unwrap();
        assert_eq!(h.rd.count, 1);
    }

    #[test]
    fn open_store_commits_space_only_on_finish() {
        use crate::simnet::{Engine, FlowSet, Signal};
        let (mut topo, ftp) = setup();
        let mut eng = Engine::new(FlowSet::new(f64::INFINITY));
        let avail0 = topo.site(2).available_space();
        let open = ftp
            .store_begin(&mut eng, &mut topo, 2, "economy", 5e6, 0)
            .unwrap();
        // In flight: slot held, nothing committed yet.
        assert_eq!(topo.site(2).active_transfers, 1);
        assert_eq!(topo.site(2).available_space(), avail0);
        match eng.next(&mut topo) {
            Some(Signal::FlowDone(c)) => {
                assert_eq!(c.flow, open.flow);
                let out = ftp.store_finish(&mut topo, &open, c.at);
                assert_eq!(out.applied, 5e6, "uncontended store commits in full");
                assert!(out.duration > 0.0);
            }
            other => panic!("expected FlowDone, got {other:?}"),
        }
        assert_eq!(topo.site(2).active_transfers, 0);
        assert!((avail0 - topo.site(2).available_space() - 5e6).abs() < 1.0);
        let h = ftp.history(2);
        let h = h.read().unwrap();
        assert_eq!(h.wr.count, 1);
        assert_eq!(h.rd.count, 0);
        assert_eq!(h.wr.last_peer, "economy");
    }

    #[test]
    fn abandoned_open_store_consumes_nothing() {
        use crate::simnet::{Engine, FlowSet};
        let (mut topo, ftp) = setup();
        let mut eng = Engine::new(FlowSet::new(f64::INFINITY));
        let avail0 = topo.site(1).available_space();
        let open = ftp
            .store_begin(&mut eng, &mut topo, 1, "economy", 5e6, 0)
            .unwrap();
        // Destination lost mid-push: the caller cancels the flow and
        // releases the slot without ever calling store_finish.
        eng.flows.cancel(open.flow);
        topo.end_transfer(open.site);
        assert_eq!(topo.site(1).available_space(), avail0);
        assert_eq!(topo.site(1).active_transfers, 0);
        assert_eq!(ftp.history(1).read().unwrap().wr.count, 0);
    }

    #[test]
    fn open_store_refuses_dead_destinations() {
        use crate::simnet::{Engine, FaultKind, FlowSet};
        let (mut topo, ftp) = setup();
        topo.schedule_fault(3, 0.0, FaultKind::ReplicaDeath);
        let mut eng = Engine::new(FlowSet::new(f64::INFINITY));
        assert!(ftp
            .store_begin(&mut eng, &mut topo, 3, "economy", 1e6, 0)
            .is_err());
        assert_eq!(topo.site(3).active_transfers, 0);
    }

    #[test]
    fn open_fetch_refuses_dead_sources() {
        use crate::simnet::{Engine, FaultKind, FlowSet};
        let (mut topo, ftp) = setup();
        topo.schedule_fault(1, 0.0, FaultKind::ReplicaDeath);
        let mut eng = Engine::new(FlowSet::new(f64::INFINITY));
        assert!(ftp
            .fetch_begin(&mut eng, &mut topo, 1, "client", 1e6, 0)
            .is_err());
        assert_eq!(topo.site(1).active_transfers, 0);
    }

    #[test]
    fn warm_populates_all_sites() {
        let (mut topo, ftp) = setup();
        ftp.warm(&mut topo, "probe", 5, 1e6);
        for i in 0..4 {
            let h = ftp.history(i);
            let h = h.read().unwrap();
            assert_eq!(h.rd.count, 5);
            assert_eq!(h.source("probe").unwrap().len(), 5);
        }
        assert!(topo.now >= 5.0 * 60.0);
    }

    #[test]
    fn faster_sites_deliver_higher_bandwidth_on_average() {
        // Sanity link between config and outcomes: the best-connected
        // site should out-deliver the worst over many transfers.
        let cfg = GridConfig::generate(6, 33);
        let mut topo = Topology::build(&cfg);
        let ftp = GridFtp::new(&topo, 64);
        ftp.warm(&mut topo, "probe", 30, 20e6);
        let mean_bw = |i: usize| {
            let h = ftp.history(i);
            let h = h.read().unwrap();
            h.rd.avg()
        };
        let best_cfg = (0..6).max_by(|&a, &b| {
            cfg.sites[a]
                .wan_bandwidth
                .partial_cmp(&cfg.sites[b].wan_bandwidth)
                .unwrap()
        }).unwrap();
        let worst_cfg = (0..6).min_by(|&a, &b| {
            cfg.sites[a]
                .wan_bandwidth
                .partial_cmp(&cfg.sites[b].wan_bandwidth)
                .unwrap()
        }).unwrap();
        assert!(
            mean_bw(best_cfg) > mean_bw(worst_cfg),
            "best {} worst {}",
            mean_bw(best_cfg),
            mean_bw(worst_cfg)
        );
    }
}
