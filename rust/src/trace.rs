//! Flight recorder: causal per-request tracing and grid time-series
//! sampling on the *simulated* clock.
//!
//! End-of-run aggregates (`OpenReport`, `CoallocOutcome`, the `metrics`
//! histograms) say *how slow* the grid was; they cannot say *why request
//! 4711 was slow* or *what link utilization looked like at t=300s*. This
//! module adds the missing layer: a bounded ring-buffer [`Recorder`] of
//! structured [`TraceEvent`]s, each stamped with the simulated clock
//! ([`SimInstant`]) and keyed by request id, with enough causal structure
//! (arrival → gate park/unpark → discovery → selection → transfer →
//! done) that each request's **critical path** can be reconstructed from
//! the trace alone.
//!
//! # Design contract: zero cost when disabled
//!
//! Every instrumented layer holds a [`TraceHandle`] — a
//! `Option<Arc<Mutex<Recorder>>>` newtype. The default handle is
//! *disabled* (`None`): recording an event is then a single branch, no
//! allocation, no lock, no formatting. Event payloads ([`Ev`]) are
//! `Copy` and hold only numbers and `&'static str`s; site names are
//! interned into the recorder's name table ([`Recorder::intern`]) so the
//! hot path never clones a `String`. This is what keeps
//! `OpenLoopOptions::serial()` bit-for-bit equal to the serial driver
//! and keeps `bench_contention` allocation-free per event when tracing
//! is off.
//!
//! # Event model
//!
//! Events are flat, not nested: span structure is *reconstructed* from
//! the per-request event sequence by [`spans`]. For an open-loop request
//! the canonical chain is
//!
//! ```text
//! arrival ──(queue)── admit ──(discovery)── selection ──(transfer)── done
//! ```
//!
//! where `admit` is the gate-unpark instant (or the discovery-start
//! instant when the gate had a free slot) and `selection` is the instant
//! the broker ranked the candidates. The three phase durations partition
//! `[arrival, done]` exactly, so the span tree accounts for 100% of each
//! request's simulated time by construction. Rows with the
//! pseudo-request ids [`SAMPLE_REQ`] (time-series sampler) and
//! [`KERNEL_REQ`] (kernel dispatch) ride in the same buffer but are
//! excluded from request reconstruction.
//!
//! # Exporters
//!
//! * [`Recorder::jsonl`] — one JSON object per line, stable key order,
//!   byte-deterministic for identically seeded runs (pinned by a
//!   property test).
//! * [`Recorder::chrome_json`] — Chrome trace-event JSON loadable in
//!   Perfetto (`chrome://tracing`): one track per request under the
//!   "requests" process, one track per site under the "sites" process,
//!   counter tracks for the sampler series. The raw events are embedded
//!   under the `"rawEvents"` key so a `TRACE_*.json` artifact is
//!   self-contained: `trace-summary` (see `main.rs`) re-analyzes it
//!   without the JSONL sibling.
//!
//! [`load_trace`] accepts either format back.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::anyhow;

use crate::util::json::Json;

/// Simulated-clock instant in seconds (same convention as
/// `directory::giis::SimInstant`).
pub type SimInstant = f64;

/// Request identifier: the workload index for experiment drivers.
pub type ReqId = u64;

/// Interned site-name id (index into the recorder's name table).
pub type SiteId = u32;

/// Pseudo-request id carried by time-series sampler rows.
pub const SAMPLE_REQ: ReqId = u64::MAX;

/// Pseudo-request id carried by kernel dispatch rows.
pub const KERNEL_REQ: ReqId = u64::MAX - 1;

/// Default ring capacity used by experiment runners (events, not bytes).
pub const DEFAULT_CAPACITY: usize = 1 << 18;

/// Structured trace event payload. `Copy` on purpose: recording must
/// never allocate, so payloads carry only numbers, interned [`SiteId`]s
/// and `&'static str` tags.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Ev {
    /// Request entered the system (root of its span tree).
    Arrival,
    /// Admission gate full; request parked behind `occupancy` in-flight.
    GatePark { occupancy: u32 },
    /// Parked request got a slot after `waited_s` seconds in the gate.
    GateUnpark { waited_s: f64 },
    /// Broad GIIS lookup answered from registration snapshots;
    /// `drills` of the `placements` candidate sites get a fresh query.
    DiscoveryStart { placements: u32, drills: u32 },
    /// Directory fan-out put a per-site query on the wire.
    QueryIssue { site: SiteId },
    /// Per-site query answered.
    QueryLand { site: SiteId },
    /// Per-site query exceeded its deadline.
    QueryTimeout { site: SiteId },
    /// Fan-out straggler cutoff fired with `unresolved` queries open.
    QueryCutoff { unresolved: u32 },
    /// Synchronous fresh GRIS drill-down (serial discovery path).
    DrillDown { site: SiteId },
    /// Discovery resolved with `responses` usable site answers.
    DiscoveryEnd { responses: u32 },
    /// Broker phase wall-clock cost (µs, host clock — diagnostic only).
    BrokerPhase { phase: &'static str, wall_us: u64 },
    /// Replica chosen among `candidates` ranked matches.
    Selection { site: SiteId, candidates: u32 },
    /// Kernel flow started against `site`.
    FlowStart { site: SiteId, flow: u64, bytes: u64 },
    /// Kernel flow delivered its last byte.
    FlowFinish { site: SiteId, flow: u64, transfer_s: f64 },
    /// Closed-form (analytic) access: transfer modeled without a flow.
    AnalyticAccess { site: SiteId, transfer_s: f64 },
    /// Request finished; `transfer_s` is the service duration the
    /// report aggregates (`QualityReport::mean_time` parity anchor).
    RequestDone { transfer_s: f64 },
    /// Request abandoned (undiscoverable, wind-down, no replica).
    RequestSkipped { reason: &'static str },
    /// Co-allocation: block dispatched to a stripe source.
    BlockStart { site: SiteId, block: u64, bytes: u64 },
    /// Co-allocation: `blocks` blocks stolen from `from`'s backlog.
    BlockSteal { from: SiteId, to: SiteId, blocks: u32 },
    /// Co-allocation: source declared failed, `orphaned` blocks requeued.
    BlockFailover { site: SiteId, orphaned: u32 },
    /// Co-allocation: block re-dispatched after a failure.
    BlockRetry { site: SiteId, block: u64 },
    /// Co-allocation: block delivered and ledgered exactly-once.
    BlockFinish { site: SiteId, block: u64, bytes: u64 },
    /// Grid weather: a fault became active on `site`. `degrade` is the
    /// link factor (0 for a replica death); `heal_s` is the absolute
    /// heal instant, or −1 when the fault is permanent (JSON cannot
    /// carry ∞).
    SiteFault { site: SiteId, degrade: f64, heal_s: f64 },
    /// Grid weather: a fault interval on `site` ended.
    SiteHeal { site: SiteId },
    /// Transfer resilience: attempt `attempt` re-issued the request
    /// against `site`, resuming from byte `offset`.
    TransferRetry { site: SiteId, attempt: u32, offset: u64 },
    /// Replica economy: a replication push flow started toward `site`
    /// (kernel track — the push contends with foreground transfers).
    ReplicaPush { site: SiteId, flow: u64, bytes: u64 },
    /// Replica economy: a push landed and the replica was registered.
    ReplicaCreate { site: SiteId, transfer_s: f64 },
    /// Replica economy: a cold replica was evicted from `site`,
    /// reclaiming `bytes` under the site's space budget.
    ReplicaEvict { site: SiteId, bytes: u64 },
    /// Kernel dispatched a signal (`arrival`/`tick`/`query`/`flow_done`).
    Dispatch { kind: &'static str },
    /// Sampler row: global gauges at the sample instant.
    Sample { in_flight: u32, gate_depth: u32, giis_live: u32 },
    /// Sampler row: one site link (`utilization` = rate / capacity).
    LinkSample { site: SiteId, flows: u32, utilization: f64 },
}

impl Ev {
    /// Stable export name (snake_case, used by both exporters).
    pub fn name(&self) -> &'static str {
        match self {
            Ev::Arrival => "arrival",
            Ev::GatePark { .. } => "gate_park",
            Ev::GateUnpark { .. } => "gate_unpark",
            Ev::DiscoveryStart { .. } => "discovery_start",
            Ev::QueryIssue { .. } => "query_issue",
            Ev::QueryLand { .. } => "query_land",
            Ev::QueryTimeout { .. } => "query_timeout",
            Ev::QueryCutoff { .. } => "query_cutoff",
            Ev::DrillDown { .. } => "drill_down",
            Ev::DiscoveryEnd { .. } => "discovery_end",
            Ev::BrokerPhase { .. } => "broker_phase",
            Ev::Selection { .. } => "selection",
            Ev::FlowStart { .. } => "flow_start",
            Ev::FlowFinish { .. } => "flow_finish",
            Ev::AnalyticAccess { .. } => "analytic_access",
            Ev::RequestDone { .. } => "request_done",
            Ev::RequestSkipped { .. } => "request_skipped",
            Ev::BlockStart { .. } => "block_start",
            Ev::BlockSteal { .. } => "block_steal",
            Ev::BlockFailover { .. } => "block_failover",
            Ev::BlockRetry { .. } => "block_retry",
            Ev::BlockFinish { .. } => "block_finish",
            Ev::SiteFault { .. } => "site_fault",
            Ev::SiteHeal { .. } => "site_heal",
            Ev::TransferRetry { .. } => "transfer_retry",
            Ev::ReplicaPush { .. } => "replica_push",
            Ev::ReplicaCreate { .. } => "replica_create",
            Ev::ReplicaEvict { .. } => "replica_evict",
            Ev::Dispatch { .. } => "dispatch",
            Ev::Sample { .. } => "sample",
            Ev::LinkSample { .. } => "link_sample",
        }
    }
}

/// Map a parsed tag back to the closed set of `&'static str` values the
/// instrumentation emits (payloads must stay `Copy`, so arbitrary
/// strings cannot round-trip; unknown tags collapse to `"other"`).
fn static_tag(s: &str) -> &'static str {
    match s {
        "arrival" => "arrival",
        "tick" => "tick",
        "query" => "query",
        "flow_done" => "flow_done",
        "search" => "search",
        "convert" => "convert",
        "match" => "match",
        "undiscoverable" => "undiscoverable",
        "wind_down" => "wind_down",
        "no_replica" => "no_replica",
        "dead_source" => "dead_source",
        "gave_up" => "gave_up",
        _ => "other",
    }
}

/// One recorded event: simulated timestamp, owning request, payload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub at: SimInstant,
    pub req: ReqId,
    pub ev: Ev,
}

fn site_json(names: &[String], id: SiteId) -> Json {
    match names.get(id as usize) {
        Some(n) => Json::Str(n.clone()),
        None => Json::Str(format!("site#{id}")),
    }
}

impl TraceEvent {
    /// Export as a flat JSON object (site ids resolved to names).
    pub fn to_json(&self, names: &[String]) -> Json {
        let mut o = BTreeMap::new();
        o.insert("at".to_string(), Json::Num(self.at));
        let req = match self.req {
            SAMPLE_REQ => Json::Str("sample".to_string()),
            KERNEL_REQ => Json::Str("kernel".to_string()),
            r => Json::Num(r as f64),
        };
        o.insert("req".to_string(), req);
        o.insert("ev".to_string(), Json::Str(self.ev.name().to_string()));
        fn num(o: &mut BTreeMap<String, Json>, k: &str, v: f64) {
            o.insert(k.to_string(), Json::Num(v));
        }
        match self.ev {
            Ev::Arrival => {}
            Ev::GatePark { occupancy } => num(&mut o, "occupancy", occupancy as f64),
            Ev::GateUnpark { waited_s } => num(&mut o, "waited_s", waited_s),
            Ev::DiscoveryStart { placements, drills } => {
                num(&mut o, "placements", placements as f64);
                num(&mut o, "drills", drills as f64);
            }
            Ev::QueryCutoff { unresolved } => num(&mut o, "unresolved", unresolved as f64),
            Ev::DiscoveryEnd { responses } => num(&mut o, "responses", responses as f64),
            Ev::BrokerPhase { phase, wall_us } => {
                o.insert("phase".to_string(), Json::Str(phase.to_string()));
                o.insert("wall_us".to_string(), Json::Num(wall_us as f64));
            }
            Ev::RequestDone { transfer_s } => num(&mut o, "transfer_s", transfer_s),
            Ev::RequestSkipped { reason } => {
                o.insert("reason".to_string(), Json::Str(reason.to_string()));
            }
            Ev::Dispatch { kind } => {
                o.insert("kind".to_string(), Json::Str(kind.to_string()));
            }
            Ev::Sample { in_flight, gate_depth, giis_live } => {
                num(&mut o, "in_flight", in_flight as f64);
                num(&mut o, "gate_depth", gate_depth as f64);
                num(&mut o, "giis_live", giis_live as f64);
            }
            Ev::QueryIssue { site }
            | Ev::QueryLand { site }
            | Ev::QueryTimeout { site }
            | Ev::DrillDown { site } => {
                o.insert("site".to_string(), site_json(names, site));
            }
            Ev::Selection { site, candidates } => {
                o.insert("site".to_string(), site_json(names, site));
                num(&mut o, "candidates", candidates as f64);
            }
            Ev::FlowStart { site, flow, bytes } => {
                o.insert("site".to_string(), site_json(names, site));
                num(&mut o, "flow", flow as f64);
                num(&mut o, "bytes", bytes as f64);
            }
            Ev::FlowFinish { site, flow, transfer_s } => {
                o.insert("site".to_string(), site_json(names, site));
                num(&mut o, "flow", flow as f64);
                num(&mut o, "transfer_s", transfer_s);
            }
            Ev::AnalyticAccess { site, transfer_s } => {
                o.insert("site".to_string(), site_json(names, site));
                num(&mut o, "transfer_s", transfer_s);
            }
            Ev::BlockStart { site, block, bytes } => {
                o.insert("site".to_string(), site_json(names, site));
                num(&mut o, "block", block as f64);
                num(&mut o, "bytes", bytes as f64);
            }
            Ev::BlockSteal { from, to, blocks } => {
                o.insert("from".to_string(), site_json(names, from));
                o.insert("to".to_string(), site_json(names, to));
                num(&mut o, "blocks", blocks as f64);
            }
            Ev::BlockFailover { site, orphaned } => {
                o.insert("site".to_string(), site_json(names, site));
                num(&mut o, "orphaned", orphaned as f64);
            }
            Ev::BlockRetry { site, block } => {
                o.insert("site".to_string(), site_json(names, site));
                num(&mut o, "block", block as f64);
            }
            Ev::BlockFinish { site, block, bytes } => {
                o.insert("site".to_string(), site_json(names, site));
                num(&mut o, "block", block as f64);
                num(&mut o, "bytes", bytes as f64);
            }
            Ev::SiteFault { site, degrade, heal_s } => {
                o.insert("site".to_string(), site_json(names, site));
                num(&mut o, "degrade", degrade);
                num(&mut o, "heal_s", heal_s);
            }
            Ev::SiteHeal { site } => {
                o.insert("site".to_string(), site_json(names, site));
            }
            Ev::TransferRetry { site, attempt, offset } => {
                o.insert("site".to_string(), site_json(names, site));
                num(&mut o, "attempt", attempt as f64);
                num(&mut o, "offset", offset as f64);
            }
            Ev::ReplicaPush { site, flow, bytes } => {
                o.insert("site".to_string(), site_json(names, site));
                num(&mut o, "flow", flow as f64);
                num(&mut o, "bytes", bytes as f64);
            }
            Ev::ReplicaCreate { site, transfer_s } => {
                o.insert("site".to_string(), site_json(names, site));
                num(&mut o, "transfer_s", transfer_s);
            }
            Ev::ReplicaEvict { site, bytes } => {
                o.insert("site".to_string(), site_json(names, site));
                num(&mut o, "bytes", bytes as f64);
            }
        }
        Json::Obj(o)
    }

    /// Parse one exported object back; `intern` resolves site names to
    /// ids in the receiving recorder.
    pub fn from_json(
        v: &Json,
        intern: &mut dyn FnMut(&str) -> SiteId,
    ) -> Option<TraceEvent> {
        let o = v.as_obj()?;
        let at = o.get("at")?.as_f64()?;
        let req = match o.get("req")? {
            Json::Str(s) if s == "sample" => SAMPLE_REQ,
            Json::Str(s) if s == "kernel" => KERNEL_REQ,
            Json::Num(n) => *n as u64,
            _ => return None,
        };
        let f = |k: &str| o.get(k).and_then(Json::as_f64);
        let u = |k: &str| o.get(k).and_then(Json::as_f64).map(|n| n as u64);
        let mut site = |k: &str| -> Option<SiteId> {
            o.get(k).and_then(Json::as_str).map(|s| intern(s))
        };
        let ev = match o.get("ev")?.as_str()? {
            "arrival" => Ev::Arrival,
            "gate_park" => Ev::GatePark { occupancy: u("occupancy")? as u32 },
            "gate_unpark" => Ev::GateUnpark { waited_s: f("waited_s")? },
            "discovery_start" => Ev::DiscoveryStart {
                placements: u("placements")? as u32,
                drills: u("drills")? as u32,
            },
            "query_issue" => Ev::QueryIssue { site: site("site")? },
            "query_land" => Ev::QueryLand { site: site("site")? },
            "query_timeout" => Ev::QueryTimeout { site: site("site")? },
            "query_cutoff" => Ev::QueryCutoff { unresolved: u("unresolved")? as u32 },
            "drill_down" => Ev::DrillDown { site: site("site")? },
            "discovery_end" => Ev::DiscoveryEnd { responses: u("responses")? as u32 },
            "broker_phase" => Ev::BrokerPhase {
                phase: static_tag(o.get("phase")?.as_str()?),
                wall_us: u("wall_us")?,
            },
            "selection" => Ev::Selection {
                site: site("site")?,
                candidates: u("candidates")? as u32,
            },
            "flow_start" => Ev::FlowStart {
                site: site("site")?,
                flow: u("flow")?,
                bytes: u("bytes")?,
            },
            "flow_finish" => Ev::FlowFinish {
                site: site("site")?,
                flow: u("flow")?,
                transfer_s: f("transfer_s")?,
            },
            "analytic_access" => Ev::AnalyticAccess {
                site: site("site")?,
                transfer_s: f("transfer_s")?,
            },
            "request_done" => Ev::RequestDone { transfer_s: f("transfer_s")? },
            "request_skipped" => Ev::RequestSkipped {
                reason: static_tag(o.get("reason")?.as_str()?),
            },
            "block_start" => Ev::BlockStart {
                site: site("site")?,
                block: u("block")?,
                bytes: u("bytes")?,
            },
            "block_steal" => Ev::BlockSteal {
                from: site("from")?,
                to: site("to")?,
                blocks: u("blocks")? as u32,
            },
            "block_failover" => Ev::BlockFailover {
                site: site("site")?,
                orphaned: u("orphaned")? as u32,
            },
            "block_retry" => Ev::BlockRetry { site: site("site")?, block: u("block")? },
            "block_finish" => Ev::BlockFinish {
                site: site("site")?,
                block: u("block")?,
                bytes: u("bytes")?,
            },
            "site_fault" => Ev::SiteFault {
                site: site("site")?,
                degrade: f("degrade")?,
                heal_s: f("heal_s")?,
            },
            "site_heal" => Ev::SiteHeal { site: site("site")? },
            "transfer_retry" => Ev::TransferRetry {
                site: site("site")?,
                attempt: u("attempt")? as u32,
                offset: u("offset")?,
            },
            "replica_push" => Ev::ReplicaPush {
                site: site("site")?,
                flow: u("flow")?,
                bytes: u("bytes")?,
            },
            "replica_create" => Ev::ReplicaCreate {
                site: site("site")?,
                transfer_s: f("transfer_s")?,
            },
            "replica_evict" => Ev::ReplicaEvict { site: site("site")?, bytes: u("bytes")? },
            "dispatch" => Ev::Dispatch { kind: static_tag(o.get("kind")?.as_str()?) },
            "sample" => Ev::Sample {
                in_flight: u("in_flight")? as u32,
                gate_depth: u("gate_depth")? as u32,
                giis_live: u("giis_live")? as u32,
            },
            "link_sample" => Ev::LinkSample {
                site: site("site")?,
                flows: u("flows")? as u32,
                utilization: f("utilization")?,
            },
            _ => return None,
        };
        Some(TraceEvent { at, req, ev })
    }
}

/// Bounded ring buffer of trace events plus the site-name intern table.
///
/// When full, the oldest event is overwritten and `dropped` counts the
/// loss — tracing must never grow without bound under million-request
/// runs. Chronological order is preserved across the wrap.
#[derive(Debug)]
pub struct Recorder {
    cap: usize,
    buf: Vec<TraceEvent>,
    /// Next overwrite position once the buffer has wrapped.
    head: usize,
    dropped: u64,
    names: Vec<String>,
    by_name: BTreeMap<String, SiteId>,
}

impl Recorder {
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(1);
        Recorder {
            cap,
            buf: Vec::new(),
            head: 0,
            dropped: 0,
            names: Vec::new(),
            by_name: BTreeMap::new(),
        }
    }

    /// Intern a site (or client) name, returning its stable id.
    pub fn intern(&mut self, name: &str) -> SiteId {
        if let Some(&id) = self.by_name.get(name) {
            return id;
        }
        let id = self.names.len() as SiteId;
        self.names.push(name.to_string());
        self.by_name.insert(name.to_string(), id);
        id
    }

    /// Name for an interned id (for rendering / exporters).
    pub fn site_name(&self, id: SiteId) -> &str {
        self.names.get(id as usize).map(String::as_str).unwrap_or("?")
    }

    /// The intern table, id-ordered.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Append one event, overwriting the oldest when at capacity.
    pub fn push(&mut self, at: SimInstant, req: ReqId, ev: Ev) {
        let e = TraceEvent { at, req, ev };
        if self.buf.len() < self.cap {
            self.buf.push(e);
        } else {
            self.buf[self.head] = e;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events lost to ring overwrite.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Chronological copy of the retained events (unwraps the ring).
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }

    /// JSONL export: one stable-key-order object per line. Identically
    /// seeded runs produce byte-identical output (property-tested).
    pub fn jsonl(&self) -> String {
        let mut out = String::new();
        for e in self.events() {
            out.push_str(&e.to_json(&self.names).to_string());
            out.push('\n');
        }
        out
    }

    /// Per-request span reconstruction (sampler/kernel rows excluded).
    pub fn spans(&self) -> Vec<RequestSpans> {
        spans(&self.events())
    }

    /// Chrome trace-event JSON (Perfetto-loadable). Tracks: one per
    /// request under pid 1 ("requests"), one per site under pid 2
    /// ("sites"), counter series from the sampler. Raw events are
    /// embedded under `"rawEvents"` so the artifact is self-contained.
    pub fn chrome_json(&self) -> String {
        let evs = self.events();
        let request_spans = spans(&evs);
        let mut tev: Vec<Json> = Vec::new();

        let obj = |pairs: Vec<(&str, Json)>| {
            Json::Obj(
                pairs
                    .into_iter()
                    .map(|(k, v)| (k.to_string(), v))
                    .collect::<BTreeMap<_, _>>(),
            )
        };
        let meta = |pid: f64, tid: f64, what: &str, name: String| {
            obj(vec![
                ("ph", Json::Str("M".to_string())),
                ("name", Json::Str(what.to_string())),
                ("pid", Json::Num(pid)),
                ("tid", Json::Num(tid)),
                ("args", obj(vec![("name", Json::Str(name))])),
            ])
        };
        let complete = |pid: f64, tid: f64, name: String, at: f64, dur: f64| {
            obj(vec![
                ("ph", Json::Str("X".to_string())),
                ("name", Json::Str(name)),
                ("pid", Json::Num(pid)),
                ("tid", Json::Num(tid)),
                ("ts", Json::Num(at * 1e6)),
                ("dur", Json::Num(dur.max(0.0) * 1e6)),
            ])
        };
        let instant = |pid: f64, tid: f64, name: String, at: f64| {
            obj(vec![
                ("ph", Json::Str("i".to_string())),
                ("s", Json::Str("t".to_string())),
                ("name", Json::Str(name)),
                ("pid", Json::Num(pid)),
                ("tid", Json::Num(tid)),
                ("ts", Json::Num(at * 1e6)),
            ])
        };
        let counter = |name: String, at: f64, value: f64| {
            obj(vec![
                ("ph", Json::Str("C".to_string())),
                ("name", Json::Str(name)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(0.0)),
                ("ts", Json::Num(at * 1e6)),
                ("args", obj(vec![("value", Json::Num(value))])),
            ])
        };

        tev.push(meta(1.0, 0.0, "process_name", "requests".to_string()));
        tev.push(meta(2.0, 0.0, "process_name", "sites".to_string()));
        for (i, n) in self.names.iter().enumerate() {
            tev.push(meta(2.0, i as f64, "thread_name", format!("site {n}")));
        }
        for sp in &request_spans {
            let tid = sp.req as f64;
            tev.push(meta(1.0, tid, "thread_name", format!("req {}", sp.req)));
            if sp.skipped {
                tev.push(instant(1.0, tid, "skipped".to_string(), sp.arrival));
                continue;
            }
            tev.push(complete(1.0, tid, "queue".to_string(), sp.arrival, sp.queue_s));
            tev.push(complete(1.0, tid, "discovery".to_string(), sp.admit, sp.discovery_s));
            tev.push(complete(1.0, tid, "transfer".to_string(), sp.select, sp.transfer_s));
        }

        // Site tracks: kernel flows, analytic accesses, coalloc markers.
        let mut open_flows: BTreeMap<u64, (f64, SiteId, ReqId)> = BTreeMap::new();
        for e in &evs {
            match e.ev {
                Ev::FlowStart { site, flow, .. } => {
                    open_flows.insert(flow, (e.at, site, e.req));
                }
                Ev::FlowFinish { flow, .. } => {
                    if let Some((t0, site, req)) = open_flows.remove(&flow) {
                        tev.push(complete(
                            2.0,
                            site as f64,
                            format!("flow req {req}"),
                            t0,
                            e.at - t0,
                        ));
                    }
                }
                Ev::AnalyticAccess { site, transfer_s } => {
                    tev.push(complete(
                        2.0,
                        site as f64,
                        format!("access req {}", e.req),
                        e.at,
                        transfer_s,
                    ));
                }
                Ev::BlockSteal { to, blocks, .. } => {
                    tev.push(instant(2.0, to as f64, format!("steal x{blocks}"), e.at));
                }
                Ev::BlockFailover { site, orphaned } => {
                    tev.push(instant(
                        2.0,
                        site as f64,
                        format!("failover orphaned {orphaned}"),
                        e.at,
                    ));
                }
                Ev::SiteFault { site, degrade, .. } => {
                    let what = if degrade == 0.0 {
                        "crash".to_string()
                    } else {
                        format!("flap x{degrade:.2}")
                    };
                    tev.push(instant(2.0, site as f64, what, e.at));
                }
                Ev::SiteHeal { site } => {
                    tev.push(instant(2.0, site as f64, "heal".to_string(), e.at));
                }
                Ev::TransferRetry { site, attempt, .. } => {
                    tev.push(instant(
                        2.0,
                        site as f64,
                        format!("retry #{attempt} req {}", e.req),
                        e.at,
                    ));
                }
                Ev::ReplicaCreate { site, transfer_s } => {
                    tev.push(instant(
                        2.0,
                        site as f64,
                        format!("replica +{transfer_s:.1}s"),
                        e.at,
                    ));
                }
                Ev::ReplicaEvict { site, .. } => {
                    tev.push(instant(2.0, site as f64, "evict".to_string(), e.at));
                }
                Ev::Sample { in_flight, gate_depth, giis_live } => {
                    tev.push(counter("in_flight".to_string(), e.at, in_flight as f64));
                    tev.push(counter("gate_depth".to_string(), e.at, gate_depth as f64));
                    tev.push(counter("giis_live".to_string(), e.at, giis_live as f64));
                }
                Ev::LinkSample { site, utilization, .. } => {
                    tev.push(counter(
                        format!("util {}", self.site_name(site)),
                        e.at,
                        utilization,
                    ));
                }
                _ => {}
            }
        }

        let raw: Vec<Json> = evs.iter().map(|e| e.to_json(&self.names)).collect();
        let mut top = BTreeMap::new();
        top.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
        top.insert("traceEvents".to_string(), Json::Arr(tev));
        top.insert("rawEvents".to_string(), Json::Arr(raw));
        top.insert("droppedEvents".to_string(), Json::Num(self.dropped as f64));
        Json::Obj(top).to_string()
    }
}

/// Shared, cloneable, zero-cost-when-disabled recorder handle.
///
/// The default (and [`TraceHandle::disabled`]) handle holds `None`:
/// [`TraceHandle::rec`] is then a single branch — no lock, no
/// allocation — which is the contract that keeps traced code paths
/// bit-identical and allocation-free when tracing is off.
#[derive(Debug, Clone, Default)]
pub struct TraceHandle(Option<Arc<Mutex<Recorder>>>);

impl TraceHandle {
    /// A handle that records nothing (the default everywhere).
    pub fn disabled() -> Self {
        TraceHandle(None)
    }

    /// A live handle over a fresh ring of `capacity` events.
    pub fn new(capacity: usize) -> Self {
        TraceHandle(Some(Arc::new(Mutex::new(Recorder::new(capacity)))))
    }

    /// Is this handle recording?
    #[inline]
    pub fn on(&self) -> bool {
        self.0.is_some()
    }

    /// Record one event. One branch when disabled.
    #[inline]
    pub fn rec(&self, at: SimInstant, req: ReqId, ev: Ev) {
        if let Some(r) = &self.0 {
            r.lock().unwrap().push(at, req, ev);
        }
    }

    /// Run `f` against the recorder when enabled (for events that need
    /// name interning — the closure is never called when disabled, so
    /// the disabled path still does no work).
    #[inline]
    pub fn with<F: FnOnce(&mut Recorder)>(&self, f: F) {
        if let Some(r) = &self.0 {
            f(&mut r.lock().unwrap());
        }
    }

    /// Read access to the finished recorder (exporters, analyzers).
    pub fn read<T>(&self, f: impl FnOnce(&Recorder) -> T) -> Option<T> {
        self.0.as_ref().map(|r| f(&r.lock().unwrap()))
    }

    /// Write both artifacts (`TRACE_<name>.json` chrome +
    /// `TRACE_<name>.jsonl`) into the current directory; returns the
    /// paths written, empty when disabled.
    pub fn write_artifacts(&self, name: &str) -> crate::Result<Vec<String>> {
        let Some((chrome, jsonl)) = self.read(|r| (r.chrome_json(), r.jsonl())) else {
            return Ok(Vec::new());
        };
        let json_path = format!("TRACE_{name}.json");
        let jsonl_path = format!("TRACE_{name}.jsonl");
        std::fs::write(&json_path, chrome)?;
        std::fs::write(&jsonl_path, jsonl)?;
        Ok(vec![json_path, jsonl_path])
    }
}

/// Reconstructed span chain for one request:
/// `[arrival, admit)` queue, `[admit, select)` discovery,
/// `[select, finish)` transfer — a partition of the request's total
/// simulated time, so coverage is exact by construction.
#[derive(Debug, Clone)]
pub struct RequestSpans {
    pub req: ReqId,
    pub arrival: SimInstant,
    /// Gate-unpark instant (== arrival when the gate had a free slot).
    pub admit: SimInstant,
    /// Selection instant (discovery resolved, replica ranked).
    pub select: SimInstant,
    /// Completion instant.
    pub finish: SimInstant,
    pub queue_s: f64,
    pub discovery_s: f64,
    pub transfer_s: f64,
    /// Service duration carried by `request_done` — what
    /// `QualityReport::mean_time`/`p95_time` aggregate.
    pub reported_transfer_s: f64,
    /// Replica the broker picked, when one was recorded.
    pub site: Option<SiteId>,
    pub skipped: bool,
    /// This request's full event timeline, chronological.
    pub events: Vec<TraceEvent>,
}

impl RequestSpans {
    pub fn total_s(&self) -> f64 {
        self.finish - self.arrival
    }

    /// Fraction of `[arrival, finish]` covered by the three phase
    /// spans (1.0 by construction; `< 1` would flag a malformed trace).
    pub fn coverage(&self) -> f64 {
        let total = self.total_s();
        if total <= 0.0 {
            1.0
        } else {
            (self.queue_s + self.discovery_s + self.transfer_s) / total
        }
    }
}

/// Rebuild per-request spans from a chronological event slice.
pub fn spans(events: &[TraceEvent]) -> Vec<RequestSpans> {
    struct B {
        arrival: Option<f64>,
        unpark: Option<f64>,
        disc_start: Option<f64>,
        select_at: Option<f64>,
        flow_start: Option<f64>,
        finish: Option<f64>,
        analytic_end: Option<f64>,
        reported: f64,
        site: Option<SiteId>,
        skipped: bool,
        events: Vec<TraceEvent>,
    }
    let mut by_req: BTreeMap<ReqId, B> = BTreeMap::new();
    for e in events {
        if e.req == SAMPLE_REQ || e.req == KERNEL_REQ {
            continue;
        }
        let b = by_req.entry(e.req).or_insert(B {
            arrival: None,
            unpark: None,
            disc_start: None,
            select_at: None,
            flow_start: None,
            finish: None,
            analytic_end: None,
            reported: 0.0,
            site: None,
            skipped: false,
            events: Vec::new(),
        });
        b.events.push(*e);
        match e.ev {
            Ev::Arrival => {
                if b.arrival.is_none() {
                    b.arrival = Some(e.at);
                }
            }
            Ev::GateUnpark { .. } => b.unpark = Some(e.at),
            Ev::DiscoveryStart { .. } => {
                if b.disc_start.is_none() {
                    b.disc_start = Some(e.at);
                }
            }
            Ev::Selection { site, .. } => {
                b.select_at = Some(e.at);
                b.site = Some(site);
            }
            Ev::FlowStart { site, .. } => {
                if b.flow_start.is_none() {
                    b.flow_start = Some(e.at);
                }
                if b.site.is_none() {
                    b.site = Some(site);
                }
            }
            Ev::AnalyticAccess { site, transfer_s } => {
                if b.flow_start.is_none() {
                    b.flow_start = Some(e.at);
                }
                if b.site.is_none() {
                    b.site = Some(site);
                }
                b.analytic_end = Some(e.at + transfer_s);
            }
            Ev::RequestDone { transfer_s } => {
                b.finish = Some(e.at);
                b.reported = transfer_s;
            }
            Ev::RequestSkipped { .. } => b.skipped = true,
            _ => {}
        }
    }
    by_req
        .into_iter()
        .map(|(req, b)| {
            let arrival = b.arrival.unwrap_or(0.0);
            let admit = b.unpark.or(b.disc_start).or(b.select_at).unwrap_or(arrival);
            let select = b.select_at.or(b.flow_start).unwrap_or(admit);
            // Analytic accesses report completion at record time but
            // logically finish `transfer_s` later; prefer the explicit
            // done stamp, then the analytic end, then the select point.
            let finish = b
                .finish
                .or(b.analytic_end)
                .unwrap_or(select)
                .max(select);
            RequestSpans {
                req,
                arrival,
                admit,
                select,
                finish,
                queue_s: admit - arrival,
                discovery_s: select - admit,
                transfer_s: finish - select,
                reported_transfer_s: b.reported,
                site: b.site,
                skipped: b.skipped,
                events: b.events,
            }
        })
        .collect()
}

/// Order statistics for one phase, using the same arithmetic as
/// `experiment::quality::finish_report` (sorted, `mean = Σ/n`,
/// `q = v[(n·q) as usize % n]`) so summary numbers are comparable to
/// report numbers to the last bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseStats {
    pub n: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub max_s: f64,
}

/// Fold a duration vector into [`PhaseStats`].
pub fn phase_stats(mut v: Vec<f64>) -> PhaseStats {
    let n = v.len();
    if n == 0 {
        return PhaseStats { n: 0, mean_s: 0.0, p50_s: 0.0, p95_s: 0.0, max_s: 0.0 };
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean_s = v.iter().sum::<f64>() / n as f64;
    let q = |q: f64| v[(n as f64 * q) as usize % n];
    PhaseStats { n, mean_s, p50_s: q(0.5), p95_s: q(0.95), max_s: v[n - 1] }
}

/// `(mean, p95)` with exactly `finish_report`'s arithmetic — the
/// cross-check that lets `trace-summary` reproduce
/// `QualityReport::mean_time`/`p95_time` from a trace alone.
pub fn mean_p95(mut durations: Vec<f64>) -> (f64, f64) {
    if durations.is_empty() {
        return (0.0, 0.0);
    }
    durations.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = durations.iter().sum::<f64>() / durations.len() as f64;
    let p95 = durations[(durations.len() as f64 * 0.95) as usize % durations.len()];
    (mean, p95)
}

/// Whole-trace analysis: phase breakdown + report parity + slowest-N.
#[derive(Debug, Clone)]
pub struct TraceSummary {
    /// Completed requests found in the trace.
    pub requests: usize,
    pub skipped: usize,
    /// Events lost to ring overwrite (0 when the ring never wrapped).
    pub dropped: u64,
    pub queue: PhaseStats,
    pub discovery: PhaseStats,
    pub transfer: PhaseStats,
    pub total: PhaseStats,
    /// Reproduction of `QualityReport::mean_time` from the trace alone.
    pub mean_time: f64,
    /// Reproduction of `QualityReport::p95_time` from the trace alone.
    pub p95_time: f64,
    /// Minimum per-request span coverage (should be 1.0).
    pub min_coverage: f64,
    /// Top-N slowest requests by total simulated time, slowest first.
    pub slowest: Vec<RequestSpans>,
}

/// Summarize reconstructed spans; `top_n` bounds the slow-request list.
pub fn summarize(all: &[RequestSpans], dropped: u64, top_n: usize) -> TraceSummary {
    let done: Vec<&RequestSpans> = all.iter().filter(|s| !s.skipped).collect();
    let queue = phase_stats(done.iter().map(|s| s.queue_s).collect());
    let discovery = phase_stats(done.iter().map(|s| s.discovery_s).collect());
    let transfer = phase_stats(done.iter().map(|s| s.transfer_s).collect());
    let total = phase_stats(done.iter().map(|s| s.total_s()).collect());
    let (mean_time, p95_time) =
        mean_p95(done.iter().map(|s| s.reported_transfer_s).collect());
    let min_coverage = done.iter().map(|s| s.coverage()).fold(1.0f64, f64::min);
    let mut slowest: Vec<RequestSpans> = done.into_iter().cloned().collect();
    slowest.sort_by(|a, b| {
        b.total_s()
            .partial_cmp(&a.total_s())
            .unwrap()
            .then(a.req.cmp(&b.req))
    });
    slowest.truncate(top_n);
    TraceSummary {
        requests: all.iter().filter(|s| !s.skipped).count(),
        skipped: all.iter().filter(|s| s.skipped).count(),
        dropped,
        queue,
        discovery,
        transfer,
        total,
        mean_time,
        p95_time,
        min_coverage,
        slowest,
    }
}

/// Load a trace back from either exported format: Chrome JSON (reads
/// the embedded `"rawEvents"`) or JSONL (one object per line).
pub fn load_trace(src: &str) -> crate::Result<Recorder> {
    let trimmed = src.trim_start();
    let objects: Vec<Json> = if trimmed.starts_with('{') {
        let v = Json::parse(src).map_err(|e| anyhow!("trace parse: {e}"))?;
        v.get("rawEvents")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("trace file has no rawEvents array"))?
            .to_vec()
    } else {
        let mut out = Vec::new();
        for (i, line) in src.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            out.push(
                Json::parse(line)
                    .map_err(|e| anyhow!("trace line {}: {e}", i + 1))?,
            );
        }
        out
    };
    let mut rec = Recorder::new(objects.len().max(1));
    for (i, o) in objects.iter().enumerate() {
        // Split the borrow: intern against a detached table, then merge.
        let ev = {
            let names = &mut rec.names;
            let by_name = &mut rec.by_name;
            let mut intern = |s: &str| -> SiteId {
                if let Some(&id) = by_name.get(s) {
                    return id;
                }
                let id = names.len() as SiteId;
                names.push(s.to_string());
                by_name.insert(s.to_string(), id);
                id
            };
            TraceEvent::from_json(o, &mut intern)
                .ok_or_else(|| anyhow!("bad trace event at index {i}"))?
        };
        rec.push(ev.at, ev.req, ev.ev);
    }
    Ok(rec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraps_and_counts_drops() {
        let mut r = Recorder::new(4);
        for i in 0..10 {
            r.push(i as f64, i, Ev::Arrival);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 6);
        let ats: Vec<f64> = r.events().iter().map(|e| e.at).collect();
        assert_eq!(ats, vec![6.0, 7.0, 8.0, 9.0], "chronological across wrap");
    }

    #[test]
    fn disabled_handle_is_a_noop() {
        let h = TraceHandle::disabled();
        assert!(!h.on());
        h.rec(1.0, 1, Ev::Arrival);
        let mut called = false;
        h.with(|_| called = true);
        assert!(!called, "closure must not run when disabled");
        assert!(h.read(|r| r.len()).is_none());
        assert!(h.write_artifacts("noop").unwrap().is_empty());
        // Default is disabled too — that is the hot-path contract.
        assert!(!TraceHandle::default().on());
    }

    #[test]
    fn enabled_handle_records_and_interns() {
        let h = TraceHandle::new(16);
        assert!(h.on());
        h.with(|r| {
            let s = r.intern("siteA");
            r.push(0.5, 7, Ev::Selection { site: s, candidates: 3 });
            assert_eq!(r.intern("siteA"), s, "intern is idempotent");
        });
        h.rec(0.6, 7, Ev::RequestDone { transfer_s: 0.1 });
        assert_eq!(h.read(|r| r.len()), Some(2));
        assert_eq!(h.read(|r| r.site_name(0).to_string()), Some("siteA".into()));
    }

    /// Hand-built trace: park 2s, discover 3s, transfer 4s.
    fn hand_built() -> Recorder {
        let mut r = Recorder::new(64);
        let s = r.intern("siteA");
        r.push(0.0, 1, Ev::Arrival);
        r.push(0.0, 1, Ev::GatePark { occupancy: 4 });
        r.push(2.0, 1, Ev::GateUnpark { waited_s: 2.0 });
        r.push(2.0, 1, Ev::DiscoveryStart { placements: 3, drills: 2 });
        r.push(2.1, 1, Ev::QueryIssue { site: s });
        r.push(4.9, 1, Ev::QueryLand { site: s });
        r.push(5.0, 1, Ev::DiscoveryEnd { responses: 2 });
        r.push(5.0, 1, Ev::Selection { site: s, candidates: 2 });
        r.push(5.0, 1, Ev::FlowStart { site: s, flow: 0, bytes: 1 << 20 });
        r.push(9.0, 1, Ev::FlowFinish { site: s, flow: 0, transfer_s: 4.0 });
        r.push(9.0, 1, Ev::RequestDone { transfer_s: 4.0 });
        r
    }

    #[test]
    fn critical_path_reconstruction() {
        let r = hand_built();
        let sp = r.spans();
        assert_eq!(sp.len(), 1);
        let s = &sp[0];
        assert_eq!(s.req, 1);
        assert_eq!(s.queue_s, 2.0);
        assert_eq!(s.discovery_s, 3.0);
        assert_eq!(s.transfer_s, 4.0);
        assert_eq!(s.total_s(), 9.0);
        assert_eq!(s.coverage(), 1.0, "phases partition the request");
        assert_eq!(s.reported_transfer_s, 4.0);
        assert!(!s.skipped);
        assert_eq!(s.events.len(), 11);
    }

    #[test]
    fn ungated_request_has_zero_queue() {
        let mut r = Recorder::new(16);
        let s = r.intern("b");
        r.push(1.0, 2, Ev::Arrival);
        r.push(1.0, 2, Ev::DiscoveryStart { placements: 1, drills: 0 });
        r.push(1.5, 2, Ev::Selection { site: s, candidates: 1 });
        r.push(1.5, 2, Ev::AnalyticAccess { site: s, transfer_s: 2.5 });
        let sp = r.spans();
        assert_eq!(sp[0].queue_s, 0.0);
        assert_eq!(sp[0].discovery_s, 0.5);
        // Analytic end stamps the logical finish even without an
        // explicit request_done.
        assert_eq!(sp[0].finish, 4.0);
        assert_eq!(sp[0].transfer_s, 2.5);
    }

    #[test]
    fn summary_uses_finish_report_arithmetic() {
        let durations = vec![3.0, 1.0, 2.0, 5.0, 4.0];
        let (mean, p95) = mean_p95(durations.clone());
        assert_eq!(mean, 3.0);
        // sorted = [1,2,3,4,5]; idx = (5*0.95) as usize % 5 = 4
        assert_eq!(p95, 5.0);
        let ps = phase_stats(durations);
        assert_eq!(ps.p50_s, 3.0); // idx (5*0.5) as usize = 2
        assert_eq!(ps.max_s, 5.0);
        assert_eq!(phase_stats(Vec::new()).n, 0);
    }

    #[test]
    fn summarize_ranks_slowest_and_counts_skips() {
        let mut r = Recorder::new(64);
        let s = r.intern("a");
        for (req, dur) in [(1u64, 2.0f64), (2, 8.0), (3, 5.0)] {
            r.push(0.0, req, Ev::Arrival);
            r.push(0.0, req, Ev::Selection { site: s, candidates: 1 });
            r.push(dur, req, Ev::RequestDone { transfer_s: dur });
        }
        r.push(0.0, 4, Ev::Arrival);
        r.push(0.0, 4, Ev::RequestSkipped { reason: "wind_down" });
        let sum = summarize(&r.spans(), r.dropped(), 2);
        assert_eq!(sum.requests, 3);
        assert_eq!(sum.skipped, 1);
        assert_eq!(sum.slowest.len(), 2);
        assert_eq!(sum.slowest[0].req, 2);
        assert_eq!(sum.slowest[1].req, 3);
        assert_eq!(sum.min_coverage, 1.0);
        let (mean, p95) = mean_p95(vec![2.0, 8.0, 5.0]);
        assert_eq!(sum.mean_time, mean);
        assert_eq!(sum.p95_time, p95);
    }

    #[test]
    fn jsonl_round_trips() {
        let r = hand_built();
        let text = r.jsonl();
        assert_eq!(text.lines().count(), 11);
        let back = load_trace(&text).unwrap();
        assert_eq!(back.events(), r.events());
        assert_eq!(back.names(), r.names());
        let a = summarize(&r.spans(), 0, 5);
        let b = summarize(&back.spans(), 0, 5);
        assert_eq!(a.mean_time, b.mean_time);
        assert_eq!(a.total.p95_s, b.total.p95_s);
    }

    #[test]
    fn chrome_json_round_trips_via_raw_events() {
        let mut r = hand_built();
        r.push(
            1.0,
            SAMPLE_REQ,
            Ev::Sample { in_flight: 1, gate_depth: 0, giis_live: 3 },
        );
        r.push(1.0, KERNEL_REQ, Ev::Dispatch { kind: "tick" });
        let text = r.chrome_json();
        let v = Json::parse(&text).unwrap();
        assert!(v.get("traceEvents").unwrap().as_arr().unwrap().len() >= 5);
        let back = load_trace(&text).unwrap();
        assert_eq!(back.events(), r.events());
        // Pseudo-request rows survive the string-sentinel encoding.
        let evs = back.events();
        assert!(evs.iter().any(|e| e.req == SAMPLE_REQ));
        assert!(evs.iter().any(|e| e.req == KERNEL_REQ));
        // Sampler/kernel rows never become request spans.
        assert_eq!(back.spans().len(), 1);
    }

    #[test]
    fn weather_and_retry_events_round_trip() {
        let mut r = Recorder::new(16);
        let s = r.intern("stormy-site");
        r.push(5.0, KERNEL_REQ, Ev::SiteFault { site: s, degrade: 0.0, heal_s: 35.0 });
        r.push(7.0, KERNEL_REQ, Ev::SiteFault { site: s, degrade: 0.5, heal_s: -1.0 });
        r.push(9.0, 3, Ev::TransferRetry { site: s, attempt: 2, offset: 1 << 20 });
        r.push(9.5, 3, Ev::RequestSkipped { reason: "gave_up" });
        r.push(35.0, KERNEL_REQ, Ev::SiteHeal { site: s });
        let back = load_trace(&r.jsonl()).unwrap();
        assert_eq!(back.events(), r.events());
        // "gave_up" is in the closed tag set, not collapsed to "other".
        assert!(back
            .events()
            .iter()
            .any(|e| e.ev == Ev::RequestSkipped { reason: "gave_up" }));
        let chrome = load_trace(&r.chrome_json()).unwrap();
        assert_eq!(chrome.events(), r.events());
    }

    #[test]
    fn economy_events_round_trip() {
        let mut r = Recorder::new(16);
        let s = r.intern("hot-site");
        r.push(10.0, KERNEL_REQ, Ev::ReplicaPush { site: s, flow: 42, bytes: 1 << 28 });
        r.push(55.0, KERNEL_REQ, Ev::ReplicaCreate { site: s, transfer_s: 45.0 });
        r.push(90.0, KERNEL_REQ, Ev::ReplicaEvict { site: s, bytes: 1 << 27 });
        let back = load_trace(&r.jsonl()).unwrap();
        assert_eq!(back.events(), r.events());
        let chrome = load_trace(&r.chrome_json()).unwrap();
        assert_eq!(chrome.events(), r.events());
        // Kernel-track rows never become request spans.
        assert!(back.spans().is_empty());
    }

    #[test]
    fn skipped_only_request_reconstructs_without_panic() {
        let mut r = Recorder::new(8);
        r.push(3.0, 9, Ev::Arrival);
        r.push(3.0, 9, Ev::RequestSkipped { reason: "undiscoverable" });
        let sp = r.spans();
        assert!(sp[0].skipped);
        assert_eq!(sp[0].total_s(), 0.0);
        let sum = summarize(&sp, 0, 3);
        assert_eq!(sum.requests, 0);
        assert_eq!(sum.skipped, 1);
        assert_eq!(sum.mean_time, 0.0);
    }
}
