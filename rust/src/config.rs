//! Grid configuration: the parameterization of sites, links and
//! workloads used by the simulator, the daemons and the benches.
//!
//! Configs load from JSON (see `examples/` and `rust/tests/data`) or are
//! generated procedurally from a seed, so every experiment in
//! EXPERIMENTS.md is reproducible from its command line.

use anyhow::{bail, Context};

use crate::util::json::Json;
use crate::util::prng::Rng;

/// Per-site storage + connectivity profile.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteConfig {
    pub name: String,
    pub org: String,
    /// Local disk streaming rate (bytes/s).
    pub disk_rate: f64,
    /// Volume capacity (bytes).
    pub total_space: f64,
    /// Initially used fraction [0,1).
    pub used_frac: f64,
    /// Mean WAN bandwidth from this site to clients (bytes/s).
    pub wan_bandwidth: f64,
    /// Diurnal load swing amplitude as a fraction of the mean [0,1).
    pub diurnal_amp: f64,
    /// AR(1) noise: coefficient and innovation std (fraction of mean).
    pub ar_coeff: f64,
    pub noise_frac: f64,
    /// Probability per sample of a heavy-tail congestion episode.
    pub congestion_prob: f64,
    /// One-way latency to the client population (seconds).
    pub latency: f64,
    /// Average disk-read seek overhead (ms) — the Fig-2 `drdTime`.
    pub drd_time_ms: f64,
    /// Average disk-write seek overhead (ms) — the Fig-2 `dwrTime`.
    pub dwr_time_ms: f64,
}

/// Whole-grid configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct GridConfig {
    pub sites: Vec<SiteConfig>,
    /// Seed for everything stochastic downstream.
    pub seed: u64,
}

impl GridConfig {
    /// Procedurally generate a heterogeneous grid of `n` sites.
    ///
    /// Site profiles span the heterogeneity that makes replica selection
    /// matter (paper §5): fast well-connected centers, mid-tier
    /// university sites, and slow/overloaded archives, with parameters
    /// drawn around 2001-era magnitudes (WAN bandwidths in the
    /// 100 KB/s – 10 MB/s range; the paper's example ads use 50–75 KB/s).
    pub fn generate(n: usize, seed: u64) -> GridConfig {
        let mut rng = Rng::new(seed ^ 0x5173_C0DE);
        let orgs = ["anl", "lbl", "isi", "ncsa", "sdsc", "olemiss"];
        let mut sites = Vec::with_capacity(n);
        for i in 0..n {
            // Three site tiers with distinct profiles.
            let tier = match i % 3 {
                0 => "center",
                1 => "campus",
                _ => "archive",
            };
            let (bw_lo, bw_hi, amp, cong) = match tier {
                "center" => (2.0e6, 10.0e6, 0.25, 0.02),
                "campus" => (200e3, 2.0e6, 0.45, 0.05),
                _ => (50e3, 400e3, 0.60, 0.10),
            };
            let wan = rng.range(bw_lo, bw_hi);
            sites.push(SiteConfig {
                name: format!("{}-s{:02}", orgs[i % orgs.len()], i),
                org: orgs[i % orgs.len()].to_string(),
                disk_rate: rng.range(10e6, 60e6),
                total_space: rng.range(20.0, 200.0) * 1024f64.powi(3),
                used_frac: rng.range(0.1, 0.8),
                wan_bandwidth: wan,
                diurnal_amp: amp * rng.range(0.7, 1.3),
                ar_coeff: rng.range(0.55, 0.9),
                noise_frac: rng.range(0.08, 0.25),
                congestion_prob: cong,
                latency: rng.range(0.01, 0.12),
                drd_time_ms: rng.range(4.0, 14.0),
                dwr_time_ms: rng.range(5.0, 16.0),
            });
        }
        GridConfig { sites, seed }
    }

    /// Parse from JSON text.
    pub fn from_json(src: &str) -> anyhow::Result<GridConfig> {
        let v = Json::parse(src).context("parsing grid config JSON")?;
        let seed = v.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let sites_json = v
            .get("sites")
            .and_then(Json::as_arr)
            .context("config needs a `sites` array")?;
        let mut sites = Vec::new();
        for (i, s) in sites_json.iter().enumerate() {
            let f = |k: &str, d: f64| s.get(k).and_then(Json::as_f64).unwrap_or(d);
            let name = match s.get("name").and_then(Json::as_str) {
                Some(n) => n.to_string(),
                None => bail!("site {i} missing `name`"),
            };
            sites.push(SiteConfig {
                name,
                org: s
                    .get("org")
                    .and_then(Json::as_str)
                    .unwrap_or("grid")
                    .to_string(),
                disk_rate: f("disk_rate", 20e6),
                total_space: f("total_space", 100.0 * 1024f64.powi(3)),
                used_frac: f("used_frac", 0.5),
                wan_bandwidth: f("wan_bandwidth", 1e6),
                diurnal_amp: f("diurnal_amp", 0.4),
                ar_coeff: f("ar_coeff", 0.7),
                noise_frac: f("noise_frac", 0.15),
                congestion_prob: f("congestion_prob", 0.05),
                latency: f("latency", 0.05),
                drd_time_ms: f("drd_time_ms", 8.0),
                dwr_time_ms: f("dwr_time_ms", 10.0),
            });
        }
        if sites.is_empty() {
            bail!("config has no sites");
        }
        Ok(GridConfig { sites, seed })
    }

    /// Serialize to JSON.
    pub fn to_json(&self) -> String {
        use std::collections::BTreeMap;
        let site = |s: &SiteConfig| {
            let mut m = BTreeMap::new();
            m.insert("name".into(), Json::Str(s.name.clone()));
            m.insert("org".into(), Json::Str(s.org.clone()));
            m.insert("disk_rate".into(), Json::Num(s.disk_rate));
            m.insert("total_space".into(), Json::Num(s.total_space));
            m.insert("used_frac".into(), Json::Num(s.used_frac));
            m.insert("wan_bandwidth".into(), Json::Num(s.wan_bandwidth));
            m.insert("diurnal_amp".into(), Json::Num(s.diurnal_amp));
            m.insert("ar_coeff".into(), Json::Num(s.ar_coeff));
            m.insert("noise_frac".into(), Json::Num(s.noise_frac));
            m.insert("congestion_prob".into(), Json::Num(s.congestion_prob));
            m.insert("latency".into(), Json::Num(s.latency));
            m.insert("drd_time_ms".into(), Json::Num(s.drd_time_ms));
            m.insert("dwr_time_ms".into(), Json::Num(s.dwr_time_ms));
            Json::Obj(m)
        };
        let mut top = BTreeMap::new();
        top.insert("seed".into(), Json::Num(self.seed as f64));
        top.insert(
            "sites".into(),
            Json::Arr(self.sites.iter().map(site).collect()),
        );
        Json::Obj(top).to_string()
    }
}

/// Tuning knobs for co-allocated (striped) transfers — the
/// `crate::coalloc` subsystem. One logical file is pulled from up to
/// `max_streams` replicas at once in `block_size` chunks; streams that
/// drain their assignment steal blocks from lagging peers.
#[derive(Debug, Clone, PartialEq)]
pub struct CoallocPolicy {
    /// Chunk granularity in bytes. Smaller blocks rebalance faster but
    /// pay more per-block latency; the GridFTP work used 1–64 MB.
    pub block_size: f64,
    /// Maximum parallel streams = size of the top-K replica set.
    pub max_streams: usize,
    /// Work-stealing trigger: an idle stream steals from the peer with
    /// the largest backlog only if that backlog is at least this many
    /// blocks (half the backlog moves).
    pub rebalance_threshold: f64,
    /// Scheduler step in simulated seconds (steal decisions happen at
    /// this granularity; byte movement is exact within a step).
    pub tick: f64,
    /// Client downlink capacity shared by all streams (bytes/s);
    /// `f64::INFINITY` leaves the WAN links as the only bottleneck.
    /// The planner also consumes this cap: stripes are clipped to what
    /// the client can absorb, so sources whose bandwidth the downlink
    /// could never use are not striped at all.
    pub client_downlink: f64,
    /// Failover: how many times one block may be re-queued after its
    /// source died or stalled before the whole transfer is declared
    /// failed. 0 disables failover (the paper-era behaviour: a dying
    /// replica kills the transfer).
    pub max_block_retries: usize,
    /// Failover: a block in flight longer than this many simulated
    /// seconds marks its source as stalled (treated like a death — the
    /// stream's blocks are re-queued to survivors). `INFINITY` trusts
    /// sources to eventually deliver. Deliberately wall-clock, not
    /// progress-based: a link crawling at 0.1% is *the* stall failure
    /// mode this exists for, so "slow but moving" still trips it.
    /// Consequence for sessions sharing one open-loop kernel: size the
    /// timeout for block time *under expected contention* (or leave it
    /// infinite), because other clients' traffic legitimately
    /// stretches in-flight times.
    pub block_timeout: f64,
}

impl Default for CoallocPolicy {
    fn default() -> Self {
        CoallocPolicy {
            block_size: 16.0 * 1024.0 * 1024.0,
            max_streams: 4,
            rebalance_threshold: 2.0,
            tick: 2.0,
            client_downlink: f64::INFINITY,
            max_block_retries: 3,
            block_timeout: f64::INFINITY,
        }
    }
}

impl CoallocPolicy {
    /// Parse from JSON text; absent keys keep their defaults. A missing
    /// or non-positive `client_downlink` means uncapped.
    pub fn from_json(src: &str) -> anyhow::Result<CoallocPolicy> {
        let v = Json::parse(src).context("parsing coalloc policy JSON")?;
        let d = CoallocPolicy::default();
        let f = |k: &str, dflt: f64| v.get(k).and_then(Json::as_f64).unwrap_or(dflt);
        let downlink = f("client_downlink", 0.0);
        let timeout = f("block_timeout", 0.0);
        Ok(CoallocPolicy {
            // Floored at 64 KiB: a degenerate block size would explode
            // the block count (and the scheduler's queues) downstream.
            block_size: f("block_size", d.block_size).max(64.0 * 1024.0),
            max_streams: f("max_streams", d.max_streams as f64).max(1.0) as usize,
            rebalance_threshold: f("rebalance_threshold", d.rebalance_threshold),
            tick: f("tick", d.tick).max(1e-3),
            client_downlink: if downlink > 0.0 { downlink } else { f64::INFINITY },
            max_block_retries: f("max_block_retries", d.max_block_retries as f64)
                .max(0.0) as usize,
            // Missing or non-positive means "no stall detection".
            block_timeout: if timeout > 0.0 { timeout } else { f64::INFINITY },
        })
    }

    /// Serialize to JSON (an uncapped downlink is omitted).
    pub fn to_json(&self) -> String {
        use std::collections::BTreeMap;
        let mut m = BTreeMap::new();
        m.insert("block_size".into(), Json::Num(self.block_size));
        m.insert("max_streams".into(), Json::Num(self.max_streams as f64));
        m.insert(
            "rebalance_threshold".into(),
            Json::Num(self.rebalance_threshold),
        );
        m.insert("tick".into(), Json::Num(self.tick));
        if self.client_downlink.is_finite() {
            m.insert("client_downlink".into(), Json::Num(self.client_downlink));
        }
        m.insert(
            "max_block_retries".into(),
            Json::Num(self.max_block_retries as f64),
        );
        if self.block_timeout.is_finite() {
            m.insert("block_timeout".into(), Json::Num(self.block_timeout));
        }
        Json::Obj(m).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_is_deterministic() {
        let a = GridConfig::generate(8, 42);
        let b = GridConfig::generate(8, 42);
        assert_eq!(a, b);
        let c = GridConfig::generate(8, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn generated_sites_are_heterogeneous() {
        let g = GridConfig::generate(12, 1);
        let bws: Vec<f64> = g.sites.iter().map(|s| s.wan_bandwidth).collect();
        let max = bws.iter().cloned().fold(0.0, f64::max);
        let min = bws.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 3.0, "heterogeneity too low: {min}..{max}");
    }

    #[test]
    fn json_round_trip() {
        let g = GridConfig::generate(4, 7);
        let re = GridConfig::from_json(&g.to_json()).unwrap();
        assert_eq!(g, re);
    }

    #[test]
    fn json_defaults_fill_in() {
        let g = GridConfig::from_json(r#"{"sites": [{"name": "x"}]}"#).unwrap();
        assert_eq!(g.sites[0].name, "x");
        assert!(g.sites[0].wan_bandwidth > 0.0);
    }

    #[test]
    fn json_errors() {
        assert!(GridConfig::from_json("{}").is_err());
        assert!(GridConfig::from_json(r#"{"sites": [{}]}"#).is_err());
        assert!(GridConfig::from_json("notjson").is_err());
    }

    #[test]
    fn coalloc_policy_round_trip() {
        let p = CoallocPolicy {
            block_size: 4e6,
            max_streams: 6,
            rebalance_threshold: 3.0,
            tick: 1.0,
            client_downlink: 5e6,
            max_block_retries: 2,
            block_timeout: 120.0,
        };
        let re = CoallocPolicy::from_json(&p.to_json()).unwrap();
        assert_eq!(p, re);
        // Uncapped downlink survives the omit-on-serialize rule.
        let unc = CoallocPolicy::default();
        let re = CoallocPolicy::from_json(&unc.to_json()).unwrap();
        assert_eq!(unc, re);
    }

    #[test]
    fn coalloc_policy_defaults_and_floors() {
        let p = CoallocPolicy::from_json("{}").unwrap();
        assert_eq!(p, CoallocPolicy::default());
        let p = CoallocPolicy::from_json(r#"{"max_streams": 0, "tick": 0, "block_size": 0}"#)
            .unwrap();
        assert_eq!(p.max_streams, 1);
        assert!(p.tick > 0.0);
        assert!(p.block_size >= 64.0 * 1024.0);
        assert!(CoallocPolicy::from_json("nope").is_err());
    }
}
