//! Application metadata repository (paper §2.1 "Application Metadata",
//! §5: "An application ... begins by querying an application specific
//! metadata repository, specifying the characteristics of the desired
//! data").
//!
//! Maps descriptive attribute/value pairs (experiment, run, energy,
//! organism, ...) onto logical file names, with a conjunctive query
//! interface.

use std::collections::BTreeMap;

/// The repository: logical file → descriptive attributes.
#[derive(Debug, Default, Clone)]
pub struct MetadataRepository {
    records: BTreeMap<String, BTreeMap<String, String>>,
}

impl MetadataRepository {
    pub fn new() -> Self {
        Self::default()
    }

    /// Describe (or re-describe) a logical file.
    pub fn describe(&mut self, logical: &str, attrs: &[(&str, &str)]) {
        let rec = self.records.entry(logical.to_string()).or_default();
        for (k, v) in attrs {
            rec.insert(k.to_ascii_lowercase(), v.to_string());
        }
    }

    /// All attributes of a logical file.
    pub fn attributes(&self, logical: &str) -> Option<&BTreeMap<String, String>> {
        self.records.get(logical)
    }

    /// Conjunctive query: logical files whose metadata contains *all*
    /// the given attribute/value pairs (values case-insensitive).
    pub fn query(&self, needles: &[(&str, &str)]) -> Vec<&str> {
        self.records
            .iter()
            .filter(|(_, attrs)| {
                needles.iter().all(|(k, v)| {
                    attrs
                        .get(&k.to_ascii_lowercase())
                        .map(|have| have.eq_ignore_ascii_case(v))
                        .unwrap_or(false)
                })
            })
            .map(|(name, _)| name.as_str())
            .collect()
    }

    /// Unique query: exactly one logical file, else None.
    pub fn identify(&self, needles: &[(&str, &str)]) -> Option<&str> {
        let hits = self.query(needles);
        match hits.as_slice() {
            [one] => Some(one),
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo() -> MetadataRepository {
        let mut m = MetadataRepository::new();
        m.describe(
            "run42.dat",
            &[("experiment", "CMS"), ("year", "2001"), ("beamEnergy", "7TeV")],
        );
        m.describe(
            "run43.dat",
            &[("experiment", "CMS"), ("year", "2001"), ("beamEnergy", "8TeV")],
        );
        m.describe("genome.fa", &[("organism", "E.coli"), ("assembly", "K12")]);
        m
    }

    #[test]
    fn conjunctive_query() {
        let m = repo();
        assert_eq!(m.query(&[("experiment", "CMS")]).len(), 2);
        assert_eq!(
            m.query(&[("experiment", "cms"), ("beamenergy", "7tev")]),
            vec!["run42.dat"]
        );
        assert!(m.query(&[("experiment", "ATLAS")]).is_empty());
    }

    #[test]
    fn identify_requires_uniqueness() {
        let m = repo();
        assert_eq!(m.identify(&[("beamEnergy", "7TeV")]), Some("run42.dat"));
        assert_eq!(m.identify(&[("experiment", "CMS")]), None);
        assert_eq!(m.identify(&[("nope", "x")]), None);
    }

    #[test]
    fn redescribe_merges() {
        let mut m = repo();
        m.describe("run42.dat", &[("quality", "gold")]);
        let attrs = m.attributes("run42.dat").unwrap();
        assert_eq!(attrs.get("quality").unwrap(), "gold");
        assert_eq!(attrs.get("experiment").unwrap(), "CMS");
    }
}
