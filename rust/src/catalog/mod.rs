//! Replica catalog + application metadata repository (paper §2.2, §5).
//!
//! The replica catalog maps **logical files** (and logical collections)
//! to the **physical locations** holding replicas. The application
//! metadata repository maps *content descriptions* to logical files, so
//! an application can go `characteristics → logical file → replica
//! locations` exactly as §5 describes.

pub mod metadata;
pub mod replica;

pub use metadata::MetadataRepository;
pub use replica::{LogicalFile, PhysicalLocation, ReplicaCatalog};
