//! The replica catalog: logical files, collections, physical locations.

use std::collections::BTreeMap;

use thiserror::Error;

use crate::util::units::Bytes;

/// A logical file known to the catalog.
#[derive(Debug, Clone, PartialEq)]
pub struct LogicalFile {
    pub name: String,
    pub size: Bytes,
    /// Logical collection (dataset) the file belongs to.
    pub collection: String,
}

/// One physical replica location: a storage site + path.
#[derive(Debug, Clone, PartialEq)]
pub struct PhysicalLocation {
    /// Site name — matches the GRIS site and gridftp endpoint name.
    pub site: String,
    /// URL-ish locator, e.g. `gsiftp://mcs.anl.gov/data/f001`.
    pub url: String,
}

#[derive(Debug, Error, PartialEq)]
pub enum CatalogError {
    #[error("logical file {0:?} already registered")]
    Duplicate(String),
    #[error("logical file {0:?} not found")]
    NotFound(String),
    #[error("replica of {0:?} at site {1:?} already registered")]
    DuplicateReplica(String, String),
    #[error("replica of {0:?} at site {1:?} not found")]
    ReplicaNotFound(String, String),
}

/// The catalog. Deterministic iteration (BTreeMap) keeps broker
/// tiebreaks stable.
#[derive(Debug, Default, Clone)]
pub struct ReplicaCatalog {
    files: BTreeMap<String, LogicalFile>,
    replicas: BTreeMap<String, Vec<PhysicalLocation>>,
}

impl ReplicaCatalog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a logical file.
    pub fn create_logical(
        &mut self,
        name: &str,
        size: Bytes,
        collection: &str,
    ) -> Result<(), CatalogError> {
        if self.files.contains_key(name) {
            return Err(CatalogError::Duplicate(name.into()));
        }
        self.files.insert(
            name.to_string(),
            LogicalFile { name: name.into(), size, collection: collection.into() },
        );
        self.replicas.insert(name.to_string(), Vec::new());
        Ok(())
    }

    /// Add a replica location for a logical file.
    pub fn add_replica(&mut self, logical: &str, loc: PhysicalLocation) -> Result<(), CatalogError> {
        let reps = self
            .replicas
            .get_mut(logical)
            .ok_or_else(|| CatalogError::NotFound(logical.into()))?;
        if reps.iter().any(|r| r.site == loc.site) {
            return Err(CatalogError::DuplicateReplica(logical.into(), loc.site));
        }
        reps.push(loc);
        Ok(())
    }

    /// Remove a replica (replica management's delete operation).
    pub fn remove_replica(&mut self, logical: &str, site: &str) -> Result<(), CatalogError> {
        let reps = self
            .replicas
            .get_mut(logical)
            .ok_or_else(|| CatalogError::NotFound(logical.into()))?;
        let before = reps.len();
        reps.retain(|r| r.site != site);
        if reps.len() == before {
            return Err(CatalogError::ReplicaNotFound(logical.into(), site.into()));
        }
        Ok(())
    }

    pub fn logical(&self, name: &str) -> Option<&LogicalFile> {
        self.files.get(name)
    }

    /// All replica locations of a logical file (the Search-phase query,
    /// §5.1.2 step 1).
    pub fn locate(&self, logical: &str) -> Result<&[PhysicalLocation], CatalogError> {
        self.replicas
            .get(logical)
            .map(|v| v.as_slice())
            .ok_or_else(|| CatalogError::NotFound(logical.into()))
    }

    /// Logical files in a collection.
    pub fn collection(&self, name: &str) -> Vec<&LogicalFile> {
        self.files.values().filter(|f| f.collection == name).collect()
    }

    pub fn logical_files(&self) -> impl Iterator<Item = &LogicalFile> {
        self.files.values()
    }

    pub fn len(&self) -> usize {
        self.files.len()
    }

    pub fn is_empty(&self) -> bool {
        self.files.is_empty()
    }

    /// Total replica count across all files.
    pub fn replica_count(&self) -> usize {
        self.replicas.values().map(|v| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn catalog() -> ReplicaCatalog {
        let mut c = ReplicaCatalog::new();
        c.create_logical("run42.dat", Bytes::from_gb(2.0), "cms-run2001").unwrap();
        c.add_replica(
            "run42.dat",
            PhysicalLocation { site: "anl-mcs".into(), url: "gsiftp://anl/run42.dat".into() },
        )
        .unwrap();
        c.add_replica(
            "run42.dat",
            PhysicalLocation { site: "lbl-dsd".into(), url: "gsiftp://lbl/run42.dat".into() },
        )
        .unwrap();
        c
    }

    #[test]
    fn create_and_locate() {
        let c = catalog();
        let reps = c.locate("run42.dat").unwrap();
        assert_eq!(reps.len(), 2);
        assert_eq!(reps[0].site, "anl-mcs");
        assert_eq!(c.logical("run42.dat").unwrap().size, Bytes::from_gb(2.0));
    }

    #[test]
    fn duplicate_logical_rejected() {
        let mut c = catalog();
        assert_eq!(
            c.create_logical("run42.dat", Bytes(1.0), "x"),
            Err(CatalogError::Duplicate("run42.dat".into()))
        );
    }

    #[test]
    fn duplicate_replica_site_rejected() {
        let mut c = catalog();
        let err = c.add_replica(
            "run42.dat",
            PhysicalLocation { site: "anl-mcs".into(), url: "other".into() },
        );
        assert!(matches!(err, Err(CatalogError::DuplicateReplica(_, _))));
    }

    #[test]
    fn remove_replica() {
        let mut c = catalog();
        c.remove_replica("run42.dat", "anl-mcs").unwrap();
        assert_eq!(c.locate("run42.dat").unwrap().len(), 1);
        assert!(matches!(
            c.remove_replica("run42.dat", "anl-mcs"),
            Err(CatalogError::ReplicaNotFound(_, _))
        ));
    }

    #[test]
    fn unknown_logical_errors() {
        let c = catalog();
        assert!(matches!(c.locate("nope"), Err(CatalogError::NotFound(_))));
    }

    #[test]
    fn collections_group_files() {
        let mut c = catalog();
        c.create_logical("run43.dat", Bytes::from_gb(1.0), "cms-run2001").unwrap();
        c.create_logical("genome.fa", Bytes::from_mb(300.0), "genomics").unwrap();
        assert_eq!(c.collection("cms-run2001").len(), 2);
        assert_eq!(c.collection("genomics").len(), 1);
        assert_eq!(c.len(), 3);
        assert_eq!(c.replica_count(), 2);
    }
}
