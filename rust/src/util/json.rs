//! Minimal JSON: parse + serialize.
//!
//! Used for `artifacts/manifest.json`, simulator configs, and metrics
//! dumps. Implements the full JSON grammar (RFC 8259) minus \u surrogate
//! pairs beyond the BMP; numbers parse as f64.

use std::collections::BTreeMap;
use std::fmt;

use thiserror::Error;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Error, PartialEq)]
pub enum JsonError {
    #[error("unexpected end of input at byte {0}")]
    Eof(usize),
    #[error("unexpected character {1:?} at byte {0}")]
    Unexpected(usize, char),
    #[error("bad number at byte {0}")]
    BadNumber(usize),
    #[error("bad escape at byte {0}")]
    BadEscape(usize),
    #[error("trailing data at byte {0}")]
    Trailing(usize),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = P { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(JsonError::Trailing(p.i));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Path lookup: `get("entries.forecast.file")`.
    pub fn get(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for seg in path.split('.') {
            cur = cur.as_obj()?.get(seg)?;
        }
        Some(cur)
    }
}

struct P<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> P<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\r' | b'\n') {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.b.get(self.i) {
            None => Err(JsonError::Eof(self.i)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.i += 1;
                let mut arr = Vec::new();
                self.ws();
                if self.b.get(self.i) == Some(&b']') {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                loop {
                    self.ws();
                    arr.push(self.value()?);
                    self.ws();
                    match self.b.get(self.i) {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(arr));
                        }
                        Some(&c) => return Err(JsonError::Unexpected(self.i, c as char)),
                        None => return Err(JsonError::Eof(self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut obj = BTreeMap::new();
                self.ws();
                if self.b.get(self.i) == Some(&b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(obj));
                }
                loop {
                    self.ws();
                    let key = self.string()?;
                    self.ws();
                    match self.b.get(self.i) {
                        Some(b':') => self.i += 1,
                        Some(&c) => return Err(JsonError::Unexpected(self.i, c as char)),
                        None => return Err(JsonError::Eof(self.i)),
                    }
                    self.ws();
                    obj.insert(key, self.value()?);
                    self.ws();
                    match self.b.get(self.i) {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(obj));
                        }
                        Some(&c) => return Err(JsonError::Unexpected(self.i, c as char)),
                        None => return Err(JsonError::Eof(self.i)),
                    }
                }
            }
            Some(_) => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(JsonError::Unexpected(self.i, self.b[self.i] as char))
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        if self.b.get(self.i) != Some(&b'"') {
            return Err(JsonError::Unexpected(
                self.i,
                *self.b.get(self.i).unwrap_or(&b' ') as char,
            ));
        }
        self.i += 1;
        let mut out = String::new();
        loop {
            match self.b.get(self.i) {
                None => return Err(JsonError::Eof(self.i)),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    let esc = *self.b.get(self.i + 1).ok_or(JsonError::Eof(self.i))?;
                    self.i += 2;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or(JsonError::Eof(self.i))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| JsonError::BadEscape(self.i))?,
                                16,
                            )
                            .map_err(|_| JsonError::BadEscape(self.i))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(JsonError::BadEscape(self.i)),
                    }
                }
                Some(&c) if c < 0x80 => {
                    out.push(c as char);
                    self.i += 1;
                }
                Some(_) => {
                    // Multi-byte UTF-8: copy the full sequence.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| JsonError::BadEscape(self.i))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(JsonError::BadNumber(start))
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn round_trips(){
        let src = r#"{"entries":{"forecast":{"file":"forecast.hlo.txt","shape":[128,64]}},"version":1}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.to_string(), src);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse("\"π\"").unwrap(), Json::Str("π".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn path_get() {
        let v = Json::parse(r#"{"a":{"b":{"c":3}}}"#).unwrap();
        assert_eq!(v.get("a.b.c").unwrap().as_f64(), Some(3.0));
        assert!(v.get("a.x").is_none());
    }
}
