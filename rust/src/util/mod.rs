//! Utility substrate: deterministic PRNG, unit-suffixed quantities,
//! minimal JSON, micro-benchmark harness, property-test runner, CLI
//! argument parsing, and a small regular-expression engine.
//!
//! The build image has no network access, so the conventional crates
//! (criterion, proptest, clap, serde_json) are replaced by small,
//! purpose-built equivalents here. They are real implementations — the
//! bench harness does warmup/outlier-aware statistics, the prop runner
//! does seeded case generation with failure reporting — just scoped to
//! what this repository needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prng;
pub mod prop;
pub mod rex;
pub mod units;
