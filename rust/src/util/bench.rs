//! Micro-benchmark harness (criterion substitute, offline image).
//!
//! Measures wall-clock per iteration with warmup, adaptive iteration
//! counts, and robust statistics (mean, std, p50/p90/p99). Benches are
//! plain binaries (`[[bench]] harness = false`) that print aligned rows
//! so `cargo bench` output can be diffed against EXPERIMENTS.md.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Statistics for one benchmark case.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p90_ns: f64,
    pub p99_ns: f64,
    /// Optional caller-provided throughput denominator (items/iter).
    pub items_per_iter: f64,
}

impl Stats {
    /// items/second derived from mean latency.
    pub fn throughput(&self) -> f64 {
        if self.mean_ns <= 0.0 {
            0.0
        } else {
            self.items_per_iter * 1e9 / self.mean_ns
        }
    }

    /// The case's headline numbers as JSON — the shared shape every
    /// `BENCH_*.json` artifact uses (`scripts/bench.sh`).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut o = std::collections::BTreeMap::new();
        o.insert("name".to_string(), Json::Str(self.name.clone()));
        o.insert("ns_per_op".to_string(), Json::Num(self.mean_ns));
        o.insert("p50_ns".to_string(), Json::Num(self.p50_ns));
        o.insert("p99_ns".to_string(), Json::Num(self.p99_ns));
        o.insert("items_per_iter".to_string(), Json::Num(self.items_per_iter));
        o.insert("ops_per_sec".to_string(), Json::Num(self.throughput()));
        Json::Obj(o)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:8.1}ns")
    } else if ns < 1_000_000.0 {
        format!("{:8.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:8.2}ms", ns / 1e6)
    } else {
        format!("{:8.2}s ", ns / 1e9)
    }
}

fn fmt_rate(r: f64) -> String {
    if r >= 1e9 {
        format!("{:7.2}G/s", r / 1e9)
    } else if r >= 1e6 {
        format!("{:7.2}M/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:7.2}K/s", r / 1e3)
    } else {
        format!("{r:7.1}/s ")
    }
}

/// A benchmark group with shared config; prints rows as cases finish.
pub struct Bench {
    group: String,
    warmup: Duration,
    budget: Duration,
    min_iters: u64,
    results: Vec<Stats>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // Honor a quick mode for CI-ish runs: BENCH_QUICK=1.
        let quick = std::env::var("BENCH_QUICK").is_ok();
        let (warmup, budget) = if quick {
            (Duration::from_millis(50), Duration::from_millis(200))
        } else {
            (Duration::from_millis(300), Duration::from_secs(2))
        };
        println!("\n== {group} ==");
        println!(
            "{:<44} {:>10} {:>10} {:>10} {:>10} {:>10}",
            "case", "mean", "p50", "p99", "std", "thrpt"
        );
        Bench {
            group: group.to_string(),
            warmup,
            budget,
            min_iters: 10,
            results: Vec::new(),
        }
    }

    /// Time `f`, treating each call as processing `items` items.
    pub fn case_items<R>(&mut self, name: &str, items: f64, mut f: impl FnMut() -> R) -> &Stats {
        // Warmup + estimate per-iter cost.
        let wstart = Instant::now();
        let mut witers = 0u64;
        while wstart.elapsed() < self.warmup || witers < 3 {
            black_box(f());
            witers += 1;
            if witers > 1_000_000 {
                break;
            }
        }
        let est = wstart.elapsed().as_nanos() as f64 / witers as f64;
        // Sample in batches so Instant overhead stays <1%.
        let batch = ((100.0 / est.max(1.0)).ceil() as u64).clamp(1, 10_000);
        let target_samples = ((self.budget.as_nanos() as f64 / (est * batch as f64))
            .ceil() as u64)
            .clamp(self.min_iters, 100_000);
        let mut samples = Vec::with_capacity(target_samples as usize);
        let start = Instant::now();
        for _ in 0..target_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
            if start.elapsed() > self.budget * 2 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| samples[((n as f64 * p) as usize).min(n - 1)];
        let stats = Stats {
            name: name.to_string(),
            iters: n as u64 * batch,
            mean_ns: mean,
            std_ns: var.sqrt(),
            p50_ns: pct(0.50),
            p90_ns: pct(0.90),
            p99_ns: pct(0.99),
            items_per_iter: items,
        };
        println!(
            "{:<44} {} {} {} {} {}",
            stats.name,
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p50_ns),
            fmt_ns(stats.p99_ns),
            fmt_ns(stats.std_ns),
            fmt_rate(stats.throughput()),
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Time `f` with one logical item per iteration.
    pub fn case<R>(&mut self, name: &str, f: impl FnMut() -> R) -> &Stats {
        self.case_items(name, 1.0, f)
    }

    /// Finish the group, returning all stats.
    pub fn finish(self) -> Vec<Stats> {
        println!("-- {} done ({} cases)", self.group, self.results.len());
        self.results
    }
}

/// Print a labeled metric row (used by quality benches where the output
/// is a domain number, not a latency).
pub fn report_metric(name: &str, value: f64, unit: &str) {
    println!("{name:<44} {value:>12.4} {unit}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_sane_for_fast_op() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bench::new("selftest");
        let s = b.case("noop-ish", || std::hint::black_box(1 + 1)).clone();
        assert!(s.mean_ns > 0.0);
        assert!(s.p50_ns <= s.p99_ns);
        assert!(s.iters >= 10);
    }

    #[test]
    fn throughput_math() {
        let s = Stats {
            name: "x".into(),
            iters: 1,
            mean_ns: 1000.0,
            std_ns: 0.0,
            p50_ns: 1000.0,
            p90_ns: 1000.0,
            p99_ns: 1000.0,
            items_per_iter: 10.0,
        };
        assert_eq!(s.throughput(), 1e7);
    }
}
