//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ core.
//!
//! Everything stochastic in the repository (simulated links, workload
//! generators, property tests) draws from this generator so every run is
//! reproducible from a single `u64` seed.

/// xoshiro256++ generator (public-domain algorithm by Blackman & Vigna),
/// seeded via SplitMix64 so that small consecutive seeds yield
/// decorrelated streams.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed. Any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-site / per-client rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (n > 0), bias-free via rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn gauss(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Pareto(scale, alpha) — heavy-tailed (used for file sizes and
    /// congestion bursts).
    pub fn pareto(&mut self, scale: f64, alpha: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        scale / u.powf(1.0 / alpha)
    }

    /// Zipf-like rank selection over n items with skew `theta` in (0,1]:
    /// rank 0 most popular. Used for replica access popularity.
    pub fn zipf(&mut self, n: usize, theta: f64) -> usize {
        // Inverse-CDF on the harmonic-ish weights; O(n) setup avoided by
        // the approximation of Gray et al. (good enough for workloads).
        let u = self.f64();
        let zeta2 = 1.0 + 0.5f64.powf(theta);
        let zetan = Self::zetan(n, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        let uz = u * zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(theta) {
            return 1;
        }
        ((n as f64) * (eta * u - eta + 1.0).powf(alpha)) as usize % n
    }

    fn zetan(n: usize, theta: f64) -> f64 {
        // Cached per (n, theta)? workloads call this with a fixed n; the
        // direct sum at setup cost O(n) is fine for n <= 1e5.
        (1..=n).map(|i| 1.0 / (i as f64).powf(theta)).sum()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn pareto_exceeds_scale() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            assert!(r.pareto(2.0, 1.5) >= 2.0);
        }
    }

    #[test]
    fn zipf_head_heavier_than_tail() {
        let mut r = Rng::new(17);
        let mut counts = vec![0u32; 50];
        for _ in 0..50_000 {
            counts[r.zipf(50, 0.9)] += 1;
        }
        assert!(counts[0] > counts[25] && counts[0] > counts[49]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(23);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
