//! Unit-suffixed quantities as they appear in ClassAds and GRIS records.
//!
//! The paper's ads use values like `50G`, `75K/Sec`, `5G`: a magnitude
//! with a binary-ish storage suffix, optionally `/Sec` for rates. This
//! module parses and formats those forms and provides typed wrappers
//! ([`Bytes`], [`Bandwidth`]) used across the catalog, directory, and
//! gridftp modules.

use std::fmt;

use thiserror::Error;

/// Parse/format errors for unit-suffixed quantities.
#[derive(Debug, Error, PartialEq)]
pub enum UnitError {
    #[error("empty quantity")]
    Empty,
    #[error("bad magnitude in {0:?}")]
    BadMagnitude(String),
    #[error("unknown unit suffix in {0:?}")]
    BadSuffix(String),
}

/// Multiplier for a storage suffix (K/M/G/T/P, case-insensitive,
/// optionally followed by `B` / `iB`). The 2001-era ads use powers of
/// 1024, and so do we.
fn suffix_multiplier(s: &str) -> Option<f64> {
    let norm = s.trim().trim_end_matches("iB").trim_end_matches('B');
    match norm.to_ascii_uppercase().as_str() {
        "" => Some(1.0),
        "K" => Some(1024.0),
        "M" => Some(1024.0 * 1024.0),
        "G" => Some(1024.0 * 1024.0 * 1024.0),
        "T" => Some(1024.0f64.powi(4)),
        "P" => Some(1024.0f64.powi(5)),
        _ => None,
    }
}

/// Parse a quantity like `50G`, `75K/Sec`, `1.5M`, `1024`.
/// Returns (value_in_base_units, is_rate).
pub fn parse_quantity(input: &str) -> Result<(f64, bool), UnitError> {
    let t = input.trim();
    if t.is_empty() {
        return Err(UnitError::Empty);
    }
    let (body, is_rate) = match t
        .to_ascii_lowercase()
        .strip_suffix("/sec")
        .map(|p| p.len())
    {
        Some(len) => (&t[..len], true),
        None => (t, false),
    };
    let split = body
        .char_indices()
        .find(|(_, c)| !(c.is_ascii_digit() || *c == '.' || *c == '-' || *c == '+'))
        .map(|(i, _)| i)
        .unwrap_or(body.len());
    let (mag, suffix) = body.split_at(split);
    let value: f64 = mag
        .parse()
        .map_err(|_| UnitError::BadMagnitude(input.to_string()))?;
    let mult = suffix_multiplier(suffix).ok_or_else(|| UnitError::BadSuffix(input.to_string()))?;
    Ok((value * mult, is_rate))
}

/// Format a byte-ish magnitude. A unit suffix is used only when the
/// value is an *exact* integral multiple of the unit, so formatted
/// quantities always re-parse to the identical f64 (non-integral values
/// print as full-precision raw numbers).
pub fn format_quantity(value: f64, rate: bool) -> String {
    let tiers: [(f64, &str); 4] = [
        (1024.0f64.powi(4), "T"),
        (1024.0f64.powi(3), "G"),
        (1024.0 * 1024.0, "M"),
        (1024.0, "K"),
    ];
    let mut body = None;
    for (mult, suffix) in tiers {
        if value.abs() >= mult {
            let v = value / mult;
            if v == v.round() && v.abs() < 1e15 && v.round() * mult == value {
                body = Some(format!("{}{suffix}", v.round() as i64));
            }
            break;
        }
    }
    // `{}` on f64 is Rust's shortest round-trip representation.
    let body = body.unwrap_or_else(|| format!("{value}"));
    if rate {
        format!("{body}/Sec")
    } else {
        body
    }
}

/// A byte count (storage capacity, file size).
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub struct Bytes(pub f64);

impl Bytes {
    pub fn from_gb(gb: f64) -> Self {
        Bytes(gb * 1024.0f64.powi(3))
    }
    pub fn from_mb(mb: f64) -> Self {
        Bytes(mb * 1024.0f64.powi(2))
    }
    pub fn from_kb(kb: f64) -> Self {
        Bytes(kb * 1024.0)
    }
    pub fn gb(self) -> f64 {
        self.0 / 1024.0f64.powi(3)
    }
    pub fn mb(self) -> f64 {
        self.0 / 1024.0f64.powi(2)
    }
    pub fn parse(s: &str) -> Result<Self, UnitError> {
        let (v, _) = parse_quantity(s)?;
        Ok(Bytes(v))
    }
}

impl fmt::Display for Bytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&format_quantity(self.0, false))
    }
}

/// A transfer rate in bytes/second.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub struct Bandwidth(pub f64);

impl Bandwidth {
    pub fn from_kbps(kb: f64) -> Self {
        Bandwidth(kb * 1024.0)
    }
    pub fn from_mbps(mb: f64) -> Self {
        Bandwidth(mb * 1024.0 * 1024.0)
    }
    pub fn kbps(self) -> f64 {
        self.0 / 1024.0
    }
    pub fn mbps(self) -> f64 {
        self.0 / (1024.0 * 1024.0)
    }
    pub fn parse(s: &str) -> Result<Self, UnitError> {
        let (v, _) = parse_quantity(s)?;
        Ok(Bandwidth(v))
    }
    /// Seconds to move `bytes` at this rate.
    pub fn transfer_time(self, bytes: Bytes) -> f64 {
        if self.0 <= 0.0 {
            f64::INFINITY
        } else {
            bytes.0 / self.0
        }
    }
}

impl fmt::Display for Bandwidth {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&format_quantity(self.0, true))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_paper_literals() {
        // The exact literals from the paper's §4/§5.2 ads.
        assert_eq!(parse_quantity("50G").unwrap(), (50.0 * 1024f64.powi(3), false));
        assert_eq!(parse_quantity("10G").unwrap(), (10.0 * 1024f64.powi(3), false));
        assert_eq!(parse_quantity("5G").unwrap(), (5.0 * 1024f64.powi(3), false));
        assert_eq!(parse_quantity("75K/Sec").unwrap(), (75.0 * 1024.0, true));
        assert_eq!(parse_quantity("50K/Sec").unwrap(), (50.0 * 1024.0, true));
    }

    #[test]
    fn parses_plain_and_fractional() {
        assert_eq!(parse_quantity("1024").unwrap(), (1024.0, false));
        assert_eq!(parse_quantity("1.5K").unwrap(), (1536.0, false));
        assert_eq!(parse_quantity("-2K").unwrap(), (-2048.0, false));
    }

    #[test]
    fn parses_b_and_ib_forms() {
        assert_eq!(parse_quantity("1KB").unwrap().0, 1024.0);
        assert_eq!(parse_quantity("1KiB").unwrap().0, 1024.0);
        assert_eq!(parse_quantity("3MB/Sec").unwrap(), (3.0 * 1024.0 * 1024.0, true));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_quantity("").is_err());
        assert!(parse_quantity("G").is_err());
        assert!(parse_quantity("12Q").is_err());
        assert!(parse_quantity("abc").is_err());
    }

    #[test]
    fn round_trips_display() {
        for s in ["50G", "75K/Sec", "3M", "1T"] {
            let (v, rate) = parse_quantity(s).unwrap();
            assert_eq!(format_quantity(v, rate), s);
        }
    }

    #[test]
    fn bytes_helpers() {
        assert_eq!(Bytes::from_gb(5.0).gb(), 5.0);
        assert_eq!(Bytes::parse("5G").unwrap(), Bytes::from_gb(5.0));
        assert_eq!(Bytes::from_gb(2.0).to_string(), "2G");
    }

    #[test]
    fn bandwidth_transfer_time() {
        let bw = Bandwidth::from_kbps(75.0);
        let t = bw.transfer_time(Bytes::from_mb(75.0 / 1024.0));
        assert!((t - 1.0).abs() < 1e-9);
        assert!(Bandwidth(0.0).transfer_time(Bytes(1.0)).is_infinite());
    }

    #[test]
    fn round_trips_every_tier_and_rate_form() {
        for s in [
            "0", "1", "1K", "1M", "1G", "1T", "512K", "2G/Sec", "1024/Sec", "7M/Sec",
        ] {
            let (v, rate) = parse_quantity(s).unwrap();
            let formatted = format_quantity(v, rate);
            let (v2, rate2) = parse_quantity(&formatted).unwrap();
            assert_eq!((v, rate), (v2, rate2), "round trip of {s} via {formatted}");
        }
    }

    #[test]
    fn zero_and_fractional_values() {
        assert_eq!(parse_quantity("0").unwrap(), (0.0, false));
        assert_eq!(parse_quantity("0K").unwrap(), (0.0, false));
        assert_eq!(parse_quantity("0.25K").unwrap(), (256.0, false));
        assert_eq!(parse_quantity("2.5M/Sec").unwrap(), (2.5 * 1024.0 * 1024.0, true));
        // Non-integral multiples format as raw numbers that re-parse
        // to the identical f64.
        let v = 1.5 * 1024.0;
        let s = format_quantity(v, false);
        assert_eq!(parse_quantity(&s).unwrap().0, v);
        // Zero formats without a suffix.
        assert_eq!(format_quantity(0.0, false), "0");
        assert_eq!(format_quantity(0.0, true), "0/Sec");
    }

    #[test]
    fn malformed_input_errors_instead_of_panicking() {
        for s in [
            "", "   ", "/Sec", "K/Sec", "--3K", "3..5K", "1e", "NaNK", "12QB", "K12",
            "G5", "1KK",
        ] {
            assert!(parse_quantity(s).is_err(), "{s:?} should fail to parse");
        }
        assert!(Bytes::parse("12Q").is_err());
        assert!(Bandwidth::parse("").is_err());
        assert_eq!(parse_quantity("").unwrap_err(), UnitError::Empty);
        assert!(matches!(
            parse_quantity("xyz").unwrap_err(),
            UnitError::BadMagnitude(_)
        ));
        assert!(matches!(
            parse_quantity("3Z").unwrap_err(),
            UnitError::BadSuffix(_)
        ));
    }

    #[test]
    fn paper_request_ad_quantities_round_trip_types() {
        // `reqdSpace = 5G; reqdRDBandwidth = 50K/Sec` as typed wrappers.
        let space = Bytes::parse("5G").unwrap();
        let rate = Bandwidth::parse("50K/Sec").unwrap();
        assert_eq!(space.to_string(), "5G");
        assert_eq!(rate.to_string(), "50K/Sec");
        assert_eq!(Bytes::parse(&space.to_string()).unwrap(), space);
        assert_eq!(Bandwidth::parse(&rate.to_string()).unwrap(), rate);
    }
}
