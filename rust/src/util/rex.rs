//! Minimal regular-expression engine for the ClassAd `regexp` builtin.
//!
//! The conventional `regex` crate is not available in the offline build
//! image (see the module docs in [`crate::util`]), so this implements
//! the subset the directory/ClassAd layer needs: literals, `.`,
//! `*`/`+`/`?` and `{m}`/`{m,}`/`{m,n}` repetition, alternation `|`,
//! grouping `(...)` (and non-capturing `(?:...)`), character classes
//! `[a-z]`/`[^...]`, anchors `^`/`$`, and the `\d \D \w \W \s \S`
//! shorthands. Matching is a backtracking VM over a compiled program
//! with per-attempt `(pc, position)` state deduplication, so work is
//! bounded by O(program × text) — pathological patterns stay fast and
//! empty-width repetitions (`(a*)*`) terminate with the right answer.
//! Escapes for *unimplemented* features (`\b`, `\A`, `\p{...}`) are
//! compile errors, never silent literals.

use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum RexError {
    #[error("unbalanced group in pattern")]
    UnbalancedGroup,
    #[error("unterminated character class")]
    UnterminatedClass,
    #[error("dangling repetition operator")]
    DanglingRepeat,
    #[error("bad repetition bounds")]
    BadRepeat,
    #[error("trailing backslash")]
    TrailingEscape,
    #[error("unsupported escape \\{0}")]
    UnsupportedEscape(char),
    #[error("pattern compiles to too large a program")]
    TooLarge,
}

/// One alternative of a character class.
#[derive(Debug, Clone, Copy, PartialEq)]
enum ClassItem {
    Ch(char),
    Range(char, char),
    Digit(bool),
    Word(bool),
    Space(bool),
}

impl ClassItem {
    fn matches(&self, c: char) -> bool {
        match *self {
            ClassItem::Ch(x) => c == x,
            ClassItem::Range(a, b) => a <= c && c <= b,
            ClassItem::Digit(want) => c.is_ascii_digit() == want,
            ClassItem::Word(want) => (c.is_alphanumeric() || c == '_') == want,
            ClassItem::Space(want) => c.is_whitespace() == want,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
struct Class {
    neg: bool,
    items: Vec<ClassItem>,
}

impl Class {
    fn matches(&self, c: char) -> bool {
        self.items.iter().any(|i| i.matches(c)) != self.neg
    }
}

/// Parsed pattern tree.
#[derive(Debug, Clone)]
enum Ast {
    Char(char),
    Any,
    Class(Class),
    Start,
    End,
    Seq(Vec<Ast>),
    Alt(Vec<Ast>),
    Repeat { node: Box<Ast>, min: u32, max: Option<u32> },
}

/// Compiled instruction.
#[derive(Debug, Clone, Copy)]
enum Inst {
    Char(char),
    /// `.` — any char except newline (the regex-crate default).
    Any,
    /// Any char *including* newline — only the unanchored-search
    /// prefix uses this, so a match after a newline is still found.
    AnyNl,
    Class(usize),
    Start,
    End,
    /// Try `a` first (greedy), then `b`.
    Split(usize, usize),
    Jmp(usize),
    Match,
}

/// A compiled pattern. Unanchored patterns carry a compiled-in leading
/// "try here, else advance one char" loop, so matching is always a
/// single VM run from position 0.
#[derive(Debug)]
pub struct Rex {
    prog: Vec<Inst>,
    classes: Vec<Class>,
}

/// Compiled-program size cap: nested bounded repeats (`(a{1000}){1000}`)
/// expand by copying, so growth is bounded explicitly.
const MAX_PROG: usize = 10_000;

/// Dense visited-set cutover: `program × (text + 1)` cells up to this
/// many (1 MiB of bytes) use a flat bitmap; larger products switch to a
/// hash set bounded by [`MAX_STATES`], so a huge pattern against a huge
/// string cannot allocate unboundedly.
const MAX_DENSE: usize = 1 << 20;

/// Sparse-mode cap on explored `(pc, position)` states; exceeding it
/// reports no-match rather than consuming unbounded memory/CPU.
const MAX_STATES: usize = 1 << 20;

/// Visited `(pc, position)` states for one match run.
enum Visited {
    Dense(Vec<bool>),
    Sparse(std::collections::HashSet<(u32, u32)>),
}

impl Visited {
    /// Record the state; `false` when already present (or the sparse
    /// cap is exhausted — the caller treats that as explored).
    fn insert(&mut self, pc: usize, i: usize, width: usize) -> bool {
        match self {
            Visited::Dense(v) => {
                let slot = &mut v[pc * width + i];
                !std::mem::replace(slot, true)
            }
            Visited::Sparse(set) => {
                if set.len() >= MAX_STATES {
                    return false;
                }
                set.insert((pc as u32, i as u32))
            }
        }
    }
}

impl Rex {
    pub fn new(pattern: &str) -> Result<Rex, RexError> {
        let chars: Vec<char> = pattern.chars().collect();
        let mut p = Parser { c: &chars, i: 0 };
        let ast = p.alt()?;
        if p.i != chars.len() {
            // Only an unmatched ')' can stop the parser early.
            return Err(RexError::UnbalancedGroup);
        }
        let anchored = matches!(
            &ast,
            Ast::Start
        ) || matches!(&ast, Ast::Seq(xs) if matches!(xs.first(), Some(Ast::Start)));
        let mut c = Compiler { prog: Vec::new(), classes: Vec::new() };
        if !anchored {
            // Unanchored search compiled into the program — one run
            // from position 0 covers every start offset (with state
            // dedup this is O(program × text) total, not per-start):
            //   0: Split(3, 1)   try the body here...
            //   1: Any           ...or consume one char
            //   2: Jmp 0         and retry at the next position
            c.prog.push(Inst::Split(3, 1));
            c.prog.push(Inst::AnyNl);
            c.prog.push(Inst::Jmp(0));
        }
        c.emit(&ast);
        c.prog.push(Inst::Match);
        if c.prog.len() > MAX_PROG {
            return Err(RexError::TooLarge);
        }
        Ok(Rex { prog: c.prog, classes: c.classes })
    }

    /// Does the pattern match anywhere in `text`? (Same contract as
    /// `regex::Regex::is_match`.)
    ///
    /// The VM deduplicates `(pc, position)` states, which both
    /// terminates empty-width repetition loops (`(a*)*`) with the
    /// correct answer and bounds the work to O(program × text) — no
    /// exponential backtracking. Memory is bounded too: a flat bitmap
    /// for ordinary sizes, a capped hash set beyond [`MAX_DENSE`].
    pub fn is_match(&self, text: &str) -> bool {
        let chars: Vec<char> = text.chars().collect();
        let width = chars.len() + 1;
        let cells = self.prog.len() * width;
        let mut visited = if cells <= MAX_DENSE {
            Visited::Dense(vec![false; cells])
        } else {
            Visited::Sparse(std::collections::HashSet::new())
        };
        self.run(&chars, &mut visited, width)
    }

    fn run(&self, chars: &[char], visited: &mut Visited, width: usize) -> bool {
        let mut stack: Vec<(usize, usize)> = vec![(0, 0)];
        while let Some((mut pc, mut i)) = stack.pop() {
            loop {
                if !visited.insert(pc, i, width) {
                    break; // state already explored (or state cap hit)
                }
                match self.prog[pc] {
                    Inst::Match => return true,
                    Inst::Jmp(t) => pc = t,
                    Inst::Split(a, b) => {
                        stack.push((b, i));
                        pc = a;
                    }
                    Inst::Start => {
                        if i == 0 {
                            pc += 1;
                        } else {
                            break;
                        }
                    }
                    Inst::End => {
                        if i == chars.len() {
                            pc += 1;
                        } else {
                            break;
                        }
                    }
                    Inst::Char(c) => {
                        if i < chars.len() && chars[i] == c {
                            pc += 1;
                            i += 1;
                        } else {
                            break;
                        }
                    }
                    Inst::Any => {
                        // `.` excludes newline, matching the regex
                        // crate's default (no `(?s)` flag).
                        if i < chars.len() && chars[i] != '\n' {
                            pc += 1;
                            i += 1;
                        } else {
                            break;
                        }
                    }
                    Inst::AnyNl => {
                        if i < chars.len() {
                            pc += 1;
                            i += 1;
                        } else {
                            break;
                        }
                    }
                    Inst::Class(k) => {
                        if i < chars.len() && self.classes[k].matches(chars[i]) {
                            pc += 1;
                            i += 1;
                        } else {
                            break;
                        }
                    }
                }
            }
        }
        false
    }
}

struct Parser<'a> {
    c: &'a [char],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<char> {
        self.c.get(self.i).copied()
    }

    fn alt(&mut self) -> Result<Ast, RexError> {
        let mut branches = vec![self.seq()?];
        while self.peek() == Some('|') {
            self.i += 1;
            branches.push(self.seq()?);
        }
        Ok(if branches.len() == 1 { branches.pop().unwrap() } else { Ast::Alt(branches) })
    }

    fn seq(&mut self) -> Result<Ast, RexError> {
        let mut items = Vec::new();
        while let Some(ch) = self.peek() {
            if ch == '|' || ch == ')' {
                break;
            }
            items.push(self.repeat()?);
        }
        Ok(if items.len() == 1 { items.pop().unwrap() } else { Ast::Seq(items) })
    }

    fn repeat(&mut self) -> Result<Ast, RexError> {
        let atom = self.atom()?;
        let (min, max) = match self.peek() {
            Some('*') => (0, None),
            Some('+') => (1, None),
            Some('?') => (0, Some(1)),
            Some('{') => {
                self.i += 1;
                let (min, max) = self.bounds()?;
                // Greediness suffix handled below; '{' consumed here.
                if self.peek() == Some('?') {
                    self.i += 1;
                }
                return Ok(Ast::Repeat { node: Box::new(atom), min, max });
            }
            _ => return Ok(atom),
        };
        self.i += 1;
        // Accept and ignore a lazy-quantifier suffix: acceptance
        // (`is_match`) is unaffected by greediness.
        if self.peek() == Some('?') {
            self.i += 1;
        }
        Ok(Ast::Repeat { node: Box::new(atom), min, max })
    }

    /// `{m}`, `{m,}`, `{m,n}` — the leading `{` is already consumed.
    fn bounds(&mut self) -> Result<(u32, Option<u32>), RexError> {
        let min = self.number().ok_or(RexError::BadRepeat)?;
        match self.peek() {
            Some('}') => {
                self.i += 1;
                Ok((min, Some(min)))
            }
            Some(',') => {
                self.i += 1;
                if self.peek() == Some('}') {
                    self.i += 1;
                    return Ok((min, None));
                }
                let max = self.number().ok_or(RexError::BadRepeat)?;
                if self.peek() != Some('}') || max < min {
                    return Err(RexError::BadRepeat);
                }
                self.i += 1;
                Ok((min, Some(max)))
            }
            _ => Err(RexError::BadRepeat),
        }
    }

    fn number(&mut self) -> Option<u32> {
        let start = self.i;
        while self.peek().map_or(false, |c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.i == start {
            return None;
        }
        // Cap at 1000 repetitions so compiled programs stay small.
        let n: u32 = self.c[start..self.i].iter().collect::<String>().parse().ok()?;
        if n > 1000 {
            None
        } else {
            Some(n)
        }
    }

    fn atom(&mut self) -> Result<Ast, RexError> {
        let ch = self.peek().ok_or(RexError::DanglingRepeat)?;
        match ch {
            '(' => {
                self.i += 1;
                // Non-capturing marker: we capture nothing anyway.
                if self.c[self.i..].starts_with(&['?', ':']) {
                    self.i += 2;
                }
                let inner = self.alt()?;
                if self.peek() != Some(')') {
                    return Err(RexError::UnbalancedGroup);
                }
                self.i += 1;
                Ok(inner)
            }
            '[' => {
                self.i += 1;
                self.class()
            }
            '.' => {
                self.i += 1;
                Ok(Ast::Any)
            }
            '^' => {
                self.i += 1;
                Ok(Ast::Start)
            }
            '$' => {
                self.i += 1;
                Ok(Ast::End)
            }
            '\\' => {
                self.i += 1;
                let esc = self.peek().ok_or(RexError::TrailingEscape)?;
                self.i += 1;
                Ok(match Self::shorthand(esc) {
                    Some(item) => Ast::Class(Class { neg: false, items: vec![item] }),
                    None => Ast::Char(Self::literal_escape(esc)?),
                })
            }
            '*' | '+' | '?' => Err(RexError::DanglingRepeat),
            _ => {
                self.i += 1;
                Ok(Ast::Char(ch))
            }
        }
    }

    fn shorthand(esc: char) -> Option<ClassItem> {
        match esc {
            'd' => Some(ClassItem::Digit(true)),
            'D' => Some(ClassItem::Digit(false)),
            'w' => Some(ClassItem::Word(true)),
            'W' => Some(ClassItem::Word(false)),
            's' => Some(ClassItem::Space(true)),
            'S' => Some(ClassItem::Space(false)),
            _ => None,
        }
    }

    /// A `\x` escape that is not a class shorthand. Escaped
    /// metacharacters and punctuation are literals; *unrecognized
    /// alphanumeric* escapes (`\b`, `\A`, `\p`, ...) are rejected so a
    /// pattern relying on unimplemented regex features fails loudly
    /// (the `regexp()` builtin turns that into ERROR) instead of
    /// silently matching the letter.
    fn literal_escape(esc: char) -> Result<char, RexError> {
        match esc {
            'n' => Ok('\n'),
            't' => Ok('\t'),
            'r' => Ok('\r'),
            c if c.is_ascii_alphanumeric() => Err(RexError::UnsupportedEscape(c)),
            other => Ok(other),
        }
    }

    /// Body of a character class; the leading `[` is already consumed.
    fn class(&mut self) -> Result<Ast, RexError> {
        let neg = self.peek() == Some('^');
        if neg {
            self.i += 1;
        }
        let mut items = Vec::new();
        // A `]` first in the class is a literal.
        if self.peek() == Some(']') {
            items.push(ClassItem::Ch(']'));
            self.i += 1;
        }
        loop {
            let ch = self.peek().ok_or(RexError::UnterminatedClass)?;
            if ch == ']' {
                self.i += 1;
                return Ok(Ast::Class(Class { neg, items }));
            }
            self.i += 1;
            let lo = if ch == '\\' {
                let esc = self.peek().ok_or(RexError::TrailingEscape)?;
                self.i += 1;
                if let Some(item) = Self::shorthand(esc) {
                    items.push(item);
                    continue;
                }
                Self::literal_escape(esc)?
            } else {
                ch
            };
            // Range `a-z` (a trailing `-` is a literal).
            if self.peek() == Some('-')
                && self.c.get(self.i + 1).map_or(false, |&c| c != ']')
            {
                self.i += 1;
                let hi = self.peek().ok_or(RexError::UnterminatedClass)?;
                self.i += 1;
                let hi = if hi == '\\' {
                    let esc = self.peek().ok_or(RexError::TrailingEscape)?;
                    self.i += 1;
                    Self::literal_escape(esc)?
                } else {
                    hi
                };
                items.push(ClassItem::Range(lo.min(hi), lo.max(hi)));
            } else {
                items.push(ClassItem::Ch(lo));
            }
        }
    }
}

struct Compiler {
    prog: Vec<Inst>,
    classes: Vec<Class>,
}

impl Compiler {
    fn emit(&mut self, ast: &Ast) {
        // Stop growing once over the cap; `Rex::new` then reports
        // TooLarge (the truncated program is never used).
        if self.prog.len() > MAX_PROG {
            return;
        }
        match ast {
            Ast::Char(c) => self.prog.push(Inst::Char(*c)),
            Ast::Any => self.prog.push(Inst::Any),
            Ast::Start => self.prog.push(Inst::Start),
            Ast::End => self.prog.push(Inst::End),
            Ast::Class(cl) => {
                self.classes.push(cl.clone());
                self.prog.push(Inst::Class(self.classes.len() - 1));
            }
            Ast::Seq(items) => {
                for x in items {
                    self.emit(x);
                }
            }
            Ast::Alt(branches) => {
                // split b1, (split b2, (... bn)); each branch jumps out.
                let mut jumps = Vec::new();
                for (k, br) in branches.iter().enumerate() {
                    if k + 1 < branches.len() {
                        let split_at = self.prog.len();
                        self.prog.push(Inst::Split(0, 0)); // patched below
                        self.emit(br);
                        jumps.push(self.prog.len());
                        self.prog.push(Inst::Jmp(0)); // patched below
                        let next = self.prog.len();
                        self.prog[split_at] = Inst::Split(split_at + 1, next);
                    } else {
                        self.emit(br);
                    }
                }
                let end = self.prog.len();
                for j in jumps {
                    self.prog[j] = Inst::Jmp(end);
                }
            }
            Ast::Repeat { node, min, max } => {
                for _ in 0..*min {
                    self.emit(node);
                }
                match max {
                    None => {
                        // Greedy star over the remaining copies.
                        let loop_at = self.prog.len();
                        self.prog.push(Inst::Split(0, 0)); // patched
                        self.emit(node);
                        self.prog.push(Inst::Jmp(loop_at));
                        let after = self.prog.len();
                        self.prog[loop_at] = Inst::Split(loop_at + 1, after);
                    }
                    Some(max) => {
                        // (max - min) nested optional copies.
                        let mut splits = Vec::new();
                        for _ in *min..*max {
                            splits.push(self.prog.len());
                            self.prog.push(Inst::Split(0, 0)); // patched
                            self.emit(node);
                        }
                        let after = self.prog.len();
                        for s in splits {
                            self.prog[s] = Inst::Split(s + 1, after);
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(pat: &str, text: &str) -> bool {
        Rex::new(pat).unwrap().is_match(text)
    }

    #[test]
    fn literals_and_anchors() {
        assert!(m("abc", "xxabcxx"));
        assert!(!m("abc", "ab"));
        assert!(m("^abc", "abcdef"));
        assert!(!m("^abc", "xabc"));
        assert!(m("def$", "abcdef"));
        assert!(!m("def$", "defabc"));
        assert!(m("^$", ""));
        assert!(!m("^$", "x"));
    }

    #[test]
    fn the_paper_hostname_pattern() {
        // The pattern the eval tests use against the paper's hostname.
        assert!(m("^hu.*gov$", "hugo.mcs.anl.gov"));
        assert!(!m("^hu.*gov$", "comet.xyz.com"));
    }

    #[test]
    fn dot_star_plus_question() {
        assert!(m("a.c", "abc"));
        assert!(!m("a.c", "ac"));
        assert!(m("ab*c", "ac"));
        assert!(m("ab*c", "abbbc"));
        assert!(m("ab+c", "abbc"));
        assert!(!m("ab+c", "ac"));
        assert!(m("ab?c", "ac"));
        assert!(m("ab?c", "abc"));
        assert!(!m("ab?c", "abbc"));
    }

    #[test]
    fn classes_and_shorthands() {
        assert!(m("[a-c]+", "zzabz"));
        assert!(!m("^[a-c]+$", "abd"));
        assert!(m("[^0-9]", "a"));
        assert!(!m("^[^0-9]+$", "a1"));
        assert!(m(r"\d+", "run42"));
        assert!(!m(r"^\d+$", "run42"));
        assert!(m(r"\w+", "a_b9"));
        assert!(m(r"\s", "a b"));
        assert!(m(r"[\d]", "7"));
        assert!(m("[]a]", "]"));
    }

    #[test]
    fn alternation_and_groups() {
        assert!(m("cat|dog", "hotdog"));
        assert!(!m("^(cat|dog)$", "cow"));
        assert!(m("^(ab)+$", "ababab"));
        assert!(!m("^(ab)+$", "aba"));
        assert!(m("^(?:gsi)?ftp$", "ftp"));
        assert!(m("^(?:gsi)?ftp$", "gsiftp"));
    }

    #[test]
    fn bounded_repetition() {
        assert!(m("^a{3}$", "aaa"));
        assert!(!m("^a{3}$", "aa"));
        assert!(m("^a{2,}$", "aaaa"));
        assert!(!m("^a{2,}$", "a"));
        assert!(m("^a{1,3}$", "aa"));
        assert!(!m("^a{1,3}$", "aaaa"));
    }

    #[test]
    fn escaped_metacharacters() {
        assert!(m(r"^a\.b$", "a.b"));
        assert!(!m(r"^a\.b$", "axb"));
        assert!(m(r"\$", "cost$"));
        assert!(m(r"\\", r"a\b"));
    }

    #[test]
    fn bad_patterns_are_errors() {
        assert!(Rex::new("(ab").is_err());
        assert!(Rex::new("ab)").is_err());
        assert!(Rex::new("[ab").is_err());
        assert!(Rex::new("*a").is_err());
        assert!(Rex::new("a{2,1}").is_err());
        assert!(Rex::new("a\\").is_err());
    }

    #[test]
    fn pathological_pattern_terminates_correctly() {
        // Classic exponential backtracker: state dedup makes it
        // polynomial, and the answers stay right in both directions.
        let re = Rex::new("^(a+)+$").unwrap();
        assert!(!re.is_match(&("a".repeat(40) + "b")));
        assert!(re.is_match(&"a".repeat(40)));
    }

    #[test]
    fn nullable_repetition_still_matches() {
        // An unbounded repeat over a nullable body must not spin on
        // empty-width iterations.
        assert!(m("^(a*)*$", "aaa"));
        assert!(m("^(a*)*$", ""));
        assert!(m("^(a?)+$", "aa"));
        assert!(m("^(a|)+$", "aa"));
        assert!(!m("^(a*)*$", "aab"));
    }

    #[test]
    fn unsupported_escapes_are_errors_not_literals() {
        // regex-crate features we do not implement must fail loudly
        // (the regexp() builtin maps this to ERROR), never silently
        // match the letter.
        assert_eq!(Rex::new(r"\bgov\b").unwrap_err(), RexError::UnsupportedEscape('b'));
        assert!(Rex::new(r"\A").is_err());
        assert!(Rex::new(r"\p").is_err());
        assert!(Rex::new(r"[\z]").is_err());
    }

    #[test]
    fn oversized_programs_are_rejected() {
        assert_eq!(Rex::new("(a{1000}){1000}").unwrap_err(), RexError::TooLarge);
    }

    #[test]
    fn unicode_text() {
        assert!(m("π+", "ππ"));
        assert!(m("^.$", "π"));
    }

    #[test]
    fn dot_excludes_newline_but_search_crosses_it() {
        // regex-crate default: `.` does not match \n ...
        assert!(!m("^a.c$", "a\nc"));
        assert!(m("^a.c$", "abc"));
        // ... but unanchored search still finds matches past one.
        assert!(m("abc", "x\nabc"));
    }
}
