//! Property-test runner (proptest substitute, offline image).
//!
//! Runs a property over many seeded random cases; on failure reports the
//! failing seed so the case can be replayed deterministically:
//!
//! ```
//! use globus_replica::util::prop::{forall, Config};
//! forall("addition commutes", Config::default(), |rng| {
//!     let (a, b) = (rng.below(1000) as i64, rng.below(1000) as i64);
//!     if a + b != b + a {
//!         return Err(format!("{a} + {b}"));
//!     }
//!     Ok(())
//! });
//! ```

use super::prng::Rng;

/// Runner configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases.
    pub cases: u64,
    /// Base seed; case `i` runs with seed `base_seed + i`. Override with
    /// env `PROP_SEED` to replay a failure.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let base_seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xDA7A_621D);
        let cases = std::env::var("PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(256);
        Config { cases, base_seed }
    }
}

/// Run `property` over `cfg.cases` seeded cases; panics (with the seed)
/// on the first failure. The property returns `Err(description)` to
/// fail, `Ok(())` to pass.
pub fn forall<F>(name: &str, cfg: Config, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for i in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(i);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property {name:?} failed on case {i} (replay with PROP_SEED={seed} PROP_CASES=1): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall("trivial", Config { cases: 16, base_seed: 1 }, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "replay with PROP_SEED=")]
    fn reports_seed_on_failure() {
        forall("fails", Config { cases: 4, base_seed: 7 }, |_| Err("nope".into()));
    }
}
