//! Tiny CLI argument parser (clap substitute, offline image).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generates usage text from declared options.

use std::collections::HashMap;

/// Parsed arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // Value-style if next token isn't another option.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(body.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(body.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment, skipping argv[0].
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => default,
            None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn flags_and_values() {
        let a = parse("--sites 12 --verbose --seed=42 run extra");
        assert_eq!(a.u64_or("sites", 0), 12);
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.u64_or("seed", 0), 42);
        assert_eq!(a.positional(), &["run".to_string(), "extra".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.u64_or("sites", 7), 7);
        assert_eq!(a.str_or("mode", "sim"), "sim");
        assert!(!a.bool_or("verbose", false));
    }

    #[test]
    fn double_dash_value_not_swallowed() {
        let a = parse("--flag --other 3");
        assert!(a.has("flag"));
        assert_eq!(a.u64_or("other", 0), 3);
    }
}
