//! The PJRT engine: compile-once execution of the AOT artifacts.
//!
//! The `xla` crate's handles are `Rc`-based (not `Send`), so the
//! thread-safe face of the runtime is [`EngineHandle`]: a dedicated
//! worker thread owns the [`Engine`] and serves forecast/rank calls
//! over channels. The broker clones the handle freely across client
//! threads; the executable is still compiled exactly once.

use std::sync::mpsc;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::artifacts::Manifest;

/// Output of the forecast entry point for `n` real sites.
#[derive(Debug, Clone)]
pub struct ForecastOutput {
    /// [n][P] every forecaster's prediction.
    pub preds: Vec<Vec<f32>>,
    /// [n][P] every forecaster's backtest MSE.
    pub mses: Vec<Vec<f32>>,
    /// [n] the min-MSE forecaster's prediction.
    pub best: Vec<f32>,
    /// [n] load-discounted effective bandwidth.
    pub eff: Vec<f32>,
}

/// Output of the rank entry point for `q` requests over `r` replicas.
#[derive(Debug, Clone)]
pub struct RankOutput {
    /// [q][r] scores (-inf = infeasible).
    pub scores: Vec<Vec<f32>>,
    /// [q] winner index (meaningless when best_score is -inf).
    pub best_idx: Vec<i32>,
    /// [q] winner score.
    pub best_score: Vec<f32>,
}

struct LoadedEntry {
    exe: xla::PjRtLoadedExecutable,
    inputs: Vec<(Vec<usize>, String)>,
}

/// The engine: a shared CPU PJRT client plus one compiled executable
/// per artifact entry.
pub struct Engine {
    manifest: Manifest,
    forecast: LoadedEntry,
    rank: LoadedEntry,
    /// AOT shapes.
    pub aot_sites: usize,
    pub aot_window: usize,
    pub aot_replicas: usize,
    pub aot_requests: usize,
    pub aot_attrs: usize,
    pub num_predictors: usize,
}

fn load_entry(client: &xla::PjRtClient, manifest: &Manifest, name: &str) -> Result<LoadedEntry> {
    let spec = manifest
        .entry(name)
        .with_context(|| format!("manifest has no entry {name:?}"))?;
    let path = spec
        .file
        .to_str()
        .context("artifact path not utf-8")?
        .to_string();
    let proto = xla::HloModuleProto::from_text_file(&path)
        .with_context(|| format!("parsing HLO text {path}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client
        .compile(&comp)
        .with_context(|| format!("PJRT compile of {name}"))?;
    Ok(LoadedEntry {
        exe,
        inputs: spec
            .inputs
            .iter()
            .map(|t| (t.shape.clone(), t.dtype.clone()))
            .collect(),
    })
}

impl Engine {
    /// Load from the default artifact directory.
    pub fn load_default() -> Result<Engine> {
        Self::load(Manifest::default_dir())
    }

    /// Load + compile both entry points.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let forecast = load_entry(&client, &manifest, "forecast")?;
        let rank = load_entry(&client, &manifest, "rank")?;
        let fin = &forecast.inputs;
        let rin = &rank.inputs;
        let (aot_sites, aot_window) = (fin[0].0[0], fin[0].0[1]);
        let (aot_replicas, aot_attrs) = (rin[0].0[0], rin[0].0[1]);
        let aot_requests = rin[1].0[0];
        let num_predictors = manifest.num_predictors;
        Ok(Engine {
            manifest,
            forecast,
            rank,
            aot_sites,
            aot_window,
            aot_replicas,
            aot_requests,
            aot_attrs,
            num_predictors,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Run the forecast artifact over `n = hist.len()` sites, each with
    /// up to `aot_window` trailing observations (shorter histories are
    /// left-padded with masked slots). `n` may exceed `aot_sites`; the
    /// engine batches in AOT-sized chunks.
    pub fn forecast(&self, hist: &[Vec<f64>], load: &[f64]) -> Result<ForecastOutput> {
        if hist.len() != load.len() {
            bail!("hist ({}) and load ({}) disagree", hist.len(), load.len());
        }
        let n = hist.len();
        let (s, w, p) = (self.aot_sites, self.aot_window, self.num_predictors);
        let mut out = ForecastOutput {
            preds: Vec::with_capacity(n),
            mses: Vec::with_capacity(n),
            best: Vec::with_capacity(n),
            eff: Vec::with_capacity(n),
        };
        for chunk_start in (0..n).step_by(s) {
            let chunk = &hist[chunk_start..(chunk_start + s).min(n)];
            let loads = &load[chunk_start..(chunk_start + s).min(n)];
            let mut h = vec![0f32; s * w];
            let mut m = vec![0f32; s * w];
            let mut l = vec![0f32; s];
            for (i, series) in chunk.iter().enumerate() {
                let take = series.len().min(w);
                let src = &series[series.len() - take..];
                // Right-align the observations: oldest first at w-take.
                for (j, &v) in src.iter().enumerate() {
                    h[i * w + (w - take) + j] = v as f32;
                    m[i * w + (w - take) + j] = 1.0;
                }
                l[i] = loads[i].clamp(0.0, 1.0) as f32;
            }
            let lit_h = xla::Literal::vec1(&h).reshape(&[s as i64, w as i64])?;
            let lit_m = xla::Literal::vec1(&m).reshape(&[s as i64, w as i64])?;
            let lit_l = xla::Literal::vec1(&l);
            let result = self.forecast.exe.execute::<xla::Literal>(&[lit_h, lit_m, lit_l])?;
            let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
            let [preds, mses, best, eff]: [xla::Literal; 4] = tuple
                .try_into()
                .map_err(|_| anyhow::anyhow!("forecast artifact returned wrong arity"))?;
            let preds = preds.to_vec::<f32>()?;
            let mses = mses.to_vec::<f32>()?;
            let best = best.to_vec::<f32>()?;
            let eff = eff.to_vec::<f32>()?;
            for i in 0..chunk.len() {
                out.preds.push(preds[i * p..(i + 1) * p].to_vec());
                out.mses.push(mses[i * p..(i + 1) * p].to_vec());
                out.best.push(best[i]);
                out.eff.push(eff[i]);
            }
        }
        Ok(out)
    }

    /// Run the rank artifact: `attrs` is `r x a` (r ≤ aot_replicas per
    /// call — the engine chunks), constraints and weights are `q x a`
    /// with `q ≤ aot_requests`. Padded replica rows are filled with an
    /// out-of-range sentinel so they can never win.
    pub fn rank(
        &self,
        attrs: &[Vec<f64>],
        lo: &[Vec<f64>],
        hi: &[Vec<f64>],
        weights: &[Vec<f64>],
    ) -> Result<RankOutput> {
        let (r_aot, q_aot, a) = (self.aot_replicas, self.aot_requests, self.aot_attrs);
        let q = lo.len();
        if q == 0 || q > q_aot {
            bail!("rank supports 1..={q_aot} requests, got {q}");
        }
        if hi.len() != q || weights.len() != q {
            bail!("lo/hi/weights arity mismatch");
        }
        for row in attrs {
            if row.len() > a {
                bail!("attribute row wider ({}) than AOT width {a}", row.len());
            }
        }
        let n = attrs.len();
        let mut scores: Vec<Vec<f32>> = vec![Vec::with_capacity(n); q];
        const SENTINEL: f32 = -1e30;
        for chunk_start in (0..n.max(1)).step_by(r_aot) {
            let chunk_end = (chunk_start + r_aot).min(n);
            let mut am = vec![SENTINEL; r_aot * a];
            for (i, row) in attrs[chunk_start..chunk_end].iter().enumerate() {
                for (j, &v) in row.iter().enumerate() {
                    am[i * a + j] = v as f32;
                }
                // Unspecified trailing attrs default to 0 (in range for
                // unconstrained requests).
                for j in row.len()..a {
                    am[i * a + j] = 0.0;
                }
            }
            let fill = |rows: &[Vec<f64>], default: f32| -> Vec<f32> {
                let mut m = vec![default; q_aot * a];
                for (i, row) in rows.iter().enumerate() {
                    for j in 0..a {
                        m[i * a + j] = row.get(j).copied().unwrap_or(default as f64) as f32;
                    }
                }
                m
            };
            let lom = fill(lo, -1e30);
            let him = fill(hi, 1e30);
            let wm = fill(weights, 0.0);
            let mk = |v: &[f32], d0: usize| -> Result<xla::Literal> {
                Ok(xla::Literal::vec1(v).reshape(&[d0 as i64, a as i64])?)
            };
            let result = self.rank.exe.execute::<xla::Literal>(&[
                mk(&am, r_aot)?,
                mk(&lom, q_aot)?,
                mk(&him, q_aot)?,
                mk(&wm, q_aot)?,
            ])?;
            let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
            let [sc, _bi, _bs]: [xla::Literal; 3] = tuple
                .try_into()
                .map_err(|_| anyhow::anyhow!("rank artifact returned wrong arity"))?;
            let sc = sc.to_vec::<f32>()?;
            for qi in 0..q {
                scores[qi].extend(&sc[qi * r_aot..qi * r_aot + (chunk_end - chunk_start)]);
            }
        }
        // Recompute winners over the real (unpadded) score rows.
        let mut best_idx = Vec::with_capacity(q);
        let mut best_score = Vec::with_capacity(q);
        for row in &scores {
            let (mut bi, mut bs) = (0i32, f32::NEG_INFINITY);
            for (i, &v) in row.iter().enumerate() {
                if v > bs {
                    bs = v;
                    bi = i as i32;
                }
            }
            best_idx.push(bi);
            best_score.push(bs);
        }
        Ok(RankOutput { scores, best_idx, best_score })
    }
}

// ---------------------------------------------------------------------------
// Thread-safe handle
// ---------------------------------------------------------------------------

enum Job {
    Forecast {
        hist: Vec<Vec<f64>>,
        load: Vec<f64>,
        reply: mpsc::Sender<Result<ForecastOutput>>,
    },
    Rank {
        attrs: Vec<Vec<f64>>,
        lo: Vec<Vec<f64>>,
        hi: Vec<Vec<f64>>,
        weights: Vec<Vec<f64>>,
        reply: mpsc::Sender<Result<RankOutput>>,
    },
}

/// `Send + Sync` face of the engine: requests are serialized through a
/// worker thread that owns the non-`Send` PJRT handles.
pub struct EngineHandle {
    tx: Mutex<mpsc::Sender<Job>>,
    pub aot_sites: usize,
    pub aot_window: usize,
    pub num_predictors: usize,
}

impl EngineHandle {
    /// Load + compile the artifacts on a dedicated worker thread.
    pub fn spawn(dir: impl AsRef<std::path::Path>) -> Result<std::sync::Arc<EngineHandle>> {
        let dir = dir.as_ref().to_path_buf();
        let (tx, rx) = mpsc::channel::<Job>();
        let (boot_tx, boot_rx) = mpsc::channel::<Result<(usize, usize, usize)>>();
        std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                let engine = match Engine::load(&dir) {
                    Ok(e) => {
                        let _ = boot_tx.send(Ok((e.aot_sites, e.aot_window, e.num_predictors)));
                        e
                    }
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Forecast { hist, load, reply } => {
                            let _ = reply.send(engine.forecast(&hist, &load));
                        }
                        Job::Rank { attrs, lo, hi, weights, reply } => {
                            let _ = reply.send(engine.rank(&attrs, &lo, &hi, &weights));
                        }
                    }
                }
            })
            .context("spawning engine worker")?;
        let (aot_sites, aot_window, num_predictors) =
            boot_rx.recv().context("engine worker died during load")??;
        Ok(std::sync::Arc::new(EngineHandle {
            tx: Mutex::new(tx),
            aot_sites,
            aot_window,
            num_predictors,
        }))
    }

    /// Spawn from the default artifact directory.
    pub fn spawn_default() -> Result<std::sync::Arc<EngineHandle>> {
        Self::spawn(Manifest::default_dir())
    }

    /// See [`Engine::forecast`].
    pub fn forecast(&self, hist: &[Vec<f64>], load: &[f64]) -> Result<ForecastOutput> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::Forecast { hist: hist.to_vec(), load: load.to_vec(), reply })
            .context("engine worker gone")?;
        rx.recv().context("engine worker dropped reply")?
    }

    /// See [`Engine::rank`].
    pub fn rank(
        &self,
        attrs: &[Vec<f64>],
        lo: &[Vec<f64>],
        hi: &[Vec<f64>],
        weights: &[Vec<f64>],
    ) -> Result<RankOutput> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::Rank {
                attrs: attrs.to_vec(),
                lo: lo.to_vec(),
                hi: hi.to_vec(),
                weights: weights.to_vec(),
                reply,
            })
            .context("engine worker gone")?;
        rx.recv().context("engine worker dropped reply")?
    }
}
