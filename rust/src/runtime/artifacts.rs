//! Artifact manifest: shapes/dtypes of the AOT entry points, written by
//! `python/compile/aot.py` and validated here before anything loads.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One tensor's declared shape.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT entry point.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<EntrySpec>,
    pub predictor_names: Vec<String>,
    pub num_predictors: usize,
}

fn tensor_list(v: &Json) -> Result<Vec<TensorSpec>> {
    let arr = v.as_arr().context("expected tensor array")?;
    arr.iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .context("tensor missing name")?
                    .to_string(),
                shape: t
                    .get("shape")
                    .and_then(Json::as_arr)
                    .context("tensor missing shape")?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                dtype: t
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("f32")
                    .to_string(),
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;
        if v.get("interchange").and_then(Json::as_str) != Some("hlo-text") {
            bail!("manifest interchange format is not hlo-text");
        }
        let mut entries = Vec::new();
        let emap = v
            .get("entries")
            .and_then(Json::as_obj)
            .context("manifest missing entries")?;
        for (name, e) in emap {
            let file = dir.join(
                e.get("file")
                    .and_then(Json::as_str)
                    .context("entry missing file")?,
            );
            if !file.exists() {
                bail!("artifact file {file:?} missing — run `make artifacts`");
            }
            entries.push(EntrySpec {
                name: name.clone(),
                file,
                inputs: tensor_list(e.get("inputs").context("entry missing inputs")?)?,
                outputs: tensor_list(e.get("outputs").context("entry missing outputs")?)?,
            });
        }
        let bank = v.get("predictor_bank").context("manifest missing predictor_bank")?;
        let predictor_names: Vec<String> = bank
            .get("names")
            .and_then(Json::as_arr)
            .context("bank missing names")?
            .iter()
            .filter_map(|n| n.as_str().map(|s| s.to_string()))
            .collect();
        let num_predictors = bank
            .get("num_predictors")
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as usize;
        if predictor_names.len() != num_predictors {
            bail!(
                "bank names ({}) disagree with num_predictors ({num_predictors})",
                predictor_names.len()
            );
        }
        Ok(Manifest { dir, entries, predictor_names, num_predictors })
    }

    pub fn entry(&self, name: &str) -> Option<&EntrySpec> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// The default artifact directory: `$ARTIFACTS_DIR` or
    /// `<repo-root>/artifacts` discovered relative to the executable's
    /// cwd.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("ARTIFACTS_DIR") {
            return PathBuf::from(d);
        }
        // Walk up from cwd looking for artifacts/manifest.json.
        let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        for _ in 0..5 {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand;
            }
            if !cur.pop() {
                break;
            }
        }
        PathBuf::from("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINIMAL: &str = r#"{
        "version": 1,
        "interchange": "hlo-text",
        "predictor_bank": {"num_predictors": 2, "names": ["a", "b"],
                           "window_short": 4, "window_long": 16,
                           "ema_alphas": [0.1]},
        "entries": {
            "toy": {
                "file": "toy.hlo.txt",
                "sha256": "x",
                "inputs": [{"name": "x", "shape": [2, 3], "dtype": "f32"}],
                "outputs": [{"name": "y", "shape": [2], "dtype": "f32"}]
            }
        }
    }"#;

    fn write_minimal(dir: &Path) {
        std::fs::write(dir.join("manifest.json"), MINIMAL).unwrap();
        std::fs::write(dir.join("toy.hlo.txt"), "HloModule toy").unwrap();
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("gr-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_minimal(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.num_predictors, 2);
        let e = m.entry("toy").unwrap();
        assert_eq!(e.inputs[0].shape, vec![2, 3]);
        assert_eq!(e.inputs[0].elements(), 6);
        assert!(m.entry("nope").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_artifact_file_rejected() {
        let dir = std::env::temp_dir().join(format!("gr-manifest2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), MINIMAL).unwrap();
        // no toy.hlo.txt
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = Manifest::load("/definitely/not/here").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Soft test: exercises the real artifacts when present.
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.entry("forecast").is_some());
            assert!(m.entry("rank").is_some());
            assert_eq!(m.num_predictors, 8);
        }
    }
}
