//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts and runs
//! them on the broker's hot path.
//!
//! The Python side (`make artifacts`) lowers the L2 graphs to HLO
//! *text*; this module parses the text with
//! `HloModuleProto::from_text_file`, compiles once per entry point on a
//! shared `PjRtClient::cpu()`, and exposes typed `forecast` / `rank`
//! calls with automatic padding to the AOT shapes. Python never runs at
//! request time.

pub mod artifacts;
pub mod engine;

pub use artifacts::Manifest;
pub use engine::{Engine, ForecastOutput, RankOutput};
