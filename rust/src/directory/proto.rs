//! Wire protocol for GRIS/GIIS over TCP.
//!
//! Line-oriented, tab-separated (DNs and filters contain spaces):
//!
//! ```text
//! C: SEARCH\t<base dn>\t<scope>\t<filter>
//! S: OK\t<n>
//! S: <LDIF stream, entries separated by blank lines>
//! S: .
//!
//! C: REGISTER\t<site>\t<host:port>\t<base dn>\t<k=v;k=v;...>[\t<ttl secs>]
//! S: OK\t0
//! S: .
//!
//! C: DISCOVER\t<filter>          (GIIS only)
//! C: LIST                        (GIIS only: all registrations)
//! C: PING                        -> PONG
//! C: QUIT
//! ```
//!
//! Errors: `ERR\t<message>` followed by `.`.

use thiserror::Error;

use super::dit::Scope;
use super::entry::Dn;
use super::filter::Filter;

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Search { base: Dn, scope: Scope, filter: Filter },
    Register {
        site: String,
        addr: String,
        base: Dn,
        summary: Vec<(String, String)>,
        /// Soft-state lifetime in simulated seconds (`None` = server
        /// default).
        ttl: Option<f64>,
    },
    Discover { filter: Filter },
    List,
    Ping,
    Quit,
}

#[derive(Debug, Error, PartialEq)]
pub enum ProtoError {
    #[error("empty request")]
    Empty,
    #[error("unknown verb {0:?}")]
    UnknownVerb(String),
    #[error("wrong number of fields for {0}")]
    Arity(&'static str),
    #[error("bad dn: {0}")]
    BadDn(String),
    #[error("bad scope {0:?}")]
    BadScope(String),
    #[error("bad filter: {0}")]
    BadFilter(String),
    #[error("bad ttl (want a positive number of seconds)")]
    BadTtl,
}

impl Request {
    /// Parse one request line.
    pub fn parse(line: &str) -> Result<Request, ProtoError> {
        let line = line.trim_end_matches(['\r', '\n']);
        if line.is_empty() {
            return Err(ProtoError::Empty);
        }
        let fields: Vec<&str> = line.split('\t').collect();
        match fields[0].to_ascii_uppercase().as_str() {
            "SEARCH" => {
                if fields.len() != 4 {
                    return Err(ProtoError::Arity("SEARCH"));
                }
                let base = Dn::parse(fields[1]).map_err(|e| ProtoError::BadDn(e.to_string()))?;
                let scope =
                    Scope::parse(fields[2]).ok_or_else(|| ProtoError::BadScope(fields[2].into()))?;
                let filter = Filter::parse(fields[3])
                    .map_err(|e| ProtoError::BadFilter(e.to_string()))?;
                Ok(Request::Search { base, scope, filter })
            }
            "REGISTER" => {
                if fields.len() != 5 && fields.len() != 6 {
                    return Err(ProtoError::Arity("REGISTER"));
                }
                let base = Dn::parse(fields[3]).map_err(|e| ProtoError::BadDn(e.to_string()))?;
                let summary = fields[4]
                    .split(';')
                    .filter(|s| !s.is_empty())
                    .filter_map(|kv| kv.split_once('=').map(|(k, v)| (k.into(), v.into())))
                    .collect();
                // `inf` is a legal lifetime (never expires — the same
                // convention as the in-process soft-state model); only
                // NaN and non-positive values are malformed.
                let ttl = match fields.get(5) {
                    None => None,
                    Some(t) => Some(
                        t.parse::<f64>()
                            .ok()
                            .filter(|v| !v.is_nan() && *v > 0.0)
                            .ok_or(ProtoError::BadTtl)?,
                    ),
                };
                Ok(Request::Register {
                    site: fields[1].to_string(),
                    addr: fields[2].to_string(),
                    base,
                    summary,
                    ttl,
                })
            }
            "DISCOVER" => {
                if fields.len() != 2 {
                    return Err(ProtoError::Arity("DISCOVER"));
                }
                let filter = Filter::parse(fields[1])
                    .map_err(|e| ProtoError::BadFilter(e.to_string()))?;
                Ok(Request::Discover { filter })
            }
            "LIST" => Ok(Request::List),
            "PING" => Ok(Request::Ping),
            "QUIT" => Ok(Request::Quit),
            other => Err(ProtoError::UnknownVerb(other.to_string())),
        }
    }

    /// Serialize a request to its wire line.
    pub fn encode(&self) -> String {
        match self {
            Request::Search { base, scope, filter } => {
                format!("SEARCH\t{base}\t{}\t{filter}\n", scope.as_str())
            }
            Request::Register { site, addr, base, summary, ttl } => {
                let kv = summary
                    .iter()
                    .map(|(k, v)| format!("{k}={v}"))
                    .collect::<Vec<_>>()
                    .join(";");
                match ttl {
                    Some(t) => format!("REGISTER\t{site}\t{addr}\t{base}\t{kv}\t{t}\n"),
                    None => format!("REGISTER\t{site}\t{addr}\t{base}\t{kv}\n"),
                }
            }
            Request::Discover { filter } => format!("DISCOVER\t{filter}\n"),
            Request::List => "LIST\n".to_string(),
            Request::Ping => "PING\n".to_string(),
            Request::Quit => "QUIT\n".to_string(),
        }
    }
}

/// Terminator line closing every response body.
pub const END_MARK: &str = ".";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn search_round_trip() {
        let r = Request::Search {
            base: Dn::parse("ou=mcs, o=anl, o=grid").unwrap(),
            scope: Scope::Sub,
            filter: Filter::parse("(&(objectClass=Grid*)(availableSpace>=5))").unwrap(),
        };
        let line = r.encode();
        assert_eq!(Request::parse(&line).unwrap(), r);
    }

    #[test]
    fn register_round_trip() {
        let r = Request::Register {
            site: "mcs".into(),
            addr: "127.0.0.1:9000".into(),
            base: Dn::parse("ou=mcs, o=anl, o=grid").unwrap(),
            summary: vec![("storageType".into(), "disk".into()), ("x".into(), "1".into())],
            ttl: None,
        };
        assert_eq!(Request::parse(&r.encode()).unwrap(), r);
        let with_ttl = Request::Register {
            site: "mcs".into(),
            addr: "127.0.0.1:9000".into(),
            base: Dn::parse("ou=mcs, o=anl, o=grid").unwrap(),
            summary: vec![],
            ttl: Some(120.0),
        };
        assert_eq!(Request::parse(&with_ttl.encode()).unwrap(), with_ttl);
        // Infinite TTL (= never expires) survives the wire round trip.
        let forever = Request::Register {
            site: "mcs".into(),
            addr: "a:1".into(),
            base: Dn::parse("o=grid").unwrap(),
            summary: vec![],
            ttl: Some(f64::INFINITY),
        };
        assert_eq!(Request::parse(&forever.encode()).unwrap(), forever);
        assert!(matches!(
            Request::parse("REGISTER\tmcs\ta:1\to=grid\t\t-5"),
            Err(ProtoError::BadTtl)
        ));
        assert!(matches!(
            Request::parse("REGISTER\tmcs\ta:1\to=grid\t\tNaN"),
            Err(ProtoError::BadTtl)
        ));
    }

    #[test]
    fn simple_verbs() {
        assert_eq!(Request::parse("PING").unwrap(), Request::Ping);
        assert_eq!(Request::parse("LIST\n").unwrap(), Request::List);
        assert_eq!(Request::parse("quit").unwrap(), Request::Quit);
    }

    #[test]
    fn errors() {
        assert_eq!(Request::parse(""), Err(ProtoError::Empty));
        assert!(matches!(Request::parse("NOPE\tx"), Err(ProtoError::UnknownVerb(_))));
        assert!(matches!(Request::parse("SEARCH\tb"), Err(ProtoError::Arity(_))));
        assert!(matches!(
            Request::parse("SEARCH\to=grid\tbogus\t(a=*)"),
            Err(ProtoError::BadScope(_))
        ));
        assert!(matches!(
            Request::parse("SEARCH\to=grid\tsub\t(((("),
            Err(ProtoError::BadFilter(_))
        ));
    }
}
