//! LDAP search filters (RFC 2254 subset): `(&(objectClass=GridStorage*)
//! (availableSpace>=5368709120))`, with `&`, `|`, `!`, equality,
//! `>=`, `<=`, presence (`=*`) and substring (`=a*b*c`) matches.
//!
//! Numeric comparison applies when both sides parse as numbers (GRIS
//! attributes are numeric strings), falling back to case-insensitive
//! string ordering otherwise — matching how the paper's broker builds
//! "specialized LDAP search queries" from ClassAd constraints.

use thiserror::Error;

use super::entry::Entry;

/// A parsed search filter.
#[derive(Debug, Clone, PartialEq)]
pub enum Filter {
    And(Vec<Filter>),
    Or(Vec<Filter>),
    Not(Box<Filter>),
    /// attr = value (value may contain `*` wildcards; bare `*` = present)
    Eq(String, String),
    Ge(String, String),
    Le(String, String),
    Present(String),
}

#[derive(Debug, Error, PartialEq)]
pub enum FilterError {
    #[error("unexpected end of filter")]
    Eof,
    #[error("expected {0:?} at byte {1}")]
    Expected(char, usize),
    #[error("empty attribute at byte {0}")]
    EmptyAttr(usize),
    #[error("trailing data at byte {0}")]
    Trailing(usize),
}

impl Filter {
    /// Parse a filter string. A filter with no outer parens is accepted
    /// as a single comparison (`a>=1`).
    pub fn parse(src: &str) -> Result<Filter, FilterError> {
        let b = src.trim().as_bytes();
        let mut pos = 0usize;
        let f = parse_filter(b, &mut pos)?;
        if pos != b.len() {
            return Err(FilterError::Trailing(pos));
        }
        Ok(f)
    }

    /// Does `entry` satisfy the filter?
    pub fn matches(&self, entry: &Entry) -> bool {
        match self {
            Filter::And(fs) => fs.iter().all(|f| f.matches(entry)),
            Filter::Or(fs) => fs.iter().any(|f| f.matches(entry)),
            Filter::Not(f) => !f.matches(entry),
            Filter::Present(attr) => entry.has(attr),
            Filter::Eq(attr, pattern) => entry
                .get(attr)
                .map(|vals| vals.iter().any(|v| wildcard_match(pattern, v)))
                .unwrap_or(false),
            Filter::Ge(attr, rhs) => cmp_any(entry, attr, rhs, |o| o >= 0),
            Filter::Le(attr, rhs) => cmp_any(entry, attr, rhs, |o| o <= 0),
        }
    }
}

fn cmp_any(entry: &Entry, attr: &str, rhs: &str, ok: impl Fn(i32) -> bool) -> bool {
    let Some(vals) = entry.get(attr) else {
        return false;
    };
    vals.iter().any(|v| {
        let ord = match (v.trim().parse::<f64>(), rhs.trim().parse::<f64>()) {
            (Ok(a), Ok(b)) => a.partial_cmp(&b).map(|o| o as i32).unwrap_or(0),
            _ => v
                .to_ascii_lowercase()
                .cmp(&rhs.to_ascii_lowercase()) as i32,
        };
        ok(ord)
    })
}

/// Case-insensitive `*`-wildcard match.
fn wildcard_match(pattern: &str, value: &str) -> bool {
    let p: Vec<char> = pattern.to_ascii_lowercase().chars().collect();
    let v: Vec<char> = value.to_ascii_lowercase().chars().collect();
    // Dynamic programming over (pattern, value) positions.
    let (np, nv) = (p.len(), v.len());
    let mut dp = vec![false; nv + 1];
    dp[0] = true;
    for i in 0..np {
        if p[i] == '*' {
            for j in 1..=nv {
                dp[j] = dp[j] || dp[j - 1];
            }
        } else {
            let mut prev = dp[0];
            dp[0] = false;
            for j in 1..=nv {
                let cur = dp[j];
                dp[j] = prev && p[i] == v[j - 1];
                prev = cur;
            }
        }
    }
    dp[nv]
}

fn parse_filter(b: &[u8], pos: &mut usize) -> Result<Filter, FilterError> {
    skip_ws(b, pos);
    if b.get(*pos) != Some(&b'(') {
        // bare comparison
        return parse_item(b, pos, b.len());
    }
    *pos += 1;
    skip_ws(b, pos);
    let f = match b.get(*pos) {
        Some(b'&') => {
            *pos += 1;
            Filter::And(parse_list(b, pos)?)
        }
        Some(b'|') => {
            *pos += 1;
            Filter::Or(parse_list(b, pos)?)
        }
        Some(b'!') => {
            *pos += 1;
            let inner = parse_filter(b, pos)?;
            Filter::Not(Box::new(inner))
        }
        Some(_) => {
            // find closing paren at depth 0
            let close = find_close(b, *pos)?;
            let item = parse_item(b, pos, close)?;
            item
        }
        None => return Err(FilterError::Eof),
    };
    skip_ws(b, pos);
    match b.get(*pos) {
        Some(b')') => {
            *pos += 1;
            Ok(f)
        }
        Some(_) => Err(FilterError::Expected(')', *pos)),
        None => Err(FilterError::Eof),
    }
}

fn parse_list(b: &[u8], pos: &mut usize) -> Result<Vec<Filter>, FilterError> {
    let mut items = Vec::new();
    loop {
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b'(') => items.push(parse_filter(b, pos)?),
            Some(b')') => break,
            Some(_) => return Err(FilterError::Expected('(', *pos)),
            None => return Err(FilterError::Eof),
        }
    }
    Ok(items)
}

fn find_close(b: &[u8], from: usize) -> Result<usize, FilterError> {
    let mut i = from;
    while i < b.len() {
        if b[i] == b')' {
            return Ok(i);
        }
        i += 1;
    }
    Err(FilterError::Eof)
}

/// Parse `attr OP value` within `b[*pos..end]`.
fn parse_item(b: &[u8], pos: &mut usize, end: usize) -> Result<Filter, FilterError> {
    let seg = std::str::from_utf8(&b[*pos..end]).map_err(|_| FilterError::Eof)?;
    let (attr, op, value) = if let Some(i) = seg.find(">=") {
        (&seg[..i], ">=", &seg[i + 2..])
    } else if let Some(i) = seg.find("<=") {
        (&seg[..i], "<=", &seg[i + 2..])
    } else if let Some(i) = seg.find('=') {
        (&seg[..i], "=", &seg[i + 1..])
    } else {
        return Err(FilterError::Expected('=', *pos));
    };
    let attr = attr.trim();
    if attr.is_empty() {
        return Err(FilterError::EmptyAttr(*pos));
    }
    let value = value.trim();
    *pos = end;
    Ok(match op {
        ">=" => Filter::Ge(attr.to_string(), value.to_string()),
        "<=" => Filter::Le(attr.to_string(), value.to_string()),
        _ if value == "*" => Filter::Present(attr.to_string()),
        _ => Filter::Eq(attr.to_string(), value.to_string()),
    })
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while b.get(*pos).map(|c| c.is_ascii_whitespace()).unwrap_or(false) {
        *pos += 1;
    }
}

impl std::fmt::Display for Filter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Filter::And(fs) => {
                write!(f, "(&")?;
                for x in fs {
                    write!(f, "{x}")?;
                }
                write!(f, ")")
            }
            Filter::Or(fs) => {
                write!(f, "(|")?;
                for x in fs {
                    write!(f, "{x}")?;
                }
                write!(f, ")")
            }
            Filter::Not(x) => write!(f, "(!{x})"),
            Filter::Eq(a, v) => write!(f, "({a}={v})"),
            Filter::Ge(a, v) => write!(f, "({a}>={v})"),
            Filter::Le(a, v) => write!(f, "({a}<={v})"),
            Filter::Present(a) => write!(f, "({a}=*)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::directory::entry::Dn;

    fn entry() -> Entry {
        let mut e = Entry::new(Dn::parse("gss=vol0, o=grid").unwrap());
        e.add("objectClass", "GridStorageServerVolume");
        e.put("availableSpace", "53687091200"); // 50G
        e.put("totalSpace", "107374182400");
        e.put("mountPoint", "/dev/sandbox");
        e.add("filesystem", "ext3");
        e.add("filesystem", "xfs");
        e
    }

    #[test]
    fn equality_and_presence() {
        let e = entry();
        assert!(Filter::parse("(mountPoint=/dev/sandbox)").unwrap().matches(&e));
        assert!(Filter::parse("(availableSpace=*)").unwrap().matches(&e));
        assert!(!Filter::parse("(nonexistent=*)").unwrap().matches(&e));
    }

    #[test]
    fn numeric_comparisons() {
        let e = entry();
        assert!(Filter::parse("(availableSpace>=5368709120)").unwrap().matches(&e));
        assert!(!Filter::parse("(availableSpace>=999999999999)").unwrap().matches(&e));
        assert!(Filter::parse("(availableSpace<=107374182400)").unwrap().matches(&e));
    }

    #[test]
    fn boolean_composition() {
        let e = entry();
        let f = Filter::parse(
            "(&(objectClass=GridStorage*)(availableSpace>=1)(|(filesystem=xfs)(filesystem=zfs)))",
        )
        .unwrap();
        assert!(f.matches(&e));
        let g = Filter::parse("(!(mountPoint=/dev/sandbox))").unwrap();
        assert!(!g.matches(&e));
    }

    #[test]
    fn wildcards() {
        let e = entry();
        assert!(Filter::parse("(objectClass=Grid*Volume)").unwrap().matches(&e));
        assert!(Filter::parse("(mountPoint=*sand*)").unwrap().matches(&e));
        assert!(!Filter::parse("(mountPoint=sand*)").unwrap().matches(&e));
        // multi-valued: any value may match
        assert!(Filter::parse("(filesystem=x*)").unwrap().matches(&e));
    }

    #[test]
    fn case_insensitive_matching() {
        let e = entry();
        assert!(Filter::parse("(MOUNTPOINT=/DEV/SANDBOX)").unwrap().matches(&e));
        assert!(Filter::parse("(objectclass=gridstorage*)").unwrap().matches(&e));
    }

    #[test]
    fn display_round_trip() {
        for s in [
            "(&(a=1)(b>=2))",
            "(|(a=x*)(!(b<=3)))",
            "(present=*)",
        ] {
            let f = Filter::parse(s).unwrap();
            assert_eq!(Filter::parse(&f.to_string()).unwrap(), f);
        }
    }

    #[test]
    fn bare_comparison_accepted() {
        let e = entry();
        assert!(Filter::parse("availableSpace>=1").unwrap().matches(&e));
    }

    #[test]
    fn parse_errors() {
        assert!(Filter::parse("(&(a=1)").is_err());
        assert!(Filter::parse("(=v)").is_err());
        assert!(Filter::parse("(a=1))").is_err());
        assert!(Filter::parse("(noop)").is_err());
    }
}
