//! The Directory Information Tree: an in-memory entry store with
//! base/scope/filter search (the core of a GRIS/GIIS server).

use std::collections::BTreeMap;

use thiserror::Error;

use super::entry::{Dn, Entry};
use super::filter::Filter;

/// LDAP search scope.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scope {
    /// The base entry only.
    Base,
    /// Direct children of the base.
    One,
    /// The base and all descendants.
    Sub,
}

impl Scope {
    pub fn parse(s: &str) -> Option<Scope> {
        match s.to_ascii_lowercase().as_str() {
            "base" => Some(Scope::Base),
            "one" | "onelevel" => Some(Scope::One),
            "sub" | "subtree" => Some(Scope::Sub),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Scope::Base => "base",
            Scope::One => "one",
            Scope::Sub => "sub",
        }
    }
}

#[derive(Debug, Error, PartialEq)]
pub enum DitError {
    #[error("entry {0} already exists")]
    Exists(String),
    #[error("parent of {0} not found")]
    NoParent(String),
    #[error("entry {0} not found")]
    NotFound(String),
}

/// In-memory DIT. Entries are keyed by *normalized* DN; a BTreeMap keeps
/// deterministic iteration order (stable search results).
#[derive(Debug, Default, Clone)]
pub struct Dit {
    entries: BTreeMap<String, Entry>,
}

fn key(dn: &Dn) -> String {
    dn.to_string().to_ascii_lowercase()
}

impl Dit {
    pub fn new() -> Dit {
        Dit::default()
    }

    /// Add an entry; its parent must exist (or be the root).
    pub fn add(&mut self, entry: Entry) -> Result<(), DitError> {
        let k = key(&entry.dn);
        if self.entries.contains_key(&k) {
            return Err(DitError::Exists(entry.dn.to_string()));
        }
        if let Some(parent) = entry.dn.parent() {
            if !parent.is_root() && !self.entries.contains_key(&key(&parent)) {
                return Err(DitError::NoParent(entry.dn.to_string()));
            }
        }
        self.entries.insert(k, entry);
        Ok(())
    }

    /// Add an entry, creating any missing ancestors as plain
    /// `organizationalUnit`-ish scaffolding entries.
    pub fn add_with_ancestors(&mut self, entry: Entry) -> Result<(), DitError> {
        let mut chain = Vec::new();
        let mut cur = entry.dn.parent();
        while let Some(dn) = cur {
            if dn.is_root() || self.entries.contains_key(&key(&dn)) {
                break;
            }
            chain.push(dn.clone());
            cur = dn.parent();
        }
        for dn in chain.into_iter().rev() {
            let mut e = Entry::new(dn.clone());
            e.add("objectClass", "GridOrganizationalNode");
            if let Some((attr, val)) = dn.rdn() {
                e.put(attr, val);
            }
            self.entries.insert(key(&dn), e);
        }
        self.add(entry)
    }

    /// Replace an existing entry (same DN).
    pub fn replace(&mut self, entry: Entry) -> Result<(), DitError> {
        let k = key(&entry.dn);
        if !self.entries.contains_key(&k) {
            return Err(DitError::NotFound(entry.dn.to_string()));
        }
        self.entries.insert(k, entry);
        Ok(())
    }

    /// Insert-or-replace.
    pub fn upsert(&mut self, entry: Entry) {
        self.entries.insert(key(&entry.dn), entry);
    }

    pub fn remove(&mut self, dn: &Dn) -> Option<Entry> {
        self.entries.remove(&key(dn))
    }

    pub fn get(&self, dn: &Dn) -> Option<&Entry> {
        self.entries.get(&key(dn))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Entry> {
        self.entries.values()
    }

    /// LDAP search: all entries under `base` within `scope` satisfying
    /// `filter`.
    pub fn search(&self, base: &Dn, scope: Scope, filter: &Filter) -> Vec<&Entry> {
        self.entries
            .values()
            .filter(|e| match scope {
                Scope::Base => &e.dn == base,
                Scope::One => e.dn.parent().as_ref() == Some(base),
                Scope::Sub => e.dn.under(base),
            })
            .filter(|e| filter.matches(e))
            .collect()
    }

    /// Render the tree as indented text (the Figure-3 DIT view used by
    /// the `gris_explorer` example).
    pub fn render_tree(&self) -> String {
        let mut out = String::new();
        let mut dns: Vec<&Entry> = self.entries.values().collect();
        dns.sort_by_key(|e| (e.dn.depth(), e.dn.to_string()));
        for e in dns {
            let indent = "  ".repeat(e.dn.depth().saturating_sub(1));
            let rdn = e
                .dn
                .rdn()
                .map(|(a, v)| format!("{a}={v}"))
                .unwrap_or_else(|| "<root>".into());
            let classes = e.object_classes().join(",");
            out.push_str(&format!("{indent}{rdn}  [{classes}]\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn site_dit() -> Dit {
        let mut d = Dit::new();
        let mk = |dn: &str, class: &str| {
            let mut e = Entry::new(Dn::parse(dn).unwrap());
            e.add("objectClass", class);
            e
        };
        d.add(mk("o=grid", "GridTop")).unwrap();
        d.add(mk("o=anl, o=grid", "GridOrganization")).unwrap();
        d.add(mk("ou=mcs, o=anl, o=grid", "GridOrganizationalUnit")).unwrap();
        let mut vol = mk("gss=vol0, ou=mcs, o=anl, o=grid", "GridStorageServerVolume");
        vol.put("availableSpace", "53687091200");
        d.add(vol).unwrap();
        let mut bw = mk(
            "gss=bw, gss=vol0, ou=mcs, o=anl, o=grid",
            "GridStorageTransferBandwidth",
        );
        bw.put("AvgRDBandwidth", "81920");
        d.add(bw).unwrap();
        d
    }

    #[test]
    fn add_requires_parent() {
        let mut d = Dit::new();
        let e = Entry::new(Dn::parse("ou=mcs, o=anl, o=grid").unwrap());
        assert!(matches!(d.add(e), Err(DitError::NoParent(_))));
    }

    #[test]
    fn add_with_ancestors_scaffolds() {
        let mut d = Dit::new();
        let e = Entry::new(Dn::parse("gss=vol0, ou=mcs, o=anl, o=grid").unwrap());
        d.add_with_ancestors(e).unwrap();
        assert_eq!(d.len(), 4);
        assert!(d.get(&Dn::parse("o=anl, o=grid").unwrap()).is_some());
    }

    #[test]
    fn duplicate_rejected() {
        let mut d = Dit::new();
        d.add(Entry::new(Dn::parse("o=grid").unwrap())).unwrap();
        assert!(matches!(
            d.add(Entry::new(Dn::parse("o=grid").unwrap())),
            Err(DitError::Exists(_))
        ));
    }

    #[test]
    fn search_scopes() {
        let d = site_dit();
        let all = Filter::parse("(objectClass=*)").unwrap();
        let base = Dn::parse("ou=mcs, o=anl, o=grid").unwrap();
        assert_eq!(d.search(&base, Scope::Base, &all).len(), 1);
        assert_eq!(d.search(&base, Scope::One, &all).len(), 1);
        assert_eq!(d.search(&base, Scope::Sub, &all).len(), 3);
        let root = Dn::parse("o=grid").unwrap();
        assert_eq!(d.search(&root, Scope::Sub, &all).len(), 5);
    }

    #[test]
    fn search_with_filter() {
        let d = site_dit();
        let root = Dn::parse("o=grid").unwrap();
        let f = Filter::parse("(&(objectClass=GridStorage*)(availableSpace>=1))").unwrap();
        let hits = d.search(&root, Scope::Sub, &f);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].dn.rdn().unwrap().1, "vol0");
    }

    #[test]
    fn drill_down_pattern() {
        // The paper's GIIS→GRIS pattern: find volumes broadly, then read
        // one entry precisely.
        let d = site_dit();
        let f = Filter::parse("(objectClass=GridStorageTransferBandwidth)").unwrap();
        let hits = d.search(&Dn::parse("o=grid").unwrap(), Scope::Sub, &f);
        assert_eq!(hits.len(), 1);
        let precise = d.get(&hits[0].dn).unwrap();
        assert_eq!(precise.f64("AvgRDBandwidth").unwrap(), 81920.0);
    }

    #[test]
    fn render_tree_shape() {
        let text = site_dit().render_tree();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        assert!(lines[0].starts_with("o=grid"));
        assert!(lines[4].contains("gss=bw"));
        assert!(lines[4].starts_with("        ")); // depth-5 indent
    }

    #[test]
    fn upsert_and_replace() {
        let mut d = site_dit();
        let dn = Dn::parse("gss=vol0, ou=mcs, o=anl, o=grid").unwrap();
        let mut e = Entry::new(dn.clone());
        e.add("objectClass", "GridStorageServerVolume");
        e.put("availableSpace", "1");
        d.replace(e.clone()).unwrap();
        assert_eq!(d.get(&dn).unwrap().f64("availableSpace").unwrap(), 1.0);
        d.remove(&dn).unwrap();
        assert!(d.replace(e.clone()).is_err());
        d.upsert(e);
        assert!(d.get(&dn).is_some());
    }
}
