//! Directory entries: distinguished names and multi-valued attributes.

use std::collections::BTreeMap;
use std::fmt;

use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum DnError {
    #[error("empty DN component in {0:?}")]
    EmptyComponent(String),
    #[error("missing '=' in RDN {0:?}")]
    MissingEquals(String),
}

/// A distinguished name: ordered RDNs, most specific first, e.g.
/// `gss=volume0, ou=storage, o=anl, o=grid`.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct Dn {
    rdns: Vec<(String, String)>, // (attr, value), lowercased attr
}

impl Dn {
    pub fn root() -> Dn {
        Dn::default()
    }

    /// Parse `a=b,c=d,...`. Whitespace around components is ignored.
    pub fn parse(s: &str) -> Result<Dn, DnError> {
        let t = s.trim();
        if t.is_empty() {
            return Ok(Dn::root());
        }
        let mut rdns = Vec::new();
        for part in t.split(',') {
            let p = part.trim();
            if p.is_empty() {
                return Err(DnError::EmptyComponent(s.to_string()));
            }
            let (a, v) = p.split_once('=').ok_or_else(|| DnError::MissingEquals(p.to_string()))?;
            rdns.push((a.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
        Ok(Dn { rdns })
    }

    /// Child DN: `rdn` prepended to `self`.
    pub fn child(&self, attr: &str, value: &str) -> Dn {
        let mut rdns = Vec::with_capacity(self.rdns.len() + 1);
        rdns.push((attr.to_ascii_lowercase(), value.to_string()));
        rdns.extend(self.rdns.iter().cloned());
        Dn { rdns }
    }

    /// Parent DN (None at the root).
    pub fn parent(&self) -> Option<Dn> {
        if self.rdns.is_empty() {
            None
        } else {
            Some(Dn { rdns: self.rdns[1..].to_vec() })
        }
    }

    /// The leading (most specific) RDN.
    pub fn rdn(&self) -> Option<(&str, &str)> {
        self.rdns.first().map(|(a, v)| (a.as_str(), v.as_str()))
    }

    /// Number of RDN components.
    pub fn depth(&self) -> usize {
        self.rdns.len()
    }

    /// Is `self` equal to or under `base`?
    pub fn under(&self, base: &Dn) -> bool {
        let n = base.rdns.len();
        self.rdns.len() >= n && self.rdns[self.rdns.len() - n..] == base.rdns[..]
    }

    pub fn is_root(&self) -> bool {
        self.rdns.is_empty()
    }
}

impl fmt::Display for Dn {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, (a, v)) in self.rdns.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{a}={v}")?;
        }
        Ok(())
    }
}

/// A directory entry: a DN plus case-insensitive, multi-valued
/// attributes (insertion order of values preserved).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Entry {
    pub dn: Dn,
    attrs: BTreeMap<String, Vec<String>>, // key lowercased
    names: BTreeMap<String, String>,      // lowercased -> display name
}

impl Entry {
    pub fn new(dn: Dn) -> Entry {
        Entry { dn, ..Default::default() }
    }

    /// Add a value to an attribute (multi-valued append).
    pub fn add(&mut self, attr: &str, value: impl Into<String>) -> &mut Self {
        let key = attr.to_ascii_lowercase();
        self.names.entry(key.clone()).or_insert_with(|| attr.to_string());
        self.attrs.entry(key).or_default().push(value.into());
        self
    }

    /// Replace all values of an attribute.
    pub fn put(&mut self, attr: &str, value: impl Into<String>) -> &mut Self {
        let key = attr.to_ascii_lowercase();
        self.names.insert(key.clone(), attr.to_string());
        self.attrs.insert(key, vec![value.into()]);
        self
    }

    /// Replace with a float value (canonical formatting).
    pub fn put_f64(&mut self, attr: &str, value: f64) -> &mut Self {
        self.put(attr, format_f64(value))
    }

    pub fn get(&self, attr: &str) -> Option<&[String]> {
        self.attrs.get(&attr.to_ascii_lowercase()).map(|v| v.as_slice())
    }

    pub fn first(&self, attr: &str) -> Option<&str> {
        self.get(attr).and_then(|v| v.first()).map(|s| s.as_str())
    }

    pub fn f64(&self, attr: &str) -> Option<f64> {
        self.first(attr).and_then(|s| s.trim().parse().ok())
    }

    pub fn has(&self, attr: &str) -> bool {
        self.attrs.contains_key(&attr.to_ascii_lowercase())
    }

    pub fn remove(&mut self, attr: &str) -> bool {
        let key = attr.to_ascii_lowercase();
        self.names.remove(&key);
        self.attrs.remove(&key).is_some()
    }

    /// Iterate attributes as (display_name, values), sorted by key.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &[String])> {
        self.attrs.iter().map(|(k, v)| {
            (
                self.names.get(k).map(|s| s.as_str()).unwrap_or(k.as_str()),
                v.as_slice(),
            )
        })
    }

    /// The entry's objectClass values.
    pub fn object_classes(&self) -> Vec<&str> {
        self.get("objectclass")
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn attr_count(&self) -> usize {
        self.attrs.len()
    }
}

/// Canonical float formatting used across GRIS attributes so values
/// round-trip through LDIF text deterministically.
pub fn format_f64(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dn_parse_display_round_trip() {
        let dn = Dn::parse("gss=volume0, ou=storage, o=anl, o=grid").unwrap();
        assert_eq!(dn.depth(), 4);
        assert_eq!(dn.to_string(), "gss=volume0, ou=storage, o=anl, o=grid");
        assert_eq!(dn.rdn(), Some(("gss", "volume0")));
    }

    #[test]
    fn dn_parent_child() {
        let base = Dn::parse("o=grid").unwrap();
        let child = base.child("o", "anl").child("ou", "storage");
        assert_eq!(child.to_string(), "ou=storage, o=anl, o=grid");
        assert_eq!(child.parent().unwrap().to_string(), "o=anl, o=grid");
        assert!(child.under(&base));
        assert!(!base.under(&child));
        assert!(child.under(&child));
    }

    #[test]
    fn dn_attr_case_insensitive() {
        let a = Dn::parse("OU=Storage, O=Grid").unwrap();
        let b = Dn::parse("ou=Storage, o=Grid").unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn dn_errors() {
        assert!(Dn::parse("a=b,,c=d").is_err());
        assert!(Dn::parse("nodelimiter").is_err());
    }

    #[test]
    fn entry_multi_valued() {
        let mut e = Entry::new(Dn::parse("o=grid").unwrap());
        e.add("filesystem", "ext3").add("filesystem", "xfs");
        assert_eq!(e.get("FILESYSTEM").unwrap(), &["ext3", "xfs"]);
        e.put("filesystem", "zfs");
        assert_eq!(e.get("filesystem").unwrap(), &["zfs"]);
    }

    #[test]
    fn entry_numeric_round_trip() {
        let mut e = Entry::new(Dn::root());
        e.put_f64("availableSpace", 53687091200.0);
        assert_eq!(e.first("availablespace").unwrap(), "53687091200");
        assert_eq!(e.f64("availableSpace").unwrap(), 53687091200.0);
        e.put_f64("drdTime", 8.5);
        assert_eq!(e.first("drdtime").unwrap(), "8.5");
    }

    #[test]
    fn entry_preserves_display_name() {
        let mut e = Entry::new(Dn::root());
        e.put("MaxRDBandwidth", "1");
        let names: Vec<_> = e.iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["MaxRDBandwidth"]);
    }
}
