//! LDIF (LDAP Data Interchange Format) read/write.
//!
//! GRIS query responses travel as LDIF text (paper §3.1/§5.1.2 step 3);
//! the broker's conversion library turns it into ClassAds. Supports
//! multi-entry streams, comment lines, line folding (continuation lines
//! start with a single space) and base64 values (`attr:: b64`).

use thiserror::Error;

use super::entry::{Dn, Entry};

#[derive(Debug, Error, PartialEq)]
pub enum LdifError {
    #[error("entry at line {0} does not start with dn:")]
    MissingDn(usize),
    #[error("bad attribute line {0}: {1:?}")]
    BadLine(usize, String),
    #[error("bad dn at line {0}: {1}")]
    BadDn(usize, String),
    #[error("bad base64 at line {0}")]
    BadBase64(usize),
}

/// Serialize one entry as LDIF.
pub fn to_ldif(entry: &Entry) -> String {
    let mut out = format!("dn: {}\n", entry.dn);
    for (name, values) in entry.iter() {
        for v in values {
            if v.chars().all(|c| !c.is_control()) && !v.starts_with([' ', ':', '<']) {
                out.push_str(&format!("{name}: {v}\n"));
            } else {
                out.push_str(&format!("{name}:: {}\n", b64_encode(v.as_bytes())));
            }
        }
    }
    out
}

/// Serialize a stream of entries separated by blank lines.
pub fn to_ldif_stream(entries: &[Entry]) -> String {
    entries.iter().map(to_ldif).collect::<Vec<_>>().join("\n")
}

/// Parse an LDIF stream into entries.
pub fn parse_ldif(src: &str) -> Result<Vec<Entry>, LdifError> {
    // Unfold continuation lines first.
    let mut lines: Vec<(usize, String)> = Vec::new();
    for (i, raw) in src.lines().enumerate() {
        if let Some(cont) = raw.strip_prefix(' ') {
            if let Some(last) = lines.last_mut() {
                last.1.push_str(cont);
                continue;
            }
        }
        lines.push((i + 1, raw.to_string()));
    }

    let mut entries = Vec::new();
    let mut cur: Option<Entry> = None;
    for (lineno, line) in lines {
        let t = line.trim_end();
        if t.is_empty() {
            if let Some(e) = cur.take() {
                entries.push(e);
            }
            continue;
        }
        if t.starts_with('#') {
            continue;
        }
        let (attr, rest) = t
            .split_once(':')
            .ok_or_else(|| LdifError::BadLine(lineno, t.to_string()))?;
        let attr = attr.trim();
        let (value, b64) = match rest.strip_prefix(':') {
            Some(v) => (v.trim(), true),
            None => (rest.trim(), false),
        };
        let value = if b64 {
            String::from_utf8(b64_decode(value).ok_or(LdifError::BadBase64(lineno))?)
                .map_err(|_| LdifError::BadBase64(lineno))?
        } else {
            value.to_string()
        };
        if attr.eq_ignore_ascii_case("dn") {
            if let Some(e) = cur.take() {
                entries.push(e);
            }
            let dn = Dn::parse(&value).map_err(|e| LdifError::BadDn(lineno, e.to_string()))?;
            cur = Some(Entry::new(dn));
        } else {
            match cur.as_mut() {
                Some(e) => {
                    e.add(attr, value);
                }
                None => return Err(LdifError::MissingDn(lineno)),
            }
        }
    }
    if let Some(e) = cur.take() {
        entries.push(e);
    }
    Ok(entries)
}

const B64: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

fn b64_encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = u32::from_be_bytes([0, b[0], b[1], b[2]]);
        out.push(B64[(n >> 18 & 63) as usize] as char);
        out.push(B64[(n >> 12 & 63) as usize] as char);
        out.push(if chunk.len() > 1 { B64[(n >> 6 & 63) as usize] as char } else { '=' });
        out.push(if chunk.len() > 2 { B64[(n & 63) as usize] as char } else { '=' });
    }
    out
}

fn b64_decode(s: &str) -> Option<Vec<u8>> {
    let val = |c: u8| -> Option<u32> {
        match c {
            b'A'..=b'Z' => Some((c - b'A') as u32),
            b'a'..=b'z' => Some((c - b'a' + 26) as u32),
            b'0'..=b'9' => Some((c - b'0' + 52) as u32),
            b'+' => Some(62),
            b'/' => Some(63),
            _ => None,
        }
    };
    let bytes: Vec<u8> = s.bytes().filter(|b| !b.is_ascii_whitespace()).collect();
    if bytes.len() % 4 != 0 {
        return None;
    }
    let mut out = Vec::new();
    for chunk in bytes.chunks(4) {
        let pad = chunk.iter().filter(|&&c| c == b'=').count();
        let mut n: u32 = 0;
        for (i, &c) in chunk.iter().enumerate() {
            let v = if c == b'=' {
                if i < 2 {
                    return None;
                }
                0
            } else {
                val(c)?
            };
            n = (n << 6) | v;
        }
        out.push((n >> 16) as u8);
        if pad < 2 {
            out.push((n >> 8) as u8);
        }
        if pad < 1 {
            out.push(n as u8);
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Entry {
        let mut e = Entry::new(Dn::parse("gss=vol0, ou=mcs, o=anl, o=grid").unwrap());
        e.add("objectClass", "GridStorageServerVolume");
        e.put("availableSpace", "53687091200");
        e.put("mountPoint", "/dev/sandbox");
        e.add("filesystem", "ext3");
        e.add("filesystem", "xfs");
        e
    }

    #[test]
    fn round_trips_single_entry() {
        let e = sample();
        let text = to_ldif(&e);
        let parsed = parse_ldif(&text).unwrap();
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0], e);
    }

    #[test]
    fn round_trips_stream() {
        let mut e2 = Entry::new(Dn::parse("gss=vol1, o=grid").unwrap());
        e2.put("totalSpace", "1");
        let entries = vec![sample(), e2];
        let parsed = parse_ldif(&to_ldif_stream(&entries)).unwrap();
        assert_eq!(parsed, entries);
    }

    #[test]
    fn multi_valued_preserved_in_order() {
        let parsed = parse_ldif(&to_ldif(&sample())).unwrap();
        assert_eq!(parsed[0].get("filesystem").unwrap(), &["ext3", "xfs"]);
    }

    #[test]
    fn folding_and_comments() {
        let src = "# a comment\ndn: o=grid\nattr: hello\n world\n";
        let parsed = parse_ldif(src).unwrap();
        assert_eq!(parsed[0].first("attr").unwrap(), "helloworld");
    }

    #[test]
    fn base64_for_awkward_values() {
        let mut e = Entry::new(Dn::parse("o=grid").unwrap());
        e.put("note", " leading space");
        e.put("ctl", "a\nb");
        let text = to_ldif(&e);
        assert!(text.contains("note:: "));
        let parsed = parse_ldif(&text).unwrap();
        assert_eq!(parsed[0].first("note").unwrap(), " leading space");
        assert_eq!(parsed[0].first("ctl").unwrap(), "a\nb");
    }

    #[test]
    fn b64_primitives() {
        assert_eq!(b64_encode(b"hi"), "aGk=");
        assert_eq!(b64_decode("aGk=").unwrap(), b"hi");
        assert_eq!(b64_encode(b"hello!"), "aGVsbG8h");
        assert_eq!(b64_decode("aGVsbG8h").unwrap(), b"hello!");
        assert!(b64_decode("a").is_none());
        assert!(b64_decode("====").is_none());
    }

    #[test]
    fn errors() {
        assert!(matches!(parse_ldif("attr: 1\n"), Err(LdifError::MissingDn(1))));
        assert!(parse_ldif("dn: o=grid\nbogusline\n").is_err());
        assert!(parse_ldif("dn: notadn\n").is_err());
    }
}
