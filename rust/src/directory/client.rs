//! Directory client: the broker side of the GRIS/GIIS protocol.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use thiserror::Error;

use super::dit::Scope;
use super::entry::{Dn, Entry};
use super::filter::Filter;
use super::ldif::parse_ldif;
use super::proto::{Request, END_MARK};

#[derive(Debug, Error)]
pub enum ClientError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("server error: {0}")]
    Server(String),
    #[error("malformed response: {0}")]
    Malformed(String),
    #[error("ldif: {0}")]
    Ldif(#[from] super::ldif::LdifError),
}

/// A connected directory client (one TCP session; requests are
/// pipelined sequentially).
pub struct DirectoryClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl DirectoryClient {
    /// Connect with a default 5s timeout.
    pub fn connect(addr: &str) -> Result<DirectoryClient, ClientError> {
        Self::connect_timeout(addr, Duration::from_secs(5))
    }

    pub fn connect_timeout(addr: &str, timeout: Duration) -> Result<DirectoryClient, ClientError> {
        let sock_addr = addr
            .parse()
            .map_err(|e| ClientError::Malformed(format!("bad addr {addr}: {e}")))?;
        let stream = TcpStream::connect_timeout(&sock_addr, timeout)?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        Ok(DirectoryClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    fn roundtrip(&mut self, req: &Request) -> Result<(String, String), ClientError> {
        self.writer.write_all(req.encode().as_bytes())?;
        self.writer.flush()?;
        let mut status = String::new();
        if self.reader.read_line(&mut status)? == 0 {
            return Err(ClientError::Malformed("connection closed".into()));
        }
        let status = status.trim_end().to_string();
        let mut body = String::new();
        if status != "BYE" {
            loop {
                let mut line = String::new();
                if self.reader.read_line(&mut line)? == 0 {
                    return Err(ClientError::Malformed("truncated response".into()));
                }
                if line.trim_end() == END_MARK {
                    break;
                }
                body.push_str(&line);
            }
        }
        if let Some(err) = status.strip_prefix("ERR\t") {
            return Err(ClientError::Server(err.to_string()));
        }
        Ok((status, body))
    }

    /// LDAP-style search.
    pub fn search(
        &mut self,
        base: &Dn,
        scope: Scope,
        filter: &Filter,
    ) -> Result<Vec<Entry>, ClientError> {
        let (_status, body) = self.roundtrip(&Request::Search {
            base: base.clone(),
            scope,
            filter: filter.clone(),
        })?;
        Ok(parse_ldif(&body)?)
    }

    /// Register a GRIS with a GIIS (server-default TTL).
    pub fn register(
        &mut self,
        site: &str,
        addr: &str,
        base: &Dn,
        summary: Vec<(String, String)>,
    ) -> Result<(), ClientError> {
        self.register_ttl(site, addr, base, summary, None)
    }

    /// Register a GRIS with a GIIS, requesting an explicit soft-state
    /// lifetime (simulated seconds).
    pub fn register_ttl(
        &mut self,
        site: &str,
        addr: &str,
        base: &Dn,
        summary: Vec<(String, String)>,
        ttl: Option<f64>,
    ) -> Result<(), ClientError> {
        self.roundtrip(&Request::Register {
            site: site.into(),
            addr: addr.into(),
            base: base.clone(),
            summary,
            ttl,
        })?;
        Ok(())
    }

    /// Broad GIIS discovery.
    pub fn discover(&mut self, filter: &Filter) -> Result<Vec<Entry>, ClientError> {
        let (_s, body) = self.roundtrip(&Request::Discover { filter: filter.clone() })?;
        Ok(parse_ldif(&body)?)
    }

    /// All registrations on a GIIS.
    pub fn list(&mut self) -> Result<Vec<Entry>, ClientError> {
        let (_s, body) = self.roundtrip(&Request::List)?;
        Ok(parse_ldif(&body)?)
    }

    pub fn ping(&mut self) -> Result<bool, ClientError> {
        let (status, _) = self.roundtrip(&Request::Ping)?;
        Ok(status == "PONG")
    }

    pub fn quit(mut self) {
        let _ = self.roundtrip(&Request::Quit);
    }
}
