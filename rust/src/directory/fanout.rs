//! Event-driven directory fan-out on the simulation kernel (ISSUE 5
//! tentpole).
//!
//! The broker's original Search fan-out was a blocking ≤ 8-worker
//! scoped-thread pool — fine for a handful of real TCP sockets,
//! useless for *simulating* discovery at hundreds of slow sites (the
//! pool consumes no simulated time, so every response is magically
//! fresh). [`DirectoryFanout`] models the fan-out the way the kernel
//! models transfers: each per-site query is an event
//! ([`crate::simnet::Engine::schedule_query`]) whose response lands
//! after that site's simulated round-trip latency, under
//!
//! * **bounded in-flight concurrency** — at most
//!   [`FanoutPolicy::max_in_flight`] queries outstanding; the next
//!   queued site is issued when a response (or timeout) frees a slot,
//! * **a per-query deadline** — a site slower than
//!   [`FanoutPolicy::per_query_deadline`] resolves as a timeout at the
//!   deadline instant (the client stops waiting; the site contributes
//!   no fresh data),
//! * **bounded retry with backoff** — a timed-out query is re-issued
//!   up to [`FanoutPolicy::max_retries`] times, each after
//!   [`FanoutPolicy::retry_backoff`] seconds, before the site is
//!   abandoned (ISSUE 7: one slow attempt is weather, not death), and
//! * **a straggler cutoff** — [`FanoutPolicy::straggler_cutoff`]
//!   seconds after the fan-out starts, everything still queued or in
//!   flight is abandoned and the fan-out completes with what it has.
//!
//! Because responses take simulated time, a driver that selects at
//! fan-out completion sees data of *mixed ages* — the first site's
//! answer is older than the last site's — which is exactly the
//! staleness a real MDS client lives with (`experiment::run_quality_open`
//! drives this; `prop_invariants` pins the cap/completion/determinism
//! contracts).
//!
//! The fan-out is transport-only: it decides *when* each site's
//! response arrives; the caller samples the site's data at that
//! instant (e.g. [`super::hier::HierarchicalDirectory::drill_down`]).

use std::collections::BTreeMap;

use crate::simnet::{Engine, Signal, Topology};
use crate::trace::{Ev, ReqId, SiteId, TraceHandle};

/// Bounds on one fan-out.
#[derive(Debug, Clone, Copy)]
pub struct FanoutPolicy {
    /// Maximum queries outstanding at once (≥ 1; the paper-era thread
    /// pool's 8 is the default).
    pub max_in_flight: usize,
    /// A query slower than this (seconds) resolves as a timeout.
    pub per_query_deadline: f64,
    /// The whole fan-out is cut off this many seconds after it starts.
    pub straggler_cutoff: f64,
    /// Extra attempts after a blown deadline before the site is
    /// abandoned (0 = legacy fail-fast). The GRIS keeps computing its
    /// answer server-side while the client has stopped waiting, so a
    /// retry resumes from `latency − attempts·deadline` of remaining
    /// work — a slow-but-alive site eventually answers, a dead one
    /// (infinite latency) times out every attempt and exhausts the
    /// budget.
    pub max_retries: usize,
    /// Seconds to wait after a timed-out attempt before re-issuing.
    /// The query keeps its in-flight slot through the backoff — the
    /// concurrency cap bounds *commitments*, not wire activity.
    pub retry_backoff: f64,
}

impl Default for FanoutPolicy {
    fn default() -> Self {
        FanoutPolicy {
            max_in_flight: 8,
            per_query_deadline: f64::INFINITY,
            straggler_cutoff: f64::INFINITY,
            max_retries: 0,
            retry_backoff: 0.0,
        }
    }
}

/// Allocator for kernel query ids — globally unique across every live
/// fan-out sharing one [`Engine`], so a driver can route
/// [`Signal::Query`] events by id alone.
#[derive(Debug, Default)]
pub struct QueryIds {
    next: u64,
}

impl QueryIds {
    pub fn new() -> QueryIds {
        QueryIds::default()
    }

    pub fn next(&mut self) -> u64 {
        let id = self.next;
        self.next += 1;
        id
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum QueryState {
    Queued,
    InFlight,
    Responded,
    TimedOut,
    CutOff,
}

#[derive(Debug, Clone)]
struct Query {
    site: usize,
    latency: f64,
    /// One pre-allocated kernel id per attempt (`1 + max_retries`), so
    /// retried queries stay routable through a driver's qid→request
    /// map built once at [`DirectoryFanout::start`].
    qids: Vec<u64>,
    /// 0-based attempt currently (or last) in flight.
    attempt: u32,
    state: QueryState,
    resolved_at: f64,
}

/// What one [`DirectoryFanout::on_query`] delivery meant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FanoutStep {
    /// `site`'s response arrived at `at`: sample its data now.
    Response { site: usize, at: f64 },
    /// `site` blew its per-query deadline; no data.
    TimedOut { site: usize, at: f64 },
    /// `site`'s attempt timed out but retries remain: the query was
    /// re-issued after the backoff (`attempt` is the 1-based retry now
    /// pending). Not a terminal outcome — the site is still in flight.
    Retried { site: usize, attempt: u32, at: f64 },
    /// The straggler cutoff fired; remaining sites were abandoned.
    CutOff { at: f64 },
    /// Not one of this fan-out's ids (or already finished) — ignore.
    Ignored,
}

/// One in-progress fan-out (see module docs).
#[derive(Debug)]
pub struct DirectoryFanout {
    queries: Vec<Query>,
    by_qid: BTreeMap<u64, usize>,
    policy: FanoutPolicy,
    cutoff_qid: Option<u64>,
    started_at: f64,
    /// Index of the next queued entry to issue.
    next_queued: usize,
    in_flight: usize,
    outstanding: usize,
    peak_in_flight: usize,
    retries: usize,
    finished_at: Option<f64>,
    /// Flight recorder (disabled unless [`DirectoryFanout::start_traced`]
    /// wired one in): per-query issue/land/timeout/cutoff events keyed
    /// by the owning request.
    trace: TraceHandle,
    trace_req: ReqId,
    /// Interned display labels aligned with `queries` (empty when
    /// untraced — the caller interns because only it knows site names).
    labels: Vec<SiteId>,
}

impl DirectoryFanout {
    /// Start a fan-out over `sites` (site index + round-trip query
    /// latency in simulated seconds, issued in the given order). The
    /// first `max_in_flight` queries are scheduled immediately; ids
    /// come from `ids` so several fan-outs can share one engine.
    pub fn start(
        eng: &mut Engine,
        ids: &mut QueryIds,
        now: f64,
        sites: &[(usize, f64)],
        policy: FanoutPolicy,
    ) -> DirectoryFanout {
        Self::start_traced(eng, ids, now, sites, policy, TraceHandle::disabled(), 0, &[])
    }

    /// [`DirectoryFanout::start`] with a flight recorder attached:
    /// every query issue/land/timeout and the straggler cutoff are
    /// recorded against request `req`. `labels` carries one interned
    /// site id per `sites` entry (the caller interns — only it knows
    /// the display names behind the opaque site tokens); it may be
    /// empty when `trace` is disabled.
    #[allow(clippy::too_many_arguments)]
    pub fn start_traced(
        eng: &mut Engine,
        ids: &mut QueryIds,
        now: f64,
        sites: &[(usize, f64)],
        policy: FanoutPolicy,
        trace: TraceHandle,
        req: ReqId,
        labels: &[SiteId],
    ) -> DirectoryFanout {
        let max_in_flight = policy.max_in_flight.max(1);
        let queries: Vec<Query> = sites
            .iter()
            .map(|&(site, latency)| Query {
                site,
                latency: latency.max(0.0),
                qids: (0..=policy.max_retries).map(|_| ids.next()).collect(),
                attempt: 0,
                state: QueryState::Queued,
                resolved_at: f64::NAN,
            })
            .collect();
        let by_qid = queries
            .iter()
            .enumerate()
            .flat_map(|(i, q)| q.qids.iter().map(move |&qid| (qid, i)))
            .collect();
        let cutoff_qid = if policy.straggler_cutoff.is_finite() && !queries.is_empty() {
            let qid = ids.next();
            eng.schedule_query(now + policy.straggler_cutoff.max(0.0), qid);
            Some(qid)
        } else {
            None
        };
        let mut f = DirectoryFanout {
            outstanding: queries.len(),
            queries,
            by_qid,
            policy: FanoutPolicy { max_in_flight, ..policy },
            cutoff_qid,
            started_at: now,
            next_queued: 0,
            in_flight: 0,
            peak_in_flight: 0,
            retries: 0,
            finished_at: if sites.is_empty() { Some(now) } else { None },
            trace,
            trace_req: req,
            labels: labels.to_vec(),
        };
        f.issue_up_to_cap(eng, now);
        f
    }

    /// Every kernel id this fan-out owns (site queries + cutoff) — for
    /// drivers that route [`Signal::Query`] events through a map.
    pub fn qids(&self) -> Vec<u64> {
        self.queries
            .iter()
            .flat_map(|q| q.qids.iter().copied())
            .chain(self.cutoff_qid)
            .collect()
    }

    fn issue_up_to_cap(&mut self, eng: &mut Engine, now: f64) {
        while self.in_flight < self.policy.max_in_flight && self.next_queued < self.queries.len()
        {
            let q = &mut self.queries[self.next_queued];
            self.next_queued += 1;
            q.state = QueryState::InFlight;
            // A query that cannot beat its deadline resolves *at* the
            // deadline as a timeout — the client stops waiting there.
            let resolves_in = q.latency.min(self.policy.per_query_deadline);
            eng.schedule_query(now + resolves_in, q.qids[0]);
            self.in_flight += 1;
            if self.trace.on() {
                let site = self.labels.get(self.next_queued - 1).copied().unwrap_or(0);
                self.trace.rec(now, self.trace_req, Ev::QueryIssue { site });
            }
        }
        self.peak_in_flight = self.peak_in_flight.max(self.in_flight);
    }

    /// Deliver one [`Signal::Query`] event. Unknown ids (other
    /// fan-outs, or events landing after this fan-out finished) come
    /// back as [`FanoutStep::Ignored`].
    pub fn on_query(&mut self, eng: &mut Engine, id: u64, at: f64) -> FanoutStep {
        if self.finished_at.is_some() {
            return FanoutStep::Ignored;
        }
        if Some(id) == self.cutoff_qid {
            let mut cut = 0u32;
            for q in &mut self.queries {
                if matches!(q.state, QueryState::Queued | QueryState::InFlight) {
                    q.state = QueryState::CutOff;
                    q.resolved_at = at;
                    self.outstanding -= 1;
                    cut += 1;
                }
            }
            self.in_flight = 0;
            self.next_queued = self.queries.len();
            self.finished_at = Some(at);
            if self.trace.on() {
                self.trace.rec(at, self.trace_req, Ev::QueryCutoff { unresolved: cut });
            }
            return FanoutStep::CutOff { at };
        }
        let Some(&i) = self.by_qid.get(&id) else {
            return FanoutStep::Ignored;
        };
        if self.queries[i].state != QueryState::InFlight {
            return FanoutStep::Ignored;
        }
        let deadline = self.policy.per_query_deadline;
        // Server-side progress carries across attempts (the GRIS keeps
        // computing after the client stops waiting), so attempt k
        // resumes with `latency − k·deadline` of work left. attempt 0
        // is special-cased to dodge `0 × ∞ = NaN` under the default
        // infinite deadline.
        let attempt = self.queries[i].attempt;
        let remaining = if attempt == 0 {
            self.queries[i].latency
        } else {
            self.queries[i].latency - attempt as f64 * deadline
        };
        let timed_out = remaining > deadline;
        if timed_out && (attempt as usize) < self.policy.max_retries {
            // Retry budget left: re-issue after the backoff instead of
            // abandoning the site. The slot stays held (in-flight
            // count, outstanding count unchanged) so the concurrency
            // cap keeps bounding commitments.
            let q = &mut self.queries[i];
            q.attempt += 1;
            let resolves_in = (remaining - deadline).min(deadline);
            let reissue_at = at + self.policy.retry_backoff.max(0.0);
            eng.schedule_query(reissue_at + resolves_in, q.qids[q.attempt as usize]);
            self.retries += 1;
            let (site, attempt) = (q.site, q.attempt);
            if self.trace.on() {
                let label = self.labels.get(i).copied().unwrap_or(0);
                self.trace.rec(at, self.trace_req, Ev::QueryTimeout { site: label });
                self.trace.rec(reissue_at, self.trace_req, Ev::QueryIssue { site: label });
            }
            return FanoutStep::Retried { site, attempt, at };
        }
        self.queries[i].state = if timed_out {
            QueryState::TimedOut
        } else {
            QueryState::Responded
        };
        self.queries[i].resolved_at = at;
        self.in_flight -= 1;
        self.outstanding -= 1;
        self.issue_up_to_cap(eng, at);
        if self.outstanding == 0 {
            self.finished_at = Some(at);
        }
        let site = self.queries[i].site;
        if self.trace.on() {
            let label = self.labels.get(i).copied().unwrap_or(0);
            let ev = if timed_out {
                Ev::QueryTimeout { site: label }
            } else {
                Ev::QueryLand { site: label }
            };
            self.trace.rec(at, self.trace_req, ev);
        }
        if timed_out {
            FanoutStep::TimedOut { site, at }
        } else {
            FanoutStep::Response { site, at }
        }
    }

    pub fn finished(&self) -> bool {
        self.finished_at.is_some()
    }

    /// Instant the fan-out completed (last resolution or cutoff).
    pub fn finished_at(&self) -> Option<f64> {
        self.finished_at
    }

    pub fn started_at(&self) -> f64 {
        self.started_at
    }

    /// Queries outstanding right now.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// The most queries ever simultaneously outstanding — must never
    /// exceed the policy cap (`prop_invariants`).
    pub fn peak_in_flight(&self) -> usize {
        self.peak_in_flight
    }

    /// Timed-out attempts that were re-issued instead of abandoned.
    pub fn retries(&self) -> usize {
        self.retries
    }

    /// Sites whose responses arrived, with arrival instants, in
    /// resolution order.
    pub fn responses(&self) -> Vec<(usize, f64)> {
        let mut out: Vec<(usize, f64, u64)> = self
            .queries
            .iter()
            .filter(|q| q.state == QueryState::Responded)
            .map(|q| (q.site, q.resolved_at, q.qids[0]))
            .collect();
        out.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.2.cmp(&b.2)));
        out.into_iter().map(|(s, at, _)| (s, at)).collect()
    }

    /// Sites that never answered (deadline or cutoff).
    pub fn unresolved(&self) -> Vec<usize> {
        self.queries
            .iter()
            .filter(|q| {
                matches!(
                    q.state,
                    QueryState::TimedOut | QueryState::CutOff | QueryState::Queued
                        | QueryState::InFlight
                )
            })
            .map(|q| q.site)
            .collect()
    }
}

/// Drive one fan-out to completion on a private kernel, starting at
/// absolute instant `now` — the blocking convenience for benches and
/// serial drivers. Returns the finished fan-out (inspect
/// [`DirectoryFanout::responses`] / [`DirectoryFanout::finished_at`]).
/// The caller's topology is untouched: the kernel only needs a clock,
/// so the drive runs on a one-site scratch [`Topology`] (no
/// full-topology clone — that per-call deep-copy pattern is exactly
/// what PR 4 removed from the oracle).
pub fn run_fanout(now: f64, sites: &[(usize, f64)], policy: FanoutPolicy) -> DirectoryFanout {
    let mut scratch = Topology::build(&crate::config::GridConfig::generate(1, 0));
    run_fanout_on(&mut scratch, now, sites, policy)
}

/// [`run_fanout`] driving a caller-provided scratch topology — reuse
/// one scratch across many drives to keep its construction out of
/// measured loops (`bench_directory` does). Only the scratch's clock
/// is consumed; it is advanced monotonically and never rolled back.
pub fn run_fanout_on(
    scratch: &mut Topology,
    now: f64,
    sites: &[(usize, f64)],
    policy: FanoutPolicy,
) -> DirectoryFanout {
    scratch.advance_to(now);
    let mut eng = Engine::new(crate::simnet::FlowSet::new(f64::INFINITY));
    let mut ids = QueryIds::new();
    let mut f = DirectoryFanout::start(&mut eng, &mut ids, now, sites, policy);
    while !f.finished() {
        match eng.next(scratch) {
            Some(Signal::Query { id, at }) => {
                f.on_query(&mut eng, id, at);
            }
            Some(_) => continue,
            None => break,
        }
    }
    f
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_sites_respond_in_latency_order_under_the_cap() {
        let sites = vec![(0, 0.30), (1, 0.10), (2, 0.20)];
        let f = run_fanout(7.0, &sites, FanoutPolicy { max_in_flight: 3, ..Default::default() });
        assert!(f.finished());
        let order: Vec<usize> = f.responses().iter().map(|&(s, _)| s).collect();
        assert_eq!(order, vec![1, 2, 0], "responses land in latency order");
        assert!(f.unresolved().is_empty());
        assert_eq!(f.peak_in_flight(), 3);
        assert!((f.finished_at().unwrap() - 7.30).abs() < 1e-9);
    }

    #[test]
    fn cap_one_serializes_queries() {
        let sites = vec![(0, 0.30), (1, 0.10), (2, 0.20)];
        let f = run_fanout(0.0, &sites, FanoutPolicy { max_in_flight: 1, ..Default::default() });
        assert_eq!(f.peak_in_flight(), 1);
        // Serialized: total time is the sum of latencies, and issue
        // order (not latency order) decides completion order.
        let order: Vec<usize> = f.responses().iter().map(|&(s, _)| s).collect();
        assert_eq!(order, vec![0, 1, 2]);
        assert!((f.finished_at().unwrap() - 0.60).abs() < 1e-9);
    }

    #[test]
    fn deadline_times_slow_sites_out() {
        let sites = vec![(0, 5.0), (1, 0.1)];
        let f = run_fanout(
            0.0,
            &sites,
            FanoutPolicy { per_query_deadline: 1.0, ..Default::default() },
        );
        assert_eq!(f.responses().len(), 1);
        assert_eq!(f.responses()[0].0, 1);
        assert_eq!(f.unresolved(), vec![0]);
        assert_eq!(f.retries(), 0, "fail-fast default never retries");
        // The client stopped waiting at the deadline, not at 5 s.
        assert!((f.finished_at().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn retries_let_a_slow_but_alive_site_answer() {
        // latency 2.5 against a 1.0 s per-attempt deadline: attempt 0
        // times out at 1.0, retry waits 0.5 and resumes 1.5 s of work
        // (times out again at 2.5+0.5=3.0... attempt 1 runs 1.5→2.5),
        // attempt 2 runs the final 0.5 s. Timeline: t=1.0 timeout,
        // reissue 1.5, t=2.5 timeout, reissue 3.0, answer at 3.5.
        let sites = vec![(0, 2.5)];
        let f = run_fanout(
            0.0,
            &sites,
            FanoutPolicy {
                per_query_deadline: 1.0,
                max_retries: 2,
                retry_backoff: 0.5,
                ..Default::default()
            },
        );
        assert!(f.finished());
        assert_eq!(f.retries(), 2);
        assert_eq!(f.responses().len(), 1, "third attempt lands the answer");
        assert!(f.unresolved().is_empty());
        assert!((f.finished_at().unwrap() - 3.5).abs() < 1e-9, "{:?}", f.finished_at());
    }

    #[test]
    fn retry_budget_exhaustion_abandons_a_dead_site() {
        // An unreachable site (infinite latency) times out every
        // attempt; after 1 + max_retries tries it is abandoned, and a
        // healthy peer is unaffected.
        let sites = vec![(0, f64::INFINITY), (1, 0.1)];
        let f = run_fanout(
            0.0,
            &sites,
            FanoutPolicy {
                per_query_deadline: 1.0,
                max_retries: 2,
                retry_backoff: 0.0,
                ..Default::default()
            },
        );
        assert!(f.finished());
        assert_eq!(f.retries(), 2);
        assert_eq!(f.responses().len(), 1);
        assert_eq!(f.responses()[0].0, 1);
        assert_eq!(f.unresolved(), vec![0]);
        // Three back-to-back 1 s waits on the dead site.
        assert!((f.finished_at().unwrap() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn cutoff_cancels_a_query_waiting_out_its_backoff() {
        // The straggler cutoff fires while site 0 sits in retry
        // backoff: the pending retry is abandoned, not resurrected.
        let sites = vec![(0, f64::INFINITY)];
        let f = run_fanout(
            0.0,
            &sites,
            FanoutPolicy {
                per_query_deadline: 1.0,
                max_retries: 5,
                retry_backoff: 10.0,
                straggler_cutoff: 5.0,
                ..Default::default()
            },
        );
        assert!(f.finished());
        assert_eq!(f.retries(), 1, "one reissue before the cutoff");
        assert_eq!(f.unresolved(), vec![0]);
        assert!((f.finished_at().unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn straggler_cutoff_abandons_the_tail() {
        // Cap 1 ⇒ site 2 would start at 4.0; the cutoff at 2.5 lands
        // mid-flight for site 1 and pre-issue for site 2.
        let sites = vec![(0, 2.0), (1, 2.0), (2, 2.0)];
        let f = run_fanout(
            0.0,
            &sites,
            FanoutPolicy { max_in_flight: 1, straggler_cutoff: 2.5, ..Default::default() },
        );
        assert_eq!(f.responses().len(), 1);
        assert_eq!(f.unresolved().len(), 2);
        assert!((f.finished_at().unwrap() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn empty_fanout_finishes_immediately() {
        let f = run_fanout(0.0, &[], FanoutPolicy::default());
        assert!(f.finished());
        assert!(f.responses().is_empty());
    }
}
