//! GIIS — the Grid Index Information Service.
//!
//! GRIS servers register here; clients direct *broad* queries at the
//! GIIS to discover resources, then drill down with direct GRIS queries
//! for fresh detail (paper §3). Registrations carry a TTL and must be
//! refreshed, mirroring MDS soft-state registration.
//!
//! **Clock discipline (ISSUE 5):** everything here runs on the
//! *simulated* clock, not the wall clock. The original implementation
//! stamped registrations with `std::time::Instant` — dead wrong under
//! simulation, where a whole multi-hour sweep executes in microseconds
//! of real time, so no registration ever expired. Expiry is now a pure
//! function of an explicit [`SimInstant`] ([`Registration::expired`]),
//! and the `Giis` carries its own logical clock
//! ([`Giis::advance_to`] / [`Giis::tick`]) that drivers advance in
//! lock-step with [`crate::simnet::Topology::now`]. TTL expiry,
//! re-registration churn and cache ages are therefore deterministic
//! and testable (`it_giis`).
//!
//! Besides the coarse `summary` attributes (what broad `discover`
//! filters match against), a registration may carry a **cached entry
//! snapshot** ([`Registration::cached`]) — the soft-state copy of the
//! site's storage entries captured at registration time. This is what
//! lets a GIIS answer a broker's broad Search without fanning out to
//! every GRIS: the answer is *stale by construction* (as old as the
//! registration), and the broker drills down to the site's GRIS only
//! for the candidates it actually cares about
//! (`crate::directory::hier`, `crate::broker::Broker::with_discovery`).

use std::collections::BTreeMap;

use super::entry::{format_f64, Dn, Entry};
use super::filter::Filter;

/// An instant on the simulated clock, in seconds — the same time base
/// as [`crate::simnet::Topology::now`]. Wall-clock types
/// (`std::time::Instant`) must never be stored in simulated soft
/// state; see the module docs.
pub type SimInstant = f64;

/// One GRIS registration record.
#[derive(Debug, Clone)]
pub struct Registration {
    pub site: String,
    /// host:port of the GRIS server.
    pub addr: String,
    /// Base DN the GRIS serves.
    pub base_dn: Dn,
    /// Coarse summary attributes pushed with the registration (lets the
    /// GIIS answer broad `discover` queries without fanning out).
    pub summary: Vec<(String, String)>,
    /// Soft-state snapshot of the site's storage entries, captured at
    /// registration time (may be empty for summary-only registrations).
    cached: Vec<Entry>,
    registered_at: SimInstant,
    /// Lifetime in simulated seconds.
    ttl: f64,
}

impl Registration {
    /// Whether this registration has outlived its TTL at `now`. Takes
    /// the instant explicitly: expiry is a property of *simulated*
    /// elapsed time, never of the process wall clock.
    pub fn expired(&self, now: SimInstant) -> bool {
        now - self.registered_at > self.ttl
    }

    /// Simulated seconds since the registration was (re)pushed.
    pub fn age(&self, now: SimInstant) -> f64 {
        (now - self.registered_at).max(0.0)
    }

    pub fn registered_at(&self) -> SimInstant {
        self.registered_at
    }

    pub fn ttl(&self) -> f64 {
        self.ttl
    }

    /// The cached entry snapshot pushed with the registration.
    pub fn cached(&self) -> &[Entry] {
        &self.cached
    }
}

/// The index service.
#[derive(Debug)]
pub struct Giis {
    regs: BTreeMap<String, Registration>,
    default_ttl: f64,
    /// Logical clock (simulated seconds); drivers advance it in
    /// lock-step with the topology clock.
    clock: SimInstant,
}

impl Default for Giis {
    fn default() -> Self {
        Giis::new()
    }
}

impl Giis {
    pub fn new() -> Giis {
        Giis::with_ttl(300.0)
    }

    /// A GIIS whose registrations default to `ttl` simulated seconds.
    pub fn with_ttl(ttl: f64) -> Giis {
        Giis { regs: BTreeMap::new(), default_ttl: ttl, clock: 0.0 }
    }

    /// The GIIS's current simulated instant.
    pub fn now(&self) -> SimInstant {
        self.clock
    }

    /// Advance the logical clock to the absolute instant `t` (no-op if
    /// already past it — same monotone contract as
    /// `Topology::advance_to`).
    pub fn advance_to(&mut self, t: SimInstant) {
        if t > self.clock {
            self.clock = t;
        }
    }

    /// Advance the logical clock by `dt` simulated seconds.
    pub fn tick(&mut self, dt: f64) {
        if dt > 0.0 {
            self.clock += dt;
        }
    }

    /// Register (or refresh) a GRIS with summary attributes only.
    pub fn register(
        &mut self,
        site: &str,
        addr: &str,
        base_dn: Dn,
        summary: Vec<(String, String)>,
    ) {
        self.register_full(site, addr, base_dn, summary, Vec::new(), None);
    }

    /// Register (or refresh) a GRIS, pushing a cached entry snapshot
    /// alongside the coarse summary.
    pub fn register_cached(
        &mut self,
        site: &str,
        addr: &str,
        base_dn: Dn,
        summary: Vec<(String, String)>,
        cached: Vec<Entry>,
    ) {
        self.register_full(site, addr, base_dn, summary, cached, None);
    }

    /// The full registration: summary + cached snapshot + optional
    /// per-registration TTL override (`None` = the GIIS default).
    pub fn register_full(
        &mut self,
        site: &str,
        addr: &str,
        base_dn: Dn,
        summary: Vec<(String, String)>,
        cached: Vec<Entry>,
        ttl: Option<f64>,
    ) {
        self.regs.insert(
            site.to_ascii_lowercase(),
            Registration {
                site: site.to_string(),
                addr: addr.to_string(),
                base_dn,
                summary,
                cached,
                registered_at: self.clock,
                ttl: ttl.unwrap_or(self.default_ttl),
            },
        );
    }

    pub fn unregister(&mut self, site: &str) -> bool {
        self.regs.remove(&site.to_ascii_lowercase()).is_some()
    }

    /// Drop expired registrations; returns how many were removed.
    pub fn sweep(&mut self) -> usize {
        let now = self.clock;
        let before = self.regs.len();
        self.regs.retain(|_, r| !r.expired(now));
        before - self.regs.len()
    }

    /// All live registrations.
    pub fn registrations(&self) -> Vec<&Registration> {
        self.regs
            .values()
            .filter(|r| !r.expired(self.clock))
            .collect()
    }

    pub fn lookup(&self, site: &str) -> Option<&Registration> {
        self.regs
            .get(&site.to_ascii_lowercase())
            .filter(|r| !r.expired(self.clock))
    }

    /// Like [`Self::lookup`] but ignoring TTL expiry: the last
    /// registration record ever pushed, however stale. This is the
    /// degrade-chain fallback (ISSUE 7) — a resilient broker that finds
    /// the live index empty would rather act on an expired snapshot
    /// than on nothing. Never returned by [`Self::registrations`] or
    /// [`Self::discover`]; normal discovery still hides expired sites.
    pub fn lookup_any(&self, site: &str) -> Option<&Registration> {
        self.regs.get(&site.to_ascii_lowercase())
    }

    /// Broad discovery: match registrations' summary attributes against
    /// an LDAP filter (each registration is viewed as one entry).
    pub fn discover(&self, filter: &Filter) -> Vec<&Registration> {
        let now = self.clock;
        self.registrations()
            .into_iter()
            .filter(|r| filter.matches(&registration_entry(r, now)))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.registrations().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// View a registration as a directory entry (`objectClass=
/// GridServiceRegistration`) so filters apply uniformly. `now` stamps
/// the record's simulated age (`regAge`, seconds) so discovery filters
/// can select on freshness.
pub fn registration_entry(r: &Registration, now: SimInstant) -> Entry {
    let mut e = Entry::new(Dn::parse(&format!("site={}, o=giis", r.site)).unwrap());
    e.add("objectClass", "GridServiceRegistration");
    e.put("site", &r.site);
    e.put("addr", &r.addr);
    e.put("baseDn", r.base_dn.to_string());
    e.put("regAge", format_f64(r.age(now)));
    for (k, v) in &r.summary {
        e.add(k, v.clone());
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dn(site: &str) -> Dn {
        Dn::parse(&format!("ou={site}, o=anl, o=grid")).unwrap()
    }

    #[test]
    fn register_lookup_unregister() {
        let mut g = Giis::new();
        g.register("mcs", "127.0.0.1:9001", dn("mcs"), vec![]);
        assert_eq!(g.len(), 1);
        assert_eq!(g.lookup("MCS").unwrap().addr, "127.0.0.1:9001");
        assert!(g.unregister("mcs"));
        assert!(g.is_empty());
    }

    #[test]
    fn refresh_replaces_and_restamps() {
        let mut g = Giis::new();
        g.register("mcs", "127.0.0.1:9001", dn("mcs"), vec![]);
        g.advance_to(100.0);
        g.register("mcs", "127.0.0.1:9002", dn("mcs"), vec![]);
        assert_eq!(g.len(), 1);
        let r = g.lookup("mcs").unwrap();
        assert_eq!(r.addr, "127.0.0.1:9002");
        assert_eq!(r.registered_at(), 100.0);
        assert_eq!(r.age(130.0), 30.0);
    }

    #[test]
    fn ttl_expiry_on_the_sim_clock() {
        // No sleeps: expiry is purely a function of the logical clock,
        // so a sweep that runs in microseconds of real time still ages
        // registrations correctly.
        let mut g = Giis::with_ttl(10.0);
        g.register("mcs", "a:1", dn("mcs"), vec![]);
        g.advance_to(9.0);
        assert_eq!(g.len(), 1, "within TTL");
        g.advance_to(10.5);
        assert_eq!(g.len(), 0, "past TTL");
        assert!(g.lookup("mcs").is_none());
        assert_eq!(g.sweep(), 1);
        // Re-registration (soft-state refresh) revives the site.
        g.register("mcs", "a:1", dn("mcs"), vec![]);
        assert_eq!(g.len(), 1);
        assert_eq!(g.lookup("mcs").unwrap().registered_at(), 10.5);
    }

    #[test]
    fn per_registration_ttl_overrides_default() {
        let mut g = Giis::with_ttl(10.0);
        g.register_full("short", "a:1", dn("short"), vec![], Vec::new(), Some(2.0));
        g.register("long", "b:2", dn("long"), vec![]);
        g.advance_to(5.0);
        assert!(g.lookup("short").is_none());
        assert!(g.lookup("long").is_some());
    }

    #[test]
    fn cached_snapshot_rides_the_registration() {
        let mut g = Giis::new();
        let mut e = Entry::new(dn("mcs").child("gss", "vol0"));
        e.add("objectClass", "GridStorageServerVolume");
        e.put_f64("availableSpace", 42.0);
        g.register_cached("mcs", "a:1", dn("mcs"), vec![], vec![e]);
        let r = g.lookup("mcs").unwrap();
        assert_eq!(r.cached().len(), 1);
        assert_eq!(r.cached()[0].f64("availableSpace"), Some(42.0));
    }

    #[test]
    fn discover_filters_on_summary_and_age() {
        let mut g = Giis::new();
        g.register(
            "mcs",
            "a:1",
            dn("mcs"),
            vec![("storageType".into(), "disk".into()), ("totalSpace".into(), "100".into())],
        );
        g.advance_to(40.0);
        g.register(
            "hpss",
            "b:2",
            dn("hpss"),
            vec![("storageType".into(), "tape".into()), ("totalSpace".into(), "90000".into())],
        );
        let disk = g.discover(&Filter::parse("(storageType=disk)").unwrap());
        assert_eq!(disk.len(), 1);
        assert_eq!(disk[0].site, "mcs");
        let big = g.discover(&Filter::parse("(totalSpace>=1000)").unwrap());
        assert_eq!(big.len(), 1);
        assert_eq!(big[0].site, "hpss");
        let all = g.discover(&Filter::parse("(objectClass=GridServiceRegistration)").unwrap());
        assert_eq!(all.len(), 2);
        // Freshness is a first-class discovery attribute.
        let fresh = g.discover(&Filter::parse("(regAge<=10)").unwrap());
        assert_eq!(fresh.len(), 1);
        assert_eq!(fresh[0].site, "hpss");
    }
}
