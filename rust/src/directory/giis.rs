//! GIIS — the Grid Index Information Service.
//!
//! GRIS servers register here; clients direct *broad* queries at the
//! GIIS to discover resources, then drill down with direct GRIS queries
//! for fresh detail (paper §3). Registrations carry a TTL and must be
//! refreshed, mirroring MDS soft-state registration.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use super::entry::{Dn, Entry};
use super::filter::Filter;

/// One GRIS registration record.
#[derive(Debug, Clone)]
pub struct Registration {
    pub site: String,
    /// host:port of the GRIS server.
    pub addr: String,
    /// Base DN the GRIS serves.
    pub base_dn: Dn,
    /// Coarse summary attributes pushed with the registration (lets the
    /// GIIS answer broad queries without fanning out).
    pub summary: Vec<(String, String)>,
    registered_at: Instant,
    ttl: Duration,
}

impl Registration {
    pub fn expired(&self) -> bool {
        self.registered_at.elapsed() > self.ttl
    }
}

/// The index service.
#[derive(Debug, Default)]
pub struct Giis {
    regs: BTreeMap<String, Registration>,
    default_ttl: Duration,
}

impl Giis {
    pub fn new() -> Giis {
        Giis { regs: BTreeMap::new(), default_ttl: Duration::from_secs(300) }
    }

    pub fn with_ttl(ttl: Duration) -> Giis {
        Giis { regs: BTreeMap::new(), default_ttl: ttl }
    }

    /// Register (or refresh) a GRIS.
    pub fn register(
        &mut self,
        site: &str,
        addr: &str,
        base_dn: Dn,
        summary: Vec<(String, String)>,
    ) {
        self.regs.insert(
            site.to_ascii_lowercase(),
            Registration {
                site: site.to_string(),
                addr: addr.to_string(),
                base_dn,
                summary,
                registered_at: Instant::now(),
                ttl: self.default_ttl,
            },
        );
    }

    pub fn unregister(&mut self, site: &str) -> bool {
        self.regs.remove(&site.to_ascii_lowercase()).is_some()
    }

    /// Drop expired registrations; returns how many were removed.
    pub fn sweep(&mut self) -> usize {
        let before = self.regs.len();
        self.regs.retain(|_, r| !r.expired());
        before - self.regs.len()
    }

    /// All live registrations.
    pub fn registrations(&self) -> Vec<&Registration> {
        self.regs.values().filter(|r| !r.expired()).collect()
    }

    pub fn lookup(&self, site: &str) -> Option<&Registration> {
        self.regs
            .get(&site.to_ascii_lowercase())
            .filter(|r| !r.expired())
    }

    /// Broad discovery: match registrations' summary attributes against
    /// an LDAP filter (each registration is viewed as one entry).
    pub fn discover(&self, filter: &Filter) -> Vec<&Registration> {
        self.registrations()
            .into_iter()
            .filter(|r| filter.matches(&registration_entry(r)))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.registrations().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// View a registration as a directory entry (`objectClass=
/// GridServiceRegistration`) so filters apply uniformly.
pub fn registration_entry(r: &Registration) -> Entry {
    let mut e = Entry::new(Dn::parse(&format!("site={}, o=giis", r.site)).unwrap());
    e.add("objectClass", "GridServiceRegistration");
    e.put("site", &r.site);
    e.put("addr", &r.addr);
    e.put("baseDn", r.base_dn.to_string());
    for (k, v) in &r.summary {
        e.add(k, v.clone());
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dn(site: &str) -> Dn {
        Dn::parse(&format!("ou={site}, o=anl, o=grid")).unwrap()
    }

    #[test]
    fn register_lookup_unregister() {
        let mut g = Giis::new();
        g.register("mcs", "127.0.0.1:9001", dn("mcs"), vec![]);
        assert_eq!(g.len(), 1);
        assert_eq!(g.lookup("MCS").unwrap().addr, "127.0.0.1:9001");
        assert!(g.unregister("mcs"));
        assert!(g.is_empty());
    }

    #[test]
    fn refresh_replaces() {
        let mut g = Giis::new();
        g.register("mcs", "127.0.0.1:9001", dn("mcs"), vec![]);
        g.register("mcs", "127.0.0.1:9002", dn("mcs"), vec![]);
        assert_eq!(g.len(), 1);
        assert_eq!(g.lookup("mcs").unwrap().addr, "127.0.0.1:9002");
    }

    #[test]
    fn ttl_expiry_and_sweep() {
        let mut g = Giis::with_ttl(Duration::from_millis(10));
        g.register("mcs", "a:1", dn("mcs"), vec![]);
        assert_eq!(g.len(), 1);
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(g.len(), 0);
        assert!(g.lookup("mcs").is_none());
        assert_eq!(g.sweep(), 1);
    }

    #[test]
    fn discover_filters_on_summary() {
        let mut g = Giis::new();
        g.register(
            "mcs",
            "a:1",
            dn("mcs"),
            vec![("storageType".into(), "disk".into()), ("totalSpace".into(), "100".into())],
        );
        g.register(
            "hpss",
            "b:2",
            dn("hpss"),
            vec![("storageType".into(), "tape".into()), ("totalSpace".into(), "90000".into())],
        );
        let disk = g.discover(&Filter::parse("(storageType=disk)").unwrap());
        assert_eq!(disk.len(), 1);
        assert_eq!(disk[0].site, "mcs");
        let big = g.discover(&Filter::parse("(totalSpace>=1000)").unwrap());
        assert_eq!(big.len(), 1);
        assert_eq!(big[0].site, "hpss");
        let all = g.discover(&Filter::parse("(objectClass=GridServiceRegistration)").unwrap());
        assert_eq!(all.len(), 2);
    }
}
