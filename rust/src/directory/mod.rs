//! LDAP-lite directory service — the Globus MDS substrate (paper §3).
//!
//! The paper publishes storage metadata through the Metacomputing
//! Directory Service: per-resource **GRIS** servers answer LDAP searches
//! with dynamically generated attributes and register with index
//! servers (**GIIS**); information is organized in a Directory
//! Information Tree of object classes (Figures 2–5) and interchanged as
//! LDIF. This module implements that machinery:
//!
//! * [`entry`] — DNs and multi-valued attribute entries,
//! * [`schema`] — the paper's object classes (`Grid::Storage::ServerVolume`,
//!   `TransferBandwidth`, `SourceTransferBandwidth`) with MUST/MAY
//!   validation and the Figure-3 DIT hierarchy,
//! * [`filter`] — RFC-2254-style search filters (`(&(a>=1)(b=x*))`),
//! * [`ldif`] — LDIF serialization / parsing,
//! * [`dit`] — the in-memory tree with base/scope/filter search,
//! * [`gris`] — a per-site GRIS daemon whose dynamic attributes are
//!   produced by provider callbacks (the "shell backend" analog),
//! * [`giis`] — the index service GRISes register with. Soft state
//!   lives on the **simulated clock** ([`giis::SimInstant`]): TTL
//!   expiry, refresh churn and registration ages are deterministic
//!   functions of logical time, never of the process wall clock,
//! * [`hier`] — the hierarchical discovery path (ISSUE 5): per-site
//!   GRIS servers registered into one GIIS with cached entry
//!   snapshots, so a broker answers broad queries from (stale by
//!   construction) soft state and *drills down* to the live GRIS only
//!   for its top candidates,
//! * [`fanout`] — the event-driven directory client on the
//!   `simnet` kernel: per-site query latency, bounded in-flight
//!   concurrency, per-query deadlines and a straggler cutoff — the
//!   replacement for blocking thread-pool fan-out at hundreds of slow
//!   sites,
//! * [`proto`], [`server`], [`client`] — a line-oriented TCP protocol so
//!   brokers query GRIS/GIIS over the network exactly in the paper's
//!   search-phase pattern (REGISTER carries an optional soft-state
//!   TTL).

pub mod client;
pub mod dit;
pub mod entry;
pub mod fanout;
pub mod filter;
pub mod giis;
pub mod gris;
pub mod hier;
pub mod ldif;
pub mod proto;
pub mod schema;
pub mod server;

pub use dit::{Dit, Scope};
pub use entry::{Dn, Entry};
pub use fanout::{DirectoryFanout, FanoutPolicy, FanoutStep, QueryIds};
pub use filter::Filter;
pub use giis::{Giis, SimInstant};
pub use gris::{Gris, Provider};
pub use hier::{DiscoveryStats, HierarchicalDirectory};
