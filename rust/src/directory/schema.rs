//! The paper's LDAP object classes (Figures 2, 4, 5) and the Figure-3
//! DIT hierarchy, with MUST/MAY validation.
//!
//! `Grid::Storage::ServerVolume` (Fig 2) publishes system-configuration
//! metadata; `Grid::Storage::TransferBandwidth` (Fig 4) the site-wide
//! GridFTP performance summary; `Grid::Storage::SourceTransferBandwidth`
//! (Fig 5) per-source performance records. Attribute syntaxes follow
//! the figures (`cisfloat` = numeric string, `cis` = case-insensitive
//! string; `singular`/`multiple` arity).

use std::collections::BTreeMap;

use once_cell::sync::Lazy;
use thiserror::Error;

use super::entry::Entry;

/// Attribute syntax, as written in the paper's figures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Syntax {
    /// `cisfloat` — numeric.
    Float,
    /// `cis` — case-insensitive string.
    String,
}

/// Attribute arity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arity {
    Singular,
    Multiple,
}

/// One attribute spec inside an object class.
#[derive(Debug, Clone)]
pub struct AttrSpec {
    pub name: &'static str,
    pub syntax: Syntax,
    pub arity: Arity,
    pub mandatory: bool,
}

/// An object-class definition (Figure 2/4/5 style).
#[derive(Debug, Clone)]
pub struct ObjectClass {
    pub name: &'static str,
    pub subclass_of: Option<&'static str>,
    /// RDN attribute, e.g. `gss`.
    pub rdn_attr: &'static str,
    pub attrs: Vec<AttrSpec>,
}

impl ObjectClass {
    pub fn must(&self) -> impl Iterator<Item = &AttrSpec> {
        self.attrs.iter().filter(|a| a.mandatory)
    }

    pub fn may(&self) -> impl Iterator<Item = &AttrSpec> {
        self.attrs.iter().filter(|a| !a.mandatory)
    }

    pub fn attr(&self, name: &str) -> Option<&AttrSpec> {
        self.attrs.iter().find(|a| a.name.eq_ignore_ascii_case(name))
    }

    /// Render in the paper's Figure-2 text style (used by the
    /// `gris_explorer` example to regenerate the figure).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("{}\nOBJECT CLASS ::={{\n", self.name));
        if let Some(parent) = self.subclass_of {
            s.push_str(&format!("SUBCLASS OF {parent}\n"));
        }
        s.push_str(&format!("RDN = {}({})\n", self.rdn_attr, self.name));
        s.push_str("MUST CONTAIN {\n");
        for a in self.must() {
            s.push_str(&format!("  {}::{}::{},\n", a.name, syntax_str(a.syntax), arity_str(a.arity)));
        }
        s.push_str("}\nMAY CONTAIN {\n");
        for a in self.may() {
            s.push_str(&format!("  {}::{}::{},\n", a.name, syntax_str(a.syntax), arity_str(a.arity)));
        }
        s.push_str("}\n}\n");
        s
    }
}

fn syntax_str(s: Syntax) -> &'static str {
    match s {
        Syntax::Float => "cisfloat",
        Syntax::String => "cis",
    }
}

fn arity_str(a: Arity) -> &'static str {
    match a {
        Arity::Singular => "singular",
        Arity::Multiple => "multiple",
    }
}

/// Validation failures against an object class.
#[derive(Debug, Error, PartialEq)]
pub enum SchemaError {
    #[error("entry lacks objectClass {0}")]
    MissingObjectClass(&'static str),
    #[error("missing mandatory attribute {0}")]
    MissingMust(&'static str),
    #[error("attribute {0} must be numeric, got {1:?}")]
    NotNumeric(&'static str, String),
    #[error("attribute {0} is singular but has {1} values")]
    NotSingular(&'static str, usize),
}

const M: bool = true;
const O: bool = false;

fn spec(name: &'static str, syntax: Syntax, arity: Arity, mandatory: bool) -> AttrSpec {
    AttrSpec { name, syntax, arity, mandatory }
}

/// `Grid::Storage::ServerVolume` — Figure 2.
pub static SERVER_VOLUME: Lazy<ObjectClass> = Lazy::new(|| ObjectClass {
    name: "GridStorageServerVolume",
    subclass_of: Some("GridPhysicalResource"),
    rdn_attr: "gss",
    attrs: vec![
        spec("totalSpace", Syntax::Float, Arity::Singular, M),
        spec("availableSpace", Syntax::Float, Arity::Singular, M),
        spec("mountPoint", Syntax::String, Arity::Singular, M),
        spec("diskTransferRate", Syntax::Float, Arity::Singular, M),
        spec("drdTime", Syntax::Float, Arity::Singular, M),
        spec("dwrTime", Syntax::Float, Arity::Singular, M),
        spec("requirements", Syntax::String, Arity::Singular, O),
        spec("filesystem", Syntax::String, Arity::Multiple, O),
    ],
});

/// `Grid::Storage::TransferBandwidth` — Figure 4.
pub static TRANSFER_BANDWIDTH: Lazy<ObjectClass> = Lazy::new(|| ObjectClass {
    name: "GridStorageTransferBandwidth",
    subclass_of: Some("GridStorageServerVolume"),
    rdn_attr: "gss",
    attrs: vec![
        spec("MaxRDBandwidth", Syntax::Float, Arity::Singular, M),
        spec("MinRDBandwidth", Syntax::Float, Arity::Singular, M),
        spec("AvgRDBandwidth", Syntax::Float, Arity::Singular, M),
        spec("MaxWRBandwidth", Syntax::Float, Arity::Singular, M),
        spec("MinWRBandwidth", Syntax::Float, Arity::Singular, M),
        spec("AvgWRBandwidth", Syntax::Float, Arity::Singular, M),
        // Statistical extensions the paper motivates in §3.2.
        spec("StdRDBandwidth", Syntax::Float, Arity::Singular, O),
        spec("StdWRBandwidth", Syntax::Float, Arity::Singular, O),
        spec("NumTransfers", Syntax::Float, Arity::Singular, O),
    ],
});

/// `Grid::Storage::SourceTransferBandwidth` — Figure 5.
pub static SOURCE_TRANSFER_BANDWIDTH: Lazy<ObjectClass> = Lazy::new(|| ObjectClass {
    name: "GridStorageSourceTransferBandwidth",
    subclass_of: Some("GridStorageTransferBandwidth"),
    rdn_attr: "gss",
    attrs: vec![
        spec("lastWRBandwidth", Syntax::Float, Arity::Singular, M),
        spec("lastWRurl", Syntax::String, Arity::Singular, M),
        spec("lastRDBandwidth", Syntax::Float, Arity::Singular, M),
        spec("lastRDurl", Syntax::String, Arity::Singular, M),
        // Per-source history window published for the forecast engine.
        spec("rdHistory", Syntax::String, Arity::Multiple, O),
        spec("AvgRDBandwidth", Syntax::Float, Arity::Singular, O),
        spec("NumTransfers", Syntax::Float, Arity::Singular, O),
    ],
});

/// All classes, by (case-insensitive) name.
pub static REGISTRY: Lazy<BTreeMap<String, &'static ObjectClass>> = Lazy::new(|| {
    let mut m = BTreeMap::new();
    for oc in [&*SERVER_VOLUME, &*TRANSFER_BANDWIDTH, &*SOURCE_TRANSFER_BANDWIDTH] {
        m.insert(oc.name.to_ascii_lowercase(), oc);
    }
    m
});

pub fn lookup(name: &str) -> Option<&'static ObjectClass> {
    REGISTRY.get(&name.to_ascii_lowercase()).copied()
}

/// Validate an entry against an object class: the entry must carry the
/// class in `objectClass`, all MUST attributes present, `cisfloat`
/// values numeric, singular attributes single-valued.
pub fn validate(entry: &Entry, oc: &ObjectClass) -> Result<(), SchemaError> {
    let has_class = entry
        .object_classes()
        .iter()
        .any(|c| c.eq_ignore_ascii_case(oc.name));
    if !has_class {
        return Err(SchemaError::MissingObjectClass(oc.name));
    }
    for a in &oc.attrs {
        let vals = entry.get(a.name);
        match vals {
            None if a.mandatory => return Err(SchemaError::MissingMust(a.name)),
            None => continue,
            Some(vals) => {
                if a.arity == Arity::Singular && vals.len() != 1 {
                    return Err(SchemaError::NotSingular(a.name, vals.len()));
                }
                if a.syntax == Syntax::Float {
                    for v in vals {
                        if v.trim().parse::<f64>().is_err() {
                            return Err(SchemaError::NotNumeric(a.name, v.clone()));
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

/// The Figure-3 DIT skeleton under which GRIS entries live:
/// `o=grid / o=<org> / ou=<site> / gss=<volume>`.
pub fn dit_levels() -> [&'static str; 4] {
    ["o=grid", "o=<organization>", "ou=<organizational unit>", "gss=<server volume>"]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::directory::entry::{Dn, Entry};

    fn volume_entry() -> Entry {
        let mut e = Entry::new(Dn::parse("gss=vol0, ou=mcs, o=anl, o=grid").unwrap());
        e.add("objectClass", "GridPhysicalResource");
        e.add("objectClass", "GridStorageServerVolume");
        e.put_f64("totalSpace", 107374182400.0);
        e.put_f64("availableSpace", 53687091200.0);
        e.put("mountPoint", "/dev/sandbox");
        e.put_f64("diskTransferRate", 20971520.0);
        e.put_f64("drdTime", 8.5);
        e.put_f64("dwrTime", 9.5);
        e
    }

    #[test]
    fn fig2_class_shape() {
        let oc = &*SERVER_VOLUME;
        assert_eq!(oc.must().count(), 6);
        assert_eq!(oc.may().count(), 2);
        assert_eq!(oc.attr("requirements").unwrap().syntax, Syntax::String);
        assert_eq!(oc.attr("filesystem").unwrap().arity, Arity::Multiple);
    }

    #[test]
    fn fig4_class_shape() {
        let oc = &*TRANSFER_BANDWIDTH;
        let must: Vec<_> = oc.must().map(|a| a.name).collect();
        assert_eq!(
            must,
            vec![
                "MaxRDBandwidth",
                "MinRDBandwidth",
                "AvgRDBandwidth",
                "MaxWRBandwidth",
                "MinWRBandwidth",
                "AvgWRBandwidth"
            ]
        );
        assert_eq!(oc.subclass_of, Some("GridStorageServerVolume"));
    }

    #[test]
    fn fig5_class_shape() {
        let oc = &*SOURCE_TRANSFER_BANDWIDTH;
        let must: Vec<_> = oc.must().map(|a| a.name).collect();
        assert!(must.contains(&"lastRDBandwidth"));
        assert!(must.contains(&"lastWRurl"));
        assert_eq!(oc.subclass_of, Some("GridStorageTransferBandwidth"));
    }

    #[test]
    fn validates_good_entry() {
        assert_eq!(validate(&volume_entry(), &SERVER_VOLUME), Ok(()));
    }

    #[test]
    fn rejects_missing_must() {
        let mut e = volume_entry();
        e.remove("drdTime");
        assert_eq!(
            validate(&e, &SERVER_VOLUME),
            Err(SchemaError::MissingMust("drdTime"))
        );
    }

    #[test]
    fn rejects_non_numeric_float() {
        let mut e = volume_entry();
        e.put("availableSpace", "lots");
        assert!(matches!(
            validate(&e, &SERVER_VOLUME),
            Err(SchemaError::NotNumeric("availableSpace", _))
        ));
    }

    #[test]
    fn rejects_multi_valued_singular() {
        let mut e = volume_entry();
        e.add("mountPoint", "/second");
        assert_eq!(
            validate(&e, &SERVER_VOLUME),
            Err(SchemaError::NotSingular("mountPoint", 2))
        );
    }

    #[test]
    fn rejects_wrong_class() {
        let mut e = volume_entry();
        e.remove("objectClass");
        e.add("objectClass", "SomethingElse");
        assert_eq!(
            validate(&e, &SERVER_VOLUME),
            Err(SchemaError::MissingObjectClass("GridStorageServerVolume"))
        );
    }

    #[test]
    fn registry_lookup() {
        assert!(lookup("gridstorageservervolume").is_some());
        assert!(lookup("GridStorageTransferBandwidth").is_some());
        assert!(lookup("nope").is_none());
    }

    #[test]
    fn render_matches_figure_style() {
        let text = SERVER_VOLUME.render();
        assert!(text.contains("OBJECT CLASS ::={"));
        assert!(text.contains("SUBCLASS OF GridPhysicalResource"));
        assert!(text.contains("totalSpace::cisfloat::singular,"));
        assert!(text.contains("filesystem::cis::multiple,"));
    }
}
