//! TCP server hosting a GRIS or GIIS backend.
//!
//! Thread-per-connection over `std::net` (the image ships no tokio; the
//! protocol is tiny request/response so blocking I/O with a bounded
//! accept loop is appropriate — see DESIGN.md §Substitutions).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::dit::Scope;
use super::entry::{Dn, Entry};
use super::filter::Filter;
use super::giis::{registration_entry, Giis};
use super::gris::Gris;
use super::ldif::to_ldif_stream;
use super::proto::{Request, END_MARK};

/// What a directory server serves.
pub trait Backend: Send {
    /// Handle a SEARCH.
    fn search(&self, base: &Dn, scope: Scope, filter: &Filter) -> Vec<Entry>;
    /// Handle a REGISTER (GIIS only; GRIS returns an error message).
    /// `ttl` is the client-requested soft-state lifetime in simulated
    /// seconds (`None` = backend default).
    fn register(
        &mut self,
        _site: &str,
        _addr: &str,
        _base: Dn,
        _summary: Vec<(String, String)>,
        _ttl: Option<f64>,
    ) -> Result<(), String> {
        Err("backend does not accept registrations".into())
    }
    /// Handle DISCOVER / LIST (GIIS only).
    fn discover(&self, _filter: Option<&Filter>) -> Result<Vec<Entry>, String> {
        Err("backend does not index registrations".into())
    }
}

impl Backend for Gris {
    fn search(&self, base: &Dn, scope: Scope, filter: &Filter) -> Vec<Entry> {
        Gris::search(self, base, scope, filter)
    }
}

impl Backend for Giis {
    fn search(&self, _base: &Dn, _scope: Scope, filter: &Filter) -> Vec<Entry> {
        // A GIIS answers searches over its registration records.
        let now = self.now();
        Giis::discover(self, filter)
            .into_iter()
            .map(|r| registration_entry(r, now))
            .collect()
    }

    fn register(
        &mut self,
        site: &str,
        addr: &str,
        base: Dn,
        summary: Vec<(String, String)>,
        ttl: Option<f64>,
    ) -> Result<(), String> {
        Giis::register_full(self, site, addr, base, summary, Vec::new(), ttl);
        Ok(())
    }

    fn discover(&self, filter: Option<&Filter>) -> Result<Vec<Entry>, String> {
        let now = self.now();
        let regs = match filter {
            Some(f) => Giis::discover(self, f),
            None => self.registrations(),
        };
        Ok(regs.into_iter().map(|r| registration_entry(r, now)).collect())
    }
}

/// Handle to a running directory server.
pub struct DirectoryServer {
    addr: String,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    served: Arc<AtomicU64>,
}

impl DirectoryServer {
    /// Spawn a server for `backend` on `127.0.0.1:<port>` (port 0 picks
    /// a free port; the bound address is available via [`Self::addr`]).
    pub fn spawn(backend: Arc<Mutex<dyn Backend>>, port: u16) -> std::io::Result<DirectoryServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        let stop2 = stop.clone();
        let served2 = served.clone();
        let handle = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let backend = backend.clone();
                let served = served2.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, backend, served);
                });
            }
        });
        Ok(DirectoryServer { addr, stop, handle: Some(handle), served })
    }

    /// The bound `host:port`.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Total requests served (all connections).
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::SeqCst)
    }

    /// Stop accepting and join the accept loop.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the accept loop.
        let _ = TcpStream::connect(&self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for DirectoryServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_conn(
    stream: TcpStream,
    backend: Arc<Mutex<dyn Backend>>,
    served: Arc<AtomicU64>,
) -> std::io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        served.fetch_add(1, Ordering::Relaxed);
        let reply = match Request::parse(&line) {
            Err(e) => format!("ERR\t{e}\n{END_MARK}\n"),
            Ok(Request::Quit) => {
                out.write_all(b"BYE\n")?;
                return Ok(());
            }
            Ok(Request::Ping) => format!("PONG\n{END_MARK}\n"),
            Ok(Request::Search { base, scope, filter }) => {
                let entries = backend.lock().unwrap().search(&base, scope, &filter);
                format!(
                    "OK\t{}\n{}\n{END_MARK}\n",
                    entries.len(),
                    to_ldif_stream(&entries)
                )
            }
            Ok(Request::Register { site, addr, base, summary, ttl }) => {
                match backend.lock().unwrap().register(&site, &addr, base, summary, ttl) {
                    Ok(()) => format!("OK\t0\n{END_MARK}\n"),
                    Err(e) => format!("ERR\t{e}\n{END_MARK}\n"),
                }
            }
            Ok(Request::Discover { filter }) => respond_entries(
                backend.lock().unwrap().discover(Some(&filter)),
            ),
            Ok(Request::List) => respond_entries(backend.lock().unwrap().discover(None)),
        };
        out.write_all(reply.as_bytes())?;
        out.flush()?;
    }
}

fn respond_entries(res: Result<Vec<Entry>, String>) -> String {
    match res {
        Ok(entries) => format!(
            "OK\t{}\n{}\n{END_MARK}\n",
            entries.len(),
            to_ldif_stream(&entries)
        ),
        Err(e) => format!("ERR\t{e}\n{END_MARK}\n"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    fn tiny_gris() -> Gris {
        let mut g = Gris::new("anl", "mcs");
        let base = g.base_dn().clone();
        let mut e = Entry::new(base.child("gss", "vol0"));
        e.add("objectClass", "GridStorageServerVolume");
        g.add_entry(e);
        g
    }

    #[test]
    fn shutdown_is_idempotent_and_frees_port() {
        let mut s = DirectoryServer::spawn(Arc::new(Mutex::new(tiny_gris())), 0).unwrap();
        let addr = s.addr().to_string();
        s.shutdown();
        s.shutdown(); // second call is a no-op
        // Port is released: we can bind it again.
        let port: u16 = addr.rsplit(':').next().unwrap().parse().unwrap();
        let rebind = std::net::TcpListener::bind(("127.0.0.1", port));
        assert!(rebind.is_ok(), "port {port} still held after shutdown");
    }

    #[test]
    fn served_counter_tracks_requests() {
        let s = DirectoryServer::spawn(Arc::new(Mutex::new(tiny_gris())), 0).unwrap();
        let mut c = crate::directory::client::DirectoryClient::connect(s.addr()).unwrap();
        assert!(c.ping().unwrap());
        assert!(c.ping().unwrap());
        // Allow the handler thread to tick the counter.
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(s.served() >= 2);
    }
}
