//! GRIS — the per-resource Grid Resource Information Service.
//!
//! Each storage site runs one (paper §3.1). Static attributes (seek
//! times, policies) come from the site's configuration; *dynamic*
//! attributes (availableSpace, load, bandwidth history) are produced at
//! query time by registered **providers** — the analog of the OpenLDAP
//! "shell backend" scripts the paper describes.

use std::collections::HashMap;
use std::sync::Arc;

use super::dit::{Dit, Scope};
use super::entry::{Dn, Entry};
use super::filter::Filter;

/// A dynamic-attribute provider: returns `(attr, value)` pairs merged
/// into its entry at query time.
pub type Provider = Arc<dyn Fn() -> Vec<(String, String)> + Send + Sync>;

/// A GRIS instance for one site.
pub struct Gris {
    /// Site identity: `ou=<site>, o=<org>, o=grid`.
    base_dn: Dn,
    site: String,
    /// Static portion of the tree.
    dit: Dit,
    /// Dynamic providers keyed by DN (DNs normalize attribute case at
    /// parse time, so direct keying avoids per-query string building —
    /// Perf log P3).
    providers: HashMap<Dn, Vec<Provider>>,
}

impl Gris {
    /// Create a GRIS rooted at `ou=<site>, o=<org>, o=grid` with the
    /// scaffolding entries of the Figure-3 DIT.
    pub fn new(org: &str, site: &str) -> Gris {
        let root = Dn::parse("o=grid").unwrap();
        let org_dn = root.child("o", org);
        let base_dn = org_dn.child("ou", site);
        let mut dit = Dit::new();
        let mut top = Entry::new(root.clone());
        top.add("objectClass", "GridTop");
        dit.add(top).unwrap();
        let mut o = Entry::new(org_dn.clone());
        o.add("objectClass", "GridOrganization");
        o.put("o", org);
        dit.add(o).unwrap();
        let mut ou = Entry::new(base_dn.clone());
        ou.add("objectClass", "GridOrganizationalUnit");
        ou.put("ou", site);
        dit.add(ou).unwrap();
        Gris { base_dn, site: site.to_string(), dit, providers: HashMap::new() }
    }

    pub fn base_dn(&self) -> &Dn {
        &self.base_dn
    }

    pub fn site(&self) -> &str {
        &self.site
    }

    /// Add a static entry under the site (ancestors must exist).
    pub fn add_entry(&mut self, entry: Entry) {
        self.dit
            .add_with_ancestors(entry)
            .expect("gris entry insert");
    }

    /// Attach a dynamic provider to the entry at `dn`.
    pub fn add_provider(&mut self, dn: &Dn, p: Provider) {
        self.providers.entry(dn.clone()).or_default().push(p);
    }

    /// Materialize an entry with its dynamic attributes applied.
    fn materialize(&self, e: &Entry) -> Entry {
        match self.providers.get(&e.dn) {
            None => e.clone(),
            Some(ps) => {
                let mut out = e.clone();
                for p in ps {
                    for (attr, value) in p() {
                        out.put(&attr, value);
                    }
                }
                out
            }
        }
    }

    /// LDAP-style search with dynamic attributes resolved ("up-to-date,
    /// detailed information", paper §3).
    pub fn search(&self, base: &Dn, scope: Scope, filter: &Filter) -> Vec<Entry> {
        // Dynamic attributes may affect filter outcomes, so materialize
        // before filtering.
        self.dit
            .iter()
            .filter(|e| match scope {
                Scope::Base => &e.dn == base,
                Scope::One => e.dn.parent().as_ref() == Some(base),
                Scope::Sub => e.dn.under(base),
            })
            .map(|e| self.materialize(e))
            .filter(|e| filter.matches(e))
            .collect()
    }

    /// Snapshot the whole tree (dynamic attributes applied).
    pub fn snapshot(&self) -> Vec<Entry> {
        self.dit.iter().map(|e| self.materialize(e)).collect()
    }

    /// Render the live DIT (Figure 3 view).
    pub fn render_tree(&self) -> String {
        let mut d = Dit::new();
        for e in self.snapshot() {
            d.upsert(e);
        }
        d.render_tree()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn volume_entry(base: &Dn) -> Entry {
        let mut e = Entry::new(base.child("gss", "vol0"));
        e.add("objectClass", "GridStorageServerVolume");
        e.put("mountPoint", "/dev/sandbox");
        e.put_f64("totalSpace", 107374182400.0);
        e.put_f64("availableSpace", 0.0); // overwritten by provider
        e.put_f64("diskTransferRate", 20971520.0);
        e.put_f64("drdTime", 8.5);
        e.put_f64("dwrTime", 9.5);
        e
    }

    #[test]
    fn static_search_works() {
        let mut g = Gris::new("anl", "mcs");
        let base = g.base_dn().clone();
        g.add_entry(volume_entry(&base));
        let hits = g.search(
            &Dn::parse("o=grid").unwrap(),
            Scope::Sub,
            &Filter::parse("(objectClass=GridStorageServerVolume)").unwrap(),
        );
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].first("mountPoint").unwrap(), "/dev/sandbox");
    }

    #[test]
    fn provider_values_fresh_per_query() {
        let mut g = Gris::new("anl", "mcs");
        let base = g.base_dn().clone();
        let vol_dn = base.child("gss", "vol0");
        g.add_entry(volume_entry(&base));
        let counter = Arc::new(AtomicU64::new(0));
        let c2 = counter.clone();
        g.add_provider(
            &vol_dn,
            Arc::new(move || {
                let n = c2.fetch_add(1, Ordering::SeqCst) + 1;
                vec![("availableSpace".into(), format!("{}", n * 1000))]
            }),
        );
        let f = Filter::parse("(objectClass=GridStorageServerVolume)").unwrap();
        let root = Dn::parse("o=grid").unwrap();
        let h1 = g.search(&root, Scope::Sub, &f);
        let h2 = g.search(&root, Scope::Sub, &f);
        assert_eq!(h1[0].f64("availableSpace").unwrap(), 1000.0);
        assert_eq!(h2[0].f64("availableSpace").unwrap(), 2000.0);
    }

    #[test]
    fn filter_sees_dynamic_values() {
        let mut g = Gris::new("anl", "mcs");
        let base = g.base_dn().clone();
        let vol_dn = base.child("gss", "vol0");
        g.add_entry(volume_entry(&base));
        g.add_provider(
            &vol_dn,
            Arc::new(|| vec![("availableSpace".into(), "555".into())]),
        );
        let hit = g.search(
            &Dn::parse("o=grid").unwrap(),
            Scope::Sub,
            &Filter::parse("(availableSpace>=500)").unwrap(),
        );
        assert_eq!(hit.len(), 1);
        let miss = g.search(
            &Dn::parse("o=grid").unwrap(),
            Scope::Sub,
            &Filter::parse("(availableSpace>=600)").unwrap(),
        );
        assert!(miss.is_empty());
    }

    #[test]
    fn tree_renders_site_hierarchy() {
        let mut g = Gris::new("anl", "mcs");
        let base = g.base_dn().clone();
        g.add_entry(volume_entry(&base));
        let t = g.render_tree();
        assert!(t.contains("o=grid"));
        assert!(t.contains("o=anl"));
        assert!(t.contains("ou=mcs"));
        assert!(t.contains("gss=vol0"));
    }
}
