//! GRIS — the per-resource Grid Resource Information Service.
//!
//! Each storage site runs one (paper §3.1). Static attributes (seek
//! times, policies) come from the site's configuration; *dynamic*
//! attributes (availableSpace, load, bandwidth history) are produced at
//! query time by registered **providers** — the analog of the OpenLDAP
//! "shell backend" scripts the paper describes.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::dit::{Dit, Scope};
use super::entry::{Dn, Entry};
use super::filter::Filter;

/// A dynamic-attribute provider: returns `(attr, value)` pairs merged
/// into its entry at query time.
pub type Provider = Arc<dyn Fn() -> Vec<(String, String)> + Send + Sync>;

/// One cached provider materialization (see [`Gris::set_cache_ttl`]).
struct CachedMaterialization {
    generation: u64,
    filled_at: f64,
    entry: Entry,
}

/// A GRIS instance for one site.
pub struct Gris {
    /// Site identity: `ou=<site>, o=<org>, o=grid`.
    base_dn: Dn,
    site: String,
    /// Static portion of the tree.
    dit: Dit,
    /// Dynamic providers keyed by DN (DNs normalize attribute case at
    /// parse time, so direct keying avoids per-query string building —
    /// Perf log P3).
    providers: HashMap<Dn, Vec<Provider>>,
    /// Content generation: bumped whenever the tree or provider set
    /// changes, and by [`Gris::invalidate`]. Cached materializations
    /// from older generations are stale.
    generation: u64,
    /// Provider-output caching policy. `None` (the default) re-runs
    /// providers on every query — the paper's "up-to-date, detailed
    /// information" freshness contract. `Some(ttl)` caches provider
    /// output per `(dn, generation)` for `ttl` seconds of
    /// [`Gris::tick`] time (use `f64::INFINITY` for
    /// cache-until-invalidated).
    cache_ttl: Option<f64>,
    /// Logical clock advanced by [`Gris::tick`]; drives TTL expiry.
    clock: f64,
    cache: Mutex<HashMap<Dn, CachedMaterialization>>,
}

impl Gris {
    /// Create a GRIS rooted at `ou=<site>, o=<org>, o=grid` with the
    /// scaffolding entries of the Figure-3 DIT.
    pub fn new(org: &str, site: &str) -> Gris {
        let root = Dn::parse("o=grid").unwrap();
        let org_dn = root.child("o", org);
        let base_dn = org_dn.child("ou", site);
        let mut dit = Dit::new();
        let mut top = Entry::new(root.clone());
        top.add("objectClass", "GridTop");
        dit.add(top).unwrap();
        let mut o = Entry::new(org_dn.clone());
        o.add("objectClass", "GridOrganization");
        o.put("o", org);
        dit.add(o).unwrap();
        let mut ou = Entry::new(base_dn.clone());
        ou.add("objectClass", "GridOrganizationalUnit");
        ou.put("ou", site);
        dit.add(ou).unwrap();
        Gris {
            base_dn,
            site: site.to_string(),
            dit,
            providers: HashMap::new(),
            generation: 0,
            cache_ttl: None,
            clock: 0.0,
            cache: Mutex::new(HashMap::new()),
        }
    }

    pub fn base_dn(&self) -> &Dn {
        &self.base_dn
    }

    pub fn site(&self) -> &str {
        &self.site
    }

    /// Add a static entry under the site (ancestors must exist).
    pub fn add_entry(&mut self, entry: Entry) {
        self.dit
            .add_with_ancestors(entry)
            .expect("gris entry insert");
        self.generation += 1;
    }

    /// Attach a dynamic provider to the entry at `dn`.
    pub fn add_provider(&mut self, dn: &Dn, p: Provider) {
        self.providers.entry(dn.clone()).or_default().push(p);
        self.generation += 1;
    }

    /// The current content generation (changes whenever cached
    /// materializations become stale).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Mark all cached provider output stale: the next query re-runs
    /// every provider. (A generation bump — the explicit way for a site
    /// to signal "my dynamic state changed".)
    pub fn invalidate(&mut self) {
        self.generation += 1;
    }

    /// Enable (`Some(ttl_seconds)`) or disable (`None`) provider-output
    /// caching. With caching on, repeated broker fan-outs against an
    /// unchanged site stop paying the provider-run + merge cost; calls
    /// to [`Gris::invalidate`] / [`Gris::tick`] restore freshness.
    pub fn set_cache_ttl(&mut self, ttl: Option<f64>) {
        self.cache_ttl = ttl;
        self.cache.lock().unwrap().clear();
    }

    /// Advance the site's logical clock by `dt` seconds; cached
    /// provider output older than the configured TTL expires.
    ///
    /// Clock-discipline audit (ISSUE 5): unlike the original GIIS,
    /// this cache TTL was never wall-clock — `clock` is logical time
    /// the driver advances, so cache expiry is deterministic under
    /// simulation. [`Gris::advance_to`] mirrors
    /// `Topology::advance_to` for drivers that track absolute instants.
    pub fn tick(&mut self, dt: f64) {
        if dt > 0.0 {
            self.clock += dt;
        }
    }

    /// Advance the site's logical clock to the absolute instant `t`
    /// (no-op if already past it).
    pub fn advance_to(&mut self, t: f64) {
        if t > self.clock {
            self.clock = t;
        }
    }

    /// Run `entry`'s providers and merge their output.
    fn run_providers(e: &Entry, ps: &[Provider]) -> Entry {
        let mut out = e.clone();
        for p in ps {
            for (attr, value) in p() {
                out.put(&attr, value);
            }
        }
        out
    }

    /// Materialize an entry with its dynamic attributes applied,
    /// through the `(dn, generation)` cache when enabled.
    fn materialize(&self, e: &Entry) -> Entry {
        match self.providers.get(&e.dn) {
            None => e.clone(),
            Some(ps) => self.materialize_dynamic(e, ps),
        }
    }

    /// [`Gris::materialize`] for an entry whose provider list is
    /// already in hand (the search path looks it up exactly once).
    fn materialize_dynamic(&self, e: &Entry, ps: &[Provider]) -> Entry {
        let ttl = match self.cache_ttl {
            None => return Self::run_providers(e, ps),
            Some(ttl) => ttl,
        };
        {
            let cache = self.cache.lock().unwrap();
            if let Some(c) = cache.get(&e.dn) {
                if c.generation == self.generation && self.clock - c.filled_at < ttl {
                    return c.entry.clone();
                }
            }
        }
        // Providers run outside the cache lock (they are arbitrary
        // closures); a concurrent miss at worst runs them twice.
        let out = Self::run_providers(e, ps);
        self.cache.lock().unwrap().insert(
            e.dn.clone(),
            CachedMaterialization {
                generation: self.generation,
                filled_at: self.clock,
                entry: out.clone(),
            },
        );
        out
    }

    /// LDAP-style search with dynamic attributes resolved ("up-to-date,
    /// detailed information", paper §3).
    ///
    /// Entries without providers are filtered *by reference* and cloned
    /// only when they match; dynamic entries must materialize before
    /// filtering (provider output can affect the filter outcome).
    pub fn search(&self, base: &Dn, scope: Scope, filter: &Filter) -> Vec<Entry> {
        self.dit
            .iter()
            .filter(|e| match scope {
                Scope::Base => &e.dn == base,
                Scope::One => e.dn.parent().as_ref() == Some(base),
                Scope::Sub => e.dn.under(base),
            })
            .filter_map(|e| match self.providers.get(&e.dn) {
                Some(ps) => {
                    let m = self.materialize_dynamic(e, ps);
                    if filter.matches(&m) {
                        Some(m)
                    } else {
                        None
                    }
                }
                None => {
                    if filter.matches(e) {
                        Some(e.clone())
                    } else {
                        None
                    }
                }
            })
            .collect()
    }

    /// Snapshot the whole tree (dynamic attributes applied).
    pub fn snapshot(&self) -> Vec<Entry> {
        self.dit.iter().map(|e| self.materialize(e)).collect()
    }

    /// Render the live DIT (Figure 3 view).
    pub fn render_tree(&self) -> String {
        let mut d = Dit::new();
        for e in self.snapshot() {
            d.upsert(e);
        }
        d.render_tree()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn volume_entry(base: &Dn) -> Entry {
        let mut e = Entry::new(base.child("gss", "vol0"));
        e.add("objectClass", "GridStorageServerVolume");
        e.put("mountPoint", "/dev/sandbox");
        e.put_f64("totalSpace", 107374182400.0);
        e.put_f64("availableSpace", 0.0); // overwritten by provider
        e.put_f64("diskTransferRate", 20971520.0);
        e.put_f64("drdTime", 8.5);
        e.put_f64("dwrTime", 9.5);
        e
    }

    #[test]
    fn static_search_works() {
        let mut g = Gris::new("anl", "mcs");
        let base = g.base_dn().clone();
        g.add_entry(volume_entry(&base));
        let hits = g.search(
            &Dn::parse("o=grid").unwrap(),
            Scope::Sub,
            &Filter::parse("(objectClass=GridStorageServerVolume)").unwrap(),
        );
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].first("mountPoint").unwrap(), "/dev/sandbox");
    }

    #[test]
    fn provider_values_fresh_per_query() {
        let mut g = Gris::new("anl", "mcs");
        let base = g.base_dn().clone();
        let vol_dn = base.child("gss", "vol0");
        g.add_entry(volume_entry(&base));
        let counter = Arc::new(AtomicU64::new(0));
        let c2 = counter.clone();
        g.add_provider(
            &vol_dn,
            Arc::new(move || {
                let n = c2.fetch_add(1, Ordering::SeqCst) + 1;
                vec![("availableSpace".into(), format!("{}", n * 1000))]
            }),
        );
        let f = Filter::parse("(objectClass=GridStorageServerVolume)").unwrap();
        let root = Dn::parse("o=grid").unwrap();
        let h1 = g.search(&root, Scope::Sub, &f);
        let h2 = g.search(&root, Scope::Sub, &f);
        assert_eq!(h1[0].f64("availableSpace").unwrap(), 1000.0);
        assert_eq!(h2[0].f64("availableSpace").unwrap(), 2000.0);
    }

    #[test]
    fn filter_sees_dynamic_values() {
        let mut g = Gris::new("anl", "mcs");
        let base = g.base_dn().clone();
        let vol_dn = base.child("gss", "vol0");
        g.add_entry(volume_entry(&base));
        g.add_provider(
            &vol_dn,
            Arc::new(|| vec![("availableSpace".into(), "555".into())]),
        );
        let hit = g.search(
            &Dn::parse("o=grid").unwrap(),
            Scope::Sub,
            &Filter::parse("(availableSpace>=500)").unwrap(),
        );
        assert_eq!(hit.len(), 1);
        let miss = g.search(
            &Dn::parse("o=grid").unwrap(),
            Scope::Sub,
            &Filter::parse("(availableSpace>=600)").unwrap(),
        );
        assert!(miss.is_empty());
    }

    /// A GRIS whose provider counts its own invocations.
    fn counting_gris() -> (Gris, Arc<AtomicU64>) {
        let mut g = Gris::new("anl", "mcs");
        let base = g.base_dn().clone();
        let vol_dn = base.child("gss", "vol0");
        g.add_entry(volume_entry(&base));
        let counter = Arc::new(AtomicU64::new(0));
        let c2 = counter.clone();
        g.add_provider(
            &vol_dn,
            Arc::new(move || {
                let n = c2.fetch_add(1, Ordering::SeqCst) + 1;
                vec![("availableSpace".into(), format!("{}", n * 1000))]
            }),
        );
        (g, counter)
    }

    fn space_of(g: &Gris) -> f64 {
        let hits = g.search(
            &Dn::parse("o=grid").unwrap(),
            Scope::Sub,
            &Filter::parse("(objectClass=GridStorageServerVolume)").unwrap(),
        );
        hits[0].f64("availableSpace").unwrap()
    }

    #[test]
    fn cached_provider_output_reused_until_invalidated() {
        let (mut g, counter) = counting_gris();
        g.set_cache_ttl(Some(f64::INFINITY));
        assert_eq!(space_of(&g), 1000.0);
        assert_eq!(space_of(&g), 1000.0, "second query must hit the cache");
        assert_eq!(counter.load(Ordering::SeqCst), 1);
        g.invalidate();
        assert_eq!(space_of(&g), 2000.0, "invalidate() restores freshness");
        assert_eq!(space_of(&g), 2000.0);
        assert_eq!(counter.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn cache_ttl_expires_with_tick() {
        let (mut g, counter) = counting_gris();
        g.set_cache_ttl(Some(10.0));
        assert_eq!(space_of(&g), 1000.0);
        g.tick(5.0);
        assert_eq!(space_of(&g), 1000.0, "within TTL: cached");
        g.tick(6.0);
        assert_eq!(space_of(&g), 2000.0, "past TTL: re-materialized");
        assert_eq!(counter.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn structural_changes_bump_generation() {
        let (mut g, _) = counting_gris();
        g.set_cache_ttl(Some(f64::INFINITY));
        assert_eq!(space_of(&g), 1000.0);
        let g0 = g.generation();
        let mut extra = Entry::new(g.base_dn().clone().child("gss", "vol1"));
        extra.add("objectClass", "GridStorageServerVolume");
        extra.put_f64("availableSpace", 7.0);
        g.add_entry(extra);
        assert!(g.generation() > g0);
        // The cached vol0 materialization is stale now: re-runs.
        assert_eq!(space_of(&g), 2000.0);
    }

    #[test]
    fn disabling_cache_restores_per_query_freshness() {
        let (mut g, _) = counting_gris();
        g.set_cache_ttl(Some(f64::INFINITY));
        assert_eq!(space_of(&g), 1000.0);
        g.set_cache_ttl(None);
        assert_eq!(space_of(&g), 2000.0);
        assert_eq!(space_of(&g), 3000.0);
    }

    #[test]
    fn tree_renders_site_hierarchy() {
        let mut g = Gris::new("anl", "mcs");
        let base = g.base_dn().clone();
        g.add_entry(volume_entry(&base));
        let t = g.render_tree();
        assert!(t.contains("o=grid"));
        assert!(t.contains("o=anl"));
        assert!(t.contains("ou=mcs"));
        assert!(t.contains("gss=vol0"));
    }
}
