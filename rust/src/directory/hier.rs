//! Hierarchical MDS: per-site GRIS servers soft-state-registered into
//! one GIIS, with broad queries answered from the registrations' cached
//! snapshots and *drill-down* queries going to the live GRIS (ISSUE 5
//! tentpole).
//!
//! The paper's discovery pattern (§3) is two-level: a broker asks the
//! index ("which storage sites could serve this?") and then queries
//! the interesting sites directly for "up-to-date, detailed
//! information". [`HierarchicalDirectory`] packages that wiring for
//! the in-process grid:
//!
//! * [`HierarchicalDirectory::refresh_site`] re-registers one site —
//!   it runs the site's GRIS search *once*, caches the resulting
//!   entries in the GIIS registration ([`Registration::cached`]) and
//!   derives the coarse summary attributes broad `discover` filters
//!   match against. Until the next refresh, everything the GIIS says
//!   about the site is **stale by construction**: exactly as old as
//!   the registration.
//! * [`HierarchicalDirectory::cached`] is the broad path: no GRIS is
//!   touched, the answer comes from the soft-state snapshot (plus its
//!   age). Expired registrations answer nothing — an unreachable or
//!   churned-out site simply is not discovered, the EU-DataGrid
//!   failure mode the test suite pins.
//! * [`HierarchicalDirectory::drill_down`] queries the live GRIS
//!   (providers run now), and counts the query — the scarce resource
//!   this layer exists to conserve at hundreds of sites.
//!
//! All timestamps live on the simulated clock ([`SimInstant`]); the
//! driver advances it in lock-step with `Topology::now`.

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

use super::dit::Scope;
use super::entry::{format_f64, Dn, Entry};
use super::filter::Filter;
use super::giis::{Giis, SimInstant};
use super::gris::Gris;

/// Query accounting: what the discovery layer cost so far.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DiscoveryStats {
    /// Broad queries answered purely from GIIS soft state.
    pub broad_queries: u64,
    /// Fresh per-site GRIS queries (the expensive fan-out unit).
    pub drill_downs: u64,
    /// Site re-registrations (each runs one GRIS search to snapshot).
    pub refreshes: u64,
}

impl DiscoveryStats {
    /// Accumulate another directory's accounting — how a sharded run
    /// (ISSUE 8: one registration domain per broker shard) reports one
    /// grid-wide total over its per-shard directories.
    pub fn merge(&mut self, other: &DiscoveryStats) {
        self.broad_queries += other.broad_queries;
        self.drill_downs += other.drill_downs;
        self.refreshes += other.refreshes;
    }
}

/// Summary attributes lifted from a site's cached entries into the
/// registration, so broad `discover` filters can select on them.
const SUMMARY_ATTRS: [&str; 5] = [
    "availableSpace",
    "totalSpace",
    "load",
    "AvgRDBandwidth",
    "predictedRDBandwidth",
];

/// The storage search filter — what a broker Search fetches and
/// therefore exactly what registrations snapshot and drill-downs
/// return. ONE definition: the GIIS↔direct parity contract depends on
/// the hierarchical route capturing the same entry set the direct
/// route queries, so `Broker::search_filter` parses this same string.
pub const STORAGE_SEARCH_FILTER: &str = "(|(objectClass=GridStorageServerVolume)\
    (objectClass=GridStorageTransferBandwidth)\
    (objectClass=GridStorageSourceTransferBandwidth))";

/// Indices of `preds` in drill-down order: predicted bandwidth
/// descending, index ascending on ties. Shared by
/// `Broker::with_discovery`'s Search route and the open-loop
/// discovery driver so both routes drill the same sites for the same
/// stale view.
pub fn drill_order(preds: &[f64]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..preds.len()).collect();
    order.sort_by(|&a, &b| {
        preds[b]
            .partial_cmp(&preds[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    order
}

/// One GIIS over many GRIS handles (see module docs).
pub struct HierarchicalDirectory {
    giis: Giis,
    sites: BTreeMap<String, Arc<RwLock<Gris>>>,
    /// The storage filter whose results are snapshotted into
    /// registrations and returned by drill-downs — the same constant
    /// filter the broker's Search phase uses.
    filter: Filter,
    stats: DiscoveryStats,
}

impl HierarchicalDirectory {
    /// A directory whose registrations live `ttl` simulated seconds
    /// between refreshes.
    pub fn new(ttl: f64) -> HierarchicalDirectory {
        HierarchicalDirectory {
            giis: Giis::with_ttl(ttl),
            sites: BTreeMap::new(),
            filter: Filter::parse(STORAGE_SEARCH_FILTER).unwrap(),
            stats: DiscoveryStats::default(),
        }
    }

    /// Attach a site's GRIS. The site is *not* registered until its
    /// first [`Self::refresh_site`] — soft state must be pushed, never
    /// assumed.
    pub fn add_site(&mut self, site: &str, gris: Arc<RwLock<Gris>>) {
        self.sites.insert(site.to_string(), gris);
    }

    pub fn now(&self) -> SimInstant {
        self.giis.now()
    }

    /// Advance the simulated clock (lock-step with `Topology::now`).
    pub fn advance_to(&mut self, t: SimInstant) {
        self.giis.advance_to(t);
    }

    pub fn stats(&self) -> DiscoveryStats {
        self.stats
    }

    /// The underlying index (registration-level inspection).
    pub fn giis(&self) -> &Giis {
        &self.giis
    }

    /// Number of attached sites (registered or not).
    pub fn sites(&self) -> usize {
        self.sites.len()
    }

    /// Re-register `site`: snapshot its current GRIS answer into the
    /// GIIS. Returns false for an unknown site.
    pub fn refresh_site(&mut self, site: &str) -> bool {
        let Some(gris) = self.sites.get(site) else {
            return false;
        };
        let (base_dn, entries) = {
            let g = gris.read().unwrap();
            let entries = g.search(&Dn::parse("o=grid").unwrap(), Scope::Sub, &self.filter);
            (g.base_dn().clone(), entries)
        };
        let summary = summarize(&entries);
        self.stats.refreshes += 1;
        self.giis
            .register_cached(site, &format!("sim://{site}"), base_dn, summary, entries);
        true
    }

    /// Refresh every attached site (the periodic soft-state push).
    pub fn refresh_all(&mut self) {
        let names: Vec<String> = self.sites.keys().cloned().collect();
        for s in names {
            self.refresh_site(&s);
        }
    }

    /// Drop `site`'s registration (simulated registration churn: the
    /// site falls out of the index until its next refresh).
    pub fn unregister(&mut self, site: &str) -> bool {
        self.giis.unregister(site)
    }

    /// Count one broad query against the index. Callers answering a
    /// multi-site broad query via repeated [`Self::cached`] lookups
    /// charge it once, not per site.
    pub fn note_broad(&mut self) {
        self.stats.broad_queries += 1;
    }

    /// The broad path: `site`'s cached snapshot and its age in
    /// simulated seconds. `None` when the site never registered or its
    /// registration expired. Touches no GRIS.
    pub fn cached(&self, site: &str) -> Option<(&[Entry], f64)> {
        let r = self.giis.lookup(site)?;
        Some((r.cached(), r.age(self.giis.now())))
    }

    /// Degrade-chain accessor (ISSUE 7): `site`'s snapshot **even if
    /// the registration expired** — the stale-snapshot fallback a
    /// resilient broker consults when the live index answers nothing.
    /// `None` only when the site never registered at all. Normal broad
    /// discovery never serves expired state; callers opting into this
    /// accept arbitrarily old data over no data.
    pub fn cached_any(&self, site: &str) -> Option<(&[Entry], f64)> {
        let r = self.giis.lookup_any(site)?;
        Some((r.cached(), r.age(self.giis.now())))
    }

    /// Broad discovery over registration summaries (no GRIS touched):
    /// live registered site names matching `filter`, with ages.
    pub fn discover(&mut self, filter: &Filter) -> Vec<(String, f64)> {
        self.note_broad();
        let now = self.giis.now();
        self.giis
            .discover(filter)
            .into_iter()
            .map(|r| (r.site.clone(), r.age(now)))
            .collect()
    }

    /// The drill-down path: a fresh query against `site`'s live GRIS
    /// (dynamic providers run at this instant). Counted.
    pub fn drill_down(&mut self, site: &str) -> Option<Vec<Entry>> {
        let gris = self.sites.get(site)?;
        self.stats.drill_downs += 1;
        let g = gris.read().unwrap();
        Some(g.search(&Dn::parse("o=grid").unwrap(), Scope::Sub, &self.filter))
    }
}

/// Lift the coarse summary attributes out of a snapshot (first
/// occurrence wins; entries are site-local so duplicates agree).
fn summarize(entries: &[Entry]) -> Vec<(String, String)> {
    let mut out = Vec::new();
    for attr in SUMMARY_ATTRS {
        if let Some(v) = entries.iter().find_map(|e| e.f64(attr)) {
            out.push((attr.to_string(), format_f64(v)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// A site whose provider counts invocations and publishes a live
    /// value from shared state.
    fn counting_site(
        name: &str,
        value: Arc<RwLock<f64>>,
    ) -> (Arc<RwLock<Gris>>, Arc<AtomicU64>) {
        let mut g = Gris::new("org", name);
        let base = g.base_dn().clone();
        let vol = base.child("gss", "vol0");
        let mut e = Entry::new(vol.clone());
        e.add("objectClass", "GridStorageServerVolume");
        e.put_f64("totalSpace", 100.0);
        e.put_f64("availableSpace", 0.0);
        g.add_entry(e);
        let count = Arc::new(AtomicU64::new(0));
        let c = count.clone();
        g.add_provider(
            &vol,
            Arc::new(move || {
                c.fetch_add(1, Ordering::SeqCst);
                vec![(
                    "availableSpace".into(),
                    format_f64(*value.read().unwrap()),
                )]
            }),
        );
        (Arc::new(RwLock::new(g)), count)
    }

    #[test]
    fn broad_path_serves_the_snapshot_without_touching_gris() {
        let v = Arc::new(RwLock::new(10.0));
        let (gris, count) = counting_site("mcs", v.clone());
        let mut h = HierarchicalDirectory::new(300.0);
        h.add_site("mcs", gris);
        assert!(h.cached("mcs").is_none(), "nothing pushed yet");
        h.refresh_site("mcs");
        assert_eq!(count.load(Ordering::SeqCst), 1, "refresh runs providers once");
        *v.write().unwrap() = 99.0; // the site changes after the push
        let (cached, age) = h.cached("mcs").unwrap();
        assert_eq!(age, 0.0);
        let space = cached.iter().find_map(|e| e.f64("availableSpace")).unwrap();
        assert_eq!(space, 10.0, "broad answer is the stale snapshot");
        assert_eq!(count.load(Ordering::SeqCst), 1, "no GRIS touched");
        // Drill-down sees the live value and is counted.
        let fresh = h.drill_down("mcs").unwrap();
        let space = fresh.iter().find_map(|e| e.f64("availableSpace")).unwrap();
        assert_eq!(space, 99.0);
        assert_eq!(h.stats().drill_downs, 1);
        assert_eq!(count.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn expiry_hides_the_site_until_refresh() {
        let v = Arc::new(RwLock::new(1.0));
        let (gris, _) = counting_site("mcs", v);
        let mut h = HierarchicalDirectory::new(60.0);
        h.add_site("mcs", gris);
        h.refresh_site("mcs");
        h.advance_to(59.0);
        assert!(h.cached("mcs").is_some());
        h.advance_to(61.0);
        assert!(h.cached("mcs").is_none(), "expired soft state answers nothing");
        h.refresh_site("mcs");
        let (_, age) = h.cached("mcs").unwrap();
        assert_eq!(age, 0.0, "refresh restamps at the current instant");
    }

    #[test]
    fn cached_any_serves_expired_snapshots_with_their_true_age() {
        let v = Arc::new(RwLock::new(7.0));
        let (gris, _) = counting_site("mcs", v);
        let mut h = HierarchicalDirectory::new(60.0);
        h.add_site("mcs", gris);
        assert!(h.cached_any("mcs").is_none(), "never registered → nothing");
        h.refresh_site("mcs");
        h.advance_to(200.0);
        assert!(h.cached("mcs").is_none(), "expired for the normal path");
        let (entries, age) = h.cached_any("mcs").expect("degrade path still answers");
        assert_eq!(age, 200.0);
        let space = entries.iter().find_map(|e| e.f64("availableSpace")).unwrap();
        assert_eq!(space, 7.0, "the pre-expiry snapshot survives");
    }

    #[test]
    fn discover_matches_summary_attributes() {
        let small = Arc::new(RwLock::new(5.0));
        let big = Arc::new(RwLock::new(500.0));
        let (g1, _) = counting_site("small", small);
        let (g2, _) = counting_site("big", big);
        let mut h = HierarchicalDirectory::new(300.0);
        h.add_site("small", g1);
        h.add_site("big", g2);
        h.refresh_all();
        let hits = h.discover(&Filter::parse("(availableSpace>=100)").unwrap());
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].0, "big");
        assert_eq!(h.stats().broad_queries, 1);
        assert_eq!(h.stats().refreshes, 2);
    }
}
