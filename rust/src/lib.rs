//! # globus-replica
//!
//! A full reproduction of *“Replica Selection in the Globus Data Grid”*
//! (Vazhkudai, Tuecke & Foster, 2001) as a three-layer Rust + JAX/Pallas
//! system.
//!
//! The paper builds a **decentralized storage broker** that selects the best
//! replica of a logical file by (1) querying a **replica catalog**, (2)
//! pulling storage-system metadata from per-site **GRIS** directory servers
//! (Globus MDS / LDAP), (3) converting the LDIF results into Condor
//! **ClassAds** and matchmaking them against the application's request ad,
//! and (4) ranking matches — e.g. by available space or by predicted
//! transfer bandwidth derived from GridFTP instrumentation history.
//!
//! The repo-level `ARCHITECTURE.md` is the map of how these layers
//! stack, the kernel's event/determinism contract, the broker shard
//! boundary and the life of one request; `BENCHMARKS.md` documents
//! every recorded `BENCH_*.json` artifact. This crate doc is the
//! module-level index.
//!
//! Every substrate the paper depends on is implemented here:
//!
//! * [`classad`] — the Condor ClassAd language: lexer, parser, three-valued
//!   evaluator, `MatchClassAd` semantics, ranking.
//! * [`directory`] — an LDAP-lite MDS: DIT, object-class schema (Figures
//!   2–5 of the paper), search filters, LDIF, GRIS/GIIS servers with a TCP
//!   wire protocol. Discovery is hierarchical (`directory::hier`): sites
//!   soft-state-register into the GIIS on the *simulated* clock (TTL
//!   expiry and refresh churn are deterministic), brokers answer broad
//!   queries from the stale registration snapshots and drill down to live
//!   GRIS servers only for their top candidates, and at scale the
//!   per-site fan-out runs event-driven on the `simnet` kernel
//!   (`directory::fanout`: per-site latency, bounded in-flight
//!   concurrency, deadlines, straggler cutoff).
//! * [`catalog`] — replica catalog + application metadata repository.
//! * [`gridftp`] — a simulated GridFTP fabric with transfer instrumentation
//!   feeding per-source bandwidth history (paper §3.2).
//! * [`simnet`] — the time-varying wide-area network simulator standing in
//!   for the authors' testbed, including the open-loop discrete-event
//!   kernel (`simnet::engine`) under which many transfers are in flight
//!   at once, sharing site links and per-client downlinks — the
//!   contention regime the paper's dynamic-information thesis targets.
//!   The kernel's steady state is **allocation-free**: an arena-backed
//!   event queue (`simnet::arena`), struct-of-arrays flow columns with
//!   scratch-buffered bandwidth recomputes (`simnet::flows`), and
//!   capacity pre-sizing — 10⁵ concurrent flows without a heap
//!   allocation in the hot loop (`experiment::run_kernel` measures
//!   the events/sec this buys).
//!   Its failure model is **grid weather** (`simnet::weather`): seeded
//!   crash/recover and link-degrade/restore schedules over explicit
//!   `[at, heal_at)` intervals, against which every request path —
//!   transfers (timeout, exponential backoff, failover, byte-offset
//!   resume), directory fan-out (bounded query retry), broker discovery
//!   (live GIIS → stale snapshot → direct GRIS → blind degrade chain)
//!   and co-allocated streams (crash-then-recover revival) — carries
//!   end-to-end retry and failover, swept by `experiment::run_chaos`.
//! * [`forecast`] — NWS-style bandwidth predictor bank (pure Rust reference
//!   implementation).
//! * [`runtime`] — PJRT engine that loads the AOT-compiled JAX/Pallas
//!   forecast + rank kernels (`artifacts/*.hlo.txt`) onto the broker's hot
//!   path; Python never runs at request time.
//! * [`broker`] — the paper's contribution: the decentralized storage
//!   broker (Search / Match / Access phases) plus baseline selectors and a
//!   centralized-manager comparator. At scale the control plane
//!   **shards** along the registration hierarchy (`broker::shard`):
//!   contiguous site slices, each with its own GIIS registration
//!   domain and batched admissions, cross-shard consults only when a
//!   replica set spans shards — and the 1-shard configuration is
//!   pinned bit-identical to the unsharded driver
//!   (`experiment::run_quality_sharded`, `tests/it_shard.rs`).
//! * [`coalloc`] — co-allocated (striped) Access: a stripe planner that
//!   splits one logical file across the broker's top-K replicas in
//!   proportion to forecast bandwidth (clipped to the client downlink —
//!   no phantom parallelism), and a block scheduler with work-stealing
//!   rebalancing that drives the parallel streams through `simnet`'s
//!   concurrent-flow engine (the paper's §7 future work / Allcock et
//!   al. parallel-GridFTP direction). Survives churn: sources that die
//!   or stall mid-transfer fail over to survivors with bounded
//!   per-block retries and an exactly-once integrity check, and the
//!   write-direction dual — striped `store()` — creates replicas at
//!   several destinations in parallel.
//! * [`util`] — deterministic PRNG, unit parsing (`50G`, `75K/Sec`), JSON,
//!   micro-benchmark + property-test harnesses (the image has no network,
//!   so criterion/proptest equivalents are provided in-tree).
//! * [`trace`] — the flight recorder: causal per-request tracing and
//!   grid time-series sampling on the simulated clock, with JSONL and
//!   Perfetto (Chrome trace-event) exporters and the `trace-summary`
//!   critical-path analyzer behind the `globus-replica` binary.
//!
//! ## Reading a trace
//!
//! Every experiment runner can run with the flight recorder on
//! (`OpenLoopOptions { trace: TraceHandle::new(cap), sample_period,
//! .. }` or the `simulate --trace` subcommand); it then writes
//! `TRACE_<name>.json` (Chrome trace-event JSON — drag into
//! <https://ui.perfetto.dev> for one track per request and per site,
//! plus `in_flight` / `gate_depth` / `giis_live` / per-link
//! utilization counter series) and `TRACE_<name>.jsonl` (raw events).
//! A slow request is diagnosed without any UI, straight from the
//! artifact:
//!
//! ```text
//! $ globus-replica trace-summary TRACE_open_loop.json --top 3
//! requests 96 (skipped 4), dropped 0, min span coverage 100.0%
//! phase       p50        p95        mean
//! queue       0.000 s    41.3 s     12.9 s
//! discovery   0.240 s    0.310 s    0.251 s
//! transfer    155.1 s    402.7 s    182.4 s
//! ...
//! #1 slowest: req 4711  total 512.4 s = queue 301.2 + disc 0.3 + xfer 210.9
//!     0.0 s arrival | 0.0 s gate_park occupancy=32 | 301.2 s gate_unpark ...
//! ```
//!
//! The per-request chain is `arrival ──queue── admit ──discovery──
//! selection ──transfer── done`; the three spans partition the
//! request's simulated lifetime (coverage is exact by construction), so
//! "where did the time go" always has a complete answer: here, req 4711
//! was not slow at the chosen site — it sat 301 s in the admission
//! gate. `trace-summary` also recomputes the report's `mean_time` /
//! `p95_time` from the trace alone (same arithmetic as
//! `finish_report`), which pins the recorder against the aggregates it
//! explains.

pub mod broker;
pub mod catalog;
pub mod classad;
pub mod coalloc;
pub mod config;
pub mod directory;
pub mod experiment;
pub mod forecast;
pub mod gridftp;
pub mod metrics;
pub mod runtime;
pub mod simnet;
pub mod trace;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
