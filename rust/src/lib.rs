//! # globus-replica
//!
//! A full reproduction of *“Replica Selection in the Globus Data Grid”*
//! (Vazhkudai, Tuecke & Foster, 2001) as a three-layer Rust + JAX/Pallas
//! system.
//!
//! The paper builds a **decentralized storage broker** that selects the best
//! replica of a logical file by (1) querying a **replica catalog**, (2)
//! pulling storage-system metadata from per-site **GRIS** directory servers
//! (Globus MDS / LDAP), (3) converting the LDIF results into Condor
//! **ClassAds** and matchmaking them against the application's request ad,
//! and (4) ranking matches — e.g. by available space or by predicted
//! transfer bandwidth derived from GridFTP instrumentation history.
//!
//! Every substrate the paper depends on is implemented here:
//!
//! * [`classad`] — the Condor ClassAd language: lexer, parser, three-valued
//!   evaluator, `MatchClassAd` semantics, ranking.
//! * [`directory`] — an LDAP-lite MDS: DIT, object-class schema (Figures
//!   2–5 of the paper), search filters, LDIF, GRIS/GIIS servers with a TCP
//!   wire protocol. Discovery is hierarchical (`directory::hier`): sites
//!   soft-state-register into the GIIS on the *simulated* clock (TTL
//!   expiry and refresh churn are deterministic), brokers answer broad
//!   queries from the stale registration snapshots and drill down to live
//!   GRIS servers only for their top candidates, and at scale the
//!   per-site fan-out runs event-driven on the `simnet` kernel
//!   (`directory::fanout`: per-site latency, bounded in-flight
//!   concurrency, deadlines, straggler cutoff).
//! * [`catalog`] — replica catalog + application metadata repository.
//! * [`gridftp`] — a simulated GridFTP fabric with transfer instrumentation
//!   feeding per-source bandwidth history (paper §3.2).
//! * [`simnet`] — the time-varying wide-area network simulator standing in
//!   for the authors' testbed, including the open-loop discrete-event
//!   kernel (`simnet::engine`) under which many transfers are in flight
//!   at once, sharing site links and per-client downlinks — the
//!   contention regime the paper's dynamic-information thesis targets.
//! * [`forecast`] — NWS-style bandwidth predictor bank (pure Rust reference
//!   implementation).
//! * [`runtime`] — PJRT engine that loads the AOT-compiled JAX/Pallas
//!   forecast + rank kernels (`artifacts/*.hlo.txt`) onto the broker's hot
//!   path; Python never runs at request time.
//! * [`broker`] — the paper's contribution: the decentralized storage
//!   broker (Search / Match / Access phases) plus baseline selectors and a
//!   centralized-manager comparator.
//! * [`coalloc`] — co-allocated (striped) Access: a stripe planner that
//!   splits one logical file across the broker's top-K replicas in
//!   proportion to forecast bandwidth (clipped to the client downlink —
//!   no phantom parallelism), and a block scheduler with work-stealing
//!   rebalancing that drives the parallel streams through `simnet`'s
//!   concurrent-flow engine (the paper's §7 future work / Allcock et
//!   al. parallel-GridFTP direction). Survives churn: sources that die
//!   or stall mid-transfer fail over to survivors with bounded
//!   per-block retries and an exactly-once integrity check, and the
//!   write-direction dual — striped `store()` — creates replicas at
//!   several destinations in parallel.
//! * [`util`] — deterministic PRNG, unit parsing (`50G`, `75K/Sec`), JSON,
//!   micro-benchmark + property-test harnesses (the image has no network,
//!   so criterion/proptest equivalents are provided in-tree).

pub mod broker;
pub mod catalog;
pub mod classad;
pub mod coalloc;
pub mod config;
pub mod directory;
pub mod experiment;
pub mod forecast;
pub mod gridftp;
pub mod metrics;
pub mod runtime;
pub mod simnet;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
