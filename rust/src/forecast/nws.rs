//! NWS-style predictive information service (paper §7).
//!
//! "Finally, the statistical information published by the storage
//! resource can be fed to an information service, such as the Network
//! Weather Service, to perform predictive analysis of the behavior of
//! storage resources."
//!
//! [`PredictiveFeed`] closes that loop: it owns the per-(site, source)
//! forecast state, ingests the instrumentation stream, and exposes a
//! GRIS provider that publishes `predictedRDBandwidth`,
//! `predictionError` (RMS of the chosen forecaster's backtest) and
//! `predictor` (which bank member is currently winning) — so *any*
//! broker, not just ours, can rank on predictions with a plain LDAP
//! query.

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

use crate::directory::gris::Provider;
use crate::gridftp::HistoryStore;

use super::predictors::forecast_bank;

/// Names of the bank members, indexed like the predictor axis
/// (mirrors `python/compile/kernels/common.py`).
pub const PREDICTOR_NAMES: [&str; 8] = [
    "last_value",
    "running_mean",
    "sliding_mean_4",
    "sliding_mean_16",
    "ema_0.10",
    "ema_0.30",
    "ema_0.60",
    "median_3",
];

/// One site's published prediction.
#[derive(Debug, Clone, PartialEq)]
pub struct Prediction {
    /// Predicted read bandwidth toward `source`, bytes/s.
    pub bandwidth: f64,
    /// RMS backtest error of the chosen forecaster.
    pub rms_error: f64,
    /// Winning bank member.
    pub predictor: &'static str,
    /// Observations backing the prediction.
    pub samples: usize,
}

/// The predictive feed for one site's GRIS.
pub struct PredictiveFeed {
    history: Arc<RwLock<HistoryStore>>,
    /// Cache: source → (history length at compute time, prediction).
    cache: RwLock<BTreeMap<String, (usize, Prediction)>>,
}

impl PredictiveFeed {
    pub fn new(history: Arc<RwLock<HistoryStore>>) -> Arc<PredictiveFeed> {
        Arc::new(PredictiveFeed { history, cache: RwLock::new(BTreeMap::new()) })
    }

    /// Current prediction toward `source` (None with no history).
    /// Recomputed only when new observations arrived.
    pub fn predict(&self, source: &str) -> Option<Prediction> {
        let (window, count) = {
            let h = self.history.read().unwrap();
            let src = h.source(source)?;
            (src.window(), src.stats.count as usize)
        };
        if window.is_empty() {
            return None;
        }
        if let Some((seen, pred)) = self.cache.read().unwrap().get(source) {
            if *seen == count {
                return Some(pred.clone());
            }
        }
        let mask = vec![1.0; window.len()];
        let bank = forecast_bank(&window, &mask);
        let best = bank.best_index();
        let pred = Prediction {
            bandwidth: bank.preds[best],
            rms_error: bank.mses[best].sqrt(),
            predictor: PREDICTOR_NAMES[best],
            samples: window.len(),
        };
        self.cache
            .write()
            .unwrap()
            .insert(source.to_string(), (count, pred.clone()));
        Some(pred)
    }

    /// A GRIS provider publishing the prediction toward `source` as
    /// directory attributes (attach to the site's Figure-5 entry).
    pub fn provider(self: &Arc<Self>, source: &str) -> Provider {
        let feed = self.clone();
        let source = source.to_string();
        Arc::new(move || match feed.predict(&source) {
            None => vec![],
            Some(p) => vec![
                (
                    "predictedRDBandwidth".to_string(),
                    crate::directory::entry::format_f64(p.bandwidth),
                ),
                (
                    "predictionError".to_string(),
                    crate::directory::entry::format_f64(p.rms_error),
                ),
                ("predictor".to_string(), p.predictor.to_string()),
                ("predictionSamples".to_string(), p.samples.to_string()),
            ],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gridftp::history::{Direction, TransferRecord};

    fn feed_with(bws: &[f64]) -> (Arc<PredictiveFeed>, Arc<RwLock<HistoryStore>>) {
        let h = Arc::new(RwLock::new(HistoryStore::new("anl", 32)));
        for (i, bw) in bws.iter().enumerate() {
            h.write().unwrap().record(TransferRecord {
                at: i as f64,
                peer: "client".into(),
                direction: Direction::Read,
                bytes: *bw,
                duration: 1.0,
            });
        }
        (PredictiveFeed::new(h.clone()), h)
    }

    #[test]
    fn no_history_no_prediction() {
        let (feed, _) = feed_with(&[]);
        assert!(feed.predict("client").is_none());
        assert!(feed.predict("stranger").is_none());
    }

    #[test]
    fn stable_series_predicts_the_level() {
        let (feed, _) = feed_with(&[50e3; 12]);
        let p = feed.predict("client").unwrap();
        assert!((p.bandwidth - 50e3).abs() < 1.0);
        assert!(p.rms_error < 1.0);
        assert_eq!(p.samples, 12);
    }

    #[test]
    fn cache_invalidates_on_new_transfers() {
        let (feed, h) = feed_with(&[50e3; 8]);
        let p1 = feed.predict("client").unwrap();
        // Same history -> cached object.
        assert_eq!(feed.predict("client").unwrap(), p1);
        // New observation at a different level -> prediction moves.
        h.write().unwrap().record(TransferRecord {
            at: 99.0,
            peer: "client".into(),
            direction: Direction::Read,
            bytes: 200e3,
            duration: 1.0,
        });
        let p2 = feed.predict("client").unwrap();
        assert_ne!(p1, p2);
        assert!(p2.bandwidth > p1.bandwidth);
    }

    #[test]
    fn provider_publishes_attributes() {
        let (feed, _) = feed_with(&[10e3, 12e3, 11e3, 13e3]);
        let p = feed.provider("client");
        let attrs: std::collections::BTreeMap<String, String> = p().into_iter().collect();
        assert!(attrs.contains_key("predictedRDBandwidth"));
        assert!(attrs.contains_key("predictionError"));
        assert!(PREDICTOR_NAMES.contains(&attrs["predictor"].as_str()));
        assert_eq!(attrs["predictionSamples"], "4");
        // Unknown source publishes nothing (entry stays as-is).
        let p2 = feed.provider("stranger");
        assert!(p2().is_empty());
    }

    #[test]
    fn predictor_name_is_meaningful() {
        // A spiky series should select a robust predictor, and its name
        // must come from the shared bank layout.
        let mut bws = vec![80e3; 20];
        bws[5] = 2e3;
        bws[12] = 3e3;
        let (feed, _) = feed_with(&bws);
        let p = feed.predict("client").unwrap();
        assert!(PREDICTOR_NAMES.contains(&p.predictor));
        // Prediction should be near the 80e3 level, not dragged to the
        // collapse values.
        assert!(p.bandwidth > 60e3, "bandwidth {}", p.bandwidth);
    }
}
