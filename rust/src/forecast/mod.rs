//! Bandwidth forecasting — the NWS-style predictor bank (paper §3.2/§7).
//!
//! The paper favours "historical information concerning data transfer
//! rates ... as a predictor of future transfer times" and points at the
//! Network Weather Service for the statistical machinery. This module
//! is the pure-Rust reference implementation of the same predictor bank
//! the L1 Pallas kernel computes (`python/compile/kernels/forecast.py`);
//! the two are cross-validated bit-for-bit-ish (f32 vs f64 tolerance) in
//! `rust/tests/it_runtime_artifacts.rs`. The broker uses this path when
//! artifacts are absent and the PJRT path (`crate::runtime`) when built.

pub mod nws;
pub mod predictors;

pub use nws::{PredictiveFeed, Prediction};
pub use predictors::{forecast_bank, AdaptiveForecast, BankOutput, NUM_PREDICTORS};
