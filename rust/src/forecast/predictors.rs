//! The predictor bank. Index layout MUST match
//! `python/compile/kernels/common.py`:
//!
//! | idx | predictor              | parameter |
//! |-----|------------------------|-----------|
//! | 0   | last value             | —         |
//! | 1   | running mean           | full      |
//! | 2   | sliding mean           | w = 4     |
//! | 3   | sliding mean           | w = 16    |
//! | 4   | exponential smoothing  | α = 0.10  |
//! | 5   | exponential smoothing  | α = 0.30  |
//! | 6   | exponential smoothing  | α = 0.60  |
//! | 7   | median-of-3            | last 3    |

/// Number of predictors in the bank.
pub const NUM_PREDICTORS: usize = 8;

/// Sliding-window widths (predictors 2, 3).
pub const WINDOW_SHORT: usize = 4;
pub const WINDOW_LONG: usize = 16;

/// EMA gains (predictors 4–6).
pub const EMA_ALPHAS: [f64; 3] = [0.10, 0.30, 0.60];

/// Output of one site's bank evaluation.
#[derive(Debug, Clone, PartialEq)]
pub struct BankOutput {
    /// Final prediction of each forecaster.
    pub preds: [f64; NUM_PREDICTORS],
    /// Backtest MSE of each forecaster over the window.
    pub mses: [f64; NUM_PREDICTORS],
}

impl BankOutput {
    /// Index of the minimum-MSE forecaster (ties → lowest index, same
    /// as `jnp.argmin`).
    pub fn best_index(&self) -> usize {
        let mut best = 0;
        for i in 1..NUM_PREDICTORS {
            if self.mses[i] < self.mses[best] {
                best = i;
            }
        }
        best
    }

    /// The adaptive prediction: the min-MSE forecaster's value.
    pub fn best(&self) -> f64 {
        self.preds[self.best_index()]
    }
}

#[derive(Debug, Clone, Default)]
struct State {
    count: f64,
    last: f64,
    total: f64,
    last3: [f64; 3],
    ema: [f64; 3],
}

fn predict(s: &State, hist: &[f64], mask: &[f64], t: usize) -> [f64; NUM_PREDICTORS] {
    let mut p = [0.0; NUM_PREDICTORS];
    if s.count <= 0.0 {
        return p;
    }
    p[0] = s.last;
    p[1] = s.total / s.count.max(1.0);
    for (slot, w) in [(2usize, WINDOW_SHORT), (3, WINDOW_LONG)] {
        let lo = t.saturating_sub(w);
        let mut n = 0.0;
        let mut sum = 0.0;
        for i in lo..t {
            sum += hist[i] * mask[i];
            n += mask[i];
        }
        p[slot] = if n > 0.0 { sum / n } else { s.last };
    }
    for i in 0..3 {
        p[4 + i] = s.ema[i];
    }
    p[7] = if s.count >= 3.0 {
        let mut v = s.last3;
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[1]
    } else if s.count == 2.0 {
        (s.last3[1] + s.last3[2]) / 2.0
    } else {
        s.last
    };
    p
}

fn update(s: &mut State, x: f64, m: f64) {
    if m <= 0.5 {
        return;
    }
    let first = s.count == 0.0;
    s.total += x;
    if first {
        s.last3 = [x, x, x];
        s.ema = [x, x, x];
    } else {
        s.last3 = [s.last3[1], s.last3[2], x];
        for (i, a) in EMA_ALPHAS.iter().enumerate() {
            s.ema[i] = (1.0 - a) * s.ema[i] + a * x;
        }
    }
    s.last = x;
    s.count += 1.0;
}

/// Run the bank over one site's masked window (oldest → newest); the
/// exact semantics of `compile.kernels.ref.forecast_ref`.
pub fn forecast_bank(hist: &[f64], mask: &[f64]) -> BankOutput {
    assert_eq!(hist.len(), mask.len());
    let mut s = State::default();
    let mut err = [0.0; NUM_PREDICTORS];
    let mut nerr = 0.0f64;
    for t in 0..hist.len() {
        let (x, m) = (hist[t], mask[t]);
        if m > 0.5 && s.count > 0.0 {
            let p = predict(&s, hist, mask, t);
            for i in 0..NUM_PREDICTORS {
                let d = p[i] - x;
                err[i] += d * d;
            }
            nerr += 1.0;
        }
        update(&mut s, x, m);
    }
    let denom = nerr.max(1.0);
    let mut mses = [0.0; NUM_PREDICTORS];
    for i in 0..NUM_PREDICTORS {
        mses[i] = err[i] / denom;
    }
    BankOutput { preds: predict(&s, hist, mask, hist.len()), mses }
}

/// Convenience wrapper for unmasked observation vectors.
pub fn forecast_dense(obs: &[f64]) -> BankOutput {
    let mask = vec![1.0; obs.len()];
    forecast_bank(obs, &mask)
}

/// Streaming adaptive forecaster for one (site, client) stream — the
/// incremental API the broker uses between GRIS refreshes.
#[derive(Debug, Clone, Default)]
pub struct AdaptiveForecast {
    obs: Vec<f64>,
    capacity: usize,
}

impl AdaptiveForecast {
    pub fn new(capacity: usize) -> Self {
        AdaptiveForecast { obs: Vec::new(), capacity: capacity.max(1) }
    }

    pub fn observe(&mut self, bw: f64) {
        self.obs.push(bw);
        if self.obs.len() > self.capacity {
            let drop = self.obs.len() - self.capacity;
            self.obs.drain(..drop);
        }
    }

    pub fn len(&self) -> usize {
        self.obs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.obs.is_empty()
    }

    /// Current adaptive prediction (None with no history).
    pub fn predict(&self) -> Option<f64> {
        if self.obs.is_empty() {
            None
        } else {
            Some(forecast_dense(&self.obs).best())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_history_predicts_zero() {
        let out = forecast_bank(&[], &[]);
        assert_eq!(out.preds, [0.0; NUM_PREDICTORS]);
        assert_eq!(out.mses, [0.0; NUM_PREDICTORS]);
    }

    #[test]
    fn single_observation_everywhere() {
        let out = forecast_bank(&[0.0, 42.0, 0.0], &[0.0, 1.0, 0.0]);
        for p in out.preds {
            assert_eq!(p, 42.0);
        }
        assert_eq!(out.mses, [0.0; NUM_PREDICTORS]);
    }

    #[test]
    fn constant_series_zero_mse() {
        let obs = vec![7.0; 20];
        let out = forecast_dense(&obs);
        for p in out.preds {
            assert!((p - 7.0).abs() < 1e-12);
        }
        for m in out.mses {
            assert!(m.abs() < 1e-12);
        }
    }

    #[test]
    fn last_value_and_running_mean() {
        let out = forecast_dense(&[10.0, 20.0, 30.0, 40.0]);
        assert_eq!(out.preds[0], 40.0);
        assert_eq!(out.preds[1], 25.0);
    }

    #[test]
    fn sliding_means() {
        let obs: Vec<f64> = (1..=12).map(|x| x as f64).collect();
        let out = forecast_dense(&obs);
        assert_eq!(out.preds[2], (9.0 + 10.0 + 11.0 + 12.0) / 4.0);
        assert_eq!(out.preds[1], 6.5);
    }

    #[test]
    fn median_rejects_spike() {
        let mut obs = vec![50.0; 10];
        obs.extend([5000.0, 50.0, 50.0]);
        let out = forecast_dense(&obs);
        assert_eq!(out.preds[7], 50.0);
        // last-value also fine here; EMA 0.6 got dragged up.
        assert!(out.preds[6] > 50.0);
    }

    #[test]
    fn ema_ordering_after_step() {
        let mut obs = vec![10.0; 16];
        obs.extend(vec![100.0; 8]);
        let out = forecast_dense(&obs);
        assert!(out.preds[4] < out.preds[5]);
        assert!(out.preds[5] < out.preds[6]);
        assert!(out.preds[6] > 90.0);
    }

    #[test]
    fn adaptive_prefers_mean_on_white_noise() {
        // Deterministic pseudo-noise around 50.
        let mut rng = crate::util::prng::Rng::new(5);
        let obs: Vec<f64> = (0..64).map(|_| rng.gauss(50.0, 5.0)).collect();
        let out = forecast_dense(&obs);
        let best = out.best_index();
        // An averaging predictor (running/long-window mean or an EMA)
        // should win over last-value on white noise.
        assert!(out.mses[best] <= out.mses[0]);
        assert!([1usize, 3, 4, 5].contains(&best), "best {best}");
        assert!(out.mses[1] < out.mses[0], "mean must beat last-value");
    }

    #[test]
    fn adaptive_prefers_fast_tracker_on_random_walk() {
        let mut rng = crate::util::prng::Rng::new(6);
        let mut x = 500.0;
        let obs: Vec<f64> = (0..64)
            .map(|_| {
                x += rng.gauss(0.0, 30.0);
                x
            })
            .collect();
        let out = forecast_dense(&obs);
        let best = out.best_index();
        // Last-value / fast EMA / short mean family tracks a walk best.
        assert!([0usize, 2, 5, 6, 7].contains(&best), "best {best}");
    }

    #[test]
    fn masked_slots_do_not_perturb() {
        let hist = [10.0, 999.0, 20.0, 999.0, 30.0];
        let mask = [1.0, 0.0, 1.0, 0.0, 1.0];
        let dense = forecast_dense(&[10.0, 20.0, 30.0]);
        let masked = forecast_bank(&hist, &mask);
        // Predictors that only depend on the valid subsequence agree:
        assert_eq!(masked.preds[0], dense.preds[0]);
        assert_eq!(masked.preds[1], dense.preds[1]);
        assert_eq!(masked.preds[4], dense.preds[4]);
        assert_eq!(masked.preds[7], dense.preds[7]);
    }

    #[test]
    fn streaming_wrapper_trims_and_predicts() {
        let mut f = AdaptiveForecast::new(8);
        assert!(f.predict().is_none());
        for i in 0..20 {
            f.observe(100.0 + i as f64);
        }
        assert_eq!(f.len(), 8);
        let p = f.predict().unwrap();
        assert!(p > 100.0 && p < 130.0);
    }
}
