//! `globus-replica` — CLI launcher for the replica-selection system.
//!
//! Subcommands:
//!
//! * `schema`   — print the paper's object classes (Figures 2/4/5) and
//!   the DIT skeleton (Figure 3).
//! * `gris`     — run a storage-site GRIS daemon on a TCP port.
//! * `giis`     — run a GIIS index daemon.
//! * `select`   — one decentralized selection against a generated
//!   in-process grid (prints the Figure-6 phase trace).
//! * `simulate` — pointer to the end-to-end workload simulation
//!   (`examples/datagrid_sim`); with `--trace`, runs a flight-recorded
//!   open-loop scenario here and writes `TRACE_*.json` artifacts.
//! * `chaos`    — grid-weather sweep (ISSUE 7): replays one seeded
//!   request trace under seeded crash/flap schedules, once per recovery
//!   policy (fail-fast / retry / retry+failover), and reports the
//!   completion-rate gap. Fully deterministic: same flags, same output.
//! * `kernel`   — one kernel-throughput point (ISSUE 8): a same-instant
//!   surge to the requested concurrency on the sharded control plane,
//!   reporting events/sec; `--out` writes the JSON point.
//! * `economy`  — replica-economy sweep (ISSUE 10): identical demand
//!   traces (flash crowd / diurnal shift / cold start) replayed with
//!   placement frozen vs the popularity-driven economy ticking inside
//!   the kernel, reporting hit-rate-at-nearest-replica, mean time and
//!   bytes moved. Fully deterministic: same flags, same output.
//! * `trace-summary` — critical-path analysis of an exported trace
//!   (per-phase p50/p95 breakdown, report parity, slowest requests).
//!
//! Run `globus-replica help` for flags.

use std::sync::{Arc, Mutex, RwLock};

use globus_replica::broker::{
    parse_request_ad, Broker, LocalInfoService, RankPolicy, SelectorKind,
};
use globus_replica::catalog::{PhysicalLocation, ReplicaCatalog};
use globus_replica::config::GridConfig;
use globus_replica::directory::schema;
use globus_replica::directory::server::DirectoryServer;
use globus_replica::directory::{Entry, Giis, Gris};
use globus_replica::broker::EconomyOptions;
use globus_replica::experiment::{
    run_chaos, run_economy, run_kernel, run_quality_open, ChaosArm, ChaosOptions, EconomyArm,
    EconomySweepOptions, KernelOptions, OpenLoopOptions, RetryOptions, ShardOptions,
};
use globus_replica::metrics::Metrics;
use globus_replica::simnet::{WeatherSpec, Workload, WorkloadSpec};
use globus_replica::trace::{load_trace, summarize, TraceHandle, TraceSummary};
use globus_replica::util::cli::Args;
use globus_replica::util::units::Bytes;

const USAGE: &str = "\
globus-replica <command> [flags]

commands:
  schema                         print Figures 2-5 object classes + DIT
  gris   --site S --org O --port P   run a GRIS daemon
  giis   --port P                run a GIIS daemon
  select [--sites N] [--seed K] [--policy classad|forecast]
                                 one brokered selection w/ phase trace
  simulate [--sites N] [--requests R] [--seed K]
           [--trace [--sample-period S] [--trace-name NAME]]
                                 workload simulation; --trace runs a
                                 flight-recorded open-loop and writes
                                 TRACE_NAME.json + TRACE_NAME.jsonl
  chaos    [--sites N] [--requests R] [--seed K] [--weather-seed W]
           [--weather calm|breeze|storm|hurricane|all] [--out FILE]
                                 fault-intensity x recovery-policy sweep
                                 (fail-fast / retry / retry+failover) on
                                 identically seeded grids; --out writes
                                 the deterministic JSON report
  kernel   [--surge N] [--trickle N] [--sites N] [--shards N]
           [--batch N] [--window S] [--steady-events N] [--seed K]
           [--out FILE]
                                 one kernel-throughput point: surge to N
                                 concurrent transfers on the sharded
                                 control plane, report events/sec
  economy  [--sites N] [--requests R] [--seed K] [--replicas N]
           [--warm N] [--period S] [--half-life S] [--threshold X]
           [--budget-frac F] [--out FILE]
                                 static placement vs the replica economy
                                 on identical traces (flash crowd /
                                 diurnal shift / cold start); --out
                                 writes the deterministic JSON report
  trace-summary <file> [--top N] [--metrics] [--json]
                                 critical-path breakdown of a
                                 TRACE_*.json / .jsonl artifact
  help                           this text
";

fn main() {
    let args = Args::from_env();
    let cmd = args.positional().first().cloned().unwrap_or_default();
    match cmd.as_str() {
        "schema" => cmd_schema(),
        "gris" => cmd_gris(&args),
        "giis" => cmd_giis(&args),
        "select" => cmd_select(&args),
        "simulate" => cmd_simulate(&args),
        "chaos" => cmd_chaos(&args),
        "economy" => cmd_economy(&args),
        "kernel" => cmd_kernel(&args),
        "trace-summary" => cmd_trace_summary(&args),
        _ => print!("{USAGE}"),
    }
}

fn cmd_schema() {
    println!("# Figure 2 — system configuration metadata\n");
    println!("{}", schema::SERVER_VOLUME.render());
    println!("# Figure 4 — site-wide transfer bandwidth\n");
    println!("{}", schema::TRANSFER_BANDWIDTH.render());
    println!("# Figure 5 — per-source transfer bandwidth\n");
    println!("{}", schema::SOURCE_TRANSFER_BANDWIDTH.render());
    println!("# Figure 3 — DIT levels\n");
    for (i, level) in schema::dit_levels().iter().enumerate() {
        println!("{}{}", "  ".repeat(i), level);
    }
}

fn cmd_gris(args: &Args) {
    let site = args.str_or("site", "mcs");
    let org = args.str_or("org", "anl");
    let port = args.u64_or("port", 0) as u16;
    let mut gris = Gris::new(&org, &site);
    let base = gris.base_dn().clone();
    // A demo volume; a real deployment would load site config here.
    let mut e = Entry::new(base.child("gss", "vol0"));
    e.add("objectClass", "GridStorageServerVolume");
    e.put_f64("totalSpace", 100.0 * 1024f64.powi(3));
    e.put_f64("availableSpace", 50.0 * 1024f64.powi(3));
    e.put("mountPoint", "/dev/sandbox");
    e.put_f64("diskTransferRate", 2e7);
    e.put_f64("drdTime", 8.0);
    e.put_f64("dwrTime", 9.0);
    gris.add_entry(e);
    let server = DirectoryServer::spawn(Arc::new(Mutex::new(gris)), port).expect("bind");
    println!("GRIS for {org}/{site} listening on {}", server.addr());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_giis(args: &Args) {
    let port = args.u64_or("port", 0) as u16;
    let giis = Giis::new();
    let server = DirectoryServer::spawn(Arc::new(Mutex::new(giis)), port).expect("bind");
    println!("GIIS listening on {}", server.addr());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Build an in-process demo grid: catalog + one GRIS per site.
fn demo_grid(
    n: usize,
    seed: u64,
) -> (Arc<Mutex<ReplicaCatalog>>, Arc<LocalInfoService>, GridConfig) {
    let cfg = GridConfig::generate(n, seed);
    let mut catalog = ReplicaCatalog::new();
    catalog
        .create_logical("run42.dat", Bytes::from_gb(2.0), "cms")
        .unwrap();
    let mut info = LocalInfoService::new();
    let mut rng = globus_replica::util::prng::Rng::new(seed ^ 0xDE40);
    for sc in &cfg.sites {
        catalog
            .add_replica(
                "run42.dat",
                PhysicalLocation {
                    site: sc.name.clone(),
                    url: format!("gsiftp://{}/run42.dat", sc.name),
                },
            )
            .unwrap();
        let mut gris = Gris::new(&sc.org, &sc.name);
        let base = gris.base_dn().clone();
        let vol = base.child("gss", "vol0");
        let mut e = Entry::new(vol.clone());
        e.add("objectClass", "GridStorageServerVolume");
        e.put_f64("totalSpace", sc.total_space);
        e.put_f64("availableSpace", sc.total_space * (1.0 - sc.used_frac));
        e.put("mountPoint", "/data");
        e.put_f64("diskTransferRate", sc.disk_rate);
        e.put_f64("drdTime", sc.drd_time_ms);
        e.put_f64("dwrTime", sc.dwr_time_ms);
        e.put_f64("load", rng.range(0.0, 0.6));
        gris.add_entry(e);
        let mut bw = Entry::new(vol.child("gss", "bw"));
        bw.add("objectClass", "GridStorageTransferBandwidth");
        for a in ["MaxRDBandwidth", "AvgRDBandwidth"] {
            bw.put_f64(a, sc.wan_bandwidth);
        }
        for a in ["MinRDBandwidth", "MaxWRBandwidth", "MinWRBandwidth", "AvgWRBandwidth"] {
            bw.put_f64(a, sc.wan_bandwidth * 0.5);
        }
        gris.add_entry(bw);
        let mut src = Entry::new(vol.child("gss", "src"));
        src.add("objectClass", "GridStorageSourceTransferBandwidth");
        src.put_f64("lastRDBandwidth", sc.wan_bandwidth);
        src.put("lastRDurl", "gsiftp://client/");
        src.put_f64("lastWRBandwidth", sc.wan_bandwidth * 0.4);
        src.put("lastWRurl", "gsiftp://client/");
        let hist: Vec<String> = (0..8)
            .map(|_| format!("{:.0}", sc.wan_bandwidth * rng.range(0.6, 1.2)))
            .collect();
        src.put("rdHistory", hist.join(","));
        gris.add_entry(src);
        info.add(&sc.name, Arc::new(RwLock::new(gris)));
    }
    (Arc::new(Mutex::new(catalog)), Arc::new(info), cfg)
}

fn cmd_select(args: &Args) {
    let n = args.usize_or("sites", 6);
    let seed = args.u64_or("seed", 42);
    let policy = match args.str_or("policy", "classad").as_str() {
        "forecast" => RankPolicy::ForecastBandwidth { engine: None },
        _ => RankPolicy::ClassAdRank,
    };
    let (catalog, info, _cfg) = demo_grid(n, seed);
    let broker = Broker::new(catalog, info, policy);
    // The CLI is a broker boundary: request ads go through the
    // intern-budget gate even though this demo ad is a known literal.
    let request = parse_request_ad(
        r#"hostname = "comet.xyz.com";
           reqdSpace = 5G;
           reqdRDBandwidth = 50K/Sec;
           rank = other.availableSpace;
           requirement = other.availableSpace > 5G
               && other.MaxRDBandwidth > 50K/Sec;"#,
    )
    .unwrap();
    match broker.select("run42.dat", &request) {
        Ok(sel) => {
            let t = &sel.trace;
            println!("replica catalog: {} sites {:?}", t.replica_sites.len(), t.replica_sites);
            println!("search phase:  {}µs (GRIS fan-out + LDIF)", t.search_us);
            println!("convert phase: {}µs (LDIF → ClassAds)", t.convert_us);
            println!("match phase:   {}µs", t.match_us);
            for (site, ok) in &t.match_results {
                println!("  {site:<14} {}", if *ok { "MATCH" } else { "reject" });
            }
            println!("ranking:");
            for (site, score) in &t.ranking {
                println!("  {site:<14} {score:.1}");
            }
            println!("selected: {} ({})", sel.site, sel.url);
        }
        Err(e) => println!("selection failed: {e:#}"),
    }
}

fn cmd_simulate(args: &Args) {
    let n = args.usize_or("sites", 8);
    let requests = args.usize_or("requests", 200);
    let seed = args.u64_or("seed", 42);
    if !args.has("trace") {
        // Thin pointer; the example hosts the full simulation driver.
        println!(
            "run `cargo run --release --example datagrid_sim -- --sites {n} --requests {requests} --seed {seed}`"
        );
        println!("(or add --trace to run a flight-recorded open-loop scenario here)");
        return;
    }

    // Flight-recorded open-loop run: the same kernel the contention
    // bench drives, with the recorder and the time-series sampler on.
    let cfg = GridConfig::generate(n, seed);
    let spec = WorkloadSpec {
        files: n.max(4),
        mean_interarrival: args.f64_or("interarrival", 60.0),
        ..Default::default()
    };
    let mut workload = Workload::new(spec.clone(), seed);
    let reqs = workload.take(requests);
    let trace = TraceHandle::new(args.usize_or("trace-capacity", 1 << 18));
    let opts = OpenLoopOptions {
        trace: trace.clone(),
        sample_period: args.f64_or("sample-period", 30.0),
        ..OpenLoopOptions::open()
    };
    let report = run_quality_open(
        &cfg,
        &spec,
        &reqs,
        args.usize_or("replicas", 4),
        args.usize_or("warm", 6),
        SelectorKind::Forecast,
        &opts,
        None,
    );
    println!(
        "open-loop: {} requests ({} skipped), mean {:.1}s p95 {:.1}s, makespan {:.1}s, peak in flight {}",
        report.quality.requests,
        report.skipped,
        report.quality.mean_time,
        report.quality.p95_time,
        report.makespan,
        report.peak_in_flight,
    );
    let name = args.str_or("trace-name", "open_loop");
    match trace.write_artifacts(&name) {
        Ok(paths) => {
            for p in &paths {
                println!("wrote {p}");
            }
            println!("inspect with `globus-replica trace-summary TRACE_{name}.json`");
        }
        Err(e) => eprintln!("could not write trace artifacts: {e:#}"),
    }
}

/// The named weather intensities the `chaos` subcommand sweeps.
fn weather_ladder() -> Vec<(&'static str, WeatherSpec)> {
    vec![
        ("calm", WeatherSpec::default()),
        (
            "breeze",
            WeatherSpec {
                horizon: 1200.0,
                mtbf: 600.0,
                mttr: 60.0,
                ..WeatherSpec::default()
            },
        ),
        (
            "storm",
            WeatherSpec {
                horizon: 1200.0,
                mtbf: 180.0,
                mttr: 90.0,
                perm_frac: 0.2,
                flap_rate: 1.0 / 300.0,
                flap_duration: 45.0,
                flap_floor: 0.1,
                ..WeatherSpec::default()
            },
        ),
        (
            "hurricane",
            WeatherSpec {
                horizon: 1200.0,
                mtbf: 80.0,
                mttr: 120.0,
                perm_frac: 0.4,
                flap_rate: 1.0 / 150.0,
                flap_duration: 60.0,
                flap_floor: 0.05,
                ..WeatherSpec::default()
            },
        ),
    ]
}

fn cmd_chaos(args: &Args) {
    use std::collections::BTreeMap;
    use globus_replica::util::json::Json;

    let n = args.usize_or("sites", 8);
    let requests = args.usize_or("requests", 20);
    let seed = args.u64_or("seed", 42);
    let which = args.str_or("weather", "storm");
    let ladder = weather_ladder();
    let weathers: Vec<(&str, WeatherSpec)> = if which == "all" {
        ladder
    } else {
        match ladder.into_iter().find(|(name, _)| *name == which) {
            Some(w) => vec![w],
            None => {
                eprintln!(
                    "unknown --weather {which:?} (use calm, breeze, storm, hurricane or all)"
                );
                std::process::exit(2);
            }
        }
    };
    let cfg = GridConfig::generate(n, seed);
    let spec = WorkloadSpec {
        files: n.max(4),
        mean_interarrival: args.f64_or("interarrival", 12.0),
        ..Default::default()
    };
    let opts = ChaosOptions {
        retry: RetryOptions {
            transfer_timeout: args.f64_or("transfer-timeout", 30.0),
            ..RetryOptions::default()
        },
        weather_seed: args.u64_or("weather-seed", 7),
        ..ChaosOptions::default()
    };
    let report = run_chaos(&cfg, &spec, requests, 4, 4, &weathers, &opts);

    println!(
        "{:<11} {:>7} {:>7} | {:>9} {:>9} {:>9} | {:>8} {:>8}",
        "weather", "crashes", "faults", "ff done", "rt done", "fo done", "fo mttr", "ff quit"
    );
    for p in &report.points {
        println!(
            "{:<11} {:>7} {:>7} | {:>8.0}% {:>8.0}% {:>8.0}% | {:>7.1}s {:>8}",
            p.label,
            p.crashes,
            p.faults,
            p.fail_fast.completion_rate * 100.0,
            p.retry.completion_rate * 100.0,
            p.retry_failover.completion_rate * 100.0,
            p.retry_failover.mttr,
            p.fail_fast.gave_up,
        );
    }

    if args.has("out") {
        let arm_json = |a: &ChaosArm| {
            let mut o = BTreeMap::new();
            o.insert("completion_rate".to_string(), Json::Num(a.completion_rate));
            o.insert("mttr_s".to_string(), Json::Num(a.mttr));
            o.insert("p95_time_s".to_string(), Json::Num(a.p95));
            o.insert("goodput_bps".to_string(), Json::Num(a.goodput));
            o.insert("retries".to_string(), Json::Num(a.retries as f64));
            o.insert("failovers".to_string(), Json::Num(a.failovers as f64));
            o.insert("gave_up".to_string(), Json::Num(a.gave_up as f64));
            o.insert("skipped".to_string(), Json::Num(a.skipped as f64));
            Json::Obj(o)
        };
        let mut root = BTreeMap::new();
        root.insert("sweep".to_string(), Json::Str("chaos".to_string()));
        root.insert("sites".to_string(), Json::Num(n as f64));
        root.insert("requests".to_string(), Json::Num(requests as f64));
        root.insert("seed".to_string(), Json::Num(seed as f64));
        root.insert(
            "points".to_string(),
            Json::Arr(
                report
                    .points
                    .iter()
                    .map(|p| {
                        let mut o = BTreeMap::new();
                        o.insert("weather".to_string(), Json::Str(p.label.clone()));
                        o.insert("crashes".to_string(), Json::Num(p.crashes as f64));
                        o.insert("faults".to_string(), Json::Num(p.faults as f64));
                        o.insert("fail_fast".to_string(), arm_json(&p.fail_fast));
                        o.insert("retry".to_string(), arm_json(&p.retry));
                        o.insert("retry_failover".to_string(), arm_json(&p.retry_failover));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        let path = args.str_or("out", "CHAOS_report.json");
        match std::fs::write(&path, Json::Obj(root).to_string()) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

fn cmd_economy(args: &Args) {
    use std::collections::BTreeMap;
    use globus_replica::util::json::Json;

    let n = args.usize_or("sites", 8);
    let requests = args.usize_or("requests", 60);
    let seed = args.u64_or("seed", 42);
    let cfg = GridConfig::generate(n, seed);
    let spec = WorkloadSpec {
        files: n.max(4),
        mean_interarrival: args.f64_or("interarrival", 8.0),
        ..Default::default()
    };
    let defaults = EconomyOptions::default();
    let opts = EconomySweepOptions {
        economy: EconomyOptions {
            period: args.f64_or("period", defaults.period),
            half_life: args.f64_or("half-life", defaults.half_life),
            replicate_threshold: args.f64_or("threshold", defaults.replicate_threshold),
            budget_frac: args.f64_or("budget-frac", defaults.budget_frac),
            ..defaults
        },
        ..EconomySweepOptions::default()
    };
    let report = run_economy(
        &cfg,
        &spec,
        requests,
        args.usize_or("replicas", 2),
        args.usize_or("warm", 4),
        &opts,
    );

    println!(
        "{:<14} | {:>9} {:>9} | {:>9} {:>9} | {:>9} {:>6} {:>6}",
        "scenario", "st hit", "ec hit", "st mean", "ec mean", "moved MB", "repl", "evict"
    );
    for p in &report.points {
        println!(
            "{:<14} | {:>8.0}% {:>8.0}% | {:>8.1}s {:>8.1}s | {:>9.1} {:>6} {:>6}",
            p.label,
            p.static_placement.hit_rate_nearest * 100.0,
            p.economy.hit_rate_nearest * 100.0,
            p.static_placement.mean_time,
            p.economy.mean_time,
            p.economy.bytes_moved / 1e6,
            p.economy.replicas_created,
            p.economy.evictions,
        );
    }

    if args.has("out") {
        let arm_json = |a: &EconomyArm| {
            let mut o = BTreeMap::new();
            o.insert("mean_time_s".to_string(), Json::Num(a.mean_time));
            o.insert("p95_time_s".to_string(), Json::Num(a.p95));
            o.insert("completion_rate".to_string(), Json::Num(a.completion_rate));
            o.insert("hit_rate_nearest".to_string(), Json::Num(a.hit_rate_nearest));
            o.insert("bytes_moved".to_string(), Json::Num(a.bytes_moved));
            o.insert("replicas_created".to_string(), Json::Num(a.replicas_created as f64));
            o.insert("evictions".to_string(), Json::Num(a.evictions as f64));
            o.insert("failed_pushes".to_string(), Json::Num(a.failed_pushes as f64));
            Json::Obj(o)
        };
        let mut root = BTreeMap::new();
        root.insert("sweep".to_string(), Json::Str("economy".to_string()));
        root.insert("sites".to_string(), Json::Num(n as f64));
        root.insert("requests".to_string(), Json::Num(requests as f64));
        root.insert("seed".to_string(), Json::Num(seed as f64));
        root.insert(
            "points".to_string(),
            Json::Arr(
                report
                    .points
                    .iter()
                    .map(|p| {
                        let mut o = BTreeMap::new();
                        o.insert("scenario".to_string(), Json::Str(p.label.clone()));
                        o.insert("static".to_string(), arm_json(&p.static_placement));
                        o.insert("economy".to_string(), arm_json(&p.economy));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        let path = args.str_or("out", "ECONOMY_report.json");
        match std::fs::write(&path, Json::Obj(root).to_string()) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

fn cmd_kernel(args: &Args) {
    use std::collections::BTreeMap;
    use globus_replica::util::json::Json;

    let defaults = KernelOptions::default();
    let o = KernelOptions {
        sites: args.usize_or("sites", defaults.sites),
        seed: args.u64_or("seed", defaults.seed),
        surge: args.usize_or("surge", 20_000),
        trickle: args.usize_or("trickle", 500),
        steady_events: args.usize_or("steady-events", defaults.steady_events),
        shard: ShardOptions {
            shards: args.usize_or("shards", defaults.shard.shards),
            batch_max: args.usize_or("batch", defaults.shard.batch_max),
            batch_window: args.f64_or("window", defaults.shard.batch_window),
        },
        ..defaults
    };
    let r = run_kernel(&o);
    println!(
        "kernel: {} requests ({} surged), peak in flight {}, {} events in {:.2}s = {:.0} events/sec",
        r.requests, r.concurrent, r.peak_in_flight, r.events, r.wall_s, r.events_per_sec
    );
    println!(
        "shards {}: {} flushes, {} cross-shard selections; finished {} skipped {} gave_up {}",
        o.shard.shards, r.flushes, r.cross_shard_selections, r.finished, r.skipped, r.gave_up
    );
    if args.has("out") {
        let mut root = BTreeMap::new();
        root.insert("point".to_string(), Json::Str("kernel".to_string()));
        root.insert("sites".to_string(), Json::Num(o.sites as f64));
        root.insert("shards".to_string(), Json::Num(o.shard.shards as f64));
        root.insert("requests".to_string(), Json::Num(r.requests as f64));
        root.insert("concurrent".to_string(), Json::Num(r.concurrent as f64));
        root.insert("peak_in_flight".to_string(), Json::Num(r.peak_in_flight as f64));
        root.insert("events".to_string(), Json::Num(r.events as f64));
        root.insert("wall_s".to_string(), Json::Num(r.wall_s));
        root.insert("events_per_sec".to_string(), Json::Num(r.events_per_sec));
        root.insert("flushes".to_string(), Json::Num(r.flushes as f64));
        root.insert(
            "cross_shard_selections".to_string(),
            Json::Num(r.cross_shard_selections as f64),
        );
        let path = args.str_or("out", "KERNEL_point.json");
        match std::fs::write(&path, Json::Obj(root).to_string()) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

fn cmd_trace_summary(args: &Args) {
    let path = match args.positional().get(1) {
        Some(p) => p.clone(),
        None => {
            eprintln!("usage: globus-replica trace-summary <TRACE_file.json|.jsonl> [--top N]");
            std::process::exit(2);
        }
    };
    let src = match std::fs::read_to_string(&path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("cannot read {path}: {e}");
            std::process::exit(2);
        }
    };
    let rec = match load_trace(&src) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{path}: {e:#}");
            std::process::exit(2);
        }
    };
    let spans = rec.spans();
    let summary = summarize(&spans, rec.dropped(), args.usize_or("top", 5));

    // All aggregates flow through one Metrics registry so the JSON
    // dump is the registry's stable-ordered `snapshot()`, not a
    // hand-rolled serializer.
    let m = Metrics::new();
    m.counter("trace.requests").add(summary.requests as u64);
    m.counter("trace.skipped").add(summary.skipped as u64);
    m.counter("trace.dropped_events").add(summary.dropped);
    for s in spans.iter().filter(|s| !s.skipped) {
        m.histogram("trace.queue_ns").observe_ns((s.queue_s * 1e9) as u64);
        m.histogram("trace.discovery_ns").observe_ns((s.discovery_s * 1e9) as u64);
        m.histogram("trace.transfer_ns").observe_ns((s.transfer_s * 1e9) as u64);
        m.histogram("trace.total_ns").observe_ns((s.total_s() * 1e9) as u64);
    }
    if args.has("json") {
        println!("{}", m.to_json());
        return;
    }
    print_trace_summary(&summary);
    if args.has("metrics") {
        println!("\n{}", m.render());
    }
}

fn print_trace_summary(s: &TraceSummary) {
    println!(
        "requests {} (skipped {}), dropped {}, min span coverage {:.1}%",
        s.requests,
        s.skipped,
        s.dropped,
        s.min_coverage * 100.0
    );
    println!(
        "{:<11} {:>10} {:>10} {:>10} {:>10}",
        "phase", "p50", "p95", "mean", "max"
    );
    for (name, p) in [
        ("queue", &s.queue),
        ("discovery", &s.discovery),
        ("transfer", &s.transfer),
        ("total", &s.total),
    ] {
        println!(
            "{:<11} {:>9.3}s {:>9.3}s {:>9.3}s {:>9.3}s",
            name, p.p50_s, p.p95_s, p.mean_s, p.max_s
        );
    }
    println!(
        "report parity: mean_time {:.3}s  p95_time {:.3}s (finish_report arithmetic)",
        s.mean_time, s.p95_time
    );
    for (k, r) in s.slowest.iter().enumerate() {
        println!(
            "#{} slowest: req {}  total {:.1}s = queue {:.1} + disc {:.1} + xfer {:.1}",
            k + 1,
            r.req,
            r.total_s(),
            r.queue_s,
            r.discovery_s,
            r.transfer_s
        );
        for e in &r.events {
            println!("    {:>10.3}s  {}", e.at - r.arrival, e.ev.name());
        }
    }
}
