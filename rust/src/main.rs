//! `globus-replica` — CLI launcher for the replica-selection system.
//!
//! Subcommands:
//!
//! * `schema`   — print the paper's object classes (Figures 2/4/5) and
//!   the DIT skeleton (Figure 3).
//! * `gris`     — run a storage-site GRIS daemon on a TCP port.
//! * `giis`     — run a GIIS index daemon.
//! * `select`   — one decentralized selection against a generated
//!   in-process grid (prints the Figure-6 phase trace).
//! * `simulate` — pointer to the end-to-end workload simulation
//!   (`examples/datagrid_sim`).
//!
//! Run `globus-replica help` for flags.

use std::sync::{Arc, Mutex, RwLock};

use globus_replica::broker::{parse_request_ad, Broker, LocalInfoService, RankPolicy};
use globus_replica::catalog::{PhysicalLocation, ReplicaCatalog};
use globus_replica::config::GridConfig;
use globus_replica::directory::schema;
use globus_replica::directory::server::DirectoryServer;
use globus_replica::directory::{Entry, Giis, Gris};
use globus_replica::util::cli::Args;
use globus_replica::util::units::Bytes;

const USAGE: &str = "\
globus-replica <command> [flags]

commands:
  schema                         print Figures 2-5 object classes + DIT
  gris   --site S --org O --port P   run a GRIS daemon
  giis   --port P                run a GIIS daemon
  select [--sites N] [--seed K] [--policy classad|forecast]
                                 one brokered selection w/ phase trace
  simulate [--sites N] [--requests R] [--seed K]
                                 workload simulation (quality metrics)
  help                           this text
";

fn main() {
    let args = Args::from_env();
    let cmd = args.positional().first().cloned().unwrap_or_default();
    match cmd.as_str() {
        "schema" => cmd_schema(),
        "gris" => cmd_gris(&args),
        "giis" => cmd_giis(&args),
        "select" => cmd_select(&args),
        "simulate" => cmd_simulate(&args),
        _ => print!("{USAGE}"),
    }
}

fn cmd_schema() {
    println!("# Figure 2 — system configuration metadata\n");
    println!("{}", schema::SERVER_VOLUME.render());
    println!("# Figure 4 — site-wide transfer bandwidth\n");
    println!("{}", schema::TRANSFER_BANDWIDTH.render());
    println!("# Figure 5 — per-source transfer bandwidth\n");
    println!("{}", schema::SOURCE_TRANSFER_BANDWIDTH.render());
    println!("# Figure 3 — DIT levels\n");
    for (i, level) in schema::dit_levels().iter().enumerate() {
        println!("{}{}", "  ".repeat(i), level);
    }
}

fn cmd_gris(args: &Args) {
    let site = args.str_or("site", "mcs");
    let org = args.str_or("org", "anl");
    let port = args.u64_or("port", 0) as u16;
    let mut gris = Gris::new(&org, &site);
    let base = gris.base_dn().clone();
    // A demo volume; a real deployment would load site config here.
    let mut e = Entry::new(base.child("gss", "vol0"));
    e.add("objectClass", "GridStorageServerVolume");
    e.put_f64("totalSpace", 100.0 * 1024f64.powi(3));
    e.put_f64("availableSpace", 50.0 * 1024f64.powi(3));
    e.put("mountPoint", "/dev/sandbox");
    e.put_f64("diskTransferRate", 2e7);
    e.put_f64("drdTime", 8.0);
    e.put_f64("dwrTime", 9.0);
    gris.add_entry(e);
    let server = DirectoryServer::spawn(Arc::new(Mutex::new(gris)), port).expect("bind");
    println!("GRIS for {org}/{site} listening on {}", server.addr());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_giis(args: &Args) {
    let port = args.u64_or("port", 0) as u16;
    let giis = Giis::new();
    let server = DirectoryServer::spawn(Arc::new(Mutex::new(giis)), port).expect("bind");
    println!("GIIS listening on {}", server.addr());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Build an in-process demo grid: catalog + one GRIS per site.
fn demo_grid(
    n: usize,
    seed: u64,
) -> (Arc<Mutex<ReplicaCatalog>>, Arc<LocalInfoService>, GridConfig) {
    let cfg = GridConfig::generate(n, seed);
    let mut catalog = ReplicaCatalog::new();
    catalog
        .create_logical("run42.dat", Bytes::from_gb(2.0), "cms")
        .unwrap();
    let mut info = LocalInfoService::new();
    let mut rng = globus_replica::util::prng::Rng::new(seed ^ 0xDE40);
    for sc in &cfg.sites {
        catalog
            .add_replica(
                "run42.dat",
                PhysicalLocation {
                    site: sc.name.clone(),
                    url: format!("gsiftp://{}/run42.dat", sc.name),
                },
            )
            .unwrap();
        let mut gris = Gris::new(&sc.org, &sc.name);
        let base = gris.base_dn().clone();
        let vol = base.child("gss", "vol0");
        let mut e = Entry::new(vol.clone());
        e.add("objectClass", "GridStorageServerVolume");
        e.put_f64("totalSpace", sc.total_space);
        e.put_f64("availableSpace", sc.total_space * (1.0 - sc.used_frac));
        e.put("mountPoint", "/data");
        e.put_f64("diskTransferRate", sc.disk_rate);
        e.put_f64("drdTime", sc.drd_time_ms);
        e.put_f64("dwrTime", sc.dwr_time_ms);
        e.put_f64("load", rng.range(0.0, 0.6));
        gris.add_entry(e);
        let mut bw = Entry::new(vol.child("gss", "bw"));
        bw.add("objectClass", "GridStorageTransferBandwidth");
        for a in ["MaxRDBandwidth", "AvgRDBandwidth"] {
            bw.put_f64(a, sc.wan_bandwidth);
        }
        for a in ["MinRDBandwidth", "MaxWRBandwidth", "MinWRBandwidth", "AvgWRBandwidth"] {
            bw.put_f64(a, sc.wan_bandwidth * 0.5);
        }
        gris.add_entry(bw);
        let mut src = Entry::new(vol.child("gss", "src"));
        src.add("objectClass", "GridStorageSourceTransferBandwidth");
        src.put_f64("lastRDBandwidth", sc.wan_bandwidth);
        src.put("lastRDurl", "gsiftp://client/");
        src.put_f64("lastWRBandwidth", sc.wan_bandwidth * 0.4);
        src.put("lastWRurl", "gsiftp://client/");
        let hist: Vec<String> = (0..8)
            .map(|_| format!("{:.0}", sc.wan_bandwidth * rng.range(0.6, 1.2)))
            .collect();
        src.put("rdHistory", hist.join(","));
        gris.add_entry(src);
        info.add(&sc.name, Arc::new(RwLock::new(gris)));
    }
    (Arc::new(Mutex::new(catalog)), Arc::new(info), cfg)
}

fn cmd_select(args: &Args) {
    let n = args.usize_or("sites", 6);
    let seed = args.u64_or("seed", 42);
    let policy = match args.str_or("policy", "classad").as_str() {
        "forecast" => RankPolicy::ForecastBandwidth { engine: None },
        _ => RankPolicy::ClassAdRank,
    };
    let (catalog, info, _cfg) = demo_grid(n, seed);
    let broker = Broker::new(catalog, info, policy);
    // The CLI is a broker boundary: request ads go through the
    // intern-budget gate even though this demo ad is a known literal.
    let request = parse_request_ad(
        r#"hostname = "comet.xyz.com";
           reqdSpace = 5G;
           reqdRDBandwidth = 50K/Sec;
           rank = other.availableSpace;
           requirement = other.availableSpace > 5G
               && other.MaxRDBandwidth > 50K/Sec;"#,
    )
    .unwrap();
    match broker.select("run42.dat", &request) {
        Ok(sel) => {
            let t = &sel.trace;
            println!("replica catalog: {} sites {:?}", t.replica_sites.len(), t.replica_sites);
            println!("search phase:  {}µs (GRIS fan-out + LDIF)", t.search_us);
            println!("convert phase: {}µs (LDIF → ClassAds)", t.convert_us);
            println!("match phase:   {}µs", t.match_us);
            for (site, ok) in &t.match_results {
                println!("  {site:<14} {}", if *ok { "MATCH" } else { "reject" });
            }
            println!("ranking:");
            for (site, score) in &t.ranking {
                println!("  {site:<14} {score:.1}");
            }
            println!("selected: {} ({})", sel.site, sel.url);
        }
        Err(e) => println!("selection failed: {e:#}"),
    }
}

fn cmd_simulate(args: &Args) {
    // Thin pointer; the example hosts the full simulation driver.
    let n = args.usize_or("sites", 8);
    let requests = args.usize_or("requests", 200);
    let seed = args.u64_or("seed", 42);
    println!(
        "run `cargo run --release --example datagrid_sim -- --sites {n} --requests {requests} --seed {seed}`"
    );
}
