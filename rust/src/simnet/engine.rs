//! The open-loop discrete-event kernel (ISSUE 4 tentpole; made
//! allocation-free and shard-aware by ISSUE 8).
//!
//! Before this module, every experiment replayed requests *serially*:
//! the clock jumped to each arrival and that one transfer ran to
//! completion alone, so cross-request contention — the regime the
//! paper's dynamic-information thesis actually bites in — could not
//! occur. [`Engine`] replaces that with an event queue over
//!
//! * **arrivals** — requests admitted at their Poisson instants
//!   ([`Engine::schedule_arrival`]),
//! * **timers** — GRIS dynamics refresh ticks, the co-allocation
//!   scheduler's maintenance ticks, and the sharded broker's
//!   per-shard admission-batch flush timers
//!   ([`Engine::schedule_tick`]),
//! * **directory queries** — in-flight GRIS/GIIS round trips whose
//!   responses land after a simulated network latency
//!   ([`Engine::schedule_query`]; driven by
//!   [`crate::directory::fanout::DirectoryFanout`]), and
//! * **flow completions** — discovered by integrating the one
//!   grid-wide [`FlowSet`] between scheduled instants, so every
//!   in-flight transfer (single-best fetches *and* co-allocated stripe
//!   streams) shares site links and per-client downlinks
//!   simultaneously. Scheduled topology faults are also integration
//!   boundaries (the `FlowSet` splits its steps at trigger instants).
//!
//! The kernel is deliberately *polled*, not callback-driven: the
//! driver loops on [`Engine::next`], which advances simulated time to
//! the earliest event and returns it as a [`Signal`]. Ties at one
//! instant resolve deterministically — buffered flow completions
//! first, then scheduled entries in scheduling order — so every run is
//! replayable from its seed. Like [`FlowSet`], the engine borrows the
//! [`Topology`] per call instead of owning it, which lets drivers keep
//! snapshot/rollback idioms (`clone_for_probe`) unchanged.
//!
//! ## Steady-state allocation freedom (ISSUE 8)
//!
//! The schedule lives in an [`EventArena`] — a reusable 4-ary min-heap
//! slab with the same `(time, insertion order)` total order the old
//! `BinaryHeap<Reverse<Sched>>` had, so the swap is bit-transparent —
//! and flow completions are collected into one reusable buffer
//! ([`FlowSet::advance_some_into`]). After warm-up, an event step
//! allocates nothing: a 10⁵–10⁶-request day of traffic runs at a flat
//! memory ceiling (measured by `bench_kernel`, reported as events/sec
//! in `BENCH_kernel.json`). Back-to-back events at the *same* instant
//! pop without re-integrating the flow set, which is what makes a
//! same-instant arrival surge (the `run_kernel` ramp) linear in the
//! surge size rather than quadratic.

use std::collections::VecDeque;

use crate::simnet::arena::EventArena;
use crate::simnet::{Completion, FlowSet, Topology};
use crate::trace::{Ev, TraceHandle, KERNEL_REQ};

/// How far the kernel integrates live flows past the last scheduled
/// event, per chunk, before checking for progress. A chunk that moves
/// nothing (dead sources, nothing watching them) makes
/// [`Engine::next`] return `None` instead of advancing the clock to
/// infinity; chunks that *do* move bytes keep going until a completion
/// fires (slow links are slow, not stalled).
const STALL_CHUNK_S: f64 = 3_600.0;
/// Backstop on progressing-but-never-completing chunks (≈ 11 simulated
/// years) — unreachable for any finite flow over the ≥ 1 B/s link
/// floor, so it only guards against float pathology.
const STALL_CHUNKS_MAX: usize = 100_000;

/// An event delivered by [`Engine::next`].
#[derive(Debug, Clone, PartialEq)]
pub enum Signal {
    /// A scheduled request arrival reached its instant.
    Arrival { id: u64, at: f64 },
    /// A scheduled timer fired (GRIS refresh, scheduler maintenance,
    /// shard-batch flush).
    Tick { id: u64, at: f64 },
    /// A scheduled directory query resolved (response arrived, or its
    /// deadline/cutoff passed — the scheduler does not distinguish;
    /// the issuing fan-out does).
    Query { id: u64, at: f64 },
    /// A flow in the shared [`FlowSet`] delivered its last byte.
    FlowDone(Completion),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SchedKind {
    Arrival(u64),
    Tick(u64),
    Query(u64),
}

impl SchedKind {
    fn into_signal(self, at: f64) -> Signal {
        match self {
            SchedKind::Arrival(id) => Signal::Arrival { id, at },
            SchedKind::Tick(id) => Signal::Tick { id, at },
            SchedKind::Query(id) => Signal::Query { id, at },
        }
    }
}

/// The discrete-event kernel: a schedule of arrivals/ticks plus the
/// grid-wide [`FlowSet`] whose completions are events too.
pub struct Engine {
    /// The shared flow set every in-flight transfer lives in. Drivers
    /// and sessions register flows directly (`flows.add_in`) and get
    /// their completions back as [`Signal::FlowDone`].
    pub flows: FlowSet,
    /// Flight-recorder handle; disabled by default, in which case
    /// dispatch accounting costs one branch per delivered signal.
    pub trace: TraceHandle,
    /// Arena-backed schedule: time order, FIFO ties (the exact order
    /// the original binary heap produced).
    queue: EventArena<SchedKind>,
    pending: VecDeque<Completion>,
    /// Reusable completion buffer for `advance_some_into` — drained
    /// into `pending` after every integration, never reallocated in
    /// steady state.
    done_buf: Vec<Completion>,
}

impl Engine {
    pub fn new(flows: FlowSet) -> Engine {
        Engine {
            flows,
            trace: TraceHandle::disabled(),
            queue: EventArena::new(),
            pending: VecDeque::new(),
            done_buf: Vec::new(),
        }
    }

    /// [`Engine::new`] with the schedule arena pre-sized for `events`
    /// concurrent entries — the surge path reserves once up front.
    pub fn with_capacity(flows: FlowSet, events: usize) -> Engine {
        Engine {
            flows,
            trace: TraceHandle::disabled(),
            queue: EventArena::with_capacity(events),
            pending: VecDeque::new(),
            done_buf: Vec::new(),
        }
    }

    /// Record the dispatch of `sig` (when tracing) and hand it out.
    fn deliver(&self, sig: Signal) -> Option<Signal> {
        if self.trace.on() {
            let (kind, at) = match &sig {
                Signal::Arrival { at, .. } => ("arrival", *at),
                Signal::Tick { at, .. } => ("tick", *at),
                Signal::Query { at, .. } => ("query", *at),
                Signal::FlowDone(c) => ("flow_done", c.at),
            };
            self.trace.rec(at, KERNEL_REQ, Ev::Dispatch { kind });
        }
        Some(sig)
    }

    /// Schedule a request arrival at absolute simulated time `at`.
    pub fn schedule_arrival(&mut self, at: f64, id: u64) {
        self.queue.push(at, SchedKind::Arrival(id));
    }

    /// Schedule a timer at absolute simulated time `at`.
    pub fn schedule_tick(&mut self, at: f64, id: u64) {
        self.queue.push(at, SchedKind::Tick(id));
    }

    /// Schedule a directory-query resolution at absolute simulated
    /// time `at`. Ids are caller-allocated and must be unique across
    /// live queries (see `directory::fanout::QueryIds`).
    pub fn schedule_query(&mut self, at: f64, id: u64) {
        self.queue.push(at, SchedKind::Query(id));
    }

    /// Scheduled entries (arrivals + ticks) not yet delivered.
    pub fn scheduled(&self) -> usize {
        self.queue.len()
    }

    /// Integrate live flows for up to `dt`; buffer all but the first
    /// completion and deliver that one, or report `None` if the whole
    /// budget passed quietly. Uses the reusable `done_buf`.
    fn integrate(&mut self, topo: &mut Topology, dt: f64) -> Option<Signal> {
        self.done_buf.clear();
        // Field-disjoint borrows: `flows` integrates into `done_buf`.
        let Engine { flows, done_buf, .. } = self;
        flows.advance_some_into(topo, dt, done_buf);
        if self.done_buf.is_empty() {
            return None;
        }
        let first = self.done_buf[0];
        self.pending.extend(self.done_buf.drain(1..));
        self.deliver(Signal::FlowDone(first))
    }

    /// Advance simulated time to the earliest event and return it:
    /// buffered completions first, then flow completions discovered on
    /// the way to the next scheduled instant, then that instant itself.
    /// Returns `None` when nothing is scheduled and no live flow can
    /// make progress (all drained, or the survivors are stalled on
    /// dead sources).
    pub fn next(&mut self, topo: &mut Topology) -> Option<Signal> {
        if let Some(c) = self.pending.pop_front() {
            return self.deliver(Signal::FlowDone(c));
        }
        loop {
            let next_at = self.queue.peek_at();
            if self.flows.live() == 0 {
                // Pure scheduling: jump the clock to the next entry.
                let (at, kind) = self.queue.pop()?;
                topo.advance_to(at);
                return self.deliver(kind.into_signal(at));
            }
            match next_at {
                Some(at) if at <= topo.now + 1e-12 => {
                    // The scheduled instant is now; completions at this
                    // instant were delivered on the way here.
                    let (at, kind) = self.queue.pop().expect("peeked entry");
                    topo.advance_to(at);
                    return self.deliver(kind.into_signal(at));
                }
                Some(at) => {
                    // Integrate flows up to the scheduled instant; a
                    // completion on the way preempts it.
                    if let Some(sig) = self.integrate(topo, at - topo.now) {
                        return Some(sig);
                    }
                    // Reached the instant (advance_some consumed the
                    // whole budget): snap exactly, loop pops it.
                    topo.advance_to(at);
                }
                None => {
                    // Live flows, nothing scheduled: integrate in
                    // bounded chunks; give up when nothing moves.
                    let mut chunks = 0usize;
                    loop {
                        let before = self.flows.progress_metric();
                        if let Some(sig) = self.integrate(topo, STALL_CHUNK_S) {
                            return Some(sig);
                        }
                        chunks += 1;
                        if self.flows.progress_metric() <= before + 1e-9
                            || chunks >= STALL_CHUNKS_MAX
                        {
                            return None;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GridConfig;

    fn flat_topo(n: usize) -> Topology {
        let mut cfg = GridConfig::generate(n, 5);
        for s in &mut cfg.sites {
            s.wan_bandwidth = 1e6;
            s.diurnal_amp = 0.0;
            s.noise_frac = 0.0;
            s.congestion_prob = 0.0;
            s.ar_coeff = 0.0;
            s.latency = 0.0;
        }
        Topology::build(&cfg)
    }

    #[test]
    fn events_fire_in_time_order_with_stable_ties() {
        let mut topo = flat_topo(2);
        let mut eng = Engine::new(FlowSet::new(f64::INFINITY));
        eng.schedule_tick(5.0, 100);
        eng.schedule_arrival(1.0, 0);
        eng.schedule_arrival(5.0, 1); // tie with the tick, scheduled later
        let a = eng.next(&mut topo).unwrap();
        assert_eq!(a, Signal::Arrival { id: 0, at: 1.0 });
        assert!((topo.now - 1.0).abs() < 1e-12);
        let b = eng.next(&mut topo).unwrap();
        assert_eq!(b, Signal::Tick { id: 100, at: 5.0 });
        let c = eng.next(&mut topo).unwrap();
        assert_eq!(c, Signal::Arrival { id: 1, at: 5.0 });
        assert!(eng.next(&mut topo).is_none());
    }

    #[test]
    fn query_events_share_the_time_order() {
        let mut topo = flat_topo(2);
        let mut eng = Engine::new(FlowSet::new(f64::INFINITY));
        eng.schedule_query(0.2, 7);
        eng.schedule_tick(0.1, 1);
        eng.schedule_query(0.2, 8); // tie: scheduling order wins
        assert_eq!(eng.next(&mut topo), Some(Signal::Tick { id: 1, at: 0.1 }));
        assert_eq!(eng.next(&mut topo), Some(Signal::Query { id: 7, at: 0.2 }));
        assert_eq!(eng.next(&mut topo), Some(Signal::Query { id: 8, at: 0.2 }));
        assert!(eng.next(&mut topo).is_none());
    }

    #[test]
    fn flow_completions_interleave_with_schedule() {
        let mut topo = flat_topo(2);
        let mut eng = Engine::new(FlowSet::new(f64::INFINITY));
        // 1e6 bytes over a 1e6 B/s pipe → completes at t=1, between
        // the two scheduled entries.
        let f = eng.flows.add(&topo, 0, 1e6, 0.0);
        eng.schedule_tick(0.5, 7);
        eng.schedule_tick(2.0, 8);
        assert_eq!(eng.next(&mut topo), Some(Signal::Tick { id: 7, at: 0.5 }));
        match eng.next(&mut topo) {
            Some(Signal::FlowDone(c)) => {
                assert_eq!(c.flow, f);
                assert!((c.at - 1.0).abs() < 1e-6, "at {}", c.at);
            }
            other => panic!("expected FlowDone, got {other:?}"),
        }
        assert_eq!(eng.next(&mut topo), Some(Signal::Tick { id: 8, at: 2.0 }));
        assert!((topo.now - 2.0).abs() < 1e-12);
    }

    #[test]
    fn simultaneous_completions_drain_one_per_call() {
        let mut topo = flat_topo(3);
        let mut eng = Engine::new(FlowSet::new(f64::INFINITY));
        eng.flows.add(&topo, 0, 1e6, 0.0);
        eng.flows.add(&topo, 1, 1e6, 0.0);
        let mut seen = 0;
        while let Some(sig) = eng.next(&mut topo) {
            match sig {
                Signal::FlowDone(c) => {
                    assert!((c.at - 1.0).abs() < 1e-6);
                    seen += 1;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(seen, 2);
    }

    #[test]
    fn stalled_flows_end_the_run_instead_of_hanging() {
        use crate::simnet::topology::FaultKind;
        let mut topo = flat_topo(2);
        topo.schedule_fault(0, 0.0, FaultKind::ReplicaDeath);
        let mut eng = Engine::new(FlowSet::new(f64::INFINITY));
        eng.flows.add(&topo, 0, 1e6, 0.0); // will never move a byte
        assert!(eng.next(&mut topo).is_none());
        assert!(topo.now.is_finite());
    }

    #[test]
    fn dispatch_events_are_recorded_when_traced() {
        let mut topo = flat_topo(2);
        let mut eng = Engine::new(FlowSet::new(f64::INFINITY));
        eng.trace = TraceHandle::new(16);
        eng.schedule_tick(1.0, 1);
        eng.schedule_arrival(2.0, 2);
        while eng.next(&mut topo).is_some() {}
        let kinds: Vec<&'static str> = eng
            .trace
            .read(|r| {
                r.events()
                    .iter()
                    .map(|e| match e.ev {
                        Ev::Dispatch { kind } => kind,
                        _ => "?",
                    })
                    .collect()
            })
            .unwrap();
        assert_eq!(kinds, vec!["tick", "arrival"]);
    }

    #[test]
    fn deterministic_given_identical_schedules() {
        let run = || {
            let mut topo = flat_topo(3);
            let mut eng = Engine::new(FlowSet::new(1e6));
            eng.flows.add(&topo, 0, 2e6, 0.0);
            eng.flows.add(&topo, 1, 1e6, 0.5);
            eng.schedule_tick(1.5, 1);
            eng.schedule_arrival(2.5, 2);
            let mut log = Vec::new();
            while let Some(sig) = eng.next(&mut topo) {
                log.push(format!("{sig:?}"));
            }
            (log, topo.now)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn preallocated_engine_behaves_identically() {
        let run = |prealloc: bool| {
            let mut topo = flat_topo(3);
            let mut eng = if prealloc {
                Engine::with_capacity(FlowSet::with_capacity(1e6, 8), 32)
            } else {
                Engine::new(FlowSet::new(1e6))
            };
            eng.flows.add(&topo, 0, 2e6, 0.0);
            eng.flows.add(&topo, 1, 1e6, 0.5);
            eng.schedule_tick(1.5, 1);
            eng.schedule_arrival(2.5, 2);
            let mut log = Vec::new();
            while let Some(sig) = eng.next(&mut topo) {
                log.push(format!("{sig:?}"));
            }
            (log, topo.now)
        };
        assert_eq!(run(false), run(true));
    }
}
