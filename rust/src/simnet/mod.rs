//! Wide-area network + storage simulator — the testbed substitute.
//!
//! The paper's evaluation ran on real Globus sites; with no such testbed
//! available the reproduction simulates the property the paper's
//! technique exploits: **per-(site,client) transfer bandwidth is
//! temporally correlated** (history predicts the near future) while
//! differing wildly across sites. Links combine
//!
//! * a site-specific mean (config `wan_bandwidth`),
//! * a diurnal load cycle (slow sinusoid),
//! * AR(1) noise (short-term correlation — what the forecasters latch
//!   onto),
//! * rare heavy-tailed congestion episodes (what robust predictors must
//!   survive), and
//! * a utilization-dependent share (concurrent transfers divide the
//!   pipe), and
//! * scheduled **faults** ([`Topology::schedule_fault`]): replica death
//!   and link degradation at configurable times — the churn the
//!   co-allocation failover path ([`crate::coalloc`]) exists to absorb.
//!
//! Simulated time is explicit (`f64` seconds) so experiments are fully
//! deterministic given a seed. Historically every experiment replayed
//! requests serially (one transfer alone on the grid at a time); that
//! assumption is gone: the [`engine`] module provides the open-loop
//! discrete-event kernel — an event queue over arrivals, timers, and
//! [`FlowSet`] completions — under which many transfers are in flight
//! simultaneously, sharing site links and per-client downlinks. The
//! serial replay survives only as the concurrency-1 special case the
//! parity tests pin against (`experiment::run_quality_trace`). The
//! kernel's steady state is allocation-free (ISSUE 8): the schedule
//! lives in a reusable [`arena::EventArena`] slab, the flow set is
//! structure-of-arrays with scratch-buffered rate recomputes, and
//! completions drain through one reusable buffer — see
//! `ARCHITECTURE.md` for the event/determinism contract.
//!
//! # Failure model (ISSUE 7: grid weather)
//!
//! Faults are **intervals**, not one-shot events. A [`Fault`] is
//! active over `[at, heal_at)`; `heal_at = ∞` reproduces the original
//! permanent semantics ([`Topology::schedule_fault`]), a finite heal
//! ([`Topology::schedule_fault_for`]) models a crash the site recovers
//! from. Two fault kinds exist:
//!
//! * [`FaultKind::ReplicaDeath`] — the site's control channel is down
//!   ([`Topology::site_alive`] is false) and its data flows deliver
//!   zero bytes while the fault is active; at the heal instant stalled
//!   flows resume from their delivered offset.
//! * [`FaultKind::LinkDegrade`] — the site's WAN bandwidth is scaled
//!   by the product of the active factors
//!   ([`Topology::degrade_factor`]); a finite heal makes it a *flap*.
//!
//! [`FlowSet`] integration sub-steps split at **every** fault boundary
//! — triggers and heals alike ([`Topology::next_fault_after`]) — so no
//! bytes are delivered past a death and no free bytes accrue before a
//! heal. The hot-path liveness/degradation checks read a per-site
//! cache refreshed when the clock crosses the next boundary, not a
//! linear scan over the fault list.
//!
//! [`weather`] generates seeded random fault schedules
//! ([`weather::WeatherPlan`]): per-site crash/heal renewal processes
//! (MTBF/MTTR, a `perm_frac` share of permanent deaths) plus link-flap
//! episodes. The retry/backoff knobs that let the request paths ride
//! this weather out live with their consumers:
//! `experiment::open_loop::RetryOptions` (transfer timeout, bounded
//! attempts, exponential backoff + deterministic jitter, failover) and
//! `directory::fanout::FanoutPolicy::{max_retries, retry_backoff}`
//! (information-plane query retry).

pub mod arena;
pub mod engine;
pub mod flows;
pub mod link;
pub mod topology;
pub mod trace;
pub mod weather;
pub mod workload;

pub use arena::EventArena;
pub use engine::{Engine, Signal};
pub use flows::{Completion, Flow, FlowSet};
pub use link::Link;
pub use topology::{Fault, FaultKind, Site, Topology};
pub use weather::{WeatherPlan, WeatherSpec};
pub use workload::{Request, Workload, WorkloadSpec};
