//! Wide-area network + storage simulator — the testbed substitute.
//!
//! The paper's evaluation ran on real Globus sites; with no such testbed
//! available the reproduction simulates the property the paper's
//! technique exploits: **per-(site,client) transfer bandwidth is
//! temporally correlated** (history predicts the near future) while
//! differing wildly across sites. Links combine
//!
//! * a site-specific mean (config `wan_bandwidth`),
//! * a diurnal load cycle (slow sinusoid),
//! * AR(1) noise (short-term correlation — what the forecasters latch
//!   onto),
//! * rare heavy-tailed congestion episodes (what robust predictors must
//!   survive), and
//! * a utilization-dependent share (concurrent transfers divide the
//!   pipe), and
//! * scheduled **faults** ([`Topology::schedule_fault`]): replica death
//!   and link degradation at configurable times — the churn the
//!   co-allocation failover path ([`crate::coalloc`]) exists to absorb.
//!
//! Simulated time is explicit (`f64` seconds) so experiments are fully
//! deterministic given a seed. Historically every experiment replayed
//! requests serially (one transfer alone on the grid at a time); that
//! assumption is gone: the [`engine`] module provides the open-loop
//! discrete-event kernel — an event queue over arrivals, timers, and
//! [`FlowSet`] completions — under which many transfers are in flight
//! simultaneously, sharing site links and per-client downlinks. The
//! serial replay survives only as the concurrency-1 special case the
//! parity tests pin against (`experiment::run_quality_trace`).

pub mod engine;
pub mod flows;
pub mod link;
pub mod topology;
pub mod trace;
pub mod workload;

pub use engine::{Engine, Signal};
pub use flows::{Completion, Flow, FlowSet};
pub use link::Link;
pub use topology::{Fault, FaultKind, Site, Topology};
pub use workload::{Request, Workload, WorkloadSpec};
