//! Concurrent flow advancement — multiple simultaneous transfers that
//! share link capacity.
//!
//! The single-transfer path ([`Topology::transfer_from`]) integrates one
//! flow to completion. Concurrent access — co-allocated stripe streams
//! *and*, since the open-loop runtime (`simnet::engine`), unrelated
//! requests in flight at once — needs the dual view: a *set* of flows
//! advanced together in simulated time so that (a) flows from the same
//! site split that site's sampled link bandwidth, (b) flows of the same
//! client share that client's downlink cap, and (c) a completion
//! immediately returns capacity to the survivors. [`FlowSet`] provides
//! exactly that and nothing more; scheduling (which bytes go on which
//! flow) lives in `crate::coalloc`, and event ordering in
//! [`crate::simnet::engine`].
//!
//! Sharing convention: per-flow bandwidth is
//! [`Topology::current_bandwidth`], which divides the link by the
//! site's `active_transfers` counter. Callers must `begin_transfer`
//! once per stream before advancing flows (exactly what
//! `GridFtp::fetch` does for single transfers); same-site flows then
//! share that link through the counter itself, so single-source and
//! co-allocated paths see the identical per-stream share and
//! comparisons between them are fair. The downlink caps are the one
//! piece of sharing the set computes internally: each flow belongs to a
//! *group* (one per client endpoint — [`FlowSet::add_group`]), and a
//! group's aggregate rate is clipped to its downlink capacity. A set
//! built with [`FlowSet::new`] has a single group 0, which keeps the
//! one-client co-allocation semantics unchanged.
//!
//! ## Layout (ISSUE 8)
//!
//! The set is stored structure-of-arrays: each per-flow field is its
//! own column, so the bandwidth recompute — the hot loop under 10⁵
//! concurrent requests — is a linear scan over dense `f64` columns
//! instead of pointer-striding over an array of structs. The rate
//! snapshot and per-group totals live in *reusable scratch buffers*
//! (and the per-site link share is memoized within a sub-step, which
//! is bit-transparent because [`Topology::current_bandwidth`] is a
//! pure function of topology state between clock advances), so the
//! steady state of [`FlowSet::advance_some_into`] performs zero heap
//! allocations. [`Flow`] remains the public view of one flow, now
//! materialized by value from the columns; retirement is O(1) via a
//! position index instead of a linear scan. None of this changes a
//! single arithmetic operation or its order — every seeded scenario
//! (and the `it_contention` / `it_shard` parity anchors) produces
//! bit-identical completion instants.

use crate::simnet::Topology;

/// One in-flight transfer leg — a by-value snapshot of the set's
/// columns for that flow (see [`FlowSet::flow`]).
#[derive(Debug, Clone, Copy)]
pub struct Flow {
    /// Topology index of the source site.
    pub site: usize,
    /// Bytes still to move (0 once done).
    pub remaining: f64,
    /// Bytes delivered so far.
    pub delivered: f64,
    /// Connection-setup latency still to pay before bytes move.
    pub lead: f64,
    /// Simulated time the flow was added.
    pub started_at: f64,
    /// Completion time, once finished.
    pub finished_at: Option<f64>,
    /// True once the flow was abandoned via [`FlowSet::cancel`] — it
    /// will never complete and its delivered bytes are discarded by the
    /// caller (a cancelled block is re-fetched whole).
    pub cancelled: bool,
    /// Downlink-sharing group (client endpoint) the flow belongs to.
    pub group: usize,
}

impl Flow {
    pub fn is_done(&self) -> bool {
        self.finished_at.is_some()
    }
}

/// A flow completion reported by [`FlowSet::advance`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Completion {
    /// Index of the flow within the set (as returned by [`FlowSet::add`]).
    pub flow: usize,
    /// Absolute simulated completion time.
    pub at: f64,
}

/// Sentinel in the `finished_at` column: still in flight.
const UNFINISHED: f64 = f64::NAN;
/// Sentinel in the `live_pos` index: not in the live set.
const RETIRED: usize = usize::MAX;

/// A set of concurrent flows sharing link capacity, stored as
/// structure-of-arrays (one column per [`Flow`] field).
#[derive(Debug, Clone)]
pub struct FlowSet {
    site: Vec<usize>,
    remaining: Vec<f64>,
    delivered: Vec<f64>,
    lead: Vec<f64>,
    started_at: Vec<f64>,
    /// `NAN` = in flight (the column twin of `Option<f64>`).
    finished_at: Vec<f64>,
    cancelled: Vec<bool>,
    group: Vec<usize>,
    /// Indices of flows that are not yet done — the working set every
    /// sub-step iterates, so long transfers that accumulate thousands
    /// of completed block-flows don't pay for them on every tick.
    live_ids: Vec<usize>,
    /// flow id → its position in `live_ids` (`RETIRED` once done /
    /// cancelled), making retirement O(1) instead of a scan — a
    /// 10⁵-flow wind-down would otherwise be quadratic.
    live_pos: Vec<usize>,
    /// Per-group client downlink capacities (bytes/s);
    /// `f64::INFINITY` means the WAN links are the only bottleneck for
    /// that group. Group 0 always exists (the [`FlowSet::new`] cap).
    groups: Vec<f64>,
    // Reusable scratch (never shrinks): the steady state of
    // `advance_some_into` allocates nothing.
    /// `(flow id, rate)` snapshot of the current sub-step.
    bws: Vec<(usize, f64)>,
    /// Per-group aggregate rate of the current sub-step.
    totals: Vec<f64>,
    /// Per-site memo of `current_bandwidth(s).min(disk)` …
    site_rate: Vec<f64>,
    /// … valid for site `s` iff `site_mark[s] == mark`.
    site_mark: Vec<u64>,
    mark: u64,
}

impl FlowSet {
    /// A set with a single downlink group 0 capped at `downlink` — the
    /// one-client configuration every pre-runtime caller uses.
    pub fn new(downlink: f64) -> FlowSet {
        FlowSet {
            site: Vec::new(),
            remaining: Vec::new(),
            delivered: Vec::new(),
            lead: Vec::new(),
            started_at: Vec::new(),
            finished_at: Vec::new(),
            cancelled: Vec::new(),
            group: Vec::new(),
            live_ids: Vec::new(),
            live_pos: Vec::new(),
            groups: vec![downlink],
            bws: Vec::new(),
            totals: Vec::new(),
            site_rate: Vec::new(),
            site_mark: Vec::new(),
            mark: 0,
        }
    }

    /// [`FlowSet::new`] with all columns pre-sized for `n` flows — the
    /// surge path reserves once up front.
    pub fn with_capacity(downlink: f64, n: usize) -> FlowSet {
        let mut fs = FlowSet::new(downlink);
        fs.site.reserve(n);
        fs.remaining.reserve(n);
        fs.delivered.reserve(n);
        fs.lead.reserve(n);
        fs.started_at.reserve(n);
        fs.finished_at.reserve(n);
        fs.cancelled.reserve(n);
        fs.group.reserve(n);
        fs.live_ids.reserve(n);
        fs.live_pos.reserve(n);
        fs.bws.reserve(n);
        fs
    }

    /// Register another client endpoint with its own downlink capacity;
    /// returns the group id to pass to [`FlowSet::add_in`]. Flows in
    /// different groups contend only on shared site links, never on
    /// each other's downlink.
    pub fn add_group(&mut self, downlink: f64) -> usize {
        self.groups.push(downlink);
        self.groups.len() - 1
    }

    /// Downlink capacity of `group`.
    pub fn group_cap(&self, group: usize) -> f64 {
        self.groups[group]
    }

    /// Number of downlink groups (≥ 1).
    pub fn group_count(&self) -> usize {
        self.groups.len()
    }

    /// Add a flow of `bytes` from `site` in downlink group 0, paying
    /// `lead` seconds of setup latency first. Returns the flow's index.
    pub fn add(&mut self, topo: &Topology, site: usize, bytes: f64, lead: f64) -> usize {
        self.add_in(topo, site, bytes, lead, 0)
    }

    /// [`FlowSet::add`] into an explicit downlink group.
    pub fn add_in(
        &mut self,
        topo: &Topology,
        site: usize,
        bytes: f64,
        lead: f64,
        group: usize,
    ) -> usize {
        debug_assert!(group < self.groups.len());
        let id = self.site.len();
        self.site.push(site);
        self.remaining.push(bytes.max(0.0));
        self.delivered.push(0.0);
        self.lead.push(lead.max(0.0));
        self.started_at.push(topo.now);
        self.finished_at.push(UNFINISHED);
        self.cancelled.push(false);
        self.group.push(group);
        self.live_pos.push(self.live_ids.len());
        self.live_ids.push(id);
        id
    }

    /// Total flows ever added (finished and cancelled ones included).
    pub fn len(&self) -> usize {
        self.site.len()
    }

    pub fn is_empty(&self) -> bool {
        self.site.is_empty()
    }

    /// By-value view of one flow, materialized from the columns.
    pub fn flow(&self, idx: usize) -> Flow {
        let fin = self.finished_at[idx];
        Flow {
            site: self.site[idx],
            remaining: self.remaining[idx],
            delivered: self.delivered[idx],
            lead: self.lead[idx],
            started_at: self.started_at[idx],
            finished_at: if fin.is_nan() { None } else { Some(fin) },
            cancelled: self.cancelled[idx],
            group: self.group[idx],
        }
    }

    /// Number of flows still moving bytes.
    pub fn live(&self) -> usize {
        self.live_ids.len()
    }

    /// Σ (delivered − lead) over every flow ever added, in index
    /// order: grows whenever anything moved — the kernel's stall
    /// detector ([`crate::simnet::engine::Engine`]).
    pub fn progress_metric(&self) -> f64 {
        self.delivered.iter().zip(&self.lead).map(|(d, l)| d - l).sum()
    }

    /// Drop the live entry at `live_ids[pos]`, keeping the position
    /// index consistent (the classic swap-remove bookkeeping).
    fn unlive_at(&mut self, pos: usize) {
        let flow = self.live_ids.swap_remove(pos);
        self.live_pos[flow] = RETIRED;
        if pos < self.live_ids.len() {
            self.live_pos[self.live_ids[pos]] = pos;
        }
    }

    fn retire(&mut self, flow: usize) {
        let pos = self.live_pos[flow];
        if pos != RETIRED {
            self.unlive_at(pos);
        }
    }

    /// Abandon a live flow: it stops moving bytes, never completes, and
    /// frees its share of the downlink immediately. The failover path
    /// uses this when a source dies or stalls mid-block. No-op on a
    /// flow that already finished.
    pub fn cancel(&mut self, flow: usize) {
        if self.finished_at[flow].is_nan() {
            self.cancelled[flow] = true;
            self.retire(flow);
        }
    }

    /// Byte rate of each *live* flow right now, as `(flow id, rate)`
    /// pairs: the site link's sampled share via
    /// [`Topology::current_bandwidth`] (same-site flows divide the link
    /// through the `active_transfers` counter their registration
    /// bumped), capped by the source's disk streaming rate (the
    /// slower pipeline stage dominates, as in
    /// [`Topology::transfer_from`]), then scaled down per downlink
    /// group if that group's aggregate exceeds its client downlink.
    /// Flows still paying connection-setup latency move nothing yet and
    /// do not consume downlink.
    ///
    /// This is the allocating diagnostic entry point (samplers and
    /// property tests); the kernel's sub-step uses the scratch-backed
    /// twin of the same arithmetic.
    pub fn bandwidths(&self, topo: &mut Topology) -> Vec<(usize, f64)> {
        let mut bws: Vec<(usize, f64)> = Vec::with_capacity(self.live_ids.len());
        let mut totals = vec![0.0f64; self.groups.len()];
        for &i in &self.live_ids {
            let bw = if self.lead[i] > 0.0 {
                0.0
            } else {
                let disk = topo.site(self.site[i]).cfg.disk_rate;
                topo.current_bandwidth(self.site[i]).min(disk)
            };
            totals[self.group[i]] += bw;
            bws.push((i, bw));
        }
        for pair in &mut bws {
            let g = self.group[pair.0];
            if totals[g] > self.groups[g] {
                pair.1 *= self.groups[g] / totals[g];
            }
        }
        bws
    }

    /// Scratch-backed twin of [`FlowSet::bandwidths`]: same iteration
    /// order, same summation order, same clip arithmetic — into the
    /// caller-provided snapshot instead of a fresh `Vec`. The per-site
    /// link share is computed once per sub-step and memoized
    /// (stamp-validated), which is bit-identical because
    /// `current_bandwidth` is pure between clock advances: the link's
    /// AR(1) state only steps when the 60 s bucket index grows, the
    /// fault view only refreshes when the clock crosses a boundary,
    /// and `active_transfers` never changes mid-sub-step.
    fn fill_rates(&mut self, topo: &mut Topology, bws: &mut Vec<(usize, f64)>) {
        bws.clear();
        self.totals.clear();
        self.totals.resize(self.groups.len(), 0.0);
        self.mark += 1;
        for &i in &self.live_ids {
            let bw = if self.lead[i] > 0.0 {
                0.0
            } else {
                let s = self.site[i];
                if s >= self.site_rate.len() {
                    self.site_rate.resize(s + 1, 0.0);
                    self.site_mark.resize(s + 1, 0);
                }
                if self.site_mark[s] != self.mark {
                    let disk = topo.site(s).cfg.disk_rate;
                    self.site_rate[s] = topo.current_bandwidth(s).min(disk);
                    self.site_mark[s] = self.mark;
                }
                self.site_rate[s]
            };
            self.totals[self.group[i]] += bw;
            bws.push((i, bw));
        }
        for pair in bws.iter_mut() {
            let g = self.group[pair.0];
            if self.totals[g] > self.groups[g] {
                pair.1 *= self.groups[g] / self.totals[g];
            }
        }
    }

    /// Advance every live flow by `dt` simulated seconds, splitting the
    /// step at completions so freed capacity is re-shared immediately.
    /// Advances `topo.now` by `dt` and returns the completions in time
    /// order.
    pub fn advance(&mut self, topo: &mut Topology, dt: f64) -> Vec<Completion> {
        let mut out = Vec::new();
        let mut left = dt.max(0.0);
        let t_end = topo.now + left;
        while left > 1e-12 && !self.live_ids.is_empty() {
            let before = out.len();
            let used = self.advance_some_into(topo, left, &mut out);
            left -= used;
            if out.len() == before {
                // The whole remainder elapsed with nothing finishing.
                break;
            }
        }
        // Idle remainder of the window (all flows done early).
        if topo.now < t_end {
            let gap = t_end - topo.now;
            topo.advance(gap);
        }
        out
    }

    /// Advance until the first completion(s) or until `dt` elapses,
    /// whichever comes first. Returns the simulated time consumed and
    /// the completions (empty ⇔ the full `dt` passed, or no flows are
    /// live). Unlike [`FlowSet::advance`] this never idles past an
    /// event, so a scheduler can hand freed capacity new work at the
    /// exact completion instant.
    pub fn advance_some(&mut self, topo: &mut Topology, dt: f64) -> (f64, Vec<Completion>) {
        let mut out = Vec::new();
        let used = self.advance_some_into(topo, dt, &mut out);
        (used, out)
    }

    /// Allocation-free [`FlowSet::advance_some`]: completions are
    /// appended to `out` (the kernel reuses one buffer across events)
    /// and the simulated time consumed is returned. Stops at the first
    /// sub-step that produced completions, exactly like its allocating
    /// wrapper.
    pub fn advance_some_into(
        &mut self,
        topo: &mut Topology,
        dt: f64,
        out: &mut Vec<Completion>,
    ) -> f64 {
        let start = out.len();
        let mut left = dt.max(0.0);
        let mut consumed = 0.0;
        // Detach the scratch snapshot so the columns stay mutable while
        // it is read (restored on exit; `take` swaps, never allocates).
        let mut bws = std::mem::take(&mut self.bws);
        while left > 1e-12 && !self.live_ids.is_empty() && out.len() == start {
            // Zero-length (or numerically drained) flows complete
            // immediately — otherwise they would pin `step` at 0 and
            // the loop could never consume `left`.
            let now = topo.now;
            let mut k = 0;
            while k < self.live_ids.len() {
                let i = self.live_ids[k];
                if self.lead[i] <= 0.0 && self.remaining[i] <= 1e-6 {
                    self.remaining[i] = 0.0;
                    self.finished_at[i] = now;
                    out.push(Completion { flow: i, at: now });
                    self.unlive_at(k);
                } else {
                    k += 1;
                }
            }
            if out.len() > start {
                break;
            }
            self.fill_rates(topo, &mut bws);
            // Earliest event within this sub-step: a flow finishing, or
            // a flow leaving connection setup (its rate changes then).
            let mut step = left;
            for &(i, bw) in &bws {
                if self.lead[i] > 0.0 {
                    step = step.min(self.lead[i]);
                } else if bw > 0.0 {
                    step = step.min(self.remaining[i] / bw);
                }
            }
            // A scheduled fault boundary is an event too — trigger
            // *and* heal instants: stop the step there so a
            // dying/degrading site's flows re-sample their rate at the
            // exact boundary instead of coasting. No bytes delivered
            // past a death, no free bytes before a heal.
            if let Some(at) = topo.next_fault_after(now) {
                let until = at - now;
                if until > 1e-9 {
                    step = step.min(until);
                }
            }
            // Move bytes for `step` seconds at the sampled rates.
            for &(i, bw) in &bws {
                let mut avail = step;
                if self.lead[i] > 0.0 {
                    let used = self.lead[i].min(avail);
                    self.lead[i] -= used;
                    avail -= used;
                }
                if avail > 0.0 {
                    let moved = (bw * avail).min(self.remaining[i]);
                    self.remaining[i] -= moved;
                    self.delivered[i] += moved;
                    if self.remaining[i] <= 1e-6 {
                        self.remaining[i] = 0.0;
                        self.finished_at[i] = now + step;
                        out.push(Completion { flow: i, at: now + step });
                        self.retire(i);
                    }
                }
            }
            topo.advance(step);
            consumed += step;
            left -= step;
        }
        self.bws = bws;
        consumed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GridConfig;

    fn flat_topo(n: usize) -> Topology {
        // Deterministic links: no noise, no congestion, no diurnal.
        let mut cfg = GridConfig::generate(n, 5);
        for s in &mut cfg.sites {
            s.wan_bandwidth = 1e6;
            s.diurnal_amp = 0.0;
            s.noise_frac = 0.0;
            s.congestion_prob = 0.0;
            s.ar_coeff = 0.0;
            s.latency = 0.0;
        }
        Topology::build(&cfg)
    }

    #[test]
    fn single_flow_matches_link_rate() {
        let mut topo = flat_topo(2);
        let mut fs = FlowSet::new(f64::INFINITY);
        fs.add(&topo, 0, 1e6, 0.0);
        // No begin_transfer: share = full pipe (1e6 B/s) → 1 second.
        let done = fs.advance(&mut topo, 10.0);
        assert_eq!(done.len(), 1);
        assert!((done[0].at - 1.0).abs() < 1e-6, "at {}", done[0].at);
        assert!((topo.now - 10.0).abs() < 1e-9);
    }

    #[test]
    fn same_site_flows_split_the_pipe() {
        let mut topo = flat_topo(2);
        // Both streams register, per the module convention.
        topo.begin_transfer(0);
        topo.begin_transfer(0);
        let mut fs = FlowSet::new(f64::INFINITY);
        fs.add(&topo, 0, 1e6, 0.0);
        fs.add(&topo, 0, 1e6, 0.0);
        let done = fs.advance(&mut topo, 30.0);
        // Identical to two concurrent GridFtp fetches: active=2 →
        // share 1/3 each (1e6/3 B/s) → both complete at t=3.
        assert_eq!(done.len(), 2);
        for c in &done {
            assert!((c.at - 3.0).abs() < 1e-6, "at {}", c.at);
        }
    }

    #[test]
    fn completion_returns_downlink_capacity_mid_step() {
        let mut topo = flat_topo(3);
        let mut fs = FlowSet::new(1e6); // cap below the 2e6 aggregate
        fs.add(&topo, 0, 0.5e6, 0.0); // finishes first
        fs.add(&topo, 1, 1.5e6, 0.0);
        let done = fs.advance(&mut topo, 30.0);
        assert_eq!(done.len(), 2);
        // Capped at 0.5e6 each until t=1; then the survivor takes the
        // whole 1e6 cap: remaining 1.0e6 → done at t=2, not t=3.
        assert!((done[0].at - 1.0).abs() < 1e-6, "first at {}", done[0].at);
        assert!((done[1].at - 2.0).abs() < 1e-6, "second at {}", done[1].at);
    }

    #[test]
    fn setup_phase_flows_do_not_consume_downlink() {
        let mut topo = flat_topo(3);
        let mut fs = FlowSet::new(1e6);
        fs.add(&topo, 0, 1e6, 0.0);
        fs.add(&topo, 1, 1e6, 2.0); // still connecting
        let done = fs.advance(&mut topo, 30.0);
        assert_eq!(done.len(), 2);
        // The connecting flow must not halve the cap: flow A takes the
        // whole 1e6 B/s and finishes at t=1, flow B at 2s lead + 1s.
        assert!((done[0].at - 1.0).abs() < 1e-6, "A at {}", done[0].at);
        assert!((done[1].at - 3.0).abs() < 1e-6, "B at {}", done[1].at);
    }

    #[test]
    fn disk_rate_caps_flow_bandwidth() {
        let mut topo = {
            let mut cfg = crate::config::GridConfig::generate(2, 5);
            for s in &mut cfg.sites {
                s.wan_bandwidth = 10e6;
                s.disk_rate = 1e6; // disk-bound site
                s.diurnal_amp = 0.0;
                s.noise_frac = 0.0;
                s.congestion_prob = 0.0;
                s.ar_coeff = 0.0;
                s.latency = 0.0;
            }
            Topology::build(&cfg)
        };
        let mut fs = FlowSet::new(f64::INFINITY);
        fs.add(&topo, 0, 2e6, 0.0);
        let done = fs.advance(&mut topo, 30.0);
        // 2e6 bytes through a 1e6 B/s disk (WAN would allow 10e6).
        assert!((done[0].at - 2.0).abs() < 1e-6, "at {}", done[0].at);
    }

    #[test]
    fn zero_byte_flow_completes_instead_of_hanging() {
        let mut topo = flat_topo(2);
        let mut fs = FlowSet::new(f64::INFINITY);
        fs.add(&topo, 0, 0.0, 0.0);
        fs.add(&topo, 1, 1e6, 0.0);
        let done = fs.advance(&mut topo, 10.0);
        assert_eq!(done.len(), 2);
        assert!((done[0].at - 0.0).abs() < 1e-9, "zero flow at {}", done[0].at);
        assert!((done[1].at - 1.0).abs() < 1e-6);
        assert!((topo.now - 10.0).abs() < 1e-9);
    }

    #[test]
    fn distinct_sites_do_not_interfere() {
        let mut topo = flat_topo(3);
        let mut fs = FlowSet::new(f64::INFINITY);
        fs.add(&topo, 0, 1e6, 0.0);
        fs.add(&topo, 1, 1e6, 0.0);
        fs.add(&topo, 2, 1e6, 0.0);
        let done = fs.advance(&mut topo, 10.0);
        assert_eq!(done.len(), 3);
        for c in &done {
            assert!((c.at - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn downlink_cap_bounds_aggregate() {
        let mut topo = flat_topo(4);
        let mut fs = FlowSet::new(1e6); // client pipe = one site's rate
        for s in 0..4 {
            fs.add(&topo, s, 1e6, 0.0);
        }
        let done = fs.advance(&mut topo, 60.0);
        assert_eq!(done.len(), 4);
        // 4e6 bytes through a 1e6 B/s cap → last completion at t≈4.
        let last = done.iter().map(|c| c.at).fold(0.0, f64::max);
        assert!((last - 4.0).abs() < 1e-6, "last {last}");
    }

    #[test]
    fn cancel_frees_downlink_and_never_completes() {
        let mut topo = flat_topo(3);
        let mut fs = FlowSet::new(1e6); // cap below the 2e6 aggregate
        let a = fs.add(&topo, 0, 2e6, 0.0);
        let b = fs.add(&topo, 1, 1e6, 0.0);
        // Half a second at 0.5e6 B/s each, then flow A is abandoned.
        let done = fs.advance(&mut topo, 0.5);
        assert!(done.is_empty());
        fs.cancel(a);
        assert!(fs.flow(a).cancelled);
        assert_eq!(fs.live(), 1);
        // The survivor takes the whole cap: 0.75e6 left → done at t=1.25.
        let done = fs.advance(&mut topo, 10.0);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].flow, b);
        assert!((done[0].at - 1.25).abs() < 1e-6, "at {}", done[0].at);
        assert!(fs.flow(a).finished_at.is_none());
        // Cancelling a finished flow is a no-op.
        fs.cancel(b);
        assert!(!fs.flow(b).cancelled);
    }

    #[test]
    fn death_mid_step_stops_bytes_at_the_fault_instant() {
        use crate::simnet::topology::FaultKind;
        let mut topo = flat_topo(2);
        // Alive, the 1e6-byte flow would finish at t=1; the site dies
        // at t=0.5, so exactly half the bytes may move.
        topo.schedule_fault(0, 0.5, FaultKind::ReplicaDeath);
        let mut fs = FlowSet::new(f64::INFINITY);
        let f = fs.add(&topo, 0, 1e6, 0.0);
        let done = fs.advance(&mut topo, 10.0);
        assert!(done.is_empty(), "dead site must not complete the flow");
        assert!(
            (fs.flow(f).delivered - 0.5e6).abs() < 1.0,
            "delivered {} past the death instant",
            fs.flow(f).delivered
        );
        assert!((topo.now - 10.0).abs() < 1e-9);
    }

    #[test]
    fn heal_mid_step_resumes_bytes_at_the_heal_instant() {
        use crate::simnet::topology::FaultKind;
        let mut topo = flat_topo(2);
        // The site is down over [0.5, 1.5): a 2e6-byte flow on the
        // 1e6 B/s pipe moves 0.5e6 bytes, stalls one second, then
        // finishes the remaining 1.5e6 — completion at exactly t=3.
        topo.schedule_fault_for(0, 0.5, 1.0, FaultKind::ReplicaDeath);
        let mut fs = FlowSet::new(f64::INFINITY);
        let f = fs.add(&topo, 0, 2e6, 0.0);
        let done = fs.advance(&mut topo, 10.0);
        assert_eq!(done.len(), 1, "healed flow must complete");
        assert!((done[0].at - 3.0).abs() < 1e-6, "at {}", done[0].at);
        assert!((fs.flow(f).delivered - 2e6).abs() < 1.0);
    }

    #[test]
    fn no_free_bytes_before_the_heal_instant() {
        use crate::simnet::topology::FaultKind;
        let mut topo = flat_topo(2);
        topo.schedule_fault_for(0, 0.5, 1.0, FaultKind::ReplicaDeath);
        let mut fs = FlowSet::new(f64::INFINITY);
        let f = fs.add(&topo, 0, 2e6, 0.0);
        // Integrate through the outage in coarse steps that straddle
        // both boundaries; the sub-step split must pin the byte count
        // to exactly the up-time.
        fs.advance(&mut topo, 1.0); // t=1.0: inside the outage
        assert!(
            (fs.flow(f).delivered - 0.5e6).abs() < 1.0,
            "delivered {} while the site was down",
            fs.flow(f).delivered
        );
        fs.advance(&mut topo, 0.4); // t=1.4: still down
        assert!((fs.flow(f).delivered - 0.5e6).abs() < 1.0);
        fs.advance(&mut topo, 0.6); // t=2.0: healed at 1.5, 0.5 s of flow
        assert!(
            (fs.flow(f).delivered - 1.0e6).abs() < 1.0,
            "delivered {} after the heal",
            fs.flow(f).delivered
        );
    }

    #[test]
    fn flap_interval_slows_then_restores_the_rate() {
        use crate::simnet::topology::FaultKind;
        let mut topo = flat_topo(2);
        // 0.5× degradation over [0.0, 1.0): a 2e6-byte flow moves
        // 0.5e6 in the flap, then 1.5e6 at full rate → done at 2.5.
        topo.schedule_fault_for(0, 0.0, 1.0, FaultKind::LinkDegrade { factor: 0.5 });
        let mut fs = FlowSet::new(f64::INFINITY);
        fs.add(&topo, 0, 2e6, 0.0);
        let done = fs.advance(&mut topo, 10.0);
        assert_eq!(done.len(), 1);
        assert!((done[0].at - 2.5).abs() < 1e-6, "at {}", done[0].at);
    }

    #[test]
    fn dead_site_flows_stall_without_blocking_time() {
        use crate::simnet::topology::FaultKind;
        let mut topo = flat_topo(2);
        topo.schedule_fault(0, 0.0, FaultKind::ReplicaDeath);
        let mut fs = FlowSet::new(f64::INFINITY);
        let dead = fs.add(&topo, 0, 1e6, 0.0);
        fs.add(&topo, 1, 1e6, 0.0);
        let done = fs.advance(&mut topo, 10.0);
        // The healthy flow completes; the dead one stalls but time
        // still advances past it.
        assert_eq!(done.len(), 1);
        assert!((done[0].at - 1.0).abs() < 1e-6);
        assert!((topo.now - 10.0).abs() < 1e-9);
        assert!(fs.flow(dead).finished_at.is_none());
        assert_eq!(fs.flow(dead).delivered, 0.0);
    }

    #[test]
    fn lead_latency_delays_bytes() {
        let mut topo = flat_topo(2);
        let mut fs = FlowSet::new(f64::INFINITY);
        fs.add(&topo, 0, 1e6, 0.5);
        let done = fs.advance(&mut topo, 10.0);
        assert!((done[0].at - 1.5).abs() < 1e-6, "at {}", done[0].at);
    }

    #[test]
    fn groups_do_not_share_downlink() {
        let mut topo = flat_topo(3);
        let mut fs = FlowSet::new(1e6);
        let g2 = fs.add_group(1e6);
        // Two flows from distinct sites in distinct groups: neither
        // group's 1e6 cap binds (each group aggregates one 1e6 flow),
        // so both finish at t=1 — unlike the single-group case where
        // they would split one cap and finish at t=2.
        fs.add_in(&topo, 0, 1e6, 0.0, 0);
        fs.add_in(&topo, 1, 1e6, 0.0, g2);
        let done = fs.advance(&mut topo, 10.0);
        assert_eq!(done.len(), 2);
        for c in &done {
            assert!((c.at - 1.0).abs() < 1e-6, "at {}", c.at);
        }
    }

    #[test]
    fn per_group_caps_bind_independently() {
        let mut topo = flat_topo(4);
        let mut fs = FlowSet::new(0.5e6); // group 0: tight cap
        let g2 = fs.add_group(f64::INFINITY); // group 1: uncapped
        fs.add_in(&topo, 0, 1e6, 0.0, 0);
        fs.add_in(&topo, 1, 1e6, 0.0, 0);
        fs.add_in(&topo, 2, 1e6, 0.0, g2);
        // Group 0: 2e6 aggregate clipped to 0.5e6 → 0.25e6 each → t=4.
        // Group 1: full link rate → t=1.
        let done = fs.advance(&mut topo, 30.0);
        assert_eq!(done.len(), 3);
        assert!((done[0].at - 1.0).abs() < 1e-6, "uncapped at {}", done[0].at);
        assert!((done[1].at - 4.0).abs() < 1e-6, "capped at {}", done[1].at);
        assert!((done[2].at - 4.0).abs() < 1e-6, "capped at {}", done[2].at);
        assert_eq!(fs.group_count(), 2);
        assert_eq!(fs.group_cap(0), 0.5e6);
    }

    #[test]
    fn same_site_cross_group_flows_still_share_the_link() {
        let mut topo = flat_topo(2);
        // Two clients fetching from one site: the link is the shared
        // resource even though downlinks are disjoint.
        topo.begin_transfer(0);
        topo.begin_transfer(0);
        let mut fs = FlowSet::new(f64::INFINITY);
        let g2 = fs.add_group(f64::INFINITY);
        fs.add_in(&topo, 0, 1e6, 0.0, 0);
        fs.add_in(&topo, 0, 1e6, 0.0, g2);
        let done = fs.advance(&mut topo, 30.0);
        // active=2 → share 1/3 each → both complete at t=3, exactly as
        // two same-group streams would.
        assert_eq!(done.len(), 2);
        for c in &done {
            assert!((c.at - 3.0).abs() < 1e-6, "at {}", c.at);
        }
    }

    #[test]
    fn respects_active_transfer_sharing_convention() {
        let mut topo = flat_topo(2);
        topo.begin_transfer(0); // the stream registered itself
        let mut fs = FlowSet::new(f64::INFINITY);
        fs.add(&topo, 0, 1e6, 0.0);
        let done = fs.advance(&mut topo, 10.0);
        // active_transfers=1 → share 1/2 → 2 seconds, matching what a
        // GridFtp::fetch of the same bytes would see.
        assert!((done[0].at - 2.0).abs() < 1e-6, "at {}", done[0].at);
    }

    #[test]
    fn soa_view_and_scratch_paths_agree() {
        // The by-value Flow view reflects the columns, the scratch
        // rate path matches the allocating diagnostic one, and O(1)
        // retirement leaves the live set consistent.
        let mut topo = flat_topo(4);
        let mut fs = FlowSet::with_capacity(f64::INFINITY, 8);
        let ids: Vec<usize> = (0..4).map(|s| fs.add(&topo, s, (s as f64 + 1.0) * 1e5, 0.0)).collect();
        assert_eq!(fs.len(), 4);
        assert_eq!(fs.live(), 4);
        let via_diag = fs.bandwidths(&mut topo);
        let mut scratch = Vec::new();
        fs.fill_rates(&mut topo, &mut scratch);
        assert_eq!(via_diag, scratch, "diagnostic and scratch rates must agree");
        fs.cancel(ids[1]);
        assert_eq!(fs.live(), 3);
        let done = fs.advance(&mut topo, 30.0);
        assert_eq!(done.len(), 3);
        assert!(fs.flow(ids[1]).cancelled);
        assert!(fs.flow(ids[1]).finished_at.is_none());
        for &id in [ids[0], ids[2], ids[3]].iter() {
            assert!(fs.flow(id).is_done());
            assert_eq!(fs.flow(id).remaining, 0.0);
        }
        assert_eq!(fs.live(), 0);
        // progress_metric sums delivered − lead over all flows ever
        // added, index order.
        let manual: f64 = (0..fs.len()).map(|i| fs.flow(i).delivered - fs.flow(i).lead).sum();
        assert_eq!(fs.progress_metric(), manual);
    }
}
