//! Workload generation: the request streams driving the experiments.
//!
//! Clients request logical files with Zipf popularity, Pareto file
//! sizes (scientific datasets are heavy-tailed) and Poisson arrivals —
//! the standard 2001-era data-grid workload assumptions.

use crate::util::prng::Rng;

/// One replica-access request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Arrival time (simulated seconds).
    pub at: f64,
    /// Client id.
    pub client: usize,
    /// Logical file index.
    pub file: usize,
    /// Required read bandwidth floor (bytes/s) the request ad carries
    /// (0 = unconstrained).
    pub min_bandwidth: f64,
}

/// Workload parameters.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub clients: usize,
    pub files: usize,
    /// Mean request inter-arrival (seconds).
    pub mean_interarrival: f64,
    /// Zipf skew for file popularity (0 = uniform-ish, →1 = very skewed).
    pub zipf_theta: f64,
    /// Fraction of requests that carry a bandwidth floor.
    pub constrained_frac: f64,
    /// The floor, bytes/s, when present.
    pub bandwidth_floor: f64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            clients: 8,
            files: 32,
            mean_interarrival: 30.0,
            zipf_theta: 0.8,
            constrained_frac: 0.2,
            bandwidth_floor: 50.0 * 1024.0, // the paper's 50K/Sec
        }
    }
}

/// A lazily generated request stream.
pub struct Workload {
    spec: WorkloadSpec,
    rng: Rng,
    now: f64,
}

impl Workload {
    pub fn new(spec: WorkloadSpec, seed: u64) -> Workload {
        Workload { spec, rng: Rng::new(seed ^ 0x30AD_10AD), now: 0.0 }
    }

    /// Generate the next request.
    pub fn next_request(&mut self) -> Request {
        self.now += self.rng.exp(1.0 / self.spec.mean_interarrival);
        let file = self.rng.zipf(self.spec.files, self.spec.zipf_theta);
        Request {
            at: self.now,
            client: self.rng.index(self.spec.clients),
            file,
            min_bandwidth: if self.rng.chance(self.spec.constrained_frac) {
                self.spec.bandwidth_floor
            } else {
                0.0
            },
        }
    }

    /// Generate `n` requests in arrival order.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }

    /// Pareto file sizes for the catalog (index-addressed, deterministic
    /// for a given workload seed).
    pub fn file_sizes(spec: &WorkloadSpec, seed: u64, median_mb: f64) -> Vec<f64> {
        let mut rng = Rng::new(seed ^ 0xF11E_5125);
        (0..spec.files)
            .map(|_| {
                let mb = rng.pareto(median_mb / 1.5, 1.3).min(median_mb * 100.0);
                mb * 1024.0 * 1024.0
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_monotone_and_poisson_ish() {
        let mut w = Workload::new(WorkloadSpec::default(), 1);
        let reqs = w.take(2000);
        for pair in reqs.windows(2) {
            assert!(pair[1].at >= pair[0].at);
        }
        let gaps: Vec<f64> = reqs.windows(2).map(|p| p[1].at - p[0].at).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        assert!((mean - 30.0).abs() < 3.0, "mean gap {mean}");
    }

    #[test]
    fn popularity_skewed() {
        let mut w = Workload::new(WorkloadSpec::default(), 2);
        let reqs = w.take(5000);
        let mut counts = vec![0usize; w.spec.files];
        for r in &reqs {
            counts[r.file] += 1;
        }
        let top: usize = counts.iter().copied().max().unwrap();
        let med = {
            let mut c = counts.clone();
            c.sort_unstable();
            c[c.len() / 2]
        };
        assert!(top > med * 3, "top {top} median {med}");
    }

    #[test]
    fn constrained_fraction_respected() {
        let mut w = Workload::new(WorkloadSpec { constrained_frac: 0.5, ..Default::default() }, 3);
        let reqs = w.take(4000);
        let frac = reqs.iter().filter(|r| r.min_bandwidth > 0.0).count() as f64 / 4000.0;
        assert!((frac - 0.5).abs() < 0.05, "frac {frac}");
    }

    #[test]
    fn sizes_heavy_tailed_and_deterministic() {
        let spec = WorkloadSpec::default();
        let a = Workload::file_sizes(&spec, 9, 100.0);
        let b = Workload::file_sizes(&spec, 9, 100.0);
        assert_eq!(a, b);
        let max = a.iter().cloned().fold(0.0, f64::max);
        let min = a.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 5.0);
    }

    #[test]
    fn clients_in_range() {
        let mut w = Workload::new(WorkloadSpec::default(), 4);
        for r in w.take(500) {
            assert!(r.client < 8);
            assert!(r.file < 32);
        }
    }
}
