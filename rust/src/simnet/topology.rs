//! Grid topology: sites with storage state and their WAN links.

use std::collections::BTreeMap;

use crate::config::{GridConfig, SiteConfig};
use crate::util::prng::Rng;

use super::link::Link;

/// A storage site's simulated state.
#[derive(Debug, Clone)]
pub struct Site {
    pub cfg: SiteConfig,
    /// Bytes currently used on the volume.
    pub used: f64,
    /// Number of transfers currently in flight from this site.
    pub active_transfers: usize,
}

impl Site {
    pub fn available_space(&self) -> f64 {
        (self.cfg.total_space - self.used).max(0.0)
    }

    /// Current utilization in [0,1] — published as the GRIS "load"
    /// dynamic attribute and used by the paper's §3.2 heuristic.
    pub fn load(&self) -> f64 {
        // Saturating occupancy model: each active transfer consumes a
        // share of the site's service capacity.
        (self.active_transfers as f64 / 8.0).min(1.0)
    }
}

/// The whole simulated grid: sites + per-site client-facing links.
#[derive(Clone)]
pub struct Topology {
    sites: Vec<Site>,
    links: Vec<Link>,
    by_name: BTreeMap<String, usize>,
    /// Simulated wall clock (seconds).
    pub now: f64,
}

impl Topology {
    /// Build from a config; all randomness forks from `cfg.seed`.
    pub fn build(cfg: &GridConfig) -> Topology {
        let mut rng = Rng::new(cfg.seed);
        let mut sites = Vec::new();
        let mut links = Vec::new();
        let mut by_name = BTreeMap::new();
        for (i, sc) in cfg.sites.iter().enumerate() {
            by_name.insert(sc.name.clone(), i);
            links.push(Link::from_site(sc, rng.fork(i as u64)));
            sites.push(Site {
                cfg: sc.clone(),
                used: sc.total_space * sc.used_frac,
                active_transfers: 0,
            });
        }
        Topology { sites, links, by_name, now: 0.0 }
    }

    pub fn len(&self) -> usize {
        self.sites.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sites.is_empty()
    }

    pub fn site(&self, idx: usize) -> &Site {
        &self.sites[idx]
    }

    pub fn site_mut(&mut self, idx: usize) -> &mut Site {
        &mut self.sites[idx]
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }

    pub fn site_by_name(&self, name: &str) -> Option<&Site> {
        self.index_of(name).map(|i| self.site(i))
    }

    pub fn sites(&self) -> impl Iterator<Item = &Site> {
        self.sites.iter()
    }

    /// Advance simulated time.
    pub fn advance(&mut self, dt: f64) {
        self.now += dt;
    }

    /// Sample the instantaneous bandwidth a new transfer from `site`
    /// would get right now.
    pub fn current_bandwidth(&mut self, site: usize) -> f64 {
        let concurrent = self.sites[site].active_transfers;
        self.links[site].bandwidth_at(self.now, concurrent)
    }

    /// Simulate one read transfer of `bytes` from `site` starting now;
    /// returns (duration_s, mean_bandwidth). Includes the disk-read
    /// overhead (`drdTime`) and WAN latency; marks the transfer active
    /// for the duration with respect to *itself* only (the caller
    /// advances time between transfers as its workload dictates).
    pub fn transfer_from(&mut self, site: usize, bytes: f64) -> (f64, f64) {
        let concurrent = self.sites[site].active_transfers;
        let disk = self.sites[site].cfg.drd_time_ms / 1e3
            + bytes / self.sites[site].cfg.disk_rate;
        let wan = self.links[site].transfer_duration(self.now, bytes, concurrent);
        // Disk and WAN pipeline; the slower stage dominates.
        let duration = disk.max(wan);
        let mean_bw = bytes / duration;
        (duration, mean_bw)
    }

    /// Mark a transfer in flight (affects sharing for others).
    pub fn begin_transfer(&mut self, site: usize) {
        self.sites[site].active_transfers += 1;
    }

    pub fn end_transfer(&mut self, site: usize) {
        let s = &mut self.sites[site];
        s.active_transfers = s.active_transfers.saturating_sub(1);
    }

    /// A probe copy: identical upcoming link behaviour (same RNG
    /// state), so the clairvoyant oracle can measure "what would this
    /// transfer have cost from site X" without disturbing the real
    /// topology.
    pub fn clone_for_probe(&self) -> Topology {
        self.clone()
    }

    /// Consume space on a site (replica creation).
    pub fn consume_space(&mut self, site: usize, bytes: f64) {
        self.sites[site].used = (self.sites[site].used + bytes).min(self.sites[site].cfg.total_space);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::build(&GridConfig::generate(6, 11))
    }

    #[test]
    fn build_indexes_sites() {
        let t = topo();
        assert_eq!(t.len(), 6);
        let name = t.site(3).cfg.name.clone();
        assert_eq!(t.index_of(&name), Some(3));
        assert!(t.index_of("nope").is_none());
    }

    #[test]
    fn load_tracks_active_transfers() {
        let mut t = topo();
        assert_eq!(t.site(0).load(), 0.0);
        for _ in 0..4 {
            t.begin_transfer(0);
        }
        assert_eq!(t.site(0).load(), 0.5);
        for _ in 0..20 {
            t.begin_transfer(0);
        }
        assert_eq!(t.site(0).load(), 1.0);
        t.end_transfer(0);
        assert!(t.site(0).load() < 1.0 || t.site(0).active_transfers >= 8);
    }

    #[test]
    fn transfer_duration_reasonable() {
        let mut t = topo();
        let bytes = 10e6;
        let (d, bw) = t.transfer_from(0, bytes);
        assert!(d > 0.0);
        assert!((bw - bytes / d).abs() < 1e-6);
        // Mean bandwidth cannot exceed the configured pipe by much.
        assert!(bw <= t.site(0).cfg.wan_bandwidth * 4.0);
    }

    #[test]
    fn space_accounting() {
        let mut t = topo();
        let avail0 = t.site(2).available_space();
        t.consume_space(2, 1e9);
        assert!((avail0 - t.site(2).available_space() - 1e9).abs() < 1.0);
        // Saturates at capacity.
        t.consume_space(2, 1e18);
        assert_eq!(t.site(2).available_space(), 0.0);
    }

    #[test]
    fn deterministic_across_builds() {
        let mut a = topo();
        let mut b = topo();
        for i in 0..5 {
            a.advance(100.0);
            b.advance(100.0);
            let (da, _) = a.transfer_from(i % 6, 5e6);
            let (db, _) = b.transfer_from(i % 6, 5e6);
            assert_eq!(da, db);
        }
    }
}
