//! Grid topology: sites with storage state and their WAN links.

use std::collections::BTreeMap;

use crate::config::{GridConfig, SiteConfig};
use crate::util::prng::Rng;

use super::link::Link;

/// A storage site's simulated state.
#[derive(Debug, Clone)]
pub struct Site {
    pub cfg: SiteConfig,
    /// Bytes currently used on the volume.
    pub used: f64,
    /// Number of transfers currently in flight from this site.
    pub active_transfers: usize,
}

impl Site {
    pub fn available_space(&self) -> f64 {
        (self.cfg.total_space - self.used).max(0.0)
    }

    /// Current utilization in [0,1] — published as the GRIS "load"
    /// dynamic attribute and used by the paper's §3.2 heuristic.
    pub fn load(&self) -> f64 {
        // Saturating occupancy model: each active transfer consumes a
        // share of the site's service capacity.
        (self.active_transfers as f64 / 8.0).min(1.0)
    }
}

/// What happens to a site at a fault's trigger time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The replica server vanishes: transfers from it stall, and the
    /// control channel reports it dead ([`Topology::site_alive`]).
    ReplicaDeath,
    /// The site's WAN link degrades to `factor` (in (0,1]) of its
    /// modeled bandwidth — the EU-DataGrid "replica still there but
    /// crawling" failure mode.
    LinkDegrade { factor: f64 },
}

/// A scheduled fault: `kind` strikes `site` at simulated time `at` and
/// stays active over `[at, heal_at)`. `heal_at = ∞` is the PR-5
/// permanent fault; a finite `heal_at` models a crash the site
/// *recovers* from (grid weather) — at that instant the site is alive
/// again / the degradation lifts, and stalled flows resume.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fault {
    pub site: usize,
    pub at: f64,
    /// Instant the fault heals; `f64::INFINITY` = never.
    pub heal_at: f64,
    pub kind: FaultKind,
}

impl Fault {
    /// Whether this fault is active at instant `t` (`[at, heal_at)`).
    pub fn active_at(&self, t: f64) -> bool {
        self.at <= t && t < self.heal_at
    }
}

/// Per-site view of the fault set evaluated at `Topology::now`, so the
/// hot paths (`site_alive`, `degrade_factor` — called per flow per
/// integration sub-step) are O(1) lookups instead of linear scans over
/// every scheduled fault. Refreshed whenever the clock crosses
/// `next_change` (the earliest upcoming trigger or heal instant) and
/// whenever the fault set itself changes.
#[derive(Debug, Clone)]
struct FaultView {
    /// Indices into `Topology::faults`, per site, insertion order (the
    /// degrade product is order-sensitive in principle; keeping
    /// insertion order makes the cached product bit-identical to the
    /// old linear scan).
    by_site: Vec<Vec<usize>>,
    dead: Vec<bool>,
    degrade: Vec<f64>,
    /// Earliest instant strictly after the evaluation time at which
    /// any site's active set changes; `∞` when settled.
    next_change: f64,
}

impl FaultView {
    fn empty(n: usize) -> FaultView {
        FaultView {
            by_site: vec![Vec::new(); n],
            dead: vec![false; n],
            degrade: vec![1.0; n],
            next_change: f64::INFINITY,
        }
    }
}

/// The whole simulated grid: sites + per-site client-facing links.
#[derive(Clone)]
pub struct Topology {
    sites: Vec<Site>,
    links: Vec<Link>,
    by_name: BTreeMap<String, usize>,
    /// Scheduled faults (unordered; evaluated through `fault_view`).
    faults: Vec<Fault>,
    fault_view: FaultView,
    /// Simulated wall clock (seconds).
    pub now: f64,
}

impl Topology {
    /// Build from a config; all randomness forks from `cfg.seed`.
    pub fn build(cfg: &GridConfig) -> Topology {
        let mut rng = Rng::new(cfg.seed);
        let mut sites = Vec::new();
        let mut links = Vec::new();
        let mut by_name = BTreeMap::new();
        for (i, sc) in cfg.sites.iter().enumerate() {
            by_name.insert(sc.name.clone(), i);
            links.push(Link::from_site(sc, rng.fork(i as u64)));
            sites.push(Site {
                cfg: sc.clone(),
                used: sc.total_space * sc.used_frac,
                active_transfers: 0,
            });
        }
        Topology {
            fault_view: FaultView::empty(sites.len()),
            sites,
            links,
            by_name,
            faults: Vec::new(),
            now: 0.0,
        }
    }

    /// Schedule `kind` to strike `site` at simulated time `at`,
    /// permanently (heals only at [`Self::clear_faults`] — the PR-5
    /// semantics every existing caller relies on).
    pub fn schedule_fault(&mut self, site: usize, at: f64, kind: FaultKind) {
        self.schedule(Fault { site, at, heal_at: f64::INFINITY, kind });
    }

    /// Schedule `kind` to strike `site` at `at` and heal `downtime`
    /// seconds later (a crash the site recovers from). A non-finite
    /// `downtime` is permanent.
    pub fn schedule_fault_for(&mut self, site: usize, at: f64, downtime: f64, kind: FaultKind) {
        let heal_at = if downtime.is_finite() { at + downtime } else { f64::INFINITY };
        self.schedule(Fault { site, at, heal_at, kind });
    }

    /// Schedule a fully specified fault (weather plans build these).
    pub fn schedule(&mut self, fault: Fault) {
        debug_assert!(fault.site < self.sites.len());
        debug_assert!(fault.heal_at >= fault.at);
        let idx = self.faults.len();
        self.faults.push(fault);
        self.fault_view.by_site[fault.site].push(idx);
        self.refresh_fault_view();
    }

    /// Drop every scheduled fault (scenario reset between requests).
    pub fn clear_faults(&mut self) {
        self.faults.clear();
        self.fault_view = FaultView::empty(self.sites.len());
    }

    /// Every scheduled fault, in scheduling order (weather inspection,
    /// trace pre-recording).
    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    /// Re-evaluate the per-site fault cache at `self.now`.
    fn refresh_fault_view(&mut self) {
        let now = self.now;
        let mut next = f64::INFINITY;
        for site in 0..self.sites.len() {
            let mut dead = false;
            let mut degrade = 1.0f64;
            for &fi in &self.fault_view.by_site[site] {
                let f = &self.faults[fi];
                if f.at > now {
                    next = next.min(f.at);
                    continue;
                }
                if now < f.heal_at {
                    if f.heal_at.is_finite() {
                        next = next.min(f.heal_at);
                    }
                    match f.kind {
                        FaultKind::ReplicaDeath => dead = true,
                        FaultKind::LinkDegrade { factor } => degrade *= factor.clamp(0.0, 1.0),
                    }
                }
            }
            self.fault_view.dead[site] = dead;
            self.fault_view.degrade[site] = degrade;
        }
        self.fault_view.next_change = next;
    }

    /// Whether `site`'s replica server is reachable right now — false
    /// while a [`FaultKind::ReplicaDeath`] fault is active (between its
    /// trigger and its heal instant). This is the control-channel view
    /// a GridFTP client gets; data flows from a dead site deliver
    /// nothing (see [`Self::current_bandwidth`]). O(1): reads the
    /// per-site cache refreshed on clock advances.
    pub fn site_alive(&self, site: usize) -> bool {
        !self.fault_view.dead[site]
    }

    /// Earliest scheduled fault **boundary** (trigger or finite heal)
    /// strictly after `t`, if any. [`crate::simnet::FlowSet`] splits
    /// its integration steps there so flow rates re-sample at the exact
    /// instant a fault lands — and, symmetrically, at the exact instant
    /// it heals: no bytes delivered past a death, no free bytes before
    /// a heal.
    pub fn next_fault_after(&self, t: f64) -> Option<f64> {
        let mut min: Option<f64> = None;
        for f in &self.faults {
            if f.at > t {
                min = Some(min.map_or(f.at, |m: f64| m.min(f.at)));
            }
            if f.heal_at.is_finite() && f.heal_at > t {
                min = Some(min.map_or(f.heal_at, |m: f64| m.min(f.heal_at)));
            }
        }
        min
    }

    /// Product of the active [`FaultKind::LinkDegrade`] factors on
    /// `site` (1.0 when none are active). O(1): cached per site.
    pub fn degrade_factor(&self, site: usize) -> f64 {
        self.fault_view.degrade[site]
    }

    pub fn len(&self) -> usize {
        self.sites.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sites.is_empty()
    }

    pub fn site(&self, idx: usize) -> &Site {
        &self.sites[idx]
    }

    pub fn site_mut(&mut self, idx: usize) -> &mut Site {
        &mut self.sites[idx]
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }

    pub fn site_by_name(&self, name: &str) -> Option<&Site> {
        self.index_of(name).map(|i| self.site(i))
    }

    pub fn sites(&self) -> impl Iterator<Item = &Site> {
        self.sites.iter()
    }

    /// Advance simulated time.
    pub fn advance(&mut self, dt: f64) {
        self.now += dt;
        if self.now >= self.fault_view.next_change {
            self.refresh_fault_view();
        }
    }

    /// Advance simulated time to the absolute instant `t` (no-op if
    /// the clock is already past it). The event kernel
    /// ([`crate::simnet::engine::Engine`]) uses this so scheduled
    /// instants land exactly, with no accumulated floating-point drift
    /// from repeated relative advances.
    pub fn advance_to(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
            if self.now >= self.fault_view.next_change {
                self.refresh_fault_view();
            }
        }
    }

    /// Sample the instantaneous bandwidth a new transfer from `site`
    /// would get right now. 0 for a dead site (its flows stall);
    /// scaled down while a link-degradation fault is active.
    pub fn current_bandwidth(&mut self, site: usize) -> f64 {
        if !self.site_alive(site) {
            return 0.0;
        }
        let concurrent = self.sites[site].active_transfers;
        self.links[site].bandwidth_at(self.now, concurrent) * self.degrade_factor(site)
    }

    /// Shared cost model behind [`Self::transfer_from`] and
    /// [`Self::probe_transfer`]: disk stage (seek + streaming) and WAN
    /// stage (latency + bucket-integrated byte movement, stretched by
    /// any active link degradation) pipelined, the slower dominating.
    fn transfer_cost(
        site: &Site,
        link: &mut Link,
        degrade: f64,
        now: f64,
        bytes: f64,
        concurrent: usize,
    ) -> (f64, f64) {
        let disk = site.cfg.drd_time_ms / 1e3 + bytes / site.cfg.disk_rate;
        let mut wan = link.transfer_duration(now, bytes, concurrent);
        // An active link degradation stretches the byte-moving part of
        // the WAN stage (approximation: the factor is treated as
        // constant over the transfer, exact when the fault triggered
        // before the transfer started).
        if degrade < 1.0 {
            let latency = link.latency;
            wan = latency + (wan - latency).max(0.0) / degrade.max(1e-9);
        }
        let duration = disk.max(wan);
        let mean_bw = bytes / duration;
        (duration, mean_bw)
    }

    /// Simulate one read transfer of `bytes` from `site` starting now;
    /// returns (duration_s, mean_bandwidth). Includes the disk-read
    /// overhead (`drdTime`) and WAN latency; marks the transfer active
    /// for the duration with respect to *itself* only (the caller
    /// advances time between transfers as its workload dictates).
    pub fn transfer_from(&mut self, site: usize, bytes: f64) -> (f64, f64) {
        if !self.site_alive(site) {
            // Dead replica: the fetch never completes.
            return (f64::INFINITY, 0.0);
        }
        let degrade = self.degrade_factor(site);
        let concurrent = self.sites[site].active_transfers;
        let now = self.now;
        Self::transfer_cost(
            &self.sites[site],
            &mut self.links[site],
            degrade,
            now,
            bytes,
            concurrent,
        )
    }

    /// What a transfer of `bytes` from `site` would cost right now for
    /// a client adding `extra_transfers` concurrent streams on top of
    /// the site's current in-flight count — **without mutating any
    /// real state**. Only the one link is cloned (its RNG stream is
    /// consumed on the clone and discarded), which replaces the
    /// clairvoyant oracle's full-topology probe clones: the old
    /// `clone_for_probe()`-per-candidate pattern deep-copied every
    /// site and link O(sites × requests) times per experiment.
    pub fn probe_transfer(&self, site: usize, bytes: f64, extra_transfers: usize) -> (f64, f64) {
        if !self.site_alive(site) {
            return (f64::INFINITY, 0.0);
        }
        let degrade = self.degrade_factor(site);
        let concurrent = self.sites[site].active_transfers + extra_transfers;
        let mut link = self.links[site].clone();
        Self::transfer_cost(&self.sites[site], &mut link, degrade, self.now, bytes, concurrent)
    }

    /// Mark a transfer in flight (affects sharing for others).
    pub fn begin_transfer(&mut self, site: usize) {
        self.sites[site].active_transfers += 1;
    }

    pub fn end_transfer(&mut self, site: usize) {
        let s = &mut self.sites[site];
        s.active_transfers = s.active_transfers.saturating_sub(1);
    }

    /// A probe copy: identical upcoming link behaviour (same RNG
    /// state), so the clairvoyant oracle can measure "what would this
    /// transfer have cost from site X" without disturbing the real
    /// topology.
    pub fn clone_for_probe(&self) -> Topology {
        self.clone()
    }

    /// Consume space on a site (replica creation; negative `bytes` is
    /// a reclaim). `used` is clamped to `[0, total_space]` and the
    /// **actually applied** delta is returned: a store that clamps at
    /// capacity followed by a full-size reclaim would otherwise drive
    /// `used` below zero — phantom free space `available_space()`'s
    /// own `.max(0.0)` silently launders into GRIS. Callers that must
    /// reclaim exactly (e.g. `ReplicaManager::delete_replica`) ledger
    /// this return value.
    pub fn consume_space(&mut self, site: usize, bytes: f64) -> f64 {
        let s = &mut self.sites[site];
        let before = s.used;
        s.used = (before + bytes).clamp(0.0, s.cfg.total_space);
        s.used - before
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::build(&GridConfig::generate(6, 11))
    }

    #[test]
    fn build_indexes_sites() {
        let t = topo();
        assert_eq!(t.len(), 6);
        let name = t.site(3).cfg.name.clone();
        assert_eq!(t.index_of(&name), Some(3));
        assert!(t.index_of("nope").is_none());
    }

    #[test]
    fn load_tracks_active_transfers() {
        let mut t = topo();
        assert_eq!(t.site(0).load(), 0.0);
        for _ in 0..4 {
            t.begin_transfer(0);
        }
        assert_eq!(t.site(0).load(), 0.5);
        for _ in 0..20 {
            t.begin_transfer(0);
        }
        assert_eq!(t.site(0).load(), 1.0);
        t.end_transfer(0);
        assert!(t.site(0).load() < 1.0 || t.site(0).active_transfers >= 8);
    }

    #[test]
    fn transfer_duration_reasonable() {
        let mut t = topo();
        let bytes = 10e6;
        let (d, bw) = t.transfer_from(0, bytes);
        assert!(d > 0.0);
        assert!((bw - bytes / d).abs() < 1e-6);
        // Mean bandwidth cannot exceed the configured pipe by much.
        assert!(bw <= t.site(0).cfg.wan_bandwidth * 4.0);
    }

    #[test]
    fn space_accounting() {
        let mut t = topo();
        let avail0 = t.site(2).available_space();
        t.consume_space(2, 1e9);
        assert!((avail0 - t.site(2).available_space() - 1e9).abs() < 1.0);
        // Saturates at capacity.
        t.consume_space(2, 1e18);
        assert_eq!(t.site(2).available_space(), 0.0);
    }

    #[test]
    fn consume_space_clamps_both_ends_and_reports_applied_delta() {
        let mut t = topo();
        let total = t.site(2).cfg.total_space;
        let used0 = t.site(2).used;
        // Unclamped consume applies in full.
        assert_eq!(t.consume_space(2, 1e6), 1e6);
        assert_eq!(t.site(2).used, used0 + 1e6);
        // An over-capacity store applies only what fits...
        let applied = t.consume_space(2, 1e18);
        assert!((applied - (total - used0 - 1e6)).abs() < 1.0);
        assert_eq!(t.site(2).used, total);
        // ...and reclaiming the *requested* (clamped-away) size must
        // not drive `used` negative: the reclaim clamps at zero and
        // reports the shortfall.
        let reclaimed = t.consume_space(2, -1e18);
        assert_eq!(reclaimed, -total);
        assert_eq!(t.site(2).used, 0.0);
        assert_eq!(t.site(2).available_space(), total);
        // An exact ledger round-trips: apply, then reclaim the applied
        // amount, and `used` is bit-identical to where it started.
        let a = t.consume_space(2, 3e8);
        let b = t.consume_space(2, -a);
        assert_eq!(a, -b);
        assert_eq!(t.site(2).used, 0.0);
    }

    #[test]
    fn replica_death_triggers_at_scheduled_time() {
        let mut t = topo();
        t.schedule_fault(2, 100.0, FaultKind::ReplicaDeath);
        assert!(t.site_alive(2));
        assert!(t.current_bandwidth(2) > 0.0);
        t.advance(100.0);
        assert!(!t.site_alive(2));
        assert_eq!(t.current_bandwidth(2), 0.0);
        let (d, bw) = t.transfer_from(2, 1e6);
        assert!(d.is_infinite());
        assert_eq!(bw, 0.0);
        // Other sites are unaffected.
        assert!(t.site_alive(1));
        assert!(t.current_bandwidth(1) > 0.0);
        t.clear_faults();
        assert!(t.site_alive(2));
    }

    #[test]
    fn link_degradation_scales_bandwidth() {
        let mut a = topo();
        let mut b = topo();
        b.schedule_fault(0, 0.0, FaultKind::LinkDegrade { factor: 0.25 });
        assert_eq!(b.degrade_factor(0), 0.25);
        let healthy = a.current_bandwidth(0);
        let degraded = b.current_bandwidth(0);
        assert!((degraded - healthy * 0.25).abs() < 1e-6);
        // Degraded transfers take longer than healthy ones.
        let (dh, _) = a.transfer_from(0, 20e6);
        let (dd, _) = b.transfer_from(0, 20e6);
        assert!(dd > dh, "degraded {dd} !> healthy {dh}");
        // A not-yet-triggered fault changes nothing.
        let mut c = topo();
        c.schedule_fault(0, 1e9, FaultKind::LinkDegrade { factor: 0.25 });
        assert_eq!(c.degrade_factor(0), 1.0);
    }

    #[test]
    fn probe_transfer_matches_clone_probe_and_mutates_nothing() {
        let mut t = topo();
        t.advance(500.0);
        t.begin_transfer(3);
        // The link-local probe must agree exactly with the old
        // full-topology clone probe...
        let mut clone = t.clone_for_probe();
        let (d_clone, bw_clone) = clone.transfer_from(3, 25e6);
        let (d_probe, bw_probe) = t.probe_transfer(3, 25e6, 0);
        assert_eq!(d_clone, d_probe);
        assert_eq!(bw_clone, bw_probe);
        // ...including the extra-stream variant (clone + begin_transfer).
        let mut clone2 = t.clone_for_probe();
        clone2.begin_transfer(3);
        let (d2, _) = clone2.transfer_from(3, 25e6);
        let (p2, _) = t.probe_transfer(3, 25e6, 1);
        assert_eq!(d2, p2);
        assert!(p2 > d_probe, "an extra stream must slow the probe");
        // ...and leave the real topology untouched: a probe before a
        // real transfer does not change the real transfer's outcome.
        let mut fresh = topo();
        fresh.advance(500.0);
        fresh.begin_transfer(3);
        let (d_fresh, _) = fresh.transfer_from(3, 25e6);
        let (d_real, _) = t.transfer_from(3, 25e6);
        assert_eq!(d_fresh, d_real);
        // Dead sites probe as unreachable.
        t.schedule_fault(1, 0.0, FaultKind::ReplicaDeath);
        let (d_dead, bw_dead) = t.probe_transfer(1, 1e6, 0);
        assert!(d_dead.is_infinite());
        assert_eq!(bw_dead, 0.0);
    }

    #[test]
    fn timed_fault_heals_on_schedule() {
        let mut t = topo();
        t.schedule_fault_for(2, 10.0, 5.0, FaultKind::ReplicaDeath);
        assert!(t.site_alive(2), "not triggered yet");
        t.advance_to(10.0);
        assert!(!t.site_alive(2), "trigger is inclusive");
        assert_eq!(t.current_bandwidth(2), 0.0);
        t.advance_to(14.9);
        assert!(!t.site_alive(2));
        // The heal instant itself is alive again: [at, heal_at).
        t.advance_to(15.0);
        assert!(t.site_alive(2), "healed at at + downtime");
        assert!(t.current_bandwidth(2) > 0.0);
        let (d, _) = t.transfer_from(2, 1e6);
        assert!(d.is_finite());
    }

    #[test]
    fn flapping_degrade_lifts_at_heal() {
        let mut t = topo();
        t.schedule_fault_for(0, 0.0, 5.0, FaultKind::LinkDegrade { factor: 0.25 });
        assert_eq!(t.degrade_factor(0), 0.25);
        t.advance_to(4.0);
        assert_eq!(t.degrade_factor(0), 0.25);
        t.advance_to(5.0);
        assert_eq!(t.degrade_factor(0), 1.0, "degradation lifts at the heal instant");
    }

    #[test]
    fn next_fault_after_includes_heal_instants() {
        let mut t = topo();
        t.schedule_fault_for(1, 10.0, 5.0, FaultKind::ReplicaDeath);
        t.schedule_fault(2, 40.0, FaultKind::ReplicaDeath);
        assert_eq!(t.next_fault_after(0.0), Some(10.0));
        assert_eq!(t.next_fault_after(10.0), Some(15.0), "the heal is a boundary");
        assert_eq!(t.next_fault_after(15.0), Some(40.0));
        assert_eq!(t.next_fault_after(40.0), None, "permanent faults have no heal");
    }

    #[test]
    fn overlapping_crash_intervals_stay_dead_until_the_last_heals() {
        let mut t = topo();
        t.schedule_fault_for(3, 0.0, 10.0, FaultKind::ReplicaDeath);
        t.schedule_fault_for(3, 5.0, 10.0, FaultKind::ReplicaDeath);
        t.advance_to(10.0);
        assert!(!t.site_alive(3), "second crash still active");
        t.advance_to(15.0);
        assert!(t.site_alive(3));
    }

    #[test]
    fn fault_cache_survives_schedule_after_advance() {
        // Scheduling with the clock already inside the fault interval
        // must take effect immediately (the cache refreshes on every
        // fault-set mutation, not only on clock advances).
        let mut t = topo();
        t.advance_to(50.0);
        t.schedule_fault_for(4, 20.0, 100.0, FaultKind::ReplicaDeath);
        assert!(!t.site_alive(4));
        t.clear_faults();
        assert!(t.site_alive(4));
    }

    #[test]
    fn advance_to_is_monotone() {
        let mut t = topo();
        t.advance_to(100.0);
        assert_eq!(t.now, 100.0);
        t.advance_to(50.0); // never backwards
        assert_eq!(t.now, 100.0);
        t.advance_to(100.0);
        assert_eq!(t.now, 100.0);
    }

    #[test]
    fn deterministic_across_builds() {
        let mut a = topo();
        let mut b = topo();
        for i in 0..5 {
            a.advance(100.0);
            b.advance(100.0);
            let (da, _) = a.transfer_from(i % 6, 5e6);
            let (db, _) = b.transfer_from(i % 6, 5e6);
            assert_eq!(da, db);
        }
    }
}
