//! Grid weather (ISSUE 7 tentpole): seeded, deterministic crash/heal
//! and link-flap schedules.
//!
//! The EU-DataGrid operations experience is that production sites
//! crash *and come back*: outages are intervals, not one-shot deaths.
//! [`WeatherPlan::generate`] draws, per site, an alternating renewal
//! process on [`crate::util::prng::Rng`] —
//!
//! * **crashes**: up-times ~ Exp(mean = `mtbf`), downtimes ~ Exp(mean
//!   = `mttr`); a `perm_frac` fraction of crashes never heal (the
//!   site churns out of the grid for good, the PR-5 permanent fault);
//! * **flaps**: [`FaultKind::LinkDegrade`] episodes arriving at
//!   `flap_rate` per second with Exp(mean = `flap_duration`) lengths
//!   and a uniform degradation factor in `[flap_floor, 1)`.
//!
//! Every draw forks from one seed, so two plans generated with the
//! same `(spec, n_sites, seed)` are identical — the property the
//! chaos experiment's identically-seeded policy comparison and the
//! byte-identical trace-export acceptance check stand on. Fault
//! instants in a plan are *relative* (t = 0 is the start of the
//! weather window); [`WeatherPlan::apply`] offsets them onto the
//! topology's clock.

use crate::util::prng::Rng;

use super::topology::{Fault, FaultKind, Topology};

/// Weather intensity knobs (all times in simulated seconds).
#[derive(Debug, Clone, Copy)]
pub struct WeatherSpec {
    /// Length of the weather window; no fault triggers after it.
    pub horizon: f64,
    /// Mean up-time between crashes per site (`∞` disables crashes).
    pub mtbf: f64,
    /// Mean downtime per healing crash.
    pub mttr: f64,
    /// Fraction of crashes that are permanent (never heal).
    pub perm_frac: f64,
    /// Link-flap arrivals per second per site (0 disables flaps).
    pub flap_rate: f64,
    /// Mean flap length in seconds.
    pub flap_duration: f64,
    /// Worst degradation factor a flap can impose (factor is uniform
    /// in `[flap_floor, 1)`).
    pub flap_floor: f64,
}

impl Default for WeatherSpec {
    fn default() -> Self {
        WeatherSpec {
            horizon: 3_600.0,
            mtbf: f64::INFINITY,
            mttr: 120.0,
            perm_frac: 0.0,
            flap_rate: 0.0,
            flap_duration: 60.0,
            flap_floor: 0.2,
        }
    }
}

/// A deterministic fault schedule (relative instants; see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct WeatherPlan {
    pub faults: Vec<Fault>,
}

impl WeatherPlan {
    /// No weather at all (the fair-skies control arm).
    pub fn calm() -> WeatherPlan {
        WeatherPlan { faults: Vec::new() }
    }

    /// Draw a plan for `n_sites` sites. Identical inputs yield an
    /// identical plan; each site's weather comes from its own forked
    /// stream, so adding sites never perturbs existing ones.
    pub fn generate(spec: &WeatherSpec, n_sites: usize, seed: u64) -> WeatherPlan {
        let mut faults = Vec::new();
        let mut root = Rng::new(seed ^ 0x5745_4154_4845_5221); // "WEATHER!"
        for site in 0..n_sites {
            let mut r = root.fork(site as u64);
            if spec.mtbf.is_finite() && spec.mtbf > 0.0 {
                let mut t = r.exp(1.0 / spec.mtbf);
                while t < spec.horizon {
                    let permanent = spec.perm_frac > 0.0 && r.chance(spec.perm_frac);
                    // The downtime draw happens unconditionally so a
                    // permanent crash consumes the same RNG budget as
                    // a healing one (plan stability under perm_frac).
                    let downtime = r.exp(1.0 / spec.mttr.max(1e-9));
                    let heal_at = if permanent { f64::INFINITY } else { t + downtime };
                    faults.push(Fault { site, at: t, heal_at, kind: FaultKind::ReplicaDeath });
                    if !heal_at.is_finite() {
                        break; // dead for good; no further weather matters
                    }
                    t = heal_at + r.exp(1.0 / spec.mtbf);
                }
            }
            if spec.flap_rate > 0.0 {
                let mut fr = root.fork(0x0001_0000 | site as u64);
                let mut t = fr.exp(spec.flap_rate);
                while t < spec.horizon {
                    let len = fr.exp(1.0 / spec.flap_duration.max(1e-9));
                    let factor = fr.range(spec.flap_floor.clamp(0.0, 1.0), 1.0);
                    faults.push(Fault {
                        site,
                        at: t,
                        heal_at: t + len,
                        kind: FaultKind::LinkDegrade { factor },
                    });
                    t = t + len + fr.exp(spec.flap_rate);
                }
            }
        }
        // Deterministic presentation order: by trigger, then site.
        faults.sort_by(|a, b| {
            a.at.total_cmp(&b.at)
                .then(a.site.cmp(&b.site))
                .then(a.heal_at.total_cmp(&b.heal_at))
        });
        WeatherPlan { faults }
    }

    /// Schedule every fault onto `topo`, offsetting the plan's
    /// relative instants by `t0` (typically the post-warm clock).
    pub fn apply(&self, topo: &mut Topology, t0: f64) {
        for f in &self.faults {
            topo.schedule(Fault {
                site: f.site,
                at: t0 + f.at,
                heal_at: if f.heal_at.is_finite() { t0 + f.heal_at } else { f64::INFINITY },
                kind: f.kind,
            });
        }
    }

    /// Crash faults in the plan (heal-aware deaths, permanent or not).
    pub fn crashes(&self) -> usize {
        self.faults
            .iter()
            .filter(|f| f.kind == FaultKind::ReplicaDeath)
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GridConfig;

    fn stormy() -> WeatherSpec {
        WeatherSpec {
            horizon: 2_000.0,
            mtbf: 400.0,
            mttr: 150.0,
            perm_frac: 0.25,
            flap_rate: 1.0 / 500.0,
            flap_duration: 80.0,
            flap_floor: 0.3,
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = WeatherPlan::generate(&stormy(), 8, 42);
        let b = WeatherPlan::generate(&stormy(), 8, 42);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "a stormy spec must produce weather");
        let c = WeatherPlan::generate(&stormy(), 8, 43);
        assert_ne!(a, c, "a different seed must produce different weather");
    }

    #[test]
    fn faults_are_well_formed_and_inside_the_horizon() {
        let spec = stormy();
        let plan = WeatherPlan::generate(&spec, 12, 7);
        for f in &plan.faults {
            assert!(f.site < 12);
            assert!(f.at >= 0.0 && f.at < spec.horizon, "trigger {} outside window", f.at);
            assert!(f.heal_at > f.at, "heal {} !> trigger {}", f.heal_at, f.at);
            if let FaultKind::LinkDegrade { factor } = f.kind {
                assert!((0.3..1.0).contains(&factor));
                assert!(f.heal_at.is_finite(), "flaps always heal");
            }
        }
    }

    #[test]
    fn perm_frac_extremes() {
        let all_heal = WeatherSpec { perm_frac: 0.0, ..stormy() };
        let plan = WeatherPlan::generate(&all_heal, 10, 11);
        assert!(plan
            .faults
            .iter()
            .filter(|f| f.kind == FaultKind::ReplicaDeath)
            .all(|f| f.heal_at.is_finite()));
        let all_perm = WeatherSpec { perm_frac: 1.0, flap_rate: 0.0, ..stormy() };
        let plan = WeatherPlan::generate(&all_perm, 10, 11);
        assert!(plan.faults.iter().all(|f| !f.heal_at.is_finite()));
        for site in 0..10 {
            assert!(
                plan.faults.iter().filter(|f| f.site == site).count() <= 1,
                "a permanently dead site crashes at most once"
            );
        }
    }

    #[test]
    fn apply_offsets_onto_the_topology_clock() {
        let spec = WeatherSpec { mtbf: 300.0, mttr: 100.0, horizon: 1_000.0, ..Default::default() };
        let plan = WeatherPlan::generate(&spec, 4, 99);
        assert!(plan.crashes() > 0);
        let mut topo = Topology::build(&GridConfig::generate(4, 1));
        topo.advance_to(500.0);
        let t0 = topo.now;
        plan.apply(&mut topo, t0);
        assert_eq!(topo.faults().len(), plan.faults.len());
        for (sched, rel) in topo.faults().iter().zip(&plan.faults) {
            assert_eq!(sched.at, t0 + rel.at);
            if rel.heal_at.is_finite() {
                assert_eq!(sched.heal_at, t0 + rel.heal_at);
            } else {
                assert!(!sched.heal_at.is_finite());
            }
        }
        // The first boundary after t0 is the first fault's trigger.
        assert_eq!(topo.next_fault_after(t0), Some(t0 + plan.faults[0].at));
    }
}
