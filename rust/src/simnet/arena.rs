//! Arena-backed event queue for the discrete-event kernel (ISSUE 8).
//!
//! `BinaryHeap<Reverse<Sched>>` was correct but re-allocated as the
//! schedule grew and shrank across a day of traffic. [`EventArena`] is
//! the allocation-free replacement: one contiguous slab of slots,
//! arranged as a 4-ary min-heap, that is *reused* — `pop` never
//! shrinks the allocation, so after the warm-up ramp the steady state
//! performs zero heap allocations no matter how many events churn
//! through. The payload is generic and `Copy`, so push/pop move plain
//! words, never drop glue.
//!
//! Ordering contract (identical to the kernel's original heap, pinned
//! by `tie_break_is_fifo`): events order by time via `f64::total_cmp`,
//! ties resolve by insertion order (the arena stamps a monotone
//! sequence number on every push). Because `(at, seq)` is a total
//! order with unique `seq`, the pop order is *exactly* the sorted
//! order of the pushes — which is what makes swapping the queue
//! implementation bit-transparent to every seeded scenario.
//!
//! A 4-ary layout (children of `i` at `4i+1 .. 4i+4`) halves the tree
//! depth of a binary heap; sift-down compares at most 4 children per
//! level, which trades a few comparisons for far fewer cache lines on
//! the deep heaps a 10⁵-request surge builds.

/// One scheduled entry: an instant plus a caller payload.
#[derive(Debug, Clone, Copy)]
struct Slot<K: Copy> {
    at: f64,
    seq: u64,
    kind: K,
}

impl<K: Copy> Slot<K> {
    /// Strict ordering: earlier time first, FIFO within a tie.
    #[inline]
    fn before(&self, other: &Slot<K>) -> bool {
        match self.at.total_cmp(&other.at) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => self.seq < other.seq,
        }
    }
}

/// A reusable 4-ary min-heap of `(time, payload)` events.
#[derive(Debug, Clone)]
pub struct EventArena<K: Copy> {
    slots: Vec<Slot<K>>,
    /// Monotone push counter — the FIFO tie-breaker. Never reset by
    /// `clear`, so tie order stays stable across queue reuse.
    seq: u64,
}

impl<K: Copy> Default for EventArena<K> {
    fn default() -> Self {
        EventArena::new()
    }
}

impl<K: Copy> EventArena<K> {
    pub fn new() -> EventArena<K> {
        EventArena { slots: Vec::new(), seq: 0 }
    }

    /// An arena pre-sized for `n` concurrent events — the surge path
    /// reserves once, then the steady state never allocates.
    pub fn with_capacity(n: usize) -> EventArena<K> {
        EventArena { slots: Vec::with_capacity(n), seq: 0 }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Slots currently reserved (never shrinks — that is the point).
    pub fn capacity(&self) -> usize {
        self.slots.capacity()
    }

    /// Drop all pending events, keeping the allocation.
    pub fn clear(&mut self) {
        self.slots.clear();
    }

    /// Schedule `kind` at absolute time `at`.
    pub fn push(&mut self, at: f64, kind: K) {
        let seq = self.seq;
        self.seq += 1;
        self.slots.push(Slot { at, seq, kind });
        self.sift_up(self.slots.len() - 1);
    }

    /// Instant of the earliest pending event.
    pub fn peek_at(&self) -> Option<f64> {
        self.slots.first().map(|s| s.at)
    }

    /// Remove and return the earliest event as `(at, kind)`.
    pub fn pop(&mut self) -> Option<(f64, K)> {
        if self.slots.is_empty() {
            return None;
        }
        let last = self.slots.len() - 1;
        self.slots.swap(0, last);
        let s = self.slots.pop().expect("non-empty");
        if !self.slots.is_empty() {
            self.sift_down(0);
        }
        Some((s.at, s.kind))
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 4;
            if self.slots[i].before(&self.slots[parent]) {
                self.slots.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.slots.len();
        loop {
            let first_child = 4 * i + 1;
            if first_child >= n {
                break;
            }
            let mut best = first_child;
            let end = (first_child + 4).min(n);
            for c in first_child + 1..end {
                if self.slots[c].before(&self.slots[best]) {
                    best = c;
                }
            }
            if self.slots[best].before(&self.slots[i]) {
                self.slots.swap(i, best);
                i = best;
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventArena::new();
        q.push(5.0, 'c');
        q.push(1.0, 'a');
        q.push(3.0, 'b');
        assert_eq!(q.peek_at(), Some(1.0));
        assert_eq!(q.pop(), Some((1.0, 'a')));
        assert_eq!(q.pop(), Some((3.0, 'b')));
        assert_eq!(q.pop(), Some((5.0, 'c')));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn tie_break_is_fifo() {
        let mut q = EventArena::new();
        for id in 0..16u64 {
            q.push(2.0, id);
        }
        q.push(1.0, 99);
        assert_eq!(q.pop(), Some((1.0, 99)));
        for id in 0..16u64 {
            assert_eq!(q.pop(), Some((2.0, id)), "tie order must be FIFO");
        }
    }

    #[test]
    fn matches_a_sorted_reference_on_random_input() {
        let mut rng = Rng::new(0xA4EA);
        let mut q = EventArena::new();
        let mut reference: Vec<(f64, u64)> = Vec::new();
        for id in 0..500u64 {
            // Coarse quantization forces plenty of exact ties.
            let at = (rng.range(0.0, 50.0) * 4.0).floor() / 4.0;
            q.push(at, id);
            reference.push((at, id));
        }
        // Stable sort on time == (time, insertion order): the arena's
        // contract.
        reference.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut popped = Vec::new();
        while let Some(e) = q.pop() {
            popped.push(e);
        }
        assert_eq!(popped, reference);
    }

    #[test]
    fn interleaved_push_pop_preserves_order() {
        let mut q = EventArena::new();
        q.push(4.0, 1u32);
        q.push(2.0, 2);
        assert_eq!(q.pop(), Some((2.0, 2)));
        q.push(1.0, 3);
        q.push(3.0, 4);
        assert_eq!(q.pop(), Some((1.0, 3)));
        assert_eq!(q.pop(), Some((3.0, 4)));
        assert_eq!(q.pop(), Some((4.0, 1)));
    }

    #[test]
    fn steady_state_reuses_the_allocation() {
        let mut q = EventArena::with_capacity(64);
        let cap = q.capacity();
        assert!(cap >= 64);
        // Many fill/drain cycles inside the reserved size: capacity
        // must never move (no allocator traffic in steady state).
        for round in 0..50u64 {
            for i in 0..64u64 {
                q.push((i % 7) as f64, round * 64 + i);
            }
            while q.pop().is_some() {}
            assert_eq!(q.capacity(), cap, "round {round} reallocated");
        }
        assert!(q.is_empty());
        q.clear();
        assert_eq!(q.capacity(), cap);
    }
}
