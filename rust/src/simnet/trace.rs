//! Workload traces: record request streams to JSONL and replay them.
//!
//! The original evaluation would have driven the broker with real
//! application request logs; this module provides the equivalent
//! interchange so experiments can run from a *recorded* trace instead
//! of the synthetic generator — `examples/datagrid_sim --trace-out t.jsonl`
//! records, `--trace-in t.jsonl` replays, and identical traces yield
//! identical selections (seeded end to end).
//!
//! Format: one JSON object per line:
//! `{"at": 12.5, "client": 3, "file": 17, "min_bandwidth": 51200}`

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::workload::Request;

/// Serialize one request as a JSONL line.
pub fn to_line(r: &Request) -> String {
    use std::collections::BTreeMap;
    let mut m = BTreeMap::new();
    m.insert("at".to_string(), Json::Num(r.at));
    m.insert("client".to_string(), Json::Num(r.client as f64));
    m.insert("file".to_string(), Json::Num(r.file as f64));
    m.insert("min_bandwidth".to_string(), Json::Num(r.min_bandwidth));
    Json::Obj(m).to_string()
}

/// Parse one JSONL line.
pub fn from_line(line: &str) -> Result<Request> {
    let v = Json::parse(line.trim()).context("parsing trace line")?;
    let num = |k: &str| -> Result<f64> {
        v.get(k)
            .and_then(Json::as_f64)
            .with_context(|| format!("trace line missing {k:?}: {line}"))
    };
    Ok(Request {
        at: num("at")?,
        client: num("client")? as usize,
        file: num("file")? as usize,
        min_bandwidth: num("min_bandwidth")?,
    })
}

/// Write a trace file.
pub fn save(path: impl AsRef<Path>, requests: &[Request]) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating trace {:?}", path.as_ref()))?;
    for r in requests {
        writeln!(f, "{}", to_line(r))?;
    }
    Ok(())
}

/// Load a trace file (blank lines and `#` comments ignored); validates
/// that arrival times are non-decreasing.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<Request>> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening trace {:?}", path.as_ref()))?;
    let mut out = Vec::new();
    let mut last_at = f64::NEG_INFINITY;
    for (i, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let r = from_line(t).with_context(|| format!("trace line {}", i + 1))?;
        if r.at < last_at {
            anyhow::bail!("trace not time-ordered at line {}", i + 1);
        }
        last_at = r.at;
        out.push(r);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::workload::{Workload, WorkloadSpec};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gr-trace-{}-{name}", std::process::id()))
    }

    #[test]
    fn round_trips_a_generated_trace() {
        let mut w = Workload::new(WorkloadSpec::default(), 5);
        let reqs = w.take(200);
        let path = tmp("roundtrip.jsonl");
        save(&path, &reqs).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded, reqs);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let path = tmp("comments.jsonl");
        std::fs::write(
            &path,
            "# a trace\n\n{\"at\":1,\"client\":0,\"file\":2,\"min_bandwidth\":0}\n",
        )
        .unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded[0].file, 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_time_disorder_and_garbage() {
        let path = tmp("bad.jsonl");
        std::fs::write(
            &path,
            "{\"at\":5,\"client\":0,\"file\":0,\"min_bandwidth\":0}\n\
             {\"at\":1,\"client\":0,\"file\":0,\"min_bandwidth\":0}\n",
        )
        .unwrap();
        assert!(load(&path).unwrap_err().to_string().contains("time-ordered"));
        std::fs::write(&path, "{\"at\":5}\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, "notjson\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
