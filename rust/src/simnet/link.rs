//! Time-varying link model.

use crate::config::SiteConfig;
use crate::util::prng::Rng;

/// One directed WAN path from a storage site toward the client
/// population. Bandwidth samples are generated lazily per *time bucket*
/// so that queries at the same simulated time agree and the AR(1)
/// correlation structure is respected no matter how irregularly the
/// simulation samples.
#[derive(Debug, Clone)]
pub struct Link {
    /// Mean bandwidth, bytes/s.
    pub mean: f64,
    /// Diurnal amplitude (fraction of mean).
    pub diurnal_amp: f64,
    /// Diurnal period, seconds (24h scaled down in tests).
    pub period: f64,
    /// AR(1) coefficient of the noise process.
    pub ar: f64,
    /// Innovation std (fraction of mean).
    pub noise_frac: f64,
    /// Per-bucket congestion probability.
    pub congestion_prob: f64,
    /// One-way latency (s).
    pub latency: f64,
    /// Sample bucket width (s).
    pub bucket: f64,
    rng: Rng,
    /// (bucket index, ar_state, congestion_factor) of the last sample.
    state: Option<(i64, f64, f64)>,
}

impl Link {
    pub fn from_site(cfg: &SiteConfig, rng: Rng) -> Link {
        Link {
            mean: cfg.wan_bandwidth,
            diurnal_amp: cfg.diurnal_amp,
            period: 86_400.0,
            ar: cfg.ar_coeff,
            noise_frac: cfg.noise_frac,
            congestion_prob: cfg.congestion_prob,
            latency: cfg.latency,
            bucket: 60.0,
            rng,
            state: None,
        }
    }

    /// Deterministic diurnal multiplier at time `t` (no randomness).
    fn diurnal(&self, t: f64) -> f64 {
        1.0 - self.diurnal_amp * 0.5 * (1.0 + (std::f64::consts::TAU * t / self.period).sin())
    }

    /// Advance the AR(1)/congestion state to the bucket containing `t`
    /// and return the (bandwidth multiplier) noise state.
    fn advance(&mut self, t: f64) -> (f64, f64) {
        let target = (t / self.bucket).floor() as i64;
        let (mut idx, mut ar_state, mut cong) = match self.state {
            Some(s) if s.0 <= target => s,
            // Time went backwards or first sample: re-seed at target.
            _ => (target - 1, 0.0, 1.0),
        };
        while idx < target {
            idx += 1;
            ar_state = self.ar * ar_state + self.rng.gauss(0.0, self.noise_frac);
            // Congestion episodes decay geometrically once triggered.
            if self.rng.chance(self.congestion_prob) {
                cong = (1.0 / self.rng.pareto(1.5, 1.2)).min(1.0); // share collapse
            } else {
                cong = (cong * 1.6).min(1.0); // recovery
            }
        }
        self.state = Some((idx, ar_state, cong));
        (ar_state, cong)
    }

    /// Bandwidth available to a *single* transfer starting at `t` that
    /// shares the pipe with `concurrent` other active transfers.
    /// Constant within one sample bucket (time is quantized so repeated
    /// queries at the same instant agree).
    pub fn bandwidth_at(&mut self, t: f64, concurrent: usize) -> f64 {
        let (ar_state, cong) = self.advance(t);
        let tq = (t / self.bucket).floor() * self.bucket;
        let noise = (1.0 + ar_state).clamp(0.05, 3.0);
        let share = 1.0 / (concurrent as f64 + 1.0);
        (self.mean * self.diurnal(tq) * noise * cong * share).max(1.0)
    }

    /// Observe the *mean* bandwidth a transfer of `bytes` starting at
    /// `t` would see, integrating over bucket transitions.
    pub fn transfer_duration(&mut self, t: f64, bytes: f64, concurrent: usize) -> f64 {
        let mut remaining = bytes;
        let mut now = t;
        let mut total = self.latency; // connection setup
        // Integrate bucket by bucket; bail out after a hard cap.
        for _ in 0..100_000 {
            let bw = self.bandwidth_at(now, concurrent);
            let bucket_end = (now / self.bucket).floor() * self.bucket + self.bucket;
            let dt = (bucket_end - now).max(1e-6);
            let can_move = bw * dt;
            if can_move >= remaining {
                total += remaining / bw;
                return total;
            }
            remaining -= can_move;
            total += dt;
            now = bucket_end;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GridConfig;

    fn link(seed: u64) -> Link {
        let cfg = &GridConfig::generate(3, 9).sites[1];
        Link::from_site(cfg, Rng::new(seed))
    }

    #[test]
    fn bandwidth_positive_and_bounded() {
        let mut l = link(1);
        for i in 0..500 {
            let bw = l.bandwidth_at(i as f64 * 30.0, 0);
            assert!(bw > 0.0);
            assert!(bw < l.mean * 4.0, "bw {bw} vs mean {}", l.mean);
        }
    }

    #[test]
    fn same_bucket_same_bandwidth() {
        let mut l = link(2);
        let a = l.bandwidth_at(1000.0, 0);
        let b = l.bandwidth_at(1000.5, 0);
        assert_eq!(a, b);
    }

    #[test]
    fn temporal_correlation_exists() {
        // Lag-1 autocorrelation of consecutive bucket samples should be
        // clearly positive — this is the signal history-based selection
        // exploits.
        let mut l = link(3);
        l.congestion_prob = 0.0; // isolate the AR component
        let xs: Vec<f64> = (0..2000)
            .map(|i| l.bandwidth_at(i as f64 * l.bucket, 0))
            .collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum();
        let cov: f64 = xs.windows(2).map(|w| (w[0] - mean) * (w[1] - mean)).sum();
        let rho = cov / var;
        assert!(rho > 0.3, "lag-1 autocorrelation too low: {rho}");
    }

    #[test]
    fn concurrency_shares_pipe() {
        let mut a = link(4);
        let mut b = link(4);
        let t = 500.0;
        let solo = a.bandwidth_at(t, 0);
        let shared = b.bandwidth_at(t, 3);
        assert!((solo / shared - 4.0).abs() < 1e-9);
    }

    #[test]
    fn duration_scales_with_size() {
        let mut l = link(5);
        l.congestion_prob = 0.0;
        let d1 = l.transfer_duration(0.0, 1e6, 0);
        let mut l2 = link(5);
        l2.congestion_prob = 0.0;
        let d2 = l2.transfer_duration(0.0, 1e7, 0);
        assert!(d2 > d1 * 5.0, "d1={d1} d2={d2}");
    }

    #[test]
    fn diurnal_trough_slower_than_peak() {
        let mut l = link(6);
        l.noise_frac = 0.0;
        l.congestion_prob = 0.0;
        // quarter period: sin=1 (trough multiplier), three-quarters: sin=-1.
        let trough = l.bandwidth_at(l.period * 0.25, 0);
        let peak = l.bandwidth_at(l.period * 0.75, 0);
        assert!(peak > trough);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = link(7);
        let mut b = link(7);
        for i in 0..100 {
            let t = i as f64 * 77.0;
            assert_eq!(a.bandwidth_at(t, 1), b.bandwidth_at(t, 1));
        }
    }
}
