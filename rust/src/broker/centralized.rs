//! The centralized-manager comparator (paper §5.1.1).
//!
//! The paper argues for decentralized brokering because a central
//! matchmaker is a scalability bottleneck and a single point of
//! failure. This module models the Condor-style central manager the
//! paper contrasts with: all clients funnel selections through one
//! serialized decision queue. `bench_broker` measures selection latency
//! vs. offered concurrency for both architectures; the decentralized
//! broker stays flat while the central queue grows linearly.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::classad::ClassAd;

use super::engine::{Broker, Selection};

/// A central manager: one broker instance behind a mutex (the decision
/// queue) plus an optional per-decision service cost modeling the
/// manager's bookkeeping.
pub struct CentralManager {
    broker: Mutex<Broker>,
    service_cost: Duration,
    pub decisions: Mutex<u64>,
}

impl CentralManager {
    pub fn new(broker: Broker, service_cost: Duration) -> Arc<CentralManager> {
        Arc::new(CentralManager {
            broker: Mutex::new(broker),
            service_cost,
            decisions: Mutex::new(0),
        })
    }

    /// A client submits a selection request and blocks until the
    /// manager serves it. Returns (selection, queueing+service time).
    pub fn submit(&self, logical: &str, request: &ClassAd) -> Result<(Selection, Duration)> {
        let t0 = Instant::now();
        let broker = self.broker.lock().unwrap();
        // Service time: the matchmaking work itself plus fixed cost.
        let sel = broker.select(logical, request)?;
        if !self.service_cost.is_zero() {
            spin_for(self.service_cost);
        }
        *self.decisions.lock().unwrap() += 1;
        Ok((sel, t0.elapsed()))
    }
}

/// Busy-wait (sleep granularity is too coarse for µs-scale service
/// costs on loaded CI machines).
fn spin_for(d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

/// Virtual-time queueing comparison (used when wall-clock threading
/// cannot expose the difference, e.g. single-core CI): requests arrive
/// at `arrivals` (seconds); each decision costs `service_s`.
///
/// * central manager = one FIFO server: `finish[i] =
///   max(arrive[i], finish[i-1]) + service`.
/// * decentralized = every client is its own server; a client's
///   requests only queue behind its *own* previous request.
///
/// Returns per-request decision latency (seconds).
pub fn queueing_latencies_central(arrivals: &[f64], service_s: f64) -> Vec<f64> {
    let mut free_at = 0.0f64;
    arrivals
        .iter()
        .map(|&at| {
            let start = free_at.max(at);
            free_at = start + service_s;
            free_at - at
        })
        .collect()
}

/// See [`queueing_latencies_central`]; `client_of[i]` assigns request
/// `i` to a client (its private broker).
pub fn queueing_latencies_decentralized(
    arrivals: &[f64],
    service_s: f64,
    client_of: &[usize],
    clients: usize,
) -> Vec<f64> {
    let mut free_at = vec![0.0f64; clients];
    arrivals
        .iter()
        .zip(client_of)
        .map(|(&at, &c)| {
            let start = free_at[c].max(at);
            free_at[c] = start + service_s;
            free_at[c] - at
        })
        .collect()
}

/// Run `clients` threads each performing `per_client` selections
/// against the central manager; returns mean latency.
pub fn run_centralized(
    manager: &Arc<CentralManager>,
    logical: &str,
    request: &ClassAd,
    clients: usize,
    per_client: usize,
) -> Duration {
    let total_ns: u64 = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..clients {
            let mgr = manager.clone();
            let req = request.clone();
            handles.push(scope.spawn(move || {
                let mut ns = 0u64;
                for _ in 0..per_client {
                    let (_sel, lat) = mgr.submit(logical, &req).expect("selection");
                    ns += lat.as_nanos() as u64;
                }
                ns
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    Duration::from_nanos(total_ns / (clients * per_client) as u64)
}

/// The decentralized counterpart: every client runs its *own* broker
/// clone; no shared lock. Returns mean latency.
pub fn run_decentralized(
    broker: &Broker,
    logical: &str,
    request: &ClassAd,
    clients: usize,
    per_client: usize,
    service_cost: Duration,
) -> Duration {
    let total_ns: u64 = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..clients {
            let b = broker.clone();
            let req = request.clone();
            handles.push(scope.spawn(move || {
                let mut ns = 0u64;
                for _ in 0..per_client {
                    let t0 = Instant::now();
                    let _sel = b.select(logical, &req).expect("selection");
                    if !service_cost.is_zero() {
                        spin_for(service_cost);
                    }
                    ns += t0.elapsed().as_nanos() as u64;
                }
                ns
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    Duration::from_nanos(total_ns / (clients * per_client) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn central_queue_grows_with_offered_load() {
        // 16 requests arriving simultaneously, 1ms service.
        let arrivals = vec![0.0; 16];
        let lat = queueing_latencies_central(&arrivals, 1e-3);
        let mean: f64 = lat.iter().sum::<f64>() / lat.len() as f64;
        // FIFO positions 1..16 -> mean 8.5ms.
        assert!((mean - 8.5e-3).abs() < 1e-9, "mean {mean}");
    }

    #[test]
    fn decentralized_stays_flat_per_client() {
        let arrivals = vec![0.0; 16];
        let client_of: Vec<usize> = (0..16).collect();
        let lat = queueing_latencies_decentralized(&arrivals, 1e-3, &client_of, 16);
        for l in lat {
            assert!((l - 1e-3).abs() < 1e-12);
        }
    }

    #[test]
    fn sparse_arrivals_no_queueing_either_way() {
        let arrivals: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let c = queueing_latencies_central(&arrivals, 1e-3);
        let d = queueing_latencies_decentralized(
            &arrivals,
            1e-3,
            &vec![0usize; 10],
            1,
        );
        assert_eq!(c, d);
        assert!(c.iter().all(|l| (l - 1e-3).abs() < 1e-12));
    }
}
