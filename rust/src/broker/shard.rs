//! Broker shards: partitioning the grid's control plane (ISSUE 8).
//!
//! The paper's broker is decentralized per client; what it never had
//! to answer is how the *information plane* scales when one deployment
//! fronts hundreds of sites. The answer built here follows the PR 5
//! registration hierarchy: the grid is partitioned into **shards**,
//! each owning a contiguous slice of topology sites, and each shard
//! runs its own GIIS registration domain (its sites soft-state
//! register only there) and its own admission batch. A request is
//! routed to its **home shard** — the shard owning the plurality of
//! its replica sites — and only consults other shards' domains when
//! its replica set actually spans the boundary (a *cross-shard
//! selection*, counted by the driver).
//!
//! [`ShardMap`] is the pure routing piece: deterministic, index-based,
//! no I/O — everything else (batching, domains, telemetry) lives in
//! `experiment::sharded`. A 1-shard map routes everything to shard 0,
//! which is how the sharded driver collapses to the unsharded path
//! bit-for-bit (the `it_shard` parity anchor).

/// A partition of topology sites `0..sites` into `shards` contiguous,
/// near-equal ranges. Shard `s` owns `[bounds[s], bounds[s+1])`.
#[derive(Debug, Clone)]
pub struct ShardMap {
    bounds: Vec<usize>,
}

impl ShardMap {
    /// Split `sites` sites into `shards` contiguous ranges whose sizes
    /// differ by at most one (the first `sites % shards` ranges get
    /// the extra site). `shards` is clamped to `[1, sites.max(1)]` so
    /// every shard owns at least one site.
    pub fn contiguous(sites: usize, shards: usize) -> ShardMap {
        let shards = shards.clamp(1, sites.max(1));
        let base = sites / shards;
        let extra = sites % shards;
        let mut bounds = Vec::with_capacity(shards + 1);
        let mut at = 0usize;
        bounds.push(at);
        for s in 0..shards {
            at += base + usize::from(s < extra);
            bounds.push(at);
        }
        debug_assert_eq!(*bounds.last().unwrap(), sites);
        ShardMap { bounds }
    }

    /// Number of shards (≥ 1).
    pub fn shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Sites owned by shard `s`.
    pub fn sites_of(&self, s: usize) -> std::ops::Range<usize> {
        self.bounds[s]..self.bounds[s + 1]
    }

    /// The shard owning topology site `site`.
    pub fn owner(&self, site: usize) -> usize {
        // Ranges are sorted and contiguous: the owner is the partition
        // point. `site` past the last bound maps to the last shard
        // (can't happen for valid topology indices; keeps this total).
        match self.bounds.binary_search(&site) {
            Ok(b) => b.min(self.shards() - 1),
            Err(ins) => ins - 1,
        }
    }

    /// Route a replica set: returns `(home shard, spans)` where home
    /// is the shard owning the most replicas (ties to the lowest
    /// shard index — deterministic) and `spans` is true iff the
    /// replicas live under more than one shard, i.e. the selection
    /// must consult foreign registration domains.
    pub fn home(&self, replica_sites: &[usize]) -> (usize, bool) {
        let n = self.shards();
        if n == 1 || replica_sites.is_empty() {
            return (0, false);
        }
        let first = self.owner(replica_sites[0]);
        let mut spans = false;
        // Replica sets are small (a handful of sites); count owners
        // without allocating.
        let mut best = first;
        let mut best_count = 0usize;
        for s in 0..n {
            let count = replica_sites.iter().filter(|&&r| self.owner(r) == s).count();
            if count > 0 && s != first {
                spans = true;
            }
            if count > best_count {
                best = s;
                best_count = count;
            }
        }
        (best, spans)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_ranges_cover_all_sites_exactly_once() {
        for sites in [1usize, 5, 8, 64, 257] {
            for shards in [1usize, 2, 3, 7, 300] {
                let m = ShardMap::contiguous(sites, shards);
                assert!(m.shards() >= 1 && m.shards() <= sites);
                let mut seen = 0usize;
                for s in 0..m.shards() {
                    let r = m.sites_of(s);
                    assert!(!r.is_empty(), "shard {s} empty ({sites}/{shards})");
                    assert_eq!(r.start, seen, "gap before shard {s}");
                    for site in r.clone() {
                        assert_eq!(m.owner(site), s);
                    }
                    seen = r.end;
                }
                assert_eq!(seen, sites);
            }
        }
    }

    #[test]
    fn near_equal_split() {
        let m = ShardMap::contiguous(10, 4);
        let sizes: Vec<usize> = (0..4).map(|s| m.sites_of(s).len()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
    }

    #[test]
    fn one_shard_routes_everything_home() {
        let m = ShardMap::contiguous(16, 1);
        assert_eq!(m.shards(), 1);
        assert_eq!(m.home(&[0, 7, 15]), (0, false));
        assert_eq!(m.home(&[]), (0, false));
    }

    #[test]
    fn home_is_plurality_with_low_tie_break() {
        let m = ShardMap::contiguous(8, 4); // shards: {0,1} {2,3} {4,5} {6,7}
        // Majority in shard 1, one foreign replica → spans.
        assert_eq!(m.home(&[2, 3, 6]), (1, true));
        // All in one shard → no span.
        assert_eq!(m.home(&[4, 5]), (2, false));
        // 1–1 tie between shards 0 and 3 → lowest wins, spans.
        assert_eq!(m.home(&[7, 0]), (0, true));
    }
}
