//! Baseline selectors — the uninformed strategies the benches compare
//! the broker against (EXPERIMENTS.md R7). All operate on the same
//! candidate lists the broker sees, so the only difference measured is
//! the *selection policy*.

use crate::util::prng::Rng;

use super::convert::Candidate;
use super::policy::{RankPolicy, Ranked};

/// Which baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectorKind {
    /// Uniform random replica.
    Random,
    /// Cycle through replicas.
    RoundRobin,
    /// Max published `availableSpace` (the paper's §5.2 rank, applied
    /// statically).
    StaticSpace,
    /// Max published `AvgRDBandwidth` (static history summary, Fig 4).
    AvgBandwidth,
    /// Max `lastRDBandwidth` (Fig 5's most recent observation).
    LastBandwidth,
    /// Max `predictedRDBandwidth` as *published by the site's GRIS*
    /// through the §7 NWS-style predictive feed — the broker itself
    /// runs no forecasting code.
    Published,
    /// The full forecast policy (predictor bank + load discount).
    Forecast,
}

impl SelectorKind {
    pub fn name(self) -> &'static str {
        match self {
            SelectorKind::Random => "random",
            SelectorKind::RoundRobin => "round-robin",
            SelectorKind::StaticSpace => "static-space",
            SelectorKind::AvgBandwidth => "avg-bandwidth",
            SelectorKind::LastBandwidth => "last-bandwidth",
            SelectorKind::Published => "published-pred",
            SelectorKind::Forecast => "forecast",
        }
    }

    pub fn all() -> [SelectorKind; 7] {
        [
            SelectorKind::Random,
            SelectorKind::RoundRobin,
            SelectorKind::StaticSpace,
            SelectorKind::AvgBandwidth,
            SelectorKind::LastBandwidth,
            SelectorKind::Published,
            SelectorKind::Forecast,
        ]
    }
}

/// Stateful selector instance.
pub struct Selector {
    kind: SelectorKind,
    rng: Rng,
    rr_next: usize,
}

impl Selector {
    pub fn new(kind: SelectorKind, seed: u64) -> Selector {
        Selector { kind, rng: Rng::new(seed ^ 0x5E1E_C70E), rr_next: 0 }
    }

    pub fn kind(&self) -> SelectorKind {
        self.kind
    }

    /// Pick among `eligible` indices into `candidates` (non-empty).
    pub fn pick(&mut self, candidates: &[Candidate], eligible: &[usize]) -> usize {
        assert!(!eligible.is_empty());
        match self.kind {
            SelectorKind::Random => eligible[self.rng.index(eligible.len())],
            SelectorKind::RoundRobin => {
                let i = eligible[self.rr_next % eligible.len()];
                self.rr_next += 1;
                i
            }
            SelectorKind::StaticSpace => Self::argmax(candidates, eligible, |c| {
                c.ad.number("availableSpace").unwrap_or(0.0)
            }),
            SelectorKind::AvgBandwidth => Self::argmax(candidates, eligible, |c| {
                c.ad.number("AvgRDBandwidth").unwrap_or(0.0)
            }),
            SelectorKind::LastBandwidth => Self::argmax(candidates, eligible, |c| {
                c.ad.number("lastRDBandwidth").unwrap_or(0.0)
            }),
            SelectorKind::Published => Self::argmax(candidates, eligible, |c| {
                c.ad.number("predictedRDBandwidth").unwrap_or(0.0)
            }),
            SelectorKind::Forecast => {
                let preds = RankPolicy::ForecastBandwidth { engine: None }
                    .predicted_bandwidth(candidates);
                Self::argmax(candidates, eligible, |c| {
                    let idx = candidates
                        .iter()
                        .position(|x| std::ptr::eq(x, c))
                        .unwrap();
                    preds[idx]
                })
            }
        }
    }

    /// Top-K *set* selection for co-allocated access: among the ranked
    /// survivors, the `k` candidate indices with the highest predicted
    /// bandwidth (ties broken by candidate index, so the choice is
    /// deterministic). Returns fewer than `k` when fewer survived.
    pub fn top_k_set(ranked: &[Ranked], preds: &[f64], k: usize) -> Vec<usize> {
        let mut order: Vec<usize> = ranked.iter().map(|r| r.index).collect();
        order.sort_by(|&a, &b| {
            preds[b]
                .partial_cmp(&preds[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        order.truncate(k.max(1));
        order
    }

    fn argmax(
        candidates: &[Candidate],
        eligible: &[usize],
        f: impl Fn(&Candidate) -> f64,
    ) -> usize {
        let mut best = eligible[0];
        let mut best_v = f(&candidates[best]);
        for &i in &eligible[1..] {
            let v = f(&candidates[i]);
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classad::parse_classad;

    fn cands() -> Vec<Candidate> {
        let mk = |site: &str, space: f64, avg: f64, last: f64, hist: &[f64]| Candidate {
            site: site.into(),
            url: format!("gsiftp://{site}/f"),
            ad: parse_classad(&format!(
                "availableSpace = {space}; AvgRDBandwidth = {avg}; lastRDBandwidth = {last};"
            ))
            .unwrap(),
            history: hist.to_vec(),
            load: 0.0,
        };
        vec![
            mk("a", 10.0, 100.0, 500.0, &[100.0, 100.0, 100.0]),
            mk("b", 90.0, 300.0, 100.0, &[300.0, 310.0, 305.0]),
            mk("c", 40.0, 200.0, 900.0, &[200.0, 190.0, 210.0]),
        ]
    }

    #[test]
    fn static_selectors_pick_expected_sites() {
        let cs = cands();
        let all = [0usize, 1, 2];
        assert_eq!(Selector::new(SelectorKind::StaticSpace, 0).pick(&cs, &all), 1);
        assert_eq!(Selector::new(SelectorKind::AvgBandwidth, 0).pick(&cs, &all), 1);
        assert_eq!(Selector::new(SelectorKind::LastBandwidth, 0).pick(&cs, &all), 2);
        assert_eq!(Selector::new(SelectorKind::Forecast, 0).pick(&cs, &all), 1);
    }

    #[test]
    fn round_robin_cycles_eligible() {
        let cs = cands();
        let mut s = Selector::new(SelectorKind::RoundRobin, 0);
        let picks: Vec<usize> = (0..4).map(|_| s.pick(&cs, &[0, 2])).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn random_is_deterministic_per_seed_and_in_range() {
        let cs = cands();
        let mut a = Selector::new(SelectorKind::Random, 7);
        let mut b = Selector::new(SelectorKind::Random, 7);
        for _ in 0..50 {
            let pa = a.pick(&cs, &[1, 2]);
            assert_eq!(pa, b.pick(&cs, &[1, 2]));
            assert!([1, 2].contains(&pa));
        }
    }

    #[test]
    fn respects_eligible_subset() {
        let cs = cands();
        // b (index 1) has the most space but is not eligible.
        assert_eq!(Selector::new(SelectorKind::StaticSpace, 0).pick(&cs, &[0, 2]), 2);
    }

    #[test]
    fn all_kinds_have_names() {
        for k in SelectorKind::all() {
            assert!(!k.name().is_empty());
        }
    }

    #[test]
    fn top_k_set_orders_by_prediction() {
        let ranked = vec![
            Ranked { index: 0, score: 1.0 },
            Ranked { index: 1, score: 2.0 },
            Ranked { index: 2, score: 3.0 },
        ];
        let preds = [50.0, 300.0, 200.0];
        assert_eq!(Selector::top_k_set(&ranked, &preds, 2), vec![1, 2]);
        // k larger than the survivor set returns everyone.
        assert_eq!(Selector::top_k_set(&ranked, &preds, 9), vec![1, 2, 0]);
        // k = 0 still returns the best single candidate.
        assert_eq!(Selector::top_k_set(&ranked, &preds, 0), vec![1]);
    }

    #[test]
    fn top_k_set_respects_survivors_only() {
        // Candidate 1 (highest prediction) did not survive matching.
        let ranked = vec![Ranked { index: 0, score: 1.0 }, Ranked { index: 2, score: 2.0 }];
        let preds = [50.0, 300.0, 200.0];
        assert_eq!(Selector::top_k_set(&ranked, &preds, 2), vec![2, 0]);
    }
}
