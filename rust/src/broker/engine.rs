//! The broker engine: Search → Match → Access orchestration.
//!
//! The Search phase has two discovery routes (ISSUE 5):
//!
//! * **Direct fan-out** (the default): every replica site's GRIS is
//!   queried for fresh entries — through a bounded scoped-thread pool
//!   when the [`InfoService`] blocks on real per-site I/O. Fresh, but
//!   the query count grows with the replica set; at hundreds of sites
//!   the *simulated* analog is the event-driven
//!   [`crate::directory::fanout::DirectoryFanout`].
//! * **Hierarchical GIIS → GRIS drill-down**
//!   ([`Broker::with_discovery`]): the broad query is answered from the
//!   GIIS's soft-state registration snapshots (stale by construction —
//!   as old as each site's last refresh), sites without a live
//!   registration are simply not discovered, and only the top
//!   [`HierDiscovery::drill_down`] summary-ranked candidates get a
//!   fresh GRIS query. Per selection this costs 1 broad lookup + K
//!   drill-downs instead of N site queries; when every registration is
//!   fresh the selection is *provably identical* to the direct route
//!   (the `it_giis` parity suite pins this).
//!
//! Under the sharded control plane (ISSUE 8,
//! [`crate::broker::shard::ShardMap`]) the hierarchical route is
//! per-shard: each shard runs its own GIIS registration domain over
//! the sites it owns, a request's broad query goes to its home shard's
//! GIIS, and replica sites owned by foreign shards are resolved
//! against *their* domains (the cross-shard consult the driver
//! counts). The broker engine itself is shard-agnostic — selection is
//! a pure function of the candidate set — which is why one shared
//! `Broker` serves every shard and the 1-shard configuration is
//! bit-identical to the unsharded path.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::catalog::ReplicaCatalog;
use crate::classad::{CandidateTable, ClassAd, CompiledMatch, Match, VmScratch};
use crate::coalloc::{plan_stripes, StripePlan, StripeSource};
use crate::config::CoallocPolicy;
use crate::directory::client::DirectoryClient;
use crate::directory::dit::Scope;
use crate::directory::entry::{Dn, Entry};
use crate::directory::filter::Filter;
use crate::directory::gris::Gris;
use crate::directory::hier::HierarchicalDirectory;
use crate::metrics::Metrics;
use crate::trace::{Ev, ReqId, TraceHandle};

use super::convert::{entries_to_candidate, Candidate};
use super::policy::{RankPolicy, Ranked};
use super::selectors::Selector;

/// Generous default for how many *new* attribute names an untrusted
/// request ad may introduce at the broker boundary. The GRIS schema
/// vocabulary plus the paper's request attributes total a few dozen
/// names; a legitimate request inventing more than this is implausible,
/// while a hostile one generating fresh names per request would grow
/// the leaked intern table forever (ROADMAP open item).
pub const REQUEST_AD_NAME_BUDGET: usize = 64;

/// Parse an untrusted request ad at the broker boundary, rejecting it
/// *before interning* if it would add more than
/// [`REQUEST_AD_NAME_BUDGET`] new attribute names to the global
/// [`crate::classad::intern`] table (see
/// [`crate::classad::parse_classad_bounded`]). Trusted in-process ads
/// (schema vocabulary, test fixtures) can keep using `parse_classad`.
pub fn parse_request_ad(src: &str) -> Result<ClassAd> {
    parse_request_ad_with_budget(src, REQUEST_AD_NAME_BUDGET)
}

/// [`parse_request_ad`] with an explicit budget (deployments that trim
/// or widen the boundary).
pub fn parse_request_ad_with_budget(src: &str, max_new_names: usize) -> Result<ClassAd> {
    crate::classad::parse_classad_bounded(src, max_new_names)
        .map_err(|e| anyhow::anyhow!(e).context("rejecting request ad at the broker boundary"))
}

/// Where the broker gets per-site capability data (the GRIS fan-out).
/// Implementations: in-process ([`LocalInfoService`], for the simulator
/// and benches) and TCP ([`RemoteInfoService`], the deployed topology).
pub trait InfoService: Send + Sync {
    /// Query one site's GRIS; returns its matching entries.
    fn query_site(&self, site: &str, filter: &Filter) -> Result<Vec<Entry>>;

    /// Whether the Search phase should fan site queries out across a
    /// thread pool. True for services that block on real per-site I/O
    /// (the TCP topology); the in-process registry answers from
    /// memory, where thread-spawn overhead exceeds the query itself.
    fn parallel_fanout(&self) -> bool {
        true
    }
}

/// In-process GRIS registry.
#[derive(Default)]
pub struct LocalInfoService {
    grises: BTreeMap<String, Arc<RwLock<Gris>>>,
}

impl LocalInfoService {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, site: &str, gris: Arc<RwLock<Gris>>) {
        self.grises.insert(site.to_string(), gris);
    }

    /// The registered GRIS handle for `site`, if any.
    pub fn gris(&self, site: &str) -> Option<&Arc<RwLock<Gris>>> {
        self.grises.get(site)
    }

    /// All registered (site, GRIS) handles — what a
    /// [`HierarchicalDirectory`] is wired from.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &Arc<RwLock<Gris>>)> {
        self.grises.iter().map(|(s, g)| (s.as_str(), g))
    }

    /// All storage entries of one site (replica-manager placement scan).
    pub fn query_site_all(&self, site: &str) -> Result<Vec<Entry>> {
        self.query_site(
            site,
            &Filter::parse(crate::directory::hier::STORAGE_SEARCH_FILTER).unwrap(),
        )
    }
}

impl InfoService for LocalInfoService {
    fn query_site(&self, site: &str, filter: &Filter) -> Result<Vec<Entry>> {
        let gris = self
            .grises
            .get(site)
            .with_context(|| format!("no GRIS registered for site {site:?}"))?;
        let g = gris.read().unwrap();
        Ok(g.search(&Dn::parse("o=grid").unwrap(), Scope::Sub, filter))
    }

    fn parallel_fanout(&self) -> bool {
        false // in-memory lookups; thread spawn would dominate
    }
}

/// TCP-backed info service: site → GRIS server address.
pub struct RemoteInfoService {
    addrs: BTreeMap<String, String>,
}

impl RemoteInfoService {
    pub fn new(addrs: BTreeMap<String, String>) -> Self {
        RemoteInfoService { addrs }
    }
}

impl InfoService for RemoteInfoService {
    fn query_site(&self, site: &str, filter: &Filter) -> Result<Vec<Entry>> {
        let addr = self
            .addrs
            .get(site)
            .with_context(|| format!("no GRIS address for site {site:?}"))?;
        let mut client = DirectoryClient::connect(addr)?;
        let entries = client.search(&Dn::parse("o=grid").unwrap(), Scope::Sub, filter)?;
        Ok(entries)
    }
}

/// Phase-by-phase trace of one selection (the Figure-6 walk-through the
/// quickstart example prints, and the data for `bench_broker`).
#[derive(Debug, Clone, Default)]
pub struct BrokerTrace {
    pub logical: String,
    pub replica_sites: Vec<String>,
    pub search_us: u128,
    pub convert_us: u128,
    pub match_us: u128,
    /// (site, matched?) per candidate.
    pub match_results: Vec<(String, bool)>,
    /// Ranked survivors, best first: (site, score).
    pub ranking: Vec<(String, f64)>,
    /// Hierarchical route only: fresh GRIS drill-down queries issued.
    pub drill_downs: usize,
    /// Hierarchical route only: candidates served purely from the
    /// (stale) GIIS registration snapshot.
    pub summary_sites: usize,
    /// Degrade chain ([`HierDiscovery::degrade`]): candidates served
    /// from an *expired* GIIS snapshot after the live index had
    /// nothing.
    pub degrade_stale: usize,
    /// Degrade chain: candidates recovered by querying the site's GRIS
    /// directly, bypassing the dead index entirely.
    pub degrade_direct: usize,
    /// Degrade chain: candidates admitted blind (no information at
    /// all — an empty ad the selector can only pick at random).
    pub degrade_blind: usize,
}

impl BrokerTrace {
    /// File this selection's phase timings into the flight recorder as
    /// [`Ev::BrokerPhase`] spans under request `req` at simulated
    /// instant `at`. Broker phases are *wall-clock* compute measured
    /// inside Search/Convert/Match, so each event carries `wall_us`
    /// rather than stretching simulated time; `trace-summary` reports
    /// them as a per-phase overhead table, not as lifetime spans.
    pub fn record_trace(&self, trace: &TraceHandle, at: f64, req: ReqId) {
        if !trace.on() {
            return;
        }
        for (phase, us) in [
            ("search", self.search_us),
            ("convert", self.convert_us),
            ("match", self.match_us),
        ] {
            let wall_us = us.min(u64::MAX as u128) as u64;
            trace.rec(at, req, Ev::BrokerPhase { phase, wall_us });
        }
    }
}

/// Result of a selection.
#[derive(Debug, Clone)]
pub struct Selection {
    /// The winning candidate.
    pub site: String,
    pub url: String,
    pub score: f64,
    /// All ranked survivors (best first), for k-choice policies.
    pub ranked: Vec<Ranked>,
    pub candidates: Vec<Candidate>,
    pub trace: BrokerTrace,
}

/// How the Access phase executes a selection (paper §5.1.2 step 3).
#[derive(Debug, Clone)]
pub enum AccessStrategy {
    /// Fetch the whole file from the single best-ranked replica — the
    /// paper's original behaviour.
    SingleBest,
    /// Stripe the file across the top-K ranked replicas and pull the
    /// ranges in parallel (`crate::coalloc`).
    Coallocated(CoallocPolicy),
}

/// A co-allocated selection: the ordinary ranked selection plus the
/// stripe plan over its top-K survivors. Execution happens through
/// [`crate::coalloc::execute`] because transfer simulation lives with
/// the driver, exactly like the single-source Access phase.
#[derive(Debug, Clone)]
pub struct CoallocSelection {
    pub selection: Selection,
    /// Candidate indices the plan actually stripes over, in assignment
    /// (byte-offset) order — one per `plan.assignments` entry.
    pub sources: Vec<usize>,
    pub plan: StripePlan,
}

/// A request compiled for repeated selection: the search filter parsed
/// once and the request's match/rank expressions compiled once
/// ([`CompiledMatch`]). Build with [`Broker::prepare`], reuse across
/// [`Broker::select_prepared`] / [`Broker::select_batch`] calls.
#[derive(Clone)]
pub struct PreparedRequest {
    compiled: CompiledMatch,
    filter: Filter,
}

impl PreparedRequest {
    /// The snapshotted request ad (owned by the compiled handle).
    pub fn ad(&self) -> &ClassAd {
        self.compiled.request()
    }

    pub fn compiled(&self) -> &CompiledMatch {
        &self.compiled
    }
}

/// Reusable per-selection buffers: the Search-phase scaffolding
/// (replica locations, raw per-site responses) plus the Match-phase
/// arena — the batch [`CandidateTable`], match flags, ranked
/// survivors and the bytecode VM's stack — so a batch of selections
/// performs no per-candidate heap allocation in steady state.
#[derive(Default)]
pub struct SelectScratch {
    locations: Vec<(String, String)>,
    raw: Vec<(String, String, Vec<Entry>)>,
    table: CandidateTable,
    flags: Vec<bool>,
    ms: Vec<Match>,
    matched: Vec<usize>,
    vm: VmScratch,
}

/// Hierarchical-discovery configuration: the shared directory plus how
/// many summary-ranked candidates get a fresh drill-down query.
#[derive(Clone)]
pub struct HierDiscovery {
    pub dir: Arc<RwLock<HierarchicalDirectory>>,
    /// Top-K sites (by predicted bandwidth over the *stale* snapshots)
    /// whose GRIS is queried fresh per selection. 0 = summaries only.
    pub drill_down: usize,
    /// Information-plane degrade chain (ISSUE 7). Off (the default):
    /// a site without a live registration is simply not a candidate —
    /// the strict behaviour the staleness experiments pin. On: the
    /// broker walks live GIIS → *expired* GIIS snapshot → direct GRIS
    /// query → blind candidate, counting each step in
    /// [`BrokerTrace`], so selection survives a dead or lagging index
    /// at the cost of selecting on worse information.
    pub degrade: bool,
}

/// The decentralized storage broker. One per client; cheap to clone
/// (shared catalog + info service handles).
#[derive(Clone)]
pub struct Broker {
    catalog: Arc<Mutex<ReplicaCatalog>>,
    info: Arc<dyn InfoService>,
    policy: RankPolicy,
    metrics: Option<Arc<Metrics>>,
    discovery: Option<HierDiscovery>,
}

impl Broker {
    pub fn new(
        catalog: Arc<Mutex<ReplicaCatalog>>,
        info: Arc<dyn InfoService>,
        policy: RankPolicy,
    ) -> Broker {
        Broker { catalog, info, policy, metrics: None, discovery: None }
    }

    /// Attach a metrics registry; the Search phase records per-site
    /// GRIS query latency and failure counts into it.
    pub fn with_metrics(mut self, metrics: Arc<Metrics>) -> Broker {
        self.metrics = Some(metrics);
        self
    }

    /// Route the Search phase through the hierarchical GIIS → GRIS
    /// drill-down path instead of the direct per-site fan-out (see the
    /// module docs).
    pub fn with_discovery(mut self, discovery: HierDiscovery) -> Broker {
        self.discovery = Some(discovery);
        self
    }

    pub fn policy(&self) -> &RankPolicy {
        &self.policy
    }

    /// Build the "specialized LDAP search query" (paper §5.2) from the
    /// request ad: always fetch storage + bandwidth entries; the GRIS
    /// evaluates dynamic attributes at query time. The hierarchical
    /// route snapshots and drills with this same filter
    /// ([`crate::directory::hier::STORAGE_SEARCH_FILTER`]) — the
    /// parity contract depends on the two routes fetching the same
    /// entry set.
    fn search_filter(_request: &ClassAd) -> Filter {
        Filter::parse(crate::directory::hier::STORAGE_SEARCH_FILTER).unwrap()
    }

    /// Compile `request` for repeated selection: parse the search
    /// filter and pre-bind the match/rank expressions once.
    pub fn prepare(&self, request: &ClassAd) -> PreparedRequest {
        PreparedRequest {
            compiled: CompiledMatch::compile(request),
            filter: Self::search_filter(request),
        }
    }

    /// **Search phase**: catalog lookup + GRIS fan-out.
    pub fn search(&self, logical: &str, request: &ClassAd) -> Result<(Vec<Candidate>, BrokerTrace)> {
        let filter = Self::search_filter(request);
        self.search_with(logical, &filter, &mut SelectScratch::default())
    }

    /// Search with a pre-parsed filter and reusable buffers — the
    /// batch path.
    fn search_with(
        &self,
        logical: &str,
        filter: &Filter,
        scratch: &mut SelectScratch,
    ) -> Result<(Vec<Candidate>, BrokerTrace)> {
        let SelectScratch { locations, raw, .. } = scratch;
        let mut trace = BrokerTrace { logical: logical.to_string(), ..Default::default() };
        let t0 = Instant::now();
        locations.clear();
        {
            let cat = self.catalog.lock().unwrap();
            locations.extend(
                cat.locate(logical)?
                    .iter()
                    .map(|l| (l.site.clone(), l.url.clone())),
            );
        }
        if locations.is_empty() {
            bail!("logical file {logical:?} has no replicas");
        }
        trace.replica_sites = locations.iter().map(|(s, _)| s.clone()).collect();
        // GRIS fan-out: when the info service blocks on real per-site
        // I/O, the sites are queried concurrently from a small
        // scoped-thread pool. Workers pull site indices from a shared
        // counter, so a hundred replicas still cost at most
        // `MAX_FANOUT_WORKERS` threads, and responses are collected in
        // catalog order so selection stays deterministic. In-process
        // services answer inline (their queries are cheaper than a
        // thread spawn); both paths record per-site latency.
        const MAX_FANOUT_WORKERS: usize = 8;
        let info: &dyn InfoService = self.info.as_ref();
        let locations: &[(String, String)] = locations;
        let responses: Vec<(Result<Vec<Entry>>, u64)> = if let Some(disc) = &self.discovery {
            self.hier_responses(disc, locations, &mut trace)
        } else if locations.len() > 1 && info.parallel_fanout() {
            let next = AtomicUsize::new(0);
            let mut slots: Vec<Option<(Result<Vec<Entry>>, u64)>> =
                (0..locations.len()).map(|_| None).collect();
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..locations.len().min(MAX_FANOUT_WORKERS))
                    .map(|_| {
                        let next = &next;
                        scope.spawn(move || {
                            let mut mine = Vec::new();
                            loop {
                                let i = next.fetch_add(1, AtomicOrdering::Relaxed);
                                if i >= locations.len() {
                                    break;
                                }
                                let tq = Instant::now();
                                let r = info.query_site(&locations[i].0, filter);
                                mine.push((i, (r, tq.elapsed().as_nanos() as u64)));
                            }
                            mine
                        })
                    })
                    .collect();
                for h in handles {
                    for (i, res) in h.join().expect("GRIS query worker panicked") {
                        slots[i] = Some(res);
                    }
                }
            });
            slots
                .into_iter()
                .map(|s| s.expect("every replica site queried"))
                .collect()
        } else {
            locations
                .iter()
                .map(|(site, _)| {
                    let tq = Instant::now();
                    let r = info.query_site(site, filter);
                    (r, tq.elapsed().as_nanos() as u64)
                })
                .collect()
        };
        raw.clear();
        raw.reserve(locations.len());
        for ((site, url), (resp, ns)) in locations.iter().zip(responses) {
            if let Some(m) = &self.metrics {
                m.histogram("broker.search.site_ns").observe_ns(ns);
                m.histogram(&format!("broker.search.site_ns.{site}")).observe_ns(ns);
            }
            // A site that fails to answer is simply not a candidate —
            // the decentralized broker degrades, it does not fail.
            match resp {
                Ok(entries) => raw.push((site.clone(), url.clone(), entries)),
                Err(_) => {
                    if let Some(m) = &self.metrics {
                        m.counter("broker.search.site_errors").inc();
                    }
                    log::warn!("site {site} did not answer; skipping");
                }
            }
        }
        trace.search_us = t0.elapsed().as_micros();
        if let Some(m) = &self.metrics {
            m.histogram("broker.phase.search_ns").observe_ns(t0.elapsed().as_nanos() as u64);
        }
        let t1 = Instant::now();
        let candidates = raw
            .iter()
            .map(|(site, url, entries)| entries_to_candidate(site, url, entries))
            .collect();
        trace.convert_us = t1.elapsed().as_micros();
        if let Some(m) = &self.metrics {
            m.histogram("broker.phase.convert_ns").observe_ns(t1.elapsed().as_nanos() as u64);
        }
        Ok((candidates, trace))
    }

    /// The hierarchical Search route (one selection): answer the broad
    /// query from every replica site's GIIS registration snapshot,
    /// rank the discovered sites by predicted bandwidth over that
    /// *stale* data — the only information a real client has before
    /// drilling down — and issue fresh GRIS queries only to the top
    /// [`HierDiscovery::drill_down`] of them. Result slots mirror
    /// `locations`; a site without a live registration (never pushed,
    /// or TTL-expired) answers with an error and is simply not a
    /// candidate, exactly like an unreachable site on the direct
    /// route. Cached slots report 0 ns (they are part of the single
    /// broad index lookup); drill-downs report their real query time.
    fn hier_responses(
        &self,
        disc: &HierDiscovery,
        locations: &[(String, String)],
        trace: &mut BrokerTrace,
    ) -> Vec<(Result<Vec<Entry>>, u64)> {
        let mut dir = disc.dir.write().unwrap();
        dir.note_broad();
        let mut cached: Vec<Option<Vec<Entry>>> = locations
            .iter()
            .map(|(site, _)| dir.cached(site).map(|(e, _)| e.to_vec()))
            .collect();
        let discovered: Vec<usize> = (0..locations.len())
            .filter(|&i| cached[i].is_some())
            .collect();
        let drill = {
            let stale_cands: Vec<Candidate> = discovered
                .iter()
                .map(|&i| {
                    entries_to_candidate(
                        &locations[i].0,
                        &locations[i].1,
                        cached[i].as_deref().unwrap(),
                    )
                })
                .collect();
            self.policy.drill_slots(&stale_cands, disc.drill_down)
        };
        let mut ns: Vec<u64> = vec![0; locations.len()];
        let mut fresh: Vec<Option<Vec<Entry>>> = vec![None; locations.len()];
        for &oi in &drill {
            let li = discovered[oi];
            let tq = Instant::now();
            if let Some(entries) = dir.drill_down(&locations[li].0) {
                fresh[li] = Some(entries);
                ns[li] = tq.elapsed().as_nanos() as u64;
            }
        }
        trace.drill_downs = fresh.iter().filter(|f| f.is_some()).count();
        trace.summary_sites = discovered.len() - trace.drill_downs;
        let degrade_filter = disc
            .degrade
            .then(|| Filter::parse(crate::directory::hier::STORAGE_SEARCH_FILTER).unwrap());
        locations
            .iter()
            .enumerate()
            .map(|(i, (site, _))| {
                match fresh[i].take().or_else(|| cached[i].take()) {
                    Some(entries) => (Ok(entries), ns[i]),
                    None => match &degrade_filter {
                        // Degrade chain: expired snapshot → direct
                        // GRIS → blind. Every step yields *a*
                        // candidate — under grid weather a degraded
                        // answer beats an absent one.
                        Some(filter) => {
                            if let Some((entries, _age)) = dir.cached_any(site) {
                                trace.degrade_stale += 1;
                                (Ok(entries.to_vec()), 0)
                            } else {
                                let tq = Instant::now();
                                match self.info.query_site(site, filter) {
                                    Ok(entries) => {
                                        trace.degrade_direct += 1;
                                        (Ok(entries), tq.elapsed().as_nanos() as u64)
                                    }
                                    Err(_) => {
                                        trace.degrade_blind += 1;
                                        (Ok(Vec::new()), 0)
                                    }
                                }
                            }
                        }
                        None => (
                            Err(anyhow::anyhow!(
                                "site {site:?} has no live GIIS registration"
                            )),
                            0,
                        ),
                    },
                }
            })
            .collect()
    }

    /// **Match phase** over pre-fetched candidates.
    pub fn match_phase(
        &self,
        request: &ClassAd,
        candidates: &[Candidate],
        trace: &mut BrokerTrace,
    ) -> Vec<Ranked> {
        let compiled = CompiledMatch::compile(request);
        self.match_phase_compiled(&compiled, candidates, trace)
    }

    /// Match phase against an already-compiled request, with throwaway
    /// scratch. One-shot callers land here; the batch path uses
    /// [`Broker::match_phase_prepared`] directly. Results are
    /// bit-identical either way (same implementation underneath).
    pub fn match_phase_compiled(
        &self,
        compiled: &CompiledMatch,
        candidates: &[Candidate],
        trace: &mut BrokerTrace,
    ) -> Vec<Ranked> {
        self.match_phase_prepared(compiled, candidates, trace, &mut SelectScratch::default())
    }

    /// Match phase on the bytecode VM: the candidate batch is converted
    /// once into the scratch's struct-of-arrays [`CandidateTable`]
    /// (table-build time is conversion work — it counts into the
    /// `convert` trace field and `broker.phase.convert_ns`, not into
    /// `match`), then the compiled program runs down the table in one
    /// linear pass, reusing the scratch's flag/rank/VM buffers.
    pub fn match_phase_prepared(
        &self,
        compiled: &CompiledMatch,
        candidates: &[Candidate],
        trace: &mut BrokerTrace,
        scratch: &mut SelectScratch,
    ) -> Vec<Ranked> {
        let SelectScratch { table, flags, ms, matched, vm, .. } = scratch;
        let tb = Instant::now();
        table.rebuild(compiled.program(), candidates.iter().map(|c| &c.ad));
        trace.convert_us += tb.elapsed().as_micros();
        if let Some(m) = &self.metrics {
            m.histogram("broker.phase.convert_ns").observe_ns(tb.elapsed().as_nanos() as u64);
        }
        let t0 = Instant::now();
        let ranked = match &self.policy {
            RankPolicy::ClassAdRank => {
                compiled.match_and_rank_vm_into(
                    candidates.iter().map(|c| &c.ad),
                    Some(&*table),
                    flags,
                    ms,
                    vm,
                );
                trace.match_results = candidates
                    .iter()
                    .zip(flags.iter())
                    .map(|(c, &ok)| (c.site.clone(), ok))
                    .collect();
                ms.iter()
                    .map(|m| Ranked { index: m.index, score: m.rank })
                    .collect()
            }
            RankPolicy::ForecastBandwidth { .. } => {
                matched.clear();
                trace.match_results = candidates
                    .iter()
                    .enumerate()
                    .map(|(i, c)| {
                        let ok = compiled.matches_vm_row(&c.ad, table, i, vm);
                        if ok {
                            matched.push(i);
                        }
                        (c.site.clone(), ok)
                    })
                    .collect();
                self.policy.order_compiled(compiled, candidates, matched)
            }
        };
        trace.ranking = ranked
            .iter()
            .map(|r| (candidates[r.index].site.clone(), r.score))
            .collect();
        trace.match_us = t0.elapsed().as_micros();
        if let Some(m) = &self.metrics {
            m.histogram("broker.phase.match_ns").observe_ns(t0.elapsed().as_nanos() as u64);
        }
        ranked
    }

    /// Full selection: Search + Match. (The Access phase is executed by
    /// the caller against the returned site — see `gridftp::GridFtp` —
    /// because transfer execution lives with the simulation/driver.)
    pub fn select(&self, logical: &str, request: &ClassAd) -> Result<Selection> {
        let prepared = self.prepare(request);
        self.select_prepared(logical, &prepared, &mut SelectScratch::default())
    }

    /// One selection on the match-many path: the request is already
    /// compiled and the Search buffers are caller-owned, so the only
    /// per-call work is the actual Search → Match pipeline.
    pub fn select_prepared(
        &self,
        logical: &str,
        prepared: &PreparedRequest,
        scratch: &mut SelectScratch,
    ) -> Result<Selection> {
        let t0 = Instant::now();
        let (candidates, mut trace) = self.search_with(logical, &prepared.filter, scratch)?;
        let ranked =
            self.match_phase_prepared(&prepared.compiled, &candidates, &mut trace, scratch);
        let best = ranked
            .first()
            .cloned()
            .with_context(|| format!("no replica of {logical:?} satisfies the request"))?;
        if let Some(m) = &self.metrics {
            m.histogram("broker.select_ns").observe(t0.elapsed());
        }
        Ok(Selection {
            site: candidates[best.index].site.clone(),
            url: candidates[best.index].url.clone(),
            score: best.score,
            ranked,
            candidates,
            trace,
        })
    }

    /// Batch selection: compile the request once, then stream it across
    /// every logical file, reusing one scratch arena for the whole
    /// Search → Match pipeline. Per-file failures (no replicas, no
    /// feasible replica) land in the corresponding result slot — one
    /// missing file does not fail the batch.
    pub fn select_batch<S: AsRef<str>>(
        &self,
        logicals: &[S],
        request: &ClassAd,
    ) -> Vec<Result<Selection>> {
        let prepared = self.prepare(request);
        let mut scratch = SelectScratch::default();
        logicals
            .iter()
            .map(|logical| {
                let r = self.select_prepared(logical.as_ref(), &prepared, &mut scratch);
                if let Some(m) = &self.metrics {
                    m.counter("broker.batch.selections").inc();
                    if r.is_err() {
                        m.counter("broker.batch.failures").inc();
                    }
                }
                r
            })
            .collect()
    }

    /// Co-allocated selection (the [`AccessStrategy::Coallocated`]
    /// planning step): run the ordinary Search + Match, keep the top-K
    /// survivors by predicted bandwidth, and stripe `total_bytes`
    /// across them proportionally to those predictions. The caller
    /// executes the returned plan with [`crate::coalloc::execute`].
    pub fn select_coalloc(
        &self,
        logical: &str,
        request: &ClassAd,
        total_bytes: f64,
        policy: &CoallocPolicy,
    ) -> Result<CoallocSelection> {
        let selection = self.select(logical, request)?;
        let preds = self.policy.predicted_bandwidth(&selection.candidates);
        let top = Selector::top_k_set(&selection.ranked, &preds, policy.max_streams);
        let stripe_sources: Vec<StripeSource> = top
            .iter()
            .map(|&i| StripeSource {
                site: selection.candidates[i].site.clone(),
                url: selection.candidates[i].url.clone(),
                predicted_bw: preds[i],
            })
            .collect();
        let plan = plan_stripes(&stripe_sources, total_bytes, policy);
        // Report the candidates the plan actually stripes over — the
        // planner may drop stragglers or cap streams at the block
        // count, so `top` can be a superset of the final set. Keyed by
        // URL: a site may host several replicas of one logical file.
        let sources: Vec<usize> = plan
            .assignments
            .iter()
            .map(|a| {
                selection
                    .candidates
                    .iter()
                    .position(|c| c.url == a.source.url)
                    .expect("stripe source originates from the candidate set")
            })
            .collect();
        Ok(CoallocSelection { selection, sources, plan })
    }

    /// Plan the Access phase under `strategy`: [`AccessStrategy::
    /// SingleBest`] yields a one-stream whole-file plan for the
    /// *rank-policy winner* (the paper's original behaviour — one
    /// block, so connection setup and seek are paid once, exactly like
    /// [`crate::gridftp::GridFtp::fetch`]), [`AccessStrategy::
    /// Coallocated`] a top-K stripe plan under the given policy.
    /// Either way the caller executes the result with
    /// [`crate::coalloc::execute`] (whose run-time knobs — tick,
    /// downlink, steal threshold — come from the policy passed there;
    /// block geometry is carried by the plan itself).
    pub fn plan_access(
        &self,
        logical: &str,
        request: &ClassAd,
        total_bytes: f64,
        strategy: &AccessStrategy,
    ) -> Result<CoallocSelection> {
        match strategy {
            AccessStrategy::SingleBest => {
                let selection = self.select(logical, request)?;
                let preds = self.policy.predicted_bandwidth(&selection.candidates);
                let best = selection.ranked[0].index;
                let source = StripeSource {
                    site: selection.candidates[best].site.clone(),
                    url: selection.candidates[best].url.clone(),
                    predicted_bw: preds[best],
                };
                let whole_file = CoallocPolicy {
                    block_size: total_bytes.max(1.0),
                    max_streams: 1,
                    ..Default::default()
                };
                let plan = plan_stripes(&[source], total_bytes, &whole_file);
                // Empty plan (zero-byte file) carries no sources.
                let sources =
                    if plan.assignments.is_empty() { Vec::new() } else { vec![best] };
                Ok(CoallocSelection { selection, sources, plan })
            }
            AccessStrategy::Coallocated(policy) => {
                self.select_coalloc(logical, request, total_bytes, policy)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::PhysicalLocation;
    use crate::classad::parse_classad;
    use crate::util::units::Bytes;

    /// In-process info service that opts into the thread-pool fan-out
    /// (exercises the parallel Search path without TCP).
    struct ForceParallel(LocalInfoService);

    impl InfoService for ForceParallel {
        fn query_site(&self, site: &str, filter: &Filter) -> Result<Vec<Entry>> {
            self.0.query_site(site, filter)
        }
    }

    /// Build a 3-site in-process grid with distinct capabilities.
    fn fixture(policy: RankPolicy) -> (Broker, ClassAd) {
        fixture_impl(policy, false)
    }

    fn fixture_impl(policy: RankPolicy, parallel: bool) -> (Broker, ClassAd) {
        let (catalog, info, request) = fixture_parts();
        let info: Arc<dyn InfoService> = if parallel {
            Arc::new(ForceParallel(info))
        } else {
            Arc::new(info)
        };
        (
            Broker::new(Arc::new(Mutex::new(catalog)), info, policy),
            request,
        )
    }

    fn fixture_parts() -> (ReplicaCatalog, LocalInfoService, ClassAd) {
        let mut catalog = ReplicaCatalog::new();
        catalog
            .create_logical("run42.dat", Bytes::from_gb(1.0), "cms")
            .unwrap();
        let mut info = LocalInfoService::new();
        let sites = [
            // (site, availGB, maxRD KB/s, history KB/s, load)
            ("anl-mcs", 50.0, 75.0, vec![40.0, 42.0, 41.0], 0.1),
            ("lbl-dsd", 80.0, 60.0, vec![55.0, 57.0, 58.0], 0.0),
            ("isi-grid", 3.0, 90.0, vec![80.0, 82.0, 81.0], 0.0),
        ];
        for (site, gb, rd, hist, load) in sites {
            catalog
                .add_replica(
                    "run42.dat",
                    PhysicalLocation { site: site.into(), url: format!("gsiftp://{site}/run42.dat") },
                )
                .unwrap();
            let mut gris = Gris::new("org", site);
            let base = gris.base_dn().clone();
            let vol = base.child("gss", "vol0");
            let mut e = Entry::new(vol.clone());
            e.add("objectClass", "GridStorageServerVolume");
            e.put_f64("totalSpace", 100.0 * 1024f64.powi(3));
            e.put_f64("availableSpace", gb * 1024f64.powi(3));
            e.put("mountPoint", "/data");
            e.put_f64("diskTransferRate", 2e7);
            e.put_f64("drdTime", 8.0);
            e.put_f64("dwrTime", 9.0);
            e.put_f64("load", load);
            gris.add_entry(e);
            let mut bw = Entry::new(vol.child("gss", "bw"));
            bw.add("objectClass", "GridStorageTransferBandwidth");
            for a in ["MaxRDBandwidth", "MinRDBandwidth", "AvgRDBandwidth"] {
                bw.put_f64(a, rd * 1024.0);
            }
            for a in ["MaxWRBandwidth", "MinWRBandwidth", "AvgWRBandwidth"] {
                bw.put_f64(a, rd * 512.0);
            }
            gris.add_entry(bw);
            let mut src = Entry::new(vol.child("gss", "src"));
            src.add("objectClass", "GridStorageSourceTransferBandwidth");
            src.put_f64("lastRDBandwidth", hist.last().unwrap() * 1024.0);
            src.put("lastRDurl", "gsiftp://client/");
            src.put_f64("lastWRBandwidth", 0.0);
            src.put("lastWRurl", "gsiftp://client/");
            src.put(
                "rdHistory",
                hist.iter()
                    .map(|h| format!("{}", h * 1024.0))
                    .collect::<Vec<_>>()
                    .join(","),
            );
            gris.add_entry(src);
            info.add(site, Arc::new(RwLock::new(gris)));
        }
        let request = parse_classad(
            r#"hostname = "comet.xyz.com";
               reqdSpace = 5G;
               reqdRDBandwidth = 50K/Sec;
               rank = other.availableSpace;
               requirement = other.availableSpace > 5G
                   && other.MaxRDBandwidth > 50K/Sec;"#,
        )
        .unwrap();
        (catalog, info, request)
    }

    /// Direct + hierarchical brokers over one shared grid, plus the
    /// hierarchy handle (registrations already pushed).
    fn hier_fixture(
        policy: RankPolicy,
        drill_down: usize,
        ttl: f64,
    ) -> (Broker, Broker, Arc<RwLock<HierarchicalDirectory>>, ClassAd) {
        let (catalog, info, request) = fixture_parts();
        let mut dir = HierarchicalDirectory::new(ttl);
        for (site, gris) in info.iter() {
            dir.add_site(site, gris.clone());
        }
        dir.refresh_all();
        let dir = Arc::new(RwLock::new(dir));
        let catalog = Arc::new(Mutex::new(catalog));
        let info: Arc<dyn InfoService> = Arc::new(info);
        let direct = Broker::new(catalog.clone(), info.clone(), policy.clone());
        let hier = Broker::new(catalog, info, policy)
            .with_discovery(HierDiscovery { dir: dir.clone(), drill_down, degrade: false });
        (direct, hier, dir, request)
    }

    #[test]
    fn classad_rank_selects_most_space() {
        let (broker, request) = fixture(RankPolicy::ClassAdRank);
        let sel = broker.select("run42.dat", &request).unwrap();
        // isi-grid fails the space requirement; lbl-dsd has most space.
        assert_eq!(sel.site, "lbl-dsd");
        assert_eq!(sel.trace.replica_sites.len(), 3);
        let matched: Vec<bool> = sel.trace.match_results.iter().map(|(_, m)| *m).collect();
        assert_eq!(matched, vec![true, true, false]);
        assert_eq!(sel.ranked.len(), 2);
    }

    #[test]
    fn forecast_rank_selects_fastest_feasible() {
        let (broker, request) = fixture(RankPolicy::ForecastBandwidth { engine: None });
        let sel = broker.select("run42.dat", &request).unwrap();
        // isi is fastest but infeasible (3G < 5G); lbl (≈57K) beats
        // anl (≈41K, loaded).
        assert_eq!(sel.site, "lbl-dsd");
        assert!(sel.score > 50.0 * 1024.0);
    }

    #[test]
    fn unknown_logical_file_errors() {
        let (broker, request) = fixture(RankPolicy::ClassAdRank);
        assert!(broker.select("nope.dat", &request).is_err());
    }

    #[test]
    fn no_feasible_replica_errors() {
        let (broker, _) = fixture(RankPolicy::ClassAdRank);
        let greedy = parse_classad(
            "reqdSpace = 1G; requirement = other.availableSpace > 500G;",
        )
        .unwrap();
        let err = broker.select("run42.dat", &greedy).unwrap_err();
        assert!(format!("{err:#}").contains("satisfies"));
    }

    #[test]
    fn trace_phases_populated() {
        let (broker, request) = fixture(RankPolicy::ClassAdRank);
        let sel = broker.select("run42.dat", &request).unwrap();
        assert_eq!(sel.trace.logical, "run42.dat");
        assert_eq!(sel.trace.ranking.first().unwrap().0, "lbl-dsd");
        // Timings are measured (may be 0µs on fast machines but the
        // fields exist and ranking is consistent with `ranked`).
        assert_eq!(sel.trace.ranking.len(), sel.ranked.len());
    }

    #[test]
    fn trace_phases_reach_flight_recorder() {
        let (broker, request) = fixture(RankPolicy::ClassAdRank);
        let sel = broker.select("run42.dat", &request).unwrap();
        let handle = TraceHandle::new(64);
        sel.trace.record_trace(&handle, 12.5, 3);
        let phases: Vec<&'static str> = handle
            .read(|r| {
                r.events()
                    .iter()
                    .filter_map(|e| match e.ev {
                        Ev::BrokerPhase { phase, .. } => Some(phase),
                        _ => None,
                    })
                    .collect()
            })
            .unwrap();
        assert_eq!(phases, ["search", "convert", "match"]);
        // A disabled handle records nothing and never allocates.
        sel.trace.record_trace(&TraceHandle::disabled(), 12.5, 3);
    }

    #[test]
    fn coalloc_selection_stripes_over_feasible_survivors() {
        let (broker, request) = fixture(RankPolicy::ForecastBandwidth { engine: None });
        let policy = CoallocPolicy { max_streams: 3, ..Default::default() };
        let sel = broker
            .select_coalloc("run42.dat", &request, 1e9, &policy)
            .unwrap();
        // isi-grid fails the space requirement → only 2 sources remain
        // even though max_streams allows 3.
        assert_eq!(sel.sources.len(), 2);
        let sites: Vec<&str> = sel
            .plan
            .assignments
            .iter()
            .map(|a| a.source.site.as_str())
            .collect();
        assert!(sites.contains(&"lbl-dsd") && sites.contains(&"anl-mcs"));
        // The plan partitions the file, favouring the faster history.
        let total: f64 = sel.plan.assignments.iter().map(|a| a.bytes).sum();
        assert!((total - 1e9).abs() < 1.0);
        let lbl = sel.plan.assignments.iter().find(|a| a.source.site == "lbl-dsd").unwrap();
        let anl = sel.plan.assignments.iter().find(|a| a.source.site == "anl-mcs").unwrap();
        assert!(lbl.share > anl.share, "lbl {} !> anl {}", lbl.share, anl.share);
        // Single-best remains the ordinary selection.
        assert_eq!(sel.selection.site, "lbl-dsd");
    }

    #[test]
    fn parallel_fanout_matches_sequential_results() {
        let (seq, request) = fixture(RankPolicy::ClassAdRank);
        let (par, _) = fixture_impl(RankPolicy::ClassAdRank, true);
        let metrics = Arc::new(crate::metrics::Metrics::new());
        let par = par.with_metrics(metrics.clone());
        let a = seq.select("run42.dat", &request).unwrap();
        let b = par.select("run42.dat", &request).unwrap();
        // Same winner, same candidate order (catalog order), same
        // ranking — the thread pool must not perturb determinism.
        assert_eq!(a.site, b.site);
        assert_eq!(a.trace.replica_sites, b.trace.replica_sites);
        let sites = |s: &Selection| {
            s.candidates.iter().map(|c| c.site.clone()).collect::<Vec<_>>()
        };
        assert_eq!(sites(&a), sites(&b));
        assert_eq!(a.trace.ranking, b.trace.ranking);
        // Per-site latency lands in metrics on the pool path too.
        assert_eq!(metrics.histogram("broker.search.site_ns").count(), 3);
    }

    #[test]
    fn plan_access_dispatches_strategies() {
        let (broker, request) = fixture(RankPolicy::ForecastBandwidth { engine: None });
        let single = broker
            .plan_access("run42.dat", &request, 1e9, &AccessStrategy::SingleBest)
            .unwrap();
        assert_eq!(single.plan.assignments.len(), 1);
        assert_eq!(single.plan.assignments[0].source.site, single.selection.site);
        let policy = CoallocPolicy { max_streams: 3, ..Default::default() };
        let striped = broker
            .plan_access(
                "run42.dat",
                &request,
                1e9,
                &AccessStrategy::Coallocated(policy),
            )
            .unwrap();
        assert!(striped.plan.assignments.len() > 1);
        assert_eq!(striped.sources.len(), striped.plan.assignments.len());
    }

    #[test]
    fn search_records_per_site_latency_metrics() {
        let (broker, request) = fixture(RankPolicy::ClassAdRank);
        let metrics = Arc::new(crate::metrics::Metrics::new());
        let broker = broker.with_metrics(metrics.clone());
        broker.select("run42.dat", &request).unwrap();
        assert_eq!(metrics.histogram("broker.search.site_ns").count(), 3);
        for site in ["anl-mcs", "lbl-dsd", "isi-grid"] {
            assert_eq!(
                metrics.histogram(&format!("broker.search.site_ns.{site}")).count(),
                1,
                "missing latency sample for {site}"
            );
        }
        assert_eq!(metrics.counter("broker.search.site_errors").get(), 0);
    }

    #[test]
    fn batch_selection_matches_one_shot() {
        let (broker, request) = fixture(RankPolicy::ClassAdRank);
        let one = broker.select("run42.dat", &request).unwrap();
        let batch = broker.select_batch(&["run42.dat", "run42.dat", "nope.dat"], &request);
        assert_eq!(batch.len(), 3);
        for sel in &batch[..2] {
            let sel = sel.as_ref().unwrap();
            assert_eq!(sel.site, one.site);
            assert_eq!(sel.trace.ranking, one.trace.ranking);
            assert_eq!(sel.trace.match_results, one.trace.match_results);
        }
        assert!(batch[2].is_err(), "unknown logical must fail its own slot only");
    }

    #[test]
    fn prepared_request_matches_per_call_forecast_policy() {
        let (broker, request) = fixture(RankPolicy::ForecastBandwidth { engine: None });
        let one = broker.select("run42.dat", &request).unwrap();
        let prepared = broker.prepare(&request);
        let mut scratch = SelectScratch::default();
        for _ in 0..3 {
            let sel = broker
                .select_prepared("run42.dat", &prepared, &mut scratch)
                .unwrap();
            assert_eq!(sel.site, one.site);
            assert_eq!(sel.ranked.len(), one.ranked.len());
        }
    }

    #[test]
    fn batch_and_phase_metrics_recorded() {
        let (broker, request) = fixture(RankPolicy::ClassAdRank);
        let metrics = Arc::new(crate::metrics::Metrics::new());
        let broker = broker.with_metrics(metrics.clone());
        let batch = broker.select_batch(&["run42.dat", "run42.dat"], &request);
        assert!(batch.iter().all(|r| r.is_ok()));
        assert_eq!(metrics.counter("broker.batch.selections").get(), 2);
        assert_eq!(metrics.counter("broker.batch.failures").get(), 0);
        assert_eq!(metrics.histogram("broker.phase.search_ns").count(), 2);
        assert_eq!(metrics.histogram("broker.phase.match_ns").count(), 2);
        assert_eq!(metrics.histogram("broker.select_ns").count(), 2);
    }

    #[test]
    fn boundary_rejects_attribute_name_floods() {
        // A hostile request ad generating fresh attribute names is
        // rejected before the intern table grows (ROADMAP item).
        let flood: String = (0..(REQUEST_AD_NAME_BUDGET + 10))
            .map(|i| format!("broker_boundary_flood_{i} = {i};\n"))
            .collect();
        let err = parse_request_ad(&flood).unwrap_err();
        assert!(format!("{err:#}").contains("broker boundary"));
        assert!(crate::classad::Sym::lookup("broker_boundary_flood_0").is_none());
        // The paper's request vocabulary sails through.
        let ok = parse_request_ad(
            "reqdSpace = 5G; rank = other.availableSpace; requirement = TRUE;",
        )
        .unwrap();
        assert!(ok.get("rank").is_some());
    }

    #[test]
    fn hier_route_matches_direct_when_registrations_are_fresh() {
        for k in [0usize, 1, 3] {
            let (direct, hier, _, request) =
                hier_fixture(RankPolicy::ForecastBandwidth { engine: None }, k, 300.0);
            let a = direct.select("run42.dat", &request).unwrap();
            let b = hier.select("run42.dat", &request).unwrap();
            assert_eq!(a.site, b.site, "drill_down={k}");
            assert_eq!(a.score, b.score);
            assert_eq!(a.trace.ranking, b.trace.ranking);
            assert_eq!(a.trace.match_results, b.trace.match_results);
            assert_eq!(b.trace.drill_downs, k.min(3));
            assert_eq!(b.trace.summary_sites, 3 - k.min(3));
        }
    }

    #[test]
    fn hier_route_counts_broad_and_drill_queries() {
        let (_, hier, dir, request) =
            hier_fixture(RankPolicy::ForecastBandwidth { engine: None }, 1, 300.0);
        hier.select("run42.dat", &request).unwrap();
        hier.select("run42.dat", &request).unwrap();
        let stats = dir.read().unwrap().stats();
        assert_eq!(stats.broad_queries, 2, "one broad lookup per selection");
        assert_eq!(stats.drill_downs, 2, "one top-candidate drill-down per selection");
        assert_eq!(stats.refreshes, 3, "the initial refresh_all only");
    }

    #[test]
    fn hier_route_drops_expired_registrations() {
        let (_, hier, dir, request) =
            hier_fixture(RankPolicy::ClassAdRank, 3, 60.0);
        assert!(hier.select("run42.dat", &request).is_ok());
        dir.write().unwrap().advance_to(120.0);
        // All soft state expired: nothing is discovered any more.
        let err = hier.select("run42.dat", &request).unwrap_err();
        assert!(format!("{err:#}").contains("satisfies"));
        // A soft-state refresh revives discovery.
        dir.write().unwrap().refresh_all();
        assert!(hier.select("run42.dat", &request).is_ok());
    }

    /// ISSUE 7: with the degrade chain on, a fully expired index no
    /// longer kills selection — every slot falls back to its expired
    /// snapshot, and the trace says so.
    #[test]
    fn degrade_chain_survives_a_fully_expired_index() {
        let (_, hier, dir, request) =
            hier_fixture(RankPolicy::ClassAdRank, 0, 60.0);
        let degraded = {
            let mut disc = hier.discovery.clone().unwrap();
            disc.degrade = true;
            hier.clone().with_discovery(disc)
        };
        dir.write().unwrap().advance_to(120.0);
        // Strict route: everything expired, selection fails (the
        // pinned pre-ISSUE-7 contract).
        assert!(hier.select("run42.dat", &request).is_err());
        // Degrade chain: expired snapshots still carry Figure-2 data,
        // so selection succeeds on stale information.
        let sel = degraded.select("run42.dat", &request).unwrap();
        assert_eq!(sel.site, "lbl-dsd", "stale data is yesterday's truth, not garbage");
        assert_eq!(sel.trace.degrade_stale, 3, "every slot came from an expired snapshot");
        assert_eq!(sel.trace.degrade_direct, 0);
        assert_eq!(sel.trace.degrade_blind, 0);
    }

    /// A site the GIIS never registered falls through the stale step
    /// to a direct GRIS query; a site with no GRIS at all becomes a
    /// blind candidate instead of an error.
    #[test]
    fn degrade_chain_falls_back_to_direct_gris_then_blind() {
        let (catalog, info, request) = fixture_parts();
        // Hierarchy that only ever knew about one of the three sites.
        let mut dir = HierarchicalDirectory::new(60.0);
        let gris = info.iter().next().map(|(s, g)| (s.to_string(), g.clone())).unwrap();
        dir.add_site(&gris.0, gris.1);
        dir.refresh_all();
        // A ghost replica with no GRIS anywhere.
        let mut catalog = catalog;
        catalog
            .add_replica(
                "run42.dat",
                PhysicalLocation { site: "ghost".into(), url: "gsiftp://ghost/f".into() },
            )
            .unwrap();
        let broker = Broker::new(
            Arc::new(Mutex::new(catalog)),
            Arc::new(info),
            RankPolicy::ClassAdRank,
        )
        .with_discovery(HierDiscovery {
            dir: Arc::new(RwLock::new(dir)),
            drill_down: 0,
            degrade: true,
        });
        let sel = broker.select("run42.dat", &request).unwrap();
        // 1 slot live (the registered site), 2 recovered by direct
        // GRIS queries, and the ghost admitted blind.
        assert_eq!(sel.trace.degrade_direct, 2);
        assert_eq!(sel.trace.degrade_blind, 1);
        assert_eq!(sel.trace.degrade_stale, 0);
        assert_eq!(sel.candidates.len(), 4);
    }

    #[test]
    fn missing_site_degrades_gracefully() {
        let (broker, request) = fixture(RankPolicy::ClassAdRank);
        {
            let cat = broker.catalog.clone();
            let mut cat = cat.lock().unwrap();
            cat.add_replica(
                "run42.dat",
                PhysicalLocation { site: "ghost".into(), url: "gsiftp://ghost/f".into() },
            )
            .unwrap();
        }
        // ghost has no GRIS: selection still succeeds on the others.
        let sel = broker.select("run42.dat", &request).unwrap();
        assert_eq!(sel.site, "lbl-dsd");
        assert_eq!(sel.candidates.len(), 3);
    }
}
