//! The broker engine: Search → Match → Access orchestration.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::catalog::ReplicaCatalog;
use crate::classad::{symmetric_match, ClassAd};
use crate::directory::client::DirectoryClient;
use crate::directory::dit::Scope;
use crate::directory::entry::{Dn, Entry};
use crate::directory::filter::Filter;
use crate::directory::gris::Gris;

use super::convert::{entries_to_candidate, Candidate};
use super::policy::{RankPolicy, Ranked};

/// Where the broker gets per-site capability data (the GRIS fan-out).
/// Implementations: in-process ([`LocalInfoService`], for the simulator
/// and benches) and TCP ([`RemoteInfoService`], the deployed topology).
pub trait InfoService: Send + Sync {
    /// Query one site's GRIS; returns its matching entries.
    fn query_site(&self, site: &str, filter: &Filter) -> Result<Vec<Entry>>;
}

/// In-process GRIS registry.
#[derive(Default)]
pub struct LocalInfoService {
    grises: BTreeMap<String, Arc<RwLock<Gris>>>,
}

impl LocalInfoService {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, site: &str, gris: Arc<RwLock<Gris>>) {
        self.grises.insert(site.to_string(), gris);
    }

    /// All storage entries of one site (replica-manager placement scan).
    pub fn query_site_all(&self, site: &str) -> Result<Vec<Entry>> {
        self.query_site(
            site,
            &Filter::parse(
                "(|(objectClass=GridStorageServerVolume)\
                  (objectClass=GridStorageTransferBandwidth)\
                  (objectClass=GridStorageSourceTransferBandwidth))",
            )
            .unwrap(),
        )
    }
}

impl InfoService for LocalInfoService {
    fn query_site(&self, site: &str, filter: &Filter) -> Result<Vec<Entry>> {
        let gris = self
            .grises
            .get(site)
            .with_context(|| format!("no GRIS registered for site {site:?}"))?;
        let g = gris.read().unwrap();
        Ok(g.search(&Dn::parse("o=grid").unwrap(), Scope::Sub, filter))
    }
}

/// TCP-backed info service: site → GRIS server address.
pub struct RemoteInfoService {
    addrs: BTreeMap<String, String>,
}

impl RemoteInfoService {
    pub fn new(addrs: BTreeMap<String, String>) -> Self {
        RemoteInfoService { addrs }
    }
}

impl InfoService for RemoteInfoService {
    fn query_site(&self, site: &str, filter: &Filter) -> Result<Vec<Entry>> {
        let addr = self
            .addrs
            .get(site)
            .with_context(|| format!("no GRIS address for site {site:?}"))?;
        let mut client = DirectoryClient::connect(addr)?;
        let entries = client.search(&Dn::parse("o=grid").unwrap(), Scope::Sub, filter)?;
        Ok(entries)
    }
}

/// Phase-by-phase trace of one selection (the Figure-6 walk-through the
/// quickstart example prints, and the data for `bench_broker`).
#[derive(Debug, Clone, Default)]
pub struct BrokerTrace {
    pub logical: String,
    pub replica_sites: Vec<String>,
    pub search_us: u128,
    pub convert_us: u128,
    pub match_us: u128,
    /// (site, matched?) per candidate.
    pub match_results: Vec<(String, bool)>,
    /// Ranked survivors, best first: (site, score).
    pub ranking: Vec<(String, f64)>,
}

/// Result of a selection.
#[derive(Debug, Clone)]
pub struct Selection {
    /// The winning candidate.
    pub site: String,
    pub url: String,
    pub score: f64,
    /// All ranked survivors (best first), for k-choice policies.
    pub ranked: Vec<Ranked>,
    pub candidates: Vec<Candidate>,
    pub trace: BrokerTrace,
}

/// The decentralized storage broker. One per client; cheap to clone
/// (shared catalog + info service handles).
#[derive(Clone)]
pub struct Broker {
    catalog: Arc<Mutex<ReplicaCatalog>>,
    info: Arc<dyn InfoService>,
    policy: RankPolicy,
}

impl Broker {
    pub fn new(
        catalog: Arc<Mutex<ReplicaCatalog>>,
        info: Arc<dyn InfoService>,
        policy: RankPolicy,
    ) -> Broker {
        Broker { catalog, info, policy }
    }

    pub fn policy(&self) -> &RankPolicy {
        &self.policy
    }

    /// Build the "specialized LDAP search query" (paper §5.2) from the
    /// request ad: always fetch storage + bandwidth entries; the GRIS
    /// evaluates dynamic attributes at query time.
    fn search_filter(_request: &ClassAd) -> Filter {
        Filter::parse(
            "(|(objectClass=GridStorageServerVolume)\
              (objectClass=GridStorageTransferBandwidth)\
              (objectClass=GridStorageSourceTransferBandwidth))",
        )
        .unwrap()
    }

    /// **Search phase**: catalog lookup + GRIS fan-out.
    pub fn search(&self, logical: &str, request: &ClassAd) -> Result<(Vec<Candidate>, BrokerTrace)> {
        let mut trace = BrokerTrace { logical: logical.to_string(), ..Default::default() };
        let t0 = Instant::now();
        let locations: Vec<(String, String)> = {
            let cat = self.catalog.lock().unwrap();
            cat.locate(logical)?
                .iter()
                .map(|l| (l.site.clone(), l.url.clone()))
                .collect()
        };
        if locations.is_empty() {
            bail!("logical file {logical:?} has no replicas");
        }
        trace.replica_sites = locations.iter().map(|(s, _)| s.clone()).collect();
        let filter = Self::search_filter(request);
        let mut raw: Vec<(String, String, Vec<Entry>)> = Vec::with_capacity(locations.len());
        for (site, url) in &locations {
            // A site that fails to answer is simply not a candidate —
            // the decentralized broker degrades, it does not fail.
            match self.info.query_site(site, &filter) {
                Ok(entries) => raw.push((site.clone(), url.clone(), entries)),
                Err(_) => log::warn!("site {site} did not answer; skipping"),
            }
        }
        trace.search_us = t0.elapsed().as_micros();
        let t1 = Instant::now();
        let candidates = raw
            .iter()
            .map(|(site, url, entries)| entries_to_candidate(site, url, entries))
            .collect();
        trace.convert_us = t1.elapsed().as_micros();
        Ok((candidates, trace))
    }

    /// **Match phase** over pre-fetched candidates.
    pub fn match_phase(
        &self,
        request: &ClassAd,
        candidates: &[Candidate],
        trace: &mut BrokerTrace,
    ) -> Vec<Ranked> {
        let t0 = Instant::now();
        let matched: Vec<usize> = candidates
            .iter()
            .enumerate()
            .filter(|(_, c)| symmetric_match(request, &c.ad))
            .map(|(i, _)| i)
            .collect();
        trace.match_results = candidates
            .iter()
            .enumerate()
            .map(|(i, c)| (c.site.clone(), matched.contains(&i)))
            .collect();
        let ranked = self.policy.order(request, candidates, &matched);
        trace.ranking = ranked
            .iter()
            .map(|r| (candidates[r.index].site.clone(), r.score))
            .collect();
        trace.match_us = t0.elapsed().as_micros();
        ranked
    }

    /// Full selection: Search + Match. (The Access phase is executed by
    /// the caller against the returned site — see `gridftp::GridFtp` —
    /// because transfer execution lives with the simulation/driver.)
    pub fn select(&self, logical: &str, request: &ClassAd) -> Result<Selection> {
        let (candidates, mut trace) = self.search(logical, request)?;
        let ranked = self.match_phase(request, &candidates, &mut trace);
        let best = ranked
            .first()
            .cloned()
            .with_context(|| format!("no replica of {logical:?} satisfies the request"))?;
        Ok(Selection {
            site: candidates[best.index].site.clone(),
            url: candidates[best.index].url.clone(),
            score: best.score,
            ranked,
            candidates,
            trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::PhysicalLocation;
    use crate::classad::parse_classad;
    use crate::util::units::Bytes;

    /// Build a 3-site in-process grid with distinct capabilities.
    fn fixture(policy: RankPolicy) -> (Broker, ClassAd) {
        let mut catalog = ReplicaCatalog::new();
        catalog
            .create_logical("run42.dat", Bytes::from_gb(1.0), "cms")
            .unwrap();
        let mut info = LocalInfoService::new();
        let sites = [
            // (site, availGB, maxRD KB/s, history KB/s, load)
            ("anl-mcs", 50.0, 75.0, vec![40.0, 42.0, 41.0], 0.1),
            ("lbl-dsd", 80.0, 60.0, vec![55.0, 57.0, 58.0], 0.0),
            ("isi-grid", 3.0, 90.0, vec![80.0, 82.0, 81.0], 0.0),
        ];
        for (site, gb, rd, hist, load) in sites {
            catalog
                .add_replica(
                    "run42.dat",
                    PhysicalLocation { site: site.into(), url: format!("gsiftp://{site}/run42.dat") },
                )
                .unwrap();
            let mut gris = Gris::new("org", site);
            let base = gris.base_dn().clone();
            let vol = base.child("gss", "vol0");
            let mut e = Entry::new(vol.clone());
            e.add("objectClass", "GridStorageServerVolume");
            e.put_f64("totalSpace", 100.0 * 1024f64.powi(3));
            e.put_f64("availableSpace", gb * 1024f64.powi(3));
            e.put("mountPoint", "/data");
            e.put_f64("diskTransferRate", 2e7);
            e.put_f64("drdTime", 8.0);
            e.put_f64("dwrTime", 9.0);
            e.put_f64("load", load);
            gris.add_entry(e);
            let mut bw = Entry::new(vol.child("gss", "bw"));
            bw.add("objectClass", "GridStorageTransferBandwidth");
            for a in ["MaxRDBandwidth", "MinRDBandwidth", "AvgRDBandwidth"] {
                bw.put_f64(a, rd * 1024.0);
            }
            for a in ["MaxWRBandwidth", "MinWRBandwidth", "AvgWRBandwidth"] {
                bw.put_f64(a, rd * 512.0);
            }
            gris.add_entry(bw);
            let mut src = Entry::new(vol.child("gss", "src"));
            src.add("objectClass", "GridStorageSourceTransferBandwidth");
            src.put_f64("lastRDBandwidth", hist.last().unwrap() * 1024.0);
            src.put("lastRDurl", "gsiftp://client/");
            src.put_f64("lastWRBandwidth", 0.0);
            src.put("lastWRurl", "gsiftp://client/");
            src.put(
                "rdHistory",
                hist.iter()
                    .map(|h| format!("{}", h * 1024.0))
                    .collect::<Vec<_>>()
                    .join(","),
            );
            gris.add_entry(src);
            info.add(site, Arc::new(RwLock::new(gris)));
        }
        let request = parse_classad(
            r#"hostname = "comet.xyz.com";
               reqdSpace = 5G;
               reqdRDBandwidth = 50K/Sec;
               rank = other.availableSpace;
               requirement = other.availableSpace > 5G
                   && other.MaxRDBandwidth > 50K/Sec;"#,
        )
        .unwrap();
        (
            Broker::new(Arc::new(Mutex::new(catalog)), Arc::new(info), policy),
            request,
        )
    }

    #[test]
    fn classad_rank_selects_most_space() {
        let (broker, request) = fixture(RankPolicy::ClassAdRank);
        let sel = broker.select("run42.dat", &request).unwrap();
        // isi-grid fails the space requirement; lbl-dsd has most space.
        assert_eq!(sel.site, "lbl-dsd");
        assert_eq!(sel.trace.replica_sites.len(), 3);
        let matched: Vec<bool> = sel.trace.match_results.iter().map(|(_, m)| *m).collect();
        assert_eq!(matched, vec![true, true, false]);
        assert_eq!(sel.ranked.len(), 2);
    }

    #[test]
    fn forecast_rank_selects_fastest_feasible() {
        let (broker, request) = fixture(RankPolicy::ForecastBandwidth { engine: None });
        let sel = broker.select("run42.dat", &request).unwrap();
        // isi is fastest but infeasible (3G < 5G); lbl (≈57K) beats
        // anl (≈41K, loaded).
        assert_eq!(sel.site, "lbl-dsd");
        assert!(sel.score > 50.0 * 1024.0);
    }

    #[test]
    fn unknown_logical_file_errors() {
        let (broker, request) = fixture(RankPolicy::ClassAdRank);
        assert!(broker.select("nope.dat", &request).is_err());
    }

    #[test]
    fn no_feasible_replica_errors() {
        let (broker, _) = fixture(RankPolicy::ClassAdRank);
        let greedy = parse_classad(
            "reqdSpace = 1G; requirement = other.availableSpace > 500G;",
        )
        .unwrap();
        let err = broker.select("run42.dat", &greedy).unwrap_err();
        assert!(format!("{err:#}").contains("satisfies"));
    }

    #[test]
    fn trace_phases_populated() {
        let (broker, request) = fixture(RankPolicy::ClassAdRank);
        let sel = broker.select("run42.dat", &request).unwrap();
        assert_eq!(sel.trace.logical, "run42.dat");
        assert_eq!(sel.trace.ranking.first().unwrap().0, "lbl-dsd");
        // Timings are measured (may be 0µs on fast machines but the
        // fields exist and ranking is consistent with `ranked`).
        assert_eq!(sel.trace.ranking.len(), sel.ranked.len());
    }

    #[test]
    fn missing_site_degrades_gracefully() {
        let (broker, request) = fixture(RankPolicy::ClassAdRank);
        {
            let cat = broker.catalog.clone();
            let mut cat = cat.lock().unwrap();
            cat.add_replica(
                "run42.dat",
                PhysicalLocation { site: "ghost".into(), url: "gsiftp://ghost/f".into() },
            )
            .unwrap();
        }
        // ghost has no GRIS: selection still succeeds on the others.
        let sel = broker.select("run42.dat", &request).unwrap();
        assert_eq!(sel.site, "lbl-dsd");
        assert_eq!(sel.candidates.len(), 3);
    }
}
