//! The storage broker — the paper's contribution (§5).
//!
//! Decentralized: *every client runs its own broker* (§5.1.1); there is
//! no central matchmaker. A selection runs three phases (§5.1.2):
//!
//! 1. **Search** — replica-catalog lookup for the logical file, then an
//!    LDAP query to each replica site's GRIS built from the request's
//!    constraints ("specialized LDAP search queries").
//! 2. **Match** — LDIF → ClassAd conversion ([`convert`], the paper §6
//!    "primitive libraries"), Condor matchmaking of the request ad
//!    against every storage ad, rank ordering of survivors. On the
//!    prepared/batch path the request runs as compiled bytecode
//!    ([`crate::classad::program`]) down a struct-of-arrays
//!    [`crate::classad::CandidateTable`] rebuilt per batch in the
//!    reusable [`SelectScratch`] — one linear pass, no per-candidate
//!    allocation, bit-identical to the tree-walking reference
//!    evaluator.
//! 3. **Access** — fetch through GridFTP; instrumentation feeds the
//!    history that powers the next selection.
//!
//! Ranking policies ([`policy`]): the paper's §5.2 `rank =
//! other.availableSpace` ClassAd rank, and the §3.2 history heuristic —
//! predicted bandwidth (NWS-style bank, PJRT-accelerated when artifacts
//! are built) discounted by current load. [`selectors`] adds the
//! uninformed baselines the benches compare against; [`centralized`]
//! the single-manager comparator for the §5.1.1 scalability argument.
//!
//! At production scale the control plane shards along the PR 5
//! registration hierarchy ([`shard`], ISSUE 8): each broker shard owns
//! a contiguous slice of sites with its own GIIS registration domain
//! and admission batch, requests route to the shard owning the
//! plurality of their replicas, and only replica sets that span shards
//! pay a cross-shard consult. A 1-shard configuration is bit-identical
//! to the unsharded path (`it_shard` parity anchors); see
//! `ARCHITECTURE.md` for the shard boundary.

pub mod centralized;
pub mod convert;
pub mod economy;
pub mod engine;
pub mod policy;
pub mod replication;
pub mod selectors;
pub mod shard;

pub use convert::{entries_to_candidate, Candidate};
pub use economy::{Economy, EconomyAction, EconomyOptions, EconomyStats};
pub use engine::{
    parse_request_ad, parse_request_ad_with_budget, AccessStrategy, Broker, BrokerTrace,
    CoallocSelection, HierDiscovery, InfoService, LocalInfoService, PreparedRequest,
    RemoteInfoService, SelectScratch, REQUEST_AD_NAME_BUDGET,
};
pub use policy::RankPolicy;
pub use selectors::{Selector, SelectorKind};
pub use shard::ShardMap;
