//! LDIF/entry → ClassAd conversion — the paper's §6 "primitive
//! libraries to achieve the conversion of this attribute set".
//!
//! A site's GRIS answers a broker query with several entries (the
//! Figure-2 volume entry, the Figure-4 bandwidth summary, the Figure-5
//! per-source record). [`entries_to_candidate`] folds them into one
//! storage ClassAd: numeric strings become numbers, multi-valued
//! attributes become lists, and a published `requirements` string is
//! *parsed as a ClassAd expression* so site usage policies survive the
//! trip (paper §3.1).

use crate::classad::{parse_expr, ClassAd, Expr, Value};
use crate::directory::entry::Entry;

/// A selection candidate: one replica site's converted capability ad
/// plus the side-band data the forecast policy needs.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub site: String,
    pub url: String,
    pub ad: ClassAd,
    /// Per-source trailing bandwidth window (oldest → newest), from the
    /// Figure-5 `rdHistory` attribute.
    pub history: Vec<f64>,
    /// Current utilization [0,1] from the GRIS dynamic `load` attribute.
    pub load: f64,
}

/// Convert one attribute value: numbers become `Real`, everything else
/// a string. (LDAP `cisfloat` attributes are numeric strings.)
fn convert_value(v: &str) -> Value {
    match v.trim().parse::<f64>() {
        Ok(n) => Value::Real(n),
        Err(_) => Value::Str(v.to_string()),
    }
}

/// Fold one entry's attributes into the ad.
fn fold_entry(ad: &mut ClassAd, entry: &Entry) {
    for (name, values) in entry.iter() {
        let lower = name.to_ascii_lowercase();
        if lower == "objectclass" || lower == "rdhistory" {
            continue;
        }
        if lower == "requirements" || lower == "requirement" {
            // Site usage policy: parse as a ClassAd expression.
            if let Some(first) = values.first() {
                if let Ok(e) = parse_expr(first) {
                    ad.set(name, e);
                }
            }
            continue;
        }
        match values {
            [] => {}
            [single] => ad.set(name, Expr::Lit(convert_value(single))),
            many => ad.set(
                name,
                Expr::Lit(Value::List(many.iter().map(|v| convert_value(v)).collect())),
            ),
        }
    }
}

/// Parse the Figure-5 `rdHistory` attribute (comma-separated floats).
fn parse_history(entry: &Entry) -> Vec<f64> {
    entry
        .get("rdHistory")
        .map(|vals| {
            vals.iter()
                .flat_map(|v| v.split(','))
                .filter_map(|s| s.trim().parse::<f64>().ok())
                .collect()
        })
        .unwrap_or_default()
}

/// Build a [`Candidate`] from everything one site's GRIS returned.
pub fn entries_to_candidate(site: &str, url: &str, entries: &[Entry]) -> Candidate {
    let mut ad = ClassAd::new();
    ad.set_value("hostname", Value::Str(site.to_string()));
    let mut history = Vec::new();
    let mut load = 0.0;
    for e in entries {
        fold_entry(&mut ad, e);
        let h = parse_history(e);
        if !h.is_empty() {
            history = h;
        }
        if let Some(l) = e.f64("load") {
            load = l.clamp(0.0, 1.0);
        }
    }
    Candidate { site: site.to_string(), url: url.to_string(), ad, history, load }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classad::{eval_in_match, parse_classad, symmetric_match};
    use crate::directory::entry::Dn;
    use crate::directory::ldif::parse_ldif;

    fn volume_ldif() -> String {
        "dn: gss=vol0, ou=mcs, o=anl, o=grid\n\
         objectClass: GridStorageServerVolume\n\
         availableSpace: 53687091200\n\
         totalSpace: 107374182400\n\
         mountPoint: /dev/sandbox\n\
         diskTransferRate: 20971520\n\
         drdTime: 8.5\n\
         dwrTime: 9.5\n\
         load: 0.25\n\
         filesystem: ext3\n\
         filesystem: xfs\n\
         requirements: other.reqdSpace < 10G && other.reqdRDBandwidth < 75K/Sec\n\
         \n\
         dn: gss=bw, gss=vol0, ou=mcs, o=anl, o=grid\n\
         objectClass: GridStorageTransferBandwidth\n\
         MaxRDBandwidth: 76800\n\
         MinRDBandwidth: 10240\n\
         AvgRDBandwidth: 40960\n\
         MaxWRBandwidth: 76800\n\
         MinWRBandwidth: 10240\n\
         AvgWRBandwidth: 30720\n\
         \n\
         dn: gss=src, gss=vol0, ou=mcs, o=anl, o=grid\n\
         objectClass: GridStorageSourceTransferBandwidth\n\
         lastRDBandwidth: 51200\n\
         lastRDurl: gsiftp://comet.xyz.com/\n\
         lastWRBandwidth: 20480\n\
         lastWRurl: gsiftp://comet.xyz.com/\n\
         rdHistory: 30720,40960,51200\n"
            .to_string()
    }

    #[test]
    fn converts_full_site_response() {
        let entries = parse_ldif(&volume_ldif()).unwrap();
        let c = entries_to_candidate("anl-mcs", "gsiftp://anl/f", &entries);
        assert_eq!(c.ad.number("availableSpace").unwrap(), 53687091200.0);
        assert_eq!(c.ad.number("MaxRDBandwidth").unwrap(), 76800.0);
        assert_eq!(c.ad.number("lastRDBandwidth").unwrap(), 51200.0);
        assert_eq!(c.ad.string("mountPoint").unwrap(), "/dev/sandbox");
        assert_eq!(c.history, vec![30720.0, 40960.0, 51200.0]);
        assert!((c.load - 0.25).abs() < 1e-12);
    }

    #[test]
    fn converted_ad_matches_paper_request() {
        // End-to-end §6 claim: LDIF → ClassAd conversion feeds straight
        // into Condor matchmaking.
        let entries = parse_ldif(&volume_ldif()).unwrap();
        let c = entries_to_candidate("anl-mcs", "u", &entries);
        let request = parse_classad(
            r#"hostname = "comet.xyz.com";
               reqdSpace = 5G;
               reqdRDBandwidth = 50K/Sec;
               rank = other.availableSpace;
               requirement = other.availableSpace > 5G
                   && other.MaxRDBandwidth > 50K/Sec;"#,
        )
        .unwrap();
        assert!(symmetric_match(&request, &c.ad));
        let rank = eval_in_match(&request, &c.ad, "rank");
        assert_eq!(rank.as_number().unwrap(), 53687091200.0);
    }

    #[test]
    fn usage_policy_survives_conversion() {
        let entries = parse_ldif(&volume_ldif()).unwrap();
        let c = entries_to_candidate("anl-mcs", "u", &entries);
        // A greedy request violates the *converted* site policy.
        let greedy = parse_classad(
            r#"reqdSpace = 20G; reqdRDBandwidth = 50K/Sec;
               requirement = other.availableSpace > 1G;"#,
        )
        .unwrap();
        assert!(!symmetric_match(&greedy, &c.ad));
    }

    #[test]
    fn multi_valued_becomes_list() {
        let entries = parse_ldif(&volume_ldif()).unwrap();
        let c = entries_to_candidate("anl-mcs", "u", &entries);
        // The request must satisfy the site's usage policy too (it
        // references reqdSpace / reqdRDBandwidth).
        let req = parse_classad(
            r#"reqdSpace = 1G; reqdRDBandwidth = 10K/Sec;
               requirement = member("xfs", other.filesystem);"#,
        )
        .unwrap();
        assert!(symmetric_match(&req, &c.ad));
    }

    #[test]
    fn empty_entries_still_have_hostname() {
        let c = entries_to_candidate("site-x", "u", &[]);
        assert_eq!(c.ad.string("hostname").unwrap(), "site-x");
        assert!(c.history.is_empty());
    }

    #[test]
    fn malformed_history_values_skipped() {
        let mut e = Entry::new(Dn::parse("o=grid").unwrap());
        e.add("rdHistory", "10,notanumber,30");
        let c = entries_to_candidate("s", "u", &[e]);
        assert_eq!(c.history, vec![10.0, 30.0]);
    }
}
