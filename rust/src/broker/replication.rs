//! Replica management — the other Figure-1 higher-level service.
//!
//! "Replica management is the process of creating or deleting replicas
//! at a storage site ... to harness certain performance benefits"
//! (paper §2.2). The manager reuses the broker machinery in the *write*
//! direction: destination sites are matched against a placement ad
//! (space floor + site policy) and ranked by available space or
//! write-bandwidth history, the replica is stored via GridFTP, and the
//! catalog is updated atomically with the transfer outcome.

use anyhow::{bail, Context, Result};

use crate::catalog::PhysicalLocation;
use crate::classad::{symmetric_match, AdBuilder, ClassAd};
use crate::experiment::SimGrid;

/// Destination-ranking policy for new replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Max published `availableSpace` (balances storage).
    MostSpace,
    /// Max `AvgWRBandwidth` (fastest creation).
    FastestWrite,
}

/// Outcome of a replica creation.
#[derive(Debug, Clone)]
pub struct ReplicationOutcome {
    pub logical: String,
    pub site: String,
    pub duration: f64,
    pub bandwidth: f64,
}

/// The replica manager, operating over a [`SimGrid`] (the in-process
/// deployment; a networked variant would swap the info/ftp handles).
pub struct ReplicaManager<'g> {
    grid: &'g mut SimGrid,
    policy: PlacementPolicy,
}

impl<'g> ReplicaManager<'g> {
    pub fn new(grid: &'g mut SimGrid, policy: PlacementPolicy) -> Self {
        ReplicaManager { grid, policy }
    }

    /// The placement request ad for a file of `bytes`.
    fn placement_ad(bytes: f64, policy: PlacementPolicy) -> ClassAd {
        let rank_attr = match policy {
            PlacementPolicy::MostSpace => "other.availableSpace",
            PlacementPolicy::FastestWrite => "other.AvgWRBandwidth",
        };
        AdBuilder::new()
            .str("hostname", "replica-manager")
            .bytes("reqdSpace", bytes)
            .rate("reqdRDBandwidth", 0.0)
            .expr("rank", rank_attr)
            .expr("requirement", "other.availableSpace > reqdSpace")
            .build()
    }

    /// Create a new replica of `logical` at the best non-holding site.
    pub fn create_replica(&mut self, logical: &str) -> Result<ReplicationOutcome> {
        let f = self
            .grid
            .files
            .iter()
            .position(|n| n == logical)
            .with_context(|| format!("unknown logical file {logical:?}"))?;
        let bytes = self.grid.sizes[f];
        let holders: Vec<String> = {
            let cat = self.grid.catalog.lock().unwrap();
            cat.locate(logical)?.iter().map(|l| l.site.clone()).collect()
        };
        let request = Self::placement_ad(bytes, self.policy);

        // Candidate destinations: every site that does NOT hold a
        // replica, viewed through its GRIS (live attributes).
        self.grid.publish_dynamics();
        let mut best: Option<(usize, f64)> = None;
        for i in 0..self.grid.topo.len() {
            let site = self.grid.topo.site(i).cfg.name.clone();
            if holders.contains(&site) {
                continue;
            }
            let entries = self
                .grid
                .info
                .query_site_all(&site)
                .unwrap_or_default();
            let cand = super::convert::entries_to_candidate(&site, "", &entries);
            if !symmetric_match(&request, &cand.ad) {
                continue;
            }
            let score = crate::classad::eval_in_match(&request, &cand.ad, "rank")
                .as_number()
                .unwrap_or(0.0);
            if best.map(|(_, s)| score > s).unwrap_or(true) {
                best = Some((i, score));
            }
        }
        let (dest, _) = best.with_context(|| {
            format!("no eligible destination for a new replica of {logical:?}")
        })?;

        // Write through GridFTP (instrumented), then commit to catalog.
        let out = self
            .grid
            .ftp
            .store(&mut self.grid.topo, dest, "replica-manager", bytes);
        let site_name = self.grid.topo.site(dest).cfg.name.clone();
        {
            let mut cat = self.grid.catalog.lock().unwrap();
            cat.add_replica(
                logical,
                PhysicalLocation {
                    site: site_name.clone(),
                    url: format!("gsiftp://{site_name}/{logical}"),
                },
            )?;
        }
        self.grid.placement[f].push(dest);
        self.grid.publish_dynamics();
        Ok(ReplicationOutcome {
            logical: logical.to_string(),
            site: site_name,
            duration: out.duration,
            bandwidth: out.bandwidth,
        })
    }

    /// Delete the replica of `logical` at `site`, reclaiming space.
    pub fn delete_replica(&mut self, logical: &str, site: &str) -> Result<()> {
        let f = self
            .grid
            .files
            .iter()
            .position(|n| n == logical)
            .with_context(|| format!("unknown logical file {logical:?}"))?;
        let remaining = {
            let cat = self.grid.catalog.lock().unwrap();
            cat.locate(logical)?.len()
        };
        if remaining <= 1 {
            bail!("refusing to delete the last replica of {logical:?}");
        }
        {
            let mut cat = self.grid.catalog.lock().unwrap();
            cat.remove_replica(logical, site)?;
        }
        if let Some(idx) = self.grid.topo.index_of(site) {
            self.grid.topo.consume_space(idx, -self.grid.sizes[f]);
            self.grid.placement[f].retain(|&s| s != idx);
        }
        self.grid.publish_dynamics();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GridConfig;
    use crate::simnet::WorkloadSpec;

    fn grid() -> SimGrid {
        let cfg = GridConfig::generate(6, 88);
        let spec = WorkloadSpec { files: 4, ..Default::default() };
        let mut g = SimGrid::build(&cfg, &spec, 2, 16);
        g.warm(3);
        g
    }

    #[test]
    fn create_replica_adds_catalog_entry_on_non_holder() {
        let mut g = grid();
        let logical = g.files[0].clone();
        let before: Vec<String> = {
            let cat = g.catalog.lock().unwrap();
            cat.locate(&logical).unwrap().iter().map(|l| l.site.clone()).collect()
        };
        let out = ReplicaManager::new(&mut g, PlacementPolicy::MostSpace)
            .create_replica(&logical)
            .expect("replication");
        assert!(!before.contains(&out.site), "must pick a non-holder");
        let cat = g.catalog.lock().unwrap();
        assert_eq!(cat.locate(&logical).unwrap().len(), before.len() + 1);
        assert!(out.duration > 0.0);
    }

    #[test]
    fn create_consumes_destination_space() {
        let mut g = grid();
        let logical = g.files[1].clone();
        let out = ReplicaManager::new(&mut g, PlacementPolicy::MostSpace)
            .create_replica(&logical)
            .unwrap();
        let idx = g.topo.index_of(&out.site).unwrap();
        let f = g.files.iter().position(|n| *n == logical).unwrap();
        // GRIS now publishes the reduced space.
        let d = g.dynamics[idx].read().unwrap();
        assert!(d.available_space <= g.topo.site(idx).cfg.total_space - g.sizes[f] * 0.0 + 1.0);
        assert!(g.placement[f].contains(&idx));
    }

    #[test]
    fn write_transfer_is_instrumented() {
        let mut g = grid();
        let logical = g.files[2].clone();
        let out = ReplicaManager::new(&mut g, PlacementPolicy::FastestWrite)
            .create_replica(&logical)
            .unwrap();
        let idx = g.topo.index_of(&out.site).unwrap();
        let h = g.ftp.history(idx);
        assert!(h.read().unwrap().wr.count >= 1);
    }

    #[test]
    fn delete_respects_last_replica_guard() {
        let mut g = grid();
        let logical = g.files[3].clone();
        let sites: Vec<String> = {
            let cat = g.catalog.lock().unwrap();
            cat.locate(&logical).unwrap().iter().map(|l| l.site.clone()).collect()
        };
        let mut mgr = ReplicaManager::new(&mut g, PlacementPolicy::MostSpace);
        mgr.delete_replica(&logical, &sites[0]).unwrap();
        let err = mgr.delete_replica(&logical, &sites[1]).unwrap_err();
        assert!(format!("{err:#}").contains("last replica"));
    }

    #[test]
    fn unknown_file_errors() {
        let mut g = grid();
        let err = ReplicaManager::new(&mut g, PlacementPolicy::MostSpace)
            .create_replica("nope.dat")
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown logical file"));
    }
}
