//! Replica management — the other Figure-1 higher-level service.
//!
//! "Replica management is the process of creating or deleting replicas
//! at a storage site ... to harness certain performance benefits"
//! (paper §2.2). The manager reuses the broker machinery in the *write*
//! direction: destination sites are matched against a placement ad
//! (space floor + site policy) and ranked by available space or
//! write-bandwidth history, the replica is stored via GridFTP, and the
//! catalog is updated atomically with the transfer outcome.
//!
//! Creation dispatches on [`AccessStrategy`]: `SingleBest` stores one
//! copy at the top-ranked destination (the paper's behaviour);
//! `Coallocated` runs the **striped `store()`**
//! ([`crate::coalloc::execute_store`]) — one full copy pushed to each
//! of the top-K destinations in parallel, every copy that lands
//! registered in the catalog, destinations lost mid-push dropped
//! without failing the surviving copies.

use anyhow::{bail, Context, Result};

use crate::catalog::PhysicalLocation;
use crate::classad::{AdBuilder, ClassAd, CompiledMatch, VmScratch};
use crate::coalloc::{execute_store, StoreTarget};
use crate::config::CoallocPolicy;
use crate::experiment::SimGrid;

use super::AccessStrategy;

/// Destination-ranking policy for new replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Max published `availableSpace` (balances storage).
    MostSpace,
    /// Max `AvgWRBandwidth` (fastest creation).
    FastestWrite,
}

/// Outcome of a replica creation.
#[derive(Debug, Clone)]
pub struct ReplicationOutcome {
    pub logical: String,
    pub site: String,
    pub duration: f64,
    pub bandwidth: f64,
}

/// The replica manager, operating over a [`SimGrid`] (the in-process
/// deployment; a networked variant would swap the info/ftp handles).
pub struct ReplicaManager<'g> {
    grid: &'g mut SimGrid,
    policy: PlacementPolicy,
}

impl<'g> ReplicaManager<'g> {
    pub fn new(grid: &'g mut SimGrid, policy: PlacementPolicy) -> Self {
        ReplicaManager { grid, policy }
    }

    /// The placement request ad for a file of `bytes`. Public so the
    /// parity suite (`it_match_parity`) can pin tree-vs-VM agreement
    /// for placement matching, not just the Match phase's request ads.
    pub fn placement_ad(bytes: f64, policy: PlacementPolicy) -> ClassAd {
        let rank_attr = match policy {
            PlacementPolicy::MostSpace => "other.availableSpace",
            PlacementPolicy::FastestWrite => "other.AvgWRBandwidth",
        };
        AdBuilder::new()
            .str("hostname", "replica-manager")
            .bytes("reqdSpace", bytes)
            .rate("reqdRDBandwidth", 0.0)
            .expr("rank", rank_attr)
            .expr("requirement", "other.availableSpace > reqdSpace")
            .build()
    }

    /// Ranked candidate destinations for a new replica of `logical`
    /// sized `bytes`: every non-holding site whose GRIS view matches
    /// the placement ad, best placement rank first. The placement ad
    /// is compiled once per call ([`CompiledMatch`]) and every site
    /// runs the bytecode VM — the same compile-once/match-many route
    /// the Match phase takes, bit-identical to the per-pair tree
    /// evaluators (pinned in `it_match_parity`).
    fn rank_destinations(&self, logical: &str, bytes: f64) -> Result<Vec<(usize, f64)>> {
        let holders: Vec<String> = {
            let cat = self.grid.catalog.lock().unwrap();
            cat.locate(logical)?.iter().map(|l| l.site.clone()).collect()
        };
        let compiled = CompiledMatch::compile(&Self::placement_ad(bytes, self.policy));
        let mut vm = VmScratch::default();
        self.grid.publish_dynamics();
        let mut ranked: Vec<(usize, f64)> = Vec::new();
        for i in 0..self.grid.topo.len() {
            let site = self.grid.topo.site(i).cfg.name.clone();
            if holders.contains(&site) {
                continue;
            }
            // A dead server cannot receive a copy (control channel
            // down) — don't even rank it.
            if !self.grid.topo.site_alive(i) {
                continue;
            }
            let entries = self
                .grid
                .info
                .query_site_all(&site)
                .unwrap_or_default();
            let cand = super::convert::entries_to_candidate(&site, "", &entries);
            if !compiled.matches_vm(&cand.ad, &mut vm) {
                continue;
            }
            ranked.push((i, compiled.rank_vm(&cand.ad, &mut vm)));
        }
        // Best first; ties keep topology order (deterministic).
        ranked.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.0.cmp(&b.0))
        });
        Ok(ranked)
    }

    /// Create a new replica of `logical` at the best non-holding site.
    pub fn create_replica(&mut self, logical: &str) -> Result<ReplicationOutcome> {
        let f = self
            .grid
            .files
            .iter()
            .position(|n| n == logical)
            .with_context(|| format!("unknown logical file {logical:?}"))?;
        let bytes = self.grid.sizes[f];
        let (dest, _) = self
            .rank_destinations(logical, bytes)?
            .into_iter()
            .next()
            .with_context(|| {
                format!("no eligible destination for a new replica of {logical:?}")
            })?;

        // Write through GridFTP (instrumented), then commit to catalog.
        let out = self
            .grid
            .ftp
            .store(&mut self.grid.topo, dest, "replica-manager", bytes);
        let site_name = self.grid.topo.site(dest).cfg.name.clone();
        if !out.duration.is_finite() {
            // The destination died under the store (ranked while alive,
            // gone by write time): never register a phantom replica.
            bail!("destination {site_name} died during the store of {logical:?}");
        }
        {
            let mut cat = self.grid.catalog.lock().unwrap();
            cat.add_replica(
                logical,
                PhysicalLocation {
                    site: site_name.clone(),
                    url: format!("gsiftp://{site_name}/{logical}"),
                },
            )?;
        }
        self.grid.placement[f].push(dest);
        self.grid.space_ledger.insert((f, dest), out.applied);
        self.grid.publish_dynamics();
        Ok(ReplicationOutcome {
            logical: logical.to_string(),
            site: site_name,
            duration: out.duration,
            bandwidth: out.bandwidth,
        })
    }

    /// Create replicas of `logical` under `strategy`:
    /// [`AccessStrategy::SingleBest`] stores one copy at the top-ranked
    /// destination; [`AccessStrategy::Coallocated`] pushes one copy to
    /// each of the top `max_streams` destinations in parallel (the
    /// striped `store()`), registering every copy that lands in the
    /// catalog. Errors when no destination is eligible or no copy
    /// survives the push.
    pub fn create_replicas(
        &mut self,
        logical: &str,
        strategy: &AccessStrategy,
    ) -> Result<Vec<ReplicationOutcome>> {
        match strategy {
            AccessStrategy::SingleBest => Ok(vec![self.create_replica(logical)?]),
            AccessStrategy::Coallocated(policy) => {
                self.create_replicas_striped(logical, policy)
            }
        }
    }

    fn create_replicas_striped(
        &mut self,
        logical: &str,
        policy: &CoallocPolicy,
    ) -> Result<Vec<ReplicationOutcome>> {
        let f = self
            .grid
            .files
            .iter()
            .position(|n| n == logical)
            .with_context(|| format!("unknown logical file {logical:?}"))?;
        let bytes = self.grid.sizes[f];
        let ranked = self.rank_destinations(logical, bytes)?;
        if ranked.is_empty() {
            bail!("no eligible destination for a new replica of {logical:?}");
        }
        let targets: Vec<StoreTarget> = ranked
            .iter()
            .take(policy.max_streams.max(1))
            .map(|&(i, _)| {
                let site = self.grid.topo.site(i).cfg.name.clone();
                StoreTarget { url: format!("gsiftp://{site}/{logical}"), site }
            })
            .collect();
        let out = execute_store(
            &mut self.grid.topo,
            &self.grid.ftp,
            "replica-manager",
            &targets,
            bytes,
            policy,
        )?;
        // Commit the copies that landed; lost destinations are simply
        // not registered (the catalog never names a partial replica).
        let mut created = Vec::new();
        for r in out.reports.iter().filter(|r| r.completed) {
            {
                let mut cat = self.grid.catalog.lock().unwrap();
                cat.add_replica(
                    logical,
                    PhysicalLocation { site: r.site.clone(), url: r.url.clone() },
                )?;
            }
            self.grid.placement[f].push(r.site_index);
            self.grid.space_ledger.insert((f, r.site_index), r.applied);
            created.push(ReplicationOutcome {
                logical: logical.to_string(),
                site: r.site.clone(),
                duration: r.duration,
                bandwidth: r.mean_bandwidth,
            });
        }
        if created.is_empty() {
            bail!("striped store of {logical:?} failed at every destination");
        }
        self.grid.publish_dynamics();
        Ok(created)
    }

    /// Delete the replica of `logical` at `site`, reclaiming **exactly
    /// the space its creation consumed**: the grid's space ledger holds
    /// the applied delta the create's `consume_space` reported (a store
    /// into a nearly-full volume commits less than the file size), so a
    /// create→delete round-trip conserves `used` bit-for-bit. Seed
    /// replicas placed at build time are unledgered — they reclaim the
    /// file size, clamped at zero by the repaired topology invariant.
    pub fn delete_replica(&mut self, logical: &str, site: &str) -> Result<()> {
        let f = self
            .grid
            .files
            .iter()
            .position(|n| n == logical)
            .with_context(|| format!("unknown logical file {logical:?}"))?;
        let remaining = {
            let cat = self.grid.catalog.lock().unwrap();
            cat.locate(logical)?.len()
        };
        if remaining <= 1 {
            bail!("refusing to delete the last replica of {logical:?}");
        }
        {
            let mut cat = self.grid.catalog.lock().unwrap();
            cat.remove_replica(logical, site)?;
        }
        if let Some(idx) = self.grid.topo.index_of(site) {
            let owed = self
                .grid
                .space_ledger
                .remove(&(f, idx))
                .unwrap_or(self.grid.sizes[f]);
            self.grid.topo.consume_space(idx, -owed);
            self.grid.placement[f].retain(|&s| s != idx);
        }
        self.grid.publish_dynamics();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GridConfig;
    use crate::simnet::WorkloadSpec;

    fn grid() -> SimGrid {
        let cfg = GridConfig::generate(6, 88);
        let spec = WorkloadSpec { files: 4, ..Default::default() };
        let mut g = SimGrid::build(&cfg, &spec, 2, 16);
        g.warm(3);
        g
    }

    #[test]
    fn create_replica_adds_catalog_entry_on_non_holder() {
        let mut g = grid();
        let logical = g.files[0].clone();
        let before: Vec<String> = {
            let cat = g.catalog.lock().unwrap();
            cat.locate(&logical).unwrap().iter().map(|l| l.site.clone()).collect()
        };
        let out = ReplicaManager::new(&mut g, PlacementPolicy::MostSpace)
            .create_replica(&logical)
            .expect("replication");
        assert!(!before.contains(&out.site), "must pick a non-holder");
        let cat = g.catalog.lock().unwrap();
        assert_eq!(cat.locate(&logical).unwrap().len(), before.len() + 1);
        assert!(out.duration > 0.0);
    }

    #[test]
    fn create_consumes_destination_space() {
        let mut g = grid();
        let logical = g.files[1].clone();
        let out = ReplicaManager::new(&mut g, PlacementPolicy::MostSpace)
            .create_replica(&logical)
            .unwrap();
        let idx = g.topo.index_of(&out.site).unwrap();
        let f = g.files.iter().position(|n| *n == logical).unwrap();
        // GRIS now publishes the reduced space.
        let d = g.dynamics[idx].read().unwrap();
        assert!(d.available_space <= g.topo.site(idx).cfg.total_space - g.sizes[f] * 0.0 + 1.0);
        assert!(g.placement[f].contains(&idx));
    }

    #[test]
    fn write_transfer_is_instrumented() {
        let mut g = grid();
        let logical = g.files[2].clone();
        let out = ReplicaManager::new(&mut g, PlacementPolicy::FastestWrite)
            .create_replica(&logical)
            .unwrap();
        let idx = g.topo.index_of(&out.site).unwrap();
        let h = g.ftp.history(idx);
        assert!(h.read().unwrap().wr.count >= 1);
    }

    #[test]
    fn striped_store_registers_every_landed_copy() {
        let mut g = grid();
        let logical = g.files[0].clone();
        let before: Vec<String> = {
            let cat = g.catalog.lock().unwrap();
            cat.locate(&logical).unwrap().iter().map(|l| l.site.clone()).collect()
        };
        let policy = CoallocPolicy { max_streams: 2, ..Default::default() };
        let outs = ReplicaManager::new(&mut g, PlacementPolicy::MostSpace)
            .create_replicas(&logical, &AccessStrategy::Coallocated(policy))
            .expect("striped replication");
        assert_eq!(outs.len(), 2, "both destinations should land");
        for out in &outs {
            assert!(!before.contains(&out.site), "must pick non-holders");
            assert!(out.bandwidth > 0.0);
        }
        let f = g.files.iter().position(|n| *n == logical).unwrap();
        let cat = g.catalog.lock().unwrap();
        assert_eq!(cat.locate(&logical).unwrap().len(), before.len() + 2);
        for out in &outs {
            let idx = g.topo.index_of(&out.site).unwrap();
            assert!(g.placement[f].contains(&idx));
            // Write instrumentation reached the destination history.
            assert!(g.ftp.history(idx).read().unwrap().wr.count >= 1);
        }
    }

    #[test]
    fn striped_store_drops_a_dying_destination() {
        use crate::simnet::FaultKind;
        let mut g = grid();
        let logical = g.files[0].clone();
        let bytes = g.sizes[0];
        let policy = CoallocPolicy { max_streams: 2, ..Default::default() };
        // Find the two destinations the manager will pick and kill the
        // best one the moment bytes start moving.
        let mgr = ReplicaManager::new(&mut g, PlacementPolicy::MostSpace);
        let ranked = mgr.rank_destinations(&logical, bytes).unwrap();
        assert!(ranked.len() >= 2);
        let doomed = ranked[0].0;
        g.topo.schedule_fault(doomed, g.topo.now + 1.0, FaultKind::ReplicaDeath);
        let doomed_name = g.topo.site(doomed).cfg.name.clone();
        let outs = ReplicaManager::new(&mut g, PlacementPolicy::MostSpace)
            .create_replicas(&logical, &AccessStrategy::Coallocated(policy))
            .expect("surviving copy");
        assert_eq!(outs.len(), 1);
        assert_ne!(outs[0].site, doomed_name);
        // The dead destination was not registered.
        let cat = g.catalog.lock().unwrap();
        assert!(cat
            .locate(&logical)
            .unwrap()
            .iter()
            .all(|l| l.site != doomed_name));
    }

    #[test]
    fn single_best_strategy_matches_create_replica() {
        let mut g = grid();
        let logical = g.files[1].clone();
        let outs = ReplicaManager::new(&mut g, PlacementPolicy::FastestWrite)
            .create_replicas(&logical, &AccessStrategy::SingleBest)
            .unwrap();
        assert_eq!(outs.len(), 1);
        let cat = g.catalog.lock().unwrap();
        assert!(cat
            .locate(&logical)
            .unwrap()
            .iter()
            .any(|l| l.site == outs[0].site));
    }

    #[test]
    fn delete_respects_last_replica_guard() {
        let mut g = grid();
        let logical = g.files[3].clone();
        let sites: Vec<String> = {
            let cat = g.catalog.lock().unwrap();
            cat.locate(&logical).unwrap().iter().map(|l| l.site.clone()).collect()
        };
        let mut mgr = ReplicaManager::new(&mut g, PlacementPolicy::MostSpace);
        mgr.delete_replica(&logical, &sites[0]).unwrap();
        let err = mgr.delete_replica(&logical, &sites[1]).unwrap_err();
        assert!(format!("{err:#}").contains("last replica"));
    }

    #[test]
    fn delete_reclaims_exactly_what_create_consumed() {
        let mut g = grid();
        let logical = g.files[0].clone();
        let bytes = g.sizes[0];
        // The destination the manager will pick (rank_destinations is
        // read-only and deterministic, so peeking doesn't perturb it).
        let dest = ReplicaManager::new(&mut g, PlacementPolicy::MostSpace)
            .rank_destinations(&logical, bytes)
            .unwrap()[0]
            .0;
        let used0 = g.topo.site(dest).used;
        let out = ReplicaManager::new(&mut g, PlacementPolicy::MostSpace)
            .create_replica(&logical)
            .unwrap();
        assert_eq!(g.topo.index_of(&out.site), Some(dest));
        let ledgered = g.space_ledger[&(0, dest)];
        assert!((ledgered - bytes).abs() < 1.0, "roomy volume commits in full");
        ReplicaManager::new(&mut g, PlacementPolicy::MostSpace)
            .delete_replica(&logical, &out.site)
            .unwrap();
        assert!(
            (g.topo.site(dest).used - used0).abs() < 1.0,
            "create→delete must conserve used: {} vs {}",
            g.topo.site(dest).used,
            used0
        );
        assert!(!g.space_ledger.contains_key(&(0, dest)), "ledger entry consumed");
    }

    #[test]
    fn clamped_create_reclaims_only_the_ledgered_amount() {
        let mut g = grid();
        let logical = g.files[0].clone();
        let out = ReplicaManager::new(&mut g, PlacementPolicy::MostSpace)
            .create_replica(&logical)
            .unwrap();
        let idx = g.topo.index_of(&out.site).unwrap();
        // Emulate a create that clamped at capacity (e.g. a concurrent
        // push filled the volume between ranking and commit): only half
        // the file actually fit, and the ledger says so.
        let half = g.sizes[0] / 2.0;
        g.space_ledger.insert((0, idx), half);
        let used_before = g.topo.site(idx).used;
        ReplicaManager::new(&mut g, PlacementPolicy::MostSpace)
            .delete_replica(&logical, &out.site)
            .unwrap();
        assert!(
            (used_before - g.topo.site(idx).used - half).abs() < 1.0,
            "reclaim must match the ledgered (applied) amount, not the file size"
        );
        assert!(g.topo.site(idx).used >= 0.0);
    }

    #[test]
    fn deleting_an_unledgered_seed_replica_never_goes_negative() {
        let mut g = grid();
        // Pick a file with ≥ 2 seed replicas and drain its first
        // holder's volume to nearly empty: the seed reclaim (file size,
        // unledgered) must clamp at zero instead of minting phantom
        // free space.
        let logical = g.files[3].clone();
        let idx = g.placement[3][0];
        let site = g.topo.site(idx).cfg.name.clone();
        g.topo.site_mut(idx).used = 1.0;
        ReplicaManager::new(&mut g, PlacementPolicy::MostSpace)
            .delete_replica(&logical, &site)
            .unwrap();
        let s = g.topo.site(idx);
        assert_eq!(s.used, 0.0, "reclaim clamps at zero");
        assert!(s.available_space() <= s.cfg.total_space);
    }

    #[test]
    fn unknown_file_errors() {
        let mut g = grid();
        let err = ReplicaManager::new(&mut g, PlacementPolicy::MostSpace)
            .create_replica("nope.dat")
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown logical file"));
    }
}
