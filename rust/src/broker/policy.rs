//! Ranking policies for the Match phase.

use std::sync::Arc;

use crate::classad::{ClassAd, CompiledMatch};
use crate::forecast::forecast_bank;
use crate::runtime::engine::EngineHandle;

use super::convert::Candidate;

/// How survivors of the requirements match are ordered.
#[derive(Clone)]
pub enum RankPolicy {
    /// The request ad's own `rank` expression (paper §5.2:
    /// `rank = other.availableSpace`).
    ClassAdRank,
    /// The §3.2 heuristic: predicted transfer bandwidth from the
    /// published history, discounted by current load. Uses the PJRT
    /// forecast artifact when provided, else the pure-Rust bank.
    ForecastBandwidth { engine: Option<Arc<EngineHandle>> },
}

impl std::fmt::Debug for RankPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RankPolicy::ClassAdRank => write!(f, "ClassAdRank"),
            RankPolicy::ForecastBandwidth { engine } => write!(
                f,
                "ForecastBandwidth(engine={})",
                if engine.is_some() { "pjrt" } else { "rust" }
            ),
        }
    }
}

/// A ranked match: candidate index + the policy's score.
#[derive(Debug, Clone, PartialEq)]
pub struct Ranked {
    pub index: usize,
    pub score: f64,
}

impl RankPolicy {
    /// Predicted effective bandwidth for every candidate (forecast
    /// policy machinery; exposed for the benches).
    pub fn predicted_bandwidth(&self, candidates: &[Candidate]) -> Vec<f64> {
        match self {
            RankPolicy::ForecastBandwidth { engine: Some(engine) } => {
                let hist: Vec<Vec<f64>> = candidates.iter().map(|c| c.history.clone()).collect();
                let load: Vec<f64> = candidates.iter().map(|c| c.load).collect();
                match engine.forecast(&hist, &load) {
                    Ok(out) => out.eff.iter().map(|&v| v as f64).collect(),
                    Err(_) => Self::rust_predictions(candidates),
                }
            }
            _ => Self::rust_predictions(candidates),
        }
    }

    fn rust_predictions(candidates: &[Candidate]) -> Vec<f64> {
        candidates
            .iter()
            .map(|c| {
                if c.history.is_empty() {
                    // No history: fall back to the static AvgRDBandwidth
                    // the site published, if any.
                    c.ad.number("AvgRDBandwidth").unwrap_or(0.0) * (1.0 - c.load)
                } else {
                    let mask = vec![1.0; c.history.len()];
                    forecast_bank(&c.history, &mask).best() * (1.0 - c.load)
                }
            })
            .collect()
    }

    /// Drill-down slot selection for the hierarchical discovery routes
    /// (the broker's GIIS Search path and the open-loop discovery
    /// driver share this, so both drill the same sites for the same
    /// stale view): indices of the top `k` candidates by predicted
    /// bandwidth over their (stale) ads, index-ascending on ties.
    pub fn drill_slots(&self, stale: &[Candidate], k: usize) -> Vec<usize> {
        let preds = self.predicted_bandwidth(stale);
        let mut order = crate::directory::hier::drill_order(&preds);
        order.truncate(k);
        order
    }

    /// Order the `matched` survivor indices best-first.
    pub fn order(
        &self,
        request: &ClassAd,
        candidates: &[Candidate],
        matched: &[usize],
    ) -> Vec<Ranked> {
        match self {
            RankPolicy::ClassAdRank => {
                let compiled = CompiledMatch::compile(request);
                self.order_compiled(&compiled, candidates, matched)
            }
            RankPolicy::ForecastBandwidth { .. } => {
                self.order_forecast(candidates, matched)
            }
        }
    }

    /// [`RankPolicy::order`] with an already-compiled request — the
    /// match-many path; compiles nothing and clones no ads.
    pub fn order_compiled(
        &self,
        compiled: &CompiledMatch,
        candidates: &[Candidate],
        matched: &[usize],
    ) -> Vec<Ranked> {
        match self {
            RankPolicy::ClassAdRank => {
                let (_, ms) =
                    compiled.match_and_rank(matched.iter().map(|&i| &candidates[i].ad));
                ms.into_iter()
                    .map(|m| Ranked { index: matched[m.index], score: m.rank })
                    .collect()
            }
            RankPolicy::ForecastBandwidth { .. } => self.order_forecast(candidates, matched),
        }
    }

    fn order_forecast(&self, candidates: &[Candidate], matched: &[usize]) -> Vec<Ranked> {
        let preds = self.predicted_bandwidth(candidates);
        let mut out: Vec<Ranked> = matched
            .iter()
            .map(|&i| Ranked { index: i, score: preds[i] })
            .collect();
        out.sort_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.index.cmp(&b.index))
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classad::parse_classad;

    fn candidate(site: &str, space_gb: f64, hist: &[f64], load: f64) -> Candidate {
        let ad = parse_classad(&format!(
            "hostname = \"{site}\"; availableSpace = {}; MaxRDBandwidth = 102400;",
            space_gb * 1024f64.powi(3)
        ))
        .unwrap();
        Candidate {
            site: site.into(),
            url: format!("gsiftp://{site}/f"),
            ad,
            history: hist.to_vec(),
            load,
        }
    }

    #[test]
    fn classad_rank_orders_by_space() {
        let request = parse_classad(
            "rank = other.availableSpace; requirement = other.availableSpace > 0;",
        )
        .unwrap();
        let cands = vec![
            candidate("a", 10.0, &[], 0.0),
            candidate("b", 80.0, &[], 0.0),
            candidate("c", 40.0, &[], 0.0),
        ];
        let ranked = RankPolicy::ClassAdRank.order(&request, &cands, &[0, 1, 2]);
        let order: Vec<usize> = ranked.iter().map(|r| r.index).collect();
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn forecast_rank_prefers_fast_history() {
        let request = parse_classad("requirement = TRUE;").unwrap();
        let cands = vec![
            candidate("slow", 99.0, &[10e3, 11e3, 10e3, 12e3], 0.0),
            candidate("fast", 1.0, &[90e3, 95e3, 92e3, 96e3], 0.0),
        ];
        let policy = RankPolicy::ForecastBandwidth { engine: None };
        let ranked = policy.order(&request, &cands, &[0, 1]);
        assert_eq!(ranked[0].index, 1);
        assert!(ranked[0].score > ranked[1].score);
    }

    #[test]
    fn forecast_rank_discounts_load() {
        let request = parse_classad("requirement = TRUE;").unwrap();
        let hist = [50e3, 50e3, 50e3, 50e3];
        let cands = vec![
            candidate("busy", 1.0, &hist, 0.9),
            candidate("idle", 1.0, &hist, 0.0),
        ];
        let policy = RankPolicy::ForecastBandwidth { engine: None };
        let ranked = policy.order(&request, &cands, &[0, 1]);
        assert_eq!(ranked[0].index, 1);
        assert!((ranked[1].score - 5e3).abs() < 1.0);
    }

    #[test]
    fn historyless_candidate_uses_published_average() {
        let mut c = candidate("nohist", 1.0, &[], 0.0);
        c.ad.set_value("AvgRDBandwidth", 1234.0);
        let preds = RankPolicy::ForecastBandwidth { engine: None }
            .predicted_bandwidth(&[c]);
        assert_eq!(preds[0], 1234.0);
    }

    #[test]
    fn order_respects_matched_subset() {
        let request = parse_classad("rank = other.availableSpace;").unwrap();
        let cands = vec![
            candidate("a", 10.0, &[], 0.0),
            candidate("b", 80.0, &[], 0.0),
            candidate("c", 40.0, &[], 0.0),
        ];
        // b was filtered out by requirements: only a and c compete.
        let ranked = RankPolicy::ClassAdRank.order(&request, &cands, &[0, 2]);
        let order: Vec<usize> = ranked.iter().map(|r| r.index).collect();
        assert_eq!(order, vec![2, 0]);
    }
}
