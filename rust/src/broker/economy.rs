//! Replica economy (ISSUE 10) — popularity-driven replication and
//! eviction as a *policy engine inside the open-loop kernel run*.
//!
//! The paper's replica management (§2.2) creates and deletes replicas
//! "to harness certain performance benefits", but the serial
//! [`super::replication::ReplicaManager`] only ever acts when a caller
//! asks it to. This module closes the loop: the open-loop driver feeds
//! every request arrival into a decayed per-file popularity counter and
//! fires a recurring economy tick that
//!
//! 1. **evicts** cold replicas at sites over their space budget
//!    (coldest first, never the last copy — an eviction is a catalog
//!    operation and reclaims exactly the ledgered bytes), and
//! 2. **replicates** hot under-replicated files to the best
//!    VM-compiled-placement destination, as a *real kernel write flow*
//!    ([`crate::gridftp::GridFtp::store_begin`]) that occupies the
//!    destination link and contends with foreground transfers until its
//!    completion event commits the space and the catalog entry.
//!
//! The engine itself is deliberately split from execution: [`Economy`]
//! owns the counters and *plans* ([`Economy::plan`]) a bounded list of
//! [`EconomyAction`]s per tick; the driver executes them against the
//! live grid, so the policy is unit-testable without a kernel.

use std::collections::BTreeSet;

use crate::classad::{CompiledMatch, VmScratch};
use crate::experiment::SimGrid;

use super::replication::{PlacementPolicy, ReplicaManager};

/// Configuration of the replica economy.
#[derive(Debug, Clone, Copy)]
pub struct EconomyOptions {
    /// Economy tick period in simulated seconds. Non-finite or
    /// non-positive = the tick is never scheduled (the driver treats
    /// the whole economy as off).
    pub period: f64,
    /// Popularity half-life (s): a file's access count decays by ×½
    /// every `half_life` seconds, so a flash crowd's heat fades once
    /// the crowd moves on. Non-finite = counts never decay.
    pub half_life: f64,
    /// Decayed popularity at or above which an under-replicated file
    /// earns a new replica.
    pub replicate_threshold: f64,
    /// Ceiling on copies per logical file (replication never pushes a
    /// file past this; the seed placement may already exceed it).
    pub max_replicas_per_file: usize,
    /// Per-site space budget as a fraction of `total_space`: eviction
    /// triggers when `used` exceeds it, and replication never targets a
    /// site the new copy would push over it.
    pub budget_frac: f64,
    /// Decayed popularity strictly below which a replica is cold, i.e.
    /// evictable when its site is over budget.
    pub evict_threshold: f64,
    /// Cap on economy actions (evictions + pushes) per tick — the
    /// economy heals gradually instead of storming the grid.
    pub max_actions_per_tick: usize,
    /// Destination-ranking policy for replication pushes.
    pub placement: PlacementPolicy,
}

impl Default for EconomyOptions {
    fn default() -> Self {
        EconomyOptions {
            period: 30.0,
            half_life: 120.0,
            replicate_threshold: 3.0,
            max_replicas_per_file: 3,
            budget_frac: 0.9,
            evict_threshold: 0.25,
            max_actions_per_tick: 2,
            placement: PlacementPolicy::MostSpace,
        }
    }
}

/// End-of-run economy accounting (surfaced as
/// `OpenReport::economy`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EconomyStats {
    /// Replication pushes that landed and registered a replica.
    pub replicas_created: usize,
    /// Cold replicas evicted under a space budget.
    pub evictions: usize,
    /// Bytes carried by landed replication pushes — the economy's
    /// network cost, paid on the same links foreground transfers use.
    pub bytes_moved: f64,
    /// Pushes that never committed: destination dead at launch or at
    /// landing, or cancelled by the run's wind-down.
    pub failed_pushes: usize,
}

/// Exponentially-decayed per-file access counter: `note` adds 1 to the
/// file's score, and every score decays by `2^(-Δt / half_life)` as the
/// simulated clock advances. Decay is applied lazily on access, so the
/// cost is O(files) per tick, not per request.
#[derive(Debug, Clone)]
pub struct Popularity {
    half_life: f64,
    scores: Vec<f64>,
    last: f64,
}

impl Popularity {
    pub fn new(files: usize, half_life: f64) -> Popularity {
        Popularity { half_life, scores: vec![0.0; files], last: 0.0 }
    }

    /// Decay every score to instant `at` (monotone; earlier instants
    /// are no-ops so out-of-order feeds cannot inflate scores).
    pub fn decay_to(&mut self, at: f64) {
        let dt = at - self.last;
        if dt <= 0.0 {
            return;
        }
        if self.half_life.is_finite() && self.half_life > 0.0 {
            let k = (-std::f64::consts::LN_2 * dt / self.half_life).exp();
            for s in &mut self.scores {
                *s *= k;
            }
        }
        self.last = at;
    }

    /// One access to `file` at instant `at`.
    pub fn note(&mut self, file: usize, at: f64) {
        self.decay_to(at);
        if let Some(s) = self.scores.get_mut(file) {
            *s += 1.0;
        }
    }

    /// `file`'s decayed score as of the last decay instant.
    pub fn score(&self, file: usize) -> f64 {
        self.scores.get(file).copied().unwrap_or(0.0)
    }
}

/// One planned economy action, executed by the driver.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EconomyAction {
    /// Push a new replica of `file` to topology site `dest` as a
    /// kernel write flow.
    Replicate { file: usize, dest: usize },
    /// Drop the replica of `file` at topology site `site` (catalog
    /// removal + exact ledgered-space reclaim).
    Evict { file: usize, site: usize },
}

/// The economy engine: popularity state, in-flight push bookkeeping,
/// and the per-tick planner.
pub struct Economy {
    pub opts: EconomyOptions,
    pop: Popularity,
    pub stats: EconomyStats,
    /// Files with a replication push currently on the wire — excluded
    /// from further planning until the push resolves, so one hot file
    /// cannot fan out duplicate pushes across consecutive ticks.
    inflight: BTreeSet<usize>,
}

impl Economy {
    pub fn new(opts: EconomyOptions, files: usize) -> Economy {
        Economy {
            opts,
            pop: Popularity::new(files, opts.half_life),
            stats: EconomyStats::default(),
            inflight: BTreeSet::new(),
        }
    }

    /// Feed one request arrival into the popularity counter.
    pub fn note_access(&mut self, file: usize, at: f64) {
        self.pop.note(file, at);
    }

    /// `file`'s current decayed popularity.
    pub fn score(&self, file: usize) -> f64 {
        self.pop.score(file)
    }

    /// A push for `file` went on the wire.
    pub fn push_started(&mut self, file: usize) {
        self.inflight.insert(file);
    }

    /// `file`'s push resolved (landed, failed, or was cancelled).
    pub fn push_resolved(&mut self, file: usize) {
        self.inflight.remove(&file);
    }

    /// Plan this tick's actions against the grid's current state:
    /// evictions first (they free the space replication wants), then
    /// replication pushes, both bounded by `max_actions_per_tick`.
    /// Read-only on the grid — execution is the driver's job.
    pub fn plan(&mut self, grid: &SimGrid, at: f64) -> Vec<EconomyAction> {
        self.pop.decay_to(at);
        let mut actions = Vec::new();
        let mut remaining = self.opts.max_actions_per_tick;

        // Eviction: sites over budget drop their coldest evictable
        // replicas until the *projected* used (current minus planned
        // reclaims) is back under budget.
        for site in 0..grid.topo.len() {
            if remaining == 0 {
                break;
            }
            let total = grid.topo.site(site).cfg.total_space;
            let budget = (self.opts.budget_frac * total).min(total);
            let mut used = grid.topo.site(site).used;
            if used <= budget {
                continue;
            }
            let mut cold: Vec<(f64, usize)> = grid
                .placement
                .iter()
                .enumerate()
                .filter(|(f, sites)| {
                    sites.contains(&site)
                        && sites.len() > 1 // never the last copy
                        && !self.inflight.contains(f)
                        && self.pop.score(*f) < self.opts.evict_threshold
                })
                .map(|(f, _)| (self.pop.score(f), f))
                .collect();
            cold.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            for (_, f) in cold {
                if remaining == 0 || used <= budget {
                    break;
                }
                let freed =
                    grid.space_ledger.get(&(f, site)).copied().unwrap_or(grid.sizes[f]);
                actions.push(EconomyAction::Evict { file: f, site });
                used -= freed;
                remaining -= 1;
            }
        }

        // Replication: hottest eligible files first.
        let mut hot: Vec<(f64, usize)> = (0..grid.files.len())
            .map(|f| (self.pop.score(f), f))
            .filter(|&(s, f)| {
                s >= self.opts.replicate_threshold
                    && grid.placement[f].len() < self.opts.max_replicas_per_file
                    && !self.inflight.contains(&f)
            })
            .collect();
        hot.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        for (_, f) in hot {
            if remaining == 0 {
                break;
            }
            if let Some(dest) = self.best_destination(grid, f) {
                actions.push(EconomyAction::Replicate { file: f, dest });
                remaining -= 1;
            }
        }
        actions
    }

    /// Best destination for a new replica of `file`: the same
    /// VM-compiled placement matching the serial
    /// [`ReplicaManager`] runs (compile the placement ad once, run the
    /// bytecode per site), with the economy's extra constraint that the
    /// landed copy must fit under the destination's space budget.
    /// Ties keep topology order, like `rank_destinations`.
    fn best_destination(&self, grid: &SimGrid, file: usize) -> Option<usize> {
        let bytes = grid.sizes[file];
        let compiled =
            CompiledMatch::compile(&ReplicaManager::placement_ad(bytes, self.opts.placement));
        let mut vm = VmScratch::default();
        grid.publish_dynamics();
        let mut best: Option<(usize, f64)> = None;
        for i in 0..grid.topo.len() {
            if grid.placement[file].contains(&i) || !grid.topo.site_alive(i) {
                continue;
            }
            let s = grid.topo.site(i);
            let budget = (self.opts.budget_frac * s.cfg.total_space).min(s.cfg.total_space);
            if s.used + bytes > budget {
                continue;
            }
            let name = s.cfg.name.clone();
            let entries = grid.info.query_site_all(&name).unwrap_or_default();
            let cand = super::convert::entries_to_candidate(&name, "", &entries);
            if !compiled.matches_vm(&cand.ad, &mut vm) {
                continue;
            }
            let r = compiled.rank_vm(&cand.ad, &mut vm);
            if best.map_or(true, |(_, br)| r > br) {
                best = Some((i, r));
            }
        }
        best.map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GridConfig;
    use crate::simnet::WorkloadSpec;

    fn grid() -> SimGrid {
        let cfg = GridConfig::generate(6, 99);
        let spec = WorkloadSpec { files: 5, ..Default::default() };
        let mut g = SimGrid::build(&cfg, &spec, 2, 16);
        g.warm(2);
        g
    }

    #[test]
    fn popularity_decays_by_half_life() {
        let mut p = Popularity::new(2, 100.0);
        p.note(0, 0.0);
        p.note(0, 0.0);
        assert_eq!(p.score(0), 2.0);
        p.decay_to(100.0);
        assert!((p.score(0) - 1.0).abs() < 1e-12, "one half-life halves the score");
        p.decay_to(300.0);
        assert!((p.score(0) - 0.25).abs() < 1e-12);
        // Out-of-order feeds cannot rewind the decay clock.
        p.decay_to(200.0);
        assert!((p.score(0) - 0.25).abs() < 1e-12);
        assert_eq!(p.score(1), 0.0);
    }

    #[test]
    fn infinite_half_life_never_decays() {
        let mut p = Popularity::new(1, f64::INFINITY);
        p.note(0, 0.0);
        p.decay_to(1e9);
        assert_eq!(p.score(0), 1.0);
    }

    #[test]
    fn hot_file_earns_a_replication_push() {
        let g = grid();
        let mut e = Economy::new(EconomyOptions::default(), g.files.len());
        for _ in 0..10 {
            e.note_access(0, g.topo.now);
        }
        let actions = e.plan(&g, g.topo.now);
        let rep = actions.iter().find_map(|a| match a {
            &EconomyAction::Replicate { file, dest } => Some((file, dest)),
            _ => None,
        });
        let (file, dest) = rep.expect("a hot under-replicated file must earn a push");
        assert_eq!(file, 0);
        assert!(!g.placement[0].contains(&dest), "destination must be a non-holder");
        assert!(g.topo.site_alive(dest));
    }

    #[test]
    fn cold_files_are_not_replicated() {
        let g = grid();
        let mut e = Economy::new(EconomyOptions::default(), g.files.len());
        e.note_access(0, g.topo.now); // one access: below threshold
        assert!(e.plan(&g, g.topo.now).is_empty());
    }

    #[test]
    fn inflight_push_suppresses_duplicates() {
        let g = grid();
        let mut e = Economy::new(EconomyOptions::default(), g.files.len());
        for _ in 0..10 {
            e.note_access(0, g.topo.now);
        }
        e.push_started(0);
        assert!(e.plan(&g, g.topo.now).is_empty());
        e.push_resolved(0);
        assert!(!e.plan(&g, g.topo.now).is_empty());
    }

    #[test]
    fn over_budget_site_evicts_coldest_but_never_last_copy() {
        let mut g = grid();
        let mut e = Economy::new(
            EconomyOptions { max_actions_per_tick: 8, ..Default::default() },
            g.files.len(),
        );
        // Fill site 0's volume past its budget; every file there is
        // stone cold (no accesses recorded).
        let site = g.placement[0][0];
        let total = g.topo.site(site).cfg.total_space;
        g.topo.site_mut(site).used = total;
        let actions = e.plan(&g, g.topo.now);
        let evicted: Vec<usize> = actions
            .iter()
            .filter_map(|a| match a {
                &EconomyAction::Evict { file, site: s } if s == site => Some(file),
                _ => None,
            })
            .collect();
        assert!(!evicted.is_empty(), "an over-budget site must shed cold replicas");
        for &f in &evicted {
            assert!(g.placement[f].len() > 1, "never plan to evict the last copy");
            assert!(g.placement[f].contains(&site));
        }
        // No file is planned for eviction twice at the same site.
        let mut sorted = evicted.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), evicted.len());
    }

    #[test]
    fn under_budget_site_evicts_nothing() {
        let g = grid();
        let mut e = Economy::new(EconomyOptions::default(), g.files.len());
        let actions = e.plan(&g, g.topo.now);
        assert!(
            !actions.iter().any(|a| matches!(a, EconomyAction::Evict { .. })),
            "fresh grids are under budget everywhere"
        );
    }

    #[test]
    fn plan_is_deterministic() {
        let run = || {
            let g = grid();
            let mut e = Economy::new(EconomyOptions::default(), g.files.len());
            for f in 0..g.files.len() {
                for _ in 0..(f + 3) {
                    e.note_access(f, g.topo.now);
                }
            }
            e.plan(&g, g.topo.now)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn execution_roundtrip_respects_ledger() {
        // Plan → execute an eviction via the ReplicaManager: the
        // catalog, placement and ledger all agree afterwards.
        let mut g = grid();
        let mut e = Economy::new(
            EconomyOptions { max_actions_per_tick: 1, ..Default::default() },
            g.files.len(),
        );
        let site = g.placement[1][0];
        g.topo.site_mut(site).used = g.topo.site(site).cfg.total_space;
        let actions = e.plan(&g, g.topo.now);
        let Some(&EconomyAction::Evict { file, site: s }) = actions.first() else {
            panic!("expected an eviction plan");
        };
        let logical = g.files[file].clone();
        let name = g.topo.site(s).cfg.name.clone();
        ReplicaManager::new(&mut g, PlacementPolicy::MostSpace)
            .delete_replica(&logical, &name)
            .unwrap();
        assert!(!g.placement[file].contains(&s));
        assert!(!g.space_ledger.contains_key(&(file, s)));
        let cat = g.catalog.lock().unwrap();
        assert!(cat.locate(&logical).unwrap().iter().all(|l| l.site != name));
    }
}
