//! Lightweight metrics registry: counters and latency histograms used
//! by the broker, the directory servers and the gridftp fabric.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A fixed-bucket log2 latency histogram (nanoseconds).
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>, // bucket i: [2^i, 2^(i+1)) ns
    sum_ns: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..48).map(|_| AtomicU64::new(0)).collect(),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe_ns(&self, ns: u64) {
        let idx = (64 - ns.max(1).leading_zeros() as usize - 1).min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn observe(&self, d: std::time::Duration) {
        self.observe_ns(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile from bucket boundaries (upper bound).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        u64::MAX
    }
}

/// A named registry of counters and histograms.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Render all metrics as aligned text (the CLI's `--metrics` dump).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {name:<40} {}\n", c.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "hist    {name:<40} n={} mean={:.1}µs p99≤{:.1}µs\n",
                h.count(),
                h.mean_ns() / 1e3,
                h.quantile_ns(0.99) as f64 / 1e3,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.counter("requests").inc();
        m.counter("requests").add(4);
        assert_eq!(m.counter("requests").get(), 5);
        assert_eq!(m.counter("other").get(), 0);
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::default();
        for ns in [100u64, 200, 400, 800, 100_000] {
            h.observe_ns(ns);
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean_ns() > 0.0);
        assert!(h.quantile_ns(0.5) >= 128);
        assert!(h.quantile_ns(0.99) >= 65_536);
    }

    #[test]
    fn render_contains_names() {
        let m = Metrics::new();
        m.counter("broker.requests").inc();
        m.histogram("broker.match_ns").observe_ns(1234);
        let text = m.render();
        assert!(text.contains("broker.requests"));
        assert!(text.contains("broker.match_ns"));
    }
}
