//! Lightweight metrics registry: counters and latency histograms used
//! by the broker, the directory servers and the gridftp fabric.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A fixed-bucket log2 latency histogram (nanoseconds), plus exact
/// min/max so quantile estimates can be clamped to observed reality.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>, // bucket i: [2^i, 2^(i+1)) ns
    sum_ns: AtomicU64,
    count: AtomicU64,
    min_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..48).map(|_| AtomicU64::new(0)).collect(),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
            min_ns: AtomicU64::new(u64::MAX),
            max_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe_ns(&self, ns: u64) {
        let idx = (64 - ns.max(1).leading_zeros() as usize - 1).min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.min_ns.fetch_min(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn observe(&self, d: std::time::Duration) {
        self.observe_ns(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact smallest observation (0 before any observation).
    pub fn min_ns(&self) -> u64 {
        if self.count() == 0 {
            0
        } else {
            self.min_ns.load(Ordering::Relaxed)
        }
    }

    /// Exact largest observation (0 before any observation).
    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Quantile estimate: linear interpolation of the target rank
    /// within its log2 bucket, clamped to the exact observed
    /// `[min_ns, max_ns]` range. The clamp matters at the tail — a
    /// lone p99 sample no longer reads as its bucket's upper bound
    /// (up to 2× the real value) but as the exact maximum.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * q).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            if seen + n >= target {
                let lo = 1u64 << i;
                let hi = 1u64 << (i + 1);
                let frac = (target - seen) as f64 / n as f64;
                let est = lo as f64 + frac * (hi - lo) as f64;
                return (est as u64).clamp(self.min_ns(), self.max_ns());
            }
            seen += n;
        }
        self.max_ns()
    }

    /// The pre-P8 estimate: the matching bucket's upper bound, which
    /// overstates tail quantiles by up to 2×. Kept verbatim for parity
    /// checks against historical dumps.
    pub fn quantile_ns_upper_bound(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        u64::MAX
    }
}

/// A named registry of counters and histograms.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Render all metrics as aligned text (the CLI's `--metrics` dump).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {name:<40} {}\n", c.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "hist    {name:<40} n={} mean={:.1}µs p99≈{:.1}µs max={:.1}µs\n",
                h.count(),
                h.mean_ns() / 1e3,
                h.quantile_ns(0.99) as f64 / 1e3,
                h.max_ns() as f64 / 1e3,
            ));
        }
        out
    }

    /// One stable-ordered pass over every counter and histogram
    /// (BTreeMap iteration = alphabetical), capturing values at a
    /// single instant. Benches and `trace-summary` serialize this
    /// instead of ad-hoc printing.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(name, c)| (name.clone(), c.get()))
            .collect();
        let histograms = self
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(name, h)| HistogramSummary {
                name: name.clone(),
                count: h.count(),
                mean_ns: h.mean_ns(),
                min_ns: h.min_ns(),
                max_ns: h.max_ns(),
                p50_ns: h.quantile_ns(0.50),
                p95_ns: h.quantile_ns(0.95),
                p99_ns: h.quantile_ns(0.99),
            })
            .collect();
        MetricsSnapshot { counters, histograms }
    }

    /// [`Self::snapshot`] serialized as one stable-keyed JSON object.
    pub fn to_json(&self) -> String {
        self.snapshot().to_json()
    }
}

/// Point-in-time summary of one histogram (exact min/max, interpolated
/// quantiles).
#[derive(Debug, Clone)]
pub struct HistogramSummary {
    pub name: String,
    pub count: u64,
    pub mean_ns: f64,
    pub min_ns: u64,
    pub max_ns: u64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
}

/// Stable-ordered capture of a whole [`Metrics`] registry.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// `(name, value)`, alphabetical by name.
    pub counters: Vec<(String, u64)>,
    /// Histogram summaries, alphabetical by name.
    pub histograms: Vec<HistogramSummary>,
}

impl MetricsSnapshot {
    /// Serialize as `{"counters": {...}, "histograms": {name: {...}}}`
    /// — key order is alphabetical at every level (BTreeMap-backed
    /// [`Json`] objects), so identical registries produce identical
    /// bytes.
    pub fn to_json(&self) -> String {
        use crate::util::json::Json;
        use std::collections::BTreeMap;

        let counters: BTreeMap<String, Json> = self
            .counters
            .iter()
            .map(|(name, v)| (name.clone(), Json::Num(*v as f64)))
            .collect();
        let histograms: BTreeMap<String, Json> = self
            .histograms
            .iter()
            .map(|h| {
                let mut o = BTreeMap::new();
                o.insert("count".to_string(), Json::Num(h.count as f64));
                o.insert("mean_ns".to_string(), Json::Num(h.mean_ns));
                o.insert("min_ns".to_string(), Json::Num(h.min_ns as f64));
                o.insert("max_ns".to_string(), Json::Num(h.max_ns as f64));
                o.insert("p50_ns".to_string(), Json::Num(h.p50_ns as f64));
                o.insert("p95_ns".to_string(), Json::Num(h.p95_ns as f64));
                o.insert("p99_ns".to_string(), Json::Num(h.p99_ns as f64));
                (h.name.clone(), Json::Obj(o))
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("counters".to_string(), Json::Obj(counters));
        root.insert("histograms".to_string(), Json::Obj(histograms));
        Json::Obj(root).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.counter("requests").inc();
        m.counter("requests").add(4);
        assert_eq!(m.counter("requests").get(), 5);
        assert_eq!(m.counter("other").get(), 0);
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::default();
        for ns in [100u64, 200, 400, 800, 100_000] {
            h.observe_ns(ns);
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean_ns() > 0.0);
        assert!(h.quantile_ns(0.5) >= 128);
        assert!(h.quantile_ns(0.99) >= 65_536);
    }

    #[test]
    fn exact_extremes_and_interpolated_quantiles() {
        let h = Histogram::default();
        for ns in [100u64, 200, 400, 800, 100_000] {
            h.observe_ns(ns);
        }
        assert_eq!(h.min_ns(), 100);
        assert_eq!(h.max_ns(), 100_000);
        // The old estimate returns the bucket upper bound (131072 for
        // a 100000 ns sample — a 1.31× overstatement); the new one is
        // clamped to the exact maximum.
        assert_eq!(h.quantile_ns_upper_bound(0.99), 131_072);
        assert_eq!(h.quantile_ns(0.99), 100_000);
        // Interpolation stays within the observed range everywhere.
        for q in [0.1, 0.5, 0.9, 0.99, 1.0] {
            let v = h.quantile_ns(q);
            assert!((100..=100_000).contains(&v), "q{q}: {v}");
        }
        // Empty histogram degrades to zeros.
        let empty = Histogram::default();
        assert_eq!(empty.min_ns(), 0);
        assert_eq!(empty.max_ns(), 0);
        assert_eq!(empty.quantile_ns(0.5), 0);
    }

    #[test]
    fn snapshot_is_stable_ordered_and_round_trips_as_json() {
        let m = Metrics::new();
        m.counter("z.last").add(3);
        m.counter("a.first").inc();
        m.histogram("broker.match_ns").observe_ns(1234);
        let snap = m.snapshot();
        let names: Vec<&str> = snap.counters.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["a.first", "z.last"]);
        let text = m.to_json();
        assert_eq!(text, m.to_json(), "serialization must be deterministic");
        // Metric names contain dots, so walk the objects directly
        // (Json::get's path syntax would split them).
        let v = crate::util::json::Json::parse(&text).unwrap();
        let counters = v.get("counters").unwrap().as_obj().unwrap();
        assert_eq!(counters.get("a.first").and_then(|j| j.as_f64()), Some(1.0));
        assert_eq!(counters.get("z.last").and_then(|j| j.as_f64()), Some(3.0));
        let hists = v.get("histograms").unwrap().as_obj().unwrap();
        let h = hists.get("broker.match_ns").unwrap().as_obj().unwrap();
        assert_eq!(h.get("count").and_then(|j| j.as_f64()), Some(1.0));
        assert_eq!(h.get("max_ns").and_then(|j| j.as_f64()), Some(1234.0));
    }

    #[test]
    fn render_contains_names() {
        let m = Metrics::new();
        m.counter("broker.requests").inc();
        m.histogram("broker.match_ns").observe_ns(1234);
        let text = m.render();
        assert!(text.contains("broker.requests"));
        assert!(text.contains("broker.match_ns"));
    }
}
