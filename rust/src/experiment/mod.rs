//! Experiment driver: the reusable simulation harness behind
//! `examples/datagrid_sim`, `benches/bench_selection_quality`,
//! `benches/bench_contention` and the end-to-end integration tests.
//!
//! Builds a complete in-process data grid — simnet topology, GridFTP
//! fabric, one GRIS per site with live providers (dynamic
//! `availableSpace`/`load` + Figure-4/5 bandwidth attributes straight
//! from the instrumentation store), replica catalog, metadata
//! repository — then replays a workload under a chosen selection policy
//! and scores the outcome against the clairvoyant oracle.
//!
//! Two replay regimes exist:
//!
//! * **Serial** ([`run_quality_trace`], [`run_churn`]): the clock jumps
//!   to each arrival and one transfer runs at a time — the legacy
//!   semantics, kept as the concurrency-1 reference the open-loop
//!   parity test pins against.
//! * **Open-loop** ([`run_quality_open`], [`run_contention`]): requests
//!   are admitted at their Poisson instants on the `simnet` event
//!   kernel, every in-flight transfer shares links and client
//!   downlinks, and selection sees *live* in-flight load through the
//!   GRIS dynamics — the contention regime the paper's
//!   dynamic-information thesis is actually about. With
//!   [`OpenLoopOptions::discovery`] set, selection additionally pays
//!   for its information: broad answers come from stale GIIS soft
//!   state and fresh detail arrives through an event-driven drill-down
//!   fan-out with per-site latency.
//!
//! [`run_scale`] sweeps the discovery layer itself: site count ×
//! soft-state staleness, GIIS-routed vs always-fresh direct selection,
//! reporting the quality degradation and the query economy (ISSUE 5).
//!
//! [`run_chaos`] is the robustness counterpart (ISSUE 7): seeded grid
//! weather ([`crate::simnet::WeatherPlan`]) × recovery policy
//! (fail-fast / retry / retry+failover) on identically seeded grids,
//! reporting completion rate, time-to-recover, p95 and goodput.
//!
//! [`run_economy`] (ISSUE 10) pits static placement against the
//! [`crate::broker::Economy`] policy engine — popularity-driven
//! replication and eviction running *inside* the open-loop kernel — on
//! identical traces under three demand shapes (flash crowd, diurnal
//! region shift, cold start), reporting hit-rate-at-nearest-replica,
//! mean/p95 time and the bytes the economy moved to earn them.
//!
//! [`run_quality_sharded`] (ISSUE 8) runs the open-loop driver under a
//! sharded control plane — contiguous site shards, per-shard GIIS
//! registration domains and admission batches — with the
//! [`sharded::ShardOptions::parity`] configuration pinned bit-identical
//! to the unsharded path. [`run_kernel`] is its throughput companion:
//! a day-of-traffic surge at 10⁵⁺ concurrent transfers, reporting
//! kernel events per second (`BENCH_kernel.json`).

pub mod chaos;
pub mod churn;
pub mod economy;
pub mod grid;
pub mod kernel;
pub mod open_loop;
pub mod quality;
pub mod scale;
pub mod sharded;

pub use chaos::{run_chaos, ChaosArm, ChaosOptions, ChaosPoint, ChaosReport};
pub use churn::{run_churn, run_churn_traced, ChurnReport, ChurnStrategyReport};
pub use economy::{
    run_economy, run_economy_point, EconomyArm, EconomyPoint, EconomyReport, EconomySweepOptions,
};
pub use grid::SimGrid;
pub use kernel::{run_kernel, KernelOptions, KernelReport};
pub use open_loop::{
    run_contention, run_quality_open, AccessMode, ContentionPoint, ContentionReport,
    DiscoveryOptions, OpenLoopOptions, OpenReport, RequestTrace, RetryOptions,
};
pub use sharded::{run_quality_sharded, ShardOptions, ShardStats, ShardedReport};
pub use quality::{
    run_coalloc_quality, run_quality, run_quality_trace, CoallocReport, QualityReport,
};
pub use scale::{run_scale, ScaleOptions, ScalePoint, ScaleReport};
