//! Experiment driver: the reusable simulation harness behind
//! `examples/datagrid_sim`, `benches/bench_selection_quality` and the
//! end-to-end integration tests.
//!
//! Builds a complete in-process data grid — simnet topology, GridFTP
//! fabric, one GRIS per site with live providers (dynamic
//! `availableSpace`/`load` + Figure-4/5 bandwidth attributes straight
//! from the instrumentation store), replica catalog, metadata
//! repository — then replays a workload under a chosen selection policy
//! and scores the outcome against the clairvoyant oracle.

pub mod churn;
pub mod grid;
pub mod quality;

pub use churn::{run_churn, ChurnReport, ChurnStrategyReport};
pub use grid::SimGrid;
pub use quality::{
    run_coalloc_quality, run_quality, run_quality_trace, CoallocReport, QualityReport,
};
