//! Chaos sweep (ISSUE 7 tentpole): fault intensity × recovery policy.
//!
//! [`run_chaos`] replays the *same* request trace on *identically
//! seeded* grids under the *same* seeded weather
//! ([`crate::simnet::WeatherPlan`]) three times — once per recovery
//! policy:
//!
//! * **fail-fast** — attempt budget 1: the first stall or dead source
//!   ends the request (`gave_up`), the pre-ISSUE-7 behaviour made
//!   explicit;
//! * **retry** — exponential backoff with deterministic jitter, every
//!   re-issue pinned to the originally chosen source;
//! * **retry+failover** — backoff plus re-selection among the
//!   surviving replicas, resuming from the delivered byte offset.
//!
//! Because grid, workload and weather are bit-identical across the
//! arms, any difference in completion rate, time-to-recover, p95 or
//! goodput is attributable to the recovery policy alone — the
//! robustness claim `bench_chaos` records as `BENCH_chaos.json`.

use crate::config::GridConfig;
use crate::broker::selectors::SelectorKind;
use crate::simnet::{WeatherPlan, WeatherSpec, Workload, WorkloadSpec};

use super::open_loop::{run_quality_open, OpenLoopOptions, OpenReport, RetryOptions};

/// Shared knobs of one chaos sweep.
#[derive(Debug, Clone)]
pub struct ChaosOptions {
    /// Selection policy every arm runs under.
    pub kind: SelectorKind,
    /// Backoff/timeout knobs for the retrying arms; the fail-fast arm
    /// reuses them with `max_attempts = 1`, so stall *detection* is
    /// identical across arms and only the *reaction* differs.
    pub retry: RetryOptions,
    /// Base open-loop configuration (`retry`/`faults` are overwritten
    /// per arm/point).
    pub open: OpenLoopOptions,
    /// Seed of the weather generator (independent of `cfg.seed` so
    /// grid and weather vary separately).
    pub weather_seed: u64,
}

impl Default for ChaosOptions {
    fn default() -> Self {
        ChaosOptions {
            kind: SelectorKind::Forecast,
            retry: RetryOptions::default(),
            open: OpenLoopOptions::open(),
            weather_seed: 7,
        }
    }
}

/// One recovery policy's outcome under one weather intensity.
#[derive(Debug, Clone)]
pub struct ChaosArm {
    /// Finished requests / total requests.
    pub completion_rate: f64,
    /// Mean time-to-recover: `finished_at − first_failure_at` over the
    /// requests that lost a transfer *and still finished* (0 when none
    /// did — nothing failed, or nothing recovered).
    pub mttr: f64,
    /// p95 request duration over finished requests (s).
    pub p95: f64,
    /// Delivered bytes of finished requests per simulated second of
    /// makespan.
    pub goodput: f64,
    pub retries: usize,
    pub failovers: usize,
    pub gave_up: usize,
    pub skipped: usize,
    /// The full open-loop report, for drill-down.
    pub report: OpenReport,
}

fn arm(report: OpenReport, total: usize) -> ChaosArm {
    let finished = report.per_request.len();
    let mut recoveries = 0usize;
    let mut recover_sum = 0.0;
    let mut bytes = 0.0;
    for t in &report.per_request {
        if let Some(f) = t.first_failure_at {
            recoveries += 1;
            recover_sum += (t.finished_at - f).max(0.0);
        }
        bytes += t.bandwidth * t.duration;
    }
    ChaosArm {
        completion_rate: if total == 0 { 0.0 } else { finished as f64 / total as f64 },
        mttr: if recoveries == 0 { 0.0 } else { recover_sum / recoveries as f64 },
        p95: report.quality.p95_time,
        goodput: if report.makespan > 0.0 { bytes / report.makespan } else { 0.0 },
        retries: report.retries,
        failovers: report.failovers,
        gave_up: report.gave_up,
        skipped: report.skipped,
        report,
    }
}

/// One weather intensity: the three policy arms on identical inputs.
#[derive(Debug, Clone)]
pub struct ChaosPoint {
    pub label: String,
    /// Crash faults the weather plan scheduled (intensity realized).
    pub crashes: usize,
    /// Total faults including link flaps.
    pub faults: usize,
    pub fail_fast: ChaosArm,
    pub retry: ChaosArm,
    pub retry_failover: ChaosArm,
}

/// The whole sweep.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    pub points: Vec<ChaosPoint>,
}

/// Sweep `weathers` (label × intensity) × recovery policy. Each point
/// generates one deterministic [`WeatherPlan`] from
/// `(spec, sites, weather_seed)` and replays the identical request
/// trace under it three times, differing only in
/// [`OpenLoopOptions::retry`].
pub fn run_chaos(
    cfg: &GridConfig,
    spec: &WorkloadSpec,
    n_requests: usize,
    replicas_per_file: usize,
    warm: usize,
    weathers: &[(&str, WeatherSpec)],
    opts: &ChaosOptions,
) -> ChaosReport {
    let requests = Workload::new(spec.clone(), cfg.seed).take(n_requests);
    let points = weathers
        .iter()
        .map(|(label, wspec)| {
            let plan = WeatherPlan::generate(wspec, cfg.sites.len(), opts.weather_seed);
            let run = |retry: RetryOptions| {
                let o = OpenLoopOptions {
                    retry: Some(retry),
                    faults: plan.faults.clone(),
                    ..opts.open.clone()
                };
                let r = run_quality_open(
                    cfg,
                    spec,
                    &requests,
                    replicas_per_file,
                    warm,
                    opts.kind,
                    &o,
                    None,
                );
                arm(r, n_requests)
            };
            let fail_fast = run(RetryOptions { max_attempts: 1, ..opts.retry });
            let retry = run(RetryOptions { failover: false, ..opts.retry });
            let retry_failover = run(RetryOptions { failover: true, ..opts.retry });
            ChaosPoint {
                label: label.to_string(),
                crashes: plan.crashes(),
                faults: plan.faults.len(),
                fail_fast,
                retry,
                retry_failover,
            }
        })
        .collect();
    ChaosReport { points }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::{Fault, FaultKind};
    use crate::trace::TraceHandle;

    fn flat_cfg(n: usize, seed: u64) -> GridConfig {
        let mut cfg = GridConfig::generate(n, seed);
        for s in &mut cfg.sites {
            s.wan_bandwidth = 1e6;
            s.diurnal_amp = 0.0;
            s.noise_frac = 0.0;
            s.congestion_prob = 0.0;
            s.ar_coeff = 0.0;
            s.latency = 0.0;
            s.drd_time_ms = 0.0;
            s.disk_rate = 1e9;
        }
        cfg
    }

    #[test]
    fn calm_weather_equalizes_every_arm() {
        let cfg = GridConfig::generate(4, 41);
        let spec = WorkloadSpec { files: 4, mean_interarrival: 15.0, ..Default::default() };
        let calm = WeatherSpec::default(); // mtbf = ∞, no flaps
        let r = run_chaos(&cfg, &spec, 8, 3, 2, &[("calm", calm)], &ChaosOptions::default());
        assert_eq!(r.points.len(), 1);
        let p = &r.points[0];
        assert_eq!(p.crashes, 0);
        assert_eq!(p.faults, 0);
        for a in [&p.fail_fast, &p.retry, &p.retry_failover] {
            assert_eq!(a.completion_rate, 1.0, "calm skies must complete everything");
            assert_eq!(a.retries, 0);
            assert_eq!(a.gave_up, 0);
            assert_eq!(a.mttr, 0.0);
        }
        // Identical inputs, identical outcomes: the retry knob is the
        // only difference and it never engaged.
        assert_eq!(p.fail_fast.p95, p.retry.p95);
        assert_eq!(p.retry.p95, p.retry_failover.p95);
        assert_eq!(p.fail_fast.goodput, p.retry_failover.goodput);
    }

    /// The acceptance anchor: under moderate weather on identically
    /// seeded grids, retry+failover strictly beats fail-fast on
    /// completion rate.
    #[test]
    fn retry_failover_strictly_beats_fail_fast_under_weather() {
        let cfg = flat_cfg(4, 42);
        let spec = WorkloadSpec { files: 6, mean_interarrival: 8.0, ..Default::default() };
        let requests = 20;
        // Hand-crafted moderate storm instead of a generated plan so
        // the outcome is structurally guaranteed: 3 of 4 sites die
        // permanently 20 s in; every file is replicated everywhere, so
        // one survivor can always serve. The uninformed selector keeps
        // picking dead sites, which is exactly the point: fail-fast
        // gives those requests up, failover saves them.
        let faults: Vec<Fault> = (0..3)
            .map(|s| Fault {
                site: s,
                at: 20.0,
                heal_at: f64::INFINITY,
                kind: FaultKind::ReplicaDeath,
            })
            .collect();
        let base = RetryOptions {
            transfer_timeout: 15.0,
            backoff_base: 1.0,
            backoff_max: 10.0,
            ..RetryOptions::default()
        };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(requests);
        let run = |retry: RetryOptions| {
            let o = OpenLoopOptions {
                retry: Some(retry),
                faults: faults.clone(),
                ..OpenLoopOptions::open()
            };
            let r = run_quality_open(
                &cfg,
                &spec,
                &reqs,
                4,
                2,
                SelectorKind::Random,
                &o,
                None,
            );
            arm(r, requests)
        };
        let fail_fast = run(RetryOptions { max_attempts: 1, ..base });
        let failover = run(RetryOptions { failover: true, ..base });
        assert!(
            fail_fast.gave_up > 0,
            "a 3/4-dead grid must cost the fail-fast arm requests"
        );
        assert!(
            failover.completion_rate > fail_fast.completion_rate,
            "retry+failover ({:.2}) must strictly beat fail-fast ({:.2})",
            failover.completion_rate,
            fail_fast.completion_rate
        );
        assert!(failover.failovers > 0);
        // Recovered requests report a positive time-to-recover.
        if failover.retries > 0 {
            assert!(failover.mttr > 0.0);
        }
    }

    /// The determinism acceptance check: two identically seeded chaos
    /// runs export byte-identical traces.
    #[test]
    fn identically_seeded_chaos_runs_export_identical_traces() {
        let cfg = GridConfig::generate(4, 43);
        let spec = WorkloadSpec { files: 4, mean_interarrival: 10.0, ..Default::default() };
        let wspec = WeatherSpec {
            horizon: 600.0,
            mtbf: 150.0,
            mttr: 60.0,
            flap_rate: 1.0 / 200.0,
            ..WeatherSpec::default()
        };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(10);
        let export = || {
            let plan = WeatherPlan::generate(&wspec, cfg.sites.len(), 7);
            let trace = TraceHandle::new(4096);
            let o = OpenLoopOptions {
                retry: Some(RetryOptions {
                    transfer_timeout: 20.0,
                    backoff_base: 1.0,
                    ..RetryOptions::default()
                }),
                faults: plan.faults.clone(),
                trace: trace.clone(),
                sample_period: 50.0,
                ..OpenLoopOptions::open()
            };
            run_quality_open(&cfg, &spec, &reqs, 3, 2, SelectorKind::Forecast, &o, None);
            let mut out = String::new();
            trace.with(|r| out = r.jsonl());
            out
        };
        let a = export();
        let b = export();
        assert!(!a.is_empty());
        assert_eq!(a, b, "chaos trace export must be byte-identical across runs");
        // The weather actually showed up in the export.
        assert!(
            a.contains("site_fault"),
            "a stormy plan must emit site_fault events"
        );
    }

    #[test]
    fn generated_weather_degrades_fail_fast_more_than_failover() {
        let cfg = flat_cfg(5, 44);
        let spec = WorkloadSpec { files: 5, mean_interarrival: 10.0, ..Default::default() };
        let storm = WeatherSpec {
            horizon: 400.0,
            mtbf: 120.0,
            mttr: 80.0,
            perm_frac: 0.3,
            ..WeatherSpec::default()
        };
        let opts = ChaosOptions {
            kind: SelectorKind::Random,
            retry: RetryOptions {
                transfer_timeout: 15.0,
                backoff_base: 1.0,
                backoff_max: 10.0,
                ..RetryOptions::default()
            },
            ..ChaosOptions::default()
        };
        let r = run_chaos(&cfg, &spec, 15, 4, 2, &[("storm", storm)], &opts);
        let p = &r.points[0];
        assert!(p.crashes > 0, "a 120 s MTBF storm must schedule crashes");
        // Weak ordering (the strict acceptance anchor lives in the
        // hand-crafted test above): failover can only help.
        assert!(
            p.retry_failover.completion_rate >= p.fail_fast.completion_rate,
            "failover {:.2} < fail-fast {:.2}",
            p.retry_failover.completion_rate,
            p.fail_fast.completion_rate
        );
        assert!(
            p.retry_failover.completion_rate >= p.retry.completion_rate,
            "failover {:.2} < pinned retry {:.2}",
            p.retry_failover.completion_rate,
            p.retry.completion_rate
        );
    }
}
