//! Churn scenario — transfers under replica failure (ISSUE 3).
//!
//! The EU DataGrid experience report (cs/0306011) found replicas
//! vanishing mid-operation to be the common case on a real grid, not
//! the exception. This experiment injects exactly that: for every
//! request, the transfer's *predicted-best* source is killed
//! ([`FaultKind::ReplicaDeath`]) once a configurable fraction of the
//! plan's predicted makespan has elapsed, and three Access strategies
//! replay the identical workload on identically seeded grids:
//!
//! * **single-best** — the paper's one-source fetch; its only source
//!   dying aborts the request.
//! * **striped** — co-allocated, failover disabled
//!   (`max_block_retries = 0`): the death of one stripe still kills
//!   the whole transfer, but the surviving bytes arrived faster.
//! * **striped-failover** — co-allocated with per-block retry/failover:
//!   the dead source's blocks are re-queued to survivors and the
//!   transfer completes.
//!
//! The report shows the availability claim directly: completion rate
//! under churn, plus the time and failover-counter costs of surviving.
//!
//! Since ISSUE 4, `coalloc::execute` itself runs as an event-driven
//! session on the `simnet` kernel, so this scenario exercises the same
//! machinery the open-loop contention runtime drives — one request at
//! a time, which is exactly the regime a churn comparison wants (the
//! injected death, not cross-request contention, is the variable).

use crate::broker::{AccessStrategy, RankPolicy};
use crate::classad::{parse_classad, ClassAd};
use crate::coalloc;
use crate::config::{CoallocPolicy, GridConfig};
use crate::simnet::{FaultKind, Workload, WorkloadSpec};
use crate::trace::{Ev, TraceHandle};

use super::grid::SimGrid;

/// Outcome of one strategy's replay under churn.
#[derive(Debug, Clone)]
pub struct ChurnStrategyReport {
    pub strategy: String,
    /// Requests attempted (selection failures are skipped).
    pub attempts: usize,
    /// Requests whose transfer delivered every byte.
    pub completed: usize,
    /// Requests aborted by the injected failure.
    pub failed: usize,
    /// Mean duration of the *completed* transfers (s).
    pub mean_time: f64,
    /// Failover events across all transfers (streams lost + absorbed).
    pub failovers: usize,
    /// Blocks re-queued off dead sources across all transfers.
    pub blocks_requeued: usize,
    /// Work-stealing events across all transfers.
    pub steals: usize,
}

/// The three-strategy comparison.
#[derive(Debug, Clone)]
pub struct ChurnReport {
    pub single_best: ChurnStrategyReport,
    pub striped: ChurnStrategyReport,
    pub striped_failover: ChurnStrategyReport,
}

impl ChurnReport {
    pub fn strategies(&self) -> [&ChurnStrategyReport; 3] {
        [&self.single_best, &self.striped, &self.striped_failover]
    }
}

fn request_ad() -> ClassAd {
    parse_classad("hostname = \"client\"; reqdSpace = 0; requirement = TRUE;").unwrap()
}

#[allow(clippy::too_many_arguments)]
fn replay(
    name: &str,
    cfg: &GridConfig,
    spec: &WorkloadSpec,
    n_requests: usize,
    replicas_per_file: usize,
    warm: usize,
    strategy: &AccessStrategy,
    exec_policy: &CoallocPolicy,
    death_fraction: f64,
    trace: &TraceHandle,
    req_base: u64,
) -> ChurnStrategyReport {
    let mut workload = Workload::new(spec.clone(), cfg.seed);
    let requests = workload.take(n_requests);
    let mut grid = SimGrid::build(cfg, spec, replicas_per_file, 64);
    grid.warm(warm);
    let broker = grid.broker(RankPolicy::ForecastBandwidth { engine: None });
    let ad = request_ad();

    let mut report = ChurnStrategyReport {
        strategy: name.to_string(),
        attempts: 0,
        completed: 0,
        failed: 0,
        mean_time: 0.0,
        failovers: 0,
        blocks_requeued: 0,
        steals: 0,
    };
    let mut durations = Vec::new();
    // Absolute arrival instants from the post-warm clock — the same
    // arithmetic the open-loop kernel uses (see `run_quality_trace`).
    let t0 = grid.topo.now;
    for (i, req) in requests.iter().enumerate() {
        let id = req_base + i as u64;
        grid.topo.advance_to(t0 + req.at);
        grid.publish_dynamics();
        trace.rec(grid.topo.now, id, Ev::Arrival);
        let logical = &grid.files[req.file];
        let size = grid.sizes[req.file];
        let sel = match broker.plan_access(logical, &ad, size, strategy) {
            Ok(s) => s,
            Err(_) => {
                trace.rec(grid.topo.now, id, Ev::RequestSkipped { reason: "no_replica" });
                continue;
            }
        };
        if sel.plan.assignments.is_empty() {
            trace.rec(grid.topo.now, id, Ev::RequestSkipped { reason: "no_replica" });
            continue;
        }
        report.attempts += 1;
        if trace.on() {
            let now = grid.topo.now;
            let candidates = sel.plan.assignments.len() as u32;
            let name = sel.plan.assignments[0].source.site.clone();
            trace.with(|r| {
                let site = r.intern(&name);
                r.push(now, id, Ev::Selection { site, candidates });
            });
            sel.selection.trace.record_trace(trace, now, id);
        }
        // Kill the plan's largest stripe — the predicted-best source —
        // a fraction of the way into its own predicted makespan.
        let victim = sel
            .plan
            .assignments
            .iter()
            .max_by(|a, b| a.share.partial_cmp(&b.share).unwrap())
            .unwrap();
        let victim_site = grid.topo.index_of(&victim.source.site).unwrap();
        let makespan = sel.plan.predicted_makespan();
        let death_at = grid.topo.now
            + death_fraction * if makespan.is_finite() && makespan > 0.0 {
                makespan
            } else {
                size / 1e6
            };
        grid.topo.schedule_fault(victim_site, death_at, FaultKind::ReplicaDeath);

        // Execute on the live topology; a failed attempt rolls clock,
        // link state AND instrumentation history back, so later
        // requests in every strategy rank against identical conditions
        // (an aborted attempt's partial block records must not bias
        // the forecast the way a completed transfer's would).
        let topo_before = grid.topo.clone_for_probe();
        let hist_before: Vec<_> = (0..grid.topo.len())
            .map(|i| grid.ftp.history(i).read().unwrap().clone())
            .collect();
        match coalloc::execute(&mut grid.topo, &grid.ftp, "client", &sel.plan, exec_policy) {
            Ok(out) => {
                report.completed += 1;
                report.failovers += out.failovers;
                report.blocks_requeued += out.blocks_requeued;
                report.steals += out.steals;
                trace.rec(
                    out.started_at + out.duration,
                    id,
                    Ev::RequestDone { transfer_s: out.duration },
                );
                durations.push(out.duration);
            }
            Err(_) => {
                report.failed += 1;
                trace.rec(grid.topo.now, id, Ev::RequestSkipped { reason: "dead_source" });
                grid.topo = topo_before;
                for (i, h) in hist_before.into_iter().enumerate() {
                    *grid.ftp.history(i).write().unwrap() = h;
                }
            }
        }
        grid.topo.clear_faults();
    }
    report.mean_time = if durations.is_empty() {
        0.0
    } else {
        durations.iter().sum::<f64>() / durations.len() as f64
    };
    report
}

/// Replay the synthetic workload under mid-transfer replica death with
/// each of the three Access strategies (identically seeded grids).
/// `death_fraction` places the kill at that fraction of each plan's
/// predicted makespan (0.5 = halfway through).
pub fn run_churn(
    cfg: &GridConfig,
    spec: &WorkloadSpec,
    n_requests: usize,
    replicas_per_file: usize,
    warm: usize,
    policy: &CoallocPolicy,
    death_fraction: f64,
) -> ChurnReport {
    run_churn_traced(
        cfg,
        spec,
        n_requests,
        replicas_per_file,
        warm,
        policy,
        death_fraction,
        &TraceHandle::disabled(),
    )
}

/// [`run_churn`] with the flight recorder attached: each strategy's
/// request lifecycle roots land in `trace` under a disjoint request-id
/// band (strategy index × [`CHURN_REQ_STRIDE`]), so one trace file
/// holds all three replays without id collisions.
#[allow(clippy::too_many_arguments)]
pub fn run_churn_traced(
    cfg: &GridConfig,
    spec: &WorkloadSpec,
    n_requests: usize,
    replicas_per_file: usize,
    warm: usize,
    policy: &CoallocPolicy,
    death_fraction: f64,
    trace: &TraceHandle,
) -> ChurnReport {
    let no_failover = CoallocPolicy { max_block_retries: 0, ..policy.clone() };
    let with_failover = CoallocPolicy {
        max_block_retries: policy.max_block_retries.max(1),
        ..policy.clone()
    };
    ChurnReport {
        single_best: replay(
            "single-best",
            cfg,
            spec,
            n_requests,
            replicas_per_file,
            warm,
            &AccessStrategy::SingleBest,
            &no_failover,
            death_fraction,
            trace,
            0,
        ),
        striped: replay(
            "striped",
            cfg,
            spec,
            n_requests,
            replicas_per_file,
            warm,
            &AccessStrategy::Coallocated(no_failover.clone()),
            &no_failover,
            death_fraction,
            trace,
            CHURN_REQ_STRIDE,
        ),
        striped_failover: replay(
            "striped-failover",
            cfg,
            spec,
            n_requests,
            replicas_per_file,
            warm,
            &AccessStrategy::Coallocated(with_failover.clone()),
            &with_failover,
            death_fraction,
            trace,
            2 * CHURN_REQ_STRIDE,
        ),
    }
}

/// Request-id band width separating the three strategies' lifecycle
/// roots in one shared trace.
pub const CHURN_REQ_STRIDE: u64 = 1_000_000;

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> (GridConfig, WorkloadSpec, CoallocPolicy) {
        // Similar (not identical) site profiles so every plan stripes
        // over several sources — the failover-completes-everything
        // claim needs survivors to exist, which a grid of extreme
        // stragglers cannot promise.
        let mut cfg = GridConfig::generate(6, 2026);
        for (i, s) in cfg.sites.iter_mut().enumerate() {
            s.wan_bandwidth = 1.0e6 + 0.2e6 * i as f64;
            s.diurnal_amp = 0.1;
            s.noise_frac = 0.05;
            s.congestion_prob = 0.0;
            s.disk_rate = 1e8;
        }
        let spec = WorkloadSpec { files: 6, mean_interarrival: 200.0, ..Default::default() };
        let policy = CoallocPolicy {
            block_size: 8.0 * 1024.0 * 1024.0,
            max_streams: 4,
            tick: 2.0,
            max_block_retries: 3,
            ..Default::default()
        };
        (cfg, spec, policy)
    }

    #[test]
    fn failover_survives_churn_that_kills_the_others() {
        let (cfg, spec, policy) = small();
        let r = run_churn(&cfg, &spec, 12, 4, 4, &policy, 0.5);
        assert!(r.striped_failover.attempts > 0);
        // The headline: with failover every attempt completes…
        assert_eq!(
            r.striped_failover.completed, r.striped_failover.attempts,
            "failover must absorb mid-transfer deaths: {:?}",
            r.striped_failover
        );
        assert!(r.striped_failover.failovers > 0, "deaths were injected");
        // …while the fail-fast strategies lose requests to the same
        // churn (the predicted-best source dies mid-transfer).
        assert!(
            r.single_best.failed > 0,
            "single-best should lose requests: {:?}",
            r.single_best
        );
        assert!(
            r.striped.completed <= r.striped_failover.completed,
            "failover cannot complete less than fail-fast striping"
        );
    }

    #[test]
    fn churn_report_is_deterministic() {
        let (cfg, spec, policy) = small();
        let a = run_churn(&cfg, &spec, 6, 3, 3, &policy, 0.5);
        let b = run_churn(&cfg, &spec, 6, 3, 3, &policy, 0.5);
        for (x, y) in a.strategies().iter().zip(b.strategies().iter()) {
            assert_eq!(x.completed, y.completed);
            assert_eq!(x.failed, y.failed);
            assert_eq!(x.mean_time, y.mean_time);
            assert_eq!(x.failovers, y.failovers);
        }
    }
}
