//! Assembly of a full in-process data grid.

use std::sync::{Arc, Mutex, RwLock};

use crate::broker::{Broker, HierDiscovery, LocalInfoService, RankPolicy};
use crate::catalog::{MetadataRepository, PhysicalLocation, ReplicaCatalog};
use crate::config::GridConfig;
use crate::directory::entry::Entry;
use crate::directory::hier::HierarchicalDirectory;
use crate::directory::gris::{Gris, Provider};
use crate::gridftp::GridFtp;
use crate::simnet::{Topology, Workload, WorkloadSpec};
use crate::util::prng::Rng;

/// Dynamic per-site state shared between the simulation loop and the
/// site's GRIS providers (the "shell backend" data source).
#[derive(Debug, Default)]
pub struct SiteDynamics {
    pub available_space: f64,
    pub load: f64,
}

/// A complete simulated grid.
pub struct SimGrid {
    pub cfg: GridConfig,
    pub topo: Topology,
    pub ftp: GridFtp,
    pub catalog: Arc<Mutex<ReplicaCatalog>>,
    pub metadata: MetadataRepository,
    pub info: Arc<LocalInfoService>,
    pub dynamics: Vec<Arc<RwLock<SiteDynamics>>>,
    /// file index → logical name.
    pub files: Vec<String>,
    /// file index → size in bytes.
    pub sizes: Vec<f64>,
    /// file index → replica site indices.
    pub placement: Vec<Vec<usize>>,
    /// Space ledger: `(file index, site index)` → bytes the replica's
    /// creation **actually consumed** on the volume
    /// (`Topology::consume_space`'s applied delta, which a store into
    /// a nearly-full volume clamps below the file size). Deletion
    /// reclaims exactly the ledgered amount, so create→delete
    /// round-trips conserve `used` bit-for-bit. Seed replicas placed by
    /// [`SimGrid::build`] are *not* ledgered — they live inside the
    /// site's configured `used_frac` abstraction and reclaim
    /// `sizes[f]` (clamped at zero by the topology) if ever deleted.
    pub space_ledger: std::collections::BTreeMap<(usize, usize), f64>,
}

impl SimGrid {
    /// Build a grid: sites from `cfg`, `spec.files` logical files each
    /// replicated at `replicas_per_file` distinct random sites, GRIS
    /// per site with live providers, history window `window`.
    pub fn build(
        cfg: &GridConfig,
        spec: &WorkloadSpec,
        replicas_per_file: usize,
        window: usize,
    ) -> SimGrid {
        let topo = Topology::build(cfg);
        let ftp = GridFtp::new(&topo, window);
        let mut catalog = ReplicaCatalog::new();
        let mut metadata = MetadataRepository::new();
        let mut info = LocalInfoService::new();
        let mut rng = Rng::new(cfg.seed ^ 0x6121D);

        // Dynamic state handles.
        let dynamics: Vec<Arc<RwLock<SiteDynamics>>> = (0..topo.len())
            .map(|i| {
                Arc::new(RwLock::new(SiteDynamics {
                    available_space: topo.site(i).available_space(),
                    load: 0.0,
                }))
            })
            .collect();

        // One GRIS per site with Figure-2 static entry + providers.
        for i in 0..topo.len() {
            let sc = &topo.site(i).cfg;
            let mut gris = Gris::new(&sc.org, &sc.name);
            let base = gris.base_dn().clone();
            let vol = base.child("gss", "vol0");
            let mut e = Entry::new(vol.clone());
            e.add("objectClass", "GridStorageServerVolume");
            e.put_f64("totalSpace", sc.total_space);
            e.put_f64("availableSpace", 0.0); // provider overwrites
            e.put("mountPoint", "/data");
            e.put_f64("diskTransferRate", sc.disk_rate);
            e.put_f64("drdTime", sc.drd_time_ms);
            e.put_f64("dwrTime", sc.dwr_time_ms);
            gris.add_entry(e);
            let dyn_handle = dynamics[i].clone();
            let p: Provider = Arc::new(move || {
                let d = dyn_handle.read().unwrap();
                vec![
                    (
                        "availableSpace".to_string(),
                        crate::directory::entry::format_f64(d.available_space),
                    ),
                    ("load".to_string(), format!("{:.4}", d.load)),
                ]
            });
            gris.add_provider(&vol, p);

            // Figure-4 + Figure-5 entries fed live from instrumentation.
            let mut bw = Entry::new(vol.child("gss", "bw"));
            bw.add("objectClass", "GridStorageTransferBandwidth");
            gris.add_entry(bw);
            let hist_handle = ftp.history(i);
            let p4: Provider = Arc::new(move || hist_handle.write().unwrap().fig4_attributes());
            gris.add_provider(&vol.child("gss", "bw"), p4);

            let mut src = Entry::new(vol.child("gss", "src"));
            src.add("objectClass", "GridStorageSourceTransferBandwidth");
            gris.add_entry(src);
            let hist_handle5 = ftp.history(i);
            let p5: Provider = Arc::new(move || {
                // Per-source data for the (single) client population —
                // the sim's clients share a vantage point, matching the
                // paper's "per source basis" with source = client org.
                hist_handle5.write().unwrap().fig5_attributes("client")
            });
            gris.add_provider(&vol.child("gss", "src"), p5);
            // §7 future-work loop: the NWS-style predictive feed
            // publishes predictedRDBandwidth into the same entry.
            let feed = crate::forecast::PredictiveFeed::new(ftp.history(i));
            gris.add_provider(&vol.child("gss", "src"), feed.provider("client"));

            info.add(&sc.name, Arc::new(RwLock::new(gris)));
        }

        // Logical files: sizes, placement, catalog, metadata.
        let sizes = Workload::file_sizes(spec, cfg.seed, 80.0);
        let mut files = Vec::with_capacity(spec.files);
        let mut placement = Vec::with_capacity(spec.files);
        for f in 0..spec.files {
            let name = format!("file{f:04}.dat");
            catalog
                .create_logical(&name, crate::util::units::Bytes(sizes[f]), "sim")
                .unwrap();
            metadata.describe(&name, &[("collection", "sim"), ("index", &f.to_string())]);
            let k = replicas_per_file.min(topo.len());
            let mut sites: Vec<usize> = (0..topo.len()).collect();
            rng.shuffle(&mut sites);
            let mut chosen = sites[..k].to_vec();
            chosen.sort_unstable();
            for &s in &chosen {
                catalog
                    .add_replica(
                        &name,
                        PhysicalLocation {
                            site: topo.site(s).cfg.name.clone(),
                            url: format!("gsiftp://{}/{name}", topo.site(s).cfg.name),
                        },
                    )
                    .unwrap();
            }
            placement.push(chosen);
            files.push(name);
        }

        SimGrid {
            cfg: cfg.clone(),
            topo,
            ftp,
            catalog: Arc::new(Mutex::new(catalog)),
            metadata,
            info: Arc::new(info),
            dynamics,
            files,
            sizes,
            placement,
            space_ledger: std::collections::BTreeMap::new(),
        }
    }

    /// Refresh the dynamic state published by each GRIS from the live
    /// topology (called by the simulation loop between requests).
    pub fn publish_dynamics(&self) {
        for i in 0..self.topo.len() {
            self.publish_site(i);
        }
    }

    /// Refresh one site's published dynamics — what a single drill-down
    /// query needs; publishing the whole grid per query event would be
    /// O(sites × queries) at scale.
    pub fn publish_site(&self, i: usize) {
        // A down site's GRIS cannot answer its providers: the last
        // snapshot published before the outage persists and goes stale,
        // exactly what a real client staring at a dead MDS entry sees.
        // Liveness-filtered refresh paths (the open-loop soft-state
        // tick) skip the site entirely, so its registration ages out.
        if !self.topo.site_alive(i) {
            return;
        }
        let mut d = self.dynamics[i].write().unwrap();
        d.available_space = self.topo.site(i).available_space();
        d.load = self.topo.site(i).load();
    }

    /// A broker (decentralized — one per client) over this grid.
    pub fn broker(&self, policy: RankPolicy) -> Broker {
        Broker::new(self.catalog.clone(), self.info.clone(), policy)
    }

    /// A hierarchical directory over this grid's GRIS servers:
    /// registrations live `ttl` simulated seconds and are pushed once
    /// at the current clock (callers re-push via
    /// `HierarchicalDirectory::refresh_all` to model soft-state
    /// refresh; see `experiment::run_scale`).
    pub fn hierarchy(&self, ttl: f64) -> Arc<RwLock<HierarchicalDirectory>> {
        self.hierarchy_range(ttl, 0, self.topo.len())
    }

    /// A hierarchical directory over only the GRIS servers of topology
    /// sites `lo..hi` — one broker shard's GIIS registration domain
    /// (ISSUE 8). `hierarchy` is the `0..len` special case, so a
    /// 1-shard partition builds the exact directory the unsharded path
    /// builds: same sites, added in the same (name-sorted) iteration
    /// order, refreshed by the same `refresh_all` pass.
    pub fn hierarchy_range(
        &self,
        ttl: f64,
        lo: usize,
        hi: usize,
    ) -> Arc<RwLock<HierarchicalDirectory>> {
        let owned: std::collections::BTreeSet<&str> = (lo..hi.min(self.topo.len()))
            .map(|i| self.topo.site(i).cfg.name.as_str())
            .collect();
        let mut dir = HierarchicalDirectory::new(ttl);
        for (site, gris) in self.info.iter() {
            if owned.contains(site) {
                dir.add_site(site, gris.clone());
            }
        }
        dir.advance_to(self.topo.now);
        dir.refresh_all();
        Arc::new(RwLock::new(dir))
    }

    /// A broker whose Search phase routes through the hierarchical
    /// GIIS → GRIS drill-down path.
    pub fn broker_hier(
        &self,
        policy: RankPolicy,
        dir: Arc<RwLock<HierarchicalDirectory>>,
        drill_down: usize,
    ) -> Broker {
        self.broker(policy)
            .with_discovery(HierDiscovery { dir, drill_down, degrade: false })
    }

    /// Warm per-site histories with `n` probe transfers each.
    pub fn warm(&mut self, n: usize) {
        self.ftp.warm(&mut self.topo, "client", n, 8.0 * 1024.0 * 1024.0);
        self.publish_dynamics();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classad::parse_classad;

    fn grid() -> SimGrid {
        let cfg = GridConfig::generate(5, 77);
        let spec = WorkloadSpec { files: 6, ..Default::default() };
        SimGrid::build(&cfg, &spec, 3, 16)
    }

    #[test]
    fn builds_catalog_and_placement() {
        let g = grid();
        let cat = g.catalog.lock().unwrap();
        assert_eq!(cat.len(), 6);
        for (f, sites) in g.placement.iter().enumerate() {
            assert_eq!(sites.len(), 3);
            assert_eq!(cat.locate(&g.files[f]).unwrap().len(), 3);
        }
    }

    #[test]
    fn gris_publishes_live_dynamics() {
        let mut g = grid();
        g.warm(2);
        let site0 = g.topo.site(0).cfg.name.clone();
        let broker = g.broker(RankPolicy::ClassAdRank);
        let req = parse_classad("requirement = TRUE;").unwrap();
        // Find a file with a replica on site 0 to exercise the path.
        let f = g
            .placement
            .iter()
            .position(|sites| sites.contains(&0))
            .expect("some file on site 0");
        let (cands, _) = broker.search(&g.files[f], &req).unwrap();
        let c0 = cands.iter().find(|c| c.site == site0).unwrap();
        assert!(c0.ad.number("availableSpace").unwrap() > 0.0);
        assert!(c0.ad.number("AvgRDBandwidth").unwrap() > 0.0);
        assert!(!c0.history.is_empty(), "warm transfers must appear in rdHistory");
    }

    #[test]
    fn metadata_identifies_files() {
        let g = grid();
        assert_eq!(g.metadata.identify(&[("index", "3")]), Some("file0003.dat"));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = grid();
        let b = grid();
        assert_eq!(a.placement, b.placement);
        assert_eq!(a.sizes, b.sizes);
    }
}
