//! Selection-quality experiment (EXPERIMENTS.md R7 — the headline).
//!
//! Replays the same workload under each selection policy on identically
//! seeded grids and scores achieved transfer time against the
//! clairvoyant oracle (which probes every replica — link-locally, via
//! [`crate::simnet::Topology::probe_transfer`], not by deep-cloning
//! the topology per candidate — before choosing).
//!
//! [`run_quality_trace`] is the *serial replay*: the clock jumps to
//! each arrival and the transfer is costed in closed form, alone on
//! the grid — the legacy semantics the open-loop kernel
//! ([`super::open_loop`]) must reproduce exactly at concurrency 1 (the
//! `it_contention` parity test pins this). Cross-request contention
//! lives in the open-loop drivers, not here.

use crate::broker::selectors::{Selector, SelectorKind};
use crate::broker::RankPolicy;
use crate::classad::{parse_classad, symmetric_match, ClassAd};
use crate::coalloc;
use crate::config::{CoallocPolicy, GridConfig};
use crate::simnet::{Request, Workload, WorkloadSpec};

use super::grid::SimGrid;

/// Aggregated outcome of one policy's run.
#[derive(Debug, Clone)]
pub struct QualityReport {
    pub policy: String,
    pub requests: usize,
    /// Mean transfer duration (s).
    pub mean_time: f64,
    /// 95th percentile duration (s).
    pub p95_time: f64,
    /// Mean achieved bandwidth (bytes/s).
    pub mean_bandwidth: f64,
    /// Fraction of requests where the policy picked the oracle-best
    /// replica.
    pub pct_optimal: f64,
    /// Mean slowdown vs the oracle pick (1.0 = always optimal).
    pub mean_slowdown: f64,
}

pub(crate) fn request_ad(min_bw: f64) -> ClassAd {
    if min_bw > 0.0 {
        parse_classad(&format!(
            "hostname = \"client\"; reqdSpace = 0; reqdRDBandwidth = {min_bw}; \
             requirement = other.AvgRDBandwidth > {min_bw};"
        ))
        .unwrap()
    } else {
        parse_classad("hostname = \"client\"; reqdSpace = 0; requirement = TRUE;").unwrap()
    }
}

/// One request's Search + Match + oracle + pick — the per-request
/// selection logic the serial replay and the open-loop kernel drivers
/// share, so the parity between them is structural.
pub(crate) struct PickOutcome {
    /// Topology index of the policy's chosen source.
    pub pick_site: usize,
    /// Topology index of the oracle-best source.
    pub best_site: usize,
    /// The oracle-best probe duration (s).
    pub best_oracle: f64,
}

pub(crate) fn pick_replica(
    grid: &SimGrid,
    broker: &crate::broker::Broker,
    selector: &mut Selector,
    kind: SelectorKind,
    logical: &str,
    size: f64,
    ad: &ClassAd,
) -> PickOutcome {
    // The candidate view every policy sees (Search + convert).
    let (cands, _trace) = broker.search(logical, ad).expect("search");
    pick_from_candidates(grid, broker, selector, kind, &cands, size, ad)
        .expect("search yielded no candidates")
}

/// [`pick_replica`] from an already-assembled candidate set — the
/// entry point for drivers that gather candidates themselves (the
/// event-driven discovery path assembles a mix of fresh drill-down
/// answers and stale GIIS snapshots before selecting). Returns `None`
/// when `cands` is empty (nothing was discovered).
pub(crate) fn pick_from_candidates(
    grid: &SimGrid,
    broker: &crate::broker::Broker,
    selector: &mut Selector,
    kind: SelectorKind,
    cands: &[crate::broker::Candidate],
    size: f64,
    ad: &ClassAd,
) -> Option<PickOutcome> {
    if cands.is_empty() {
        return None;
    }
    // Requirements filter (Match phase step 2).
    let matched: Vec<usize> = cands
        .iter()
        .enumerate()
        .filter(|(_, c)| symmetric_match(ad, &c.ad))
        .map(|(i, _)| i)
        .collect();
    // Unsatisfiable constraint: fall back to all replicas (the
    // request still needs the file).
    let eligible = if matched.is_empty() {
        (0..cands.len()).collect::<Vec<_>>()
    } else {
        matched
    };

    // Oracle: probe every eligible replica. `probe_transfer` clones
    // only the one link it costs, so this is O(eligible) link clones
    // per request instead of O(eligible) full-topology deep copies.
    let site_indices: Vec<usize> = eligible
        .iter()
        .map(|&i| grid.topo.index_of(&cands[i].site).unwrap())
        .collect();
    let mut best_oracle = f64::INFINITY;
    let mut best_site = site_indices[0];
    for &s in &site_indices {
        let (d, _) = grid.topo.probe_transfer(s, size, 0);
        if d < best_oracle {
            best_oracle = d;
            best_site = s;
        }
    }

    // The policy's pick.
    let pick_idx = match kind {
        SelectorKind::Forecast => {
            let mut trace = crate::broker::BrokerTrace::default();
            let ranked = broker.match_phase(ad, cands, &mut trace);
            ranked
                .iter()
                .find(|r| eligible.contains(&r.index))
                .map(|r| r.index)
                .unwrap_or(eligible[0])
        }
        _ => selector.pick(cands, &eligible),
    };
    Some(PickOutcome {
        pick_site: grid.topo.index_of(&cands[pick_idx].site).unwrap(),
        best_site,
        best_oracle,
    })
}

/// Fold per-request measurements into a [`QualityReport`] — shared by
/// the serial and open-loop drivers so the aggregation arithmetic (and
/// therefore the parity) is identical to the last bit.
pub(crate) fn finish_report(
    policy: &str,
    mut durations: Vec<f64>,
    bandwidths: &[f64],
    slowdowns: &[f64],
    optimal_hits: usize,
) -> QualityReport {
    let n = durations.len();
    if n == 0 {
        return QualityReport {
            policy: policy.to_string(),
            requests: 0,
            mean_time: 0.0,
            p95_time: 0.0,
            mean_bandwidth: 0.0,
            pct_optimal: 0.0,
            mean_slowdown: 0.0,
        };
    }
    durations.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean_time = durations.iter().sum::<f64>() / durations.len() as f64;
    let p95_time = durations[(durations.len() as f64 * 0.95) as usize % durations.len()];
    QualityReport {
        policy: policy.to_string(),
        requests: n,
        mean_time,
        p95_time,
        mean_bandwidth: bandwidths.iter().sum::<f64>() / bandwidths.len() as f64,
        pct_optimal: optimal_hits as f64 / n as f64,
        mean_slowdown: slowdowns.iter().sum::<f64>() / slowdowns.len() as f64,
    }
}

/// Run `n_requests` of the synthetic workload under `kind` and score
/// against the oracle.
///
/// `engine`: PJRT forecast engine for the `Forecast` selector when
/// artifacts are built (None → pure-Rust bank; numerically equivalent).
pub fn run_quality(
    cfg: &GridConfig,
    spec: &WorkloadSpec,
    n_requests: usize,
    replicas_per_file: usize,
    warm: usize,
    kind: SelectorKind,
    engine: Option<std::sync::Arc<crate::runtime::engine::EngineHandle>>,
) -> QualityReport {
    let mut workload = Workload::new(spec.clone(), cfg.seed);
    let requests = workload.take(n_requests);
    run_quality_trace(cfg, spec, &requests, replicas_per_file, warm, kind, engine)
}

/// Replay an explicit request trace (recorded or synthetic — see
/// `simnet::trace`) under `kind` and score against the oracle.
pub fn run_quality_trace(
    cfg: &GridConfig,
    spec: &WorkloadSpec,
    requests: &[Request],
    replicas_per_file: usize,
    warm: usize,
    kind: SelectorKind,
    engine: Option<std::sync::Arc<crate::runtime::engine::EngineHandle>>,
) -> QualityReport {
    let n_requests = requests.len();
    let mut grid = SimGrid::build(cfg, spec, replicas_per_file, 64);
    grid.warm(warm);
    let mut selector = Selector::new(kind, cfg.seed);
    let policy = match kind {
        SelectorKind::Forecast => RankPolicy::ForecastBandwidth { engine: engine.clone() },
        _ => RankPolicy::ClassAdRank,
    };
    let broker = grid.broker(policy.clone());

    let mut durations = Vec::with_capacity(n_requests);
    let mut bandwidths = Vec::with_capacity(n_requests);
    let mut optimal_hits = 0usize;
    let mut slowdowns = Vec::with_capacity(n_requests);
    // Arrivals are absolute offsets from the post-warm clock — the
    // same arithmetic the event kernel uses to schedule them, so the
    // concurrency-1 kernel run reproduces this replay bit-for-bit.
    let t0 = grid.topo.now;

    for req in requests {
        grid.topo.advance_to(t0 + req.at);
        grid.publish_dynamics();
        let logical = grid.files[req.file].clone();
        let size = grid.sizes[req.file];
        let ad = request_ad(req.min_bandwidth);
        let pick = pick_replica(&grid, &broker, &mut selector, kind, &logical, size, &ad);

        // Access phase: the real transfer (advances link state).
        let out = grid.ftp.fetch(&mut grid.topo, pick.pick_site, "client", size);
        durations.push(out.duration);
        bandwidths.push(out.bandwidth);
        if pick.pick_site == pick.best_site {
            optimal_hits += 1;
        }
        slowdowns.push(out.duration / pick.best_oracle.max(1e-9));
    }

    finish_report(kind.name(), durations, &bandwidths, &slowdowns, optimal_hits)
}

/// Aggregated outcome of the single-best vs co-allocated comparison.
#[derive(Debug, Clone)]
pub struct CoallocReport {
    /// Requests actually executed (selection failures are skipped).
    pub requests: usize,
    /// Mean duration the best single-source fetch would have taken,
    /// measured per request on a probe copy of the topology.
    pub single_mean_time: f64,
    /// Mean duration of the co-allocated transfer (executed for real).
    pub coalloc_mean_time: f64,
    /// `single_mean_time / coalloc_mean_time` (>1 ⇒ striping wins).
    pub speedup: f64,
    /// Mean number of streams per transfer.
    pub mean_streams: f64,
    /// Total work-stealing events across all transfers.
    pub steals: usize,
}

/// Replay the synthetic workload with the co-allocated Access strategy
/// and score it against the best single-source fetch of each request.
///
/// Both alternatives see identical link state: the single-source cost
/// is measured with [`crate::simnet::Topology::probe_transfer`] (the
/// same upcoming RNG stream, consumed on a link-local clone), then the
/// striped transfer executes on the real topology, feeding the
/// per-site history stores.
pub fn run_coalloc_quality(
    cfg: &GridConfig,
    spec: &WorkloadSpec,
    n_requests: usize,
    replicas_per_file: usize,
    warm: usize,
    policy: &CoallocPolicy,
) -> CoallocReport {
    let mut workload = Workload::new(spec.clone(), cfg.seed);
    let requests = workload.take(n_requests);
    let mut grid = SimGrid::build(cfg, spec, replicas_per_file, 64);
    grid.warm(warm);
    let broker = grid.broker(RankPolicy::ForecastBandwidth { engine: None });

    let mut single = Vec::with_capacity(n_requests);
    let mut co = Vec::with_capacity(n_requests);
    let mut steals = 0usize;
    let mut streams_total = 0usize;
    let t0 = grid.topo.now;
    for req in &requests {
        grid.topo.advance_to(t0 + req.at);
        grid.publish_dynamics();
        let logical = &grid.files[req.file];
        let size = grid.sizes[req.file];
        let ad = request_ad(req.min_bandwidth);
        let sel = match broker.select_coalloc(logical, &ad, size, policy) {
            Ok(s) => s,
            Err(_) => continue,
        };
        // The best single-source Access, costed link-locally with the
        // same sharing convention as `GridFtp::fetch` (the transfer
        // registers itself: one extra stream on the probe).
        let best_site = grid.topo.index_of(&sel.selection.site).unwrap();
        let (d_single, _) = grid.topo.probe_transfer(best_site, size, 1);
        // The co-allocated Access, executed for real: instrumentation
        // lands in the same history stores the GRIS providers publish.
        // A transfer that fails to converge is skipped — and the
        // topology (clock + link state) is rolled back to the
        // pre-transfer snapshot, since a failed execution may have
        // advanced simulated time by its whole tick budget, which
        // would poison every later measurement.
        let before = grid.topo.clone_for_probe();
        let out = match coalloc::execute(&mut grid.topo, &grid.ftp, "client", &sel.plan, policy)
        {
            Ok(out) => out,
            Err(_) => {
                grid.topo = before;
                continue;
            }
        };
        single.push(d_single);
        co.push(out.duration);
        steals += out.steals;
        streams_total += out.streams.len();
    }
    let n = co.len();
    let mean = |v: &[f64]| {
        if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<f64>() / v.len() as f64
        }
    };
    let single_mean_time = mean(&single);
    let coalloc_mean_time = mean(&co);
    CoallocReport {
        requests: n,
        single_mean_time,
        coalloc_mean_time,
        speedup: if coalloc_mean_time > 0.0 {
            single_mean_time / coalloc_mean_time
        } else {
            1.0
        },
        mean_streams: if n > 0 { streams_total as f64 / n as f64 } else { 0.0 },
        steals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> (GridConfig, WorkloadSpec) {
        let cfg = GridConfig::generate(6, 1234);
        let spec = WorkloadSpec { files: 8, mean_interarrival: 120.0, ..Default::default() };
        (cfg, spec)
    }

    #[test]
    fn reports_are_sane() {
        let (cfg, spec) = small();
        let r = run_quality(&cfg, &spec, 40, 3, 4, SelectorKind::Random, None);
        assert_eq!(r.requests, 40);
        assert!(r.mean_time > 0.0);
        assert!(r.p95_time >= r.mean_time * 0.2);
        assert!((0.0..=1.0).contains(&r.pct_optimal));
        assert!(r.mean_slowdown >= 0.99, "slowdown {}", r.mean_slowdown);
    }

    #[test]
    fn forecast_beats_random_on_heterogeneous_grid() {
        // The paper's core qualitative claim (R7): informed,
        // history-based selection outperforms uninformed selection.
        let (cfg, spec) = small();
        let rnd = run_quality(&cfg, &spec, 60, 3, 6, SelectorKind::Random, None);
        let fc = run_quality(&cfg, &spec, 60, 3, 6, SelectorKind::Forecast, None);
        assert!(
            fc.mean_time < rnd.mean_time,
            "forecast {:.1}s !< random {:.1}s",
            fc.mean_time,
            rnd.mean_time
        );
        assert!(fc.pct_optimal > rnd.pct_optimal);
    }

    #[test]
    fn deterministic_given_seed() {
        let (cfg, spec) = small();
        let a = run_quality(&cfg, &spec, 20, 3, 2, SelectorKind::RoundRobin, None);
        let b = run_quality(&cfg, &spec, 20, 3, 2, SelectorKind::RoundRobin, None);
        assert_eq!(a.mean_time, b.mean_time);
        assert_eq!(a.pct_optimal, b.pct_optimal);
    }

    #[test]
    fn coalloc_beats_single_best_with_enough_replicas() {
        let (cfg, spec) = small();
        let policy = CoallocPolicy { block_size: 8.0 * 1024.0 * 1024.0, ..Default::default() };
        let r = run_coalloc_quality(&cfg, &spec, 25, 4, 4, &policy);
        assert!(r.requests > 0);
        assert!(r.mean_streams > 1.5, "streams {}", r.mean_streams);
        assert!(
            r.coalloc_mean_time < r.single_mean_time,
            "coalloc {:.1}s !< single {:.1}s",
            r.coalloc_mean_time,
            r.single_mean_time
        );
        assert!(r.speedup > 1.0);
    }

    #[test]
    fn coalloc_report_deterministic() {
        let (cfg, spec) = small();
        let policy = CoallocPolicy::default();
        let a = run_coalloc_quality(&cfg, &spec, 10, 3, 3, &policy);
        let b = run_coalloc_quality(&cfg, &spec, 10, 3, 3, &policy);
        assert_eq!(a.coalloc_mean_time, b.coalloc_mean_time);
        assert_eq!(a.single_mean_time, b.single_mean_time);
        assert_eq!(a.steals, b.steals);
    }
}

#[cfg(test)]
mod trace_tests {
    use super::*;
    use crate::simnet::trace;

    #[test]
    fn replaying_the_same_trace_reproduces_the_report() {
        let cfg = GridConfig::generate(5, 71);
        let spec = WorkloadSpec { files: 6, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(25);
        let a = run_quality_trace(&cfg, &spec, &reqs, 3, 2, SelectorKind::Forecast, None);
        let b = run_quality_trace(&cfg, &spec, &reqs, 3, 2, SelectorKind::Forecast, None);
        assert_eq!(a.mean_time, b.mean_time);
        assert_eq!(a.pct_optimal, b.pct_optimal);
    }

    #[test]
    fn trace_file_round_trip_drives_the_pipeline() {
        let cfg = GridConfig::generate(5, 72);
        let spec = WorkloadSpec { files: 6, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(20);
        let path = std::env::temp_dir().join(format!("gr-q-{}.jsonl", std::process::id()));
        trace::save(&path, &reqs).unwrap();
        let loaded = trace::load(&path).unwrap();
        let direct = run_quality_trace(&cfg, &spec, &reqs, 3, 2, SelectorKind::Random, None);
        let replay = run_quality_trace(&cfg, &spec, &loaded, 3, 2, SelectorKind::Random, None);
        assert_eq!(direct.mean_time, replay.mean_time);
        std::fs::remove_file(&path).ok();
    }
}
