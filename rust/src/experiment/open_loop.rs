//! Open-loop experiment drivers on the discrete-event kernel
//! (ISSUE 4) — the contention regime the serial replay cannot reach.
//!
//! [`run_quality_open`] replays a request trace with arrivals admitted
//! at their Poisson instants on a [`crate::simnet::Engine`]: each
//! admitted request selects a replica against *live* in-flight load
//! (site dynamics republished at every admission, plus optional
//! periodic GRIS refresh ticks) and its transfer then occupies the
//! grid — a flow in the one shared `FlowSet` — until its completion
//! event fires, contending with every other in-flight transfer for
//! site links and per-client downlinks. With
//! [`OpenLoopOptions::serial`] the driver degrades to the legacy
//! closed-loop semantics exactly (concurrency 1, closed-form Access):
//! the `it_contention` parity test asserts bit-for-bit agreement with
//! [`super::run_quality_trace`].
//!
//! With [`OpenLoopOptions::discovery`] set (ISSUE 5), admission no
//! longer selects from omniscient fresh data: the broad query is
//! answered from GIIS soft-state snapshots and a bounded, event-driven
//! drill-down fan-out ([`crate::directory::fanout`]) fetches fresh
//! detail for the top candidates — each answer landing after that
//! site's simulated round trip — so selection runs on **stale-by-
//! construction, mixed-age** GRIS data, exactly as a real MDS client
//! would see it.
//!
//! With [`OpenLoopOptions::retry`] set (ISSUE 7), the driver survives
//! grid weather ([`OpenLoopOptions::faults`]): a per-flow progress
//! check detects transfers starved by a mid-flight crash or link flap,
//! cancels them, backs off exponentially (deterministic jitter), and
//! re-issues from the delivered byte offset — against the best
//! surviving replica when `failover` is on — under a bounded attempt
//! budget whose exhaustion is an explicit `gave_up` outcome. See
//! [`super::chaos::run_chaos`] for the fault-intensity × policy sweep
//! built on it.
//!
//! With a [`super::sharded::ShardOptions`] (ISSUE 8, via
//! [`super::run_quality_sharded`]), the control plane partitions along
//! the registration hierarchy: contiguous site shards
//! ([`crate::broker::ShardMap`]), one GIIS registration domain per
//! shard, and per-shard admission batches that republish site dynamics
//! once per flush instead of once per admission. The parity
//! configuration (1 shard, batch 1) collapses onto the unsharded
//! driver bit-for-bit (`it_shard`), and [`super::run_kernel`] drives
//! this path at 10⁵ concurrent transfers for the throughput bench.
//!
//! [`run_contention`] is the load sweep the paper's thesis wants:
//! arrival rate from idle to saturation, informed (Forecast) vs
//! uninformed (Random) selection on identical traces, reporting
//! mean/p95 time, makespan and the informed-vs-uninformed gap as
//! contention grows (`bench_contention` records it as
//! `BENCH_contention.json`).

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, RwLock};

use crate::broker::selectors::{Selector, SelectorKind};
use crate::broker::{
    entries_to_candidate, Broker, Candidate, Economy, EconomyAction, EconomyOptions,
    EconomyStats, RankPolicy, ShardMap,
};
use crate::broker::replication::ReplicaManager;
use crate::catalog::PhysicalLocation;
use crate::config::GridConfig;
use crate::directory::entry::Entry;
use crate::directory::fanout::{DirectoryFanout, FanoutPolicy, FanoutStep, QueryIds};
use crate::directory::hier::HierarchicalDirectory;
use crate::gridftp::{OpenFetch, OpenStore};
use crate::simnet::{
    Engine, Fault, FaultKind, FlowSet, Request, Signal, WeatherPlan, Workload, WorkloadSpec,
};
use crate::trace::{Ev, SiteId, TraceHandle, KERNEL_REQ, SAMPLE_REQ};
use crate::util::prng::Rng;

use super::grid::SimGrid;
use super::quality::{
    finish_report, pick_from_candidates, pick_replica, request_ad, PickOutcome, QualityReport,
};
use super::sharded::{ShardOptions, ShardStats};

/// Timer id of the recurring GRIS dynamics refresh.
const GRIS_TICK_ID: u64 = u64::MAX;
/// Timer id of the recurring GIIS soft-state re-registration push.
const REG_TICK_ID: u64 = u64::MAX - 1;
/// Timer id of the flight recorder's time-series sampler.
const SAMPLE_TICK_ID: u64 = u64::MAX - 2;
/// Timer id of the recurring replica-economy tick (ISSUE 10).
const ECONOMY_TICK_ID: u64 = u64::MAX - 3;
/// First id of the per-transfer retry/timeout timer range; the driver
/// allocates downward from here, so retry timers can never collide
/// with the reserved recurring ticks above.
const RETRY_TIMER_BASE: u64 = u64::MAX - 4;

/// How the open-loop driver executes an admitted request's Access
/// phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessMode {
    /// The legacy closed-form fetch (`GridFtp::fetch`): costed
    /// analytically at the admission instant, consuming no simulated
    /// time — the serial replay's semantics.
    Analytic,
    /// The transfer is registered as a flow in the kernel's shared
    /// `FlowSet` (`GridFtp::fetch_begin`); it occupies its site link
    /// and the client's downlink until the completion event fires, so
    /// concurrent requests contend.
    Flow,
}

/// Hierarchical-discovery configuration for the open-loop driver
/// (ISSUE 5): when set, an admitted request no longer selects
/// instantaneously from omniscient fresh data — it answers the broad
/// query from the GIIS's soft-state snapshots (stale by construction)
/// and runs an **event-driven drill-down fan-out** on the kernel, so
/// each fresh per-site answer arrives after that site's simulated
/// round-trip latency and selection happens at fan-out completion over
/// data of mixed ages — exactly what a real MDS client sees.
#[derive(Debug, Clone)]
pub struct DiscoveryOptions {
    /// Fresh GRIS drill-downs per admission (top-K by predicted
    /// bandwidth over the stale snapshots). 0 = summaries only.
    pub drill_down: usize,
    /// Bounds on the per-admission drill-down fan-out (in-flight cap,
    /// per-query deadline, straggler cutoff).
    pub fanout: FanoutPolicy,
    /// Registration TTL in simulated seconds — sites not re-registered
    /// within this window fall out of discovery entirely.
    pub registration_ttl: f64,
    /// Soft-state re-registration period (every site re-pushes its
    /// snapshot); `f64::INFINITY` = register once at the start.
    pub refresh_period: f64,
    /// Drill-down query round trip = `rtt_factor` × the site's one-way
    /// latency from the topology.
    pub rtt_factor: f64,
}

impl Default for DiscoveryOptions {
    fn default() -> Self {
        DiscoveryOptions {
            drill_down: 3,
            fanout: FanoutPolicy::default(),
            registration_ttl: 600.0,
            refresh_period: 120.0,
            rtt_factor: 2.0,
        }
    }
}

/// End-to-end transfer resilience (ISSUE 7): how the open-loop driver
/// reacts when an in-flight flow stops making progress — its source
/// crashed mid-transfer, or a link flap starved it. The driver arms a
/// progress-check timer per flow; a check that finds no new bytes (or
/// a dead source) cancels the flow, backs off exponentially with
/// deterministic jitter, re-selects among *surviving* replicas
/// (failover) or re-tries the original source (pinned), and resumes
/// from the delivered byte offset via
/// [`crate::gridftp::GridFtp::fetch_begin_range`]. A bounded attempt
/// budget turns the worst case into an explicit `gave_up` outcome
/// instead of an unbounded stall.
#[derive(Debug, Clone, Copy)]
pub struct RetryOptions {
    /// Progress-check period (s): a flow that delivered no new bytes
    /// over one whole period is declared stalled and cancelled.
    pub transfer_timeout: f64,
    /// Total attempt budget per request (1 = fail fast: the initial
    /// attempt only, no retry).
    pub max_attempts: u32,
    /// Backoff before attempt `n+1` is
    /// `min(backoff_base · backoff_factor^(n−1), backoff_max)`,
    /// jittered by ±`jitter_frac` (deterministic, seeded).
    pub backoff_base: f64,
    pub backoff_factor: f64,
    pub backoff_max: f64,
    pub jitter_frac: f64,
    /// Re-select among surviving replicas (`true`) or pin every retry
    /// to the originally chosen source (`false`).
    pub failover: bool,
}

impl Default for RetryOptions {
    fn default() -> Self {
        RetryOptions {
            transfer_timeout: 60.0,
            max_attempts: 4,
            backoff_base: 2.0,
            backoff_factor: 2.0,
            backoff_max: 60.0,
            jitter_frac: 0.2,
            failover: true,
        }
    }
}

impl RetryOptions {
    /// Retry with backoff but never switch sources — the middle arm of
    /// the chaos experiment's policy comparison.
    pub fn pinned() -> RetryOptions {
        RetryOptions { failover: false, ..RetryOptions::default() }
    }
}

/// Configuration of one open-loop run.
#[derive(Debug, Clone)]
pub struct OpenLoopOptions {
    pub access: AccessMode,
    /// Admission cap: arrivals beyond this many in-flight transfers
    /// queue FIFO and are admitted at completion instants.
    /// `usize::MAX` = pure open loop (no gate).
    pub max_in_flight: usize,
    /// Per-client downlink capacity in [`AccessMode::Flow`] (bytes/s);
    /// flows of the same workload client share it, different clients
    /// cap independently. `f64::INFINITY` leaves the WAN links as the
    /// only bottleneck.
    pub client_downlink: f64,
    /// Period of the recurring GRIS dynamics refresh tick; dynamics
    /// are also republished at every admission. `f64::INFINITY` =
    /// admission-driven refresh only.
    pub gris_refresh: f64,
    /// Route discovery through the hierarchical GIIS path with an
    /// event-driven drill-down fan-out. `None` (the default, and the
    /// parity-anchored legacy behaviour) selects instantaneously from
    /// fresh direct-GRIS data.
    pub discovery: Option<DiscoveryOptions>,
    /// Flight recorder ([`crate::trace`]): disabled by default, in
    /// which case every instrumentation point costs one branch and the
    /// run is bit-identical to an untraced one (the parity anchor).
    pub trace: TraceHandle,
    /// Time-series sampler cadence in simulated seconds (in-flight
    /// flows, gate depth, GIIS liveness, per-link utilization).
    /// `f64::INFINITY` (default) = no sampling; requires `trace`.
    pub sample_period: f64,
    /// Transfer resilience ([`RetryOptions`]). `None` (the default,
    /// and the parity-anchored legacy behaviour): a dead source at
    /// admission is skipped and a mid-flight death stalls until
    /// wind-down.
    pub retry: Option<RetryOptions>,
    /// Grid weather: a fault schedule with *relative* instants
    /// (t = 0 is the post-warm clock origin), applied onto the
    /// topology at the start of the run — typically
    /// [`crate::simnet::WeatherPlan::generate`]'s output. Empty
    /// (the default) leaves the run bit-identical to pre-weather
    /// builds.
    pub faults: Vec<Fault>,
    /// Replica economy (ISSUE 10): popularity-driven replication and
    /// eviction running on a recurring kernel tick, with replication
    /// traffic as real flows contending with foreground transfers.
    /// `None` (the default) schedules no tick and changes no event
    /// interleaving — the run is bit-identical to pre-economy builds
    /// (the parity anchor `it_economy` pins).
    pub economy: Option<EconomyOptions>,
}

impl OpenLoopOptions {
    /// Pure open loop: flow-based Access, no admission gate.
    pub fn open() -> OpenLoopOptions {
        OpenLoopOptions {
            access: AccessMode::Flow,
            max_in_flight: usize::MAX,
            client_downlink: f64::INFINITY,
            gris_refresh: f64::INFINITY,
            discovery: None,
            trace: TraceHandle::disabled(),
            sample_period: f64::INFINITY,
            retry: None,
            faults: Vec::new(),
            economy: None,
        }
    }

    /// The serial-replay configuration: concurrency 1 with the
    /// analytic Access primitive — the kernel expression of the legacy
    /// `run_quality_trace` loop, reproduced bit-for-bit (the parity
    /// anchor).
    pub fn serial() -> OpenLoopOptions {
        OpenLoopOptions {
            access: AccessMode::Analytic,
            max_in_flight: 1,
            ..OpenLoopOptions::open()
        }
    }
}

/// One request's life on the kernel.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    /// Index into the input request trace.
    pub request: usize,
    /// Topology index of the chosen source.
    pub site: usize,
    /// Admission instant (= arrival unless the admission gate queued
    /// it).
    pub admitted_at: f64,
    pub finished_at: f64,
    pub duration: f64,
    pub bandwidth: f64,
    /// The clairvoyant oracle's best probe duration at admission.
    pub oracle_best: f64,
    /// Whether the policy picked the oracle-best replica.
    pub hit_optimal: bool,
    /// Transfer attempts beyond the first (0 = clean first try).
    pub retries: u32,
    /// Instant the request first lost its transfer (stall detected or
    /// dead source), if it ever did — `finished_at − first_failure_at`
    /// is the request's time-to-recover, the chaos experiment's MTTR
    /// numerator.
    pub first_failure_at: Option<f64>,
}

/// Aggregate + per-request outcome of one open-loop run.
#[derive(Debug, Clone)]
pub struct OpenReport {
    pub quality: QualityReport,
    /// Simulated span from first admission to last completion.
    pub makespan: f64,
    /// Peak number of flow-based transfers simultaneously in flight
    /// (0 in the analytic configuration — those consume no time).
    pub peak_in_flight: usize,
    /// Admissions that happened while at least one transfer was
    /// already in flight — the overlap the serial replay forbids.
    pub overlapped_admissions: usize,
    /// Requests that never delivered: dead source at admission,
    /// transfers still stalled when the run wound down (their slots
    /// are released), or arrivals parked behind the admission gate at
    /// the end. `quality` covers only completed requests, so compare
    /// policies with an eye on this count.
    pub skipped: usize,
    /// Completed requests in completion order, with their flow
    /// start/finish instants — the data the overlap assertions and the
    /// contention bench read.
    pub per_request: Vec<RequestTrace>,
    /// Discovery-mode query accounting (broad lookups, drill-downs,
    /// refreshes); `None` on the legacy fresh-data path.
    pub discovery: Option<crate::directory::hier::DiscoveryStats>,
    /// Re-issued transfer attempts across the run (0 without
    /// [`OpenLoopOptions::retry`]).
    pub retries: usize,
    /// Retries that switched to a different source than the one that
    /// failed (⊆ `retries`).
    pub failovers: usize,
    /// Requests that exhausted their attempt budget. Disjoint from
    /// `skipped`: a gave-up request *tried* — its death is visible in
    /// the trace as `transfer_retry` events ending in a `gave_up`
    /// skip record.
    pub gave_up: usize,
    /// Kernel events polled by the run's event loop (arrivals,
    /// completions, query responses, timers — and the terminating
    /// poll, if the run drained). The kernel-throughput bench divides
    /// this by wall time.
    pub events: usize,
    /// Replica-economy accounting (pushes landed, evictions, bytes
    /// moved); `None` when the economy was off.
    pub economy: Option<EconomyStats>,
}

struct InFlight {
    request: usize,
    open: OpenFetch,
    oracle_best: f64,
    hit_optimal: bool,
    /// 1-based attempt number of this flow.
    attempt: u32,
    /// The request's original admission instant (survives retries;
    /// `open.started_at` restarts on every attempt).
    admitted_at: f64,
    first_failure_at: Option<f64>,
    /// Retries consumed so far by this request.
    retries: u32,
    /// Delivered bytes observed by the last progress check.
    last_delivered: f64,
}

/// What a driver-owned kernel timer means when it fires.
enum TimerKind {
    /// Per-flow progress check ([`RetryOptions::transfer_timeout`]).
    Timeout { flow: usize },
    /// A backed-off request's re-issue instant.
    Resume(PendingRetry),
    /// A shard's admission-batch window elapsed: flush whatever is
    /// queued, full or not (ISSUE 8).
    Flush { shard: usize },
}

/// A request between attempts: its flow was cancelled (stall, dead
/// source, or a failed re-issue) and it sits out its backoff before
/// re-selecting. It still holds its admission slot — the request is in
/// service, just not on the wire.
struct PendingRetry {
    request: usize,
    /// Attempts consumed so far.
    attempt: u32,
    /// Absolute byte offset already delivered (resume point).
    offset: f64,
    /// Bytes still owed.
    remaining: f64,
    /// Source of the failed attempt (the pinned policy's only
    /// candidate; failover avoids counting a re-pick of it).
    last_site: usize,
    oracle_best: f64,
    hit_optimal: bool,
    admitted_at: f64,
    first_failure_at: f64,
    retries: u32,
}

/// One admitted request whose discovery fan-out is still in flight:
/// the broad (stale) snapshots are in hand, fresh drill-down answers
/// accumulate as their query events land.
struct PendingDiscovery {
    request: usize,
    size: f64,
    /// Discovered replica slots in catalog order:
    /// (site name, replica URL, topology index).
    sites: Vec<(String, String, usize)>,
    /// Per-slot GIIS snapshot (stale by construction).
    stale: Vec<Vec<Entry>>,
    /// Per-slot fresh drill-down answer, once its response arrives.
    fresh: Vec<Option<Vec<Entry>>>,
    fanout: DirectoryFanout,
}

/// The sharded control plane of one run (ISSUE 8): the site
/// partition, per-shard admission batches, per-shard GIIS registration
/// domains, and per-shard outcome accounting. `None` = the unsharded
/// legacy driver, bit-for-bit the pre-shard behaviour.
struct ShardState {
    map: ShardMap,
    /// Admissions per shard batched before a flush (≥ 1; 1 = flush
    /// every arrival immediately — the parity configuration).
    batch_max: usize,
    /// Max simulated seconds an arrival may sit in a batch before a
    /// window timer flushes it regardless of depth. Non-positive or
    /// non-finite = no window timer (batches flush only when full).
    batch_window: f64,
    /// Per-shard FIFO admission batches (request ids awaiting flush).
    batches: Vec<VecDeque<u64>>,
    /// Whether shard `s` currently has a window timer armed. A flush
    /// clears it without cancelling the kernel timer; the stale fire
    /// flushes early, which only tightens the staleness bound.
    armed: Vec<bool>,
    /// Per-shard GIIS registration domains (discovery mode only; empty
    /// otherwise). Domain `s` holds exactly the registrations of
    /// `map.sites_of(s)`.
    domains: Vec<Arc<RwLock<HierarchicalDirectory>>>,
    /// Request id → home shard (plurality owner of its replica set,
    /// assigned at arrival) — the attribution key for the per-shard
    /// conservation invariant.
    home: Vec<usize>,
    /// Request id → whether its replica set spans shard boundaries.
    spans: Vec<bool>,
    stats: Vec<ShardStats>,
    /// Admissions whose replica set spanned shards — selections that
    /// had to consult foreign registration domains.
    cross_shard: usize,
}

/// Everything one open-loop run mutates, so the admission logic is a
/// method instead of a 12-argument function.
struct Driver<'a> {
    grid: &'a mut SimGrid,
    broker: Broker,
    selector: Selector,
    kind: SelectorKind,
    opts: &'a OpenLoopOptions,
    requests: &'a [Request],
    /// Workload client id → downlink group in the shared FlowSet.
    groups: Vec<usize>,
    /// Live flow id → in-flight transfer state.
    inflight: BTreeMap<usize, InFlight>,
    /// Arrivals parked by the admission gate, FIFO.
    waiting: VecDeque<u64>,
    /// Discovery mode only: the shared GIIS hierarchy (unsharded runs;
    /// a sharded run keeps its per-shard domains in [`ShardState`]).
    hier: Option<Arc<RwLock<HierarchicalDirectory>>>,
    /// Sharded control plane ([`ShardState`]); `None` = legacy driver.
    shard: Option<ShardState>,
    /// Kernel query-id allocator (unique across all fan-outs).
    qids: QueryIds,
    /// Live kernel query id → request id.
    qid_map: BTreeMap<u64, u64>,
    /// Request id → its in-flight discovery.
    pending_disc: BTreeMap<u64, PendingDiscovery>,
    /// Live driver-owned timers (progress checks, backoff resumes),
    /// keyed by kernel timer id. Stale ids (flow already completed)
    /// fire harmlessly and are dropped.
    timers: BTreeMap<u64, TimerKind>,
    /// Next retry-range timer id (allocated downward from
    /// [`RETRY_TIMER_BASE`]; never reused within a run).
    next_timer: u64,
    /// How many [`TimerKind::Resume`] entries are pending — requests
    /// holding admission slots while backing off.
    retry_waiting: usize,
    /// Deterministic jitter stream for backoff delays.
    retry_rng: Rng,
    /// Replica economy engine (`None` = off; no tick is scheduled).
    economy: Option<Economy>,
    /// Live economy push flows: flow id → (file index, open store).
    /// Checked before `inflight` on every completion — economy flows
    /// are background traffic, not admissions, so they hold no gate
    /// slot and produce no `RequestTrace`.
    econ_pushes: BTreeMap<usize, (usize, OpenStore)>,
    finished: Vec<RequestTrace>,
    peak_in_flight: usize,
    overlapped_admissions: usize,
    skipped: usize,
    retries: usize,
    failovers: usize,
    gave_up: usize,
    /// Post-warm clock origin; arrival instants are `t0 + req.at`
    /// (the flight recorder derives gate wait times from it).
    t0: f64,
}

impl Driver<'_> {
    /// Requests currently holding an admission slot: in-flight
    /// transfers, in-flight discoveries, and backed-off retries (a
    /// request occupies its slot from admission through its last byte
    /// or its give-up).
    fn occupancy(&self) -> usize {
        self.inflight.len() + self.pending_disc.len() + self.retry_waiting
    }

    /// Allocate a fresh driver timer id (downward from
    /// [`RETRY_TIMER_BASE`]).
    fn alloc_timer(&mut self) -> u64 {
        let id = self.next_timer;
        self.next_timer -= 1;
        id
    }

    /// Count a skip, attributed to the request's home shard — together
    /// with [`Self::note_gave_up`] and [`Self::note_finish`] this keeps
    /// the per-shard conservation invariant exact:
    /// `finished[s] + skipped[s] + gave_up[s] == arrivals[s]`.
    fn note_skip(&mut self, id: u64) {
        self.skipped += 1;
        if let Some(sh) = self.shard.as_mut() {
            sh.stats[sh.home[id as usize]].skipped += 1;
        }
    }

    /// Count an exhausted attempt budget against the home shard.
    fn note_gave_up(&mut self, id: u64) {
        self.gave_up += 1;
        if let Some(sh) = self.shard.as_mut() {
            sh.stats[sh.home[id as usize]].gave_up += 1;
        }
    }

    /// Count a completion against the home shard.
    fn note_finish(&mut self, id: u64) {
        if let Some(sh) = self.shard.as_mut() {
            sh.stats[sh.home[id as usize]].finished += 1;
        }
    }

    /// The GIIS domain answering request `id`'s broad query: its home
    /// shard's registration domain in a sharded run, the single shared
    /// hierarchy otherwise.
    fn broad_domain(&self, id: u64) -> Arc<RwLock<HierarchicalDirectory>> {
        if let Some(sh) = &self.shard {
            if !sh.domains.is_empty() {
                return sh.domains[sh.home[id as usize]].clone();
            }
        }
        self.hier.clone().expect("discovery mode wires a hierarchy")
    }

    /// The GIIS domain holding topology site `site`'s registration —
    /// a foreign shard's domain when the replica set spans the
    /// boundary (the cross-shard consult).
    fn site_domain(&self, site: usize) -> Arc<RwLock<HierarchicalDirectory>> {
        if let Some(sh) = &self.shard {
            if !sh.domains.is_empty() {
                return sh.domains[sh.map.owner(site)].clone();
            }
        }
        self.hier.clone().expect("discovery mode wires a hierarchy")
    }

    /// An arrival event: gate-check and admit directly (legacy), or
    /// route into the home shard's admission batch (sharded).
    fn arrival(&mut self, eng: &mut Engine, id: u64, at: f64) {
        // The popularity counter sees demand at arrival (gated or not):
        // a flash crowd heats its file before the first transfer lands,
        // which is exactly when replication should trigger.
        if let Some(e) = self.economy.as_mut() {
            e.note_access(self.requests[id as usize].file, at);
        }
        if self.shard.is_some() {
            self.shard_arrival(eng, id, at);
            return;
        }
        if self.occupancy() < self.opts.max_in_flight {
            self.admit(eng, id);
        } else {
            if self.opts.trace.on() {
                self.opts.trace.rec(
                    at,
                    id,
                    Ev::GatePark { occupancy: self.occupancy() as u32 },
                );
            }
            self.waiting.push_back(id);
        }
    }

    /// Sharded arrival: resolve the home shard from the replica set,
    /// queue into its batch, and flush when the batch fills (or arm
    /// the window timer so it cannot sit forever).
    fn shard_arrival(&mut self, eng: &mut Engine, id: u64, at: f64) {
        let file = self.requests[id as usize].file;
        let (home, spans) = {
            let sh = self.shard.as_ref().expect("sharded arrival");
            sh.map.home(&self.grid.placement[file])
        };
        let sh = self.shard.as_mut().expect("sharded arrival");
        sh.home[id as usize] = home;
        sh.spans[id as usize] = spans;
        sh.stats[home].arrivals += 1;
        sh.batches[home].push_back(id);
        if sh.batches[home].len() >= sh.batch_max {
            self.flush_shard(eng, home, at);
            return;
        }
        let window = sh.batch_window;
        if !sh.armed[home] && window.is_finite() && window > 0.0 {
            sh.armed[home] = true;
            let tid = self.alloc_timer();
            self.timers.insert(tid, TimerKind::Flush { shard: home });
            eng.schedule_tick(at + window, tid);
        }
    }

    /// Flush shard `s`'s admission batch FIFO: dynamics are republished
    /// once for the whole batch (the batching win — the legacy path
    /// republishes per admission), then each queued arrival is admitted
    /// or gate-parked exactly as the legacy arrival path would. With
    /// `batch_max = 1` the flush holds one id and publishes once, so
    /// the operation sequence is identical to the unsharded arrival —
    /// the 1-shard parity anchor.
    fn flush_shard(&mut self, eng: &mut Engine, s: usize, at: f64) {
        let sh = self.shard.as_mut().expect("sharded flush");
        sh.armed[s] = false;
        if sh.batches[s].is_empty() {
            return; // stale window timer: the batch already flushed full
        }
        sh.stats[s].flushes += 1;
        let mut batch = std::mem::take(&mut sh.batches[s]);
        let mut published = false;
        while let Some(id) = batch.pop_front() {
            if self.occupancy() < self.opts.max_in_flight {
                if !published {
                    self.grid.publish_dynamics();
                    published = true;
                }
                self.admit_prepublished(eng, id);
            } else {
                if self.opts.trace.on() {
                    self.opts.trace.rec(
                        at,
                        id,
                        Ev::GatePark { occupancy: self.occupancy() as u32 },
                    );
                }
                self.waiting.push_back(id);
            }
        }
        // Hand the drained deque's allocation back so the steady state
        // stays allocation-free.
        self.shard.as_mut().expect("sharded flush").batches[s] = batch;
    }

    /// Admit one request *now*: republish dynamics, then either select
    /// immediately against fresh direct-GRIS data (the legacy,
    /// parity-anchored path) or start the event-driven hierarchical
    /// discovery ([`DiscoveryOptions`]).
    fn admit(&mut self, eng: &mut Engine, id: u64) {
        self.grid.publish_dynamics();
        self.admit_prepublished(eng, id);
    }

    /// Admission with dynamics already republished — the shard batch
    /// flush publishes once per flush, not once per admission.
    fn admit_prepublished(&mut self, eng: &mut Engine, id: u64) {
        let req = &self.requests[id as usize];
        if let Some(sh) = self.shard.as_mut() {
            let home = sh.home[id as usize];
            sh.stats[home].admitted += 1;
            if sh.spans[id as usize] {
                sh.cross_shard += 1;
            }
        }
        if self.opts.discovery.is_some() {
            self.begin_discovery(eng, id);
            return;
        }
        let logical = self.grid.files[req.file].clone();
        let size = self.grid.sizes[req.file];
        let ad = request_ad(req.min_bandwidth);
        if self.opts.trace.on() {
            // Legacy direct-GRIS path: every placement is queried fresh
            // and selection is instantaneous at this very event.
            let placements = self.grid.placement[req.file].len() as u32;
            self.opts.trace.rec(
                self.grid.topo.now,
                id,
                Ev::DiscoveryStart { placements, drills: placements },
            );
        }
        let pick = pick_replica(
            self.grid,
            &self.broker,
            &mut self.selector,
            self.kind,
            &logical,
            size,
            &ad,
        );
        if self.opts.trace.on() {
            let now = self.grid.topo.now;
            let candidates = self.grid.placement[req.file].len() as u32;
            let name = self.grid.topo.site(pick.pick_site).cfg.name.clone();
            self.opts.trace.with(|r| {
                let s = r.intern(&name);
                r.push(now, id, Ev::Selection { site: s, candidates });
            });
        }
        self.run_access(eng, id, size, pick);
    }

    /// Start the hierarchical discovery for request `id`: the broad
    /// query is answered from GIIS soft state *now* (no simulated
    /// cost — one index lookup), and a drill-down fan-out over the
    /// top summary-ranked replicas goes onto the kernel. Selection
    /// happens when the fan-out completes.
    fn begin_discovery(&mut self, eng: &mut Engine, id: u64) {
        let disc = self.opts.discovery.clone().expect("discovery mode");
        let req = &self.requests[id as usize];
        let logical = self.grid.files[req.file].clone();
        let size = self.grid.sizes[req.file];
        let now = self.grid.topo.now;
        // The broad query lands on the home domain; each replica's
        // snapshot is read from the domain its site registers in —
        // the same single directory in the unsharded (and 1-shard)
        // configuration, a foreign shard's domain when the replica set
        // spans the boundary. `advance_to` at a fixed instant is
        // idempotent, so re-advancing the same directory per replica
        // leaves it bit-identical to the legacy one-lock walk.
        {
            let home = self.broad_domain(id);
            let mut dir = home.write().unwrap();
            dir.advance_to(now);
            dir.note_broad();
        }
        let mut sites = Vec::new();
        let mut stale: Vec<Vec<Entry>> = Vec::new();
        for &s in &self.grid.placement[req.file] {
            let name = self.grid.topo.site(s).cfg.name.clone();
            let dom = self.site_domain(s);
            let mut dir = dom.write().unwrap();
            dir.advance_to(now);
            if let Some((entries, _age)) = dir.cached(&name) {
                stale.push(entries.to_vec());
                let url = format!("gsiftp://{name}/{logical}");
                sites.push((name, url, s));
            }
        }
        if sites.is_empty() {
            // Every replica site's registration expired or was never
            // pushed: the file is undiscoverable right now.
            self.opts.trace.rec(now, id, Ev::RequestSkipped { reason: "undiscoverable" });
            self.note_skip(id);
            return;
        }
        // Drill-down selection: predicted bandwidth over the *stale*
        // snapshots — all a real client knows before asking. Shares
        // `RankPolicy::drill_slots` with the broker's hierarchical
        // Search route so both drill the same sites for the same
        // stale view.
        let stale_cands: Vec<Candidate> = sites
            .iter()
            .zip(&stale)
            .map(|((name, url, _), entries)| entries_to_candidate(name, url, entries))
            .collect();
        let fan_sites: Vec<(usize, f64)> = self
            .broker
            .policy()
            .drill_slots(&stale_cands, disc.drill_down)
            .into_iter()
            .map(|slot| {
                let rtt = disc.rtt_factor * self.grid.topo.site(sites[slot].2).cfg.latency;
                (slot, rtt)
            })
            .collect();
        let mut labels: Vec<SiteId> = Vec::new();
        if self.opts.trace.on() {
            self.opts.trace.rec(
                now,
                id,
                Ev::DiscoveryStart {
                    placements: sites.len() as u32,
                    drills: fan_sites.len() as u32,
                },
            );
            self.opts.trace.with(|r| {
                labels = fan_sites.iter().map(|&(slot, _)| r.intern(&sites[slot].0)).collect();
            });
        }
        let fanout = DirectoryFanout::start_traced(
            eng,
            &mut self.qids,
            now,
            &fan_sites,
            disc.fanout,
            self.opts.trace.clone(),
            id,
            &labels,
        );
        let fresh = vec![None; sites.len()];
        let pd = PendingDiscovery { request: id as usize, size, sites, stale, fresh, fanout };
        if pd.fanout.finished() {
            // drill_down = 0: summaries only, selection is immediate
            // (no query ids to track — nothing was scheduled).
            self.finish_discovery(eng, pd);
        } else {
            for q in pd.fanout.qids() {
                self.qid_map.insert(q, id);
            }
            self.pending_disc.insert(id, pd);
        }
    }

    /// A kernel query event: route it to its fan-out. A response
    /// samples that site's *live* GRIS at this instant — by the time
    /// the last answer arrives, the first one is already stale.
    fn on_query(&mut self, eng: &mut Engine, qid: u64, at: f64) {
        let Some(req_id) = self.qid_map.remove(&qid) else {
            return;
        };
        let Some(mut pd) = self.pending_disc.remove(&req_id) else {
            return;
        };
        if let FanoutStep::Response { site: slot, .. } = pd.fanout.on_query(eng, qid, at) {
            // Only the responding site is queried, so only its
            // dynamics need republishing at this instant. The fresh
            // answer lands in the domain owning that site.
            self.grid.publish_site(pd.sites[slot].2);
            let dom = self.site_domain(pd.sites[slot].2);
            let mut dir = dom.write().unwrap();
            dir.advance_to(at);
            if let Some(entries) = dir.drill_down(&pd.sites[slot].0) {
                pd.fresh[slot] = Some(entries);
            }
        }
        if pd.fanout.finished() {
            // Drop every id this fan-out still owns (queued queries
            // abandoned by a cutoff never get an engine event, so
            // their routing entries would otherwise leak forever).
            for q in pd.fanout.qids() {
                self.qid_map.remove(&q);
            }
            self.finish_discovery(eng, pd);
        } else {
            self.pending_disc.insert(req_id, pd);
        }
    }

    /// Discovery complete: assemble the mixed-age candidate set (fresh
    /// drill-down answers where they arrived, stale snapshots
    /// everywhere else), select, and run the Access phase.
    fn finish_discovery(&mut self, eng: &mut Engine, pd: PendingDiscovery) {
        let req = &self.requests[pd.request];
        if self.opts.trace.on() {
            let responses = pd.fresh.iter().filter(|f| f.is_some()).count() as u32;
            self.opts.trace.rec(
                self.grid.topo.now,
                pd.request as u64,
                Ev::DiscoveryEnd { responses },
            );
        }
        let cands: Vec<Candidate> = pd
            .sites
            .iter()
            .enumerate()
            .map(|(i, (name, url, _))| {
                let entries = pd.fresh[i].as_deref().unwrap_or(&pd.stale[i]);
                entries_to_candidate(name, url, entries)
            })
            .collect();
        let ad = request_ad(req.min_bandwidth);
        match pick_from_candidates(
            self.grid,
            &self.broker,
            &mut self.selector,
            self.kind,
            &cands,
            pd.size,
            &ad,
        ) {
            Some(pick) => {
                if self.opts.trace.on() {
                    let now = self.grid.topo.now;
                    let candidates = cands.len() as u32;
                    let name = self.grid.topo.site(pick.pick_site).cfg.name.clone();
                    self.opts.trace.with(|r| {
                        let s = r.intern(&name);
                        r.push(now, pd.request as u64, Ev::Selection { site: s, candidates });
                    });
                }
                self.run_access(eng, pd.request as u64, pd.size, pick)
            }
            None => {
                self.opts.trace.rec(
                    self.grid.topo.now,
                    pd.request as u64,
                    Ev::RequestSkipped { reason: "no_replica" },
                );
                self.note_skip(pd.request as u64)
            }
        }
        // No gate drain here: the event loop runs `drain_gate` after
        // every event, and draining from inside finish_discovery would
        // recurse (admit → begin_discovery → finish_discovery when
        // drill_down = 0) one stack frame per parked arrival.
    }

    /// Admit parked arrivals while the gate has room. Called from the
    /// event loop after every event — admission slots free both on
    /// flow completions and on discovery outcomes that never start a
    /// flow (Analytic access, failed `fetch_begin`, undiscoverable
    /// file), and only the latter path would otherwise strand the
    /// queue: no flow completion ever fires for it.
    fn drain_gate(&mut self, eng: &mut Engine) {
        while self.occupancy() < self.opts.max_in_flight {
            match self.waiting.pop_front() {
                Some(id) => {
                    if self.opts.trace.on() {
                        let now = self.grid.topo.now;
                        let arrived = self.t0 + self.requests[id as usize].at;
                        self.opts.trace.rec(
                            now,
                            id,
                            Ev::GateUnpark { waited_s: (now - arrived).max(0.0) },
                        );
                    }
                    self.admit(eng, id)
                }
                None => break,
            }
        }
    }

    /// The Access phase for an admitted request whose selection is
    /// made, per the configured mode.
    fn run_access(&mut self, eng: &mut Engine, id: u64, size: f64, pick: PickOutcome) {
        let req = &self.requests[id as usize];
        let overlapping = !self.inflight.is_empty();
        match self.opts.access {
            AccessMode::Analytic => {
                if overlapping {
                    self.overlapped_admissions += 1;
                }
                let out = self
                    .grid
                    .ftp
                    .fetch(&mut self.grid.topo, pick.pick_site, "client", size);
                let now = self.grid.topo.now;
                if self.opts.trace.on() {
                    let name = self.grid.topo.site(pick.pick_site).cfg.name.clone();
                    let dur = out.duration;
                    self.opts.trace.with(|r| {
                        let s = r.intern(&name);
                        r.push(now, id, Ev::AnalyticAccess { site: s, transfer_s: dur });
                        // The analytic fetch consumes no kernel time:
                        // stamp the logical completion instant.
                        r.push(now + dur, id, Ev::RequestDone { transfer_s: dur });
                    });
                }
                self.finished.push(RequestTrace {
                    request: id as usize,
                    site: pick.pick_site,
                    admitted_at: now,
                    finished_at: now + out.duration,
                    duration: out.duration,
                    bandwidth: out.bandwidth,
                    oracle_best: pick.best_oracle,
                    hit_optimal: pick.pick_site == pick.best_site,
                    retries: 0,
                    first_failure_at: None,
                });
                self.note_finish(id);
            }
            AccessMode::Flow => {
                let group = self.groups[req.client % self.groups.len()];
                match self.grid.ftp.fetch_begin(
                    eng,
                    &mut self.grid.topo,
                    pick.pick_site,
                    "client",
                    size,
                    group,
                ) {
                    Ok(open) => {
                        // Count the overlap only once the transfer
                        // actually occupies the grid.
                        if overlapping {
                            self.overlapped_admissions += 1;
                        }
                        if self.opts.trace.on() {
                            let now = self.grid.topo.now;
                            let name =
                                self.grid.topo.site(pick.pick_site).cfg.name.clone();
                            let flow = open.flow as u64;
                            self.opts.trace.with(|r| {
                                let s = r.intern(&name);
                                r.push(
                                    now,
                                    id,
                                    Ev::FlowStart { site: s, flow, bytes: size as u64 },
                                );
                            });
                        }
                        let now = self.grid.topo.now;
                        let flow = open.flow;
                        self.inflight.insert(
                            flow,
                            InFlight {
                                request: id as usize,
                                open,
                                oracle_best: pick.best_oracle,
                                hit_optimal: pick.pick_site == pick.best_site,
                                attempt: 1,
                                admitted_at: now,
                                first_failure_at: None,
                                retries: 0,
                                last_delivered: 0.0,
                            },
                        );
                        self.peak_in_flight = self.peak_in_flight.max(self.inflight.len());
                        if let Some(r) = self.opts.retry {
                            let tid = self.alloc_timer();
                            self.timers.insert(tid, TimerKind::Timeout { flow });
                            eng.schedule_tick(now + r.transfer_timeout, tid);
                        }
                    }
                    Err(_) => {
                        if self.opts.retry.is_some() {
                            // A source that died between selection and
                            // the control channel's open is the first
                            // failed attempt, not a silent skip.
                            let now = self.grid.topo.now;
                            self.schedule_retry(
                                eng,
                                PendingRetry {
                                    request: id as usize,
                                    attempt: 1,
                                    offset: 0.0,
                                    remaining: size,
                                    last_site: pick.pick_site,
                                    oracle_best: pick.best_oracle,
                                    hit_optimal: pick.pick_site == pick.best_site,
                                    admitted_at: now,
                                    first_failure_at: now,
                                    retries: 0,
                                },
                                now,
                            );
                        } else {
                            self.opts.trace.rec(
                                self.grid.topo.now,
                                id,
                                Ev::RequestSkipped { reason: "dead_source" },
                            );
                            self.note_skip(id)
                        }
                    }
                }
            }
        }
    }

    /// A driver timer fired: a per-flow progress check or a backed-off
    /// request's resume instant. Unknown ids (a check armed for a flow
    /// that since completed) are stale and ignored — flow ids are
    /// never reused, so staleness is unambiguous.
    fn on_timer(&mut self, eng: &mut Engine, tid: u64, at: f64) {
        match self.timers.remove(&tid) {
            Some(TimerKind::Timeout { flow }) => self.check_timeout(eng, flow, at),
            Some(TimerKind::Resume(pr)) => {
                self.retry_waiting -= 1;
                self.resume(eng, pr, at);
            }
            Some(TimerKind::Flush { shard }) => self.flush_shard(eng, shard, at),
            None => {}
        }
    }

    /// Progress check on one in-flight flow: new bytes since the last
    /// check and a live source re-arm the timer; a stalled or dead
    /// flow is cancelled and its request enters backoff, owing only
    /// the bytes not yet delivered.
    fn check_timeout(&mut self, eng: &mut Engine, flow: usize, at: f64) {
        let r = self.opts.retry.expect("progress timers exist only with retry configured");
        let Some(fi) = self.inflight.get(&flow) else {
            return; // completed before the check fired
        };
        let (site, seen) = (fi.open.site, fi.last_delivered);
        let delivered = eng.flows.flow(flow).delivered;
        if self.grid.topo.site_alive(site) && delivered > seen + 1e-9 {
            let tid = self.alloc_timer();
            self.timers.insert(tid, TimerKind::Timeout { flow });
            eng.schedule_tick(at + r.transfer_timeout, tid);
            if let Some(fi) = self.inflight.get_mut(&flow) {
                fi.last_delivered = delivered;
            }
            return;
        }
        let fi = self.inflight.remove(&flow).expect("checked above");
        eng.flows.cancel(flow);
        self.grid.topo.end_transfer(fi.open.site);
        let delivered = delivered.clamp(0.0, fi.open.bytes);
        self.schedule_retry(
            eng,
            PendingRetry {
                request: fi.request,
                attempt: fi.attempt,
                offset: fi.open.offset + delivered,
                remaining: fi.open.bytes - delivered,
                last_site: fi.open.site,
                oracle_best: fi.oracle_best,
                hit_optimal: fi.hit_optimal,
                admitted_at: fi.admitted_at,
                first_failure_at: fi.first_failure_at.unwrap_or(at),
                retries: fi.retries,
            },
            at,
        );
    }

    /// A failed attempt: either give up (budget exhausted) or park the
    /// request for its exponential-backoff delay, jittered from the
    /// seeded retry stream so two identically seeded runs back off
    /// identically.
    fn schedule_retry(&mut self, eng: &mut Engine, pr: PendingRetry, at: f64) {
        let r = self.opts.retry.expect("retry configured");
        if pr.attempt >= r.max_attempts {
            self.opts.trace.rec(at, pr.request as u64, Ev::RequestSkipped { reason: "gave_up" });
            self.note_gave_up(pr.request as u64);
            return;
        }
        let exp = r.backoff_base * r.backoff_factor.powi(pr.attempt.saturating_sub(1) as i32);
        let jitter = 1.0 + r.jitter_frac * self.retry_rng.range(-1.0, 1.0);
        let delay = (exp.min(r.backoff_max) * jitter).max(1e-3);
        let tid = self.alloc_timer();
        self.timers.insert(tid, TimerKind::Resume(pr));
        self.retry_waiting += 1;
        eng.schedule_tick(at + delay, tid);
    }

    /// A backed-off request's re-issue: pick the best surviving
    /// replica (or the pinned original source), resume from the
    /// delivered byte offset, and re-arm the progress check. No
    /// survivor, or an open that fails under our feet, burns the
    /// attempt and backs off again.
    fn resume(&mut self, eng: &mut Engine, mut pr: PendingRetry, at: f64) {
        let r = self.opts.retry.expect("retry configured");
        let req = &self.requests[pr.request];
        let mut best: Option<(usize, f64)> = None;
        for &s in &self.grid.placement[req.file] {
            if !r.failover && s != pr.last_site {
                continue;
            }
            if !self.grid.topo.site_alive(s) {
                continue;
            }
            let (d, _) = self.grid.topo.probe_transfer(s, pr.remaining, 0);
            let better = match best {
                Some((_, bd)) => d < bd,
                None => true,
            };
            if d.is_finite() && better {
                best = Some((s, d));
            }
        }
        pr.attempt += 1;
        let Some((site, _)) = best else {
            // Nobody can serve it right now (every replica down, or
            // the pinned source still dead): burn the attempt.
            self.schedule_retry(eng, pr, at);
            return;
        };
        let group = self.groups[req.client % self.groups.len()];
        match self.grid.ftp.fetch_begin_range(
            eng,
            &mut self.grid.topo,
            site,
            "client",
            pr.offset,
            pr.remaining,
            group,
        ) {
            Ok(open) => {
                if self.opts.trace.on() {
                    let name = self.grid.topo.site(site).cfg.name.clone();
                    let id = pr.request as u64;
                    let attempt = pr.attempt;
                    let offset = pr.offset as u64;
                    let flow = open.flow as u64;
                    let bytes = pr.remaining as u64;
                    self.opts.trace.with(|r| {
                        let s = r.intern(&name);
                        r.push(at, id, Ev::TransferRetry { site: s, attempt, offset });
                        r.push(at, id, Ev::FlowStart { site: s, flow, bytes });
                    });
                }
                self.retries += 1;
                if site != pr.last_site {
                    self.failovers += 1;
                }
                let flow = open.flow;
                self.inflight.insert(
                    flow,
                    InFlight {
                        request: pr.request,
                        open,
                        oracle_best: pr.oracle_best,
                        hit_optimal: pr.hit_optimal,
                        attempt: pr.attempt,
                        admitted_at: pr.admitted_at,
                        first_failure_at: Some(pr.first_failure_at),
                        retries: pr.retries + 1,
                        last_delivered: 0.0,
                    },
                );
                self.peak_in_flight = self.peak_in_flight.max(self.inflight.len());
                let tid = self.alloc_timer();
                self.timers.insert(tid, TimerKind::Timeout { flow });
                eng.schedule_tick(at + r.transfer_timeout, tid);
            }
            Err(_) => self.schedule_retry(eng, pr, at),
        }
    }

    /// A flow completion from the kernel: finish the fetch (slot
    /// release + instrumentation record). The event loop drains the
    /// admission gate right after.
    fn complete(&mut self, c: &crate::simnet::Completion) {
        if self.econ_pushes.contains_key(&c.flow) {
            self.econ_complete(c);
            return;
        }
        let fi = match self.inflight.remove(&c.flow) {
            Some(fi) => fi,
            None => return,
        };
        let out = self.grid.ftp.fetch_finish(&mut self.grid.topo, &fi.open, c.at);
        if self.opts.trace.on() {
            let name = self.grid.topo.site(fi.open.site).cfg.name.clone();
            let flow = c.flow as u64;
            let dur = out.duration;
            let req = fi.request as u64;
            let at = c.at;
            self.opts.trace.with(|r| {
                let s = r.intern(&name);
                r.push(at, req, Ev::FlowFinish { site: s, flow, transfer_s: dur });
                r.push(at, req, Ev::RequestDone { transfer_s: dur });
            });
        }
        // A retried request's duration spans admission → last byte
        // (backoffs included) and its bandwidth covers every byte of
        // the file across all attempts; a clean first try keeps the
        // instrumentation's own arithmetic bit-for-bit (the parity
        // anchor).
        let (duration, bandwidth) = if fi.retries == 0 {
            (out.duration, out.bandwidth)
        } else {
            let d = (c.at - fi.admitted_at).max(1e-9);
            (d, (fi.open.offset + fi.open.bytes) / d)
        };
        self.finished.push(RequestTrace {
            request: fi.request,
            site: fi.open.site,
            admitted_at: fi.admitted_at,
            finished_at: c.at,
            duration,
            bandwidth,
            oracle_best: fi.oracle_best,
            hit_optimal: fi.hit_optimal,
            retries: fi.retries,
            first_failure_at: fi.first_failure_at,
        });
        self.note_finish(fi.request as u64);
    }

    /// An economy push delivered its last byte: commit the space
    /// (exactly what the volume accepted — the applied delta goes into
    /// the ledger), register the catalog entry and the placement row,
    /// and republish the destination's dynamics. A destination that
    /// died mid-push is abandoned: slot released, nothing committed,
    /// counted as a failed push.
    fn econ_complete(&mut self, c: &crate::simnet::Completion) {
        let (file, open) =
            self.econ_pushes.remove(&c.flow).expect("routed on contains_key");
        if let Some(e) = self.economy.as_mut() {
            e.push_resolved(file);
        }
        if !self.grid.topo.site_alive(open.site) {
            self.grid.topo.end_transfer(open.site);
            if let Some(e) = self.economy.as_mut() {
                e.stats.failed_pushes += 1;
            }
            return;
        }
        let out = self.grid.ftp.store_finish(&mut self.grid.topo, &open, c.at);
        let site_name = self.grid.topo.site(open.site).cfg.name.clone();
        let logical = self.grid.files[file].clone();
        let _ = self.grid.catalog.lock().unwrap().add_replica(
            &logical,
            PhysicalLocation {
                site: site_name.clone(),
                url: format!("gsiftp://{site_name}/{logical}"),
            },
        );
        self.grid.placement[file].push(open.site);
        self.grid.space_ledger.insert((file, open.site), out.applied);
        self.grid.publish_site(open.site);
        if let Some(e) = self.economy.as_mut() {
            e.stats.replicas_created += 1;
            e.stats.bytes_moved += open.bytes;
        }
        if self.opts.trace.on() {
            let dur = out.duration;
            let at = c.at;
            self.opts.trace.with(|r| {
                let s = r.intern(&site_name);
                r.push(at, KERNEL_REQ, Ev::ReplicaCreate { site: s, transfer_s: dur });
            });
        }
    }

    /// The recurring economy tick (ECONOMY_TICK): decay popularity,
    /// plan this tick's bounded action list, and execute it — an
    /// eviction is instant (catalog removal + exact ledgered reclaim
    /// via the [`ReplicaManager`]); a replication push goes on the
    /// kernel as a real write flow that contends with foreground
    /// transfers until [`Self::econ_complete`] commits it.
    fn economy_tick(&mut self, eng: &mut Engine, at: f64) {
        let Some(mut econ) = self.economy.take() else {
            return;
        };
        let actions = econ.plan(self.grid, at);
        for a in actions {
            match a {
                EconomyAction::Evict { file, site } => {
                    let name = self.grid.topo.site(site).cfg.name.clone();
                    let logical = self.grid.files[file].clone();
                    let freed = self
                        .grid
                        .space_ledger
                        .get(&(file, site))
                        .copied()
                        .unwrap_or(self.grid.sizes[file]);
                    if ReplicaManager::new(self.grid, econ.opts.placement)
                        .delete_replica(&logical, &name)
                        .is_ok()
                    {
                        econ.stats.evictions += 1;
                        if self.opts.trace.on() {
                            self.opts.trace.with(|r| {
                                let s = r.intern(&name);
                                r.push(
                                    at,
                                    KERNEL_REQ,
                                    Ev::ReplicaEvict { site: s, bytes: freed as u64 },
                                );
                            });
                        }
                    }
                }
                EconomyAction::Replicate { file, dest } => {
                    let bytes = self.grid.sizes[file];
                    // Group 0 of the base flow set is the unconstrained
                    // group: economy pushes are server-to-server, not
                    // behind any client's downlink.
                    match self.grid.ftp.store_begin(
                        eng,
                        &mut self.grid.topo,
                        dest,
                        "economy",
                        bytes,
                        0,
                    ) {
                        Ok(open) => {
                            econ.push_started(file);
                            if self.opts.trace.on() {
                                let name = self.grid.topo.site(dest).cfg.name.clone();
                                let flow = open.flow as u64;
                                self.opts.trace.with(|r| {
                                    let s = r.intern(&name);
                                    r.push(
                                        at,
                                        KERNEL_REQ,
                                        Ev::ReplicaPush {
                                            site: s,
                                            flow,
                                            bytes: bytes as u64,
                                        },
                                    );
                                });
                            }
                            self.econ_pushes.insert(open.flow, (file, open));
                        }
                        Err(_) => econ.stats.failed_pushes += 1,
                    }
                }
            }
        }
        self.economy = Some(econ);
    }

    /// The flight recorder's time-series sampler (SAMPLE_TICK): global
    /// gauges (in-flight flows, gate depth, GIIS registration liveness)
    /// plus one utilization row per site link with live flows.
    fn sample(&mut self, eng: &Engine) {
        let now = self.grid.topo.now;
        let giis_live = if let Some(sh) =
            self.shard.as_ref().filter(|sh| !sh.domains.is_empty())
        {
            // Sharded: liveness is the sum over registration domains.
            sh.domains
                .iter()
                .map(|d| {
                    let mut dir = d.write().unwrap();
                    dir.advance_to(now);
                    dir.giis().registrations().len() as u32
                })
                .sum()
        } else {
            self.hier
                .as_ref()
                .map(|h| {
                    let mut dir = h.write().unwrap();
                    dir.advance_to(now);
                    dir.giis().registrations().len() as u32
                })
                .unwrap_or(0)
        };
        self.opts.trace.rec(
            now,
            SAMPLE_REQ,
            Ev::Sample {
                in_flight: self.inflight.len() as u32,
                gate_depth: self.waiting.len() as u32,
                giis_live,
            },
        );
        // Per-link utilization: live per-flow rates (downlink-clipped,
        // the same arithmetic the integrator uses) summed per source
        // site over that site's current WAN bandwidth.
        let rates = eng.flows.bandwidths(&mut self.grid.topo);
        let mut per_site: BTreeMap<usize, (u32, f64)> = BTreeMap::new();
        for (idx, rate) in rates {
            let e = per_site.entry(eng.flows.flow(idx).site).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += rate;
        }
        for (site, (flows, rate)) in per_site {
            let cap = self.grid.topo.current_bandwidth(site);
            let utilization = if cap > 0.0 { rate / cap } else { 0.0 };
            let name = self.grid.topo.site(site).cfg.name.clone();
            self.opts.trace.with(|r| {
                let s = r.intern(&name);
                r.push(now, SAMPLE_REQ, Ev::LinkSample { site: s, flows, utilization });
            });
        }
    }
}

/// Replay an explicit request trace open-loop on the event kernel and
/// score it against the clairvoyant oracle, exactly like
/// [`super::run_quality_trace`] scores the serial replay. `engine` is
/// the optional PJRT forecast artifact for the `Forecast` selector
/// (None → pure-Rust bank; numerically equivalent).
#[allow(clippy::too_many_arguments)]
pub fn run_quality_open(
    cfg: &GridConfig,
    spec: &WorkloadSpec,
    requests: &[Request],
    replicas_per_file: usize,
    warm: usize,
    kind: SelectorKind,
    opts: &OpenLoopOptions,
    engine: Option<std::sync::Arc<crate::runtime::engine::EngineHandle>>,
) -> OpenReport {
    run_open_internal(cfg, spec, requests, replicas_per_file, warm, kind, opts, engine, None, None)
        .0
}

/// Per-shard telemetry extracted from a sharded run — what
/// [`super::sharded::run_quality_sharded`] wraps into its report.
pub(crate) struct ShardTelemetry {
    pub stats: Vec<ShardStats>,
    pub cross_shard: usize,
}

/// The full driver: [`run_quality_open`] with `shard: None`, the
/// sharded control plane (ISSUE 8) with `shard: Some(..)`, and an
/// optional override of the default event budget (the kernel bench
/// bounds its run by events, not by request completion).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_open_internal(
    cfg: &GridConfig,
    spec: &WorkloadSpec,
    requests: &[Request],
    replicas_per_file: usize,
    warm: usize,
    kind: SelectorKind,
    opts: &OpenLoopOptions,
    engine: Option<std::sync::Arc<crate::runtime::engine::EngineHandle>>,
    shard: Option<&ShardOptions>,
    event_budget: Option<usize>,
) -> (OpenReport, Option<ShardTelemetry>) {
    let mut grid = SimGrid::build(cfg, spec, replicas_per_file, 64);
    grid.warm(warm);
    let selector = Selector::new(kind, cfg.seed);
    let policy = match kind {
        SelectorKind::Forecast => RankPolicy::ForecastBandwidth { engine },
        _ => RankPolicy::ClassAdRank,
    };
    let broker = grid.broker(policy);

    // Pre-size the flow columns and the event arena for the request
    // count so the kernel's steady state allocates nothing (ISSUE 8);
    // behaviourally identical to `Engine::new` — capacity only.
    let prealloc = requests.len().min(1 << 21);
    let mut eng = Engine::with_capacity(
        FlowSet::with_capacity(f64::INFINITY, prealloc),
        prealloc + 64,
    );
    eng.trace = opts.trace.clone();
    // Group 0 of the base set stays empty; every workload client gets
    // its own downlink group so client pipes cap independently.
    let groups: Vec<usize> = (0..spec.clients.max(1))
        .map(|_| eng.flows.add_group(opts.client_downlink))
        .collect();
    // Arrivals are absolute offsets from the post-warm clock — the
    // same arithmetic the serial replay uses, so concurrency 1 with
    // analytic Access reproduces it bit-for-bit.
    let t0 = grid.topo.now;
    for (i, r) in requests.iter().enumerate() {
        eng.schedule_arrival(t0 + r.at, i as u64);
    }
    // Grid weather: the fault schedule's relative instants land on the
    // post-warm clock — identical `opts.faults` on identically seeded
    // grids means identical weather, the chaos experiment's control.
    if !opts.faults.is_empty() {
        WeatherPlan { faults: opts.faults.clone() }.apply(&mut grid.topo, t0);
    }
    // Flight-recorder view of the weather: every trigger and heal
    // boundary, in chronological order, emitted as kernel-track events
    // as the run's clock passes them.
    let mut weather: Vec<(f64, usize, Option<(f64, f64)>)> = Vec::new();
    if opts.trace.on() {
        for f in grid.topo.faults() {
            let degrade = match f.kind {
                FaultKind::ReplicaDeath => 0.0,
                FaultKind::LinkDegrade { factor } => factor,
            };
            let heal_s = if f.heal_at.is_finite() { f.heal_at } else { -1.0 };
            weather.push((f.at, f.site, Some((degrade, heal_s))));
            if f.heal_at.is_finite() {
                weather.push((f.heal_at, f.site, None));
            }
        }
        weather.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    }
    let mut wx = 0usize;
    if opts.gris_refresh.is_finite() && opts.gris_refresh > 0.0 {
        eng.schedule_tick(t0 + opts.gris_refresh, GRIS_TICK_ID);
    }
    if opts.trace.on() && opts.sample_period.is_finite() && opts.sample_period > 0.0 {
        eng.schedule_tick(t0 + opts.sample_period, SAMPLE_TICK_ID);
    }
    // Replica economy (ISSUE 10): the tick exists only when the
    // economy is on — `economy: None` schedules nothing, so the event
    // interleaving (and therefore every float in the run) is
    // bit-identical to pre-economy builds.
    if let Some(e) = opts.economy.as_ref() {
        if e.period.is_finite() && e.period > 0.0 {
            eng.schedule_tick(t0 + e.period, ECONOMY_TICK_ID);
        }
    }
    let n_files = grid.files.len();
    // Discovery mode: wire the GIIS registration domain(s) (initial
    // soft-state push at t0) and the periodic re-registration tick. An
    // unsharded run builds one grid-wide hierarchy; a sharded run
    // builds one domain per shard over exactly its owned site range —
    // 1 shard builds the `0..len` range, i.e. the identical directory.
    if let Some(d) = opts.discovery.as_ref() {
        if d.refresh_period.is_finite() && d.refresh_period > 0.0 {
            eng.schedule_tick(t0 + d.refresh_period, REG_TICK_ID);
        }
    }
    let shard_state = shard.map(|so| {
        let map = ShardMap::contiguous(grid.topo.len(), so.shards);
        let n = map.shards();
        let domains = match opts.discovery.as_ref() {
            Some(d) => (0..n)
                .map(|s| {
                    let r = map.sites_of(s);
                    grid.hierarchy_range(d.registration_ttl, r.start, r.end)
                })
                .collect(),
            None => Vec::new(),
        };
        ShardState {
            map,
            batch_max: so.batch_max.max(1),
            batch_window: so.batch_window,
            batches: vec![VecDeque::new(); n],
            armed: vec![false; n],
            domains,
            home: vec![0; requests.len()],
            spans: vec![false; requests.len()],
            stats: vec![ShardStats::default(); n],
            cross_shard: 0,
        }
    });
    let hier = match &shard_state {
        Some(_) => None,
        None => opts.discovery.as_ref().map(|d| grid.hierarchy(d.registration_ttl)),
    };

    let mut driver = Driver {
        grid: &mut grid,
        broker,
        selector,
        kind,
        opts,
        requests,
        groups,
        inflight: BTreeMap::new(),
        waiting: VecDeque::new(),
        hier,
        shard: shard_state,
        qids: QueryIds::new(),
        qid_map: BTreeMap::new(),
        pending_disc: BTreeMap::new(),
        timers: BTreeMap::new(),
        next_timer: RETRY_TIMER_BASE,
        retry_waiting: 0,
        retry_rng: Rng::new(cfg.seed ^ 0x5245_5452_5921), // "RETRY!"
        economy: opts.economy.map(|e| Economy::new(e, n_files)),
        econ_pushes: BTreeMap::new(),
        finished: Vec::new(),
        peak_in_flight: 0,
        overlapped_admissions: 0,
        skipped: 0,
        retries: 0,
        failovers: 0,
        gave_up: 0,
        t0,
    };

    // Event budget: arrivals + completions + GRIS ticks for any sane
    // run fit easily; a stalled-but-ticking grid (faulted sources with
    // a finite refresh period) terminates instead of spinning. The
    // kernel bench overrides it to bound the run by events processed.
    let max_events = event_budget.unwrap_or(1_000_000 + 100 * requests.len());
    let mut events = 0usize;
    while driver.finished.len() + driver.skipped + driver.gave_up < requests.len() {
        events += 1;
        if events > max_events {
            break;
        }
        let signal = eng.next(&mut driver.grid.topo);
        // Narrate the weather boundaries the clock just passed (the
        // kernel advanced `topo.now` to this signal's instant).
        if signal.is_some() && wx < weather.len() {
            let now = driver.grid.topo.now;
            while wx < weather.len() && weather[wx].0 <= now + 1e-12 {
                let (t, site, mark) = weather[wx];
                let name = driver.grid.topo.site(site).cfg.name.clone();
                driver.opts.trace.with(|r| {
                    let s = r.intern(&name);
                    match mark {
                        Some((degrade, heal_s)) => {
                            r.push(t, KERNEL_REQ, Ev::SiteFault { site: s, degrade, heal_s })
                        }
                        None => r.push(t, KERNEL_REQ, Ev::SiteHeal { site: s }),
                    }
                });
                wx += 1;
            }
        }
        match signal {
            Some(Signal::Arrival { id, at }) => {
                driver.opts.trace.rec(at, id, Ev::Arrival);
                driver.arrival(&mut eng, id, at);
            }
            Some(Signal::FlowDone(c)) => driver.complete(&c),
            Some(Signal::Query { id, at }) => driver.on_query(&mut eng, id, at),
            Some(Signal::Tick { id: REG_TICK_ID, .. }) => {
                // Soft-state push: every *live* site re-registers its
                // current snapshot. A down site cannot push, so its
                // registration ages toward the TTL — and on the first
                // tick after its heal it re-registers by itself, with
                // no special recovery path (ISSUE 7).
                driver.grid.publish_dynamics();
                if driver.shard.as_ref().is_some_and(|sh| !sh.domains.is_empty()) {
                    // Sharded: each live site re-registers into its
                    // owner shard's domain. One shard walks `0..len`
                    // in index order — the unsharded pass exactly.
                    let d = driver.opts.discovery.as_ref().expect("REG_TICK implies discovery");
                    let now = driver.grid.topo.now;
                    let sh = driver.shard.as_ref().expect("checked above");
                    for (s, dom) in sh.domains.iter().enumerate() {
                        let mut dir = dom.write().unwrap();
                        dir.advance_to(now);
                        for i in sh.map.sites_of(s) {
                            if driver.grid.topo.site_alive(i) {
                                let name = driver.grid.topo.site(i).cfg.name.clone();
                                dir.refresh_site(&name);
                            }
                        }
                    }
                    eng.schedule_tick(now + d.refresh_period, REG_TICK_ID);
                } else if let (Some(h), Some(d)) = (&driver.hier, &driver.opts.discovery) {
                    let mut dir = h.write().unwrap();
                    dir.advance_to(driver.grid.topo.now);
                    for i in 0..driver.grid.topo.len() {
                        if driver.grid.topo.site_alive(i) {
                            let name = driver.grid.topo.site(i).cfg.name.clone();
                            dir.refresh_site(&name);
                        }
                    }
                    eng.schedule_tick(driver.grid.topo.now + d.refresh_period, REG_TICK_ID);
                }
            }
            Some(Signal::Tick { id: SAMPLE_TICK_ID, .. }) => {
                driver.sample(&eng);
                eng.schedule_tick(driver.grid.topo.now + opts.sample_period, SAMPLE_TICK_ID);
            }
            Some(Signal::Tick { id: GRIS_TICK_ID, .. }) => {
                driver.grid.publish_dynamics();
                let next = driver.grid.topo.now + driver.opts.gris_refresh;
                eng.schedule_tick(next, GRIS_TICK_ID);
            }
            Some(Signal::Tick { id: ECONOMY_TICK_ID, at }) => {
                driver.economy_tick(&mut eng, at);
                if let Some(e) = driver.opts.economy.as_ref() {
                    eng.schedule_tick(driver.grid.topo.now + e.period, ECONOMY_TICK_ID);
                }
            }
            Some(Signal::Tick { id, at }) => driver.on_timer(&mut eng, id, at),
            // Stalled in-flight transfers with nothing scheduled:
            // whatever completed is the result.
            None => break,
        }
        // Every event can free admission slots (a completion, or a
        // discovery that resolved without starting a flow): drain the
        // parked arrivals at this same instant.
        driver.drain_gate(&mut eng);
    }

    // Wind down whatever never finished (stalled flows on faulted
    // sources, or a blown event budget): release the transfer slots
    // they still hold and surface them as `skipped` rather than
    // silently shrinking the report — the per-policy comparisons in
    // `run_contention` read `skipped` to know the means cover
    // different request subsets. Parked arrivals count too.
    let wind_down_at = driver.grid.topo.now;
    for (flow, fi) in std::mem::take(&mut driver.inflight) {
        eng.flows.cancel(flow);
        driver.grid.topo.end_transfer(fi.open.site);
        driver.opts.trace.rec(
            wind_down_at,
            fi.request as u64,
            Ev::RequestSkipped { reason: "wind_down" },
        );
        driver.note_skip(fi.request as u64);
    }
    // Economy pushes still on the wire are abandoned: cancel the flow
    // and release the destination's transfer slot. Space is committed
    // only at store-finish, so an abandoned push consumes nothing.
    for (flow, (file, open)) in std::mem::take(&mut driver.econ_pushes) {
        eng.flows.cancel(flow);
        driver.grid.topo.end_transfer(open.site);
        if let Some(e) = driver.economy.as_mut() {
            e.push_resolved(file);
            e.stats.failed_pushes += 1;
        }
    }
    let in_discovery: Vec<u64> = driver.pending_disc.keys().copied().collect();
    for id in in_discovery {
        driver.opts.trace.rec(wind_down_at, id, Ev::RequestSkipped { reason: "wind_down" });
        driver.note_skip(id);
    }
    let parked: Vec<u64> = driver.waiting.drain(..).collect();
    for id in parked {
        driver.opts.trace.rec(wind_down_at, id, Ev::RequestSkipped { reason: "wind_down" });
        driver.note_skip(id);
    }
    // Requests still sitting out a backoff when the run wound down
    // (e.g. a blown event budget): surface them as skipped too.
    for (_, k) in std::mem::take(&mut driver.timers) {
        if let TimerKind::Resume(pr) = k {
            driver.opts.trace.rec(
                wind_down_at,
                pr.request as u64,
                Ev::RequestSkipped { reason: "wind_down" },
            );
            driver.note_skip(pr.request as u64);
        }
    }
    driver.retry_waiting = 0;
    // Arrivals still waiting in an unflushed shard batch (a window
    // longer than the residual run, or a blown event budget) never
    // reached admission: skipped, attributed to their home shard so
    // the per-shard conservation invariant stays exact.
    let unflushed: Vec<u64> = driver
        .shard
        .as_mut()
        .map(|sh| sh.batches.iter_mut().flat_map(|b| b.drain(..)).collect())
        .unwrap_or_default();
    for id in unflushed {
        driver.opts.trace.rec(wind_down_at, id, Ev::RequestSkipped { reason: "wind_down" });
        driver.note_skip(id);
    }

    let mut durations = Vec::with_capacity(driver.finished.len());
    let mut bandwidths = Vec::with_capacity(driver.finished.len());
    let mut slowdowns = Vec::with_capacity(driver.finished.len());
    let mut optimal_hits = 0usize;
    for r in &driver.finished {
        durations.push(r.duration);
        bandwidths.push(r.bandwidth);
        slowdowns.push(r.duration / r.oracle_best.max(1e-9));
        if r.hit_optimal {
            optimal_hits += 1;
        }
    }
    let makespan = if driver.finished.is_empty() {
        0.0
    } else {
        let first = driver
            .finished
            .iter()
            .map(|r| r.admitted_at)
            .fold(f64::INFINITY, f64::min);
        let last = driver
            .finished
            .iter()
            .map(|r| r.finished_at)
            .fold(f64::NEG_INFINITY, f64::max);
        (last - first).max(0.0)
    };
    let discovery_stats = if let Some(sh) =
        driver.shard.as_ref().filter(|sh| !sh.domains.is_empty())
    {
        // One grid-wide total over the per-shard domains.
        let mut total = crate::directory::hier::DiscoveryStats::default();
        for d in &sh.domains {
            total.merge(&d.read().unwrap().stats());
        }
        Some(total)
    } else {
        driver.hier.as_ref().map(|h| h.read().unwrap().stats())
    };
    let telemetry = driver
        .shard
        .take()
        .map(|sh| ShardTelemetry { stats: sh.stats, cross_shard: sh.cross_shard });
    let economy_stats = driver.economy.as_ref().map(|e| e.stats);
    let report = OpenReport {
        quality: finish_report(kind.name(), durations, &bandwidths, &slowdowns, optimal_hits),
        makespan,
        peak_in_flight: driver.peak_in_flight,
        overlapped_admissions: driver.overlapped_admissions,
        skipped: driver.skipped,
        per_request: driver.finished,
        discovery: discovery_stats,
        retries: driver.retries,
        failovers: driver.failovers,
        gave_up: driver.gave_up,
        events,
        economy: economy_stats,
    };
    (report, telemetry)
}

/// One arrival-rate point of the load sweep.
#[derive(Debug, Clone)]
pub struct ContentionPoint {
    /// Mean request inter-arrival at this point (s).
    pub mean_interarrival: f64,
    /// Informed selection (Forecast policy) under this load.
    pub informed: OpenReport,
    /// Uninformed baseline (Random) on the identical trace.
    pub uninformed: OpenReport,
    /// `uninformed mean time / informed mean time` (> 1 ⇒ dynamic
    /// information pays; the paper's claim is that it pays *more* as
    /// contention grows).
    pub gap: f64,
}

/// The full idle-to-saturation sweep.
#[derive(Debug, Clone)]
pub struct ContentionReport {
    pub points: Vec<ContentionPoint>,
}

/// Sweep arrival rate from idle to saturation (`interarrivals`, mean
/// seconds between requests, typically descending) and replay
/// `n_requests` open-loop at each point under informed (Forecast) and
/// uninformed (Random) selection — identical traces, identically
/// seeded grids. This is the Figure-style result the serial replay
/// could never produce: how much dynamic, load-aware selection buys as
/// cross-request contention grows.
pub fn run_contention(
    cfg: &GridConfig,
    spec: &WorkloadSpec,
    n_requests: usize,
    replicas_per_file: usize,
    warm: usize,
    interarrivals: &[f64],
    opts: &OpenLoopOptions,
) -> ContentionReport {
    let points = interarrivals
        .iter()
        .map(|&ia| {
            let s = WorkloadSpec { mean_interarrival: ia, ..spec.clone() };
            let reqs = Workload::new(s.clone(), cfg.seed).take(n_requests);
            let informed = run_quality_open(
                cfg,
                &s,
                &reqs,
                replicas_per_file,
                warm,
                SelectorKind::Forecast,
                opts,
                None,
            );
            let uninformed = run_quality_open(
                cfg,
                &s,
                &reqs,
                replicas_per_file,
                warm,
                SelectorKind::Random,
                opts,
                None,
            );
            let gap = if informed.quality.mean_time > 0.0 {
                uninformed.quality.mean_time / informed.quality.mean_time
            } else {
                1.0
            };
            ContentionPoint { mean_interarrival: ia, informed, uninformed, gap }
        })
        .collect();
    ContentionReport { points }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic links: durations depend only on concurrency.
    fn flat_cfg(n: usize, seed: u64) -> GridConfig {
        let mut cfg = GridConfig::generate(n, seed);
        for s in &mut cfg.sites {
            s.wan_bandwidth = 1e6;
            s.diurnal_amp = 0.0;
            s.noise_frac = 0.0;
            s.congestion_prob = 0.0;
            s.ar_coeff = 0.0;
            s.latency = 0.0;
            s.drd_time_ms = 0.0;
            s.disk_rate = 1e9;
        }
        cfg
    }

    #[test]
    fn open_loop_is_deterministic() {
        let cfg = GridConfig::generate(5, 901);
        let spec = WorkloadSpec { files: 6, mean_interarrival: 20.0, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(15);
        let run = || {
            run_quality_open(
                &cfg,
                &spec,
                &reqs,
                3,
                2,
                SelectorKind::Forecast,
                &OpenLoopOptions::open(),
                None,
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a.quality.mean_time, b.quality.mean_time);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.peak_in_flight, b.peak_in_flight);
        assert_eq!(a.overlapped_admissions, b.overlapped_admissions);
    }

    #[test]
    fn dense_arrivals_overlap_and_complete() {
        let cfg = flat_cfg(4, 11);
        // ~160 s transfers arriving every ~5 s: deep overlap.
        let spec = WorkloadSpec { files: 6, mean_interarrival: 5.0, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(12);
        let r = run_quality_open(
            &cfg,
            &spec,
            &reqs,
            3,
            2,
            SelectorKind::Forecast,
            &OpenLoopOptions::open(),
            None,
        );
        assert_eq!(r.quality.requests, 12, "every request completes");
        assert_eq!(r.skipped, 0);
        assert!(r.peak_in_flight >= 2, "peak {}", r.peak_in_flight);
        assert!(r.overlapped_admissions > 0);
        // At least one pair of transfers overlapped in time.
        let overlaps = r.per_request.iter().any(|a| {
            r.per_request.iter().any(|b| {
                a.request != b.request
                    && a.admitted_at < b.finished_at
                    && b.admitted_at < a.finished_at
            })
        });
        assert!(overlaps, "no overlapping transfer intervals recorded");
    }

    #[test]
    fn admission_gate_serializes_flow_transfers() {
        let cfg = flat_cfg(4, 12);
        let spec = WorkloadSpec { files: 6, mean_interarrival: 5.0, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(8);
        let opts = OpenLoopOptions {
            max_in_flight: 1,
            ..OpenLoopOptions::open()
        };
        let r = run_quality_open(&cfg, &spec, &reqs, 3, 2, SelectorKind::Forecast, &opts, None);
        assert_eq!(r.quality.requests, 8);
        assert_eq!(r.peak_in_flight, 1);
        assert_eq!(r.overlapped_admissions, 0);
        // Gated transfers must not overlap in time.
        let mut spans: Vec<(f64, f64)> = r
            .per_request
            .iter()
            .map(|t| (t.admitted_at, t.finished_at))
            .collect();
        spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in spans.windows(2) {
            assert!(w[1].0 >= w[0].1 - 1e-9, "gated spans overlap: {w:?}");
        }
    }

    #[test]
    fn contention_slows_transfers() {
        let cfg = flat_cfg(4, 13);
        let spec = WorkloadSpec { files: 6, ..Default::default() };
        let sweep = run_contention(&cfg, &spec, 10, 3, 2, &[1e6, 5.0], &OpenLoopOptions::open());
        assert_eq!(sweep.points.len(), 2);
        let idle = &sweep.points[0];
        let busy = &sweep.points[1];
        // On flat links duration is purely a function of concurrency:
        // the saturated point must be slower than the (near-)idle one,
        // whatever either policy picked.
        assert!(
            busy.informed.quality.mean_time > idle.informed.quality.mean_time,
            "busy {:.1}s !> idle {:.1}s",
            busy.informed.quality.mean_time,
            idle.informed.quality.mean_time
        );
        assert!(
            busy.informed.overlapped_admissions > idle.informed.overlapped_admissions,
            "saturation must overlap more: busy {} !> idle {}",
            busy.informed.overlapped_admissions,
            idle.informed.overlapped_admissions
        );
        assert!(busy.gap > 0.0);
    }

    #[test]
    fn discovery_mode_completes_and_pays_fewer_queries() {
        let cfg = GridConfig::generate(6, 31);
        let spec = WorkloadSpec { files: 6, mean_interarrival: 30.0, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(10);
        let opts = OpenLoopOptions {
            discovery: Some(DiscoveryOptions { drill_down: 2, ..Default::default() }),
            ..OpenLoopOptions::open()
        };
        let r = run_quality_open(&cfg, &spec, &reqs, 3, 2, SelectorKind::Forecast, &opts, None);
        assert_eq!(r.quality.requests, 10, "skipped {}", r.skipped);
        assert_eq!(r.skipped, 0);
        let stats = r.discovery.expect("discovery stats recorded");
        assert_eq!(stats.broad_queries, 10, "one broad lookup per admission");
        // 2 drill-downs per request (deadline/cutoff infinite), which
        // is strictly below the 3-replica full fan-out.
        assert_eq!(stats.drill_downs, 20);
        assert!(stats.drill_downs < 10 * 3);
    }

    #[test]
    fn discovery_mode_is_deterministic() {
        let cfg = GridConfig::generate(5, 32);
        let spec = WorkloadSpec { files: 5, mean_interarrival: 12.0, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(12);
        let opts = OpenLoopOptions {
            discovery: Some(DiscoveryOptions {
                drill_down: 2,
                fanout: FanoutPolicy { max_in_flight: 1, ..Default::default() },
                ..Default::default()
            }),
            ..OpenLoopOptions::open()
        };
        let run = || {
            run_quality_open(&cfg, &spec, &reqs, 3, 2, SelectorKind::Forecast, &opts, None)
        };
        let a = run();
        let b = run();
        assert_eq!(a.quality.mean_time, b.quality.mean_time);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.discovery, b.discovery);
    }

    #[test]
    fn gated_discovery_with_analytic_access_drains_every_arrival() {
        // Regression: an Analytic access after discovery frees its
        // admission slot with no flow-completion event — parked
        // arrivals must still be admitted (finish_discovery drains
        // the gate), not stranded until the event budget blows.
        let cfg = GridConfig::generate(5, 34);
        let spec = WorkloadSpec { files: 5, mean_interarrival: 2.0, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(10);
        let opts = OpenLoopOptions {
            access: AccessMode::Analytic,
            max_in_flight: 1,
            discovery: Some(DiscoveryOptions { drill_down: 2, ..Default::default() }),
            ..OpenLoopOptions::open()
        };
        let r = run_quality_open(&cfg, &spec, &reqs, 3, 2, SelectorKind::Forecast, &opts, None);
        assert_eq!(r.quality.requests, 10, "skipped {}", r.skipped);
        assert_eq!(r.skipped, 0);
    }

    #[test]
    fn unrefreshed_registrations_expire_and_requests_skip() {
        let cfg = GridConfig::generate(5, 33);
        let spec = WorkloadSpec { files: 5, mean_interarrival: 20.0, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(10);
        let opts = OpenLoopOptions {
            discovery: Some(DiscoveryOptions {
                registration_ttl: 1.0,
                refresh_period: f64::INFINITY, // registered once, never again
                ..Default::default()
            }),
            ..OpenLoopOptions::open()
        };
        let r = run_quality_open(&cfg, &spec, &reqs, 3, 2, SelectorKind::Forecast, &opts, None);
        assert_eq!(r.quality.requests + r.skipped, 10);
        assert!(
            r.skipped > 0,
            "1 s TTL with no refresh must make later requests undiscoverable"
        );
    }

    /// One site dies mid-transfer and never heals: without retry the
    /// request stalls to wind-down; with retry+failover it resumes on
    /// a survivor and completes.
    #[test]
    fn retry_failover_recovers_a_mid_flight_death() {
        let cfg = flat_cfg(3, 21);
        // One ~160 s transfer; kill whichever site was picked 10 s in.
        let spec = WorkloadSpec { files: 1, mean_interarrival: 1.0, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(1);
        let run = |retry: Option<RetryOptions>| {
            // Crash every site at t=10 for 1e9 s except one survivor:
            // we don't know the pick a priori, so kill sites 0 and 1
            // and replicate on all 3 — site 2 always survives.
            let faults: Vec<Fault> = (0..2)
                .map(|s| Fault {
                    site: s,
                    at: 10.0,
                    heal_at: f64::INFINITY,
                    kind: FaultKind::ReplicaDeath,
                })
                .collect();
            let opts = OpenLoopOptions {
                retry,
                faults,
                ..OpenLoopOptions::open()
            };
            run_quality_open(&cfg, &spec, &reqs, 3, 2, SelectorKind::Forecast, &opts, None)
        };
        let resilient = run(Some(RetryOptions {
            transfer_timeout: 20.0,
            backoff_base: 1.0,
            ..RetryOptions::default()
        }));
        assert_eq!(
            resilient.quality.requests + resilient.skipped,
            1,
            "gave_up {}",
            resilient.gave_up
        );
        if resilient.per_request.first().map(|t| t.site) != Some(2) {
            // The pick died mid-flight: the retry machine must have
            // failed over to the survivor and completed.
            assert_eq!(resilient.quality.requests, 1, "retry must complete the request");
            let t = &resilient.per_request[0];
            assert_eq!(t.site, 2, "failover must land on the survivor");
            assert!(t.retries >= 1);
            assert!(t.first_failure_at.is_some());
            assert!(resilient.failovers >= 1);
            assert_eq!(resilient.gave_up, 0);
        }
    }

    /// Same weather, identical seeds: fail-fast (attempt budget 1)
    /// must not beat retry+failover on completion rate, and with every
    /// replica of a file dead it gives up explicitly instead of
    /// stalling silently.
    #[test]
    fn attempt_budget_exhaustion_is_an_explicit_gave_up() {
        let cfg = flat_cfg(3, 22);
        let spec = WorkloadSpec { files: 2, mean_interarrival: 5.0, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(4);
        // The whole grid dies 10 s in and never heals.
        let faults: Vec<Fault> = (0..3)
            .map(|s| Fault {
                site: s,
                at: 10.0,
                heal_at: f64::INFINITY,
                kind: FaultKind::ReplicaDeath,
            })
            .collect();
        let opts = OpenLoopOptions {
            retry: Some(RetryOptions {
                transfer_timeout: 15.0,
                max_attempts: 3,
                backoff_base: 1.0,
                ..RetryOptions::default()
            }),
            faults,
            ..OpenLoopOptions::open()
        };
        let r = run_quality_open(&cfg, &spec, &reqs, 3, 2, SelectorKind::Forecast, &opts, None);
        assert_eq!(
            r.quality.requests + r.skipped + r.gave_up,
            4,
            "every request must be accounted for"
        );
        assert!(r.gave_up > 0, "a dead grid must exhaust attempt budgets");
        assert_eq!(r.quality.requests, 0, "nothing can complete on a dead grid");
    }

    /// Retry enabled but no weather scheduled: nothing stalls, so the
    /// progress checks never fire a retry and the run's outcome
    /// matches the retry-free configuration exactly.
    #[test]
    fn retry_is_inert_without_faults() {
        let cfg = GridConfig::generate(5, 23);
        let spec = WorkloadSpec { files: 5, mean_interarrival: 10.0, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(10);
        let base = run_quality_open(
            &cfg,
            &spec,
            &reqs,
            3,
            2,
            SelectorKind::Forecast,
            &OpenLoopOptions::open(),
            None,
        );
        let with_retry = run_quality_open(
            &cfg,
            &spec,
            &reqs,
            3,
            2,
            SelectorKind::Forecast,
            &OpenLoopOptions { retry: Some(RetryOptions::default()), ..OpenLoopOptions::open() },
            None,
        );
        assert_eq!(with_retry.retries, 0);
        assert_eq!(with_retry.failovers, 0);
        assert_eq!(with_retry.gave_up, 0);
        assert_eq!(base.quality.requests, with_retry.quality.requests);
        assert_eq!(base.skipped, with_retry.skipped);
        // The progress-check ticks subdivide the kernel's integration
        // intervals, so allow last-bit float drift but nothing more.
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-6 * a.abs().max(1.0);
        assert!(
            close(base.quality.mean_time, with_retry.quality.mean_time),
            "{} vs {}",
            base.quality.mean_time,
            with_retry.quality.mean_time
        );
        assert!(close(base.makespan, with_retry.makespan));
        assert!(close(base.quality.mean_bandwidth, with_retry.quality.mean_bandwidth));
    }

    /// A transfer interrupted mid-flight resumes from its delivered
    /// offset: the bytes delivered across all attempts equal the file
    /// size, not a multiple of it.
    #[test]
    fn resumed_transfers_do_not_refetch_delivered_bytes() {
        let cfg = flat_cfg(2, 24);
        let spec = WorkloadSpec { files: 1, mean_interarrival: 1.0, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(1);
        // Both replicas on both sites; the whole grid crashes at 45 s
        // (every Pareto-drawn file needs ≥ 53 s on the flat 1e6 B/s
        // links, so the crash is always mid-flight) and heals at 65 s:
        // the resume happens on a partially delivered file.
        let faults: Vec<Fault> = (0..2)
            .map(|s| Fault {
                site: s,
                at: 45.0,
                heal_at: 65.0,
                kind: FaultKind::ReplicaDeath,
            })
            .collect();
        let opts = OpenLoopOptions {
            retry: Some(RetryOptions {
                transfer_timeout: 10.0,
                max_attempts: 8,
                backoff_base: 2.0,
                backoff_max: 8.0,
                ..RetryOptions::default()
            }),
            faults,
            ..OpenLoopOptions::open()
        };
        let r = run_quality_open(&cfg, &spec, &reqs, 2, 2, SelectorKind::Forecast, &opts, None);
        assert_eq!(r.quality.requests, 1, "heal at 65 s must let the transfer finish");
        let t = &r.per_request[0];
        assert!(t.retries >= 1, "the crash must have forced at least one retry");
        assert_eq!(t.first_failure_at.map(|f| f > 0.0), Some(true));
        // Resume-from-offset pays the clean transfer time plus the
        // outage window and backoff slack (≈ +30 s); a full re-fetch
        // would additionally repay the ≥ 45 s of pre-crash bytes
        // (≈ +75 s). The +50 s bound separates the two.
        let size = Workload::file_sizes(&spec, cfg.seed, 80.0)[0];
        let clean = size / 1e6;
        assert!(
            t.duration < clean + 50.0,
            "resume-from-offset must not refetch delivered bytes \
             (took {:.0}s, clean transfer {clean:.0}s)",
            t.duration
        );
    }

    #[test]
    fn per_client_downlinks_bound_each_client() {
        let cfg = flat_cfg(3, 14);
        // One client, capped downlink, two dense arrivals: both flows
        // share the one client pipe, so each runs at ≤ cap.
        let spec = WorkloadSpec {
            files: 2,
            clients: 1,
            mean_interarrival: 1.0,
            constrained_frac: 0.0,
            ..Default::default()
        };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(2);
        let capped = OpenLoopOptions {
            client_downlink: 0.25e6,
            ..OpenLoopOptions::open()
        };
        let r = run_quality_open(&cfg, &spec, &reqs, 2, 1, SelectorKind::Forecast, &capped, None);
        assert_eq!(r.quality.requests, 2);
        for t in &r.per_request {
            assert!(
                t.bandwidth <= 0.25e6 + 1.0,
                "flow exceeded the client downlink: {} B/s",
                t.bandwidth
            );
        }
    }
}
