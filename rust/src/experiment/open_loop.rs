//! Open-loop experiment drivers on the discrete-event kernel
//! (ISSUE 4) — the contention regime the serial replay cannot reach.
//!
//! [`run_quality_open`] replays a request trace with arrivals admitted
//! at their Poisson instants on a [`crate::simnet::Engine`]: each
//! admitted request selects a replica against *live* in-flight load
//! (site dynamics republished at every admission, plus optional
//! periodic GRIS refresh ticks) and its transfer then occupies the
//! grid — a flow in the one shared `FlowSet` — until its completion
//! event fires, contending with every other in-flight transfer for
//! site links and per-client downlinks. With
//! [`OpenLoopOptions::serial`] the driver degrades to the legacy
//! closed-loop semantics exactly (concurrency 1, closed-form Access):
//! the `it_contention` parity test asserts bit-for-bit agreement with
//! [`super::run_quality_trace`].
//!
//! [`run_contention`] is the load sweep the paper's thesis wants:
//! arrival rate from idle to saturation, informed (Forecast) vs
//! uninformed (Random) selection on identical traces, reporting
//! mean/p95 time, makespan and the informed-vs-uninformed gap as
//! contention grows (`bench_contention` records it as
//! `BENCH_contention.json`).

use std::collections::{BTreeMap, VecDeque};

use crate::broker::selectors::{Selector, SelectorKind};
use crate::broker::{Broker, RankPolicy};
use crate::config::GridConfig;
use crate::gridftp::OpenFetch;
use crate::simnet::{Engine, FlowSet, Request, Signal, Workload, WorkloadSpec};

use super::grid::SimGrid;
use super::quality::{finish_report, pick_replica, request_ad, QualityReport};

/// Timer id of the recurring GRIS dynamics refresh.
const GRIS_TICK_ID: u64 = u64::MAX;

/// How the open-loop driver executes an admitted request's Access
/// phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessMode {
    /// The legacy closed-form fetch (`GridFtp::fetch`): costed
    /// analytically at the admission instant, consuming no simulated
    /// time — the serial replay's semantics.
    Analytic,
    /// The transfer is registered as a flow in the kernel's shared
    /// `FlowSet` (`GridFtp::fetch_begin`); it occupies its site link
    /// and the client's downlink until the completion event fires, so
    /// concurrent requests contend.
    Flow,
}

/// Configuration of one open-loop run.
#[derive(Debug, Clone)]
pub struct OpenLoopOptions {
    pub access: AccessMode,
    /// Admission cap: arrivals beyond this many in-flight transfers
    /// queue FIFO and are admitted at completion instants.
    /// `usize::MAX` = pure open loop (no gate).
    pub max_in_flight: usize,
    /// Per-client downlink capacity in [`AccessMode::Flow`] (bytes/s);
    /// flows of the same workload client share it, different clients
    /// cap independently. `f64::INFINITY` leaves the WAN links as the
    /// only bottleneck.
    pub client_downlink: f64,
    /// Period of the recurring GRIS dynamics refresh tick; dynamics
    /// are also republished at every admission. `f64::INFINITY` =
    /// admission-driven refresh only.
    pub gris_refresh: f64,
}

impl OpenLoopOptions {
    /// Pure open loop: flow-based Access, no admission gate.
    pub fn open() -> OpenLoopOptions {
        OpenLoopOptions {
            access: AccessMode::Flow,
            max_in_flight: usize::MAX,
            client_downlink: f64::INFINITY,
            gris_refresh: f64::INFINITY,
        }
    }

    /// The serial-replay configuration: concurrency 1 with the
    /// analytic Access primitive — the kernel expression of the legacy
    /// `run_quality_trace` loop, reproduced bit-for-bit (the parity
    /// anchor).
    pub fn serial() -> OpenLoopOptions {
        OpenLoopOptions {
            access: AccessMode::Analytic,
            max_in_flight: 1,
            ..OpenLoopOptions::open()
        }
    }
}

/// One request's life on the kernel.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    /// Index into the input request trace.
    pub request: usize,
    /// Topology index of the chosen source.
    pub site: usize,
    /// Admission instant (= arrival unless the admission gate queued
    /// it).
    pub admitted_at: f64,
    pub finished_at: f64,
    pub duration: f64,
    pub bandwidth: f64,
    /// The clairvoyant oracle's best probe duration at admission.
    pub oracle_best: f64,
    /// Whether the policy picked the oracle-best replica.
    pub hit_optimal: bool,
}

/// Aggregate + per-request outcome of one open-loop run.
#[derive(Debug, Clone)]
pub struct OpenReport {
    pub quality: QualityReport,
    /// Simulated span from first admission to last completion.
    pub makespan: f64,
    /// Peak number of flow-based transfers simultaneously in flight
    /// (0 in the analytic configuration — those consume no time).
    pub peak_in_flight: usize,
    /// Admissions that happened while at least one transfer was
    /// already in flight — the overlap the serial replay forbids.
    pub overlapped_admissions: usize,
    /// Requests that never delivered: dead source at admission,
    /// transfers still stalled when the run wound down (their slots
    /// are released), or arrivals parked behind the admission gate at
    /// the end. `quality` covers only completed requests, so compare
    /// policies with an eye on this count.
    pub skipped: usize,
    /// Completed requests in completion order, with their flow
    /// start/finish instants — the data the overlap assertions and the
    /// contention bench read.
    pub per_request: Vec<RequestTrace>,
}

struct InFlight {
    request: usize,
    open: OpenFetch,
    oracle_best: f64,
    hit_optimal: bool,
}

/// Everything one open-loop run mutates, so the admission logic is a
/// method instead of a 12-argument function.
struct Driver<'a> {
    grid: &'a mut SimGrid,
    broker: Broker,
    selector: Selector,
    kind: SelectorKind,
    opts: &'a OpenLoopOptions,
    requests: &'a [Request],
    /// Workload client id → downlink group in the shared FlowSet.
    groups: Vec<usize>,
    /// Live flow id → in-flight transfer state.
    inflight: BTreeMap<usize, InFlight>,
    /// Arrivals parked by the admission gate, FIFO.
    waiting: VecDeque<u64>,
    finished: Vec<RequestTrace>,
    peak_in_flight: usize,
    overlapped_admissions: usize,
    skipped: usize,
}

impl Driver<'_> {
    /// Admit one request *now*: republish dynamics, select against the
    /// live grid, then run the Access phase per the configured mode.
    fn admit(&mut self, eng: &mut Engine, id: u64) {
        let req = &self.requests[id as usize];
        self.grid.publish_dynamics();
        let logical = self.grid.files[req.file].clone();
        let size = self.grid.sizes[req.file];
        let ad = request_ad(req.min_bandwidth);
        let pick = pick_replica(
            self.grid,
            &self.broker,
            &mut self.selector,
            self.kind,
            &logical,
            size,
            &ad,
        );
        let overlapping = !self.inflight.is_empty();
        match self.opts.access {
            AccessMode::Analytic => {
                if overlapping {
                    self.overlapped_admissions += 1;
                }
                let out = self
                    .grid
                    .ftp
                    .fetch(&mut self.grid.topo, pick.pick_site, "client", size);
                let now = self.grid.topo.now;
                self.finished.push(RequestTrace {
                    request: id as usize,
                    site: pick.pick_site,
                    admitted_at: now,
                    finished_at: now + out.duration,
                    duration: out.duration,
                    bandwidth: out.bandwidth,
                    oracle_best: pick.best_oracle,
                    hit_optimal: pick.pick_site == pick.best_site,
                });
            }
            AccessMode::Flow => {
                let group = self.groups[req.client % self.groups.len()];
                match self.grid.ftp.fetch_begin(
                    eng,
                    &mut self.grid.topo,
                    pick.pick_site,
                    "client",
                    size,
                    group,
                ) {
                    Ok(open) => {
                        // Count the overlap only once the transfer
                        // actually occupies the grid.
                        if overlapping {
                            self.overlapped_admissions += 1;
                        }
                        self.inflight.insert(
                            open.flow,
                            InFlight {
                                request: id as usize,
                                open,
                                oracle_best: pick.best_oracle,
                                hit_optimal: pick.pick_site == pick.best_site,
                            },
                        );
                        self.peak_in_flight = self.peak_in_flight.max(self.inflight.len());
                    }
                    Err(_) => self.skipped += 1,
                }
            }
        }
    }

    /// A flow completion from the kernel: finish the fetch (slot
    /// release + instrumentation record), then let the admission gate
    /// drain its queue at this instant.
    fn complete(&mut self, eng: &mut Engine, c: &crate::simnet::Completion) {
        let fi = match self.inflight.remove(&c.flow) {
            Some(fi) => fi,
            None => return,
        };
        let out = self.grid.ftp.fetch_finish(&mut self.grid.topo, &fi.open, c.at);
        self.finished.push(RequestTrace {
            request: fi.request,
            site: fi.open.site,
            admitted_at: fi.open.started_at,
            finished_at: c.at,
            duration: out.duration,
            bandwidth: out.bandwidth,
            oracle_best: fi.oracle_best,
            hit_optimal: fi.hit_optimal,
        });
        while self.inflight.len() < self.opts.max_in_flight {
            match self.waiting.pop_front() {
                Some(id) => self.admit(eng, id),
                None => break,
            }
        }
    }
}

/// Replay an explicit request trace open-loop on the event kernel and
/// score it against the clairvoyant oracle, exactly like
/// [`super::run_quality_trace`] scores the serial replay. `engine` is
/// the optional PJRT forecast artifact for the `Forecast` selector
/// (None → pure-Rust bank; numerically equivalent).
#[allow(clippy::too_many_arguments)]
pub fn run_quality_open(
    cfg: &GridConfig,
    spec: &WorkloadSpec,
    requests: &[Request],
    replicas_per_file: usize,
    warm: usize,
    kind: SelectorKind,
    opts: &OpenLoopOptions,
    engine: Option<std::sync::Arc<crate::runtime::engine::EngineHandle>>,
) -> OpenReport {
    let mut grid = SimGrid::build(cfg, spec, replicas_per_file, 64);
    grid.warm(warm);
    let selector = Selector::new(kind, cfg.seed);
    let policy = match kind {
        SelectorKind::Forecast => RankPolicy::ForecastBandwidth { engine },
        _ => RankPolicy::ClassAdRank,
    };
    let broker = grid.broker(policy);

    let mut eng = Engine::new(FlowSet::new(f64::INFINITY));
    // Group 0 of the base set stays empty; every workload client gets
    // its own downlink group so client pipes cap independently.
    let groups: Vec<usize> = (0..spec.clients.max(1))
        .map(|_| eng.flows.add_group(opts.client_downlink))
        .collect();
    // Arrivals are absolute offsets from the post-warm clock — the
    // same arithmetic the serial replay uses, so concurrency 1 with
    // analytic Access reproduces it bit-for-bit.
    let t0 = grid.topo.now;
    for (i, r) in requests.iter().enumerate() {
        eng.schedule_arrival(t0 + r.at, i as u64);
    }
    if opts.gris_refresh.is_finite() && opts.gris_refresh > 0.0 {
        eng.schedule_tick(t0 + opts.gris_refresh, GRIS_TICK_ID);
    }

    let mut driver = Driver {
        grid: &mut grid,
        broker,
        selector,
        kind,
        opts,
        requests,
        groups,
        inflight: BTreeMap::new(),
        waiting: VecDeque::new(),
        finished: Vec::new(),
        peak_in_flight: 0,
        overlapped_admissions: 0,
        skipped: 0,
    };

    // Event budget: arrivals + completions + GRIS ticks for any sane
    // run fit easily; a stalled-but-ticking grid (faulted sources with
    // a finite refresh period) terminates instead of spinning.
    let max_events = 1_000_000 + 100 * requests.len();
    let mut events = 0usize;
    while driver.finished.len() + driver.skipped < requests.len() {
        events += 1;
        if events > max_events {
            break;
        }
        match eng.next(&mut driver.grid.topo) {
            Some(Signal::Arrival { id, .. }) => {
                if driver.inflight.len() < driver.opts.max_in_flight {
                    driver.admit(&mut eng, id);
                } else {
                    driver.waiting.push_back(id);
                }
            }
            Some(Signal::FlowDone(c)) => driver.complete(&mut eng, &c),
            Some(Signal::Tick { .. }) => {
                driver.grid.publish_dynamics();
                let next = driver.grid.topo.now + driver.opts.gris_refresh;
                eng.schedule_tick(next, GRIS_TICK_ID);
            }
            // Stalled in-flight transfers with nothing scheduled:
            // whatever completed is the result.
            None => break,
        }
    }

    // Wind down whatever never finished (stalled flows on faulted
    // sources, or a blown event budget): release the transfer slots
    // they still hold and surface them as `skipped` rather than
    // silently shrinking the report — the per-policy comparisons in
    // `run_contention` read `skipped` to know the means cover
    // different request subsets. Parked arrivals count too.
    for (flow, fi) in std::mem::take(&mut driver.inflight) {
        eng.flows.cancel(flow);
        driver.grid.topo.end_transfer(fi.open.site);
        driver.skipped += 1;
    }
    driver.skipped += driver.waiting.len();

    let mut durations = Vec::with_capacity(driver.finished.len());
    let mut bandwidths = Vec::with_capacity(driver.finished.len());
    let mut slowdowns = Vec::with_capacity(driver.finished.len());
    let mut optimal_hits = 0usize;
    for r in &driver.finished {
        durations.push(r.duration);
        bandwidths.push(r.bandwidth);
        slowdowns.push(r.duration / r.oracle_best.max(1e-9));
        if r.hit_optimal {
            optimal_hits += 1;
        }
    }
    let makespan = if driver.finished.is_empty() {
        0.0
    } else {
        let first = driver
            .finished
            .iter()
            .map(|r| r.admitted_at)
            .fold(f64::INFINITY, f64::min);
        let last = driver
            .finished
            .iter()
            .map(|r| r.finished_at)
            .fold(f64::NEG_INFINITY, f64::max);
        (last - first).max(0.0)
    };
    OpenReport {
        quality: finish_report(kind.name(), durations, &bandwidths, &slowdowns, optimal_hits),
        makespan,
        peak_in_flight: driver.peak_in_flight,
        overlapped_admissions: driver.overlapped_admissions,
        skipped: driver.skipped,
        per_request: driver.finished,
    }
}

/// One arrival-rate point of the load sweep.
#[derive(Debug, Clone)]
pub struct ContentionPoint {
    /// Mean request inter-arrival at this point (s).
    pub mean_interarrival: f64,
    /// Informed selection (Forecast policy) under this load.
    pub informed: OpenReport,
    /// Uninformed baseline (Random) on the identical trace.
    pub uninformed: OpenReport,
    /// `uninformed mean time / informed mean time` (> 1 ⇒ dynamic
    /// information pays; the paper's claim is that it pays *more* as
    /// contention grows).
    pub gap: f64,
}

/// The full idle-to-saturation sweep.
#[derive(Debug, Clone)]
pub struct ContentionReport {
    pub points: Vec<ContentionPoint>,
}

/// Sweep arrival rate from idle to saturation (`interarrivals`, mean
/// seconds between requests, typically descending) and replay
/// `n_requests` open-loop at each point under informed (Forecast) and
/// uninformed (Random) selection — identical traces, identically
/// seeded grids. This is the Figure-style result the serial replay
/// could never produce: how much dynamic, load-aware selection buys as
/// cross-request contention grows.
pub fn run_contention(
    cfg: &GridConfig,
    spec: &WorkloadSpec,
    n_requests: usize,
    replicas_per_file: usize,
    warm: usize,
    interarrivals: &[f64],
    opts: &OpenLoopOptions,
) -> ContentionReport {
    let points = interarrivals
        .iter()
        .map(|&ia| {
            let s = WorkloadSpec { mean_interarrival: ia, ..spec.clone() };
            let reqs = Workload::new(s.clone(), cfg.seed).take(n_requests);
            let informed = run_quality_open(
                cfg,
                &s,
                &reqs,
                replicas_per_file,
                warm,
                SelectorKind::Forecast,
                opts,
                None,
            );
            let uninformed = run_quality_open(
                cfg,
                &s,
                &reqs,
                replicas_per_file,
                warm,
                SelectorKind::Random,
                opts,
                None,
            );
            let gap = if informed.quality.mean_time > 0.0 {
                uninformed.quality.mean_time / informed.quality.mean_time
            } else {
                1.0
            };
            ContentionPoint { mean_interarrival: ia, informed, uninformed, gap }
        })
        .collect();
    ContentionReport { points }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic links: durations depend only on concurrency.
    fn flat_cfg(n: usize, seed: u64) -> GridConfig {
        let mut cfg = GridConfig::generate(n, seed);
        for s in &mut cfg.sites {
            s.wan_bandwidth = 1e6;
            s.diurnal_amp = 0.0;
            s.noise_frac = 0.0;
            s.congestion_prob = 0.0;
            s.ar_coeff = 0.0;
            s.latency = 0.0;
            s.drd_time_ms = 0.0;
            s.disk_rate = 1e9;
        }
        cfg
    }

    #[test]
    fn open_loop_is_deterministic() {
        let cfg = GridConfig::generate(5, 901);
        let spec = WorkloadSpec { files: 6, mean_interarrival: 20.0, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(15);
        let run = || {
            run_quality_open(
                &cfg,
                &spec,
                &reqs,
                3,
                2,
                SelectorKind::Forecast,
                &OpenLoopOptions::open(),
                None,
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a.quality.mean_time, b.quality.mean_time);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.peak_in_flight, b.peak_in_flight);
        assert_eq!(a.overlapped_admissions, b.overlapped_admissions);
    }

    #[test]
    fn dense_arrivals_overlap_and_complete() {
        let cfg = flat_cfg(4, 11);
        // ~160 s transfers arriving every ~5 s: deep overlap.
        let spec = WorkloadSpec { files: 6, mean_interarrival: 5.0, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(12);
        let r = run_quality_open(
            &cfg,
            &spec,
            &reqs,
            3,
            2,
            SelectorKind::Forecast,
            &OpenLoopOptions::open(),
            None,
        );
        assert_eq!(r.quality.requests, 12, "every request completes");
        assert_eq!(r.skipped, 0);
        assert!(r.peak_in_flight >= 2, "peak {}", r.peak_in_flight);
        assert!(r.overlapped_admissions > 0);
        // At least one pair of transfers overlapped in time.
        let overlaps = r.per_request.iter().any(|a| {
            r.per_request.iter().any(|b| {
                a.request != b.request
                    && a.admitted_at < b.finished_at
                    && b.admitted_at < a.finished_at
            })
        });
        assert!(overlaps, "no overlapping transfer intervals recorded");
    }

    #[test]
    fn admission_gate_serializes_flow_transfers() {
        let cfg = flat_cfg(4, 12);
        let spec = WorkloadSpec { files: 6, mean_interarrival: 5.0, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(8);
        let opts = OpenLoopOptions {
            max_in_flight: 1,
            ..OpenLoopOptions::open()
        };
        let r = run_quality_open(&cfg, &spec, &reqs, 3, 2, SelectorKind::Forecast, &opts, None);
        assert_eq!(r.quality.requests, 8);
        assert_eq!(r.peak_in_flight, 1);
        assert_eq!(r.overlapped_admissions, 0);
        // Gated transfers must not overlap in time.
        let mut spans: Vec<(f64, f64)> = r
            .per_request
            .iter()
            .map(|t| (t.admitted_at, t.finished_at))
            .collect();
        spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in spans.windows(2) {
            assert!(w[1].0 >= w[0].1 - 1e-9, "gated spans overlap: {w:?}");
        }
    }

    #[test]
    fn contention_slows_transfers() {
        let cfg = flat_cfg(4, 13);
        let spec = WorkloadSpec { files: 6, ..Default::default() };
        let sweep = run_contention(&cfg, &spec, 10, 3, 2, &[1e6, 5.0], &OpenLoopOptions::open());
        assert_eq!(sweep.points.len(), 2);
        let idle = &sweep.points[0];
        let busy = &sweep.points[1];
        // On flat links duration is purely a function of concurrency:
        // the saturated point must be slower than the (near-)idle one,
        // whatever either policy picked.
        assert!(
            busy.informed.quality.mean_time > idle.informed.quality.mean_time,
            "busy {:.1}s !> idle {:.1}s",
            busy.informed.quality.mean_time,
            idle.informed.quality.mean_time
        );
        assert!(
            busy.informed.overlapped_admissions > idle.informed.overlapped_admissions,
            "saturation must overlap more: busy {} !> idle {}",
            busy.informed.overlapped_admissions,
            idle.informed.overlapped_admissions
        );
        assert!(busy.gap > 0.0);
    }

    #[test]
    fn per_client_downlinks_bound_each_client() {
        let cfg = flat_cfg(3, 14);
        // One client, capped downlink, two dense arrivals: both flows
        // share the one client pipe, so each runs at ≤ cap.
        let spec = WorkloadSpec {
            files: 2,
            clients: 1,
            mean_interarrival: 1.0,
            constrained_frac: 0.0,
            ..Default::default()
        };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(2);
        let capped = OpenLoopOptions {
            client_downlink: 0.25e6,
            ..OpenLoopOptions::open()
        };
        let r = run_quality_open(&cfg, &spec, &reqs, 2, 1, SelectorKind::Forecast, &capped, None);
        assert_eq!(r.quality.requests, 2);
        for t in &r.per_request {
            assert!(
                t.bandwidth <= 0.25e6 + 1.0,
                "flow exceeded the client downlink: {} B/s",
                t.bandwidth
            );
        }
    }
}
