//! Sharded broker runs (ISSUE 8 tentpole): the open-loop driver with
//! its control plane partitioned along the PR 5 registration
//! hierarchy.
//!
//! [`run_quality_sharded`] is [`super::run_quality_open`] plus a
//! [`ShardOptions`]: the grid's sites are split into contiguous shards
//! ([`crate::broker::ShardMap`]), each shard runs its own GIIS
//! registration domain (its sites soft-state register only there) and
//! its own **admission batch** — arrivals queue per home shard and
//! flush together, republishing site dynamics once per flush instead
//! of once per admission. Requests whose replica set spans shards pay
//! a *cross-shard consult*: their drill-downs and snapshot reads hit
//! foreign domains, counted in
//! [`ShardedReport::cross_shard_selections`].
//!
//! The parity contract (same discipline as PRs 4–7): the
//! [`ShardOptions::parity`] configuration — 1 shard, batch size 1 —
//! collapses every sharded code path onto the unsharded one
//! operation-for-operation, and the `it_shard` suite pins the two
//! reports bit-for-bit. Scaling knobs only ever *add* behaviour.

use crate::broker::selectors::SelectorKind;
use crate::config::GridConfig;
use crate::simnet::{Request, WorkloadSpec};

use super::open_loop::{run_open_internal, OpenLoopOptions, OpenReport};

/// Control-plane partitioning knobs for one sharded run.
#[derive(Debug, Clone, Copy)]
pub struct ShardOptions {
    /// Number of broker shards; clamped to `[1, sites]`.
    pub shards: usize,
    /// Admissions batched per shard before a flush (≥ 1). 1 flushes
    /// every arrival at its own instant — no batching delay at all.
    pub batch_max: usize,
    /// Maximum simulated seconds an arrival waits in a partial batch
    /// before a window timer flushes it. Non-finite or ≤ 0 disables
    /// the timer: batches then flush only when full, and leftovers are
    /// wound down as skipped.
    pub batch_window: f64,
}

impl Default for ShardOptions {
    fn default() -> Self {
        ShardOptions { shards: 4, batch_max: 8, batch_window: 5.0 }
    }
}

impl ShardOptions {
    /// The parity configuration: one shard, no batching. Runs the
    /// sharded code path but is bit-identical to the unsharded driver
    /// (the `it_shard` anchor).
    pub fn parity() -> ShardOptions {
        ShardOptions { shards: 1, batch_max: 1, batch_window: 0.0 }
    }
}

/// Per-shard accounting of one sharded run. The driver maintains the
/// conservation invariant
/// `finished + skipped + gave_up == arrivals`
/// exactly, per shard — every arrival routed to a shard is eventually
/// attributed back to it, whatever its fate.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Arrivals whose home shard this is.
    pub arrivals: usize,
    /// Arrivals that reached admission (selection ran).
    pub admitted: usize,
    /// Requests that delivered their last byte.
    pub finished: usize,
    /// Requests skipped (undiscoverable, no replica, dead source,
    /// wind-down — including arrivals still in an unflushed batch).
    pub skipped: usize,
    /// Requests that exhausted their retry attempt budget.
    pub gave_up: usize,
    /// Admission-batch flushes (full batches + window-timer fires).
    pub flushes: usize,
}

/// A sharded run's outcome: the ordinary open-loop report plus the
/// shard-level telemetry.
#[derive(Debug, Clone)]
pub struct ShardedReport {
    pub open: OpenReport,
    /// Per-shard accounting, indexed by shard.
    pub shards: Vec<ShardStats>,
    /// Admissions whose replica set spanned shard boundaries — the
    /// selections that consulted foreign registration domains.
    pub cross_shard_selections: usize,
}

/// [`super::run_quality_open`] under a sharded control plane. Same
/// grid, same workload, same selection policy — only the information
/// plane (registration domains) and the admission cadence (per-shard
/// batches) change.
#[allow(clippy::too_many_arguments)]
pub fn run_quality_sharded(
    cfg: &GridConfig,
    spec: &WorkloadSpec,
    requests: &[Request],
    replicas_per_file: usize,
    warm: usize,
    kind: SelectorKind,
    opts: &OpenLoopOptions,
    shard: &ShardOptions,
    engine: Option<std::sync::Arc<crate::runtime::engine::EngineHandle>>,
) -> ShardedReport {
    let (open, telemetry) = run_open_internal(
        cfg,
        spec,
        requests,
        replicas_per_file,
        warm,
        kind,
        opts,
        engine,
        Some(shard),
        None,
    );
    let t = telemetry.expect("sharded run returns shard telemetry");
    ShardedReport { open, shards: t.stats, cross_shard_selections: t.cross_shard }
}
