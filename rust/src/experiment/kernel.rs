//! Kernel throughput sweep (ISSUE 8): how many events per second the
//! allocation-free discrete-event kernel sustains with 10⁵–10⁶
//! transfers simultaneously in flight.
//!
//! The workload is a *day of traffic* compressed to its stress shape:
//! a **surge** of `surge` requests all arriving at the same instant
//! (the kernel pops same-instant events back-to-back with no
//! integration between them, so admission is a linear ramp straight to
//! peak concurrency) followed by a **trickle** spread uniformly over
//! the remaining day, each trickle event integrating the full flow set
//! forward. The run is bounded by an explicit event budget rather than
//! by completion — at 10⁵ concurrent flows a full drain is quadratic
//! and is not what the bench certifies. What it certifies:
//!
//! * the surge reaches `peak_in_flight ≥ surge` (every arrival was
//!   admitted and concurrently in flight), and
//! * `events / wall_s` — mixed admissions, completions and
//!   integration steps per wall-clock second — on the steady state
//!   that allocates nothing: arena event queue, SoA flow columns,
//!   scratch-buffered rate recomputes.
//!
//! The control plane runs sharded ([`super::sharded`]): per-shard
//! admission batches republish site dynamics once per flush instead of
//! once per admission — at 10⁵ admissions over hundreds of sites that
//! is the difference between O(surge·sites) and O(flushes·sites)
//! publish work. `benches/bench_kernel.rs` records the sweep as
//! `BENCH_kernel.json`.

use std::time::Instant;

use crate::broker::selectors::SelectorKind;
use crate::config::GridConfig;
use crate::simnet::{Request, WorkloadSpec};
use crate::util::prng::Rng;

use super::open_loop::{run_open_internal, OpenLoopOptions};
use super::sharded::ShardOptions;

/// One kernel-throughput point.
#[derive(Debug, Clone)]
pub struct KernelOptions {
    /// Topology size.
    pub sites: usize,
    pub seed: u64,
    /// Requests arriving at the same post-warm instant — the
    /// concurrency level the point certifies.
    pub surge: usize,
    /// Requests spread uniformly over the rest of the day.
    pub trickle: usize,
    /// Day length in simulated seconds (the trickle span).
    pub day_s: f64,
    /// Logical catalog size.
    pub files: usize,
    pub replicas_per_file: usize,
    /// Control-plane sharding for the run.
    pub shard: ShardOptions,
    /// Kernel events to process beyond the arrivals before the run is
    /// cut off (completions + integration at peak concurrency).
    pub steady_events: usize,
}

impl Default for KernelOptions {
    fn default() -> Self {
        KernelOptions {
            sites: 64,
            seed: 0x8E0_57A7E,
            surge: 100_000,
            trickle: 2_000,
            day_s: 86_400.0,
            files: 512,
            replicas_per_file: 4,
            shard: ShardOptions { shards: 8, batch_max: 64, batch_window: 1.0 },
            steady_events: 2_000,
        }
    }
}

/// Headline numbers of one kernel-throughput run.
#[derive(Debug, Clone)]
pub struct KernelReport {
    /// Requests in the trace (`surge + trickle`).
    pub requests: usize,
    /// The surge size — the concurrency level this point certifies.
    pub concurrent: usize,
    /// Peak simultaneously in-flight transfers actually reached.
    pub peak_in_flight: usize,
    /// Kernel events processed before the budget cut the run off.
    pub events: usize,
    /// Wall-clock seconds of the event loop (build + warm excluded
    /// would be better still, but they are O(sites) noise at this
    /// scale; the loop dominates).
    pub wall_s: f64,
    /// `events / wall_s` — the headline.
    pub events_per_sec: f64,
    pub finished: usize,
    pub skipped: usize,
    pub gave_up: usize,
    /// Selections whose replica set spanned shard boundaries.
    pub cross_shard_selections: usize,
    /// Admission-batch flushes across all shards.
    pub flushes: usize,
}

/// Build the surge + trickle trace. Deterministic in `opts.seed`: file
/// picks come from a dedicated stream, arrival instants are closed
/// form.
fn kernel_trace(o: &KernelOptions) -> Vec<Request> {
    let files = o.files.max(1);
    let mut rng = Rng::new(o.seed ^ 0x4B52_4E4C); // "KRNL"
    let mut pick = |rng: &mut Rng| (rng.range(0.0, files as f64) as usize).min(files - 1);
    let mut requests = Vec::with_capacity(o.surge + o.trickle);
    for i in 0..o.surge {
        requests.push(Request {
            at: 0.0,
            client: i,
            file: pick(&mut rng),
            min_bandwidth: 0.0,
        });
    }
    for j in 0..o.trickle {
        requests.push(Request {
            at: o.day_s * (j as f64 + 1.0) / (o.trickle as f64 + 1.0),
            client: o.surge + j,
            file: pick(&mut rng),
            min_bandwidth: 0.0,
        });
    }
    requests
}

/// Run one kernel-throughput point: ungated open loop (no admission
/// cap, no GRIS tick, no discovery — the pure data-plane steady
/// state), sharded control plane, event-budgeted.
pub fn run_kernel(o: &KernelOptions) -> KernelReport {
    let cfg = GridConfig::generate(o.sites, o.seed);
    let spec = WorkloadSpec {
        clients: 64,
        files: o.files.max(1),
        constrained_frac: 0.0,
        ..Default::default()
    };
    let requests = kernel_trace(o);
    let opts = OpenLoopOptions::open();
    let budget = requests.len() + o.steady_events;
    let t = Instant::now();
    let (open, telemetry) = run_open_internal(
        &cfg,
        &spec,
        &requests,
        o.replicas_per_file,
        1,
        SelectorKind::Forecast,
        &opts,
        None,
        Some(&o.shard),
        Some(budget),
    );
    let wall_s = t.elapsed().as_secs_f64();
    let telemetry = telemetry.expect("sharded kernel run returns telemetry");
    KernelReport {
        requests: requests.len(),
        concurrent: o.surge,
        peak_in_flight: open.peak_in_flight,
        events: open.events,
        wall_s,
        events_per_sec: open.events as f64 / wall_s.max(1e-9),
        finished: open.quality.requests,
        skipped: open.skipped,
        gave_up: open.gave_up,
        cross_shard_selections: telemetry.cross_shard,
        flushes: telemetry.stats.iter().map(|s| s.flushes).sum(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A small point (not 10⁵ — that is the bench's job) must reach
    /// full surge concurrency and account for every request.
    #[test]
    fn surge_reaches_full_concurrency() {
        let o = KernelOptions {
            sites: 6,
            surge: 40,
            trickle: 5,
            files: 16,
            steady_events: 10_000,
            shard: ShardOptions { shards: 2, batch_max: 8, batch_window: 1.0 },
            ..Default::default()
        };
        let r = run_kernel(&o);
        assert_eq!(r.requests, 45);
        assert!(
            r.peak_in_flight >= 40,
            "surge must be fully concurrent, peak {}",
            r.peak_in_flight
        );
        assert!(r.events > 0 && r.events_per_sec > 0.0);
        assert!(r.flushes >= 1);
        assert_eq!(r.finished + r.skipped + r.gave_up, 45, "every request accounted");
    }

    #[test]
    fn kernel_point_is_deterministic_in_sim_outcomes() {
        let o = KernelOptions {
            sites: 5,
            surge: 25,
            trickle: 3,
            files: 8,
            steady_events: 5_000,
            ..Default::default()
        };
        let a = run_kernel(&o);
        let b = run_kernel(&o);
        // Wall time differs run to run; the simulated outcomes do not.
        assert_eq!(a.events, b.events);
        assert_eq!(a.peak_in_flight, b.peak_in_flight);
        assert_eq!(a.finished, b.finished);
        assert_eq!(a.skipped, b.skipped);
        assert_eq!(a.cross_shard_selections, b.cross_shard_selections);
        assert_eq!(a.flushes, b.flushes);
    }
}
